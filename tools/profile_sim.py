"""Profile the simulator's hot paths: cProfile + jax.profiler harness.

Runs a canned bench_async-style configuration (M=16 apps by default,
heterogeneous compute, >=10% churn, real training in the loop) under
cProfile, prints the top-20 cumulative hot spots, and writes trace
artifacts:

- ``<out>/cprofile.pstats`` — the full cProfile dump
  (``python -m pstats`` or snakeviz to explore);
- ``<out>/jax-trace/`` — a ``jax.profiler`` trace (open in Perfetto /
  TensorBoard) covering the same run, so XLA compile vs execute time is
  attributable alongside the Python-side event engine.

Usage (see README "Profiling"):

    PYTHONPATH=src python tools/profile_sim.py                 # optimized paths
    PYTHONPATH=src python tools/profile_sim.py --baseline      # pre-optimization
    PYTHONPATH=src python tools/profile_sim.py --m 4 --applies 2 --top 30

This is how the hot-path PR's before/after map in docs/performance.md
was produced: ``--baseline`` selects the legacy engines (Pallas
interpret kernels, per-version dispatch, full-water-filling repricing)
so the two profiles are directly comparable.
"""
from __future__ import annotations

import argparse
import cProfile
import io
import os
import pstats
import resource
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def canned_run(*, m_apps: int, applies: int, workers: int, seed: int,
               optimized: bool) -> dict:
    """The canned workload: identical to a bench_hotpath trained run."""
    from benchmarks.bench_async import _make_apps
    from benchmarks.common import build_system
    from repro.core.sim import ChurnModel
    from repro.fl import async_engine, engine
    from repro.kernels import ops as kops

    base_ms, spread = 40.0, 6.0
    per_worker = async_engine.worker_compute_fn(base_ms, spread, seed=seed)
    sys_a, nodes_a, rng_a = build_system(n_nodes=600, zones=4, seed=seed)
    apps_a = _make_apps(sys_a, nodes_a, rng_a, m_apps, workers, tag="p")
    churn = ChurnModel(
        period_ms=6.0 * base_ms, downtime_ms=12.0 * base_ms,
        group_size=max(1, round(0.1 * workers)), seed=seed,
    )
    prev_mode = kops.set_kernel_mode("auto" if optimized else "pallas")
    prev_bucketing = engine.set_bucketing(optimized)
    try:
        return async_engine.run_async(
            sys_a, apps_a, applies=applies, buffer_k=max(2, workers // 2),
            staleness_alpha=0.5, model_bytes=2e5, compute_ms=per_worker,
            churn=churn, megabatch=optimized, incremental=optimized,
        )
    finally:
        kops.set_kernel_mode(prev_mode)
        engine.set_bucketing(prev_bucketing)


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--m", type=int, default=16, help="concurrent apps (default 16)")
    ap.add_argument("--applies", type=int, default=3, help="buffered applies per app")
    ap.add_argument("--workers", type=int, default=8, help="workers per app")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--top", type=int, default=20, help="hot spots to print")
    ap.add_argument("--baseline", action="store_true",
                    help="profile the pre-optimization paths instead")
    ap.add_argument("--out", default="profile_artifacts",
                    help="artifact directory (pstats dump + jax trace)")
    ap.add_argument("--no-jax-trace", action="store_true",
                    help="skip the jax.profiler trace (cProfile only)")
    args = ap.parse_args()

    import jax

    os.makedirs(args.out, exist_ok=True)
    trace_dir = os.path.join(args.out, "jax-trace")
    label = "baseline (pre-optimization)" if args.baseline else "optimized"
    print(f"profiling {label}: M={args.m}, applies={args.applies}, "
          f"workers={args.workers}, backend={jax.default_backend()}")

    prof = cProfile.Profile()
    t0 = time.perf_counter()
    if args.no_jax_trace:
        prof.enable()
        res = canned_run(m_apps=args.m, applies=args.applies,
                         workers=args.workers, seed=args.seed,
                         optimized=not args.baseline)
        prof.disable()
    else:
        with jax.profiler.trace(trace_dir):
            prof.enable()
            res = canned_run(m_apps=args.m, applies=args.applies,
                             workers=args.workers, seed=args.seed,
                             optimized=not args.baseline)
            prof.disable()
    wall = time.perf_counter() - t0

    stats_path = os.path.join(args.out, "cprofile.pstats")
    prof.dump_stats(stats_path)
    buf = io.StringIO()
    pstats.Stats(prof, stream=buf).sort_stats("cumulative").print_stats(args.top)
    print(buf.getvalue())
    print(f"wall-clock: {wall:.2f}s; applies completed: {len(res['events'])}; "
          f"churn events: {len(res['churn'])}")
    # scale-layer counters (docs/performance.md "scale layer"): event
    # throughput and the process peak-RSS high-water mark
    sched = res["scheduler"]
    peak_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    peak_mb = peak_kb / 1024.0 if sys.platform != "darwin" else peak_kb / 2**20
    print(f"events dispatched: {sched.events_dispatched} "
          f"({sched.events_dispatched / max(wall, 1e-9):.0f} events/s wall, "
          f"heap max {sched.heap_max}); peak RSS: {peak_mb:.0f} MB")
    # per-app wire split (docs/performance.md "compressed downlink"):
    # commit (uplink) vs broadcast (downlink) bytes as the scheduler
    # priced them — compression policies show up directly here
    ts = sched.transport_stats()
    print("per-app wire bytes (up / down):")
    for ai, (up, down) in enumerate(zip(ts["uplink_bytes"], ts["downlink_bytes"])):
        print(f"  app {ai}: {up / 1e6:8.2f} MB up  /  {down / 1e6:8.2f} MB down")
    print(f"  total: {sum(ts['uplink_bytes']) / 1e6:.2f} MB up / "
          f"{sum(ts['downlink_bytes']) / 1e6:.2f} MB down")
    print(f"wrote {stats_path}")
    if not args.no_jax_trace:
        print(f"wrote jax trace under {trace_dir} (open with Perfetto or "
              f"TensorBoard's profile plugin)")


if __name__ == "__main__":
    main()
