"""Intra-repo markdown link checker for README.md and docs/.

Scans markdown files for ``[text](target)`` links and verifies every
relative target resolves to a file or directory in the repo (anchors and
``scheme://`` URLs are skipped; ``path#anchor`` checks only the path).
Exit code 1 on any broken link — this is the CI docs gate.

Usage: ``python tools/check_links.py [file-or-dir ...]``
(defaults to README.md and docs/ at the repo root).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def iter_markdown(paths: list[Path]):
    for p in paths:
        if p.is_dir():
            yield from sorted(p.rglob("*.md"))
        elif p.suffix == ".md":
            yield p


def check_file(md: Path) -> list[str]:
    errors = []
    for lineno, line in enumerate(md.read_text().splitlines(), 1):
        for target in LINK_RE.findall(line):
            if "://" in target or target.startswith(("mailto:", "#")):
                continue
            path_part = target.split("#", 1)[0]
            if not path_part:
                continue
            if path_part.startswith("/"):  # GitHub renders these repo-root-relative
                resolved = (REPO / path_part.lstrip("/")).resolve()
            else:
                resolved = (md.parent / path_part).resolve()
            where = f"{md.relative_to(REPO)}:{lineno}"
            if REPO != resolved and REPO not in resolved.parents:
                # exists locally or not, it escapes the checkout -> 404s on remotes
                errors.append(f"{where}: link escapes repo -> {target}")
            elif not resolved.exists():
                errors.append(f"{where}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    roots = [Path(a).resolve() for a in argv] if argv else [REPO / "README.md", REPO / "docs"]
    files = [f for f in iter_markdown([r for r in roots if r.exists()])]
    if not files:
        print("no markdown files found", file=sys.stderr)
        return 1
    errors = [e for f in files for e in check_file(f)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} file(s): {'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
