"""End-to-end driver: federated training of a ~100M-param LM for a few
hundred steps with k-replica checkpointing and straggler masks.

This drives the same ``repro.fl.steps.build_train_step`` round that the
dry-run lowers at production scale (Totoro+ tree aggregation semantics:
local accumulation -> hierarchical reduce -> FedAvg update).

  PYTHONPATH=src python examples/federated_lm_training.py [--steps 300]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import ckpt, configs, data
from repro.config import RunPlan
from repro.fl import steps as steps_mod
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/totoro_lm_ckpt")
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    # ~100M-param llama-family model (tinyllama structure, narrowed)
    cfg = configs.get_config("tinyllama-1.1b").replace(
        num_layers=args.layers, d_model=args.d_model,
        num_heads=args.d_model // 64, num_kv_heads=max(2, args.d_model // 256),
        head_dim=64, d_ff=args.d_model * 3, vocab_size=32000,
        dtype="float32", param_dtype="float32", learning_rate=3e-4,
        attn_chunk=128,
    )
    params = lm.init_params(jax.random.key(0), cfg)
    n = lm.count_params_analytic(cfg)[0]
    print(f"model: {n/1e6:.0f}M params, {cfg.num_layers}L x d{cfg.d_model}")

    state = steps_mod.init_train_state(cfg, params)
    start = 0
    if ckpt.latest_step(args.ckpt_dir) is not None:
        state, start = ckpt.restore(state, args.ckpt_dir)
        print(f"resumed from step {start}")

    plan = RunPlan(grad_accum=2)  # local accumulation = FedAvg local pass
    train_step = jax.jit(steps_mod.build_train_step(cfg, plan), donate_argnums=(0,))
    sc = data.StreamConfig(cfg.vocab_size, args.seq_len, args.batch, non_iid_alpha=1.0)

    rng = np.random.default_rng(0)
    t0, losses = time.time(), []
    for step in range(start, args.steps):
        batch = data.learnable_lm_batch(sc, shard=0, step=step)
        # straggler mitigation: ~10% of clients miss the round deadline
        drop = rng.random(args.batch) < 0.1
        batch["labels"] = np.where(drop[:, None], -1, batch["labels"])
        state, metrics = train_step(state, {k: jnp.asarray(v) for k, v in batch.items()})
        losses.append(float(metrics["loss"]))
        if step % 20 == 0 or step == args.steps - 1:
            dt = (time.time() - t0) / max(step - start + 1, 1)
            print(f"step {step}: loss={losses[-1]:.4f} ({dt*1e3:.0f} ms/step)")
        if (step + 1) % 50 == 0:
            ckpt.save(state, args.ckpt_dir, step=step + 1, replicas=2)
    ckpt.save(state, args.ckpt_dir, step=args.steps, replicas=2)
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"checkpoints (2 replicas) in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
