"""Quickstart: a complete Totoro+ FL application in ~60 lines.

Builds an edge overlay, publishes one FL app, subscribes workers with
non-IID shards, runs FedAvg rounds through the Table-II API (broadcast ->
local train -> tree aggregation), and survives a master failure.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import data
from repro.core.api import TotoroSystem
from repro.fl import rounds

# 1. edge nodes join the DHT-based P2P overlay (4 zones = 4 edge sites)
system = TotoroSystem(zone_bits=2, suffix_bits=24, seed=0)
rng = np.random.default_rng(0)
nodes = [
    system.Join("10.0.0.1", 9000 + i, site=i % 4, coord=rng.uniform(0, 100, 2))
    for i in range(400)
]

# 2. non-IID client shards (Dirichlet label skew, like FEMNIST splits)
x, y = data.synthetic_classification(4000, dim=32, num_classes=8, seed=0)
parts = data.dirichlet_partition(y, num_clients=16, alpha=0.5, seed=1)
workers = [int(w) for w in rng.choice(nodes, size=16, replace=False)]
shards = {w: (x[parts[i]], y[parts[i]]) for i, w in enumerate(workers)}

# 3. publish the app: its dataflow tree self-organizes around hash(name)
app = rounds.make_app(
    system, "quickstart-classifier", workers=workers, data_by_worker=shards,
    dim=32, num_classes=8, local_steps=4, lr=0.2, mu=0.01,  # FedProx
)
print(f"app '{app.name}': master={hex(app.handle.tree.root)} "
      f"depth={app.handle.tree.depth()} workers={len(app.handle.tree.members)}")

# 4. other nodes can discover running apps through the AD tree
registry = system.Discover(nodes[-1])
print("AD-tree discovery:", [m.get("name") for m in registry.values()])

# 5. FedAvg rounds: broadcast -> local steps -> tree aggregation
xt, yt = x[:500], y[:500]
for r in range(8):
    m = rounds.run_round(system, app)
    acc = rounds.evaluate(app, xt, yt)
    print(f"round {m['round']}: loss={m['loss']:.3f} acc={acc:.3f} "
          f"tree_time={m['time_ms']:.1f}ms")

# 6. kill the master mid-training: the numerically-next node takes over
#    and restores state from the k=2 neighborhood replicas
old_master = app.handle.tree.root
report = system.fail_nodes(app.handle.app_id, [old_master])
print(f"master {hex(old_master)} failed -> new master {hex(report.new_master)} "
      f"(state replica: {report.restored_from_replica is not None}, "
      f"recovery {report.recovery_time_ms:.0f} ms)")
m = rounds.run_round(system, app)
print(f"round {m['round']} after recovery: loss={m['loss']:.3f}")
