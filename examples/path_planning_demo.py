"""Game-theoretic path planning demo (paper §V + Figs 11-16).

Reproduces the Appendix-E numerical example exactly, then runs Totoro+
vs the EuroSys'24 bandit vs OPT on a constrained-bandwidth hop set and
prints the Nash-regret / latency comparison.

  PYTHONPATH=src python examples/path_planning_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core.congestion import CongestionEnv, make_env
from repro.core.pathplan import (
    BanditPlanner, GameTheoreticPlanner, OptPlanner,
    algorithm1_episode, run_planner,
)

# --- Appendix E, bit-exact -------------------------------------------------
cand = jnp.array([[0.6, 0.4], [0.5, 0.5], [0.3, 0.7], [0.1, 0.9]], jnp.float32)
out = algorithm1_episode(
    jnp.array([[0.5, 0.5]], jnp.float32), jnp.ones((1, 2), bool), cand,
    jnp.array([[0, 1]]), jnp.array([[0.4, 0.8]], jnp.float32),
    tau=2, alpha=0.5, beta=0.5,
)
print(f"Appendix E: pi^2 = {np.asarray(out[0]).round(4)}  (paper: [0.2, 0.8])")

# --- Totoro+ vs bandit vs OPT on 20-100 Mbps shared hops --------------------
env = make_env(8, seed=7, bw_range=(20.0, 100.0))
env = CongestionEnv(capacity=env.capacity, theta=env.theta, packet_mbit=2.0)
N, episodes = 128, 40
print(f"\n{N} nodes x 8 hops, {episodes} episodes x tau=16 packets:")
print(f"{'planner':16} {'cum_latency_s':>14} {'nash_regret':>12} {'reward':>8}")
for name, planner in (
    ("Totoro+ (Alg.1)", GameTheoreticPlanner(N, 8, tau=16, alpha=0.98, beta=0.5, seed=0)),
    ("Totoro (bandit)", BanditPlanner(N, 8, tau=16)),
    ("OPT (oracle)", OptPlanner(env, N, tau=16)),
):
    s = run_planner(planner, env, episodes)
    print(
        f"{name:16} {s['cum_latency_ms'][-1]/1e3:14.1f} "
        f"{np.mean(s['nash_regret'][-8:]):12.4f} "
        f"{np.mean(s['mean_reward'][-8:]):8.3f}"
    )
print("\nTotoro+ spreads traffic over contended hops (epsilon-approximate "
      "Nash equilibrium, Corollary 1); the congestion-blind bandit herds "
      "onto 'best' hops and pays the queueing penalty.")
