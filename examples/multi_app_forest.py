"""Many FL applications running simultaneously on one overlay — the
paper's headline scenario (Fig 4): per-app dataflow trees + the AD tree,
master load balance, and per-app customization (DP noise, compression,
selection functions).

  PYTHONPATH=src python examples/multi_app_forest.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.api import TotoroSystem
from repro.fl.compression import qsgd_quantize, qsgd_dequantize

system = TotoroSystem(zone_bits=3, suffix_bits=24, seed=1)
rng = np.random.default_rng(1)
nodes = [
    system.Join("edge", i, site=int(rng.integers(0, 8)), coord=rng.uniform(0, 200, 2))
    for i in range(3000)
]

# 60 concurrent applications, each with its own policies
apps = []
for i in range(60):
    hooks = {}
    if i % 3 == 0:  # DP-enabled apps add Gaussian noise in Aggregate
        hooks["privacy_fn"] = lambda v, r=np.random.default_rng(i): (
            v + r.normal(0, 0.01, np.shape(v))
        )
    if i % 2 == 0:  # compressed model broadcast (QSGD int8)
        hooks["compress_fn"] = lambda obj: qsgd_quantize(
            np.asarray(obj, np.float32).reshape(-1, 256)
        )
        hooks["decompress_fn"] = lambda qs: qsgd_dequantize(*qs).reshape(-1)
    if i % 5 == 0:  # client selection: only even node ids admitted
        hooks["selection_fn"] = lambda n: n % 2 == 0
    h = system.CreateTree(f"fl-app-{i:02d}", **hooks)
    apps.append(h)
    for w in rng.choice(nodes, size=64, replace=False):
        system.Subscribe(h.app_id, int(w))

# master load balance across the overlay (paper Fig 5)
per_node = system.forest.masters_per_node()
counts = np.zeros(len(nodes))
counts[: len(per_node)] = sorted(per_node.values(), reverse=True)
print(f"60 apps on 3000 nodes: max masters/node={int(counts.max())}, "
      f"{(counts <= 3).mean()*100:.1f}% of nodes host <=3 masters")

depths = [h.tree.depth() for h in apps]
print(f"tree depths: min={min(depths)} median={int(np.median(depths))} max={max(depths)}")

# AD-tree discovery from a newly joined node
newcomer = system.Join("new", 1, site=2, coord=(50, 50))
registry = system.Discover(newcomer)
print(f"newcomer discovered {len(registry)} running apps via the AD tree")

# one compressed broadcast round for every app, concurrently
times = []
payload = np.random.default_rng(0).standard_normal(256 * 64).astype(np.float32)
for h in apps:
    stats = system.Broadcast(h.app_id, payload)
    times.append(stats["time_ms"])
print(f"60 concurrent broadcasts: max tree latency {max(times):.1f} ms "
      f"(parallel trees -> wall time = max, not sum)")

# event-driven multi-app clock: M concurrent apps' rounds interleave on
# the shared overlay (link contention where trees overlap) vs the
# centralized coordinator that serves them one by one (paper Table III)
import types

from repro.core.sim import MultiAppSimulator, per_app_round_ms
from repro.fl.rounds import CentralizedBaseline

sim_apps = apps[:8]
model_bytes = 4.0 * 256 * 64
sim = MultiAppSimulator(system, sim_apps, model_bytes=model_bytes, compute_ms=30.0)
history = sim.run(rounds=2)
per_app = per_app_round_ms(history)
mean_round = float(np.mean([np.mean(v) for v in per_app.values()]))
shims = [types.SimpleNamespace(data={w: None for w in h.tree.members}) for h in sim_apps]
central = float(np.mean(CentralizedBaseline().round_time_ms(shims, 30.0, model_bytes)))
print(f"event-driven sim: 8 concurrent apps, mean round {mean_round:.0f} ms "
      f"vs centralized queue {central:.0f} ms ({central/mean_round:.1f}x)")

# hierarchical aggregation: one model update from 16 workers flows up the
# first app's tree level-by-level through the batched kernel
agg_members = sorted(apps[0].tree.members)[:16]
update = {w: np.random.default_rng(w % 97).standard_normal(512).astype(np.float32)
          for w in agg_members}
astats = system.Aggregate(apps[0].app_id, update)
print(f"hierarchical aggregate: {len(astats['levels'])} levels, "
      f"{astats['bytes']/1e3:.0f} kB tree traffic, {astats['time_ms']:.1f} ms")

# zone-restricted app: administrative isolation keeps packets in-site
local = system.CreateTree("hospital-local", restrict_zone=3)
zone3 = [n for n in nodes if system.space.zone_of(n) == 3][:40]
for w in zone3:
    system.Subscribe(local.app_id, w)
in_zone = all(system.space.zone_of(n) == 3 for n in local.tree.nodes())
print(f"zone-restricted tree stays in zone 3: {in_zone}")
