"""Layer 2: pub/sub forest — trees, AD tree, balance, API verbs."""
import math

import numpy as np
import pytest

from repro.core.api import TotoroSystem
from repro.core.forest import Forest
from repro.core.nodeid import IdSpace, abs_ring_distance
from repro.core.overlay import MultiRingOverlay


def build(n=2000, seed=0):
    space = IdSpace(zone_bits=3, suffix_bits=24)
    ov = MultiRingOverlay(space, base_bits=4, seed=seed)
    rng = np.random.default_rng(seed)
    for _ in range(n):
        ov.join_random(int(rng.integers(0, 8)), coord=rng.uniform(0, 100, 2))
    return ov, rng


def test_tree_root_is_rendezvous_node():
    ov, rng = build()
    f = Forest(ov)
    tree = f.create_tree("my-app")
    space = ov.space
    zone = space.zone_of(tree.root)
    suf = space.suffix_of(tree.app_id)
    members = ov.zone_members[zone]
    best = min(members, key=lambda s: abs_ring_distance(suf, s, space.suffix_space))
    assert space.suffix_of(tree.root) == best


def test_subscribers_all_reach_root():
    ov, rng = build()
    f = Forest(ov)
    tree = f.create_tree("app")
    subs = [ov.nodes()[rng.integers(ov.num_nodes)] for _ in range(300)]
    for s in subs:
        f.subscribe(tree.app_id, s)
    for s in tree.members:
        path = tree.path_to_root(s)
        assert path[-1] == tree.root


def test_tree_depth_log_and_fanout_bounded():
    ov, rng = build(n=4000)
    f = Forest(ov)
    tree = f.create_tree("app")
    for _ in range(800):
        f.subscribe(tree.app_id, ov.nodes()[rng.integers(ov.num_nodes)])
    assert tree.depth() <= math.ceil(math.log(4000 / 8, 16)) + ov.space.zone_bits + 4
    # fanout bounded by the digit base (with leaf-set/root slack)
    assert tree.fanout() <= (1 << ov.b) * 4


def test_masters_evenly_distributed():
    """Fig 5(b): with many apps, ~99.5% of nodes host <= 3 roots."""
    ov, rng = build(n=1000)
    f = Forest(ov)
    for i in range(500):
        f.create_tree(f"app-{i}")
    per_node = f.masters_per_node()
    heavy = sum(1 for v in per_node.values() if v > 3)
    assert heavy / 1000 < 0.02
    assert max(per_node.values()) < 12


def test_unsubscribe_prunes_leaves():
    ov, rng = build(n=500)
    f = Forest(ov)
    tree = f.create_tree("app")
    subs = [ov.nodes()[rng.integers(ov.num_nodes)] for _ in range(50)]
    for s in subs:
        f.subscribe(tree.app_id, s)
    before = len(tree.nodes())
    for s in subs:
        f.unsubscribe(tree.app_id, s)
    assert len(tree.nodes()) < before
    assert not tree.members


def test_ad_tree_advertise_and_discover():
    ov, rng = build(n=800)
    f = Forest(ov)
    for i in range(10):
        f.create_tree(f"fl-app-{i}", meta={"name": f"fl-app-{i}", "model": "mlp"})
    reg = f.discover(ov.nodes()[5])
    names = {v["name"] for v in reg.values()}
    assert names == {f"fl-app-{i}" for i in range(10)}
    # AD tree membership stays small: masters only (paper: M + N' << N)
    assert f.ad_tree is not None
    assert len(f.ad_tree.nodes()) < 10 * 8  # M apps x O(log N) interior


def test_api_verbs_end_to_end():
    sys = TotoroSystem(zone_bits=2, suffix_bits=20, seed=3)
    rng = np.random.default_rng(0)
    nodes = [sys.Join("10.0.0.1", 9000 + i, site=i % 4, coord=rng.uniform(0, 10, 2)) for i in range(200)]
    received = []
    h = sys.CreateTree(
        "sentiment",
        selection_fn=lambda n: n % 2 == 0,  # client selection customization
        on_broadcast=lambda app, worker, obj: received.append((worker, obj)),
    )
    ok = [sys.Subscribe(h.app_id, n) for n in nodes[:40]]
    assert any(ok) and not all(ok)  # selection_fn rejected odd nodes
    stats = sys.Broadcast(h.app_id, np.ones(10))
    assert stats["time_ms"] > 0 and stats["bytes"] > 0
    assert received  # callback fired per worker, with the receiving id
    assert {w for w, _ in received} == set(h.tree.members)
    updates = {n: np.full(10, float(i)) for i, n in enumerate(sorted(h.tree.members)[:4])}
    agg = sys.Aggregate(h.app_id, updates)
    np.testing.assert_allclose(agg["result"], np.mean([v for v in updates.values()], axis=0))
    reg = sys.Discover(nodes[-1])
    assert any(m.get("name") == "sentiment" for m in reg.values())


def test_fanout_bits_is_per_tree():
    """One app's fanout_bits must not leak into other apps' routing."""
    sys = TotoroSystem(zone_bits=2, suffix_bits=20, seed=5)
    rng = np.random.default_rng(1)
    nodes = [sys.Join("n", i, site=i % 4, coord=rng.uniform(0, 10, 2)) for i in range(400)]
    b_before = sys.overlay.b
    narrow = sys.CreateTree("narrow", fanout_bits=2)
    default = sys.CreateTree("default")
    assert sys.overlay.b == b_before  # no global mutation
    assert narrow.tree.meta["fanout_bits"] == 2
    for w in nodes[:150]:
        sys.Subscribe(narrow.app_id, w)
        sys.Subscribe(default.app_id, w)
    assert sys.overlay.b == b_before
    # explicit base_bits == overlay default leaves routing unchanged;
    # a different digit base changes this tree's routes only
    src, key = nodes[7], narrow.app_id
    assert sys.overlay.route(src, key, base_bits=b_before).path == sys.overlay.route(src, key).path
    assert sys.overlay.route(src, key, base_bits=1).path != sys.overlay.route(src, key).path
    # smaller digit base -> longer paths (deeper tree), fewer direct
    # deliveries at the rendezvous root
    assert narrow.tree.depth() >= default.tree.depth()
    assert len(narrow.tree.children[narrow.tree.root]) < len(default.tree.children[default.tree.root])


def test_zone_restricted_tree_stays_in_zone():
    ov, rng = build(n=1000)
    f = Forest(ov)
    tree = f.create_tree("local-app", restrict_zone=2)
    assert ov.space.zone_of(tree.root) == 2
    zone2 = [n for n in ov.nodes() if ov.space.zone_of(n) == 2]
    for s in zone2[:30]:
        f.subscribe(tree.app_id, s)
    assert all(ov.space.zone_of(n) == 2 for n in tree.nodes())
