"""Layer 2: pub/sub forest — trees, AD tree, balance, API verbs."""
import math

import numpy as np
import pytest

from repro.core.api import TotoroSystem
from repro.core.forest import Forest
from repro.core.nodeid import IdSpace, abs_ring_distance
from repro.core.overlay import MultiRingOverlay


def build(n=2000, seed=0):
    space = IdSpace(zone_bits=3, suffix_bits=24)
    ov = MultiRingOverlay(space, base_bits=4, seed=seed)
    rng = np.random.default_rng(seed)
    for _ in range(n):
        ov.join_random(int(rng.integers(0, 8)), coord=rng.uniform(0, 100, 2))
    return ov, rng


def test_tree_root_is_rendezvous_node():
    ov, rng = build()
    f = Forest(ov)
    tree = f.create_tree("my-app")
    space = ov.space
    zone = space.zone_of(tree.root)
    suf = space.suffix_of(tree.app_id)
    members = ov.zone_members[zone]
    best = min(members, key=lambda s: abs_ring_distance(suf, s, space.suffix_space))
    assert space.suffix_of(tree.root) == best


def test_subscribers_all_reach_root():
    ov, rng = build()
    f = Forest(ov)
    tree = f.create_tree("app")
    subs = [ov.nodes()[rng.integers(ov.num_nodes)] for _ in range(300)]
    for s in subs:
        f.subscribe(tree.app_id, s)
    for s in tree.members:
        path = tree.path_to_root(s)
        assert path[-1] == tree.root


def test_tree_depth_log_and_fanout_bounded():
    ov, rng = build(n=4000)
    f = Forest(ov)
    tree = f.create_tree("app")
    for _ in range(800):
        f.subscribe(tree.app_id, ov.nodes()[rng.integers(ov.num_nodes)])
    assert tree.depth() <= math.ceil(math.log(4000 / 8, 16)) + ov.space.zone_bits + 4
    # fanout bounded by the digit base (with leaf-set/root slack)
    assert tree.fanout() <= (1 << ov.b) * 4


def test_masters_evenly_distributed():
    """Fig 5(b): with many apps, ~99.5% of nodes host <= 3 roots."""
    ov, rng = build(n=1000)
    f = Forest(ov)
    for i in range(500):
        f.create_tree(f"app-{i}")
    per_node = f.masters_per_node()
    heavy = sum(1 for v in per_node.values() if v > 3)
    assert heavy / 1000 < 0.02
    assert max(per_node.values()) < 12


def test_unsubscribe_prunes_leaves():
    ov, rng = build(n=500)
    f = Forest(ov)
    tree = f.create_tree("app")
    subs = [ov.nodes()[rng.integers(ov.num_nodes)] for _ in range(50)]
    for s in subs:
        f.subscribe(tree.app_id, s)
    before = len(tree.nodes())
    for s in subs:
        f.unsubscribe(tree.app_id, s)
    assert len(tree.nodes()) < before
    assert not tree.members


def test_ad_tree_advertise_and_discover():
    ov, rng = build(n=800)
    f = Forest(ov)
    for i in range(10):
        f.create_tree(f"fl-app-{i}", meta={"name": f"fl-app-{i}", "model": "mlp"})
    reg = f.discover(ov.nodes()[5])
    names = {v["name"] for v in reg.values()}
    assert names == {f"fl-app-{i}" for i in range(10)}
    # AD tree membership stays small: masters only (paper: M + N' << N)
    assert f.ad_tree is not None
    assert len(f.ad_tree.nodes()) < 10 * 8  # M apps x O(log N) interior


def test_api_verbs_end_to_end():
    sys = TotoroSystem(zone_bits=2, suffix_bits=20, seed=3)
    rng = np.random.default_rng(0)
    nodes = [sys.Join("10.0.0.1", 9000 + i, site=i % 4, coord=rng.uniform(0, 10, 2)) for i in range(200)]
    received = []
    h = sys.CreateTree(
        "sentiment",
        selection_fn=lambda n: n % 2 == 0,  # client selection customization
        on_broadcast=lambda app, worker, obj: received.append((worker, obj)),
    )
    ok = [sys.Subscribe(h.app_id, n) for n in nodes[:40]]
    assert any(ok) and not all(ok)  # selection_fn rejected odd nodes
    stats = sys.Broadcast(h.app_id, np.ones(10))
    assert stats["time_ms"] > 0 and stats["bytes"] > 0
    assert received  # callback fired per worker, with the receiving id
    assert {w for w, _ in received} == set(h.tree.members)
    updates = {n: np.full(10, float(i)) for i, n in enumerate(sorted(h.tree.members)[:4])}
    agg = sys.Aggregate(h.app_id, updates)
    np.testing.assert_allclose(agg["result"], np.mean([v for v in updates.values()], axis=0))
    reg = sys.Discover(nodes[-1])
    assert any(m.get("name") == "sentiment" for m in reg.values())


def test_fanout_bits_is_per_tree():
    """One app's fanout_bits must not leak into other apps' routing."""
    sys = TotoroSystem(zone_bits=2, suffix_bits=20, seed=5)
    rng = np.random.default_rng(1)
    nodes = [sys.Join("n", i, site=i % 4, coord=rng.uniform(0, 10, 2)) for i in range(400)]
    b_before = sys.overlay.b
    narrow = sys.CreateTree("narrow", fanout_bits=2)
    default = sys.CreateTree("default")
    assert sys.overlay.b == b_before  # no global mutation
    assert narrow.tree.meta["fanout_bits"] == 2
    for w in nodes[:150]:
        sys.Subscribe(narrow.app_id, w)
        sys.Subscribe(default.app_id, w)
    assert sys.overlay.b == b_before
    # explicit base_bits == overlay default leaves routing unchanged;
    # a different digit base changes this tree's routes only
    src, key = nodes[7], narrow.app_id
    assert sys.overlay.route(src, key, base_bits=b_before).path == sys.overlay.route(src, key).path
    assert sys.overlay.route(src, key, base_bits=1).path != sys.overlay.route(src, key).path
    # smaller digit base -> longer paths (deeper tree), fewer direct
    # deliveries at the rendezvous root
    assert narrow.tree.depth() >= default.tree.depth()
    assert len(narrow.tree.children[narrow.tree.root]) < len(default.tree.children[default.tree.root])


def test_zone_restricted_tree_stays_in_zone():
    ov, rng = build(n=1000)
    f = Forest(ov)
    tree = f.create_tree("local-app", restrict_zone=2)
    assert ov.space.zone_of(tree.root) == 2
    zone2 = [n for n in ov.nodes() if ov.space.zone_of(n) == 2]
    for s in zone2[:30]:
        f.subscribe(tree.app_id, s)
    assert all(ov.space.zone_of(n) == 2 for n in tree.nodes())


# -- bulk subscribe (subscribe_many == sequential subscribe oracle) ----------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic grid fallback below
    HAVE_HYPOTHESIS = False


def full_fingerprint(tree):
    """Everything observable about a tree, including dict/list order."""
    return (
        tree.root,
        dict(tree.parent),
        list(tree.parent),
        {p: list(tree.children[p]) for p in tree.children},
        list(tree.children),
        sorted(tree.members),
        tree.aggregation_schedule(),
        tree.broadcast_schedule(),
        [sorted(l) for l in tree.levels()],
        tree.depth(),
        tree.fanout(),
        {n: tree.depth_of(n) for n in sorted(tree.nodes())},
    )


def _bulk_vs_seq(seed, n_sub, *, restrict_zone=None, fanout_bits=None, n=900):
    ov, rng = build(n=n, seed=seed)
    kw = dict(restrict_zone=restrict_zone, fanout_bits=fanout_bits)
    bulk_f, seq_f = Forest(ov), Forest(ov)
    bt = bulk_f.create_tree("app", **kw)
    st_ = seq_f.create_tree("app", **kw)
    pool = (
        ov.nodes()
        if restrict_zone is None
        else [x for x in ov.nodes() if ov.space.zone_of(x) == restrict_zone]
    )
    subs = rng.choice(pool, size=min(n_sub, len(pool)), replace=False)
    bulk_f.subscribe_many(bt.app_id, subs)
    for w in subs.tolist():
        seq_f.subscribe(st_.app_id, int(w))
    assert full_fingerprint(bt) == full_fingerprint(st_)
    return bulk_f, seq_f, bt, st_, subs


def test_subscribe_many_equals_sequential_grid():
    """Deterministic grid: default, zone-restricted, and narrow-fanout
    trees across seeds and subscriber counts."""
    for seed in (0, 1, 2):
        for n_sub in (1, 7, 150):
            _bulk_vs_seq(seed, n_sub)
    _bulk_vs_seq(3, 80, restrict_zone=2)
    _bulk_vs_seq(4, 80, fanout_bits=1)
    _bulk_vs_seq(5, 80, fanout_bits=2)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 50),
        n_sub=st.integers(1, 120),
        cfg=st.sampled_from([(None, None), (2, None), (None, 1)]),
    )
    def test_subscribe_many_equals_sequential_property(seed, n_sub, cfg):
        rz, fb = cfg
        _bulk_vs_seq(seed, n_sub, restrict_zone=rz, fanout_bits=fb, n=400)


def test_subscribe_many_duplicates_match_sequential():
    """Repeated ids in one batch graft exactly like repeated calls."""
    ov, rng = build(n=500, seed=7)
    bulk_f, seq_f = Forest(ov), Forest(ov)
    bt = bulk_f.create_tree("app")
    st_ = seq_f.create_tree("app")
    picks = rng.choice(ov.nodes(), size=40, replace=True)  # dupes likely
    bulk_f.subscribe_many(bt.app_id, picks)
    for w in picks.tolist():
        seq_f.subscribe(st_.app_id, int(w))
    assert full_fingerprint(bt) == full_fingerprint(st_)
    assert bulk_f.subscribe_many(bt.app_id, []).shape == (0,)  # no-op
    assert full_fingerprint(bt) == full_fingerprint(st_)


def test_unsubscribe_after_bulk_graft_matches_sequential():
    """Interleaved LEAVEs prune a bulk-grafted tree exactly like a
    sequentially-grafted one."""
    bulk_f, seq_f, bt, st_, subs = _bulk_vs_seq(9, 120)
    drop = subs[::3]
    for w in drop.tolist():
        bulk_f.unsubscribe(bt.app_id, int(w))
        seq_f.unsubscribe(st_.app_id, int(w))
    assert full_fingerprint(bt) == full_fingerprint(st_)
    # and a bulk re-subscribe of the dropped workers re-converges
    bulk_f.subscribe_many(bt.app_id, drop)
    for w in drop.tolist():
        seq_f.subscribe(st_.app_id, int(w))
    assert full_fingerprint(bt) == full_fingerprint(st_)


def test_ad_tree_advertise_with_bulk_created_apps():
    """Masters advertise on create_tree, so the AD tree must be
    identical no matter how each app's workers were subscribed."""
    ov, rng = build(n=800, seed=11)
    bulk_f, seq_f = Forest(ov), Forest(ov)
    subs = rng.choice(ov.nodes(), size=60, replace=False)
    for i in range(6):
        b = bulk_f.create_tree(f"fl-{i}", meta={"name": f"fl-{i}", "m": i})
        s = seq_f.create_tree(f"fl-{i}", meta={"name": f"fl-{i}", "m": i})
        bulk_f.subscribe_many(b.app_id, subs)
        for w in subs.tolist():
            seq_f.subscribe(s.app_id, int(w))
    assert full_fingerprint(bulk_f.ad_tree) == full_fingerprint(seq_f.ad_tree)
    assert bulk_f.ad_registry == seq_f.ad_registry
    reg = bulk_f.discover(ov.nodes()[3])
    assert {v["name"] for v in reg.values()} == {f"fl-{i}" for i in range(6)}


def test_subscribe_many_api_verb_respects_selection_fn():
    sys = TotoroSystem(zone_bits=2, suffix_bits=20, seed=3)
    rng = np.random.default_rng(0)
    nodes = [sys.Join("n", i, site=i % 4, coord=rng.uniform(0, 10, 2)) for i in range(200)]
    h = sys.CreateTree("bulk", selection_fn=lambda n: n % 2 == 0)
    accepted = sys.SubscribeMany(h.app_id, nodes[:40])
    assert accepted == [n for n in nodes[:40] if n % 2 == 0]
    assert set(h.tree.members) == set(accepted)
