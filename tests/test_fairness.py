"""Weighted-fair transfer pricing + staleness-aware relay admission.

Covers the PR-4 starvation fix end-to-end:
 - fluid re-pricing is progress-preserving and matches the closed-form
   processor-sharing schedule (join and complete both re-price);
 - re-pricing conserves delivered bytes exactly (no work lost or
   duplicated), and per-app uplink accounting equals commits x hops;
 - a single-flow (never contended) async trace is identical under
   ``fair=True`` and ``fair=False`` — the legacy pricing is only wrong
   under contention;
 - per-app weight and rate-cap knobs shape contended throughput;
 - relay admission defers stale commits when contended, never drops
   them, and feeds the selector's deadline signal;
 - fairness telemetry lands in ``AppHandle.round_records`` (transport:
   per-app uplink bytes/throughput + Jain's index);
 - liveness regressions: a churn fail that shrinks effective K below
   the already-buffered commits applies immediately instead of
   stalling; the force-admit guard drains the selector blocklist;
 - ``AdaptiveKController`` rate EMA survives a full-outage commit gap
   (K recovers after rejoin);
 - ``dirichlet_partition(min_samples=...)`` never emits empty clients,
   and the engine's masked-padding path matches the per-worker
   reference on heavily ragged shards.
"""
import numpy as np
import pytest

from repro import data as data_mod
from repro.core.api import TotoroSystem
from repro.core.congestion import fair_share_rates
from repro.core.sim import (
    AdaptiveKController,
    AsyncBufferScheduler,
    ChurnModel,
    EventCore,
    RelayAdmission,
)
from repro.fl import async_engine, engine, rounds
from repro.fl.selection import UtilitySelector
from repro.kernels.ops import jain_fairness


def build_app(seed=0, workers=8, n_nodes=150, name="fair-test"):
    sys_ = TotoroSystem(zone_bits=2, suffix_bits=20, seed=seed)
    rng = np.random.default_rng(seed)
    nodes = [sys_.Join("n", i, site=i % 4, coord=rng.uniform(0, 50, 2)) for i in range(n_nodes)]
    x, y = data_mod.synthetic_classification(workers * 150, 16, 4, seed=seed)
    parts = data_mod.dirichlet_partition(y, workers, alpha=1.0, seed=seed + 1)
    ws = [int(w) for w in rng.choice(nodes, size=workers, replace=False)]
    app = rounds.make_app(
        sys_, name, workers=ws,
        data_by_worker={w: (x[parts[i]], y[parts[i]]) for i, w in enumerate(ws)},
        dim=16, num_classes=4, local_steps=3, lr=0.2,
    )
    return sys_, app


def build_handles(m, workers=6, n_nodes=120, seed=0, bw=None):
    """Timing-only multi-app fixture: trees + subscriptions, no trainer."""
    sys_ = TotoroSystem(zone_bits=2, suffix_bits=20, seed=seed)
    rng = np.random.default_rng(seed)
    nodes = [
        sys_.Join("n", i, site=i % 4, coord=rng.uniform(0, 50, 2),
                  bandwidth=bw if bw is not None else float(rng.uniform(20, 100)))
        for i in range(n_nodes)
    ]
    handles = []
    for a in range(m):
        h = sys_.CreateTree(f"h{a}")
        for w in rng.choice(nodes, size=workers, replace=False):
            sys_.Subscribe(h.app_id, int(w))
        handles.append(h)
    return sys_, handles


class _BareOverlay:
    def __init__(self, bandwidth):
        self.bandwidth = dict(enumerate(bandwidth))

    def nodes(self):
        return sorted(self.bandwidth)


class _BareSystem:
    def __init__(self, bandwidth):
        self.overlay = _BareOverlay(bandwidth)


# -- the fluid engine ---------------------------------------------------------


def test_fair_share_rates_weighted_caps_waterfill():
    assert fair_share_rates(100.0, [1, 1]) == [50.0, 50.0]
    assert fair_share_rates(100.0, [3, 1]) == [75.0, 25.0]
    # a bound cap frees capacity for the uncapped flow
    assert fair_share_rates(100.0, [1, 1], [10.0, None]) == [10.0, 90.0]
    r = fair_share_rates(100.0, [1, 1, 2], [5.0, None, None])
    assert r[0] == 5.0 and r[1] == pytest.approx(95.0 / 3) and r[2] == pytest.approx(190.0 / 3)
    # degenerate inputs
    assert fair_share_rates(100.0, []) == []
    assert fair_share_rates(100.0, [1.0]) == [100.0]


def test_repricing_matches_processor_sharing_closed_form():
    """The tentpole bug, both directions: flow A starts alone (must NOT
    keep its solo rate after B joins), flow B starts contended (must NOT
    keep the half rate after A completes)."""
    core = EventCore(_BareSystem([80.0]), [], model_bytes=1e6)  # 8 mbit payload
    done = {}
    core.schedule(0.0, lambda t: core.open_flow(0, 8.0, on_done=lambda t: done.setdefault("A", t)))
    core.schedule(40.0, lambda t: core.open_flow(0, 8.0, on_done=lambda t: done.setdefault("B", t)))
    core.run_events()
    # A: 40ms solo at 80 Mbps -> 3.2 mbit, then 4.8 mbit at 40 Mbps -> t=160.
    # B: by t=160 has 4.8 mbit, remaining 3.2 at the full 80 -> t=200.
    assert done["A"] == pytest.approx(160.0)
    assert done["B"] == pytest.approx(200.0)
    # conservation across both re-prices: nothing left in flight
    assert core._flows == {} and core._flows_by_sender == {}


def test_flow_groups_split_one_share():
    """Two flows of one app against one flow of another: the app's
    aggregate share is its weight, not its flow count."""
    core = EventCore(_BareSystem([90.0]), [], model_bytes=1e6)
    done = {}
    for name, group in (("a1", "A"), ("a2", "A"), ("b", "B")):
        core.open_flow(0, 9.0, on_done=lambda t, n=name: done.setdefault(n, t), group=group)
    core.run_events()
    # app A: 45 Mbps split over two 9-mbit flows (22.5 each); app B: 45 alone.
    # B finishes at 200ms; A's flows tie, then... both still need 4.5 mbit at
    # t=200, now splitting the full 90 -> 45 each -> +100ms.
    assert done["b"] == pytest.approx(200.0)
    assert done["a1"] == pytest.approx(300.0) and done["a2"] == pytest.approx(300.0)


def test_single_flow_async_trace_identical_fair_vs_legacy():
    """Acceptance: uncontended (single-flow) pricing unchanged — one
    worker, one app can never overlap two transfers, so the fair and
    legacy schedulers must produce byte-identical event histories."""
    runs = {}
    for fair in (False, True):
        sys_, app = build_app(seed=3, workers=1)
        res = rounds.run_async(
            sys_, [app], applies=4, buffer_k=1, staleness_alpha=0.5,
            model_bytes=1e5, compute_ms=25.0, fair=fair,
        )
        runs[fair] = res
    assert runs[False]["events"] == runs[True]["events"]
    assert [h["loss"] for h in runs[False]["history"]] == [
        h["loss"] for h in runs[True]["history"]
    ]
    assert [h["t_ms"] for h in runs[False]["history"]] == [
        h["t_ms"] for h in runs[True]["history"]
    ]


def test_fair_mode_deterministic_and_conserves_uplink_bytes():
    """Contended fair runs are deterministic, and per-app uplink bytes
    equal exactly commits x path-hops x model_bytes — re-pricing moved
    completion times around but neither lost nor duplicated work."""
    model_bytes = 2e5

    def once():
        sys_, handles = build_handles(4, workers=6, seed=5)
        sched = AsyncBufferScheduler(
            sys_, handles, model_bytes=model_bytes, compute_ms=10.0, buffer_k=3,
        )
        sched.run(4)
        return sched

    a, b = once(), once()
    assert a.history == b.history and a.history
    assert any(e.max_staleness >= 0 for e in a.history)
    for ai in range(4):
        expect = sum(
            cyc * len(a._path_senders(ai, w, up=True))
            for (i, w), cyc in a._cycle.items()
            if i == ai
        ) * model_bytes
        # commit-granular accounting: exactly one leg's bytes per
        # completed cycle, every re-price included, nothing duplicated
        assert a._uplink_bytes[ai] == pytest.approx(expect)
    # horizon_ms stops the clock mid-run (fixed-window measurements)
    sys_, handles = build_handles(4, workers=6, seed=5)
    cut = AsyncBufferScheduler(
        sys_, handles, model_bytes=model_bytes, compute_ms=10.0, buffer_k=3,
    )
    cut.run(10**6, horizon_ms=200.0)
    assert cut.now >= 200.0 and not all(cut._done)
    assert cut.now <= max(e.time_ms for e in a.history)


def test_app_weights_and_rate_caps_shape_throughput():
    """Same workload, one shared bottleneck: the heavier app finishes
    first; a rate cap slows the capped app down."""
    def run(**kw):
        sys_, handles = build_handles(2, workers=5, n_nodes=40, seed=9, bw=50.0)
        sched = AsyncBufferScheduler(
            sys_, handles, model_bytes=8e5, compute_ms=5.0, buffer_k=3, **kw
        )
        sched.run(5)
        return sched.transport_stats()

    even = run()
    heavy0 = run(app_weights=[4.0, 1.0])
    # weighting app 0 up must speed it up relative to the even split
    assert heavy0["done_ms"][0] < even["done_ms"][0]
    capped0 = run(app_rate_caps=[5.0, None])
    assert capped0["done_ms"][0] > even["done_ms"][0]
    # and the handle attribute is an equivalent spelling of the knob
    sys_, handles = build_handles(2, workers=5, n_nodes=40, seed=9, bw=50.0)
    handles[0].transfer_weight = 4.0
    sched = AsyncBufferScheduler(sys_, handles, model_bytes=8e5, compute_ms=5.0, buffer_k=3)
    sched.run(5)
    assert sched.transport_stats()["done_ms"][0] == pytest.approx(heavy0["done_ms"][0])
    # a zero share would price transfers at rate 0 forever: rejected
    with pytest.raises(ValueError):
        AsyncBufferScheduler(
            sys_, handles, model_bytes=8e5, compute_ms=5.0, buffer_k=3,
            app_weights=[0.0, 1.0],
        )
    with pytest.raises(ValueError):
        AsyncBufferScheduler(
            sys_, handles, model_bytes=8e5, compute_ms=5.0, buffer_k=3,
            app_rate_caps=[-1.0, None],
        )


# -- relay admission ----------------------------------------------------------


def test_relay_admission_defers_stale_commits_but_never_drops():
    sys_, handles = build_handles(6, workers=6, n_nodes=60, seed=11, bw=40.0)
    adm = RelayAdmission(threshold=0.9, alpha=1.0, max_defer_ms=120.0)
    sched = AsyncBufferScheduler(
        sys_, handles, model_bytes=6e5, compute_ms=5.0, buffer_k=2, relay_admission=adm,
    )
    events = sched.run(6, max_events=3_000_000)
    # every app still completes every apply (deferral delays, never drops)
    per_app = {}
    for e in events:
        per_app[e.app_id] = per_app.get(e.app_id, 0) + 1
    assert all(v == 6 for v in per_app.values())
    assert sched.defer_log, "contended stale commits should have been deferred"
    for d in sched.defer_log:
        assert 0.0 <= d.waited_ms <= adm.max_defer_ms + 1e-6
    # an uncontended (single-app, single-worker) run never defers
    sys2, h2 = build_handles(1, workers=1, n_nodes=40, seed=11)
    s2 = AsyncBufferScheduler(
        sys2, h2, model_bytes=6e5, compute_ms=5.0, buffer_k=1, relay_admission=adm,
    )
    s2.run(4)
    assert s2.defer_log == []


def test_relay_admission_feeds_selector_deadline_signal():
    sel = UtilitySelector(deadline_ms=1e9, seed=0)  # never parks on its own
    sys_, handles = build_handles(6, workers=6, n_nodes=60, seed=11, bw=40.0)
    adm = RelayAdmission(threshold=0.9, alpha=1.0, max_defer_ms=120.0)
    sched = AsyncBufferScheduler(
        sys_, handles, model_bytes=6e5, compute_ms=5.0, buffer_k=2,
        relay_admission=adm, selector=sel,
    )
    sched.run(6, max_events=3_000_000)
    assert sched.defer_log
    deferred = {(d.app_idx, d.worker) for d in sched.defer_log}
    stats = [sel._s(ai, w) for ai, w in deferred]
    assert all(st.defers >= 1 for st in stats)
    # the hold time reaches the deadline term through the cycle
    # wall-clock (on_commit spans the deferral); on_defer records the
    # attribution EMA, which decays again as undeferred commits land
    worst = max(d.waited_ms for d in sched.defer_log)
    assert any(st.defer_ms > 0 for st in stats) and worst > 0
    st = sel._s(0, 10**9)
    sel.on_defer(0, 10**9, 0.0, 80.0)
    before = st.defer_ms
    sel.on_commit(0, 10**9, 1.0, 10.0)
    assert 0.0 < st.defer_ms < before


# -- fairness telemetry -------------------------------------------------------


def test_jain_fairness_formula():
    assert jain_fairness([1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert jain_fairness([1.0, 0.0, 0.0]) == pytest.approx(1.0 / 3.0)
    x = np.asarray([3.0, 1.0, 2.0, 0.5])
    assert jain_fairness(x) == pytest.approx(float(x.sum() ** 2 / (len(x) * (x**2).sum())))
    assert jain_fairness([]) == 1.0 and jain_fairness([0.0, 0.0]) == 1.0


def test_transport_records_land_in_round_records():
    sys_, app = build_app(seed=6, workers=8)
    res = rounds.run_async(
        sys_, [app], applies=4, buffer_k=3, staleness_alpha=0.5, model_bytes=1e5,
        compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=2),
    )
    recs = app.handle.round_records
    assert len(recs) == 4
    for rec in recs:
        tp = rec["transport"]
        assert tp["app_id"] == app.handle.app_id
        assert tp["uplink_bytes"] > 0 and tp["uplink_mbps"] > 0
        assert 0.0 < tp["jain_uplink"] <= 1.0
    # bytes are monotone across applies, and the scheduler-side log agrees
    bs = [r["transport"]["uplink_bytes"] for r in recs]
    assert bs == sorted(bs)
    sched = res["scheduler"]
    assert [f["uplink_bytes"] for f in sched.fairness_log] == bs
    stats = sched.transport_stats()
    assert set(stats) == {
        "uplink_bytes", "downlink_bytes", "uplink_mbps", "done_ms",
        "jain_uplink", "deferred_commits",
    }
    assert all(b > 0 for b in stats["downlink_bytes"])


# -- liveness under churn (satellite regressions) -----------------------------


def test_churn_fail_applies_buffer_that_already_meets_shrunk_k():
    """Regression: K=W barrier round, one slow worker; churn kills a
    worker after the other three committed.  Effective K clamps to 3 ==
    buffered commits, but no further commit event will ever fire — the
    old scheduler stalled until the failed worker rejoined (downtime is
    set absurdly high to expose it); the fixed one applies at fail time."""
    sys_, handles = build_handles(1, workers=4, n_nodes=60, seed=21, bw=60.0)

    def compute(handle, worker, cycle):
        return 8000.0 if worker == min(sorted(handle.tree.members)) else 10.0

    churn = ChurnModel(
        period_ms=2000.0, downtime_ms=1e9, group_size=1, seed=0, max_fail_events=1,
    )
    sched = AsyncBufferScheduler(
        sys_, handles, model_bytes=1e5, compute_ms=compute, buffer_k=4,
        barrier=True, churn=churn,
    )
    events = sched.run(1, max_events=200_000)
    assert len(events) == 1
    assert events[0].time_ms < 1e6, "apply must not wait for the rejoin"
    assert events[0].arrivals == 3


def test_unrelated_fail_does_not_restart_barrier_idlers():
    """Regression (review find): a churn fail in app B must not hand
    app A's committed barrier idlers a second cycle inside the same
    round — _kick only restarts idlers when it fired the apply itself.
    With the bug, a fast worker commits twice and the K=W round applies
    without the straggler."""
    sys_, handles = build_handles(2, workers=4, n_nodes=60, seed=23, bw=60.0)

    class FixedVictim(ChurnModel):
        def __init__(self, victim, **kw):
            super().__init__(**kw)
            self._victim = victim

        def pick_victims(self, pool):
            return [self._victim] if self._victim in pool else []

    members0, members1 = set(handles[0].tree.members), set(handles[1].tree.members)
    only1 = sorted(members1 - members0 - {handles[1].tree.root})
    assert len(only1) >= 2, "fixture needs two app-1-only non-root workers"
    slow0 = min(sorted(members0 - members1))
    slow1, victim = only1[0], only1[1]  # app 1 stays alive past the fail

    def compute(handle, worker, cycle):
        if handle.app_id == handles[0].app_id:
            return 8000.0 if worker == slow0 else 10.0
        return 8000.0 if worker == slow1 else 10.0

    churn = FixedVictim(victim, period_ms=2000.0, downtime_ms=1e9,
                        group_size=1, seed=0, max_fail_events=1)
    sched = AsyncBufferScheduler(
        sys_, handles, model_bytes=1e5, compute_ms=compute, buffer_k=4,
        barrier=True, churn=churn,
    )
    events = sched.run(1, max_events=200_000)
    assert any(c.kind == "fail" and victim in c.nodes for c in sched.churn_log)
    ev0 = [e for e in events if e.app_id == handles[0].app_id]
    assert len(ev0) == 1 and ev0[0].arrivals == 4
    # every app-0 worker ran exactly one cycle — nobody lapped the barrier
    cycles = {w: sched._cycle.get((0, w), 0) for w in sorted(members0)}
    assert all(c == 1 for c in cycles.values()), cycles


def test_force_admit_drains_blocklist_and_run_completes_under_churn():
    """Satellite: when K exceeds the live non-blocklisted pool, forced
    admissions must drain the blocklist (not leave workers pinned) and
    the buffer keeps filling through heavy churn."""
    sel = UtilitySelector(
        deadline_ms=30.0, epsilon=0.0, admit_quantile=0.9,
        blocklist_after=1, blocklist_rounds=50, seed=0,
    )
    sys_, app = build_app(seed=22, workers=8)
    churn = ChurnModel(period_ms=150.0, downtime_ms=300.0, group_size=3, seed=1)
    res = rounds.run_async(
        sys_, [app], applies=10, buffer_k=6, staleness_alpha=0.5, model_bytes=1e5,
        compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=1),
        churn=churn, selector=sel, adaptive=True,
        adaptive_kwargs={"k_min": 4, "target_staleness": 0.2, "gain": 1.0},
    )
    assert len(res["events"]) == 10
    forced = [st for st in sel._stats.values() if st.force_admits > 0]
    assert forced, "the liveness guard should have force-admitted someone"
    # the drain itself, unit-level: a forced admission zeroes the pending
    # block (misses survive, so a still-slow worker can re-earn it)
    st = sel._s(0, 10**9)
    st.block_offers, st.misses = 40, 3
    sel.on_force_admit(0, 10**9)
    assert st.block_offers == 0 and st.misses == 3 and st.force_admits == 1


def test_adaptive_k_rate_ema_survives_full_outage_gap():
    """Satellite: a commit gap longer than the apply interval (all
    workers failed, later rejoined) must not poison the arrival-rate
    EMA and clamp K at k_min forever."""
    def feed(ctrl):
        for i in range(20):
            ctrl.on_commit(10.0 * i)  # 0.1 commits/ms
        # full outage: no commits for 1e6 ms; the first post-rejoin commit
        # completes the buffer that was nearly full before the outage, so
        # the apply fires before the EMA sees any healthy inter-arrival
        ctrl.on_commit(1e6)
        return ctrl.on_apply(1e6 + 1.0, [1, 1, 1], live_workers=64)

    fixed = AdaptiveKController(
        k_init=8, k_min=1, target_staleness=1.0, gain=0.0,
        arrival_beta=0.9, max_apply_interval_ms=100.0,
    )
    k = feed(fixed)
    assert fixed.arrivals_per_ms == pytest.approx(0.1, rel=0.05)
    assert k == 8, f"K should hold across the outage, got {k}"
    # the old behavior (gap folded into the EMA) demonstrates the bug it
    # fixes: the rate collapses and the interval cap clamps K to k_min
    legacy = AdaptiveKController(
        k_init=8, k_min=1, target_staleness=1.0, gain=0.0,
        arrival_beta=0.9, max_apply_interval_ms=100.0, rate_gap_ms=1e18,
    )
    k_old = feed(legacy)
    assert legacy.arrivals_per_ms < 0.05 and k_old == 1
    # ... and with the fix, K keeps tracking once traffic resumes
    for i in range(1, 20):
        fixed.on_commit(1e6 + 10.0 * i)
    assert fixed.on_apply(1e6 + 200.0, [1, 1, 1], live_workers=64) == 8
    # persistent slowness is NOT forgiven: only the first long gap is an
    # outage; repeated long gaps fold and the interval cap pulls K down
    slow = AdaptiveKController(
        k_init=8, k_min=1, target_staleness=1.0, gain=0.0,
        arrival_beta=0.9, max_apply_interval_ms=100.0,
    )
    for i in range(10):
        slow.on_commit(1e5 * i)  # every gap >> the 100ms window
    assert slow.on_apply(1e6 + 1.0, [1, 1, 1], live_workers=64) == 1
    assert slow.arrivals_per_ms < 1e-3


# -- dirichlet min_samples + ragged masked padding (satellite) ----------------


def test_dirichlet_partition_low_alpha_zero_sample_repro_and_fix():
    y = np.random.default_rng(0).integers(0, 4, size=200).astype(np.int32)
    raw = data_mod.dirichlet_partition(y, 24, alpha=0.05, seed=3, min_samples=0)
    assert any(len(p) == 0 for p in raw), "low alpha should reproduce empty clients"
    fixed = data_mod.dirichlet_partition(y, 24, alpha=0.05, seed=3, min_samples=2)
    assert all(len(p) >= 2 for p in fixed)
    # a partition stays a partition: indices disjoint and complete
    allidx = np.concatenate(fixed)
    assert len(allidx) == len(y) and len(np.unique(allidx)) == len(y)
    # default guarantees >= 1
    dflt = data_mod.dirichlet_partition(y, 24, alpha=0.05, seed=3)
    assert all(len(p) >= 1 for p in dflt)
    # clients already above the floor are untouched by the default
    rich = data_mod.dirichlet_partition(y, 4, alpha=10.0, seed=5, min_samples=0)
    assert all(len(p) >= 1 for p in rich)
    same = data_mod.dirichlet_partition(y, 4, alpha=10.0, seed=5)
    for a, b in zip(rich, same):
        np.testing.assert_array_equal(a, b)
    with pytest.raises(ValueError):
        data_mod.dirichlet_partition(y, 300, alpha=1.0, min_samples=1)


def test_masked_padding_matches_reference_on_heavily_ragged_shards():
    """Engine equivalence where it hurts: shard sizes 1 vs ~200 in one
    padded stack — the vectorized masked path must reproduce each
    worker's unpadded loss and delta."""
    import jax

    sys_, app = build_app(seed=30, workers=6)
    ws = [w for w in sorted(app.handle.tree.members) if w in app.data]
    # make it brutally ragged: sizes 1, 2, 5, and the rest untouched
    for w, size in zip(ws[:3], (1, 2, 5)):
        x, y = app.data[w]
        app.data[w] = (x[:size], y[:size])
    x, y, mask = engine.pack_shards(app.data, ws)
    assert mask.shape[0] == len(ws)
    np.testing.assert_allclose(
        np.asarray(mask.sum(axis=1)),
        [len(app.data[w][1]) for w in ws],
    )
    vec = engine.local_training(app, ws, vectorized=True)
    ref = engine.local_training(app, ws, vectorized=False)
    assert vec[1] == ref[1]  # weights = shard sizes
    np.testing.assert_allclose(vec[2], ref[2], rtol=1e-4, atol=1e-6)
    for dv, dr in zip(vec[0], ref[0]):
        for lv, lr_ in zip(jax.tree.leaves(dv), jax.tree.leaves(dr)):
            np.testing.assert_allclose(
                np.asarray(lv), np.asarray(lr_), rtol=1e-4, atol=1e-6
            )
