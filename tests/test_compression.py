"""Compressed transport: QSGD commits through the fair-share fluid model.

Locks down the compression layer end to end:

- **Quantizer properties** (hypothesis-optional, deterministic fallback
  like test_scale.py): per-element round-trip error <= scale/2 under
  deterministic rounding and < scale under stochastic rounding, on zero
  rows, ragged last chunks, and 1-element rows; |q| bounded by
  ``levels`` so the lattice always fits int8.
- **Three-way bit-exactness**: the Pallas kernel (interpret off-TPU),
  ``ref.quantize_ref``, and the pure-JAX ``fl/compression.qsgd_quantize``
  agree bit for bit under shared uniforms, in both ``kernel_mode``
  settings and for non-default ``levels``.
- **Per-commit rounding keys** (the rand=0.5 bias fix): a fixed
  (seed, app, seq) triple reproduces the wire bytes exactly; different
  sequence numbers decorrelate the rounding.
- **Fused dequant-in-aggregate**: ``buffered_aggregate_quantized``
  (per-row scales composed with staleness weights inside one
  ``tree_aggregate_groups`` call) equals the unfused
  dequantize-then-average reference.
- **Trace identity**: ``policy=None`` and ``kind="none"`` produce
  byte-identical ApplyEvent/ChurnRecord traces and fairness logs at
  M=16 — compression off must be provably free.
- **Wire conservation**: under an enabled policy every commit-direction
  flow enters ``EventCore.open_flow`` at exactly
  ``wire_bytes(model_bytes)`` (== the real ``QuantizedDelta.nbytes``),
  downloads stay full-size, nothing is left in flight, and the uplink
  byte ledger matches commits x legs x wire bytes.
- **End-to-end**: a trained qsgd-int8 run converges next to the
  uncompressed run, and mixed quantized/raw buffers are rejected.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dev dep: the property tests widen to random draws with it
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.api import TotoroSystem
from repro.core.sim import AsyncBufferScheduler, ChurnModel
from repro.fl import compression as comp
from repro.fl.compression import CompressionPolicy, QuantizedDelta
from repro.kernels import ops as kops
from repro.kernels import quantize as kq
from repro.kernels import ref


@pytest.fixture
def kernel_mode_guard():
    prev = kops.kernel_mode()
    yield
    kops.set_kernel_mode(prev)


def _rows(seed, r, c=256, kind="normal"):
    rng = np.random.default_rng(seed)
    if kind == "zeros":
        return np.zeros((r, c), np.float32)
    x = rng.normal(0, 3.0, (r, c)).astype(np.float32)
    if kind == "spiky":
        x[rng.integers(0, r, 3), rng.integers(0, c, 3)] *= 1e4
    return x


# -- round-trip error bounds ---------------------------------------------------


def _check_roundtrip(x, levels=127, key=None):
    x = jnp.asarray(x, jnp.float32)
    if key is None:
        q, s = comp.qsgd_quantize(x, levels=levels)
        bound = 0.5  # round-half-down: error <= scale/2
    else:
        q, s = comp.qsgd_quantize(x, levels=levels, key=key)
        bound = 1.0  # stochastic floor(x/s + u): error < scale
    q, s = np.asarray(q), np.asarray(s)
    assert q.dtype == np.int8
    assert np.abs(q.astype(np.int64)).max(initial=0) <= levels
    err = np.abs(np.asarray(x) - q.astype(np.float32) * s)
    # bound is per element, in units of that row's scale (+ fp slack)
    assert np.all(err <= s * bound + 1e-5 * np.maximum(s, 1.0)), (
        float((err / s).max()), bound
    )


@pytest.mark.parametrize("seed,r,kind", [
    (0, 4, "normal"), (1, 1, "normal"), (2, 8, "spiky"), (3, 4, "zeros"),
])
def test_roundtrip_deterministic_half_scale(seed, r, kind):
    _check_roundtrip(_rows(seed, r, kind=kind))


@pytest.mark.parametrize("seed,r,levels", [(0, 4, 127), (1, 2, 15), (2, 6, 1)])
def test_roundtrip_stochastic_full_scale(seed, r, levels):
    _check_roundtrip(_rows(seed, r), levels=levels, key=jax.random.PRNGKey(seed))


def test_roundtrip_one_element_rows():
    # degenerate trailing dim: scale = |x| / levels per element
    _check_roundtrip(_rows(5, 7, c=1))


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        r=st.integers(1, 12),
        levels=st.integers(1, 127),
        stochastic=st.booleans(),
    )
    def test_roundtrip_property(seed, r, levels, stochastic):
        key = jax.random.PRNGKey(seed) if stochastic else None
        _check_roundtrip(_rows(seed, r), levels=levels, key=key)


# -- Pallas == ref == pure-JAX, both kernel modes ------------------------------


@pytest.mark.parametrize("mode", ["pallas", "jnp"])
@pytest.mark.parametrize("levels", [127, 15])
def test_three_way_bit_exact_parity(kernel_mode_guard, mode, levels):
    """One set of uniforms, three implementations: lattice points bit-
    exact, scales at 1-ULP (the /levels division fuses differently per
    compile — test_kernels.py holds the same contract)."""
    x = jnp.asarray(_rows(9, 8), jnp.float32)
    rand = jax.random.uniform(jax.random.PRNGKey(3), x.shape, jnp.float32)
    kops.set_kernel_mode(mode)
    q_w, s_w = kops.qsgd_quantize(x, rand, levels=levels)
    q_r, s_r = ref.quantize_ref(x, rand, levels=levels)
    q_p, s_p = comp.qsgd_quantize(x, levels=levels, rand=rand)
    np.testing.assert_array_equal(np.asarray(q_w), np.asarray(q_r))
    np.testing.assert_array_equal(np.asarray(q_w), np.asarray(q_p))
    np.testing.assert_allclose(
        np.asarray(s_w).ravel(), np.asarray(s_r).ravel(), rtol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(s_w).ravel(), np.asarray(s_p).ravel(), rtol=1e-6
    )


def test_pallas_kernel_direct_matches_ref():
    # the raw kernel entry point (block-aligned shapes), not the wrapper
    r = kq.ROWS_PER_BLOCK
    x = jnp.asarray(_rows(11, r), jnp.float32)
    rand = jax.random.uniform(jax.random.PRNGKey(7), x.shape, jnp.float32)
    q_k, s_k = kq.qsgd_quantize(x, rand, interpret=True, levels=31)
    q_r, s_r = ref.quantize_ref(x, rand, levels=31)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)


# -- policy object / wire-size model -------------------------------------------


def test_policy_validation_and_as_policy():
    with pytest.raises(ValueError, match="kind"):
        CompressionPolicy(kind="gzip")
    with pytest.raises(ValueError, match="levels"):
        CompressionPolicy(kind="qsgd-int8", levels=128)
    with pytest.raises(ValueError, match="chunk"):
        CompressionPolicy(kind="qsgd-int8", chunk=0)
    with pytest.raises(TypeError):
        comp.as_policy(3.14)
    assert comp.as_policy(None) is None
    assert comp.as_policy("qsgd-int8") == CompressionPolicy(kind="qsgd-int8")
    p = CompressionPolicy(kind="qsgd-int8")
    assert comp.as_policy(p) is p
    assert not CompressionPolicy().enabled and p.enabled


@pytest.mark.parametrize("n,chunk", [(1, 256), (256, 256), (257, 256), (5000, 256),
                                     (7, 64), (64, 64), (100, 3)])
def test_wire_bytes_matches_real_quantized_delta(n, chunk):
    """The scheduler's pricing model == the actual serialized size."""
    policy = CompressionPolicy(kind="qsgd-int8", chunk=chunk)
    delta = {"w": np.random.default_rng(n).normal(size=n).astype(np.float32)}
    qd = comp.quantize_delta(delta, policy, key=jax.random.PRNGKey(0))
    assert qd.nbytes == policy.wire_bytes(4.0 * n)
    rows = math.ceil(n / chunk)
    assert qd.nbytes == rows * chunk + rows * 4
    # compression actually compresses once a full f32 row is in play
    if n >= chunk:
        assert qd.nbytes < 4.0 * n


def test_wire_bytes_none_is_float_identity():
    p = CompressionPolicy()
    assert p.wire_bytes(1.5e6) == float(1.5e6)


def test_quantize_delta_roundtrip_pytree_and_padding():
    rng = np.random.default_rng(0)
    delta = {
        "a": rng.normal(size=(13, 7)).astype(np.float32),
        "b": rng.normal(size=(5,)).astype(np.float32),
    }
    policy = CompressionPolicy(kind="qsgd-int8")
    qd = comp.quantize_delta(delta, policy, key=jax.random.PRNGKey(1))
    assert qd.length == 13 * 7 + 5
    back = comp.dequantize_delta(qd)
    assert set(back) == {"a", "b"}
    assert back["a"].shape == (13, 7) and back["b"].shape == (5,)
    # rows chunk the FLATTENED pytree, so the error bound is the global
    # max-abs (one 96-element delta -> one row, one shared scale)
    s_max = max(np.abs(v).max() for v in delta.values()) / policy.levels
    for k in delta:
        assert np.abs(back[k] - delta[k]).max() < s_max + 1e-6
    # padding elements (zeros) quantize to exactly 0: floor(0 + u) = 0
    pad = np.asarray(qd.q).ravel()[qd.length:]
    assert np.all(pad == 0)


def test_commit_key_reproduces_and_decorrelates():
    policy = CompressionPolicy(kind="qsgd-int8", seed=5)
    delta = {"w": np.random.default_rng(2).normal(size=700).astype(np.float32)}
    k0 = comp.commit_key(policy, 0, 0)
    qa = comp.quantize_delta(delta, policy, k0)
    qb = comp.quantize_delta(delta, policy, comp.commit_key(policy, 0, 0))
    np.testing.assert_array_equal(qa.q, qb.q)  # fixed triple: exact bytes
    np.testing.assert_array_equal(qa.scale, qb.scale)
    # consecutive commits (and sibling apps) draw different rounding bits
    qc = comp.quantize_delta(delta, policy, comp.commit_key(policy, 0, 1))
    qd = comp.quantize_delta(delta, policy, comp.commit_key(policy, 1, 0))
    assert not np.array_equal(qa.q, qc.q)
    assert not np.array_equal(qa.q, qd.q)
    np.testing.assert_array_equal(qa.scale, qc.scale)  # scales are rand-free


# -- fused dequantize-in-aggregate ---------------------------------------------


@pytest.mark.parametrize("mode", ["jnp", "pallas"])
def test_fused_aggregate_matches_unfused_reference(kernel_mode_guard, mode):
    """agg = sum_k w_k * (q_k * s_k) / sum_k w_k with the staleness
    discount folded into the kernel's weight vector — compare against the
    plain dequantize-then-average done in float64 on the host."""
    kops.set_kernel_mode(mode)
    rng = np.random.default_rng(4)
    policy = CompressionPolicy(kind="qsgd-int8")
    K, n = 5, 600
    qds, weights, staleness = [], [], []
    for k in range(K):
        delta = {"w": rng.normal(0, 2.0, n).astype(np.float32)}
        qds.append(comp.quantize_delta(delta, policy, jax.random.PRNGKey(k)))
        weights.append(float(rng.uniform(0.5, 2.0)))
        staleness.append(float(k % 3))
    alpha = 0.5
    flat, combined = kops.buffered_aggregate_quantized(
        [q.q for q in qds], [q.scale for q in qds], weights, staleness,
        alpha=alpha,
    )
    w = np.asarray([wt / (1.0 + s) ** alpha for wt, s in zip(weights, staleness)])
    deq = np.stack([
        (q.q.astype(np.float64) * q.scale.astype(np.float64)).ravel() for q in qds
    ])
    expect = (w[:, None] * deq).sum(0) / w.sum()
    np.testing.assert_allclose(np.asarray(flat), expect, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(combined), w, rtol=1e-6)


# -- scheduler fixtures --------------------------------------------------------


def _build_handles(m, workers=4, n_nodes=160, seed=0, compression=None):
    """Timing-only fixture: M dataflow trees over one shared overlay."""
    sys_ = TotoroSystem(zone_bits=2, suffix_bits=22, seed=seed)
    rng = np.random.default_rng(seed)
    nodes = [
        sys_.Join("n", i, site=i % 4, coord=rng.uniform(0, 50, 2),
                  bandwidth=float(rng.uniform(20, 100)))
        for i in range(n_nodes)
    ]
    handles = []
    for a in range(m):
        h = sys_.CreateTree(f"comp-{m}-{a}", compression=compression)
        for w in rng.choice(nodes, size=workers, replace=False):
            sys_.Subscribe(h.app_id, int(w))
        handles.append(h)
    return sys_, handles


def _trace(m, *, compression, seed=0, applies=2, churn=True,
           model_bytes=2e5, **sched_kw):
    sys_, handles = _build_handles(m, seed=seed)
    sched = AsyncBufferScheduler(
        sys_, handles, model_bytes=model_bytes, compute_ms=25.0, buffer_k=3,
        churn=ChurnModel(period_ms=400.0, downtime_ms=600.0, group_size=2, seed=9)
        if churn else None,
        app_compression=compression, **sched_kw,
    )
    events = sched.run(applies, max_events=500_000)
    return events, list(sched.churn_log), list(sched.fairness_log), sched


# -- policy=none trace identity ------------------------------------------------


def test_m16_policy_none_trace_byte_identical():
    """Compression off must be free: the default (no policy) and an
    explicit kind="none" policy produce the same ApplyEvents,
    ChurnRecords and fairness log, byte for byte."""
    base = _trace(16, compression=None)
    off = _trace(16, compression=CompressionPolicy(kind="none"))
    assert base[0] == off[0]  # exact ApplyEvent equality
    assert base[1] == off[1]  # exact ChurnRecord equality
    assert base[2] == off[2]  # fairness log: uplink bytes, jain, rates


def test_policy_none_identity_under_legacy_and_sampled_pricing():
    for kw in (dict(fair=False), dict(congestion_mode="sampled", churn=False)):
        base = _trace(4, compression=None, **kw)
        off = _trace(4, compression="none", **kw)
        assert base[:3] == off[:3]


def test_handle_compression_feeds_scheduler_and_arg_overrides():
    sys_, handles = _build_handles(
        2, compression=CompressionPolicy(kind="qsgd-int8")
    )
    sched = AsyncBufferScheduler(sys_, handles, model_bytes=1e6)
    assert all(p is not None and p.enabled for p in sched._compression)
    assert sched._commit_bytes[0] == handles[0].compression.wire_bytes(1e6)
    # explicit arg beats the handle attribute
    sched2 = AsyncBufferScheduler(
        sys_, handles, model_bytes=1e6, app_compression="none"
    )
    assert sched2._commit_bytes == [1e6, 1e6]


# -- compressed-path wire conservation -----------------------------------------


def test_compressed_flows_priced_at_exact_wire_bytes():
    """Every commit-direction flow opens at wire_bytes(model_bytes)
    (== the serialized QuantizedDelta size), downloads stay full-size,
    and the ledger closes: no in-flight flows, uplink bytes == commit
    legs x wire bytes — exact conservation across join/complete
    repricing."""
    model_bytes = 1.5e6
    policy = CompressionPolicy(kind="qsgd-int8")
    wire_mbit = policy.wire_bytes(model_bytes) * 8e-6
    full_mbit = model_bytes * 8e-6
    assert wire_mbit < 0.3 * full_mbit

    sys_, handles = _build_handles(3, seed=1)
    sched = AsyncBufferScheduler(
        sys_, handles, model_bytes=model_bytes, compute_ms=25.0, buffer_k=3,
        app_compression=policy,
    )
    opened = []
    orig = sched.open_flow
    sched.open_flow = lambda sender, mbit, **kw: (
        opened.append(float(mbit)), orig(sender, mbit, **kw)
    )[1]
    sched.run(2, max_events=4_000_000)
    assert opened, "fair mode must route transfers through open_flow"
    # exactly two flow sizes exist: full-model downloads, compressed commits
    assert set(opened) == {full_mbit, wire_mbit}
    commits = sum(1 for m in opened if m == wire_mbit)
    assert commits > 0
    # conservation: anything still in flight at shutdown is partially
    # delivered against exactly one of the two flow sizes; completed
    # flows were drained in full by _finish_flow (delivered == total)
    for f in sched._flows.values():
        assert f.total_mbit in (full_mbit, wire_mbit)
        assert f.delivered_mbit <= f.total_mbit + 1e-12
    # the uplink ledger is commit-leg granular at the compressed size:
    # every credited commit leg contributed exactly wire_bytes
    stats = sched.transport_stats()
    credited = sum(stats["uplink_bytes"])
    assert credited > 0
    assert credited / policy.wire_bytes(model_bytes) == pytest.approx(
        round(credited / policy.wire_bytes(model_bytes))
    )
    assert credited <= commits * policy.wire_bytes(model_bytes)


def test_compressed_run_moves_fewer_bytes_and_finishes_sooner():
    base = _trace(4, compression=None, churn=False)
    qsgd = _trace(4, compression="qsgd-int8", churn=False)
    b_stats, q_stats = base[3].transport_stats(), qsgd[3].transport_stats()
    assert sum(q_stats["uplink_bytes"]) < 0.3 * sum(b_stats["uplink_bytes"])
    # commits travel ~4x faster, so every app's applies complete earlier
    assert all(
        q <= b for q, b in zip(q_stats["done_ms"], b_stats["done_ms"])
    )


# -- data-plane integration ----------------------------------------------------


def test_mixed_quantized_raw_buffer_rejected():
    sys_, handles = _build_handles(1, workers=2, n_nodes=20, seed=3)
    h = handles[0]
    raw = {"w": np.ones(4, np.float32)}
    qd = comp.quantize_delta(
        raw, CompressionPolicy(kind="qsgd-int8"), jax.random.PRNGKey(0)
    )
    ws = sorted(h.tree.members)[:2]
    sys_.CommitDelta(h.app_id, ws[0], raw, weight=1.0, staleness=0)
    sys_.CommitDelta(h.app_id, ws[1], qd, weight=1.0, staleness=0)
    with pytest.raises(ValueError, match="mixed quantized and raw"):
        sys_.ApplyBuffered(h.app_id)


def test_apply_buffered_all_quantized_matches_raw_aggregate():
    """Same deltas through the quantized and raw ApplyBuffered paths:
    results agree to quantization error (scale/levels per element)."""
    rng = np.random.default_rng(6)
    policy = CompressionPolicy(kind="qsgd-int8")
    deltas = [{"w": rng.normal(0, 1.0, 300).astype(np.float32)} for _ in range(3)]
    out = []
    for quantize in (False, True):
        sys_, handles = _build_handles(1, workers=3, n_nodes=20, seed=4)
        h = handles[0]
        for i, (w, d) in enumerate(zip(sorted(h.tree.members)[:3], deltas)):
            payload = (
                comp.quantize_delta(d, policy, jax.random.PRNGKey(i))
                if quantize else d
            )
            sys_.CommitDelta(h.app_id, w, payload, weight=1.0, staleness=i % 2)
        out.append(sys_.ApplyBuffered(h.app_id, staleness_alpha=0.5))
    raw, quant = out
    assert raw["weights"] == pytest.approx(quant["weights"])
    scale_bound = max(np.abs(d["w"]).max() for d in deltas) / policy.levels
    np.testing.assert_allclose(
        quant["result"]["w"], raw["result"]["w"], atol=scale_bound + 1e-6
    )


def _train_async(compression, seed=0):
    from benchmarks.common import build_system
    from repro import data as data_mod
    from repro.fl import async_engine, rounds

    sys_, nodes, rng = build_system(n_nodes=80, zones=3, seed=seed)
    apps = []
    for a in range(2):
        x, y = data_mod.synthetic_classification(6 * 24, 16, 4, seed=100 + a)
        parts = data_mod.dirichlet_partition(y, 6, alpha=1.0, seed=200 + a)
        ws = [int(n) for n in rng.choice(nodes, size=6, replace=False)]
        apps.append(rounds.make_app(
            sys_, f"tc-{a}", workers=ws,
            data_by_worker={n: (x[parts[i]], y[parts[i]]) for i, n in enumerate(ws)},
            dim=16, num_classes=4, local_steps=2, lr=0.2, seed=a,
        ))
    return async_engine.run_async(
        sys_, apps, applies=5, buffer_k=4, model_bytes=4e5,
        compute_ms=20.0, compression=compression,
    )


def test_trained_qsgd_converges_close_to_uncompressed():
    base = _train_async(None)
    qsgd = _train_async("qsgd-int8")
    f_base = np.mean([r["loss"] for r in base["history"][-2:]])
    f_qsgd = np.mean([r["loss"] for r in qsgd["history"][-2:]])
    assert np.isfinite(f_qsgd)
    assert abs(f_qsgd - f_base) <= 1e-1  # tiny fixture; bench gates 1e-2
    # the data plane really shipped QuantizedDeltas: commit seqs advanced
    tr = qsgd["trainer"]
    assert all(s > 0 for s in tr._commit_seq)
    # and the compressed run's commits were priced smaller
    q_up = sum(qsgd["scheduler"].transport_stats()["uplink_bytes"])
    b_up = sum(base["scheduler"].transport_stats()["uplink_bytes"])
    assert q_up < 0.3 * b_up


def test_trained_policy_none_trace_identical_to_default():
    base = _train_async(None)
    off = _train_async(CompressionPolicy(kind="none"))
    assert base["events"] == off["events"]
    assert base["churn"] == off["churn"]
    assert [r["loss"] for r in base["history"]] == [
        r["loss"] for r in off["history"]
    ]


# -- bench registration --------------------------------------------------------


def test_bench_compression_registered():
    from benchmarks.run import REGISTRY

    names = [n for n, _, _ in REGISTRY]
    mods = [m for _, m, _ in REGISTRY]
    assert "compression" in names
    assert "benchmarks.bench_compression" in mods
