"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode
on CPU; TPU is the compile target).

Since the hot-path PR, ``ops`` routes to compiled jnp fallbacks off-TPU
(``kernel_mode() == "auto"``); the property tests below pin the mode per
path so the Pallas interpret source keeps its coverage, and assert the
two paths agree on arbitrary ragged/1-sample shapes.  Deterministic
(no-hypothesis) parity coverage lives in tests/test_hotpath.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # optional dev dep
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref


@pytest.mark.parametrize("C", [2, 4, 8, 16, 32])
@pytest.mark.parametrize("L", [1024, 4096, 333])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tree_aggregate_sweep(C, L, dtype):
    key = jax.random.key(C * L)
    g = jax.random.normal(key, (C, L), dtype)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (C,))
    np.testing.assert_allclose(
        np.asarray(ops.tree_aggregate(g, w)),
        np.asarray(ref.tree_aggregate_ref(g, w)),
        rtol=1e-5, atol=1e-5,
    )


def test_tree_aggregate_pytree_matches_fedavg():
    from repro.fl.aggregation import fedavg

    key = jax.random.key(0)
    updates = [
        {"a": jax.random.normal(jax.random.fold_in(key, i), (40, 7)),
         "b": jax.random.normal(jax.random.fold_in(key, 10 + i), (13,))}
        for i in range(5)
    ]
    w = [1.0, 2.0, 3.0, 0.5, 1.5]
    agg = ops.tree_aggregate_pytree(updates, np.asarray(w) / np.sum(w))
    expect = fedavg(updates, w)
    for a, b in zip(jax.tree.leaves(agg), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("R", [64, 256, 777])
def test_quantize_bit_exact_and_bounded(R):
    key = jax.random.key(R)
    x = jax.random.normal(key, (R, 256)) * 5
    rnd = jax.random.uniform(jax.random.fold_in(key, 1), (R, 256))
    q, s = ops.qsgd_quantize(x, rnd)
    qr, sr = ref.quantize_ref(x, rnd)
    assert bool(jnp.all(q == qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    # dequant error bounded by one quantization step per element
    deq = ops.qsgd_dequantize(q, s)
    assert bool(jnp.all(jnp.abs(deq - x) <= s + 1e-6))


def test_quantize_unbiased_with_uniform_noise():
    """E[dequant] == x under stochastic rounding (QSGD property)."""
    key = jax.random.key(3)
    x = jax.random.normal(key, (4, 256))
    outs = []
    for i in range(400):
        rnd = jax.random.uniform(jax.random.fold_in(key, i), (4, 256))
        q, s = ops.qsgd_quantize(x, rnd)
        outs.append(ops.qsgd_dequantize(q, s))
    bias = jnp.mean(jnp.stack(outs), 0) - x
    assert float(jnp.max(jnp.abs(bias))) < 0.02


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 16), st.integers(1, 8), st.integers(0, 999))
def test_policy_update_kernel_matches_alg1(K, tau, seed):
    from repro.core.pathplan import algorithm1_episode, candidate_policy_set

    key = jax.random.key(seed)
    N = 64
    pi = jax.random.dirichlet(key, jnp.ones(K), (N,)).astype(jnp.float32)
    mask = jnp.ones((N, K), bool)
    cand = candidate_policy_set(K, seed=seed)
    actions = jax.random.randint(jax.random.fold_in(key, 1), (N, tau), 0, K)
    rewards = jax.random.uniform(jax.random.fold_in(key, 2), (N, tau))
    rsums = (jax.nn.one_hot(actions, K) * rewards[..., None]).sum(1)
    out_k = ops.policy_update(pi, mask, cand, rsums, tau=tau, alpha=0.8, beta=0.4)
    out_a = algorithm1_episode(pi, mask, cand, actions, rewards, tau=tau, alpha=0.8, beta=0.4)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_a), atol=1e-5)


@pytest.mark.parametrize("shape,dtype", [((1000,), jnp.float32), ((64, 100), jnp.bfloat16), ((7, 3, 11), jnp.float32)])
def test_fused_update_sweep(shape, dtype):
    key = jax.random.key(hash(shape) % 2**31)
    w = jax.random.normal(key, shape, dtype)
    g = jax.random.normal(jax.random.fold_in(key, 1), shape, dtype)
    w0 = jax.random.normal(jax.random.fold_in(key, 2), shape, dtype)
    out = ops.fused_update(w, g, w0, lr=0.05, mu=0.1, wd=0.01)
    expect = ref.fused_update_ref(w, g, w0, 0.05, 0.1, 0.01)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), rtol=1e-2, atol=1e-2
    )
    assert out.dtype == w.dtype


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6), st.integers(1, 9), st.integers(1, 2100), st.integers(0, 999))
def test_tree_aggregate_groups_mode_parity_property(G, C, L, seed):
    """jnp fallback == Pallas interpret == oracle on arbitrary ragged
    (G, C, L) — including C=1 (single-child groups) and tiny L."""
    prev = ops.kernel_mode()
    try:
        key = jax.random.key(seed)
        g = jax.random.normal(key, (G, C, L))
        w = jax.random.uniform(jax.random.fold_in(key, 1), (G, C))
        ops.set_kernel_mode("jnp")
        out_jnp = np.asarray(ops.tree_aggregate_groups(g, w))
        ops.set_kernel_mode("pallas")
        out_pl = np.asarray(ops.tree_aggregate_groups(g, w))
    finally:
        ops.set_kernel_mode(prev)
    expect = np.einsum("gc,gcl->gl", np.asarray(w), np.asarray(g))
    np.testing.assert_allclose(out_jnp, out_pl, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out_jnp, expect, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 10), st.floats(0.0, 2.0), st.integers(0, 999))
def test_buffered_aggregate_mode_parity_property(K, alpha, seed):
    """Staleness-weighted apply parity across kernel modes on ragged
    pytrees down to K=1 (a single buffered commit)."""
    rng = np.random.default_rng(seed)
    ups = [
        {"a": rng.standard_normal((5, 2)).astype(np.float32),
         "b": rng.standard_normal(9).astype(np.float32)}
        for _ in range(K)
    ]
    w = list(rng.uniform(0.5, 3.0, K))
    s = list(rng.integers(0, 6, K))
    prev = ops.kernel_mode()
    try:
        ops.set_kernel_mode("jnp")
        agg_j, cw_j = ops.buffered_aggregate(ups, w, s, alpha=alpha)
        ops.set_kernel_mode("pallas")
        agg_p, cw_p = ops.buffered_aggregate(ups, w, s, alpha=alpha)
    finally:
        ops.set_kernel_mode(prev)
    for a, b in zip(jax.tree.leaves(agg_j), jax.tree.leaves(agg_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cw_j), np.asarray(cw_p), rtol=1e-6)
    disc = np.asarray(w) * (1.0 + np.asarray(s, float)) ** -alpha
    expect = (np.stack([np.concatenate([u["a"].ravel(), u["b"].ravel()]) for u in ups])
              * disc[:, None]).sum(0) / disc.sum()
    got = np.concatenate([np.asarray(l).ravel() for l in jax.tree.leaves(agg_j)])
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 5000), st.integers(0, 999))
def test_fused_update_mode_parity_property(L, seed):
    key = jax.random.key(seed)
    w = jax.random.normal(key, (L,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (L,))
    w0 = jax.random.normal(jax.random.fold_in(key, 2), (L,))
    prev = ops.kernel_mode()
    try:
        ops.set_kernel_mode("jnp")
        out_j = np.asarray(ops.fused_update(w, g, w0, lr=0.05, mu=0.1, wd=0.01))
        ops.set_kernel_mode("pallas")
        out_p = np.asarray(ops.fused_update(w, g, w0, lr=0.05, mu=0.1, wd=0.01))
    finally:
        ops.set_kernel_mode(prev)
    expect = np.asarray(ref.fused_update_ref(w, g, w0, 0.05, 0.1, 0.01))
    np.testing.assert_allclose(out_j, expect, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out_p, expect, rtol=1e-5, atol=1e-5)
