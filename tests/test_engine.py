"""Vectorized round engine + hierarchical aggregation + event simulator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import data as data_mod
from repro.core.api import TotoroSystem
from repro.core.sim import MultiAppSimulator, per_app_round_ms
from repro.fl import engine, rounds
from repro.kernels import ops as kops


def build_app(n_nodes=150, workers=8, *, ragged=True, seed=0):
    sys_ = TotoroSystem(zone_bits=2, suffix_bits=20, seed=seed)
    rng = np.random.default_rng(seed)
    nodes = [sys_.Join("n", i, site=i % 4, coord=rng.uniform(0, 50, 2)) for i in range(n_nodes)]
    x, y = data_mod.synthetic_classification(workers * 150, 16, 4, seed=seed)
    if ragged:
        parts = data_mod.dirichlet_partition(y, workers, alpha=1.0, seed=seed + 1)
        parts = [p if len(p) else np.arange(3) for p in parts]
    else:
        parts = [np.arange(i * 150, (i + 1) * 150) for i in range(workers)]
    ws = [int(w) for w in rng.choice(nodes, size=workers, replace=False)]
    app = rounds.make_app(
        sys_, "eng-test", workers=ws,
        data_by_worker={w: (x[parts[i]], y[parts[i]]) for i, w in enumerate(ws)},
        dim=16, num_classes=4, local_steps=3, lr=0.2,
    )
    return sys_, app, (x, y)


def test_vectorized_matches_reference_loop():
    """vmapped masked local training == per-worker loop (ragged shards)."""
    _, app, _ = build_app(ragged=True)
    ws = [w for w in sorted(app.handle.tree.members) if w in app.data]
    d_v, w_v, l_v = engine.local_training(app, ws, vectorized=True)
    d_r, w_r, l_r = engine.local_training(app, ws, vectorized=False)
    assert w_v == w_r
    np.testing.assert_allclose(l_v, l_r, rtol=1e-4, atol=1e-6)
    for a, b in zip(d_v, d_r):
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_allclose(
                np.asarray(la), np.asarray(lb), rtol=5e-4, atol=5e-6
            )


def test_vectorized_matches_reference_with_fedprox():
    _, app, _ = build_app(ragged=True, seed=3)
    app.mu = 0.1
    ws = [w for w in sorted(app.handle.tree.members) if w in app.data]
    d_v, _, _ = engine.local_training(app, ws, vectorized=True)
    d_r, _, _ = engine.local_training(app, ws, vectorized=False)
    for a, b in zip(d_v, d_r):
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_allclose(
                np.asarray(la), np.asarray(lb), rtol=5e-4, atol=5e-6
            )


def test_round_vectorized_and_reference_converge_identically():
    sys_v, app_v, (x, y) = build_app(seed=1)
    sys_r, app_r, _ = build_app(seed=1)
    for _ in range(3):
        rounds.run_round(sys_v, app_v, vectorized=True)
        rounds.run_round(sys_r, app_r, vectorized=False)
    for la, lb in zip(jax.tree.leaves(app_v.params), jax.tree.leaves(app_r.params)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-3, atol=1e-5)
    assert rounds.evaluate(app_v, x[:300], y[:300]) > 0.7


def test_aggregation_schedule_invariants():
    sys_, app, _ = build_app(n_nodes=300, workers=32)
    tree = app.handle.tree
    sched = tree.aggregation_schedule()
    parents = [p for level in sched for p, _ in level]
    assert len(parents) == len(set(parents))  # each parent exactly once
    assert set(parents) == {n for n, c in tree.children.items() if c}
    # each level's parents share one depth; levels run deepest-first
    level_depths = [{tree.depth_of(p) for p, _ in level} for level in sched]
    assert all(len(d) == 1 for d in level_depths)
    flat_depths = [d.copy().pop() for d in level_depths]
    assert flat_depths == sorted(flat_depths, reverse=True)
    for level in sched:
        for p, kids in level:
            assert kids == sorted(tree.children[p])
            for c in kids:
                assert tree.parent[c] == p


def test_hierarchical_aggregate_matches_flat_mean():
    sys_, app, _ = build_app(n_nodes=300, workers=24, seed=2)
    tree = app.handle.tree
    rng = np.random.default_rng(0)
    members = sorted(tree.members)
    objs = {
        w: {"a": rng.standard_normal((7, 5)).astype(np.float32),
            "b": rng.standard_normal(33).astype(np.float32)}
        for w in members
    }
    wts = {w: float(rng.integers(1, 9)) for w in members}
    hier = sys_.Aggregate(app.handle.app_id, objs, weights=wts)
    flat = sys_.Aggregate(app.handle.app_id, objs, weights=wts, hierarchical=False)
    for la, lb in zip(jax.tree.leaves(hier["result"]), jax.tree.leaves(flat["result"])):
        np.testing.assert_allclose(
            np.asarray(la, np.float64), np.asarray(lb, np.float64), rtol=1e-5, atol=1e-6
        )
    # metrics follow the tree: one entry per level, traffic = edges * vec
    assert hier["levels"], "level metrics missing"
    assert hier["bytes"] == sum(lv["bytes"] for lv in hier["levels"])
    assert hier["time_ms"] == sum(lv["time_ms"] for lv in hier["levels"])
    n_edge_transfers = hier["bytes"] / (4.0 * (7 * 5 + 33))
    assert n_edge_transfers >= len(members)  # every member's update crossed >=1 edge


def test_hierarchical_aggregate_root_only_payload_weighted():
    """A weighted payload from just the root of a childless tree must
    still come back as the weighted mean (== the payload itself)."""
    sys_ = TotoroSystem(zone_bits=2, suffix_bits=20, seed=6)
    rng = np.random.default_rng(6)
    for i in range(50):
        sys_.Join("n", i, site=i % 4, coord=rng.uniform(0, 10, 2))
    h = sys_.CreateTree("root-only")
    v = np.ones(4, np.float32)
    res = sys_.Aggregate(h.app_id, {h.tree.root: v}, weights={h.tree.root: 2.0})
    np.testing.assert_allclose(np.asarray(res["result"]), v)


def test_hierarchical_aggregate_no_kernel_reference_path():
    sys_, app, _ = build_app(n_nodes=200, workers=10, seed=4)
    members = sorted(app.handle.tree.members)
    rng = np.random.default_rng(1)
    objs = {w: rng.standard_normal(50).astype(np.float32) for w in members}
    k = sys_.Aggregate(app.handle.app_id, objs)
    nk = sys_.Aggregate(app.handle.app_id, objs, use_kernel=False)
    np.testing.assert_allclose(np.asarray(k["result"]), np.asarray(nk["result"]), rtol=1e-5)


def test_tree_aggregate_groups_kernel_matches_oracle():
    key = jax.random.key(0)
    G, C, L = 5, 6, 700  # L not a tile multiple: wrapper pads
    g = jax.random.normal(key, (G, C, L), jnp.float32)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (G, C), jnp.float32)
    w = w.at[:, -2:].set(0.0)  # ragged groups = zero-weight padding slots
    out = kops.tree_aggregate_groups(g, w)
    oracle = (np.asarray(g) * np.asarray(w)[..., None]).sum(axis=1)
    np.testing.assert_allclose(np.asarray(out), oracle, rtol=1e-5, atol=1e-5)


def build_sim_system(m_apps=3, seed=9):
    sys_ = TotoroSystem(zone_bits=2, suffix_bits=20, seed=seed)
    rng = np.random.default_rng(seed)
    nodes = [
        sys_.Join("n", i, site=i % 4, coord=rng.uniform(0, 50, 2),
                  bandwidth=float(rng.uniform(20, 100)))
        for i in range(300)
    ]
    handles = []
    for a in range(m_apps):
        h = sys_.CreateTree(f"sim-{a}")
        for w in rng.choice(nodes, size=24, replace=False):
            sys_.Subscribe(h.app_id, int(w))
        handles.append(h)
    return sys_, handles


def test_event_clock_deterministic_m3():
    sys_, handles = build_sim_system(m_apps=3)
    runs = [
        MultiAppSimulator(sys_, handles, model_bytes=1e5, compute_ms=25.0).run(rounds=3)
        for _ in range(2)
    ]
    assert runs[0] == runs[1]  # identical event traces for a fixed system
    per_app = per_app_round_ms(runs[0])
    assert len(per_app) == 3 and all(len(v) == 3 for v in per_app.values())
    assert all(t > 0 for v in per_app.values() for t in v)
    # rounds of one app complete in order
    for h in handles:
        evs = [e for e in runs[0] if e.app_id == h.app_id]
        assert [e.round for e in evs] == [0, 1, 2]
        assert all(a.end_ms <= b.end_ms for a, b in zip(evs, evs[1:]))


def test_contention_slows_shared_overlay():
    """An app's rounds are no faster with 3 concurrent apps than alone."""
    sys_, handles = build_sim_system(m_apps=3)
    alone = MultiAppSimulator(sys_, handles[:1], model_bytes=1e5, compute_ms=25.0).run(rounds=2)
    together = MultiAppSimulator(sys_, handles, model_bytes=1e5, compute_ms=25.0).run(rounds=2)
    a = np.mean(per_app_round_ms(alone)[handles[0].app_id])
    t = np.mean(per_app_round_ms(together)[handles[0].app_id])
    assert t >= a - 1e-9
