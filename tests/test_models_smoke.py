"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs (assignment requirement), plus
decode-vs-train consistency for every cache family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import encdec, lm

ALL_ARCHS = list(configs.ARCH_IDS)


def _batch(cfg, key, B=2, S=32):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.is_encoder_decoder:
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model)) * 0.3
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    elif cfg.embed_inputs:
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model)) * 0.3
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_train_step(arch):
    cfg = configs.get_reduced(arch)
    model = encdec if cfg.is_encoder_decoder else lm
    params = model.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))
    loss_fn = (
        (lambda p, b: encdec.forward_train(p, cfg, b))
        if cfg.is_encoder_decoder
        else (lambda p, b: lm.train_loss(p, cfg, b))
    )
    (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(ce) > 0
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    # one SGD step moves the loss
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
    (loss2, _) = loss_fn(params2, batch)[0], None
    assert float(loss2[0] if isinstance(loss2, tuple) else loss2) < float(loss)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes(arch):
    cfg = configs.get_reduced(arch)
    B, S = 2, 16
    if cfg.is_encoder_decoder:
        params = encdec.init_params(jax.random.key(0), cfg)
        enc = encdec.encode(params, cfg, jnp.zeros((B, S, cfg.d_model)))
        assert enc.shape == (B, S, cfg.d_model)
        return
    params = lm.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1), B=B, S=S)
    logits, _, _ = lm.forward(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"), mode="train"
    )
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


DECODE_ARCHS = [
    "tinyllama-1.1b",  # GQA
    "qwen3-8b",  # qk_norm
    "deepseek-v2-lite-16b",  # MLA absorbed decode + MoE + first_dense
    "rwkv6-7b",  # wkv state
    "jamba-1.5-large-398b",  # mamba conv/ssm state + attention hybrid
    "moonshot-v1-16b-a3b",  # MoE
    "llava-next-34b",  # padded heads
]


def _merge(full, pre):
    def f(a, b):
        if a.shape == b.shape:
            return b.astype(a.dtype)
        return jax.lax.dynamic_update_slice(a, b.astype(a.dtype), (0,) * a.ndim)

    return jax.tree.map(f, full, pre)


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_train_forward(arch):
    cfg = configs.get_reduced(arch).replace(capacity_factor=64.0)  # dropless MoE
    params = lm.init_params(jax.random.key(0), cfg)
    B, S, P0 = 2, 32, 24
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    logits_full, _, _ = lm.forward(params, cfg, tokens=tokens, mode="train")
    cache = _merge(
        lm.init_cache(cfg, B, S),
        lm.forward(params, cfg, tokens=tokens[:, :P0], mode="prefill")[1],
    )
    errs = []
    for t in range(P0, S):
        lt, cache, _ = lm.forward(
            params, cfg, tokens=tokens[:, t : t + 1], mode="decode",
            cache=cache, cache_index=jnp.asarray(t, jnp.int32),
        )
        errs.append(float(jnp.max(jnp.abs(lt[:, 0] - logits_full[:, t]))))
    assert max(errs) < 5e-4, (arch, max(errs))


def test_encdec_decode_matches_train():
    cfg = configs.get_reduced("seamless-m4t-medium")
    params = encdec.init_params(jax.random.key(0), cfg)
    B, Ss, St, P0 = 2, 24, 16, 12
    embeds = jax.random.normal(jax.random.key(2), (B, Ss, cfg.d_model)) * 0.3
    tokens = jax.random.randint(jax.random.key(3), (B, St), 0, cfg.vocab_size)
    enc_out = encdec.encode(params, cfg, embeds)
    tgt = params["embed"][tokens]
    x_full, _ = encdec.decode_stack(params, cfg, tgt, mode="train", enc_out=enc_out)
    from repro.models.nn import rms_norm

    logits_full = jnp.einsum(
        "bsd,dv->bsv", rms_norm(x_full, params["final_norm"]), params["head"]
    )
    cache = _merge(
        encdec.init_cache(cfg, B, St, Ss),
        encdec.decode_stack(params, cfg, tgt[:, :P0], mode="prefill", enc_out=enc_out)[1],
    )
    errs = []
    for t in range(P0, St):
        cache, lt = encdec.decode_step(params, cfg, cache, tokens[:, t : t + 1], jnp.asarray(t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(lt[:, 0] - logits_full[:, t]))))
    assert max(errs) < 5e-4


def test_full_configs_match_assignment():
    """Exact published dims (the spec table)."""
    spec = {
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "rwkv6-7b": (32, 4096, None, None, 14336, 65536),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
    }
    for name, (L, d, H, KV, ff, V) in spec.items():
        cfg = configs.get_config(name)
        assert cfg.num_layers == L and cfg.d_model == d and cfg.d_ff == ff
        assert cfg.vocab_size == V
        if H is not None:
            assert cfg.num_heads == H and cfg.num_kv_heads == KV
    # MoE details
    j = configs.get_config("jamba-1.5-large-398b")
    assert (j.moe_num_experts, j.moe_top_k, j.attn_every) == (16, 2, 8)
    m = configs.get_config("moonshot-v1-16b-a3b")
    assert (m.moe_num_experts, m.moe_top_k) == (64, 6)
    d2 = configs.get_config("deepseek-v2-lite-16b")
    assert (d2.kv_lora_rank, d2.moe_num_experts, d2.moe_top_k, d2.moe_num_shared) == (512, 64, 6, 2)


def test_param_counts_near_nameplate():
    expect = {
        "mistral-large-123b": 123e9,
        "deepseek-67b": 67e9,
        "tinyllama-1.1b": 1.1e9,
        "rwkv6-7b": 7.5e9,
        "jamba-1.5-large-398b": 398e9,
        "llava-next-34b": 34e9,
    }
    for name, n in expect.items():
        total, _ = lm.count_params_analytic(configs.get_config(name))
        assert abs(total - n) / n < 0.15, (name, total)


def test_llava_padded_heads_exact_math():
    """Masked head padding must not change outputs vs an unpadded model."""
    cfg = configs.get_reduced("llava-next-34b")  # tp_pad_multiple=16 -> pads
    cfg_nopad = cfg.replace(tp_pad_multiple=1)
    from repro.models import attention as A

    H_pad, _ = A.padded_heads(cfg)
    assert H_pad > cfg.num_heads  # padding active in the reduced config
    p = A.init_gqa(jax.random.key(0), cfg)
    p_nopad = A.init_gqa(jax.random.key(0), cfg_nopad)
    # copy real heads (kv-major order) from the padded init
    G = cfg.num_heads // cfg.num_kv_heads
    G_pad = H_pad // cfg.num_kv_heads
    idx = jnp.concatenate([jnp.arange(G) + kv * G_pad for kv in range(cfg.num_kv_heads)])
    p_nopad = dict(p_nopad, wq=p["wq"][:, idx], wk=p["wk"], wv=p["wv"], wo=p["wo"][idx])
    x = jax.random.normal(jax.random.key(2), (2, 16, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32)[None], (2, 16))
    out_pad, _ = A.gqa_forward(p, cfg, x, positions=pos, mode="train")
    out_ref, _ = A.gqa_forward(p_nopad, cfg_nopad, x, positions=pos, mode="train")
    np.testing.assert_allclose(np.asarray(out_pad), np.asarray(out_ref), atol=1e-5)
