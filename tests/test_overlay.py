"""Layer 1: multi-ring overlay — routing, convergence, isolation, tables."""
import math

import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # optional dev dep
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.nodeid import IdSpace, abs_ring_distance, sha1_id
from repro.core.overlay import MultiRingOverlay, distributed_binning


def build(n=2000, zones=8, seed=0, b=4, suffix_bits=24):
    space = IdSpace(zone_bits=int(math.log2(zones)), suffix_bits=suffix_bits)
    ov = MultiRingOverlay(space, base_bits=b, seed=seed)
    rng = np.random.default_rng(seed)
    for _ in range(n):
        ov.join_random(int(rng.integers(0, zones)), coord=rng.uniform(0, 100, 2))
    return ov, rng


def test_sha1_ids_uniform():
    space = 1 << 32
    ids = [sha1_id(f"app-{i}", 32) for i in range(2000)]
    assert len(set(ids)) == 2000  # collision-free at this scale
    # roughly uniform: each quartile gets 25% +- 5%
    qs = np.histogram(ids, bins=4, range=(0, space))[0]
    assert all(abs(q / 2000 - 0.25) < 0.05 for q in qs)


def test_route_terminates_at_numerically_closest():
    ov, rng = build()
    space = ov.space
    for _ in range(50):
        src = ov.nodes()[rng.integers(ov.num_nodes)]
        key = int(rng.integers(0, 1 << space.total_bits))
        res = ov.route(src, key)
        dest = res.dest
        zone = space.zone_of(dest)
        suf = space.suffix_of(key)
        members = ov.zone_members[zone]
        best = min(members, key=lambda s: abs_ring_distance(suf, s, space.suffix_space))
        assert space.suffix_of(dest) == best


def test_route_convergence_single_destination():
    ov, rng = build()
    key = int(rng.integers(0, 1 << ov.space.total_bits))
    dests = {ov.route(ov.nodes()[rng.integers(ov.num_nodes)], key).dest for _ in range(60)}
    assert len(dests) == 1


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**48 - 1), st.integers(0, 10**6))
def test_route_hop_bound_property(key, src_seed):
    """O(log N): hops <= ceil(log_2^b N_zone) + zone hops + leaf slack."""
    ov = test_route_hop_bound_property.ov
    rng = np.random.default_rng(src_seed)
    src = ov.nodes()[rng.integers(ov.num_nodes)]
    res = ov.route(src, key % (1 << ov.space.total_bits))
    n_zone = max(len(m) for m in ov.zone_members.values())
    bound = math.ceil(math.log(n_zone, 2**ov.b)) + ov.space.zone_bits + 3
    assert res.hops <= bound, (res.hops, bound)


test_route_hop_bound_property.ov = build(n=3000)[0]


def test_hops_scale_logarithmically():
    means = []
    for n in (500, 4000):
        ov, rng = build(n=n)
        hops = [
            ov.route(
                ov.nodes()[rng.integers(ov.num_nodes)],
                int(rng.integers(0, 1 << ov.space.total_bits)),
            ).hops
            for _ in range(200)
        ]
        means.append(np.mean(hops))
    assert means[1] < means[0] * 3  # 8x nodes -> far less than 8x hops
    assert means[1] <= math.log(4000 / 8, 2**4) + 5


def test_administrative_isolation_blocks_cross_zone():
    ov, rng = build()
    src = ov.nodes()[0]
    zone = ov.space.zone_of(src)
    other_zone = (zone + 1) % ov.space.num_zones
    key = ov.space.make(other_zone, 12345)
    res = ov.route(src, key, restrict_zone=zone)
    # either delivered within the zone or blocked at the boundary
    assert all(ov.space.zone_of(n) == zone for n in res.path) or res.blocked
    # unrestricted: reaches the other zone
    res2 = ov.route(src, key)
    assert ov.space.zone_of(res2.dest) == other_zone


def test_routing_table_materialization_matches_rule():
    ov, _ = build(n=500, zones=4)
    node = ov.nodes()[3]
    table = ov.routing_table_of(node)
    assert len(table["level1"]) == ov.space.zone_bits
    # level-1 entry i points into zone (P_x + 2^{i-1}) mod 2^m (or its live successor)
    zone = ov.space.zone_of(node)
    for i, entry in enumerate(table["level1"], start=1):
        expect_zone = ov.nearest_zone((zone + (1 << (i - 1))) % ov.space.num_zones)
        assert ov.space.zone_of(entry) == expect_zone
    # level-2 rows have 2^b - 1 entries
    assert all(len(row) == (1 << ov.b) - 1 for row in table["level2"])


def test_leaf_and_neighborhood_sets():
    ov, _ = build(n=300, zones=4)
    node = ov.nodes()[10]
    leafs = ov.leaf_set(node)
    assert node not in leafs and len(leafs) > 0
    assert all(ov.space.zone_of(l) == ov.space.zone_of(node) for l in leafs)
    nbrs = ov.neighborhood_set(node)
    assert len(nbrs) == ov.neighborhood_size
    # neighborhood is by physical distance: the closest node is in it
    cx, cy = ov.coords[node]
    closest = min(
        (n for n in ov.alive if n != node),
        key=lambda n: (ov.coords[n][0] - cx) ** 2 + (ov.coords[n][1] - cy) ** 2,
    )
    assert closest in nbrs


def test_churn_routes_survive_failures():
    ov, rng = build(n=1000)
    nodes = ov.nodes()
    for n in nodes[:: 10]:  # fail 10%
        ov.fail(n)
    for _ in range(50):
        src = ov.nodes()[rng.integers(ov.num_nodes)]
        key = int(rng.integers(0, 1 << ov.space.total_bits))
        res = ov.route(src, key)
        assert all(n in ov.alive for n in res.path)


def test_distributed_binning_locality():
    rng = np.random.default_rng(0)
    # two well-separated clusters -> different bins, same-cluster same bin
    c1 = rng.normal((0, 0), 1.0, (50, 2))
    c2 = rng.normal((100, 100), 1.0, (50, 2))
    bins = distributed_binning(np.vstack([c1, c2]), num_landmarks=4, seed=1)
    assert len(set(bins[:50]) & set(bins[50:])) == 0
