"""End-to-end behaviour tests for the Totoro+ system."""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import ckpt, configs, data
from repro.config import RunPlan
from repro.core.api import TotoroSystem
from repro.fl import rounds, steps as steps_mod
from repro.models import lm


def test_full_system_multi_app_with_failures():
    """Many apps on one overlay: discovery, concurrent rounds, master +
    worker failures mid-training, training continues and converges."""
    sys_ = TotoroSystem(zone_bits=2, suffix_bits=20, seed=7)
    rng = np.random.default_rng(7)
    nodes = [sys_.Join("n", i, site=i % 4, coord=rng.uniform(0, 50, 2)) for i in range(300)]

    x, y = data.synthetic_classification(2400, 16, 4, seed=0)
    parts = data.dirichlet_partition(y, 8, alpha=1.0, seed=1)
    apps = []
    for a in range(3):
        workers = [int(w) for w in rng.choice(nodes, size=8, replace=False)]
        apps.append(
            rounds.make_app(
                sys_, f"sys-{a}", workers=workers,
                data_by_worker={w: (x[parts[i]], y[parts[i]]) for i, w in enumerate(workers)},
                dim=16, num_classes=4, local_steps=4, lr=0.3, seed=a,
            )
        )
    # discovery sees all three
    assert len(sys_.Discover(nodes[-1])) == 3

    for _ in range(3):
        for app in apps:
            rounds.run_round(sys_, app)

    # kill app0's master + two workers simultaneously
    victims = [apps[0].handle.tree.root] + sorted(apps[0].handle.tree.members)[:2]
    rep = sys_.fail_nodes(apps[0].handle.app_id, victims)
    assert rep.master_failed and rep.new_master is not None

    for _ in range(3):
        for app in apps:
            rounds.run_round(sys_, app)
    acc = rounds.evaluate(apps[0], x[:400], y[:400])
    assert acc > 0.75, acc


def test_lm_train_step_learns_and_checkpoints(tmp_path):
    """The same FL round the dry-run lowers, end-to-end on CPU: loss
    drops on a learnable stream; checkpoint/restore mid-run continues."""
    cfg = configs.get_reduced("tinyllama-1.1b").replace(learning_rate=2e-3)
    params = lm.init_params(jax.random.key(0), cfg)
    state = steps_mod.init_train_state(cfg, params)
    step_fn = jax.jit(steps_mod.build_train_step(cfg, RunPlan(grad_accum=2)), donate_argnums=(0,))
    sc = data.StreamConfig(cfg.vocab_size, 64, 8)
    losses = []
    for s in range(14):
        b = data.learnable_lm_batch(sc, 0, s)
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
        if s == 7:
            ckpt.save(state, str(tmp_path), step=8, replicas=2)
    assert losses[-1] < losses[0] - 0.5, losses

    # restart from the checkpoint (replica 0 corrupted) and keep training
    ckpt.corrupt_replica(str(tmp_path), replica=0, step=8)
    restored, st = ckpt.restore(state, str(tmp_path))
    assert st == 8
    restored = jax.device_put(restored)
    b = data.learnable_lm_batch(sc, 0, st)
    restored, m = step_fn(restored, {k: jnp.asarray(v) for k, v in b.items()})
    assert np.isfinite(float(m["loss"]))


def test_straggler_masking_changes_only_weighting():
    """Zero-weight (label -1) examples are excluded exactly."""
    cfg = configs.get_reduced("tinyllama-1.1b")
    params = lm.init_params(jax.random.key(0), cfg)
    t = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    full, _ = lm.train_loss(params, cfg, {"tokens": t, "labels": t})
    # mask half the clients
    labels = np.asarray(t).copy()
    labels[:2] = -1
    masked, _ = lm.train_loss(params, cfg, {"tokens": t, "labels": jnp.asarray(labels)})
    only_last, _ = lm.train_loss(params, cfg, {"tokens": t[2:], "labels": t[2:]})
    np.testing.assert_allclose(float(masked), float(only_last), rtol=1e-5)
