"""Failure recovery (§IV-D) + k-replica checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ckpt
from repro.core.forest import Forest
from repro.core.nodeid import IdSpace
from repro.core.overlay import MultiRingOverlay
from repro.core.recovery import ReplicaStore, fail_and_recover, verify_tree


def build_tree(n=1000, subs=200, seed=0):
    space = IdSpace(zone_bits=2, suffix_bits=22)
    ov = MultiRingOverlay(space, base_bits=4, seed=seed)
    rng = np.random.default_rng(seed)
    for _ in range(n):
        ov.join_random(int(rng.integers(0, 4)), coord=rng.uniform(0, 100, 2))
    f = Forest(ov)
    tree = f.create_tree("app")
    for _ in range(subs):
        f.subscribe(tree.app_id, ov.nodes()[rng.integers(ov.num_nodes)])
    return ov, f, tree, rng


def test_worker_failures_repair_tree():
    ov, f, tree, rng = build_tree()
    victims = [n for n in list(tree.nodes()) if n != tree.root][:16]
    rep = fail_and_recover(ov, f, tree, victims)
    assert not rep.master_failed
    assert verify_tree(tree, ov)
    assert rep.recovery_time_ms > 0 and rep.hops >= 0


def test_master_failure_promotes_numerically_next_and_restores_state():
    ov, f, tree, rng = build_tree()
    rs = ReplicaStore(k=2)
    holders = rs.replicate(ov, tree.app_id, tree.root, {"round": 3, "acc": 0.71})
    assert len(holders) == 2
    old_root = tree.root
    rep = fail_and_recover(ov, f, tree, [old_root], replicas=rs)
    assert rep.master_failed and rep.new_master is not None
    assert rep.new_master != old_root
    assert rep.restored_from_replica in holders
    assert verify_tree(tree, ov)


def test_simultaneous_master_and_worker_failures():
    ov, f, tree, rng = build_tree(subs=300)
    rs = ReplicaStore(k=2)
    rs.replicate(ov, tree.app_id, tree.root, {"round": 1})
    victims = list(tree.nodes())[:64]
    if tree.root not in victims:
        victims.append(tree.root)
    rep = fail_and_recover(ov, f, tree, victims, replicas=rs)
    assert rep.master_failed
    assert verify_tree(tree, ov)


def test_recovery_time_grows_slowly_with_failures():
    """Fig 17: linear-ish recovery time under exponentially more failures
    (parallel repair: time = detection + max re-join, not sum)."""
    times = []
    for k in (1, 8, 64):
        ov, f, tree, rng = build_tree(subs=400, seed=k)
        victims = [n for n in list(tree.nodes()) if n != tree.root][:k]
        rep = fail_and_recover(ov, f, tree, victims)
        times.append(rep.recovery_time_ms)
    assert times[2] < times[0] * 4  # 64x failures << 64x time


# ---------------------------------------------------------------------------
# checkpointing (the FL-state side of master replication)


def _state():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones(4)},
        "opt": {"step": jnp.asarray(7), "m": jnp.zeros((3, 4))},
    }


def test_ckpt_roundtrip(tmp_path):
    st = _state()
    ckpt.save(st, str(tmp_path), step=7, replicas=2)
    restored, step = ckpt.restore(st, str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_survives_replica_corruption(tmp_path):
    st = _state()
    ckpt.save(st, str(tmp_path), step=3, replicas=2)
    ckpt.corrupt_replica(str(tmp_path), replica=0, step=3)
    restored, step = ckpt.restore(st, str(tmp_path))
    assert step == 3
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(st["params"]["w"])
    )


def test_ckpt_latest_step_and_multiple(tmp_path):
    st = _state()
    for s in (1, 5, 9):
        ckpt.save(st, str(tmp_path), step=s, replicas=2)
    assert ckpt.latest_step(str(tmp_path)) == 9
    _, step = ckpt.restore(st, str(tmp_path), step=5)
    assert step == 5


def test_ckpt_elastic_reshard_resume(tmp_path):
    """Checkpoints hold full logical arrays -> resume onto any mesh: verify
    values survive a save -> restore -> re-device_put cycle."""
    st = _state()
    ckpt.save(st, str(tmp_path), step=1, replicas=2)
    restored, _ = ckpt.restore(st, str(tmp_path))
    resharded = jax.device_put(restored)  # single-device 'new mesh'
    np.testing.assert_array_equal(
        np.asarray(resharded["params"]["w"]), np.asarray(st["params"]["w"])
    )
