"""Game-theoretic path planning (Algorithm 1): paper-exact example,
simplex invariants, regret behavior, baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # optional dev dep
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.congestion import CongestionEnv, make_env
from repro.core.pathplan import (
    BanditPlanner,
    GameTheoreticPlanner,
    OptPlanner,
    algorithm1_episode,
    candidate_policy_set,
    nash_regret_step,
    run_planner,
)


def test_appendix_e_numerical_example_exact():
    """Paper Appendix E: pi=[0.5,0.5], tau=2, rewards (m1:0.4, m2:0.8),
    Delta = {[.6,.4],[.5,.5],[.3,.7],[.1,.9]}, alpha=beta=0.5 -> [0.2,0.8]."""
    cand = jnp.array([[0.6, 0.4], [0.5, 0.5], [0.3, 0.7], [0.1, 0.9]], jnp.float32)
    pi = jnp.array([[0.5, 0.5]], jnp.float32)
    out = algorithm1_episode(
        pi, jnp.ones((1, 2), bool), cand,
        jnp.array([[0, 1]]), jnp.array([[0.4, 0.8]], jnp.float32),
        tau=2, alpha=0.5, beta=0.5,
    )
    np.testing.assert_allclose(np.asarray(out[0]), [0.2, 0.8], atol=1e-6)


def test_appendix_e_intermediate_quantities():
    """Determinants 0.24/0.25/0.21/0.09 -> rho=[.1,.9]; grad=[0.4,0.8];
    inner products 0.56/0.60/0.68/0.76 -> pi~=[.1,.9]."""
    cand = np.array([[0.6, 0.4], [0.5, 0.5], [0.3, 0.7], [0.1, 0.9]])
    dets = cand.prod(axis=1)
    np.testing.assert_allclose(dets, [0.24, 0.25, 0.21, 0.09], atol=1e-9)
    assert dets.argmin() == 3
    grad = np.array([0.4, 0.8])  # (1/tau)*sum 1[p_t=p] r_t / pi(p), pi=0.5
    np.testing.assert_allclose(cand @ grad, [0.56, 0.60, 0.68, 0.76], atol=1e-9)
    assert (cand @ grad).argmax() == 3


@settings(max_examples=25, deadline=None)
@given(
    st.integers(2, 12),  # K paths
    st.integers(1, 16),  # tau
    st.floats(0.1, 0.95),
    st.floats(0.05, 0.95),
    st.integers(0, 10_000),
)
def test_update_stays_in_simplex(K, tau, alpha, beta, seed):
    key = jax.random.key(seed)
    N = 17
    pi = jax.random.dirichlet(key, jnp.ones(K), (N,)).astype(jnp.float32)
    cand = candidate_policy_set(K, seed=seed)
    actions = jax.random.randint(jax.random.fold_in(key, 1), (N, tau), 0, K)
    rewards = jax.random.uniform(jax.random.fold_in(key, 2), (N, tau))
    out = algorithm1_episode(
        pi, jnp.ones((N, K), bool), cand, actions, rewards,
        tau=tau, alpha=alpha, beta=beta,
    )
    assert bool(jnp.all(out >= -1e-6))
    np.testing.assert_allclose(np.asarray(out.sum(-1)), 1.0, atol=1e-5)
    assert bool(jnp.all(out > 0))  # Theorem 1 precondition: no zero element


def test_masked_hops_get_zero_mass():
    K, N = 6, 4
    mask = jnp.array([[True, True, True, False, False, False]] * N)
    pi = jnp.where(mask, 1 / 3, 0.0).astype(jnp.float32)
    cand = candidate_policy_set(K)
    actions = jnp.zeros((N, 3), jnp.int32)
    rewards = jnp.ones((N, 3))
    out = algorithm1_episode(pi, mask, cand, actions, rewards, tau=3, alpha=0.6, beta=0.5)
    assert bool(jnp.all(out[:, 3:] == 0))
    np.testing.assert_allclose(np.asarray(out.sum(-1)), 1.0, atol=1e-5)


def test_planner_reduces_nash_regret_vs_bandit():
    """Fig 13: Totoro+ reaches lower Nash regret than the congestion-blind
    bandit; OPT is the floor."""
    env = make_env(6, seed=3)
    N, episodes = 48, 30
    gt = run_planner(GameTheoreticPlanner(N, 6, tau=8, alpha=0.9, beta=0.5, seed=0), env, episodes)
    bd = run_planner(BanditPlanner(N, 6, tau=8), env, episodes)
    opt = run_planner(OptPlanner(env, N, tau=8), env, episodes)
    tail = slice(-10, None)
    gt_r = np.mean(gt["nash_regret"][tail])
    bd_r = np.mean(bd["nash_regret"][tail])
    opt_r = np.mean(opt["nash_regret"][tail])
    assert gt_r < bd_r, (gt_r, bd_r)
    assert opt_r <= gt_r + 0.05


def test_planner_balances_congestion_lower_latency():
    """Figs 11/14: Totoro+ spreads load -> lower cumulative latency and
    more even selection frequencies than the bandit."""
    env = make_env(6, seed=5)
    N, episodes = 48, 25
    gt = run_planner(GameTheoreticPlanner(N, 6, tau=8, seed=1), env, episodes)
    bd = run_planner(BanditPlanner(N, 6, tau=8), env, episodes)
    assert gt["cum_latency_ms"][-1] < bd["cum_latency_ms"][-1]
    # selection frequencies stay spread (no path starved — Fig 14)
    assert float(np.min(gt["selection_freq"])) > 0.02


def test_congestion_env_bandwidth_sharing():
    env = make_env(3, seed=0)
    a_lone = jnp.array([0, 1, 2])
    a_cong = jnp.array([0, 0, 0])
    lat_lone = env.latency_ms(a_lone)
    lat_cong = env.latency_ms(a_cong)
    assert float(lat_cong[0]) > float(lat_lone[0])  # sharing slows everyone
    # mean_reward decreases in k
    assert env.mean_reward(0, 1) >= env.mean_reward(0, 3) >= env.mean_reward(0, 9)
