"""Docs surface: README/docs exist and intra-repo links resolve.

The same checker gates CI (tools/check_links.py); running it under
pytest keeps `python -m pytest` the single verify command.
"""
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def _checker():
    sys.path.insert(0, str(REPO / "tools"))
    import check_links

    return check_links


def test_docs_exist():
    assert (REPO / "README.md").is_file()
    assert (REPO / "docs" / "architecture.md").is_file()
    # README covers the newcomer path: quickstart + tier-1 verify
    readme = (REPO / "README.md").read_text()
    assert "examples/quickstart.py" in readme
    assert "python -m pytest" in readme


def test_intra_repo_links_resolve():
    check_links = _checker()
    files = list(check_links.iter_markdown([REPO / "README.md", REPO / "docs"]))
    assert files, "README.md/docs/ missing"
    errors = [e for f in files for e in check_links.check_file(f)]
    assert not errors, "\n".join(errors)
