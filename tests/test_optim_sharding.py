"""Optimizers + sharded integration (subprocess small-mesh dry-run)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim


def _quad_params():
    return {"w": jnp.asarray([1.0, -2.0, 3.0]), "b": jnp.asarray([[0.5, -0.5]] * 2)}


def _grads(params):
    return jax.tree.map(lambda p: 2 * p, params)  # grad of sum(p^2)


def test_adamw_reduces_quadratic():
    opt = optim.adamw(lr=0.05)
    params = _quad_params()
    state = opt.init(params)
    for _ in range(100):
        params, state = opt.update(_grads(params), state, params)
    assert float(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(params))) < 0.2


def test_adamw_first_step_is_lr_sized():
    opt = optim.adamw(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([1.0])}
    state = opt.init(params)
    newp, _ = opt.update({"w": jnp.asarray([0.5])}, state, params)
    # bias-corrected first step = lr * g/|g| = lr
    np.testing.assert_allclose(float(newp["w"][0]), 1.0 - 0.1, atol=1e-4)


def test_adafactor_factored_states_and_descent():
    opt = optim.adafactor(lr=0.05, min_dim_size_to_factor=2)
    params = {"w": jnp.ones((128, 256)), "v": jnp.ones((5,))}
    state = opt.init(params)
    assert set(state["v"]["w"].keys()) == {"vr", "vc"}
    assert state["v"]["w"]["vr"].shape == (128,)
    assert state["v"]["w"]["vc"].shape == (256,)
    assert set(state["v"]["v"].keys()) == {"v"}
    loss0 = float(jnp.sum(jnp.square(params["w"])))
    for _ in range(20):
        params, state = opt.update(_grads(params), state, params)
    assert float(jnp.sum(jnp.square(params["w"]))) < loss0


def test_adafactor_state_specs_drop_factored_axis():
    from jax.sharding import PartitionSpec as P

    opt = optim.adafactor(min_dim_size_to_factor=2)
    pspecs = {"w": P("data", "model")}
    pshapes = {"w": jax.ShapeDtypeStruct((128, 256), jnp.float32)}
    ss = opt.state_specs(pspecs, pshapes)
    assert ss["v"]["w"]["vr"] == P("data")
    assert ss["v"]["w"]["vc"] == P("model")


def test_sgd_momentum():
    opt = optim.sgd(lr=0.1, momentum=0.9)
    params = _quad_params()
    state = opt.init(params)
    for _ in range(50):
        params, state = opt.update(_grads(params), state, params)
    assert float(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(params))) < 0.5


DRYRUN_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.launch import mesh as mesh_mod, specs as specs_mod, hlo
mesh = mesh_mod.make_test_mesh(multi_pod={mp})
cell = specs_mod.build_cell("tinyllama-1.1b", "{shape}", mesh, aggregation={agg!r})
lowered = specs_mod.lower_cell(cell, mesh)
compiled = lowered.compile()
mod = hlo.analyze_module(compiled.as_text())
assert mod.flops > 0
print("OK", compiled.memory_analysis().argument_size_in_bytes)
"""


@pytest.mark.parametrize(
    "mp,shape,agg",
    [
        (False, "train_4k", None),
        (True, "train_4k", None),
        (True, "train_4k", "totoro_tree_q8"),
        (True, "train_4k", "xla_auto"),
        (False, "decode_32k", None),
    ],
)
def test_small_mesh_dryrun_subprocess(mp, shape, agg):
    """The dry-run machinery on an 8-device test mesh (subprocess so the
    forced device count never leaks into this process)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = DRYRUN_CODE.format(mp=mp, shape=shape, agg=agg)
    p = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=900
    )
    assert p.returncode == 0, p.stderr[-3000:]
    assert "OK" in p.stdout
