"""Async buffered execution layer: staleness-aware aggregation + churn.

Covers the pluggable-scheduler refactor of ``core/sim.py``:
 - async(K=W, barrier) == synchronous engine, round for round (the
   staleness discount cancels at uniform staleness);
 - CommitDelta/ApplyBuffered verbs vs the hierarchical Aggregate;
 - staleness weighting semantics through the kernel weight vector;
 - churn injected on the event clock repairs trees (``verify_tree``);
 - pipelined dissemination never slower than synchronous level pricing;
 - empty-batch edge cases (``pack_shards`` on no workers).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import data as data_mod
from repro.core.api import TotoroSystem
from repro.core.recovery import ReplicaStore, verify_tree
from repro.core.sim import (
    AsyncBufferScheduler,
    ChurnModel,
    SyncRoundScheduler,
    pipelined_time,
)
from repro.fl import async_engine, engine, rounds
from repro.kernels import ops as kops
from repro.kernels.tree_aggregate import staleness_weights


def build_app(seed=0, workers=8, n_nodes=150, name="async-test"):
    sys_ = TotoroSystem(zone_bits=2, suffix_bits=20, seed=seed)
    rng = np.random.default_rng(seed)
    nodes = [sys_.Join("n", i, site=i % 4, coord=rng.uniform(0, 50, 2)) for i in range(n_nodes)]
    x, y = data_mod.synthetic_classification(workers * 150, 16, 4, seed=seed)
    parts = data_mod.dirichlet_partition(y, workers, alpha=1.0, seed=seed + 1)
    parts = [p if len(p) else np.arange(3) for p in parts]
    ws = [int(w) for w in rng.choice(nodes, size=workers, replace=False)]
    app = rounds.make_app(
        sys_, name, workers=ws,
        data_by_worker={w: (x[parts[i]], y[parts[i]]) for i, w in enumerate(ws)},
        dim=16, num_classes=4, local_steps=3, lr=0.2,
    )
    return sys_, app


def test_async_k_equals_w_matches_sync_engine():
    """Equivalence property: async with K=W (barrier) reproduces the
    synchronous engine round for round — and a nonzero staleness alpha
    must not matter, because uniform staleness cancels in the mean."""
    sys_a, app_a = build_app()
    sys_s, app_s = build_app()
    W = len([w for w in sorted(app_a.handle.tree.members) if w in app_a.data])
    res = rounds.run_async(
        sys_a, [app_a], applies=3, buffer_k=W, staleness_alpha=0.7,
        model_bytes=1e5, compute_ms=25.0, barrier=True,
    )
    for _ in range(3):
        rounds.run_round(sys_s, app_s)
    assert [e.arrivals for e in res["events"]] == [W, W, W]
    assert all(e.max_staleness == 0.0 for e in res["events"])
    for la, lb in zip(jax.tree.leaves(app_a.params), jax.tree.leaves(app_s.params)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-4, atol=1e-6)


def test_commit_apply_verbs_match_hierarchical_aggregate():
    """One buffer of staleness-0 commits == the hierarchical kernel
    Aggregate on the same deltas/weights."""
    sys_a, app_a = build_app(seed=2)
    sys_s, app_s = build_app(seed=2)
    ws = [w for w in sorted(app_a.handle.tree.members) if w in app_a.data]
    deltas, weights, _ = engine.local_training(app_a, ws)
    for w, d, wt in zip(ws, deltas, weights):
        stats = sys_a.CommitDelta(app_a.handle.app_id, w, d, weight=wt, staleness=0)
        assert stats["buffered"] >= 1 and stats["bytes"] >= 0.0
    out = sys_a.ApplyBuffered(app_a.handle.app_id, staleness_alpha=0.0)
    ref = sys_s.Aggregate(
        app_s.handle.app_id,
        {w: d for w, d in zip(ws, deltas)},
        weights={w: wt for w, wt in zip(ws, weights)},
    )
    assert out["arrivals"] == len(ws) and out["version"] == 1
    for la, lb in zip(jax.tree.leaves(out["result"]), jax.tree.leaves(ref["result"])):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-5, atol=1e-7)
    # buffer drained; below-min_k apply is a no-op
    assert sys_a.ApplyBuffered(app_a.handle.app_id)["result"] is None


def test_staleness_discount_in_kernel_weight_vector():
    """buffered_aggregate == manual 1/(1+s)^a weighted mean; alpha=0
    ignores staleness entirely."""
    rng = np.random.default_rng(0)
    ups = [rng.standard_normal(37).astype(np.float32) for _ in range(5)]
    w = [2.0, 1.0, 3.0, 1.0, 2.0]
    s = [0, 3, 1, 0, 7]
    alpha = 0.8
    agg, cw = kops.buffered_aggregate(ups, w, s, alpha=alpha)
    disc = np.asarray(w) * (1.0 + np.asarray(s, float)) ** -alpha
    ref = (np.stack(ups) * disc[:, None]).sum(0) / disc.sum()
    np.testing.assert_allclose(np.asarray(agg), ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cw), disc, rtol=1e-6)
    agg0, _ = kops.buffered_aggregate(ups, w, s, alpha=0.0)
    ref0 = (np.stack(ups) * np.asarray(w)[:, None]).sum(0) / np.sum(w)
    np.testing.assert_allclose(np.asarray(agg0), ref0, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(staleness_weights(jnp.asarray(w), jnp.asarray(s, jnp.float32), alpha)),
        disc, rtol=1e-6,
    )


def test_async_no_barrier_builds_staleness_and_converges():
    """Free-running async under heterogeneous compute: fast workers lap
    slow ones (staleness > 0 appears), loss still decreases."""
    sys_, app = build_app(seed=4, workers=12)
    res = rounds.run_async(
        sys_, [app], applies=8, buffer_k=4, staleness_alpha=0.5, model_bytes=1e5,
        compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=1),
    )
    assert len(res["events"]) == 8
    assert max(e.max_staleness for e in res["events"]) > 0
    losses = [h["loss"] for h in res["history"]]
    assert losses[-1] < losses[0]
    # event history is deterministic for a fixed build
    sys2, app2 = build_app(seed=4, workers=12)
    res2 = rounds.run_async(
        sys2, [app2], applies=8, buffer_k=4, staleness_alpha=0.5, model_bytes=1e5,
        compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=1),
    )
    assert res["events"] == res2["events"]


def test_free_running_apply_trigger_worker_keeps_cycling():
    """Regression: the worker whose commit fills the buffer must start
    its next cycle too — with K=1 every commit applies, and the run must
    still deliver every requested apply without stalling."""
    sys_, app = build_app(seed=13, workers=4)
    res = rounds.run_async(
        sys_, [app], applies=6, buffer_k=1, staleness_alpha=0.5,
        model_bytes=1e5, compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=2),
    )
    assert len(res["events"]) == 6
    assert all(e.arrivals == 1 for e in res["events"])


def test_barrier_with_partial_buffer_no_double_schedule():
    """Regression: barrier mode with K < W must only release workers
    idling at the barrier — mid-flight workers keep their one cycle
    (no KeyError, no leaked version refs)."""
    sys_, app = build_app(seed=14, workers=6)
    res = rounds.run_async(
        sys_, [app], applies=12, buffer_k=2, staleness_alpha=0.5, barrier=True,
        model_bytes=1e5, compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=3),
    )
    assert len(res["events"]) == 12
    assert all(e.arrivals >= 2 for e in res["events"])
    # every snapshot version still pinned has a live in-flight reference
    trainer = res["trainer"]
    assert all(r >= 0 for r in trainer._refs[0].values())


def test_churn_in_the_loop_repairs_tree():
    """Fail/rejoin events injected mid-round via the event clock: trees
    stay verifiable, failed workers return, applies keep completing."""
    sys_, app = build_app(seed=5, workers=12, n_nodes=200)
    churn = ChurnModel(period_ms=120.0, downtime_ms=400.0, group_size=2, seed=3)
    res = rounds.run_async(
        sys_, [app], applies=6, buffer_k=4, staleness_alpha=0.5, model_bytes=1e5,
        compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=1), churn=churn,
    )
    fails = [c for c in res["churn"] if c.kind == "fail"]
    rejoins = [c for c in res["churn"] if c.kind == "rejoin"]
    assert fails and rejoins
    assert any(c.recovery_ms > 0 for c in fails)
    assert len(res["events"]) == 6
    assert verify_tree(app.handle.tree, sys_.overlay)
    # membership accounting: exactly the not-yet-rejoined workers are out
    sched = res["scheduler"]
    all_workers = set(res["trainer"].workers(0)) | sched._failed
    live_members = {w for w in app.handle.tree.members if w in sys_.overlay.alive}
    assert live_members == all_workers - sched._failed
    assert all(w not in sys_.overlay.alive for w in sched._failed)


def test_restore_picks_ring_closest_live_holder():
    sys_, app = build_app(seed=7, n_nodes=100)
    tree = app.handle.tree
    rs = ReplicaStore(k=3)
    holders = rs.replicate(sys_.overlay, tree.app_id, tree.root, {"round": 1})
    assert len(holders) == 3
    space = sys_.overlay.space
    from repro.core.nodeid import abs_ring_distance

    def dist(h):
        return abs_ring_distance(
            space.suffix_of(h), space.suffix_of(tree.root), space.suffix_space
        )

    expect = min(holders, key=lambda h: (dist(h), h))
    got, state = rs.restore(sys_.overlay, tree.app_id, master=tree.root)
    assert got == expect and state == {"round": 1}
    # the ring-closest holder dying moves the pick to the next-closest
    sys_.overlay.fail(expect)
    rest = [h for h in holders if h != expect]
    got2, _ = rs.restore(sys_.overlay, tree.app_id, master=tree.root)
    assert got2 == min(rest, key=lambda h: (dist(h), h))


def test_pipelined_broadcast_not_slower_than_sync():
    """Store-and-forward overlap: pipelined round time <= synchronous,
    and the pipelined level cost approaches max-level as chunks grow."""
    sys_, app = build_app(seed=9, workers=24, n_nodes=300)
    handles = [app.handle]
    kw = dict(model_bytes=2e5, compute_ms=30.0)
    sync = SyncRoundScheduler(sys_, handles, **kw).run(rounds=2)
    pipe = SyncRoundScheduler(sys_, handles, pipelined=True, pipeline_chunks=8, **kw).run(rounds=2)
    for a, b in zip(sync, pipe):
        assert b.duration_ms <= a.duration_ms + 1e-9
    # formula properties: C=1 == sum; C->inf -> max; monotone in between
    ts = [7.0, 3.0, 11.0, 2.0]
    assert pipelined_time(ts, 1) == pytest.approx(sum(ts))
    assert pipelined_time(ts, 10**6) == pytest.approx(max(ts), rel=1e-4)
    assert max(ts) <= pipelined_time(ts, 64) <= pipelined_time(ts, 8) <= sum(ts)
    # tree-level pricing exposed on the forest layer too
    t_sync = app.handle.tree.broadcast_time(sys_.overlay, payload_ms=5.0)
    t_pipe = app.handle.tree.broadcast_time(sys_.overlay, payload_ms=5.0, pipelined=True)
    assert t_pipe <= t_sync


def test_sync_scheduler_trace_unchanged_by_refactor():
    """The pluggable-scheduler split must preserve the original
    MultiAppSimulator semantics: same class, same deterministic traces."""
    from repro.core.sim import MultiAppSimulator

    assert MultiAppSimulator is SyncRoundScheduler
    sys_, app = build_app(seed=11, workers=16, n_nodes=200)
    runs = [
        MultiAppSimulator(sys_, [app.handle], model_bytes=1e5, compute_ms=25.0).run(rounds=2)
        for _ in range(2)
    ]
    assert runs[0] == runs[1]
    assert [e.round for e in runs[0]] == [0, 1]


def test_pack_shards_and_local_training_empty_workers():
    """A drained commit batch must not crash the engine (max() on [])."""
    sys_, app = build_app(seed=12)
    x, y, mask = engine.pack_shards(app.data, [])
    assert x.shape[0] == 0 and y.shape[0] == 0 and mask.shape[0] == 0
    deltas, weights, losses = engine.local_training(app, [])
    assert deltas == [] and weights == [] and losses == []
    # and the trainer's apply is a no-op on an empty pending queue
    trainer = async_engine.AsyncTrainer(sys_, [app])
    assert trainer.apply(0, 0.0) is None
