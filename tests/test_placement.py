"""Live placement loop: planner → forest re-graft → event core → selector.

Locks down the PR-9 exactness contracts:

- ``placement=None`` (and omitting the knob entirely) is the static
  baseline: exact ApplyEvent/ChurnRecord/fairness equality at M=16
  under churn, and a ``max_moves=0`` engine — the loop wired up but
  forbidden to move anything — is trace-identical too (the hooks are
  pay-for-what-you-use);
- the vectorized cost sweep (`tree_path_costs`, one array pass per
  level) equals the retained per-node Python oracle float-for-float;
- ``regraft_many`` / ``unsubscribe_many`` are node-for-node identical
  to their scalar oracles (``regraft`` / ``unsubscribe_one`` loops),
  including under membership churn, duplicates, and invalid moves;
- replans are deterministic under fixed seeds and priced on the clock;
- the adaptive resample cadence tightens/relaxes within its bounds and
  is a no-op when off;
- selector feedback: with a placement hook, transport-deferred workers
  are handed to the planner instead of blocklisted; without one, the
  legacy blocklist policy is untouched.
"""
import numpy as np
import pytest

from benchmarks.common import build_system
from repro.core.forest import Forest
from repro.core.nodeid import IdSpace
from repro.core.overlay import MultiRingOverlay
from repro.core.pathplan import (
    Move,
    PlacementEngine,
    tree_path_costs,
    tree_path_costs_scalar,
)
from repro.core.sim import AsyncBufferScheduler, ChurnModel
from repro.fl import async_engine
from repro.fl.selection import UtilitySelector


# -- fixtures -----------------------------------------------------------------


def _make_handles(sys_, nodes, rng, m, w, tag="p"):
    handles = []
    for a in range(m):
        h = sys_.CreateTree(f"plc{tag}-{m}-{a}")
        for node in rng.choice(nodes, size=w, replace=False):
            sys_.Subscribe(h.app_id, int(node))
        handles.append(h)
    return handles


def _run_sched(m=16, *, seed=0, applies=2, workers=6, placement="omit",
               selector=None, **kw):
    """Timing-only scheduler run (no jax data plane) with churn."""
    sys_, nodes, rng = build_system(n_nodes=200, zones=4, seed=seed)
    handles = _make_handles(sys_, nodes, rng, m, workers)
    churn = ChurnModel(period_ms=180.0, downtime_ms=360.0, group_size=2,
                      seed=seed + 1)
    kwargs = dict(
        model_bytes=2e5,
        compute_ms=async_engine.worker_compute_fn(30.0, 4.0, seed=seed),
        buffer_k=3, churn=churn, selector=selector,
    )
    kwargs.update(kw)
    if placement != "omit":
        kwargs["placement"] = placement
    sched = AsyncBufferScheduler(sys_, handles, **kwargs)
    sched.run(applies, max_events=2_000_000)
    return sched


def _trace(sched):
    return (list(sched.history), list(sched.churn_log), list(sched.fairness_log))


def _build_forest(n=900, seed=0, subs=250):
    space = IdSpace(zone_bits=3, suffix_bits=24)
    ov = MultiRingOverlay(space, base_bits=4, seed=seed)
    rng = np.random.default_rng(seed)
    for _ in range(n):
        ov.join_random(int(rng.integers(0, 8)), coord=rng.uniform(0, 100, 2))
    f = Forest(ov)
    tree = f.create_tree("plc-app")
    picks = rng.choice(ov.nodes(), size=subs, replace=False)
    f.subscribe_many(tree.app_id, picks)
    return f, tree, rng


def full_fingerprint(tree):
    """Everything observable about a tree, including dict/list order."""
    return (
        tree.root,
        dict(tree.parent),
        list(tree.parent),
        {p: list(tree.children[p]) for p in tree.children},
        list(tree.children),
        sorted(tree.members),
        tree.aggregation_schedule(),
        tree.broadcast_schedule(),
        [sorted(l) for l in tree.levels()],
    )


# -- placement=None trace identity (M=16, under churn) ------------------------


def test_placement_none_trace_identity_m16():
    legacy = _run_sched(16, placement="omit")
    off = _run_sched(16, placement=None)
    assert _trace(legacy) == _trace(off) and legacy.history
    assert off.replan_log == [] and off.control_bytes == 0.0
    assert not off.uplink_bytes.any()  # ledger only charged when placed


def test_max_moves_zero_engine_is_trace_identical():
    """The full loop armed but forbidden to move: every trigger fires,
    every plan returns empty, and the event trace must not shift."""
    off = _run_sched(16, placement=None)
    armed = _run_sched(16, placement=PlacementEngine(max_moves=0))
    assert _trace(off) == _trace(armed)
    assert armed.replan_log and all(r.moves == () for r in armed.replan_log)
    assert armed.control_bytes == 0.0


def test_placement_knob_validated():
    with pytest.raises(TypeError):
        _run_sched(2, placement=object())
    sched = _run_sched(2, placement=True, applies=1)
    assert isinstance(sched.placement, PlacementEngine)


# -- vectorized cost sweep == per-node Python oracle --------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_vectorized_cost_sweep_matches_scalar_oracle(seed):
    f, tree, rng = _build_forest(seed=seed)
    n_rows = 64
    rows = rng.integers(0, n_rows, size=tree._n)
    cap = rng.uniform(20.0, 100.0, size=n_rows)
    occ = rng.integers(0, 6, size=n_rows).astype(np.float64)
    kw = dict(base_ms=5.0, down_mbit=1.6, up_mbit=2.4)
    up, down, hc_up, hc_down = tree_path_costs(tree, rows, cap, occ, **kw)
    nodes = sorted(tree.nodes())
    s_up, s_down = tree_path_costs_scalar(tree, rows, cap, occ, nodes=nodes, **kw)
    slots = np.asarray([tree._slot[n] for n in nodes])
    # EXACT float equality: the two sweeps accumulate in the same
    # two-operand order, so parity is ==, not approx
    assert np.array_equal(up[slots], s_up)
    assert np.array_equal(down[slots], s_down)
    assert np.all(np.isfinite(up[slots])) and np.all(hc_up[slots] > 0)
    # the root costs nothing to reach from itself
    rs = tree._slot[tree.root]
    assert up[rs] == 0.0 and down[rs] == 0.0


def test_cost_sweep_root_detached_slots_are_inf():
    f, tree, rng = _build_forest(n=200, seed=3, subs=40)
    # force a detached slot by pruning a leaf
    leaf = next(n for n in tree.members
                if n != tree.root and not tree.children.get(n))
    f.unsubscribe(tree.app_id, leaf)
    rows = np.zeros(tree._n, np.int64)
    up, down, _, _ = tree_path_costs(
        tree, rows, np.array([50.0]), np.array([0.0]),
        base_ms=5.0, down_mbit=1.0, up_mbit=1.0,
    )
    if leaf in tree._slot and leaf not in tree.parent:
        s = tree._slot[leaf]
        assert np.isinf(up[s]) and np.isinf(down[s])


# -- re-graft oracle parity ----------------------------------------------------


def _random_moves(tree, rng, k=40):
    pool = [n for n in tree.nodes() if n != tree.root]
    targets = list(tree.nodes())
    return [
        (int(rng.choice(pool)), int(rng.choice(targets)))
        for _ in range(min(k, len(pool)))
    ]


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_regraft_many_matches_sequential_oracle(seed):
    fa, ta, rng = _build_forest(seed=seed)
    fb, tb, _ = _build_forest(seed=seed)
    assert full_fingerprint(ta) == full_fingerprint(tb)
    # churn the trees identically first: some members leave mid-plan
    leavers = [int(n) for n in rng.choice(sorted(ta.members), size=20, replace=False)
               if int(n) != ta.root]
    fa.unsubscribe_many(ta.app_id, leavers)
    for n in leavers:
        fb.unsubscribe_one(tb.app_id, n)
    moves = _random_moves(ta, rng)
    applied_bulk = fa.regraft_many(ta.app_id, moves, strict=False)
    applied_seq = []
    for n, p in moves:
        try:
            fb.regraft(tb.app_id, n, p)
        except (KeyError, ValueError):
            continue
        applied_seq.append((n, p))
    assert applied_bulk == applied_seq
    assert full_fingerprint(ta) == full_fingerprint(tb)


def test_regraft_validation():
    f, tree, rng = _build_forest(n=300, seed=5, subs=60)
    # pick an interior node with a child: moving it under its own
    # descendant must raise (cycle guard)
    interior = next(n for n in tree.nodes()
                    if n != tree.root and tree.children.get(n))
    child = tree.children[interior][0]
    with pytest.raises(ValueError, match="cycle"):
        f.regraft(tree.app_id, interior, child)
    with pytest.raises(ValueError, match="root"):
        f.regraft(tree.app_id, tree.root, interior)
    with pytest.raises(KeyError):
        f.regraft(tree.app_id, -12345, tree.root)
    # strict=False skips exactly the invalid ones
    ok_target = tree.root
    applied = f.regraft_many(
        tree.app_id, [(interior, child), (interior, ok_target)], strict=False
    )
    assert applied == [(interior, ok_target)]
    with pytest.raises(ValueError):
        f.regraft_many(tree.app_id, [(interior, child)], strict=True)


@pytest.mark.parametrize("seed,n_leave", [(0, 1), (0, 30), (1, 80), (2, 150)])
def test_unsubscribe_many_matches_sequential_oracle(seed, n_leave):
    fa, ta, rng = _build_forest(seed=seed)
    fb, tb, _ = _build_forest(seed=seed)
    leave = [int(n) for n in rng.choice(sorted(ta.members), size=n_leave,
                                        replace=False)]
    leave += leave[: max(1, n_leave // 4)]  # duplicates must be no-ops
    leave.append(ta.root)  # root only drops membership
    fa.unsubscribe_many(ta.app_id, leave)
    for n in leave:
        fb.unsubscribe_one(tb.app_id, n)
    assert full_fingerprint(ta) == full_fingerprint(tb)
    # leavers are gone from membership; surviving members still route
    assert not (set(leave) - {ta.root}) & ta.members
    for n in list(ta.members)[:20]:
        assert ta.path_to_root(n)[-1] == ta.root


def test_unsubscribe_many_interleaved_with_regrafts():
    """The placement loop's actual sequence: re-graft, then mass-leave,
    then re-graft again — stays oracle-identical throughout."""
    fa, ta, rng = _build_forest(seed=7)
    fb, tb, _ = _build_forest(seed=7)
    for round_ in range(3):
        moves = _random_moves(ta, rng, k=15)
        a = fa.regraft_many(ta.app_id, moves, strict=False)
        b = []
        for n, p in moves:
            try:
                fb.regraft(tb.app_id, n, p)
            except (KeyError, ValueError):
                continue
            b.append((n, p))
        assert a == b
        leave = [int(n) for n in
                 rng.choice(sorted(ta.members), size=10, replace=False)]
        fa.unsubscribe_many(ta.app_id, leave)
        for n in leave:
            fb.unsubscribe_one(tb.app_id, n)
        assert full_fingerprint(ta) == full_fingerprint(tb)


# -- replan determinism + on-clock pricing ------------------------------------


def test_replan_determinism_and_pricing():
    a = _run_sched(8, placement=PlacementEngine(), applies=2)
    b = _run_sched(8, placement=PlacementEngine(), applies=2)
    assert _trace(a) == _trace(b)
    assert a.replan_log == b.replan_log and a.replan_log
    assert a.control_bytes == b.control_bytes
    triggers = {r.trigger for r in a.replan_log}
    assert triggers <= {"bootstrap", "churn", "defer", "selector", "contention"}
    assert "bootstrap" in triggers  # run() always seeds one replan
    moved = [r for r in a.replan_log if r.moves]
    if moved:  # applied moves are priced, not free
        assert all(r.cost_ms > 0 and r.control_bytes > 0 for r in moved)
        assert a.control_bytes == pytest.approx(
            sum(r.control_bytes for r in a.replan_log)
        )
        assert a.uplink_bytes.any()
    eng = a.placement
    assert eng.replans == len(a.replan_log)
    assert eng.moves_applied == sum(len(r.moves) for r in a.replan_log)


def test_replan_rate_limited_by_min_interval():
    slow = _run_sched(8, placement=PlacementEngine(min_interval_ms=1e7), applies=2)
    # only the bootstrap replan fits inside one interval
    assert len(slow.replan_log) == 1
    assert slow.replan_log[0].trigger == "bootstrap"


# -- adaptive resample cadence -------------------------------------------------


def _sampled(seed=0, **kw):
    return _run_sched(
        6, seed=seed, applies=2, congestion_mode="sampled",
        model_bytes=6e5, **kw
    )


def test_resample_target_error_validated():
    with pytest.raises(ValueError, match="needs resample_every"):
        _sampled(resample_target_error=0.1)
    with pytest.raises(ValueError, match="must be > 0"):
        _sampled(resample_every=20.0, resample_target_error=0.0)


def test_adaptive_cadence_tightens_and_bounds():
    base = 200.0
    s = _sampled(resample_every=base, resample_target_error=1e-12)
    assert s.resample_log  # controller ran
    everies = [e for (_, _, e, _) in s.resample_log]
    # an unattainable target tightens the cadence, never past base/8
    assert min(everies) < base and min(everies) >= base / 8.0
    # constructor cadence untouched for the next run
    assert s._resample_every0 == base


def test_adaptive_cadence_relaxes_and_bounds():
    base = 20.0
    s = _sampled(resample_every=base, resample_target_error=1e9)
    assert s.resample_log
    everies = [e for (_, _, e, _) in s.resample_log]
    assert max(everies) > base and max(everies) <= 4.0 * base
    # event-count variant obeys its own bounds
    s2 = _sampled(resample_events=50, resample_target_error=1e9)
    events = [ev for (_, _, _, ev) in s2.resample_log]
    assert events and max(events) <= 200 and min(events) >= 6


def test_adaptive_cadence_off_is_identity():
    frozen = _sampled(resample_every=100.0)
    again = _sampled(resample_every=100.0, resample_target_error=None)
    assert _trace(frozen) == _trace(again)
    assert frozen.resample_log == [] and again.resample_log == []
    assert frozen.resample_every == 100.0  # never mutated when off


def test_adaptive_cadence_resets_between_runs():
    s = _sampled(resample_every=200.0, resample_target_error=1e-12)
    drifted = s.resample_every
    assert drifted < 200.0
    s.run(1, max_events=2_000_000)  # re-run: cadence restored from ctor
    assert s.resample_log[0][2] <= 200.0
    assert s._resample_every0 == 200.0


# -- selector -> planner feedback ---------------------------------------------


def _miss(sel, ai, w, cycle_ms, defer_ms=None):
    if defer_ms is not None:
        sel.on_defer(ai, w, 0.0, defer_ms)
    sel.on_commit(ai, w, 0.0, cycle_ms)


def test_transport_deferred_worker_replaced_not_blocklisted():
    calls = []
    sel = UtilitySelector(deadline_ms=100.0, blocklist_after=2, seed=0)
    sel.placement_hook = lambda ai, w, kind, mag: calls.append((ai, w, kind))
    # defer EMA dominates the cycle: transport-attributed
    for _ in range(2):
        _miss(sel, 0, 7, cycle_ms=400.0, defer_ms=900.0)
    st = sel._s(0, 7)
    assert calls and calls[-1] == (0, 7, "transport")
    assert st.block_offers == 0 and st.misses == 0  # re-placed, not parked
    assert sel.replaced_total == 1
    # compute-slow worker (no defers): still blocklisted, planner told
    for _ in range(2):
        _miss(sel, 0, 9, cycle_ms=400.0)
    st9 = sel._s(0, 9)
    assert st9.block_offers > 0
    assert calls[-1] == (0, 9, "deadline")


def test_selector_legacy_policy_unchanged_without_hook():
    mk = lambda: UtilitySelector(deadline_ms=100.0, blocklist_after=2, seed=0)
    with_none, reference = mk(), mk()
    for sel in (with_none, reference):
        for _ in range(2):
            _miss(sel, 0, 7, cycle_ms=400.0, defer_ms=900.0)
    st = with_none._s(0, 7)
    assert st.block_offers == reference._s(0, 7).block_offers > 0
    assert with_none.replaced_total == 0


def test_selector_feedback_wired_end_to_end():
    """A placed run with a UtilitySelector wires the hook automatically
    and stays deterministic."""
    mk = lambda: UtilitySelector(deadline_ms=120.0, seed=0)
    a = _run_sched(6, placement=PlacementEngine(), selector=mk(), applies=2)
    b = _run_sched(6, placement=PlacementEngine(), selector=mk(), applies=2)
    assert _trace(a) == _trace(b)
    assert a.selector.placement_hook is not None


# -- engine unit behavior ------------------------------------------------------


def test_plan_tree_respects_caps_and_blocked():
    f, tree, rng = _build_forest(n=400, seed=11, subs=80)
    rows = np.arange(tree._n) % 16
    cap = np.full(16, 40.0)
    occ = np.zeros(16)
    occ[rows[tree._slot[next(iter(tree.members))]]] = 50.0  # one hot uplink
    eng = PlacementEngine(max_moves=3, cooldown_ms=0.0)
    moves = eng.plan_tree(
        tree, rows=rows, cap=cap, occ=occ, base_ms=5.0,
        down_mbit=1.6, up_mbit=2.4, blocked=frozenset(tree.members),
    )
    # every member blocked as a target: moves may still pick relays,
    # but movers/targets never include blocked nodes as new parents
    assert all(m.new_parent not in tree.members for m in moves)
    assert len(moves) <= 3
    for m in moves:
        assert isinstance(m, Move) and m.node != tree.root


def test_plan_tree_cooldown_suppresses_thrash():
    f, tree, rng = _build_forest(n=400, seed=13, subs=60)
    rows = np.arange(tree._n) % 8
    cap = np.full(8, 30.0)
    occ = rng.uniform(0.0, 8.0, size=8)
    eng = PlacementEngine(max_moves=4, cooldown_ms=1000.0)
    kw = dict(rows=rows, cap=cap, occ=occ, base_ms=5.0,
              down_mbit=1.6, up_mbit=2.4)
    first = eng.plan_tree(tree, now_ms=0.0, **kw)
    if not first:
        pytest.skip("no profitable moves on this fixture")
    again = eng.plan_tree(tree, now_ms=10.0, **kw)
    moved = {m.node for m in first}
    assert all(m.node not in moved for m in again)  # cooled down
    later = eng.plan_tree(tree, now_ms=5000.0, **kw)
    assert isinstance(later, list)  # cooldown expired: planning resumes
