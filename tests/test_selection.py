"""Adaptive-K control + utility-based client selection.

Covers the PR-3 control layer:
 - adaptive=False takes the exact PR-2 fixed-K code path (trace
   identity, also shown by a zero-gain controller);
 - selector=None == UniformSelector (admit-everyone oracle);
 - AdaptiveKController law: staleness above/below target moves K
   up/down, clamped to [k_min, live];
 - adaptive K stays within bounds under a churn schedule;
 - UtilitySelector parks stragglers but never starves a client
   (epsilon-exploration liveness floor);
 - telemetry: ApplyEvent.k, AppHandle.round_records (per-apply K,
   staleness histogram, selector scores);
 - benchmarks.run registry has a real description per bench.
"""
import numpy as np
import pytest

from repro import data as data_mod
from repro.core.api import TotoroSystem
from repro.core.sim import AdaptiveKController, ChurnModel
from repro.fl import async_engine, rounds
from repro.fl.selection import ClientSelector, UniformSelector, UtilitySelector


def build_app(seed=0, workers=8, n_nodes=150, name="sel-test"):
    sys_ = TotoroSystem(zone_bits=2, suffix_bits=20, seed=seed)
    rng = np.random.default_rng(seed)
    nodes = [sys_.Join("n", i, site=i % 4, coord=rng.uniform(0, 50, 2)) for i in range(n_nodes)]
    x, y = data_mod.synthetic_classification(workers * 150, 16, 4, seed=seed)
    parts = data_mod.dirichlet_partition(y, workers, alpha=1.0, seed=seed + 1)
    parts = [p if len(p) else np.arange(3) for p in parts]
    ws = [int(w) for w in rng.choice(nodes, size=workers, replace=False)]
    app = rounds.make_app(
        sys_, name, workers=ws,
        data_by_worker={w: (x[parts[i]], y[parts[i]]) for i, w in enumerate(ws)},
        dim=16, num_classes=4, local_steps=3, lr=0.2,
    )
    return sys_, app


def _run(seed=4, workers=8, applies=6, **kw):
    sys_, app = build_app(seed=seed, workers=workers)
    res = rounds.run_async(
        sys_, [app], applies=applies, buffer_k=3, staleness_alpha=0.5,
        model_bytes=1e5, compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=1),
        **kw,
    )
    return sys_, app, res


def test_fixed_k_trace_identical_to_zero_gain_controller():
    """adaptive=False must reproduce the PR-2 fixed-K trace; a frozen
    controller (gain=0 -> K never moves) proves the adaptive plumbing
    adds nothing but the K update itself."""
    _, app_f, fixed = _run()
    _, app_z, zero = _run(adaptive=True, adaptive_kwargs={"gain": 0.0})
    assert fixed["events"] == zero["events"]
    assert [h["loss"] for h in fixed["history"]] == [h["loss"] for h in zero["history"]]
    assert all(e.k == 3 for e in fixed["events"])
    # ... and fixed runs are deterministic run-to-run (the PR-2 anchor)
    _, _, again = _run()
    assert fixed["events"] == again["events"]


def test_uniform_selector_is_the_identity_oracle():
    _, _, none = _run()
    _, _, uni = _run(selector=UniformSelector())
    assert none["events"] == uni["events"]
    assert [h["loss"] for h in none["history"]] == [h["loss"] for h in uni["history"]]


def test_controller_law_direction_and_clamps():
    c = AdaptiveKController(k_init=8, k_min=2, target_staleness=1.5, percentile=90.0, gain=0.5)
    up = c.on_apply(10.0, [5, 6, 7, 8], live_workers=32)  # staleness >> target
    assert up > 8
    c2 = AdaptiveKController(k_init=8, k_min=2, target_staleness=1.5, gain=0.5)
    down = c2.on_apply(10.0, [0, 0, 0, 0], live_workers=32)  # staleness << target
    assert down < 8
    # clamp floor: repeated shrink can never go below k_min
    for t in range(20):
        c2.on_apply(10.0 + t, [0, 0], live_workers=32)
    assert c2.current_k == 2
    # clamp ceiling: live membership bounds growth
    c3 = AdaptiveKController(k_init=8, k_min=1, target_staleness=0.5, gain=1.0)
    for t in range(20):
        c3.on_apply(float(t), [9, 9, 9, 9], live_workers=12)
    assert c3.current_k <= 12
    # arrival-rate cap: K <= rate * max_apply_interval
    c4 = AdaptiveKController(
        k_init=8, k_min=1, target_staleness=0.5, gain=1.0, max_apply_interval_ms=100.0
    )
    for t in range(10):
        c4.on_commit(50.0 * t)  # one arrival per 50 ms -> rate 0.02/ms
    c4.on_apply(500.0, [9, 9, 9], live_workers=64)
    assert c4.current_k <= int(round(0.02 * 100.0)) + 1


def test_adaptive_k_bounded_under_churn():
    """Adaptive K under a fail/rejoin schedule stays inside
    [k_min, workers] on every apply — churn can shrink live membership
    but never push K outside bounds or stall the run."""
    workers = 12
    sys_, app = build_app(seed=5, workers=workers, n_nodes=200)
    churn = ChurnModel(period_ms=120.0, downtime_ms=400.0, group_size=2, seed=3)
    res = rounds.run_async(
        sys_, [app], applies=8, buffer_k=4, staleness_alpha=0.5, model_bytes=1e5,
        compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=1),
        churn=churn, adaptive=True,
        adaptive_kwargs={"k_min": 2, "target_staleness": 1.0},
    )
    assert len(res["events"]) == 8
    assert all(1 <= e.k <= workers for e in res["events"])
    ctrl = res["scheduler"].controllers[0]
    assert ctrl is not None and len(ctrl.history) == 8
    assert all(2 <= k <= workers for _, k, _, _ in ctrl.history)
    assert ctrl.arrivals_per_ms > 0.0
    # the controller actually moved K at least once
    assert len({k for _, k, _, _ in ctrl.history}) > 1


def test_utility_selector_parks_stragglers_but_never_starves():
    """A harsh deadline parks the slow tail, yet epsilon-exploration and
    blocklist decay guarantee every client keeps committing."""
    workers = 10
    sel = UtilitySelector(deadline_ms=150.0, epsilon=0.15, admit_quantile=0.5,
                          blocklist_after=2, blocklist_rounds=4, seed=0)
    sys_, app = build_app(seed=6, workers=workers)
    res = rounds.run_async(
        sys_, [app], applies=40, buffer_k=3, staleness_alpha=0.5, model_bytes=1e5,
        compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=1), selector=sel,
    )
    assert len(res["events"]) == 40
    assert sel.parked_total > 0  # selection actually declined someone
    counts = sel.commit_counts(0)
    assert len(counts) == workers
    assert all(c >= 1 for c in counts.values())  # liveness: nobody starved
    # utilities are populated and stragglers score below the fast tail
    scores = sel.scores(0)
    assert len(scores) == workers and max(scores.values()) > min(scores.values())


def test_selector_protocol_and_telemetry_records():
    assert isinstance(UniformSelector(), ClientSelector)
    assert isinstance(UtilitySelector(), ClientSelector)
    sel = UtilitySelector(deadline_ms=200.0, seed=1)
    sys_, app, res = None, None, None
    sys_, app = build_app(seed=8, workers=8)
    res = rounds.run_async(
        sys_, [app], applies=4, buffer_k=3, staleness_alpha=0.5, model_bytes=1e5,
        compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=2),
        adaptive=True, selector=sel,
    )
    recs = app.handle.round_records
    assert len(recs) == 4
    for rec, ev in zip(recs, res["events"]):
        assert rec["k"] == ev.k and rec["arrivals"] == ev.arrivals
        assert sum(rec["staleness_hist"]) == rec["arrivals"]
        assert rec["version"] >= 1
    # selector scores land in the records once stats exist
    assert any(r["selector_scores"] for r in recs)
    # history records carry the effective K too
    assert all(h["k"] == ev.k for h, ev in zip(res["history"], res["events"]))


def test_bench_registry_has_descriptions():
    from benchmarks.run import REGISTRY

    assert len(REGISTRY) >= 10
    for name, mod, desc in REGISTRY:
        assert isinstance(desc, str) and len(desc) > 10, name
