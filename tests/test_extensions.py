"""Paper appendix extensions: Algorithm 2 multicast (App. N-B) and
heterogeneous logical nodes (App. L)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.congestion import make_env
from repro.core.multicast import MulticastPlanner, enumerate_subsets
from repro.core.nodeid import IdSpace
from repro.core.overlay import MultiRingOverlay
from repro.core.forest import Forest


def test_enumerate_subsets_counts():
    s = enumerate_subsets(4, max_size=2)
    assert s.shape == (4 + 6, 4)
    assert set(np.asarray(s.sum(-1))) == {1.0, 2.0}


def test_multicast_planner_policies_valid_and_improve():
    env = make_env(5, seed=2)
    p = MulticastPlanner(num_nodes=24, num_paths=5, max_subset=2, tau=8, seed=0)
    key = jax.random.key(0)
    rewards_first, rewards_last = None, None
    for ep in range(15):
        key, k1, k2 = jax.random.split(key, 3)
        actions = p.sample_actions(k1)
        rewards = p.rewards(env, actions, k2)
        if ep == 0:
            rewards_first = float(jnp.mean(rewards))
        rewards_last = float(jnp.mean(rewards))
        p.update(actions, rewards)
        np.testing.assert_allclose(np.asarray(p.pi.sum(-1)), 1.0, atol=1e-4)
        assert bool(jnp.all(p.pi >= 0))
    assert rewards_last >= rewards_first - 0.05  # learning not diverging
    usage = p.subset_usage()
    assert usage.shape == (2,) and abs(usage.sum() - 1.0) < 1e-3


def test_multicast_rewards_bounded_by_subset_size():
    env = make_env(4, seed=1)
    p = MulticastPlanner(num_nodes=6, num_paths=4, max_subset=2, tau=4)
    key = jax.random.key(1)
    actions = p.sample_actions(key)
    r = p.rewards(env, actions, jax.random.fold_in(key, 1))
    assert bool(jnp.all(r >= 0)) and bool(jnp.all(r <= 2.0))  # [0, F], F=2


def test_logical_nodes_attract_proportional_masters():
    """App. L Fig 25: a physical node mapped to more logical P2P nodes
    hosts proportionally more masters."""
    space = IdSpace(zone_bits=1, suffix_bits=22)
    ov = MultiRingOverlay(space, base_bits=4, seed=0)
    rng = np.random.default_rng(0)
    # 20 small nodes (1 unit) + 5 big nodes (8 units each)
    small, big = [], []
    for i in range(20):
        small += ov.join_weighted(0, 1, coord=rng.uniform(0, 10, 2))
    for i in range(5):
        big += ov.join_weighted(0, 8, coord=rng.uniform(0, 10, 2))
    f = Forest(ov)
    for i in range(400):
        f.create_tree(f"app-{i}", salt=str(i))
    masters = f.masters_per_node()
    small_masters = sum(masters.get(n, 0) for n in small)
    big_masters = sum(masters.get(n, 0) for n in big)
    # big nodes hold 40/60 of logical ids -> expect ~2x the masters
    assert big_masters > small_masters
    # per PHYSICAL node: big nodes get several-fold more
    per_small = small_masters / 20
    per_big = big_masters / 5
    assert per_big > 3 * per_small
