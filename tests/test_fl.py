"""FL substrate: fedavg/fedprox math, compression, DP, rounds, steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import data as data_mod
from repro.fl import aggregation, compression, dp


def test_fedavg_weighted_mean():
    deltas = [{"w": jnp.ones((3,)) * i} for i in range(1, 4)]
    out = aggregation.fedavg(deltas, [1.0, 1.0, 2.0])
    np.testing.assert_allclose(np.asarray(out["w"]), (1 + 2 + 3 * 2) / 4 * np.ones(3))


def test_pairwise_accumulate_matches_fedavg():
    key = jax.random.key(0)
    deltas = [{"w": jax.random.normal(jax.random.fold_in(key, i), (5,))} for i in range(4)]
    w = np.array([0.1, 0.2, 0.3, 0.4])
    acc = None
    for d, wi in zip(deltas, w):
        acc = aggregation.pairwise_accumulate(acc, d, float(wi))
    expect = aggregation.fedavg(deltas, list(w))
    np.testing.assert_allclose(np.asarray(acc["w"]), np.asarray(expect["w"]), rtol=1e-6)


def test_fedprox_gradient_term():
    g = {"w": jnp.zeros(3)}
    p = {"w": jnp.ones(3) * 2.0}
    w0 = {"w": jnp.ones(3)}
    out = aggregation.fedprox_grad(g, p, w0, mu=0.5)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.5 * np.ones(3))
    out0 = aggregation.fedprox_grad(g, p, w0, mu=0.0)
    np.testing.assert_allclose(np.asarray(out0["w"]), np.zeros(3))


def test_straggler_mask_renormalizes():
    w = aggregation.straggler_mask([1.0, 1.0, 2.0], [True, False, True])
    np.testing.assert_allclose(np.asarray(w), [1 / 3, 0.0, 2 / 3])


def test_signsgd_and_error_feedback():
    key = jax.random.key(1)
    x = jax.random.normal(key, (8, 256))
    s, scale = compression.signsgd_compress(x)
    assert s.dtype == jnp.int8 and bool(jnp.all(jnp.abs(s) <= 1))
    # error feedback: accumulated residual shrinks the long-run bias
    err = jnp.zeros_like(x)
    recon_sum = jnp.zeros_like(x)
    for i in range(50):
        (c, sc), err = compression.error_feedback_update(x, err, compression.signsgd_compress)
        recon_sum = recon_sum + c.astype(jnp.float32) * sc
    bias = recon_sum / 50 - x
    assert float(jnp.mean(jnp.abs(bias))) < float(jnp.mean(jnp.abs(x))) * 0.3


def test_dp_clip_and_noise():
    g = {"w": jnp.ones((100,)) * 10}
    clipped, n = dp.clip_by_global_norm(g, 1.0)
    assert float(dp.global_norm(clipped)) <= 1.0 + 1e-5
    noised = dp.dp_sanitize(g, jax.random.key(0), clip=1.0, sigma=0.1)
    assert float(dp.global_norm(noised)) > 0


def test_dirichlet_partition_covers_all_and_skews():
    _, y = data_mod.synthetic_classification(3000, 16, 10, seed=0)
    parts = data_mod.dirichlet_partition(y, 10, alpha=0.1, seed=0)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == 3000 and len(np.unique(all_idx)) == 3000
    # low alpha -> skewed: some client has a dominant class
    fracs = []
    for p in parts:
        if len(p) < 20:
            continue
        counts = np.bincount(y[p], minlength=10)
        fracs.append(counts.max() / counts.sum())
    assert max(fracs) > 0.5


def test_data_streams_deterministic_and_shard_disjoint():
    sc = data_mod.StreamConfig(vocab_size=100, seq_len=8, batch_per_shard=4, seed=1)
    a = data_mod.lm_batch(sc, shard=0, step=5)
    b = data_mod.lm_batch(sc, shard=0, step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = data_mod.lm_batch(sc, shard=1, step=5)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_full_fl_round_over_overlay_converges():
    from repro.core.api import TotoroSystem
    from repro.fl import rounds

    sys_ = TotoroSystem(zone_bits=2, suffix_bits=20, seed=0)
    rng = np.random.default_rng(0)
    nodes = [sys_.Join("n", i, site=i % 4, coord=rng.uniform(0, 50, 2)) for i in range(150)]
    x, y = data_mod.synthetic_classification(1200, 16, 4, seed=0)
    parts = data_mod.dirichlet_partition(y, 8, alpha=1.0, seed=1)
    workers = [int(w) for w in rng.choice(nodes, size=8, replace=False)]
    app = rounds.make_app(
        sys_, "test", workers=workers,
        data_by_worker={w: (x[parts[i]], y[parts[i]]) for i, w in enumerate(workers)},
        dim=16, num_classes=4, local_steps=4, lr=0.3,
    )
    accs = []
    for _ in range(5):
        rounds.run_round(sys_, app)
        accs.append(rounds.evaluate(app, x[:300], y[:300]))
    assert accs[-1] > 0.8, accs
    assert accs[-1] > accs[0] - 0.05


@pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"), reason="needs jax>=0.6 explicit mesh axis types"
)
def test_q8_cross_pod_math_single_device():
    """q8_mean_over_pods == plain mean up to one quantization step."""
    from repro.fl.steps import q8_mean_over_pods

    key = jax.random.key(0)
    g = {"w": jax.random.normal(key, (2, 64, 32))}  # (pods, ...)
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    with jax.set_mesh(mesh):
        agg = jax.jit(q8_mean_over_pods)(g)
    expect = jnp.mean(g["w"], axis=0)
    step = jnp.max(jnp.abs(g["w"])) / 127
    assert float(jnp.max(jnp.abs(agg["w"] - expect))) <= float(step) + 1e-5
