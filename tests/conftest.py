import os
import sys

# NOTE: no XLA_FLAGS here — smoke tests and benches run on 1 device; only
# launch/dryrun.py forces 512 placeholder devices (in a subprocess).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
