"""Compressed downlink: version-cached broadcast of quantized deltas.

Locks down the broadcast direction end to end:

- **Policy surface**: the ``downlink``/``downlink_levels``/``chain_cap``
  axis validates its domain; ``downlink_bits`` is the minimal fixed
  width for the coarse lattice; ``downlink_wire_bytes`` prices a chain
  of k packed deltas, the quantized full model, or the f32 fallback.
- **Fused kernel parity**: ``ops.apply_quantized_broadcast`` agrees
  with the eager ``ref.apply_quantized_ref`` in BOTH kernel modes
  (pallas-interpret and compiled jnp), including row counts that need
  block padding, and the two modes agree bit for bit.
- **Reference reconstruction**: chained cached deltas from any base
  land bit-for-bit on the master's incrementally-maintained reference
  state (chain 1 and chain ``chain_cap``), the reference stays within
  one quantizer step of the true params (downlink error feedback never
  compounds), and a base past the cache window raises ``KeyError`` —
  the gap the scheduler prices as a full f32 fallback.
- **Scheduler pricing**: ``_download_mbit`` charges chain * packed
  delta bytes inside the window, the full f32 state with no cached
  base or past ``chain_cap``, and zero bytes for a version check
  (chain 0); a churned worker loses its base and rejoins on the full
  path.  The downlink ledger splits from the uplink ledger in
  ``transport_stats`` and the fairness log.
- **Trace identity**: ``downlink="none"`` is provably free — downlink
  knobs are inert and the M=16 churn trace is byte-identical to the
  plain uplink-only policy, in exact, legacy and sampled pricing.
- **EF-SGD**: with deterministic rounding a plain quantizer's commit
  stream carries a persistent bias; error feedback drives the running
  mean of dequantized commits to the true value.
- **signSGD / top-k**: closed-form ``wire_bytes`` equals the real
  ``QuantizedDelta.nbytes`` and is what the scheduler prices commits
  at; trained runs converge finitely.
"""
import math

import jax
import numpy as np
import pytest

from repro.core.api import TotoroSystem
from repro.core.sim import AsyncBufferScheduler, ChurnModel
from repro.fl import compression as comp
from repro.fl.compression import CompressionPolicy
from repro.kernels import ops as kops
from repro.kernels import ref


@pytest.fixture
def kernel_mode_guard():
    prev = kops.kernel_mode()
    yield
    kops.set_kernel_mode(prev)


# -- policy surface ------------------------------------------------------------


def test_downlink_policy_validation():
    with pytest.raises(ValueError):
        CompressionPolicy(downlink="zip")
    with pytest.raises(ValueError):
        CompressionPolicy(downlink="delta-qsgd", downlink_levels=0)
    with pytest.raises(ValueError):
        CompressionPolicy(downlink="delta-qsgd", downlink_levels=128)
    with pytest.raises(ValueError):
        CompressionPolicy(downlink="delta-qsgd", chain_cap=0)
    with pytest.raises(ValueError):
        CompressionPolicy(kind="topk", topk_frac=0.0)
    assert not CompressionPolicy().downlink_enabled
    assert CompressionPolicy(downlink="delta-qsgd").downlink_enabled


@pytest.mark.parametrize("levels,bits", [(1, 2), (3, 3), (7, 4), (15, 5), (127, 8)])
def test_downlink_bits_minimal_width(levels, bits):
    # 2*levels+1 lattice points need ceil(log2(2L+1)) bits
    assert CompressionPolicy(downlink="delta-qsgd", downlink_levels=levels).downlink_bits == bits


def test_downlink_wire_bytes_model():
    p = CompressionPolicy(kind="qsgd-int8", downlink="delta-qsgd", downlink_levels=7)
    payload = 2_000_000.0
    rows = math.ceil(payload / 4.0 / p.chunk)
    one = rows * math.ceil(p.chunk * 4 / 8) + rows * 4
    assert p.delta_wire_bytes(payload) == float(one)
    assert p.downlink_wire_bytes(payload, chain=0) == 0.0
    assert p.downlink_wire_bytes(payload, chain=1) == float(one)
    assert p.downlink_wire_bytes(payload, chain=3) == float(3 * one)
    assert p.downlink_wire_bytes(payload, chain=None) == payload  # f32 fallback
    with pytest.raises(ValueError):
        p.downlink_wire_bytes(payload, chain=-1)
    # a 4-bit packed delta is ~1/8 of the f32 state, far under the int8 floor
    assert p.delta_wire_bytes(payload) < 0.14 * payload
    q8 = CompressionPolicy(kind="qsgd-int8", downlink="qsgd-int8")
    assert q8.downlink_wire_bytes(payload) == float(rows * q8.chunk + rows * 4)
    assert q8.downlink_wire_bytes(payload, chain=2) == q8.downlink_wire_bytes(payload)
    off = CompressionPolicy(kind="qsgd-int8")
    assert off.downlink_wire_bytes(payload) == payload


def test_broadcast_key_decorrelated_from_commit_key():
    p = CompressionPolicy(kind="qsgd-int8", downlink="delta-qsgd")
    for app, v in [(0, 0), (1, 3), (2, 7)]:
        bk = np.asarray(comp.broadcast_key(p, app, v))
        ck = np.asarray(comp.commit_key(p, app, v))
        assert not np.array_equal(bk, ck)


# -- fused dequantize-and-apply kernel -----------------------------------------


def _chain_case(seed, rows, depth):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 1.0, (rows, 256)).astype(np.float32)
    q = rng.integers(-7, 8, (depth, rows, 256)).astype(np.int8)
    s = rng.uniform(1e-4, 1e-2, (depth, rows, 1)).astype(np.float32)
    return w, q, s


@pytest.mark.parametrize("rows,depth", [(4, 1), (4, 3), (3, 2), (300, 3)])
def test_apply_quantized_parity_both_modes(kernel_mode_guard, rows, depth):
    w, q, s = _chain_case(rows, rows, depth)
    want = np.asarray(ref.apply_quantized_ref(w, q, s))
    got = {}
    for mode in ("pallas", "jnp"):
        kops.set_kernel_mode(mode)
        got[mode] = np.asarray(kops.apply_quantized_broadcast(w, q, s))
        assert got[mode].shape == w.shape
        # jit fuses the multiply-add (FMA) so eager-ref agreement is fp-tight
        np.testing.assert_allclose(got[mode], want, rtol=0, atol=1e-5)
    np.testing.assert_array_equal(got["pallas"], got["jnp"])


def test_apply_quantized_chain_order(kernel_mode_guard):
    """One D-deep call == D successive single-delta calls, per mode."""
    w, q, s = _chain_case(7, 8, 3)
    for mode in ("pallas", "jnp"):
        kops.set_kernel_mode(mode)
        fused = np.asarray(kops.apply_quantized_broadcast(w, q, s))
        step = w
        for d in range(3):
            step = np.asarray(kops.apply_quantized_broadcast(step, q[d : d + 1], s[d : d + 1]))
        np.testing.assert_array_equal(fused, step)


# -- reference reconstruction --------------------------------------------------


def _master_walk(pol, versions=5, seed=0):
    """Simulate the master's broadcast-state maintenance: returns the
    per-version reference states, the delta cache, and the true params."""
    rng = np.random.default_rng(seed)
    params = {"w": rng.normal(0, 1, (40, 13)).astype(np.float32),
              "b": rng.normal(0, 1, (17,)).astype(np.float32)}
    recon, cache, states = params, {}, {0: params}
    true, trues = params, {0: params}
    for v in range(1, versions + 1):
        true = jax.tree.map(
            lambda p: p + rng.normal(0, 0.01, p.shape).astype(np.float32), true
        )
        trues[v] = true
        delta = jax.tree.map(lambda a, b: a - b, true, recon)
        qd = comp.quantize_broadcast_delta(delta, pol, comp.broadcast_key(pol, 0, v))
        cache[v] = qd
        recon = comp.apply_delta_chain(recon, [qd])
        states[v] = recon
    return states, cache, trues


def test_chained_reconstruction_bit_exact_at_1_and_cap():
    pol = CompressionPolicy(kind="qsgd-int8", downlink="delta-qsgd", chain_cap=3)
    states, cache, trues = _master_walk(pol)
    for base in (4, 2):  # chain lengths 1 and chain_cap
        chain = [cache[v] for v in range(base + 1, 6)]
        got = comp.apply_delta_chain(states[base], chain)
        for k in ("w", "b"):
            np.testing.assert_array_equal(got[k], states[5][k])
    # downlink error feedback: the reference's drift from the TRUE params
    # is bounded by one quantizer step at EVERY version — quantizing each
    # delta against the reference absorbs the error, it never compounds
    for v in range(1, 6):
        step = float(cache[v].scale.max())
        for k in ("w", "b"):
            drift = np.abs(states[v][k] - trues[v][k]).max()
            assert drift <= step + 1e-6, (v, k, drift, step)


def test_apply_delta_chain_rejects_mismatched_grid():
    pol = CompressionPolicy(kind="qsgd-int8", downlink="delta-qsgd")
    _, cache, _ = _master_walk(pol, versions=1)
    wrong = {"w": np.zeros((3, 3), np.float32)}
    with pytest.raises(ValueError):
        comp.apply_delta_chain(wrong, [cache[1]])
    assert comp.apply_delta_chain(wrong, []) is wrong  # empty chain: no-op


# -- scheduler pricing ---------------------------------------------------------


def _build_handles(m, workers=4, n_nodes=160, seed=0):
    sys_ = TotoroSystem(zone_bits=2, suffix_bits=22, seed=seed)
    rng = np.random.default_rng(seed)
    nodes = [
        sys_.Join("n", i, site=i % 4, coord=rng.uniform(0, 50, 2),
                  bandwidth=float(rng.uniform(20, 100)))
        for i in range(n_nodes)
    ]
    handles = []
    for a in range(m):
        h = sys_.CreateTree(f"dl-{m}-{a}")
        for w in rng.choice(nodes, size=workers, replace=False):
            sys_.Subscribe(h.app_id, int(w))
        handles.append(h)
    return sys_, handles


def _trace(m, *, compression, seed=0, applies=2, churn=True,
           model_bytes=2e5, **sched_kw):
    sys_, handles = _build_handles(m, seed=seed)
    sched = AsyncBufferScheduler(
        sys_, handles, model_bytes=model_bytes, compute_ms=25.0, buffer_k=3,
        churn=ChurnModel(period_ms=400.0, downtime_ms=600.0, group_size=2, seed=9)
        if churn else None,
        app_compression=compression, **sched_kw,
    )
    events = sched.run(applies, max_events=500_000)
    return events, list(sched.churn_log), list(sched.fairness_log), sched


DELTA = CompressionPolicy(kind="qsgd-int8", downlink="delta-qsgd")


def test_download_mbit_chain_selection():
    """The pricing decision table, hit directly: no base -> full, gap in
    [0, cap] -> chain, gap > cap -> full fallback."""
    sys_, handles = _build_handles(1)
    sched = AsyncBufferScheduler(
        sys_, handles, model_bytes=2e5, app_compression=DELTA
    )
    sched._version = [7]
    senders = np.asarray([0, 1], np.int64)
    w = next(iter(handles[0].tree.members))
    full = float(sched.model_bytes)
    one = DELTA.delta_wire_bytes(sched.model_bytes)

    def price(base):
        sched._worker_base.pop((0, w), None)
        if base is not None:
            sched._worker_base[(0, w)] = base
        mbit = sched._download_mbit(0, w, senders)
        t, ai, ww, chain, nbytes = sched.downlink_log[-1]
        assert (ai, ww) == (0, w)
        assert sched._worker_base[(0, w)] == 7  # base advanced to current
        assert sched._pending_down_bytes[(0, w)] == nbytes * len(senders)
        assert mbit == nbytes * 8e-6
        return chain, nbytes

    assert price(None) == (None, full)        # first download: no base
    assert price(7) == (0, 0.0)               # version check, zero payload
    assert price(6) == (1, one)
    assert price(7 - DELTA.chain_cap) == (DELTA.chain_cap, DELTA.chain_cap * one)
    assert price(7 - DELTA.chain_cap - 1) == (None, full)  # over cap: fallback


def test_downlink_log_and_ledger_delta_run():
    events, _, fair, sched = _trace(4, compression=DELTA, churn=False)
    assert events
    cap = DELTA.chain_cap
    one = DELTA.delta_wire_bytes(sched.model_bytes)
    full = float(sched.model_bytes)
    first_seen = set()
    for _, ai, w, chain, nbytes in sched.downlink_log:
        if (ai, w) not in first_seen:
            first_seen.add((ai, w))
            assert chain is None and nbytes == full  # cold start: full path
        if chain is None:
            assert nbytes == full
        else:
            assert 0 <= chain <= cap
            assert nbytes == chain * one
    stats = sched.transport_stats()
    assert len(stats["downlink_bytes"]) == 4
    assert all(b > 0 for b in stats["downlink_bytes"])
    # the ledger is exactly the credited per-cycle stashes
    assert "downlink_bytes" in fair[-1]
    # an uncompressed run's downlink ledger prices full-model legs
    _, _, _, base = _trace(4, compression=None, churn=False)
    assert sum(base.transport_stats()["downlink_bytes"]) > sum(stats["downlink_bytes"])


def test_churn_rejoin_worker_downloads_full_state():
    sys_, handles = _build_handles(8, seed=1)
    sched = AsyncBufferScheduler(
        sys_, handles, model_bytes=2e5, compute_ms=25.0, buffer_k=3,
        churn=ChurnModel(period_ms=200.0, downtime_ms=150.0, group_size=2, seed=9),
        app_compression=DELTA,
    )
    sched.run(6, max_events=500_000)
    churn_log = sched.churn_log
    fails = [(r.time_ms, set(r.nodes)) for r in churn_log if r.kind == "fail"]
    assert fails
    checked = 0
    for t_fail, victims in fails:
        for t, ai, w, chain, nbytes in sched.downlink_log:
            if w in victims and t > t_fail:
                # first post-fail download for this (app, worker): the
                # cached base was dropped, so the full path is priced
                assert chain is None and nbytes == float(sched.model_bytes)
                checked += 1
                victims = victims - {w}
    assert checked > 0


def test_delta_cache_window_and_keyerror_past_it():
    from benchmarks.common import build_system
    from repro import data as data_mod
    from repro.fl import async_engine, rounds

    sys_, nodes, rng = build_system(n_nodes=60, zones=3, seed=0)
    x, y = data_mod.synthetic_classification(4 * 24, 16, 4, seed=5)
    parts = data_mod.dirichlet_partition(y, 4, alpha=1.0, seed=6)
    ws = [int(n) for n in rng.choice(nodes, size=4, replace=False)]
    app = rounds.make_app(
        sys_, "dlw", workers=ws,
        data_by_worker={n: (x[parts[i]], y[parts[i]]) for i, n in enumerate(ws)},
        dim=16, num_classes=4, local_steps=1, lr=0.2, seed=0,
    )
    out = async_engine.run_async(
        sys_, [app], applies=6, buffer_k=3, model_bytes=2e5,
        compute_ms=10.0, compression=DELTA,
    )
    tr = out["trainer"]
    cur = tr.version[0]
    cap = DELTA.chain_cap
    assert cur > cap
    cached = sorted(tr._delta_cache[0])
    assert cached == list(range(cur - cap + 1, cur + 1))  # bounded window
    assert len(tr.delta_chain(0, cur - 1, cur)) == 1
    assert len(tr.delta_chain(0, cur - cap, cur)) == cap
    with pytest.raises(KeyError):
        tr.delta_chain(0, cur - cap - 1, cur)  # past the window: full path


# -- downlink="none" trace identity --------------------------------------------


def test_downlink_none_knobs_are_inert_m16_churn():
    """Uplink-only compression with downlink="none" must not read ANY
    downlink knob: varying them produces byte-identical ApplyEvents,
    ChurnRecords and fairness logs at M=16 under churn."""
    up = CompressionPolicy(kind="qsgd-int8")
    up_weird = CompressionPolicy(
        kind="qsgd-int8", downlink="none", downlink_levels=1, chain_cap=9
    )
    base = _trace(16, compression=up)
    off = _trace(16, compression=up_weird)
    assert base[0] == off[0]
    assert base[1] == off[1]
    assert base[2] == off[2]
    assert base[3].downlink_log == [] == off[3].downlink_log


def test_downlink_none_identity_under_legacy_and_sampled_pricing():
    for kw in (dict(fair=False), dict(congestion_mode="sampled", churn=False)):
        base = _trace(4, compression=CompressionPolicy(kind="qsgd-int8"), **kw)
        off = _trace(
            4,
            compression=CompressionPolicy(kind="qsgd-int8", chain_cap=7),
            **kw,
        )
        assert base[:3] == off[:3]


# -- EF-SGD: error feedback drives the commit-stream bias to zero --------------


def test_error_feedback_unbiases_deterministic_rounding():
    """Deterministic round-half-down quantization repeats the SAME error
    every round on a constant gradient — the running mean of dequantized
    commits keeps a persistent bias.  EF-SGD folds the residual into the
    next commit, so the running mean converges to the true value."""
    pol = CompressionPolicy(kind="qsgd-int8", levels=3)
    rng = np.random.default_rng(3)
    x = {"g": rng.normal(0, 1, (2, 200)).astype(np.float32)}
    T = 64

    plain_sum = np.zeros_like(x["g"])
    for _ in range(T):
        qd = comp.quantize_delta(x, pol)  # key=None: round-half-down
        plain_sum += qd.dequantize()["g"]
    plain_bias = np.abs(plain_sum / T - x["g"]).mean()

    ef_sum = np.zeros_like(x["g"])
    resid = {"g": np.zeros_like(x["g"])}
    for _ in range(T):
        target = {"g": x["g"] + resid["g"]}
        qd = comp.quantize_delta(target, pol)
        deq = qd.dequantize()["g"]
        resid = {"g": target["g"] - deq}
        ef_sum += deq
    ef_bias = np.abs(ef_sum / T - x["g"]).mean()

    assert plain_bias > 1e-3          # the coarse lattice really does drift
    assert ef_bias < 0.1 * plain_bias  # EF drives the mean onto the target


# -- signSGD / top-k: first-class kinds priced through the commit path ---------


@pytest.mark.parametrize("pol", [
    CompressionPolicy(kind="signsgd"),
    CompressionPolicy(kind="topk", topk_frac=0.02),
])
def test_wire_model_matches_real_delta_nbytes(pol):
    rng = np.random.default_rng(0)
    delta = {"a": rng.normal(0, 1, (37, 19)).astype(np.float32),
             "b": rng.normal(0, 1, (111,)).astype(np.float32)}
    n = sum(v.size for v in delta.values())
    qd = comp.quantize_delta(delta, pol, comp.commit_key(pol, 0, 0))
    assert qd.nbytes == pol.wire_bytes(4.0 * n)
    # the scheduler prices commits at exactly this closed form
    sys_, handles = _build_handles(2)
    sched = AsyncBufferScheduler(
        sys_, handles, model_bytes=4.0 * n, app_compression=pol
    )
    assert sched._commit_bytes[0] == pol.wire_bytes(4.0 * n)
    assert qd.nbytes < 0.3 * 4.0 * n  # both kinds beat dense int8


def test_signsgd_scale_ignores_padding():
    pol = CompressionPolicy(kind="signsgd", chunk=8)
    delta = {"a": np.asarray([1.0, -1.0, 1.0], np.float32)}  # 3 of 8 slots
    qd = comp.quantize_delta(delta, pol)
    # mean |x| over the REAL 3 elements, not the 8-slot padded row
    assert qd.scale[0, 0] == pytest.approx(1.0)
    np.testing.assert_array_equal(
        qd.dequantize()["a"], np.asarray([1.0, -1.0, 1.0], np.float32)
    )


def test_trained_signsgd_and_topk_converge_finite():
    from benchmarks.common import build_system
    from repro import data as data_mod
    from repro.fl import async_engine, rounds

    def train(pol):
        sys_, nodes, rng = build_system(n_nodes=60, zones=3, seed=0)
        x, y = data_mod.synthetic_classification(4 * 24, 16, 4, seed=7)
        parts = data_mod.dirichlet_partition(y, 4, alpha=1.0, seed=8)
        ws = [int(n) for n in rng.choice(nodes, size=4, replace=False)]
        app = rounds.make_app(
            sys_, "sk", workers=ws,
            data_by_worker={n: (x[parts[i]], y[parts[i]]) for i, n in enumerate(ws)},
            dim=16, num_classes=4, local_steps=2, lr=0.2, seed=0,
        )
        return async_engine.run_async(
            sys_, [app], applies=4, buffer_k=3, model_bytes=2e5,
            compute_ms=10.0, compression=pol,
        )

    for kind in ("signsgd", "topk"):
        out = train(CompressionPolicy(kind=kind, topk_frac=0.05))
        losses = [r["loss"] for r in out["history"]]
        assert all(np.isfinite(losses))
        assert losses[-1] < losses[0] * 2.0  # no blow-up on the tiny fixture

    ef = train(CompressionPolicy(kind="qsgd-int8", levels=7, error_feedback=True))
    assert all(np.isfinite([r["loss"] for r in ef["history"]]))
    assert any(len(d) for d in ef["trainer"]._ef)  # residuals really carried
