"""Hot-path overhaul: exactness + boundedness of the optimized engines.

Covers ISSUE 5:
 - incremental repricing (UplinkState) is exact: byte-identical event
   traces vs the legacy full-water-filling engine, bit-identical rates
   on the uncapped fast path, allclose + identical binding sets on caps;
 - EventCore.cancel no longer leaks dead heap entries for the run:
   lazy-deletion compaction keeps the heap bounded under churn-heavy
   cancellation (the satellite regression);
 - numpy-resident transfer pricing == the jitted CongestionEnv lookup;
 - compiled-vs-interpret kernel parity (tree_aggregate_groups,
   buffered_aggregate, fused_update) on ragged / 1-sample shapes;
 - megabatched + bucketed training matches the exact-shape engine and
   the per-worker reference on ragged/1-sample shards; recompile count
   per run is O(#buckets), asserted via the jit cache-miss counter and
   cross-checked against jax's own jit cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import data as data_mod
from repro.core.api import TotoroSystem
from repro.core.congestion import CongestionEnv, UplinkState, fair_share_rates
from repro.core.sim import AsyncBufferScheduler, ChurnModel
from repro.fl import async_engine, engine, rounds
from repro.kernels import ops as kops
from repro.kernels import ref


# ---------------------------------------------------------------------------
# helpers


def build_multi_app(m=3, workers=6, n_nodes=120, seed=0, shard=20):
    sys_ = TotoroSystem(zone_bits=2, suffix_bits=20, seed=seed)
    rng = np.random.default_rng(seed)
    nodes = [
        sys_.Join("n", i, site=i % 4, coord=rng.uniform(0, 50, 2))
        for i in range(n_nodes)
    ]
    apps = []
    for a in range(m):
        x, y = data_mod.synthetic_classification(workers * shard, 16, 4, seed=100 + a)
        parts = data_mod.dirichlet_partition(y, workers, alpha=0.5, seed=200 + a)
        ws = [int(n) for n in rng.choice(nodes, size=workers, replace=False)]
        apps.append(
            rounds.make_app(
                sys_, f"hot-{a}", workers=ws,
                data_by_worker={n: (x[parts[i]], y[parts[i]]) for i, n in enumerate(ws)},
                dim=16, num_classes=4, local_steps=2, lr=0.2, seed=a,
            )
        )
    return sys_, apps


@pytest.fixture
def kernel_mode_guard():
    prev = kops.kernel_mode()
    yield
    kops.set_kernel_mode(prev)


# ---------------------------------------------------------------------------
# incremental repricing: exactness


def test_uplink_state_uncapped_bit_identical_to_water_filling():
    rng = np.random.default_rng(0)
    for trial in range(25):
        n = int(rng.integers(1, 12))
        weights = rng.uniform(0.1, 4.0, n)
        groups = rng.integers(0, 3, n)
        st = UplinkState(73.5)
        for fid in range(n):
            st.add(fid, float(weights[fid]), None, ("grp", int(groups[fid])))
        gn = {g: int((groups == g).sum()) for g in set(groups.tolist())}
        expect = fair_share_rates(
            73.5, [float(weights[i]) / gn[int(groups[i])] for i in range(n)]
        )
        assert st.rates() == expect  # bit-for-bit, not just close


def test_uplink_state_capped_matches_progressive_water_filling():
    rng = np.random.default_rng(1)
    for trial in range(40):
        n = int(rng.integers(1, 10))
        weights = rng.uniform(0.1, 4.0, n)
        caps = [
            None if rng.random() < 0.4 else float(rng.uniform(0.5, 30.0))
            for _ in range(n)
        ]
        groups = rng.integers(0, 3, n)
        st = UplinkState(50.0)
        for fid in range(n):
            st.add(fid, float(weights[fid]), caps[fid], ("grp", int(groups[fid])))
        gn = {g: int((groups == g).sum()) for g in set(groups.tolist())}
        expect = fair_share_rates(
            50.0,
            [float(weights[i]) / gn[int(groups[i])] for i in range(n)],
            [None if caps[i] is None else caps[i] / gn[int(groups[i])] for i in range(n)],
        )
        got = st.rates()
        np.testing.assert_allclose(got, expect, rtol=1e-9, atol=1e-9)
        # conservation: never allocate above capacity
        assert sum(got) <= 50.0 * (1 + 1e-9)


def test_uplink_state_add_remove_keeps_order_and_counts():
    st = UplinkState(100.0)
    for fid in range(6):
        st.add(fid, 1.0 + fid, 10.0 * (fid + 1) if fid % 2 else None, ("grp", fid % 2))
    st.remove(3)
    st.remove(0)
    assert len(st) == 4
    # remaining flows keep insertion order (1, 2, 4, 5)
    assert list(st._flows) == [1, 2, 4, 5]
    st2 = UplinkState(100.0)
    for fid in (1, 2, 4, 5):
        st2.add(fid, 1.0 + fid, 10.0 * (fid + 1) if fid % 2 else None, ("grp", fid % 2))
    assert st.rates() == st2.rates()


def test_incremental_trace_byte_identical_with_churn():
    """The tentpole exactness gate, in miniature: same apply events, same
    churn log, same defer/fairness telemetry, both repricing engines."""
    results = []
    for incremental in (False, True):
        sys_, apps = build_multi_app(seed=3)
        churn = ChurnModel(period_ms=90.0, downtime_ms=300.0, group_size=2, seed=5)
        sched = AsyncBufferScheduler(
            sys_, [a.handle for a in apps], model_bytes=1.5e5,
            compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=1),
            buffer_k=3, churn=churn, incremental=incremental,
        )
        events = sched.run(6)
        results.append((events, list(sched.churn_log), sched.transport_stats()))
    (ev_a, churn_a, tp_a), (ev_b, churn_b, tp_b) = results
    assert ev_a == ev_b  # exact dataclass equality incl. float timestamps
    assert churn_a == churn_b
    assert tp_a == tp_b


def test_incremental_trace_identical_with_caps_weights_admission():
    from repro.core.sim import RelayAdmission

    results = []
    for incremental in (False, True):
        sys_, apps = build_multi_app(seed=7, m=3)
        sched = AsyncBufferScheduler(
            sys_, [a.handle for a in apps], model_bytes=2e5,
            compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=2),
            buffer_k=3,
            app_weights=[2.0, 1.0, 1.0],
            app_rate_caps=[None, 40.0, 25.0],
            relay_admission=RelayAdmission(threshold=0.6, alpha=0.8),
            incremental=incremental,
        )
        events = sched.run(5)
        results.append((events, list(sched.defer_log)))
    assert results[0] == results[1]


# ---------------------------------------------------------------------------
# heap compaction (satellite regression)


def test_cancel_compacts_dead_heap_entries():
    sys_, apps = build_multi_app(m=1, workers=2, n_nodes=40)
    core = AsyncBufferScheduler(
        sys_, [a.handle for a in apps], model_bytes=1e5, buffer_k=1
    )
    core._reset_clock()
    seqs = [core.schedule(1000.0 + i, lambda t: None) for i in range(500)]
    for s in seqs[:-1]:
        core.cancel(s)
    # lazy deletion is bounded: dead entries can never exceed the live
    # ones by more than the compaction threshold
    assert len(core._heap) < 200
    assert core._dead * 2 <= len(core._heap) or core._dead <= 64
    # double-cancel must not double-count
    before = core._dead
    core.cancel(seqs[0])
    assert core._dead == before


def test_churn_heavy_run_keeps_heap_bounded():
    """Churn cancels in-flight cycles every period; with per-flow events
    and no compaction the heap grew monotonically with every reprice.
    Bound: peak heap stays within a small multiple of live entities."""
    sys_, apps = build_multi_app(m=4, workers=6, seed=11)
    churn = ChurnModel(
        period_ms=60.0, downtime_ms=200.0, group_size=3, seed=2,
        max_fail_events=60,
    )
    sched = AsyncBufferScheduler(
        sys_, [a.handle for a in apps], model_bytes=2e5,
        compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=3),
        buffer_k=3, churn=churn,
    )
    sched.run(12)
    live_entities = 4 * 6 + 4  # worker cycles + per-app bookkeeping
    assert sched.heap_max <= 8 * live_entities
    assert sched.events_dispatched > 0


def test_compaction_preserves_event_order():
    sys_, apps = build_multi_app(m=1, workers=2, n_nodes=40)
    core = AsyncBufferScheduler(
        sys_, [a.handle for a in apps], model_bytes=1e5, buffer_k=1
    )
    core._reset_clock()
    fired = []
    keep = []
    for i in range(300):
        seq = core.schedule(float(300 - i), lambda t, i=i: fired.append((t, i)))
        if i % 7:
            core.cancel(seq)
        else:
            keep.append((float(300 - i), i))
    core.run_events()
    assert fired == sorted(keep)


# ---------------------------------------------------------------------------
# numpy transfer pricing == jitted congestion lookup


def test_transfer_ms_matches_jitted_latency():
    sys_, apps = build_multi_app(m=2, workers=5, seed=13)
    core = AsyncBufferScheduler(
        sys_, [a.handle for a in apps], model_bytes=3e5, buffer_k=2
    )
    rng = np.random.default_rng(0)
    n = len(core._cap_f32)
    for trial in range(10):
        own = rng.integers(0, n, size=rng.integers(1, 9)).astype(np.int32)
        extra = rng.integers(0, n, size=rng.integers(0, 9)).astype(np.int32)
        core._active = {0: extra} if len(extra) else {}
        actions = np.concatenate([own, extra]) if len(extra) else own
        lat = np.asarray(core.env.latency_ms(jnp.asarray(actions)))[: len(own)]
        assert core.transfer_ms(own, reduce="max") == float(lat.max())
        assert core.transfer_ms(own, reduce="sum") == float(lat.sum())
    core._active = {}


# ---------------------------------------------------------------------------
# compiled-vs-interpret kernel parity (ragged / 1-sample shapes)


@pytest.mark.parametrize("G,C,L", [(1, 1, 17), (3, 1, 1024), (5, 7, 333), (2, 9, 2048)])
def test_tree_aggregate_groups_parity_modes(kernel_mode_guard, G, C, L):
    key = jax.random.key(G * 1000 + C * 100 + L)
    g = jax.random.normal(key, (G, C, L))
    w = jax.random.uniform(jax.random.fold_in(key, 1), (G, C))
    outs = {}
    for mode in ("jnp", "pallas"):
        kops.set_kernel_mode(mode)
        outs[mode] = np.asarray(kops.tree_aggregate_groups(g, w))
    expect = np.einsum("gc,gcl->gl", np.asarray(w), np.asarray(g))
    np.testing.assert_allclose(outs["jnp"], outs["pallas"], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(outs["jnp"], expect, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("k", [1, 2, 5, 9])
def test_buffered_aggregate_parity_modes(kernel_mode_guard, k):
    rng = np.random.default_rng(k)
    ups = [
        {"a": rng.standard_normal((7, 3)).astype(np.float32),
         "b": rng.standard_normal(11).astype(np.float32)}
        for _ in range(k)
    ]
    w = list(rng.uniform(0.5, 3.0, k))
    s = list(rng.integers(0, 5, k))
    outs = {}
    for mode in ("jnp", "pallas"):
        kops.set_kernel_mode(mode)
        agg, cw = kops.buffered_aggregate(ups, w, s, alpha=0.7)
        outs[mode] = (np.asarray(jnp.concatenate([jnp.ravel(l) for l in jax.tree.leaves(agg)])), np.asarray(cw))
    np.testing.assert_allclose(outs["jnp"][0], outs["pallas"][0], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(outs["jnp"][1], outs["pallas"][1], rtol=1e-6)
    disc = np.asarray(w) * (1.0 + np.asarray(s, float)) ** -0.7
    ref_agg = (np.stack([np.concatenate([u["a"].ravel(), u["b"].ravel()]) for u in ups])
               * disc[:, None]).sum(0) / disc.sum()
    np.testing.assert_allclose(outs["jnp"][0], ref_agg, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("L,dtype", [(1, jnp.float32), (1000, jnp.float32), (2048, jnp.bfloat16)])
def test_fused_update_parity_modes_and_donation(kernel_mode_guard, L, dtype):
    key = jax.random.key(L)
    w = jax.random.normal(key, (L,), dtype)
    g = jax.random.normal(jax.random.fold_in(key, 1), (L,), dtype)
    w0 = jax.random.normal(jax.random.fold_in(key, 2), (L,), dtype)
    expect = np.asarray(ref.fused_update_ref(w, g, w0, 0.05, 0.1, 0.01), np.float32)
    outs = {}
    for mode in ("jnp", "pallas"):
        kops.set_kernel_mode(mode)
        outs[mode] = np.asarray(
            kops.fused_update(w, g, w0, lr=0.05, mu=0.1, wd=0.01), np.float32
        )
    np.testing.assert_allclose(outs["jnp"], expect, rtol=1e-2, atol=1e-2)
    np.testing.assert_allclose(outs["pallas"], expect, rtol=1e-2, atol=1e-2)
    # donation: same result, donated buffer consumed (fallback path)
    kops.set_kernel_mode("jnp")
    w_d = jnp.array(w)  # fresh buffer we are allowed to give up
    out_d = kops.fused_update(w_d, g, w0, lr=0.05, mu=0.1, wd=0.01, donate=True)
    np.testing.assert_allclose(np.asarray(out_d, np.float32), outs["jnp"], rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# megabatched / bucketed training: equivalence + recompile bound


def test_bucketed_training_matches_exact_and_reference_ragged():
    """Ragged shards incl. a 1-sample worker: bucketed (W, B) padding and
    the per-worker-params megabatch both reproduce the exact-shape
    engine and the per-worker reference loop."""
    sys_, apps = build_multi_app(m=1, workers=5, seed=17)
    app = apps[0]
    ws = sorted(app.data)
    # force heavy raggedness: shrink shards to 1..n samples
    for i, w in enumerate(ws):
        x, y = app.data[w]
        n = max(1, min(len(y), 1 + 3 * i))
        app.data[w] = (x[:n], y[:n])
    d_ref, wt_ref, l_ref = engine.local_training(app, ws, vectorized=False)
    d_exact, wt_exact, l_exact = engine.local_training(app, ws, bucketed=False)
    d_buck, wt_buck, l_buck = engine.local_training(app, ws, bucketed=True)
    [(d_mega, wt_mega, l_mega)] = engine.fused_local_training(
        [(app, ws, app.params)]
    )
    assert wt_ref == wt_exact == wt_buck == wt_mega
    np.testing.assert_allclose(l_buck, l_ref, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(l_mega, l_ref, rtol=1e-4, atol=1e-6)
    for variant in (d_exact, d_buck, d_mega):
        for dr, dv in zip(d_ref, variant):
            for a, b in zip(jax.tree.leaves(dr), jax.tree.leaves(dv)):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6
                )


def test_fused_cross_app_training_matches_per_app():
    sys_, apps = build_multi_app(m=3, workers=4, seed=19)
    jobs = [(a, sorted(a.data), a.params) for a in apps]
    fused = engine.fused_local_training(jobs)
    for (app, ws, _), (d_f, wt_f, l_f) in zip(jobs, fused):
        d_e, wt_e, l_e = engine.local_training(app, ws, bucketed=False)
        assert wt_f == wt_e
        np.testing.assert_allclose(l_f, l_e, rtol=1e-4, atol=1e-6)
        for df, de in zip(d_f, d_e):
            for a, b in zip(jax.tree.leaves(df), jax.tree.leaves(de)):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6
                )


def test_fused_training_splits_same_name_different_shape_models():
    """Regression: two apps sharing a model NAME (and steps/lr/mu/feat)
    but differing in num_classes must land in different fusion groups —
    the params signature is part of the key, not just the name."""
    sys_ = TotoroSystem(zone_bits=2, suffix_bits=20, seed=0)
    rng = np.random.default_rng(0)
    nodes = [sys_.Join("n", i, site=i % 4, coord=rng.uniform(0, 50, 2)) for i in range(60)]
    apps = []
    for a, classes in enumerate((4, 10)):
        x, y = data_mod.synthetic_classification(3 * 12, 16, classes, seed=50 + a)
        parts = data_mod.dirichlet_partition(y, 3, alpha=1.0, seed=60 + a)
        ws = [int(n) for n in rng.choice(nodes, size=3, replace=False)]
        apps.append(
            rounds.make_app(
                sys_, f"shapes-{a}", workers=ws,
                data_by_worker={n: (x[parts[i]], y[parts[i]]) for i, n in enumerate(ws)},
                dim=16, num_classes=classes, local_steps=2, lr=0.2, seed=a,
            )
        )
    jobs = [(a, sorted(a.data), a.params) for a in apps]
    fused = engine.fused_local_training(jobs)  # crashed before the fix
    for (app, ws, _), (d_f, wt_f, l_f) in zip(jobs, fused):
        d_e, wt_e, l_e = engine.local_training(app, ws, bucketed=False)
        assert wt_f == wt_e
        np.testing.assert_allclose(l_f, l_e, rtol=1e-4, atol=1e-6)
        for df, de in zip(d_f, d_e):
            for a, b in zip(jax.tree.leaves(df), jax.tree.leaves(de)):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6
                )


def test_run_round_fused_matches_run_round():
    sys_a, apps_a = build_multi_app(m=2, workers=4, seed=23)
    sys_b, apps_b = build_multi_app(m=2, workers=4, seed=23)
    fused = engine.run_round_fused(sys_a, apps_a)
    plain = [engine.run_round(sys_b, app) for app in apps_b]
    assert len(fused) == len(plain)
    for mf, mp, aa, ab in zip(fused, plain, apps_a, apps_b):
        assert mf["round"] == mp["round"]
        assert mf["loss"] == pytest.approx(mp["loss"], rel=1e-5, abs=1e-7)
        assert mf["time_ms"] == pytest.approx(mp["time_ms"])
        for la, lb in zip(jax.tree.leaves(aa.params), jax.tree.leaves(ab.params)):
            np.testing.assert_allclose(
                np.asarray(la), np.asarray(lb), rtol=1e-5, atol=1e-7
            )


def test_async_recompiles_bounded_by_buckets():
    """The jit cache-miss gate: a churny multi-app async run with ragged
    version groups must stay at one fused dispatch per apply and
    O(#buckets) compiles, cross-checked against jax's own jit cache."""
    sys_, apps = build_multi_app(m=3, workers=6, seed=29)
    churn = ChurnModel(period_ms=120.0, downtime_ms=360.0, group_size=2, seed=1)
    engine.DISPATCH.reset()
    cache_before = engine.megabatched_local_train._cache_size()
    res = async_engine.run_async(
        sys_, apps, applies=4, buffer_k=3, staleness_alpha=0.5,
        model_bytes=1.5e5, compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=4),
        churn=churn,
    )
    applies = len(res["history"])
    assert applies >= 3 * 4  # every app completed its applies
    assert engine.DISPATCH.dispatches == applies  # ONE fused dispatch per apply
    # bucket bound: one static config, W in {1..bucket(6)}, B bucketed
    bound = (int(np.log2(8)) + 1) * 4
    assert engine.DISPATCH.compiles <= bound
    cache_delta = engine.megabatched_local_train._cache_size() - cache_before
    assert cache_delta <= engine.DISPATCH.compiles


def test_async_megabatch_matches_legacy_dispatch_loop():
    """Trace + loss equivalence of the fused apply vs the per-version
    dispatch loop (the pre-optimization data plane)."""
    outs = []
    for megabatch in (True, False):
        sys_, apps = build_multi_app(m=2, workers=5, seed=31)
        res = async_engine.run_async(
            sys_, apps, applies=3, buffer_k=3, staleness_alpha=0.5,
            model_bytes=1.5e5,
            compute_ms=async_engine.worker_compute_fn(40.0, 6.0, seed=5),
            megabatch=megabatch,
        )
        outs.append(res)
    assert outs[0]["events"] == outs[1]["events"]
    la = [r["loss"] for r in outs[0]["history"]]
    lb = [r["loss"] for r in outs[1]["history"]]
    np.testing.assert_allclose(la, lb, rtol=1e-5, atol=1e-7)


def test_bench_hotpath_registered():
    from benchmarks.run import REGISTRY

    names = [n for n, _, _ in REGISTRY]
    assert "hotpath(perf)" in names
    mods = [m for _, m, _ in REGISTRY]
    assert "benchmarks.bench_hotpath" in mods
