"""Scale layer: vectorized routing parity, cohort trace identity,
sampled congestion invariants, and the enriched event-budget error.

- ``route_many`` must match the scalar object-API ``route`` (the
  oracle) hop-for-hop — path, hop count, blocked flag, and path
  latency — on random overlays with churn (hypothesis property).
- ``neighborhood_set`` (spatial-grid index) must equal the brute-force
  full-sort result.
- The cohort-batched scheduler in exact mode must reproduce the
  per-event baseline trace byte-for-byte (exact ApplyEvent/ChurnRecord
  equality) at M=16, and ``congestion_mode="sampled"`` with
  ``hot_threshold=0`` must degenerate to the exact trace.
- ``EventCore.run_events`` budget exhaustion must name the clock, heap
  occupancy, per-app progress, and the ``max_events`` knob.
"""
import math

import numpy as np
import pytest

try:  # optional dev dep: the property tests widen to random draws with it
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.nodeid import IdSpace
from repro.core.overlay import MultiRingOverlay

ZONES = 4


def build_overlay(n, seed, churn_frac=0.0):
    space = IdSpace(zone_bits=int(math.log2(ZONES)), suffix_bits=20)
    ov = MultiRingOverlay(space, base_bits=4, seed=seed)
    rng = np.random.default_rng(seed)
    ids = ov.join_many(
        rng.integers(0, ZONES, n), coords=rng.uniform(0, 100, (n, 2))
    )
    if churn_frac > 0:
        for nid in rng.choice(ids, size=int(churn_frac * n), replace=False):
            ov.fail(int(nid))
    return ov, rng


# -- route_many vs the scalar oracle ------------------------------------------


def _check_route_parity(seed, n, churn_frac):
    ov, rng = build_overlay(n, seed, churn_frac)
    nodes = ov.node_array()
    k = 40
    srcs = nodes[rng.integers(0, len(nodes), k)]
    keys = rng.integers(0, 1 << ov.space.total_bits, k)
    batch = ov.route_many(srcs, keys)
    for i in range(k):
        res = ov.route(int(srcs[i]), int(keys[i]))
        assert batch.path(i) == res.path, (i, batch.path(i), res.path)
        assert int(batch.hops[i]) == res.hops
        assert bool(batch.blocked[i]) == res.blocked
        assert batch.latency_ms[i] == pytest.approx(
            ov.path_latency(res.path), rel=1e-9
        )


@pytest.mark.parametrize("seed,n,churn_frac", [
    (0, 50, 0.0), (1, 200, 0.1), (2, 600, 0.25), (3, 333, 0.1),
])
def test_route_many_matches_scalar_oracle(seed, n, churn_frac):
    _check_route_parity(seed, n, churn_frac)


if HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.integers(50, 600),
        churn_frac=st.sampled_from([0.0, 0.1, 0.25]),
    )
    def test_route_many_matches_scalar_oracle_property(seed, n, churn_frac):
        _check_route_parity(seed, n, churn_frac)


def test_route_many_restricted_zone_matches_oracle():
    ov, rng = build_overlay(400, seed=7, churn_frac=0.1)
    nodes = ov.node_array()
    srcs = nodes[rng.integers(0, len(nodes), 60)]
    keys = rng.integers(0, 1 << ov.space.total_bits, 60)
    zone = int(ov.space.zone_of(int(srcs[0])))
    batch = ov.route_many(srcs, keys, restrict_zone=zone)
    for i in range(60):
        res = ov.route(int(srcs[i]), int(keys[i]), restrict_zone=zone)
        assert batch.path(i) == res.path
        assert bool(batch.blocked[i]) == res.blocked


# -- neighborhood grid index vs brute force -----------------------------------


def _check_neighborhood_parity(seed, n, queries=25):
    ov, rng = build_overlay(n, seed, churn_frac=0.1)
    nodes = ov.node_array()
    for nid in nodes[rng.integers(0, len(nodes), queries)]:
        nid = int(nid)
        assert ov.neighborhood_set(nid) == ov.neighborhood_set_bruteforce(nid)


@pytest.mark.parametrize("seed,n", [(0, 30), (1, 120), (2, 500)])
def test_neighborhood_grid_matches_bruteforce(seed, n):
    _check_neighborhood_parity(seed, n)


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(30, 500))
    def test_neighborhood_grid_matches_bruteforce_property(seed, n):
        _check_neighborhood_parity(seed, n)


def test_neighborhood_grid_tracks_join_leave():
    ov, rng = build_overlay(200, seed=3)
    node = int(ov.node_array()[0])
    before = ov.neighborhood_set(node)
    # join a node right on top of the query point: must displace the set
    cx, cy = ov.coords[node]
    new = ov.join_random(0, coord=np.array([cx + 1e-6, cy + 1e-6]))
    assert new in ov.neighborhood_set(node)
    ov.fail(new)
    assert ov.neighborhood_set(node) == before


# -- cohort-batched scheduler: trace identity ---------------------------------


def _timing_run(m_apps, **kw):
    from benchmarks.bench_scale import _timing_run

    return _timing_run(m_apps, **kw)


def test_m16_cohort_trace_identical_to_per_event_baseline():
    kw = dict(applies=2, seed=0)
    base = _timing_run(16, cohort=False, congestion_mode="exact", **kw)
    coh = _timing_run(16, cohort=True, congestion_mode="exact", **kw)
    assert base["events"] == coh["events"]  # exact ApplyEvent equality
    assert base["churn"] == coh["churn"]  # exact ChurnRecord equality
    assert base["events_dispatched"] == coh["events_dispatched"]
    # the cohort heap is strictly smaller: one entry per app cohort
    assert coh["heap_max"] <= base["heap_max"]


def test_sampled_hot_threshold_zero_degenerates_to_exact():
    kw = dict(applies=2, seed=1)
    base = _timing_run(8, cohort=True, congestion_mode="exact", **kw)
    deg = _timing_run(
        8, cohort=True, congestion_mode="sampled", hot_threshold=0, **kw
    )
    assert base["events"] == deg["events"]
    assert base["churn"] == deg["churn"]


def test_sampled_mode_completes_with_fewer_events():
    kw = dict(applies=2, seed=0)
    exact = _timing_run(8, cohort=True, congestion_mode="exact", **kw)
    samp = _timing_run(8, cohort=True, congestion_mode="sampled", **kw)
    assert len(samp["events"]) == len(exact["events"])  # same applies done
    assert samp["events_dispatched"] < exact["events_dispatched"]


def test_congestion_mode_validated():
    from benchmarks.common import build_system
    from repro.core.sim import AsyncBufferScheduler

    sys_a, nodes_a, rng_a = build_system(n_nodes=50, zones=4, seed=0)
    h = sys_a.CreateTree("cm-check")
    sys_a.Subscribe(h.app_id, int(nodes_a[0]))
    with pytest.raises(ValueError, match="congestion_mode"):
        AsyncBufferScheduler(
            sys_a, [h], model_bytes=1e5, congestion_mode="statistical"
        )


# -- enriched event-budget diagnostic -----------------------------------------


def test_run_events_budget_error_names_progress():
    with pytest.raises(RuntimeError) as ei:
        _timing_run(4, cohort=True, congestion_mode="exact", applies=50,
                    seed=0, max_events=200)
    msg = str(ei.value)
    assert "event budget exhausted" in msg
    assert "200 events dispatched" in msg
    assert "clock=" in msg
    assert "live" in msg and "dead" in msg  # heap occupancy
    assert "apps done" in msg and "app0=" in msg  # per-app progress
    assert "max_events" in msg  # points at the knob to raise


def test_bench_scale_registered():
    from benchmarks.run import REGISTRY

    names = [n for n, _, _ in REGISTRY]
    assert "scale(perf)" in names
    mods = [m for _, m, _ in REGISTRY]
    assert "benchmarks.bench_scale" in mods


def test_log_fit_gate_math():
    from benchmarks.bench_scale import log_fit

    curve = [
        {"n": 10 ** e, "mean_hops": 1.0 + 0.25 * math.log2(10 ** e)}
        for e in (3, 4, 5)
    ]
    fit = log_fit(curve)
    assert fit["r2"] > 0.999
    assert fit["slope_per_log2n"] == pytest.approx(0.25, rel=1e-6)


# -- sampled-load resampling knob ---------------------------------------------


def test_resample_requires_sampled_mode_and_positive_values():
    from benchmarks.common import build_system
    from repro.core.sim import AsyncBufferScheduler

    sys_a, nodes_a, _ = build_system(n_nodes=50, zones=4, seed=0)
    h = sys_a.CreateTree("rs-check")
    sys_a.Subscribe(h.app_id, int(nodes_a[0]))
    with pytest.raises(ValueError, match="sampled"):
        AsyncBufferScheduler(
            sys_a, [h], model_bytes=1e5, congestion_mode="exact",
            resample_every=10.0,
        )
    with pytest.raises(ValueError, match="sampled"):
        AsyncBufferScheduler(
            sys_a, [h], model_bytes=1e5, congestion_mode="exact",
            resample_events=100,
        )
    for bad in ({"resample_every": 0.0}, {"resample_events": -5}):
        with pytest.raises(ValueError, match="must be > 0"):
            AsyncBufferScheduler(
                sys_a, [h], model_bytes=1e5, congestion_mode="sampled", **bad
            )


def test_resample_with_hot_threshold_zero_stays_exact():
    """With hot_threshold=0 every cycle is hot (exact), no cold spans
    exist, and the resample timer must be a pure no-op on the trace."""
    kw = dict(applies=2, seed=1)
    base = _timing_run(8, cohort=True, congestion_mode="exact", **kw)
    deg = _timing_run(
        8, cohort=True, congestion_mode="sampled", hot_threshold=0,
        resample_every=25.0, **kw
    )
    assert base["events"] == deg["events"]
    assert base["churn"] == deg["churn"]


def test_resample_timer_fires_and_run_completes():
    kw = dict(applies=2, seed=0)
    frozen = _timing_run(8, cohort=True, congestion_mode="sampled", **kw)
    res = _timing_run(
        8, cohort=True, congestion_mode="sampled", resample_every=40.0, **kw
    )
    assert len(res["events"]) == len(frozen["events"])  # same applies done
    assert res["resamples"] > 0
    assert frozen["resamples"] == 0


def test_resample_event_count_variant():
    kw = dict(applies=2, seed=0)
    res = _timing_run(
        8, cohort=True, congestion_mode="sampled", resample_events=500, **kw
    )
    assert res["resamples"] > 0


# -- forest bootstrap bench gates ---------------------------------------------


def test_forest_bootstrap_identity_and_gate_math():
    from benchmarks.bench_scale import forest_bootstrap, gate, log_fit

    rows = forest_bootstrap([300, 600], m_apps=2, zones=4, seed=0,
                            oracle_max=600, speedup_at=600)
    assert all(r["identical"] for r in rows)
    assert all(r["subscribes_per_sec"] > 0 for r in rows)
    # gate() passes a clean payload and flags a broken identity/speedup
    hops_curve = [
        {"n": 10 ** e, "mean_hops": 1.0 + 0.25 * math.log2(10 ** e),
         "oracle_mismatches": 0}
        for e in (3, 4, 5)
    ]
    depth_curve = [
        {"n": 10 ** e, "mean_depth": 0.8 + 0.24 * math.log2(10 ** e),
         "identical": True, "speedup": 12.0}
        for e in (3, 4, 5)
    ]
    payload = {
        "hops_vs_n": hops_curve,
        "hops_fit": log_fit(hops_curve),
        "forest_vs_n": depth_curve,
        "depth_fit": log_fit(depth_curve, key="mean_depth"),
        "trace_identity": {
            "cohort_identical": True, "sampled_ht0_identical": True,
        },
        "events_vs_m": [],
        "applies_per_app": 2,
    }
    assert gate(payload) == []
    payload["forest_vs_n"][1]["identical"] = False
    assert any("oracle" in f for f in gate(payload))
    payload["forest_vs_n"][1]["identical"] = True
    payload["forest_vs_n"][2]["speedup"] = 1.5  # n=1e5 row: below the gate
    assert any("speedup" in f for f in gate(payload))


def test_paths_flat_matches_per_route_paths():
    ov, rng = build_overlay(300, seed=5, churn_frac=0.1)
    nodes = ov.node_array()
    srcs = nodes[rng.integers(0, len(nodes), 30)]
    keys = rng.integers(0, 1 << ov.space.total_bits, 30)
    batch = ov.route_many(srcs, keys)
    flat, offsets = batch.paths_flat()
    for i in range(30):
        assert flat[offsets[i]:offsets[i + 1]].tolist() == batch.path(i)
