"""Hillclimb driver: run one cell with overrides, print roofline delta."""
import json, subprocess, sys, os

def run(tag, arch, shape, mp=False, agg=None, overrides=None, accum=None):
    code = (
        "import json\n"
        "from repro.launch.dryrun import run_cell\n"
        f"r = run_cell({arch!r}, {shape!r}, multi_pod={mp}, aggregation={agg!r}, quiet=True,\n"
        f"             cfg_overrides={overrides!r}, grad_accum={accum!r})\n"
        "print('RESULT_JSON:' + json.dumps(r))\n"
    )
    env = dict(os.environ); env["PYTHONPATH"] = "src"
    p = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=3000)
    rec = None
    for line in p.stdout.splitlines():
        if line.startswith("RESULT_JSON:"):
            rec = json.loads(line[len("RESULT_JSON:"):])
    if rec is None:
        print(f"{tag}: FAILED\n{p.stderr[-1500:]}"); return None
    rl, c, m = rec["roofline"], rec["cost"], rec["memory"]
    print(f"{tag}: compute={rl['compute_s']:.2f}s memory={rl['memory_s']:.2f}s "
          f"coll={rl['collective_s']:.2f}s bound={rl['bound']} ratio={rl['useful_flops_ratio']:.3f} "
          f"peak={m['peak_bytes_per_dev']/1e9:.1f}GB coll_bytes={rec['collectives']['total_bytes_per_dev']/1e9:.0f}GB")
    with open("results/hillclimb.jsonl", "a") as f:
        rec["tag"] = tag
        f.write(json.dumps(rec) + "\n")
    return rec

if __name__ == "__main__":
    import importlib
    steps = json.loads(sys.argv[1])
    for s in steps:
        run(**s)
