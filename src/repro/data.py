"""Data pipeline: deterministic synthetic streams + non-IID federated partitioning.

Each data-parallel shard (FL client group) derives its own stream from
(seed, shard_id, step) so multi-host loading needs no coordination — the
same recipe a real cluster loader would use with a sharded index.

The synthetic LM stream is a Zipf-ish token model with shard-dependent
class skew so FedAvg-vs-centralized comparisons see genuinely non-IID
clients; ``dirichlet_partition`` reproduces the classic FL non-IID split
for the paper-scale (small-model) benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class StreamConfig:
    vocab_size: int
    seq_len: int
    batch_per_shard: int
    seed: int = 0
    non_iid_alpha: float = 0.0  # >0 => shard-skewed token distribution


def _rng(seed: int, shard: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, shard, step]))


def lm_batch(sc: StreamConfig, shard: int, step: int) -> dict[str, np.ndarray]:
    """One (tokens, labels) batch for a shard.  Deterministic in (seed, shard, step)."""
    rng = _rng(sc.seed, shard, step)
    if sc.non_iid_alpha > 0:
        # shard-specific Zipf tilt: each client group favours a token slice
        base = np.arange(1, sc.vocab_size + 1, dtype=np.float64) ** -1.1
        roll = (shard * 97) % sc.vocab_size
        p = np.roll(base, roll)
        p /= p.sum()
        tokens = rng.choice(sc.vocab_size, size=(sc.batch_per_shard, sc.seq_len + 1), p=p)
    else:
        tokens = rng.integers(0, sc.vocab_size, size=(sc.batch_per_shard, sc.seq_len + 1))
    tokens = tokens.astype(np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def embeds_batch(sc: StreamConfig, d_model: int, shard: int, step: int) -> np.ndarray:
    rng = _rng(sc.seed, shard, step)
    return rng.standard_normal((sc.batch_per_shard, sc.seq_len, d_model)).astype(np.float32) * 0.3


# ---------------------------------------------------------------------------
# learnable synthetic task (for convergence tests / time-to-accuracy benches):
# next token = (a * tok + b) % V with noise — a model can actually learn it.


def learnable_lm_batch(sc: StreamConfig, shard: int, step: int, noise: float = 0.05):
    rng = _rng(sc.seed, shard, step)
    B, S, V = sc.batch_per_shard, sc.seq_len, sc.vocab_size
    a, b = 7, 3
    start = rng.integers(0, V, size=(B, 1))
    seq = [start]
    for _ in range(S):
        nxt = (a * seq[-1] + b) % V
        flip = rng.random((B, 1)) < noise
        nxt = np.where(flip, rng.integers(0, V, size=(B, 1)), nxt)
        seq.append(nxt)
    toks = np.concatenate(seq, axis=1).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


# ---------------------------------------------------------------------------
# classic FL non-IID partition (for small-model paper benchmarks)


def dirichlet_partition(
    labels: np.ndarray,
    num_clients: int,
    alpha: float,
    seed: int = 0,
    min_samples: int = 1,
) -> list[np.ndarray]:
    """Partition sample indices across clients with Dirichlet(alpha) class skew.

    At low ``alpha`` the draw concentrates whole classes on few clients
    and can leave clients with *zero* samples — downstream, an all-empty
    shard turns the engine's masked padding into dead weight-0 workers
    (and callers used to paper over it with bogus fallback indices).
    ``min_samples`` (default 1) guarantees every client at least that
    many samples by deterministically reassigning from the currently
    largest clients (stable index tie-break), preserving the skew
    everywhere else.  ``min_samples=0`` reproduces the raw draw.
    Requires ``len(labels) >= num_clients * min_samples``.
    """
    if min_samples > 0 and len(labels) < num_clients * min_samples:
        raise ValueError(
            f"cannot give {num_clients} clients >= {min_samples} samples "
            f"from {len(labels)} total"
        )
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    idx_by_client: list[list[int]] = [[] for _ in range(num_clients)]
    for c in classes:
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * num_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for client, part in enumerate(np.split(idx, cuts)):
            idx_by_client[client].extend(part.tolist())
    for client in range(num_clients):
        while len(idx_by_client[client]) < min_samples:
            donor = max(
                range(num_clients), key=lambda i: (len(idx_by_client[i]), -i)
            )
            idx_by_client[client].append(idx_by_client[donor].pop())
    return [np.asarray(sorted(v), dtype=np.int64) for v in idx_by_client]


def synthetic_classification(
    n: int, dim: int, num_classes: int, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Linearly-separable-ish synthetic classification set (paper-scale models)."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((num_classes, dim)) * 2.0
    y = rng.integers(0, num_classes, size=n)
    x = centers[y] + rng.standard_normal((n, dim))
    return x.astype(np.float32), y.astype(np.int32)


def global_batch_to_host_arrays(per_shard_batches: list[dict]) -> dict:
    """Stack per-shard batches into the global batch (shard-major order)."""
    keys = per_shard_batches[0].keys()
    return {k: np.concatenate([b[k] for b in per_shard_batches], axis=0) for k in keys}
