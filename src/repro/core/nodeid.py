"""NodeIds: (m+n)-bit ids — m-bit zone prefix, n-bit intra-zone suffix.

Paper §IV-B: NodeId D = P * 2^n + S.  AppIds come from SHA-1 of the
application's textual name (+ creator key + salt), uniformly distributed.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass


@dataclass(frozen=True)
class IdSpace:
    zone_bits: int  # m
    suffix_bits: int  # n

    @property
    def total_bits(self) -> int:
        return self.zone_bits + self.suffix_bits

    @property
    def num_zones(self) -> int:
        return 1 << self.zone_bits

    @property
    def suffix_space(self) -> int:
        return 1 << self.suffix_bits

    def make(self, zone: int, suffix: int) -> int:
        assert 0 <= zone < self.num_zones and 0 <= suffix < self.suffix_space
        return zone * self.suffix_space + suffix

    def zone_of(self, node_id: int) -> int:
        return node_id >> self.suffix_bits

    def suffix_of(self, node_id: int) -> int:
        return node_id & (self.suffix_space - 1)


def sha1_id(text: str, bits: int, salt: str = "") -> int:
    """AppId = hash(app name | creator key | salt), SHA-1 (paper §IV-C)."""
    h = hashlib.sha1((text + "|" + salt).encode()).digest()
    return int.from_bytes(h, "big") % (1 << bits)


def ring_distance(a: int, b: int, space: int) -> int:
    """Clockwise distance a -> b on a ring of size `space`."""
    return (b - a) % space


def abs_ring_distance(a: int, b: int, space: int) -> int:
    d = (b - a) % space
    return min(d, space - d)


def numerically_closest(key: int, ids, space: int) -> int:
    """The id numerically closest to key on the ring (ties -> clockwise)."""
    best, best_d = None, None
    for i in ids:
        d = abs_ring_distance(key, i, space)
        if best_d is None or d < best_d or (d == best_d and ring_distance(key, i, space) <= ring_distance(key, best, space)):
            best, best_d = i, d
    return best
