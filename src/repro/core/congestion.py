"""Congestion-game environment (paper §V-A, Appendix C).

Facilities = next-hop nodes with bandwidth capacities.  When k nodes pick
the same hop, its rate drops to capacity/k (the paper's bandwidth-sharing
model, §VII-E): latency = packet_bits / (capacity/k) + propagation;
reward = 1 - latency / l_max in [0, 1] (Appendix G), times a Bernoulli
link-success draw with mean theta_p.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["capacity", "theta"],
    meta_fields=["packet_mbit", "base_ms", "l_max_ms"],
)
@dataclass(frozen=True)
class CongestionEnv:
    capacity: jax.Array  # (P,) Mbps per hop
    theta: jax.Array  # (P,) link success rate
    packet_mbit: float = 8.0
    base_ms: float = 5.0
    l_max_ms: float = 2000.0

    @property
    def num_paths(self) -> int:
        return int(self.capacity.shape[0])

    def latency_ms(self, actions: jax.Array) -> jax.Array:
        """actions: (N,) hop index per node -> per-node latency (ms)."""
        P = self.num_paths
        counts = jnp.zeros(P, jnp.float32).at[actions].add(1.0)
        n_p = counts[actions]  # congestion each node sees
        rate = self.capacity[actions] / jnp.maximum(n_p, 1.0)  # Mbps
        return self.base_ms + 1e3 * self.packet_mbit / jnp.maximum(rate, 1e-6)

    def rewards(self, actions: jax.Array, key) -> jax.Array:
        lat = self.latency_ms(actions)
        r = jnp.clip(1.0 - lat / self.l_max_ms, 0.0, 1.0)
        ok = jax.random.bernoulli(key, self.theta[actions])
        return r * ok

    def mean_reward(self, path: int, k: int) -> float:
        """r^p(k, theta_p): closed-form mean reward with k users on path."""
        rate = float(self.capacity[path]) / max(k, 1)
        lat = self.base_ms + 1e3 * self.packet_mbit / rate
        return float(np.clip(1.0 - lat / self.l_max_ms, 0.0, 1.0) * self.theta[path])


def fair_share_rates(
    capacity: float, weights, caps=None, *, eps: float = 1e-9
) -> list[float]:
    """Weighted max-min fair allocation of one uplink across its flows.

    Each flow i asks for the weighted share ``capacity * w_i / sum(w)``;
    a flow whose ``caps[i]`` (Mbps rate cap, ``None`` = uncapped) binds
    is frozen at its cap and the freed capacity is re-divided among the
    uncapped flows (progressive water-filling).  With no caps this is
    plain weighted processor sharing; with one flow it returns
    ``[capacity]`` — the uncontended solo rate, unchanged from the
    legacy ``capacity / k`` pricing at k = 1.

    Deterministic, pure host-side numpy-free arithmetic.
    """
    n = len(weights)
    if n == 0:
        return []
    cap_of = [float("inf") if c is None else float(c) for c in (caps or [None] * n)]
    rates = [0.0] * n
    active = list(range(n))
    avail = float(capacity)
    while active and avail > eps:
        wsum = sum(weights[i] for i in active)
        if wsum <= eps:
            break
        share = {i: avail * weights[i] / wsum for i in active}
        bound = [i for i in active if cap_of[i] <= share[i] + eps]
        if not bound:
            for i in active:
                rates[i] = share[i]
            return rates
        for i in bound:
            rates[i] = cap_of[i]
            avail -= cap_of[i]
            active.remove(i)
        avail = max(0.0, avail)
    return rates


class UplinkState:
    """Incremental weighted max-min fair allocator for ONE uplink.

    The legacy path rebuilt everything per flow join/complete: a group-
    count dict, weight/cap lists, then ``fair_share_rates``'s progressive
    relaxation — O(F) dict churn plus O(F x rounds) water-filling (worst
    case O(F^2) when caps bind one at a time).  This structure makes the
    per-event update cheap:

    - membership and per-group flow counts are maintained incrementally
      (``add``/``remove`` are O(log F): a dict insert plus one bisect
      into the capped-flow ladder);
    - capped flows sit in a ladder sorted by their cap-to-weight ratio
      ``cap_i / w_i`` — invariant under group-count changes, since group
      splitting divides cap and weight alike — so ``rates()`` resolves
      the water-filling level with ONE ascending walk over the ladder
      (O(#capped)) instead of progressive relaxation over all flows;
    - the uncapped fast path (no ladder entries — the common case) is a
      single pass, **bit-for-bit identical** to ``fair_share_rates``:
      same sequential weight sum in flow-insertion order, same
      ``capacity * w_i / wsum`` division.  That exactness is what lets
      the incremental event engine keep byte-identical traces
      (bench_hotpath's gate).  The weight sum is deliberately re-summed
      per call (O(F) float adds on a list walk — cheap) rather than
      maintained by +=/-=: float addition is not associative, and an
      incrementally drifted sum would break trace identity.

    Flows in one ``group`` split a single weight share and cap equally
    (per-app fairness), exactly as the legacy engine computed it.
    """

    __slots__ = ("capacity", "_flows", "_group_n", "_ladder")

    def __init__(self, capacity: float):
        self.capacity = float(capacity)
        # fid -> (weight, cap, group); dict preserves insertion order,
        # which IS the legacy flow order (list append order)
        self._flows: dict[int, tuple[float, float | None, object]] = {}
        self._group_n: dict = {}
        self._ladder: list[tuple[float, int]] = []  # (cap/weight, fid) ascending

    def __len__(self) -> int:
        return len(self._flows)

    def add(self, fid: int, weight: float, cap: float | None, group) -> None:
        self._flows[fid] = (float(weight), cap, group)
        self._group_n[group] = self._group_n.get(group, 0) + 1
        if cap is not None:
            bisect.insort(self._ladder, (float(cap) / float(weight), fid))

    def remove(self, fid: int) -> None:
        weight, cap, group = self._flows.pop(fid)
        n = self._group_n[group] - 1
        if n:
            self._group_n[group] = n
        else:
            del self._group_n[group]
        if cap is not None:
            i = bisect.bisect_left(self._ladder, (cap / weight, fid))
            while self._ladder[i][1] != fid:  # equal ratios: scan the tie run
                i += 1
            self._ladder.pop(i)

    def rates(self, *, eps: float = 1e-9) -> list[float]:
        """Fair rates for every flow, in insertion (fid-arrival) order."""
        if not self._flows:
            return []
        gn = self._group_n
        if not self._ladder:
            # uncapped fast path: identical arithmetic to fair_share_rates
            weights = [w / gn[g] for w, _, g in self._flows.values()]
            wsum = sum(weights)
            if wsum <= eps:
                return [0.0] * len(weights)
            return [self.capacity * w / wsum for w in weights]
        # capped path: walk the ladder ascending to find the binding set.
        # A flow is capped iff its ratio cap_i/w_i (group-invariant) lies
        # at or below the final water level avail/wsum_uncapped; walking
        # in ascending ratio order caps flows exactly in the order the
        # progressive relaxation would freeze them.
        weights = {fid: w / gn[g] for fid, (w, _, g) in self._flows.items()}
        wsum = sum(weights.values())
        avail = self.capacity
        capped: dict[int, float] = {}
        for ratio, fid in self._ladder:
            if wsum <= eps or avail <= eps:
                break
            w, cap, g = self._flows[fid]
            cap_eff = cap / gn[g]
            if cap_eff <= avail * weights[fid] / wsum + eps:
                capped[fid] = cap_eff
                avail = max(0.0, avail - cap_eff)
                wsum -= weights[fid]
            else:
                break  # ladder is sorted: no later flow can bind either
        out = []
        for fid, (w, _, g) in self._flows.items():
            if fid in capped:
                out.append(capped[fid])
            elif wsum <= eps or avail <= eps:
                out.append(0.0)
            else:
                out.append(avail * weights[fid] / wsum)
        return out


def make_env(num_paths: int, *, seed: int = 0, bw_range=(20.0, 100.0), theta_range=(0.9, 1.0)) -> CongestionEnv:
    rng = np.random.default_rng(seed)
    return CongestionEnv(
        capacity=jnp.asarray(rng.uniform(*bw_range, size=num_paths), jnp.float32),
        theta=jnp.asarray(rng.uniform(*theta_range, size=num_paths), jnp.float32),
    )
