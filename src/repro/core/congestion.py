"""Congestion-game environment (paper §V-A, Appendix C).

Facilities = next-hop nodes with bandwidth capacities.  When k nodes pick
the same hop, its rate drops to capacity/k (the paper's bandwidth-sharing
model, §VII-E): latency = packet_bits / (capacity/k) + propagation;
reward = 1 - latency / l_max in [0, 1] (Appendix G), times a Bernoulli
link-success draw with mean theta_p.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["capacity", "theta"],
    meta_fields=["packet_mbit", "base_ms", "l_max_ms"],
)
@dataclass(frozen=True)
class CongestionEnv:
    capacity: jax.Array  # (P,) Mbps per hop
    theta: jax.Array  # (P,) link success rate
    packet_mbit: float = 8.0
    base_ms: float = 5.0
    l_max_ms: float = 2000.0

    @property
    def num_paths(self) -> int:
        return int(self.capacity.shape[0])

    def latency_ms(self, actions: jax.Array) -> jax.Array:
        """actions: (N,) hop index per node -> per-node latency (ms)."""
        P = self.num_paths
        counts = jnp.zeros(P, jnp.float32).at[actions].add(1.0)
        n_p = counts[actions]  # congestion each node sees
        rate = self.capacity[actions] / jnp.maximum(n_p, 1.0)  # Mbps
        return self.base_ms + 1e3 * self.packet_mbit / jnp.maximum(rate, 1e-6)

    def rewards(self, actions: jax.Array, key) -> jax.Array:
        lat = self.latency_ms(actions)
        r = jnp.clip(1.0 - lat / self.l_max_ms, 0.0, 1.0)
        ok = jax.random.bernoulli(key, self.theta[actions])
        return r * ok

    def mean_reward(self, path: int, k: int) -> float:
        """r^p(k, theta_p): closed-form mean reward with k users on path."""
        rate = float(self.capacity[path]) / max(k, 1)
        lat = self.base_ms + 1e3 * self.packet_mbit / rate
        return float(np.clip(1.0 - lat / self.l_max_ms, 0.0, 1.0) * self.theta[path])


def fair_share_rates(
    capacity: float, weights, caps=None, *, eps: float = 1e-9
) -> list[float]:
    """Weighted max-min fair allocation of one uplink across its flows.

    Each flow i asks for the weighted share ``capacity * w_i / sum(w)``;
    a flow whose ``caps[i]`` (Mbps rate cap, ``None`` = uncapped) binds
    is frozen at its cap and the freed capacity is re-divided among the
    uncapped flows (progressive water-filling).  With no caps this is
    plain weighted processor sharing; with one flow it returns
    ``[capacity]`` — the uncontended solo rate, unchanged from the
    legacy ``capacity / k`` pricing at k = 1.

    Deterministic, pure host-side numpy-free arithmetic.
    """
    n = len(weights)
    if n == 0:
        return []
    cap_of = [float("inf") if c is None else float(c) for c in (caps or [None] * n)]
    rates = [0.0] * n
    active = list(range(n))
    avail = float(capacity)
    while active and avail > eps:
        wsum = sum(weights[i] for i in active)
        if wsum <= eps:
            break
        share = {i: avail * weights[i] / wsum for i in active}
        bound = [i for i in active if cap_of[i] <= share[i] + eps]
        if not bound:
            for i in active:
                rates[i] = share[i]
            return rates
        for i in bound:
            rates[i] = cap_of[i]
            avail -= cap_of[i]
            active.remove(i)
        avail = max(0.0, avail)
    return rates


def make_env(num_paths: int, *, seed: int = 0, bw_range=(20.0, 100.0), theta_range=(0.9, 1.0)) -> CongestionEnv:
    rng = np.random.default_rng(seed)
    return CongestionEnv(
        capacity=jnp.asarray(rng.uniform(*bw_range, size=num_paths), jnp.float32),
        theta=jnp.asarray(rng.uniform(*theta_range, size=num_paths), jnp.float32),
    )
