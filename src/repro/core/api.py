"""Layer 3: the Totoro+ high-level API (paper Table II).

``TotoroSystem`` wires the multi-ring overlay, the pub/sub forest, the
game-theoretic planner and failure recovery behind the paper's verbs:
Join / CreateTree / Subscribe / Unsubscribe / Broadcast / Aggregate +
onBroadcast / onAggregate / onTimer callbacks.  Application-level
customization hooks: selection_fn (client admission on JOIN),
compress_fn / decompress_fn (Broadcast/Aggregate payloads, e.g. QSGD),
aggregate_fn (FedAvg/FedProx/...), privacy_fn (e.g. DP noise).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from . import recovery as recovery_mod
from .forest import DataflowTree, Forest
from .nodeid import IdSpace
from .overlay import MultiRingOverlay


@dataclass(frozen=True)
class BufferedDelta:
    """One committed worker update waiting in the master's buffer."""

    worker: int
    delta: Any
    weight: float
    staleness: int


@dataclass
class AppHandle:
    app_id: int
    name: str
    tree: DataflowTree
    selection_fn: Callable[[int], bool] | None = None
    compress_fn: Callable | None = None
    decompress_fn: Callable | None = None
    aggregate_fn: Callable | None = None
    privacy_fn: Callable | None = None
    on_broadcast: Callable | None = None
    on_aggregate: Callable | None = None
    on_timer: Callable | None = None
    round_num: int = 0
    traffic_bytes: float = 0.0
    version: int = 0  # bumped by ApplyBuffered (async model version)
    # weighted-fair transport knobs (read by AsyncBufferScheduler):
    # the app's share of a contended uplink is proportional to
    # transfer_weight, and rate_cap_mbps bounds the app's AGGREGATE
    # rate on any single uplink (concurrent same-uplink flows split
    # both the share and the cap); both must be > 0
    transfer_weight: float = 1.0
    rate_cap_mbps: float | None = None
    # commit-direction compression policy (fl/compression.CompressionPolicy
    # or None): the async trainer quantizes delta uploads under it and the
    # scheduler prices commit flows at policy.wire_bytes(model_bytes)
    compression: Any | None = None
    buffer: list[BufferedDelta] = field(default_factory=list)
    # per-apply telemetry appended by ApplyBuffered: version, arrivals,
    # effective K, staleness histogram, selector utility scores
    round_records: list[dict] = field(default_factory=list)


class TotoroSystem:
    def __init__(
        self,
        *,
        zone_bits: int = 4,
        suffix_bits: int = 32,
        base_bits: int = 4,
        replicas: int = 2,
        seed: int = 0,
    ):
        self.space = IdSpace(zone_bits, suffix_bits)
        self.overlay = MultiRingOverlay(self.space, base_bits=base_bits, seed=seed)
        self.forest = Forest(self.overlay)
        self.replicas = recovery_mod.ReplicaStore(k=replicas)
        self.apps: dict[int, AppHandle] = {}

    # -- Table II verbs -------------------------------------------------------

    def Join(self, ip: str, port: int, site: int, *, coord=(0.0, 0.0), bandwidth=100.0) -> int:
        """Edge node joins the DHT-based P2P overlay network."""
        del ip, port  # transport is simulated; identity = NodeId
        return self.overlay.join_random(site % self.space.num_zones, coord, bandwidth)

    def CreateTree(self, app_name: str, *, restrict_zone=None, fanout_bits=None, **hooks) -> AppHandle:
        """Application owner creates a dataflow tree (+ configures hooks).
        ``fanout_bits`` is per-tree: it changes only this app's JOIN
        routing (digit base 2^b), never the shared overlay tables."""
        tree = self.forest.create_tree(
            app_name, restrict_zone=restrict_zone, fanout_bits=fanout_bits
        )
        h = AppHandle(app_id=tree.app_id, name=app_name, tree=tree, **hooks)
        self.apps[tree.app_id] = h
        return h

    def Subscribe(self, app_id: int, node: int) -> bool:
        """JOIN a dataflow tree; the owner's selection_fn can reject."""
        h = self.apps[app_id]
        if h.selection_fn is not None and not h.selection_fn(node):
            return False
        self.forest.subscribe(app_id, node)
        return True

    def SubscribeMany(self, app_id: int, nodes) -> list[int]:
        """Bulk JOIN: admit through the owner's selection_fn, then graft
        all accepted workers in one vectorized batch
        (``Forest.subscribe_many`` — tree identical to a ``Subscribe``
        loop).  Returns the admitted node ids in input order."""
        h = self.apps[app_id]
        accepted = [int(n) for n in nodes]
        if h.selection_fn is not None:
            accepted = [n for n in accepted if h.selection_fn(n)]
        if accepted:
            self.forest.subscribe_many(app_id, accepted)
        return accepted

    def Unsubscribe(self, app_id: int, node: int) -> None:
        self.forest.unsubscribe(app_id, node)

    def UnsubscribeMany(self, app_id: int, nodes) -> None:
        """Bulk LEAVE (mass-leave / zone-outage repair): splice leaving
        relays' children to their grandparents and prune dead chains in
        one vectorized fixpoint (``Forest.unsubscribe_many`` — tree
        identical to an ``unsubscribe_one`` loop)."""
        self.forest.unsubscribe_many(app_id, nodes)

    def Regraft(self, app_id: int, moves, *, strict: bool = True) -> list[tuple[int, int]]:
        """Batched placement re-graft: move each ``(node, new_parent)``
        subtree (``Forest.regraft_many`` — tree identical to a
        ``regraft`` loop).  The live ``PlacementEngine`` applies its
        decisions through this verb's forest path.  Returns the applied
        pairs."""
        return self.forest.regraft_many(app_id, moves, strict=strict)

    def Broadcast(self, app_id: int, obj: Any) -> dict:
        """Master disseminates a model (or AppIds) down the tree."""
        h = self.apps[app_id]
        payload = h.compress_fn(obj) if h.compress_fn else obj
        nbytes = _nbytes(payload)
        tree = h.tree
        n_edges = len(tree.parent)
        h.traffic_bytes += nbytes * n_edges
        time_ms = tree.broadcast_time(self.overlay, payload_ms=0.0)
        if h.on_broadcast:
            received = h.decompress_fn(payload) if h.decompress_fn else payload
            for w in sorted(tree.members):
                h.on_broadcast(app_id, w, received)
        return {"time_ms": time_ms, "bytes": nbytes * n_edges, "edges": n_edges}

    def Aggregate(
        self,
        app_id: int,
        objects: dict[int, Any],
        weights=None,
        *,
        hierarchical: bool = True,
        use_kernel: bool = True,
    ) -> dict:
        """Aggregate worker updates up the tree, level-by-level.

        The default path executes the dataflow tree's aggregation schedule
        bottom-up: each level is one batched ``tree_aggregate`` Pallas
        kernel call combining every (parent, children) group, so traffic
        and latency metrics follow the tree hop-by-hop and the computed
        result is the hierarchy's (it matches the flat weighted mean).
        A custom ``aggregate_fn`` hook (or ``hierarchical=False``) falls
        back to the flat reference reduction.
        """
        h = self.apps[app_id]
        tree = h.tree
        weights = weights or {n: 1.0 for n in objects}
        payload = objects
        if h.privacy_fn:
            payload = {n: h.privacy_fn(v) for n, v in payload.items()}

        if h.aggregate_fn is not None or not hierarchical or not payload:
            agg_fn = h.aggregate_fn or _weighted_mean
            result = agg_fn(list(payload.values()), [weights[n] for n in payload])
            nbytes = sum(_nbytes(v) for v in payload.values())
            time_ms = tree.aggregation_time(self.overlay)
            levels: list[dict] = []
        else:
            result, levels = _aggregate_hierarchical(
                self.overlay, tree, payload, weights, use_kernel=use_kernel
            )
            nbytes = sum(lv["bytes"] for lv in levels)
            time_ms = sum(lv["time_ms"] for lv in levels)
        h.traffic_bytes += nbytes
        if h.on_aggregate:
            h.on_aggregate(app_id, result)
        return {"time_ms": time_ms, "bytes": nbytes, "result": result, "levels": levels}

    # -- async buffered verbs (FedBuff-style execution path) -------------------

    def CommitDelta(self, app_id: int, worker: int, delta: Any, *, weight: float = 1.0, staleness: int = 0) -> dict:
        """A worker commits its local update to the master's buffer.

        The delta travels the worker's tree path hop-by-hop (per-edge
        traffic, store-and-forward latency); privacy/compression hooks
        apply exactly as on the synchronous Aggregate path.  Staleness is
        recorded per commit — the weight discount happens at apply time
        so one ``ApplyBuffered`` policy governs the whole buffer.
        """
        h = self.apps[app_id]
        payload = delta
        if h.privacy_fn:
            payload = h.privacy_fn(payload)
        wire = h.compress_fn(payload) if h.compress_fn else payload
        nbytes = _nbytes(wire)
        tree = h.tree
        if worker == tree.root or worker not in tree.parent:
            path = [worker]
        else:
            path = tree.path_to_root(worker)
        n_edges = len(path) - 1
        time_ms = self.overlay.path_latency(path)
        h.traffic_bytes += nbytes * n_edges
        received = h.decompress_fn(wire) if h.decompress_fn else payload
        h.buffer.append(
            BufferedDelta(worker=worker, delta=received, weight=float(weight), staleness=int(staleness))
        )
        return {
            "time_ms": time_ms,
            "bytes": nbytes * n_edges,
            "edges": n_edges,
            "buffered": len(h.buffer),
        }

    def ApplyBuffered(
        self,
        app_id: int,
        *,
        staleness_alpha: float = 0.5,
        min_k: int = 1,
        k: int | None = None,
        selector_scores: dict | None = None,
        transport: dict | None = None,
    ) -> dict:
        """Drain the buffer into one staleness-weighted aggregate.

        Weights ``w_i / (1 + staleness_i)^alpha`` are folded into the
        ``tree_aggregate_groups`` kernel's weight vector
        (``kernels.ops.buffered_aggregate``), so with alpha = 0 and a
        full uniform-staleness buffer the result is exactly the
        synchronous FedAvg weighted mean.  Returns ``result=None`` when
        fewer than ``min_k`` commits are buffered (buffer untouched).

        ``k`` (the scheduler's effective buffer threshold for this
        apply), ``selector_scores`` (per-client utilities) and
        ``transport`` (the scheduler's fairness snapshot: per-app uplink
        bytes/throughput + Jain's index) are optional caller telemetry;
        every successful apply appends a record — version, arrivals, K,
        staleness histogram, scores, transport — to the handle's
        ``round_records``.
        """
        from repro.fl.compression import QuantizedDelta
        from repro.kernels.ops import buffered_aggregate, buffered_aggregate_quantized
        from repro.kernels.tree_aggregate import staleness_weights

        h = self.apps[app_id]
        if len(h.buffer) < max(1, min_k):
            return {"result": None, "arrivals": len(h.buffer), "version": h.version}
        entries, h.buffer = h.buffer, []
        quantized = [isinstance(e.delta, QuantizedDelta) for e in entries]
        if any(quantized) and not all(quantized):
            raise ValueError(
                "ApplyBuffered: mixed quantized and raw deltas in one buffer "
                "— an app's CompressionPolicy must cover every commit"
            )
        if h.aggregate_fn is not None:
            # custom aggregators see plain pytrees: dequantize up front
            # (the fused scale/staleness composition below only applies
            # to the built-in kernel path)
            deltas = [e.delta.dequantize() if q else e.delta
                      for e, q in zip(entries, quantized)]
            result = h.aggregate_fn(
                deltas,
                list(staleness_weights(
                    np.asarray([e.weight for e in entries], np.float64),
                    np.asarray([e.staleness for e in entries], np.float64),
                    staleness_alpha,
                )),
            )
            combined = None
        elif all(quantized) and entries:
            # dequantize INSIDE the aggregation: per-row scales compose
            # with the staleness discount in one kernel call
            flat, combined = buffered_aggregate_quantized(
                [e.delta.q for e in entries],
                [e.delta.scale for e in entries],
                [e.weight for e in entries],
                [e.staleness for e in entries],
                alpha=staleness_alpha,
            )
            result = entries[0].delta.unflatten(np.asarray(flat))
        else:
            result, combined = buffered_aggregate(
                [e.delta for e in entries],
                [e.weight for e in entries],
                [e.staleness for e in entries],
                alpha=staleness_alpha,
            )
        h.version += 1
        stal = [e.staleness for e in entries]
        hist = np.bincount(np.asarray(stal, np.int64)).tolist() if entries else []
        stats = {
            "result": result,
            "arrivals": len(entries),
            "workers": [e.worker for e in entries],
            "staleness": stal,
            "staleness_hist": hist,  # hist[s] = commits applied at staleness s
            "weights": None if combined is None else [float(w) for w in combined],
            "version": h.version,
            "k": len(entries) if k is None else int(k),
        }
        h.round_records.append(
            {
                "version": h.version,
                "arrivals": len(entries),
                "k": stats["k"],
                "staleness_hist": hist,
                "selector_scores": selector_scores,
                "transport": transport,
            }
        )
        if h.on_aggregate:
            h.on_aggregate(app_id, result)
        return stats

    def Discover(self, node: int) -> dict[int, dict]:
        """AD-tree application discovery (journal addition, Appendix A)."""
        return self.forest.discover(node)

    def tick(self) -> None:
        """Periodic timer: fires owners' onTimer callbacks."""
        for h in self.apps.values():
            if h.on_timer:
                h.on_timer(h.app_id)

    # -- fault tolerance -------------------------------------------------------

    def replicate_master_state(self, app_id: int, state) -> list[int]:
        h = self.apps[app_id]
        return self.replicas.replicate(self.overlay, app_id, h.tree.root, state)

    def fail_nodes(self, app_id: int, nodes: list[int]):
        h = self.apps[app_id]
        return recovery_mod.fail_and_recover(
            self.overlay, self.forest, h.tree, nodes, replicas=self.replicas
        )


def _nbytes(obj) -> float:
    import jax

    if hasattr(obj, "nbytes"):
        return float(obj.nbytes)
    try:
        return float(sum(np.asarray(x).nbytes for x in jax.tree.leaves(obj)))
    except Exception:
        return float(len(str(obj)))


def _weighted_mean(values, weights):
    import jax

    w = np.asarray(weights, np.float64)
    w = w / w.sum()

    def avg(*leaves):
        return sum(wi * np.asarray(l, np.float64) for wi, l in zip(w, leaves))

    return jax.tree.map(avg, *values)


def _aggregate_hierarchical(overlay, tree, payload, weights, *, use_kernel=True):
    """Execute the tree's aggregation schedule bottom-up.

    Each node carries a partial *weighted sum* of its subtree's updates
    (plus the subtree weight); every level is one batched kernel call over
    its (parent, children) groups, and the master normalizes once at the
    root — associativity makes this bit-compatible (up to f32 reduction
    order) with the flat weighted mean.

    Returns (result_pytree, levels) where levels[i] records that level's
    group count, per-edge traffic and modeled latency.
    """
    import jax

    from repro.kernels import ops as kops

    first = next(iter(payload.values()))
    leaves0, treedef = jax.tree.flatten(first)
    shapes = [np.shape(l) for l in leaves0]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    L = sum(sizes)

    def flatten(obj):
        ls = jax.tree.leaves(obj)
        return np.concatenate([np.ravel(np.asarray(l)).astype(np.float32) for l in ls])

    # node -> [partial weighted-sum vec, kernel weight, subtree weight]
    state: dict[int, list] = {
        n: [flatten(v), float(weights.get(n, 1.0)), float(weights.get(n, 1.0))]
        for n, v in payload.items()
    }
    vec_bytes = 4.0 * L
    levels: list[dict] = []

    def run_level(groups, depth):
        """groups: list of (parent, contributors) where each contributor is
        a node currently in `state`; executes them as one batched call."""
        cmax = max(len(c) for _, c in groups)
        g = np.zeros((len(groups), cmax, L), np.float32)
        w = np.zeros((len(groups), cmax), np.float32)
        for i, (_, contrib) in enumerate(groups):
            for j, c in enumerate(contrib):
                g[i, j] = state[c][0]
                w[i, j] = state[c][1]
        if use_kernel:
            out = np.asarray(kops.tree_aggregate_groups(g, w))
        else:
            out = (g.astype(np.float64) * w[..., None]).sum(axis=1)
        lvl_bytes, lvl_ms = 0.0, 0.0
        for i, (parent, contrib) in enumerate(groups):
            subtree_w = sum(state[c][2] for c in contrib)
            for c in contrib:
                if c != parent:
                    lvl_bytes += vec_bytes
                    lvl_ms = max(lvl_ms, overlay.rtt(c, parent))
                del state[c]
            state[parent] = [out[i], 1.0, subtree_w]
        levels.append(
            {"level": depth, "groups": len(groups), "bytes": lvl_bytes, "time_ms": lvl_ms}
        )

    for sched in tree.aggregation_schedule():
        groups = []
        for parent, children in sched:
            contrib = [c for c in children if c in state]
            if parent in state:
                contrib.append(parent)  # parent's own update merges here
            if contrib:
                groups.append((parent, contrib))
        if groups:
            run_level(groups, depth=len(levels))
    # final merge at the root: needed for stragglers outside the tree,
    # and for any still-raw leaf payload (kernel weight not yet applied
    # — e.g. a root-only payload on a childless tree)
    if (
        len(state) != 1
        or tree.root not in state
        or state[tree.root][1] != 1.0
    ):
        run_level([(tree.root, sorted(state))], depth=len(levels))

    vec, _, total_w = state[tree.root]
    mean = np.asarray(vec, np.float64) / max(total_w, 1e-12)
    out_leaves, off = [], 0
    for s, sz in zip(shapes, sizes):
        out_leaves.append(mean[off : off + sz].reshape(s))
        off += sz
    return jax.tree.unflatten(treedef, out_leaves), levels
