"""Algorithm 2: multicast-enabled distributed hop-by-hop routing
(paper Appendix N-B).

Actions become hop SUBSETS (send to multiple next hops simultaneously);
rewards live in [0, F] where F is the max subset size.  The policy-update
math is unchanged — Algorithm 1 over the enumerated subset action space
(the paper: "each policy in Delta(P_n) becomes a |subsets|-dimensional
vector") — so ``algorithm1_episode`` is reused verbatim, which is exactly
the paper's construction.  The Nash-regret bound for this variant is
open (the paper leaves it to future work); we report empirical regret.
"""
from __future__ import annotations

from dataclasses import dataclass
from itertools import combinations

import jax
import jax.numpy as jnp
import numpy as np

from .congestion import CongestionEnv
from .pathplan import algorithm1_episode, candidate_policy_set


def enumerate_subsets(K: int, max_size: int = 2) -> np.ndarray:
    """All non-empty hop subsets up to ``max_size`` as a (M, K) 0/1 matrix."""
    rows = []
    for size in range(1, max_size + 1):
        for combo in combinations(range(K), size):
            v = np.zeros(K)
            v[list(combo)] = 1.0
            rows.append(v)
    return np.stack(rows)


@dataclass
class MulticastPlanner:
    """Totoro+ Algorithm 2: policies over subset actions."""

    num_nodes: int
    num_paths: int
    max_subset: int = 2
    tau: int = 8
    alpha: float = 0.95
    beta: float = 0.5
    seed: int = 0

    def __post_init__(self):
        self.subsets = jnp.asarray(enumerate_subsets(self.num_paths, self.max_subset), jnp.float32)
        M = self.subsets.shape[0]
        self.pi = jnp.full((self.num_nodes, M), 1.0 / M, jnp.float32)
        self.mask = jnp.ones((self.num_nodes, M), bool)
        self.cand = candidate_policy_set(M, seed=self.seed)

    def sample_actions(self, key) -> jnp.ndarray:
        """(N, tau) subset-action indices."""
        return jax.random.categorical(
            key, jnp.log(jnp.maximum(self.pi, 1e-12))[:, None, :].repeat(self.tau, 1)
        )

    def rewards(self, env: CongestionEnv, actions: jnp.ndarray, key) -> jnp.ndarray:
        """Reward of a subset = sum of member-hop rewards under the joint
        congestion produced by ALL selected hops of all nodes (in [0, F])."""
        sel = self.subsets[actions]  # (N, tau, K) 0/1
        out = []
        for t in range(actions.shape[1]):
            s_t = sel[:, t]  # (N, K)
            counts = jnp.sum(s_t, axis=0)  # users per hop
            rate = env.capacity[None, :] / jnp.maximum(counts[None, :], 1.0)
            lat = env.base_ms + 1e3 * env.packet_mbit / jnp.maximum(rate, 1e-6)
            r = jnp.clip(1.0 - lat / env.l_max_ms, 0.0, 1.0) * env.theta[None, :]
            ok = jax.random.bernoulli(jax.random.fold_in(key, t), env.theta[None, :].repeat(s_t.shape[0], 0))
            out.append(jnp.sum(s_t * r * ok, axis=-1))
        return jnp.stack(out, axis=1)  # (N, tau)

    def update(self, actions, rewards) -> None:
        self.pi = algorithm1_episode(
            self.pi, self.mask, self.cand, actions, rewards,
            tau=self.tau, alpha=self.alpha, beta=self.beta,
        )

    def subset_usage(self) -> np.ndarray:
        """Mean policy mass per subset size (diagnostics)."""
        sizes = np.asarray(self.subsets.sum(-1))
        mass = np.asarray(self.pi.mean(0))
        return np.asarray([mass[sizes == s].sum() for s in range(1, self.max_subset + 1)])
