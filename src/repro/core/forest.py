"""Layer 2: publish/subscribe forest abstraction (paper §IV-C).

Each FL application gets a dataflow tree built from the union of JOIN
message routes toward AppId; the rendezvous node (numerically closest to
AppId) is the root = master; internal nodes keep children tables and act
as coordinator/aggregator/selector; leaves are workers.  The masters of
all trees join a shared advertise-discover (AD) tree keyed by
``hash("AD application")`` that carries the application registry.

Storage follows the overlay's array-of-structs pattern: a
``DataflowTree`` keeps its topology in flat numpy arrays (parent
vector, intrusive child lists, lazily rebuilt depth/level slices) while
``parent`` / ``children`` remain zero-copy write-through dict/list
views, so the recovery, API and sim layers mutate trees through the
same idioms as the original dict-of-lists implementation — including
the transient parent/children divergence the repair path relies on.
``Forest.subscribe_many`` grafts a whole JOIN batch at once; the scalar
``subscribe`` loop stays as the exactness oracle.
"""
from __future__ import annotations

from collections.abc import MutableMapping
from typing import Iterator

import numpy as np

from .nodeid import numerically_closest, sha1_id
from .overlay import MultiRingOverlay, RouteResult

AD_TOPIC = "AD application"

_NO_DEFAULT = object()


def _isin_sorted(haystack: np.ndarray, vals: np.ndarray) -> np.ndarray:
    """Membership of ``vals`` in a *sorted* int64 ``haystack``."""
    if len(haystack) == 0:
        return np.zeros(len(vals), bool)
    i = np.searchsorted(haystack, vals)
    i[i == len(haystack)] = len(haystack) - 1
    return haystack[i] == vals


# ---------------------------------------------------------------------------
# zero-copy views over the tree arrays (the overlay's PR-6 pattern)


class _ParentView(MutableMapping):
    """``dict[int, int]`` facade (child -> parent) over the tree arrays.

    Iteration follows dict-insertion order (an insertion-seq column), so
    loops over ``tree.parent`` see exactly what the old dict showed.
    Every mutation drops the tree's derived depth/level cache.
    """

    __slots__ = ("_t",)

    def __init__(self, tree: "DataflowTree"):
        self._t = tree

    def __getitem__(self, node: int) -> int:
        t = self._t
        s = t._slot.get(node)
        if s is None or t._par[s] < 0:
            raise KeyError(node)
        return int(t._ids[t._par[s]])

    def __setitem__(self, node: int, parent: int) -> None:
        t = self._t
        s = t._slot_of(node)
        p = t._slot_of(parent)
        if t._par[s] < 0:
            t._pseq[s] = t._next_seq()
            t._par_count += 1
        t._par[s] = p
        t._invalidate()

    def __delitem__(self, node: int) -> None:
        t = self._t
        s = t._slot.get(node)
        if s is None or t._par[s] < 0:
            raise KeyError(node)
        t._par[s] = -1
        t._pseq[s] = -1
        t._par_count -= 1
        t._invalidate()

    def __contains__(self, node) -> bool:
        t = self._t
        s = t._slot.get(node)
        return s is not None and t._par[s] >= 0

    def __iter__(self) -> Iterator[int]:
        t = self._t
        slots = np.flatnonzero(t._par[: t._n] >= 0)
        order = np.argsort(t._pseq[slots], kind="stable")
        return iter(t._ids[slots[order]].tolist())

    def __len__(self) -> int:
        return self._t._par_count

    def __repr__(self) -> str:
        return repr(dict(self))


class _ChildList:
    """Ordered write-through view of one parent's children list.

    Backed by an intrusive doubly-linked list threaded through the tree
    arrays, so ``append`` / ``remove`` are O(1) and preserve exact
    list-append order (graft order matters for trace identity).
    """

    __slots__ = ("_t", "_p")

    def __init__(self, tree: "DataflowTree", pslot: int):
        self._t = tree
        self._p = pslot

    def _slots(self) -> list[int]:
        t = self._t
        out, c = [], int(t._ch_head[self._p])
        while c >= 0:
            out.append(c)
            c = int(t._ch_next[c])
        return out

    def _ids_list(self) -> list[int]:
        t = self._t
        return [int(t._ids[s]) for s in self._slots()]

    def __len__(self) -> int:
        return int(self._t._ch_len[self._p])

    def __bool__(self) -> bool:
        return bool(self._t._ch_len[self._p] > 0)

    def __iter__(self) -> Iterator[int]:
        return iter(self._ids_list())

    def __getitem__(self, i):
        return self._ids_list()[i]

    def __contains__(self, node) -> bool:
        t = self._t
        s = t._slot.get(node)
        return s is not None and t._cl_list[s] == self._p

    def __eq__(self, other) -> bool:
        if isinstance(other, _ChildList):
            other = other._ids_list()
        if isinstance(other, (list, tuple)):
            return self._ids_list() == list(other)
        return NotImplemented

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return eq if eq is NotImplemented else not eq

    def append(self, node: int) -> None:
        t = self._t
        s = t._slot_of(node)
        if t._cl_list[s] >= 0:  # a node lives in at most one children list
            t._ch_unlink(s)
        t._ch_append(self._p, s)

    def extend(self, nodes) -> None:
        for n in nodes:
            self.append(n)

    def remove(self, node: int) -> None:
        t = self._t
        s = t._slot.get(node)
        if s is None or t._cl_list[s] != self._p:
            raise ValueError(f"list.remove(x): {node} not in children list")
        t._ch_unlink(s)

    def clear(self) -> None:
        self._t._unlink_all_children(self._p)

    def index(self, node: int) -> int:
        return self._ids_list().index(node)

    def count(self, node: int) -> int:
        return 1 if node in self else 0

    def __repr__(self) -> str:
        return repr(self._ids_list())


class _ChildrenView(MutableMapping):
    """``dict[int, list[int]]`` facade over the children table.

    Key order follows key-creation order (a key-seq column), values are
    live ``_ChildList`` views; ``pop`` materializes a plain list first
    so the recovery path can iterate orphans after the unlink — exactly
    the old ``dict.pop`` contract.
    """

    __slots__ = ("_t",)

    def __init__(self, tree: "DataflowTree"):
        self._t = tree

    def __getitem__(self, parent: int) -> _ChildList:
        t = self._t
        s = t._slot.get(parent)
        if s is None or not t._ch_present[s]:
            raise KeyError(parent)
        return _ChildList(t, s)

    def __setitem__(self, parent: int, value) -> None:
        t = self._t
        s = t._slot_of(parent)
        if not t._ch_present[s]:
            t._mark_ch_present(s)
        else:
            t._unlink_all_children(s)
        lst = _ChildList(t, s)
        for c in value:
            lst.append(c)

    def __delitem__(self, parent: int) -> None:
        self.pop(parent)

    def pop(self, parent: int, default=_NO_DEFAULT):
        t = self._t
        s = t._slot.get(parent)
        if s is None or not t._ch_present[s]:
            if default is _NO_DEFAULT:
                raise KeyError(parent)
            return default
        out = _ChildList(t, s)._ids_list()
        t._unlink_all_children(s)
        t._ch_present[s] = False
        t._ch_kseq[s] = -1
        t._ch_count -= 1
        t._invalidate()
        return out

    def setdefault(self, parent: int, default=None) -> _ChildList:
        t = self._t
        s = t._slot_of(parent)
        if not t._ch_present[s]:
            t._mark_ch_present(s)
            if default:
                lst = _ChildList(t, s)
                for c in default:
                    lst.append(c)
        return _ChildList(t, s)

    def __contains__(self, parent) -> bool:
        t = self._t
        s = t._slot.get(parent)
        return s is not None and bool(t._ch_present[s])

    def __iter__(self) -> Iterator[int]:
        t = self._t
        slots = np.flatnonzero(t._ch_present[: t._n])
        order = np.argsort(t._ch_kseq[slots], kind="stable")
        return iter(t._ids[slots[order]].tolist())

    def __len__(self) -> int:
        return self._t._ch_count

    def __repr__(self) -> str:
        return repr({p: list(self[p]) for p in self})


# ---------------------------------------------------------------------------


class DataflowTree:
    """Array-backed dataflow tree.

    Topology lives in struct-of-arrays over node *slots* (append-only
    rows; ``_slot`` maps node id -> slot): ``_par``/``_pseq`` back the
    ``parent`` mapping, and an intrusive doubly-linked list per parent
    (``_ch_head``/``_ch_tail``/``_ch_next``/``_ch_prev``/``_cl_list``)
    backs the ``children`` table with exact append order.  The two
    stores are updated in tandem by callers — never derived from each
    other — because the recovery path deliberately lets them diverge
    mid-repair (orphans keep stale ``parent`` entries after their failed
    parent's ``children.pop``).

    Derived structure (depth vector, level slices, a parent->children
    CSR) is rebuilt lazily by ``_ensure_cache`` — any mutation through
    the views invalidates it — which turns ``depth_of`` into an O(1)
    lookup and ``levels`` / ``aggregation_schedule`` / ``broadcast_time``
    into array passes instead of per-node parent walks.
    """

    __slots__ = (
        "app_id", "meta", "members", "parent", "children",
        "_root", "_slot", "_ids", "_par", "_pseq",
        "_cl_list", "_ch_next", "_ch_prev", "_ch_head", "_ch_tail",
        "_ch_len", "_ch_present", "_ch_kseq",
        "_n", "_seq", "_par_count", "_ch_count", "_cache",
    )

    def __init__(
        self,
        app_id: int,
        root: int,
        parent: dict[int, int] | None = None,
        children: dict[int, list[int]] | None = None,
        members: set[int] | None = None,
        meta: dict | None = None,
    ):
        self.app_id = app_id
        self.meta = {} if meta is None else meta
        self.members = set() if members is None else set(members)
        cap = 16
        self._ids = np.zeros(cap, np.int64)
        self._par = np.full(cap, -1, np.int64)
        self._pseq = np.full(cap, -1, np.int64)
        self._cl_list = np.full(cap, -1, np.int64)
        self._ch_next = np.full(cap, -1, np.int64)
        self._ch_prev = np.full(cap, -1, np.int64)
        self._ch_head = np.full(cap, -1, np.int64)
        self._ch_tail = np.full(cap, -1, np.int64)
        self._ch_len = np.zeros(cap, np.int64)
        self._ch_present = np.zeros(cap, bool)
        self._ch_kseq = np.full(cap, -1, np.int64)
        self._slot: dict[int, int] = {}
        self._n = 0
        self._seq = 0
        self._par_count = 0
        self._ch_count = 0
        self._cache: dict | None = None
        self._root = int(root)
        self._slot_of(self._root)
        self.parent = _ParentView(self)
        self.children = _ChildrenView(self)
        if parent:
            for c, p in parent.items():
                self.parent[c] = p
        if children:
            for p, kids in children.items():
                self.children[p] = list(kids)

    # -- root (recovery reassigns it on master failover) ---------------------

    @property
    def root(self) -> int:
        return self._root

    @root.setter
    def root(self, value: int) -> None:
        self._root = int(value)
        self._slot_of(self._root)
        self._invalidate()

    def __repr__(self) -> str:
        return (
            f"DataflowTree(app_id={self.app_id}, root={self._root}, "
            f"nodes={len(self.parent) + 1}, members={len(self.members)})"
        )

    # -- slot bookkeeping -----------------------------------------------------

    def _grow(self, need: int) -> None:
        cap = len(self._ids)
        if need <= cap:
            return
        new = max(cap * 2, need)

        def ext(a: np.ndarray, fill) -> np.ndarray:
            b = np.full(new, fill, a.dtype)
            b[: self._n] = a[: self._n]
            return b

        self._ids = ext(self._ids, 0)
        self._par = ext(self._par, -1)
        self._pseq = ext(self._pseq, -1)
        self._cl_list = ext(self._cl_list, -1)
        self._ch_next = ext(self._ch_next, -1)
        self._ch_prev = ext(self._ch_prev, -1)
        self._ch_head = ext(self._ch_head, -1)
        self._ch_tail = ext(self._ch_tail, -1)
        self._ch_len = ext(self._ch_len, 0)
        self._ch_present = ext(self._ch_present, False)
        self._ch_kseq = ext(self._ch_kseq, -1)

    def _slot_of(self, node: int) -> int:
        s = self._slot.get(node)
        if s is None:
            s = self._n
            self._grow(s + 1)
            self._ids[s] = node
            self._slot[node] = s
            self._n = s + 1
        return s

    def _next_seq(self) -> int:
        s = self._seq
        self._seq += 1
        return s

    def _invalidate(self) -> None:
        self._cache = None

    # -- children linked-list primitives --------------------------------------

    def _mark_ch_present(self, s: int) -> None:
        self._ch_present[s] = True
        self._ch_kseq[s] = self._next_seq()
        self._ch_count += 1
        self._invalidate()

    def _ch_append(self, p: int, c: int) -> None:
        tail = int(self._ch_tail[p])
        if tail < 0:
            self._ch_head[p] = c
        else:
            self._ch_next[tail] = c
        self._ch_prev[c] = tail
        self._ch_next[c] = -1
        self._ch_tail[p] = c
        self._cl_list[c] = p
        self._ch_len[p] += 1
        self._invalidate()

    def _ch_unlink(self, c: int) -> None:
        p = int(self._cl_list[c])
        if p < 0:
            return
        nxt, prv = int(self._ch_next[c]), int(self._ch_prev[c])
        if prv >= 0:
            self._ch_next[prv] = nxt
        else:
            self._ch_head[p] = nxt
        if nxt >= 0:
            self._ch_prev[nxt] = prv
        else:
            self._ch_tail[p] = prv
        self._cl_list[c] = -1
        self._ch_next[c] = -1
        self._ch_prev[c] = -1
        self._ch_len[p] -= 1
        self._invalidate()

    def _unlink_all_children(self, p: int) -> None:
        c = int(self._ch_head[p])
        while c >= 0:
            nxt = int(self._ch_next[c])
            self._cl_list[c] = -1
            self._ch_next[c] = -1
            self._ch_prev[c] = -1
            c = nxt
        self._ch_head[p] = -1
        self._ch_tail[p] = -1
        self._ch_len[p] = 0
        self._invalidate()

    # -- derived structure (lazy) ---------------------------------------------

    def _ensure_cache(self) -> dict:
        """Depth vector + level slices via a level-synchronous BFS from
        the root over a searchsorted CSR of the parent vector.  Nodes in
        the parent map but unreachable from the root keep depth -1 (the
        scalar ``depth_of`` replay below reproduces the legacy error for
        them)."""
        if self._cache is not None:
            return self._cache
        n = self._n
        root_s = self._slot[self._root]
        par = self._par[:n]
        active = np.flatnonzero(par >= 0)
        order = np.argsort(par[active], kind="stable")
        kids_sorted = active[order]  # child slots grouped by parent slot
        par_sorted = par[active][order]
        depth = np.full(n, -1, np.int64)
        depth[root_s] = 0
        levels = [np.asarray([root_s], np.int64)]
        frontier = levels[0]
        while True:
            lo = np.searchsorted(par_sorted, frontier, side="left")
            hi = np.searchsorted(par_sorted, frontier, side="right")
            cnt = hi - lo
            total = int(cnt.sum())
            if total == 0:
                break
            starts = np.concatenate([[0], np.cumsum(cnt)[:-1]])
            idx = np.arange(total) - np.repeat(starts, cnt) + np.repeat(lo, cnt)
            nxt = kids_sorted[idx]
            nxt = nxt[depth[nxt] < 0]  # cycle guard: stop at seen slots
            if len(nxt) == 0:
                break
            depth[nxt] = len(levels)
            levels.append(nxt)
            frontier = np.sort(nxt)
        ids_order = np.argsort(self._ids[:n], kind="stable")
        self._cache = {
            "depth": depth,
            "levels": levels,
            "root_s": root_s,
            "active": active,
            "ids_sorted": self._ids[:n][ids_order],
            "slots_sorted": ids_order,
        }
        return self._cache

    def _slots_of(self, ids_arr: np.ndarray) -> np.ndarray:
        """Vectorized node-id -> slot lookup; KeyError on unknown ids."""
        cache = self._ensure_cache()
        srt, slots = cache["ids_sorted"], cache["slots_sorted"]
        j = np.searchsorted(srt, ids_arr)
        jj = np.minimum(j, len(srt) - 1)
        bad = (j >= len(srt)) | (srt[jj] != ids_arr)
        if bad.any():
            raise KeyError(int(ids_arr[np.flatnonzero(bad)[0]]))
        return slots[jj]

    def _check_reachable(self, slots: np.ndarray) -> np.ndarray:
        """Depths of the given slots; replay the scalar walk (which
        raises exactly like the legacy code) for any unreached slot."""
        depth = self._ensure_cache()["depth"][slots]
        if (depth < 0).any():
            bad = slots[np.flatnonzero(depth < 0)[0]]
            self._depth_walk(int(self._ids[bad]))
        return depth

    # -- topology queries ------------------------------------------------------

    def nodes(self) -> set[int]:
        mask = self._par[: self._n] >= 0
        out = set(self._ids[: self._n][mask].tolist())
        out.add(self._root)
        return out

    def _depth_walk(self, node: int) -> int:
        """Legacy scalar parent walk — kept as the error-faithful path
        for nodes the BFS cannot reach (detached chains, cycles)."""
        d, cur = 0, node
        while cur != self._root:
            cur = self.parent[cur]
            d += 1
            if d > self._par_count + 1:
                raise RuntimeError("cycle in tree")
        return d

    def depth_of(self, node: int) -> int:
        s = self._slot.get(node)
        if s is None:
            if node == self._root:
                return 0
            raise KeyError(node)
        d = self._ensure_cache()["depth"][s]
        if d >= 0:
            return int(d)
        return self._depth_walk(node)

    def depths_of(self, nodes) -> np.ndarray:
        """Vectorized ``depth_of`` over an id array."""
        arr = np.asarray(nodes, np.int64)
        if len(arr) == 0:
            return np.zeros(0, np.int64)
        return self._check_reachable(self._slots_of(arr)).copy()

    def depth(self) -> int:
        cache = self._ensure_cache()
        self._check_reachable(cache["active"])
        return len(cache["levels"]) - 1

    def levels(self) -> list[list[int]]:
        cache = self._ensure_cache()
        self._check_reachable(cache["active"])
        return [np.sort(self._ids[lv]).tolist() for lv in cache["levels"]]

    def fanout(self) -> int:
        present = self._ch_present[: self._n]
        if not present.any():
            return 0
        return int(self._ch_len[: self._n][present].max())

    def path_to_root(self, node: int) -> list[int]:
        out = [node]
        slot, par, ids, root = self._slot, self._par, self._ids, self._root
        cur = node
        while cur != root:
            s = slot.get(cur)
            if s is None or par[s] < 0:
                raise KeyError(cur)
            cur = int(ids[par[s]])
            out.append(cur)
        return out

    def paths_matrix(self, nodes) -> np.ndarray:
        """Root-ward paths for many nodes at once: row k is
        ``path_to_root(nodes[k])``, padded with -1 past the root.  One
        vectorized parent-gather per tree level instead of a Python walk
        per node."""
        arr = np.asarray(nodes, np.int64)
        if len(arr) == 0:
            return np.zeros((0, 1), np.int64)
        slots = self._slots_of(arr)
        d = self._check_reachable(slots)
        dmax = int(d.max())
        out = np.full((len(arr), dmax + 1), -1, np.int64)
        cur = slots.copy()
        alive = np.ones(len(arr), bool)
        for lev in range(dmax + 1):
            ai = np.flatnonzero(alive)
            out[ai, lev] = self._ids[cur[ai]]
            done = d[ai] == lev  # row reached the root
            alive[ai[done]] = False
            step = ai[~done]
            cur[step] = self._par[cur[step]]
        return out

    # -- bulk graft (used by Forest.subscribe_many) ---------------------------

    def _bulk_attach(self, child_ids: np.ndarray, parent_ids: np.ndarray) -> None:
        """Append many (child -> parent) edges at once, equivalent to
        ``parent[c] = p; children.setdefault(p, []).append(c)`` per pair
        in order.  Children must be new to the parent map (the graft
        merge guarantees it)."""
        k = len(child_ids)
        if k == 0:
            return
        # slot allocation for any unseen ids (children and route tails)
        all_ids = np.concatenate([child_ids, parent_ids])
        uniq = np.unique(all_ids)
        known_sorted = np.sort(self._ids[: self._n])
        fresh = uniq[~_isin_sorted(known_sorted, uniq)]
        base = self._n
        self._grow(base + len(fresh))
        self._ids[base : base + len(fresh)] = fresh
        self._n = base + len(fresh)
        self._slot.update(zip(fresh.tolist(), range(base, base + len(fresh))))
        ids_snap = self._ids[: self._n]
        sort_idx = np.argsort(ids_snap, kind="stable")
        sorted_ids = ids_snap[sort_idx]
        cs = sort_idx[np.searchsorted(sorted_ids, child_ids)]
        ps = sort_idx[np.searchsorted(sorted_ids, parent_ids)]
        assert (self._par[cs] < 0).all(), "bulk graft re-parenting existing nodes"
        # parent store
        self._par[cs] = ps
        self._pseq[cs] = self._seq + np.arange(k)
        self._seq += k
        self._par_count += k
        # children store: group appended children by parent, keeping the
        # sequential append order inside each group (stable sort)
        linked = np.flatnonzero(self._cl_list[cs] >= 0)
        for i in linked.tolist():  # defensive: a child can't be listed twice
            self._ch_unlink(int(cs[i]))
        order2 = np.argsort(ps, kind="stable")
        gp, gc = ps[order2], cs[order2]
        starts = np.flatnonzero(np.r_[True, gp[1:] != gp[:-1]])
        ends = np.r_[starts[1:], k]
        nxt = np.full(k, -1, np.int64)
        prv = np.full(k, -1, np.int64)
        nxt[:-1] = gc[1:]
        prv[1:] = gc[:-1]
        nxt[ends - 1] = -1
        prv[starts] = -1
        self._ch_next[gc] = nxt
        self._ch_prev[gc] = prv
        self._cl_list[gc] = gp
        heads, tails, parents = gc[starts], gc[ends - 1], gp[starts]
        old_tail = self._ch_tail[parents]
        has_old = old_tail >= 0
        self._ch_next[old_tail[has_old]] = heads[has_old]
        self._ch_prev[heads[has_old]] = old_tail[has_old]
        self._ch_head[parents[~has_old]] = heads[~has_old]
        self._ch_tail[parents] = tails
        self._ch_len[parents] += ends - starts
        # new children-table keys get kseq in first-append order
        newk = ~self._ch_present[parents]
        if newk.any():
            korder = np.argsort(order2[starts][newk], kind="stable")
            new_parents = parents[newk][korder]
            self._ch_present[new_parents] = True
            self._ch_kseq[new_parents] = self._seq + np.arange(len(new_parents))
            self._seq += len(new_parents)
            self._ch_count += len(new_parents)
        self._invalidate()

    # -- dataflow schedules (latency model supplied by the overlay) ----------

    def aggregation_schedule(self) -> list[list[tuple[int, list[int]]]]:
        """Per-level batches of (parent, children) groups, deepest level
        first, so partial aggregates flow leaves -> root: every internal
        node appears exactly once as a parent, and each level's groups
        are independent (executable as one batched kernel call)."""
        n = self._n
        kids = np.flatnonzero(self._cl_list[:n] >= 0)
        if len(kids) == 0:
            return []
        par = self._cl_list[kids]
        pd = self._check_reachable(par)
        pid = self._ids[par]
        kid = self._ids[kids]
        order = np.lexsort((kid, pid, -pd))
        pd_l = pd[order].tolist()
        pid_l = pid[order].tolist()
        kid_l = kid[order].tolist()
        out: list[list[tuple[int, list[int]]]] = []
        level: list[tuple[int, list[int]]] = []
        cur_d = None
        i, total = 0, len(kid_l)
        while i < total:
            j = i + 1
            while j < total and pid_l[j] == pid_l[i]:
                j += 1
            if pd_l[i] != cur_d:
                if level:
                    out.append(level)
                level, cur_d = [], pd_l[i]
            level.append((pid_l[i], kid_l[i:j]))
            i = j
        if level:
            out.append(level)
        return out

    def broadcast_schedule(self) -> list[list[tuple[int, list[int]]]]:
        """The same level batches root -> leaves (dissemination order)."""
        return list(reversed(self.aggregation_schedule()))

    def broadcast_time(
        self,
        overlay: MultiRingOverlay,
        payload_ms: float = 0.0,
        *,
        pipelined: bool = False,
        chunks: int = 8,
    ) -> float:
        """Model dissemination root->leaves: max over leaves of path latency.

        ``pipelined=True`` prices each root->leaf path with per-edge
        store-and-forward overlap: the payload is cut into ``chunks``
        pieces so a hop starts forwarding as soon as the first piece
        lands — a D-hop payload costs t*(D+C-1)/C instead of t*D,
        approaching the max single edge as C grows (never slower than
        the synchronous sum).

        Per-node latencies accumulate root-down level by level in the
        same edge order as the per-leaf ``path_latency`` sum, so the
        vectorized result matches the scalar walk
        (``_broadcast_time_walk``, kept as the oracle/fallback).
        """
        cache = self._ensure_cache()
        n = self._n
        depth, levels = cache["depth"], cache["levels"]
        if len(cache["active"]) and (depth[cache["active"]] < 0).any():
            return self._broadcast_time_walk(
                overlay, payload_ms, pipelined=pipelined, chunks=chunks
            )
        tree_slots = np.concatenate(levels)
        rows = overlay._rows_of_many(self._ids[tree_slots])
        if (rows < 0).any():  # a node the overlay no longer knows
            return self._broadcast_time_walk(
                overlay, payload_ms, pipelined=pipelined, chunks=chunks
            )
        row_of = np.full(n, -1, np.int64)
        row_of[tree_slots] = rows
        lat = np.zeros(n, np.float64)
        xy = overlay._xy
        for lev_slots in levels[1:]:
            ps = self._par[lev_slots]
            a, b = xy[row_of[ps]], xy[row_of[lev_slots]]
            dx, dy = a[:, 0] - b[:, 0], a[:, 1] - b[:, 1]
            lat[lev_slots] = lat[ps] + (1.0 + 0.1 * (dx ** 2 + dy ** 2) ** 0.5)
        leaf = ~(self._ch_present[tree_slots] & (self._ch_len[tree_slots] > 0))
        lslots = tree_slots[leaf]
        edges = depth[lslots].astype(np.float64)
        if pipelined:
            c = max(1, int(chunks))
            pay = np.where(
                depth[lslots] > 1,
                payload_ms * (edges + c - 1) / c,
                payload_ms * edges,
            )
        else:
            pay = payload_ms * edges
        if len(lslots) == 0:
            return 0.0
        return float(np.max(lat[lslots] + pay, initial=0.0))

    def _broadcast_time_walk(
        self,
        overlay: MultiRingOverlay,
        payload_ms: float = 0.0,
        *,
        pipelined: bool = False,
        chunks: int = 8,
    ) -> float:
        """Scalar per-leaf walk (the original implementation): oracle for
        the vectorized ``broadcast_time`` and fallback for trees whose
        nodes the vector path cannot resolve."""
        t = 0.0
        for node in self.nodes():
            if node not in self.children or not self.children[node]:  # leaf
                path = list(reversed(self.path_to_root(node)))
                edges = len(path) - 1
                if pipelined and edges > 1:
                    c = max(1, int(chunks))
                    payload_total = payload_ms * (edges + c - 1) / c
                else:
                    payload_total = payload_ms * edges
                t = max(t, overlay.path_latency(path) + payload_total)
        return t

    def aggregation_time(self, overlay: MultiRingOverlay, payload_ms: float = 0.0) -> float:
        return self.broadcast_time(overlay, payload_ms)  # symmetric schedule


# ---------------------------------------------------------------------------
# vectorized union-of-paths graft


def _graft_paths_bulk(tree: DataflowTree, flat: np.ndarray, offsets: np.ndarray) -> bool:
    """Apply ``Forest._graft_path`` for a whole route batch at once,
    exactly.  ``flat``/``offsets`` hold the concatenated per-route paths
    (``RouteBatch.paths_flat``).

    Sequential grafting is a fixpoint.  Route r stops at its *cut* — the
    first scanned position whose node is already in the tree as left by
    routes < r — and every pre-cut position claims its node with parent
    = next hop (plus the root-fixup claim for routes that scan through
    every edge).  A node is owned by the lexicographically first
    (route, pos) claim.  cuts -> claims is monotone and claims -> cuts
    antitone, so the composed update G is antitone: iterates sandwich
    the sequential solution S (even iterates >= S >= odd iterates), and
    any two consecutive equal iterates *are* S.  We start from the
    no-claims cut vector and iterate until stable, then apply surviving
    claims in (route, pos) order — node-for-node the sequential result.
    Returns False if the cap is hit (a 2-cycle; the caller falls back to
    the scalar loop, so exactness never depends on convergence).
    """
    K = len(offsets) - 1
    if K == 0:
        return True
    lens = np.diff(offsets)
    total = int(offsets[-1])
    pos = np.arange(total, dtype=np.int64) - np.repeat(offsets[:-1], lens)
    ridx = np.repeat(np.arange(K, dtype=np.int64), lens)
    scan = pos < (lens[ridx] - 1)  # the scalar loop tests all but the last node
    root = tree._root
    n0 = tree._n
    init_parent_ids = np.sort(tree._ids[:n0][tree._par[:n0] >= 0])
    base_hit = (_isin_sorted(init_parent_ids, flat) | (flat == root)) & scan
    last_node = flat[offsets[1:] - 1]
    last_in_init = _isin_sorted(init_parent_ids, last_node)
    NOHIT = lens - 1  # cut sentinel: route scanned every edge
    BIG = np.iinfo(np.int64).max

    def cuts_from(owned_lt: np.ndarray | None) -> np.ndarray:
        hit = base_hit if owned_lt is None else (base_hit | (owned_lt & scan))
        vals = np.where(hit, pos, BIG)
        return np.minimum(np.minimum.reduceat(vals, offsets[:-1]), NOHIT)

    def claims_from(cut: np.ndarray):
        li = np.flatnonzero(scan & (pos < cut[ridx]))
        c_node, c_route = flat[li], ridx[li]
        c_pos, c_parent = pos[li], flat[li + 1]
        fix = np.flatnonzero((cut == NOHIT) & (last_node != root) & ~last_in_init)
        if len(fix):
            c_node = np.concatenate([c_node, last_node[fix]])
            c_route = np.concatenate([c_route, fix])
            c_pos = np.concatenate([c_pos, NOHIT[fix]])
            c_parent = np.concatenate([c_parent, np.full(len(fix), root, np.int64)])
        order = np.lexsort((c_pos, c_route, c_node))
        sn = c_node[order]
        first = np.ones(len(sn), bool)
        first[1:] = sn[1:] != sn[:-1]
        return (sn[first], c_route[order][first], c_pos[order][first],
                c_parent[order][first])

    def owned_lt_from(own_nodes: np.ndarray, own_route: np.ndarray) -> np.ndarray:
        if len(own_nodes) == 0:
            return np.zeros(total, bool)
        j = np.searchsorted(own_nodes, flat)
        jj = np.minimum(j, len(own_nodes) - 1)
        return (own_nodes[jj] == flat) & (own_route[jj] < ridx)

    cut = cuts_from(None)
    own = claims_from(cut)
    for _ in range(64):
        new_cut = cuts_from(owned_lt_from(own[0], own[1]))
        if np.array_equal(new_cut, cut):
            break
        cut = new_cut
        own = claims_from(cut)
    else:
        return False
    own_nodes, own_route, own_pos, own_parent = own
    if len(own_nodes):
        app_order = np.lexsort((own_pos, own_route))
        tree._bulk_attach(own_nodes[app_order], own_parent[app_order])
    return True


class Forest:
    """All dataflow trees + the AD tree."""

    def __init__(self, overlay: MultiRingOverlay):
        self.overlay = overlay
        self.trees: dict[int, DataflowTree] = {}
        self.app_names: dict[str, int] = {}
        self.ad_tree: DataflowTree | None = None
        self.ad_registry: dict[int, dict] = {}  # app_id -> meta (held at AD root)

    # -- tree construction (union of JOIN paths) ------------------------------

    def app_id_of(self, name: str, salt: str = "") -> int:
        return sha1_id(name, self.overlay.space.total_bits, salt)

    def _rendezvous(self, key: int, restrict_zone: int | None) -> int:
        space = self.overlay.space
        if restrict_zone is not None:
            nid = self.overlay._zone_closest(restrict_zone, space.suffix_of(key))
            assert nid is not None
            return nid
        zone = self.overlay.nearest_zone(space.zone_of(key))
        return self.overlay._zone_closest(zone, space.suffix_of(key))

    def create_tree(
        self,
        name: str,
        *,
        salt: str = "",
        restrict_zone: int | None = None,
        fanout_bits: int | None = None,
        meta=None,
    ) -> DataflowTree:
        app_id = self.app_id_of(name, salt)
        root = self._rendezvous(app_id, restrict_zone)
        tree = DataflowTree(app_id=app_id, root=root, meta=meta or {"name": name})
        tree.meta.setdefault("restrict_zone", restrict_zone)
        tree.meta.setdefault("fanout_bits", fanout_bits)
        self.trees[app_id] = tree
        self.app_names[name] = app_id
        self._advertise(app_id, tree.meta)
        return tree

    @staticmethod
    def _graft_path(tree: DataflowTree, path: list[int]) -> None:
        """Union-of-JOIN-paths rule: register child->parent edges along the
        route until the path meets the existing tree."""
        for a, b in zip(path, path[1:]):
            if a == tree.root or a in tree.parent:
                return
            tree.parent[a] = b
            tree.children.setdefault(b, []).append(a)
        last = path[-1]
        if last != tree.root and last not in tree.parent:
            tree.parent[last] = tree.root
            tree.children.setdefault(tree.root, []).append(last)

    def subscribe(self, app_id: int, node: int) -> RouteResult:
        """JOIN: route toward AppId; graft onto the first tree node hit."""
        tree = self.trees[app_id]
        res = self.overlay.route(
            node,
            app_id,
            restrict_zone=tree.meta.get("restrict_zone"),
            base_bits=tree.meta.get("fanout_bits"),
        )
        tree.members.add(node)
        self._graft_path(tree, res.path)
        return res

    def subscribe_many(self, app_id: int, nodes, *, chunk: int = 1 << 16) -> np.ndarray:
        """Bulk JOIN: resolve every subscriber's route in one
        ``route_many`` batch per chunk and graft the union of paths with
        a vectorized first-hit-wins merge whose tie-break is
        sequential-subscribe order — the resulting tree is node-for-node
        identical to calling ``subscribe`` in a loop (the oracle; gated
        in bench_scale and tests/test_forest.py).  Chunks are processed
        in input order, each grafting against the tree the previous
        chunks left, so chunking cannot change the result.  Returns the
        delivered hop count per subscriber."""
        tree = self.trees[app_id]
        arr = np.asarray(list(nodes) if not isinstance(nodes, np.ndarray) else nodes,
                         np.int64).ravel()
        hops_out = np.zeros(len(arr), np.int64)
        if len(arr) == 0:
            return hops_out
        rz = tree.meta.get("restrict_zone")
        bb = tree.meta.get("fanout_bits")
        for lo in range(0, len(arr), chunk):
            part = arr[lo : lo + chunk]
            batch = self.overlay.route_many(
                part,
                np.full(len(part), tree.app_id, np.int64),
                restrict_zone=rz,
                base_bits=bb,
            )
            tree.members.update(part.tolist())
            flat, offsets = batch.paths_flat()
            if not _graft_paths_bulk(tree, flat, offsets):
                for k in range(len(part)):  # unreachable in practice: cap hit
                    self._graft_path(tree, batch.path(k))
            hops_out[lo : lo + len(part)] = batch.hops
        return hops_out

    def unsubscribe(self, app_id: int, node: int) -> None:
        """LEAVE: prune if the node is a leaf with no subtree members."""
        tree = self.trees[app_id]
        tree.members.discard(node)
        while (
            node != tree.root
            and not tree.children.get(node)
            and node not in tree.members
            and node in tree.parent
        ):
            p = tree.parent.pop(node)
            tree.children[p].remove(node)
            node = p

    # -- placement re-grafts + bulk LEAVE --------------------------------------

    @staticmethod
    def _regraft_edge(tree: DataflowTree, node: int, new_parent: int) -> int:
        """Move one child->parent edge through the O(1) list primitives.
        The parent-map update goes through ``_ParentView.__setitem__`` on
        an existing key, which preserves the node's insertion sequence —
        so re-grafts never reorder ``tree.parent`` iteration."""
        old = tree.parent[node]
        tree.children[old].remove(node)
        tree.parent[node] = new_parent
        tree.children.setdefault(new_parent, []).append(node)
        return old

    @staticmethod
    def _check_regraft(tree: DataflowTree, node: int, new_parent: int) -> None:
        if node == tree.root:
            raise ValueError(f"cannot re-graft the root {node}")
        if node not in tree.parent:
            raise KeyError(node)
        if new_parent != tree.root and new_parent not in tree.parent:
            raise KeyError(new_parent)
        # cycle guard: the new parent must not live in node's subtree
        cur, hops = new_parent, 0
        while cur != tree.root:
            if cur == node:
                raise ValueError(
                    f"regraft cycle: {new_parent} is in the subtree of {node}"
                )
            cur = tree.parent[cur]
            hops += 1
            if hops > len(tree.parent) + 1:
                raise RuntimeError("corrupt tree: parent walk did not terminate")

    def regraft(self, app_id: int, node: int, new_parent: int) -> int:
        """Move ``node`` (with its whole subtree) under ``new_parent``
        after validating reachability and acyclicity.  Scalar oracle for
        :meth:`regraft_many`; returns the old parent."""
        tree = self.trees[app_id]
        self._check_regraft(tree, node, new_parent)
        return self._regraft_edge(tree, node, new_parent)

    def regraft_many(self, app_id: int, moves, *, strict: bool = True) -> list[tuple[int, int]]:
        """Batched placement re-graft: apply ``(node, new_parent)`` moves
        in input order, node-for-node identical to calling :meth:`regraft`
        in a loop (the oracle; tests/test_placement.py).

        Independent batches — the common case, since the placement engine
        only offers attachment points outside every mover's subtree — are
        validated with ONE vectorized ``paths_matrix`` pass: if no mover
        appears on any target's root path, every target's ancestry is
        invariant under the whole batch, so all sequential cycle checks
        are guaranteed to pass and the per-move walks are skipped.
        Interacting batches fall back to sequential validation; with
        ``strict=False`` invalid moves are skipped instead of raising.
        Returns the list of applied ``(node, new_parent)`` pairs."""
        tree = self.trees[app_id]
        pairs = [(int(n), int(p)) for n, p in moves]
        if not pairs:
            return []
        nodes = np.asarray([n for n, _ in pairs], np.int64)
        targets = np.asarray([p for _, p in pairs], np.int64)
        fast = len(np.unique(nodes)) == len(nodes)
        if fast:
            try:
                mat = tree.paths_matrix(targets)
            except (KeyError, RuntimeError):
                fast = False
            else:
                fast = not np.isin(mat, nodes).any() and all(
                    n != tree.root and n in tree.parent for n in nodes.tolist()
                )
        if fast:
            for n, p in pairs:
                self._regraft_edge(tree, n, p)
            return pairs
        applied: list[tuple[int, int]] = []
        for n, p in pairs:
            try:
                self._check_regraft(tree, n, p)
            except (KeyError, ValueError):
                if strict:
                    raise
                continue
            self._regraft_edge(tree, n, p)
            applied.append((n, p))
        return applied

    def unsubscribe_one(self, app_id: int, node: int) -> None:
        """Scalar LEAVE with relay splice — the oracle for
        :meth:`unsubscribe_many`.  A leaving interior node hands its
        children to its parent (in child order, through the shared
        re-graft primitive) and is then pruned exactly like
        :meth:`unsubscribe`; the root only drops membership (masters
        leave through recovery, not LEAVE)."""
        tree = self.trees[app_id]
        tree.members.discard(node)
        if node == tree.root or node not in tree.parent:
            return
        kids = tree.children.get(node)
        if kids:
            p = tree.parent[node]
            for c in list(kids):
                self._regraft_edge(tree, c, p)
        self.unsubscribe(app_id, node)

    def unsubscribe_many(self, app_id: int, nodes) -> None:
        """Bulk LEAVE (mass-leave / zone-outage repair).  Drops all
        memberships, splices each leaving relay's children to its current
        parent in input order (same primitive as :meth:`unsubscribe_one`),
        then prunes the dead chains with a vectorized fixpoint: each round
        is one array mask over the candidate set (attached, childless,
        non-member, non-root), the pruned batch's parents become the next
        candidates.  Splices commute with deferred pruning (a spliced-out
        leaver is never again a splice target, and linked-list removals
        preserve the order of survivors), so the result is node-for-node
        identical to sequential :meth:`unsubscribe_one` calls
        (tests/test_placement.py)."""
        tree = self.trees[app_id]
        leave = [int(n) for n in nodes]
        if not leave:
            return
        for n in leave:
            tree.members.discard(n)
        for n in leave:
            if n == tree.root or n not in tree.parent:
                continue
            kids = tree.children.get(n)
            if kids:
                p = tree.parent[n]
                for c in list(kids):
                    self._regraft_edge(tree, c, p)
        cand = np.unique(np.asarray([n for n in leave if n != tree.root], np.int64))
        while len(cand):
            cache = tree._ensure_cache()
            srt, slots_srt = cache["ids_sorted"], cache["slots_sorted"]
            if len(srt) == 0:
                break
            j = np.searchsorted(srt, cand)
            jj = np.minimum(j, len(srt) - 1)
            known = (j < len(srt)) & (srt[jj] == cand)
            cs = slots_srt[jj[known]]
            ids = cand[known]
            if len(ids) == 0:
                break
            childless = ~(tree._ch_present[cs] & (tree._ch_len[cs] > 0))
            attached = tree._par[cs] >= 0
            marr = (
                np.fromiter(tree.members, np.int64, len(tree.members))
                if tree.members
                else np.empty(0, np.int64)
            )
            mask = childless & attached & (ids != tree.root) & ~np.isin(ids, marr)
            doomed = cs[mask]
            if len(doomed) == 0:
                break
            parents = np.unique(tree._ids[tree._par[doomed]])
            for s in doomed.tolist():
                nid = int(tree._ids[s])
                p = tree.parent.pop(nid)
                tree.children[p].remove(nid)
            cand = parents

    # -- AD tree (advertise / discover) ---------------------------------------

    def _ensure_ad_tree(self) -> DataflowTree:
        if self.ad_tree is None:
            ad_id = self.app_id_of(AD_TOPIC)
            root = self._rendezvous(ad_id, None)
            self.ad_tree = DataflowTree(app_id=ad_id, root=root, meta={"name": AD_TOPIC})
        return self.ad_tree

    def _advertise(self, app_id: int, meta: dict) -> None:
        """The new master JOINs the AD tree and pushes (AppId, meta) to its
        root, which maintains the registry (paper Appendix A)."""
        ad = self._ensure_ad_tree()
        master = self.trees[app_id].root
        if master != ad.root and master not in ad.parent:
            res = self.overlay.route(master, ad.app_id)
            ad.members.add(master)
            self._graft_path(ad, res.path)
        self.ad_registry[app_id] = dict(meta)

    def discover(self, node: int, *, leave_after: bool = True) -> dict[int, dict]:
        """A node subscribes to the AD tree, receives the registry of running
        applications, and (by default) leaves immediately."""
        ad = self._ensure_ad_tree()
        res = self.overlay.route(node, ad.app_id)
        registry = dict(self.ad_registry)
        if not leave_after:
            ad.members.add(node)
            self._graft_path(ad, res.path)
        return registry

    # -- stats ----------------------------------------------------------------

    def masters_per_node(self) -> dict[int, int]:
        out: dict[int, int] = {}
        for t in self.trees.values():
            out[t.root] = out.get(t.root, 0) + 1
        return out
