"""Layer 2: publish/subscribe forest abstraction (paper §IV-C).

Each FL application gets a dataflow tree built from the union of JOIN
message routes toward AppId; the rendezvous node (numerically closest to
AppId) is the root = master; internal nodes keep children tables and act
as coordinator/aggregator/selector; leaves are workers.  The masters of
all trees join a shared advertise-discover (AD) tree keyed by
``hash("AD application")`` that carries the application registry.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

from .nodeid import numerically_closest, sha1_id
from .overlay import MultiRingOverlay, RouteResult

AD_TOPIC = "AD application"


@dataclass
class DataflowTree:
    app_id: int
    root: int
    parent: dict[int, int] = field(default_factory=dict)  # node -> parent
    children: dict[int, list[int]] = field(default_factory=dict)  # children table
    members: set[int] = field(default_factory=set)  # subscribers (workers)
    meta: dict = field(default_factory=dict)

    def nodes(self) -> set[int]:
        return {self.root} | set(self.parent)

    def depth_of(self, node: int) -> int:
        d, cur = 0, node
        while cur != self.root:
            cur = self.parent[cur]
            d += 1
            if d > len(self.parent) + 1:
                raise RuntimeError("cycle in tree")
        return d

    def depth(self) -> int:
        return max((self.depth_of(n) for n in self.nodes()), default=0)

    def levels(self) -> list[list[int]]:
        by_depth: dict[int, list[int]] = {}
        for n in self.nodes():
            by_depth.setdefault(self.depth_of(n), []).append(n)
        return [by_depth[d] for d in sorted(by_depth)]

    def fanout(self) -> int:
        return max((len(c) for c in self.children.values()), default=0)

    def path_to_root(self, node: int) -> list[int]:
        out = [node]
        while out[-1] != self.root:
            out.append(self.parent[out[-1]])
        return out

    # -- dataflow schedules (latency model supplied by the overlay) ----------

    def aggregation_schedule(self) -> list[list[tuple[int, list[int]]]]:
        """Per-level batches of (parent, children) groups, deepest level
        first, so partial aggregates flow leaves -> root: every internal
        node appears exactly once as a parent, and each level's groups
        are independent (executable as one batched kernel call)."""
        by_depth: dict[int, list[tuple[int, list[int]]]] = {}
        for parent, kids in self.children.items():
            if kids:
                by_depth.setdefault(self.depth_of(parent), []).append(
                    (parent, sorted(kids))
                )
        return [
            sorted(by_depth[d]) for d in sorted(by_depth, reverse=True)
        ]

    def broadcast_schedule(self) -> list[list[tuple[int, list[int]]]]:
        """The same level batches root -> leaves (dissemination order)."""
        return list(reversed(self.aggregation_schedule()))

    def broadcast_time(
        self,
        overlay: MultiRingOverlay,
        payload_ms: float = 0.0,
        *,
        pipelined: bool = False,
        chunks: int = 8,
    ) -> float:
        """Model dissemination root->leaves: max over leaves of path latency.

        ``pipelined=True`` prices each root->leaf path with per-edge
        store-and-forward overlap: the payload is cut into ``chunks``
        pieces so a hop starts forwarding as soon as the first piece
        lands — a D-hop payload costs t*(D+C-1)/C instead of t*D,
        approaching the max single edge as C grows (never slower than
        the synchronous sum).
        """
        t = 0.0
        for n in self.nodes():
            if n not in self.children or not self.children[n]:  # leaf
                path = list(reversed(self.path_to_root(n)))
                edges = len(path) - 1
                if pipelined and edges > 1:
                    c = max(1, int(chunks))
                    payload_total = payload_ms * (edges + c - 1) / c
                else:
                    payload_total = payload_ms * edges
                t = max(t, overlay.path_latency(path) + payload_total)
        return t

    def aggregation_time(self, overlay: MultiRingOverlay, payload_ms: float = 0.0) -> float:
        return self.broadcast_time(overlay, payload_ms)  # symmetric schedule


class Forest:
    """All dataflow trees + the AD tree."""

    def __init__(self, overlay: MultiRingOverlay, *, seed: int = 0):
        self.overlay = overlay
        self.trees: dict[int, DataflowTree] = {}
        self.app_names: dict[str, int] = {}
        self.ad_tree: DataflowTree | None = None
        self.ad_registry: dict[int, dict] = {}  # app_id -> meta (held at AD root)

    # -- tree construction (union of JOIN paths) ------------------------------

    def app_id_of(self, name: str, salt: str = "") -> int:
        return sha1_id(name, self.overlay.space.total_bits, salt)

    def _rendezvous(self, key: int, restrict_zone: int | None) -> int:
        space = self.overlay.space
        if restrict_zone is not None:
            nid = self.overlay._zone_closest(restrict_zone, space.suffix_of(key))
            assert nid is not None
            return nid
        zone = self.overlay.nearest_zone(space.zone_of(key))
        return self.overlay._zone_closest(zone, space.suffix_of(key))

    def create_tree(
        self,
        name: str,
        *,
        salt: str = "",
        restrict_zone: int | None = None,
        fanout_bits: int | None = None,
        meta=None,
    ) -> DataflowTree:
        app_id = self.app_id_of(name, salt)
        root = self._rendezvous(app_id, restrict_zone)
        tree = DataflowTree(app_id=app_id, root=root, meta=meta or {"name": name})
        tree.meta.setdefault("restrict_zone", restrict_zone)
        tree.meta.setdefault("fanout_bits", fanout_bits)
        self.trees[app_id] = tree
        self.app_names[name] = app_id
        self._advertise(app_id, tree.meta)
        return tree

    @staticmethod
    def _graft_path(tree: DataflowTree, path: list[int]) -> None:
        """Union-of-JOIN-paths rule: register child->parent edges along the
        route until the path meets the existing tree."""
        for a, b in zip(path, path[1:]):
            if a == tree.root or a in tree.parent:
                return
            tree.parent[a] = b
            tree.children.setdefault(b, []).append(a)
        last = path[-1]
        if last != tree.root and last not in tree.parent:
            tree.parent[last] = tree.root
            tree.children.setdefault(tree.root, []).append(last)

    def subscribe(self, app_id: int, node: int) -> RouteResult:
        """JOIN: route toward AppId; graft onto the first tree node hit."""
        tree = self.trees[app_id]
        res = self.overlay.route(
            node,
            app_id,
            restrict_zone=tree.meta.get("restrict_zone"),
            base_bits=tree.meta.get("fanout_bits"),
        )
        tree.members.add(node)
        self._graft_path(tree, res.path)
        return res

    def unsubscribe(self, app_id: int, node: int) -> None:
        """LEAVE: prune if the node is a leaf with no subtree members."""
        tree = self.trees[app_id]
        tree.members.discard(node)
        while (
            node != tree.root
            and not tree.children.get(node)
            and node not in tree.members
            and node in tree.parent
        ):
            p = tree.parent.pop(node)
            tree.children[p].remove(node)
            node = p

    # -- AD tree (advertise / discover) ---------------------------------------

    def _ensure_ad_tree(self) -> DataflowTree:
        if self.ad_tree is None:
            ad_id = self.app_id_of(AD_TOPIC)
            root = self._rendezvous(ad_id, None)
            self.ad_tree = DataflowTree(app_id=ad_id, root=root, meta={"name": AD_TOPIC})
        return self.ad_tree

    def _advertise(self, app_id: int, meta: dict) -> None:
        """The new master JOINs the AD tree and pushes (AppId, meta) to its
        root, which maintains the registry (paper Appendix A)."""
        ad = self._ensure_ad_tree()
        master = self.trees[app_id].root
        if master != ad.root and master not in ad.parent:
            res = self.overlay.route(master, ad.app_id)
            ad.members.add(master)
            self._graft_path(ad, res.path)
        self.ad_registry[app_id] = dict(meta)

    def discover(self, node: int, *, leave_after: bool = True) -> dict[int, dict]:
        """A node subscribes to the AD tree, receives the registry of running
        applications, and (by default) leaves immediately."""
        ad = self._ensure_ad_tree()
        res = self.overlay.route(node, ad.app_id)
        registry = dict(self.ad_registry)
        if not leave_after:
            ad.members.add(node)
            self._graft_path(ad, res.path)
        return registry

    # -- stats ----------------------------------------------------------------

    def masters_per_node(self) -> dict[int, int]:
        out: dict[int, int] = {}
        for t in self.trees.values():
            out[t.root] = out.get(t.root, 0) + 1
        return out
