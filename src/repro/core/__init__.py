"""Totoro+ core: locality-aware P2P multi-ring, pub/sub forest,
game-theoretic path planning, failure recovery, high-level API."""
