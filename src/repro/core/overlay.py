"""Layer 1: locality-aware P2P multi-ring structure (paper §IV-B).

Edge nodes self-organize into m zone rings via Ratnasamy–Shenker
distributed binning over landmark RTTs.  Each node keeps:
  - a two-level routing table — level 1 fingers across zones at
    (P_x + 2^{i-1}) mod 2^m (scaled by 2^n), level 2 fingers within the
    zone at (S_y + j*2^{b*i}) mod 2^n for digits j in [1, 2^b) — per the
    paper's table definition, generalized to base 2^b so the dataflow-tree
    fanout is configurable (the paper evaluates b = 3, 4, 5);
  - a leaf set (closest ids both sides, for repair + final delivery);
  - a neighborhood set (physically closest nodes, for state replication).

Scaling note: tables are evaluated *by rule* against the live membership
(sorted-array successor lookup) rather than materialized per node, so the
simulator routes on 10^6-node rings in microseconds while following
exactly the hop sequence a materialized table would produce;
``routing_table_of`` materializes a node's table for inspection/tests.
Routing never uses global knowledge beyond each hop's own entries.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field

import numpy as np

from .nodeid import IdSpace, abs_ring_distance, ring_distance


@dataclass
class RouteResult:
    path: list[int]  # node ids visited (src first, destination last)
    hops: int
    blocked: bool = False  # administrative isolation block

    @property
    def dest(self) -> int:
        return self.path[-1]


class MultiRingOverlay:
    def __init__(
        self,
        space: IdSpace,
        *,
        base_bits: int = 4,
        leaf_size: int = 24,
        neighborhood_size: int = 8,
        seed: int = 0,
    ):
        self.space = space
        self.b = base_bits
        self.leaf_size = leaf_size
        self.neighborhood_size = neighborhood_size
        self.rng = np.random.default_rng(seed)
        self.zone_members: dict[int, list[int]] = {}  # zone -> sorted suffixes
        self.coords: dict[int, tuple[float, float]] = {}  # node_id -> position
        self.alive: set[int] = set()
        self.bandwidth: dict[int, float] = {}  # Mbps per node
        self.physical_group: dict[int, int] = {}  # logical id -> physical id (App. L)

    # -- membership ---------------------------------------------------------

    def join(self, zone: int, suffix: int, coord=(0.0, 0.0), bandwidth: float = 100.0) -> int:
        nid = self.space.make(zone, suffix)
        members = self.zone_members.setdefault(zone, [])
        i = bisect.bisect_left(members, suffix)
        if i < len(members) and members[i] == suffix:
            raise ValueError(f"suffix collision {suffix} in zone {zone}")
        members.insert(i, suffix)
        self.coords[nid] = tuple(coord)
        self.bandwidth[nid] = bandwidth
        self.alive.add(nid)
        return nid

    def join_random(self, zone: int, coord=(0.0, 0.0), bandwidth: float = 100.0) -> int:
        while True:
            suffix = int(self.rng.integers(0, self.space.suffix_space))
            try:
                return self.join(zone, suffix, coord, bandwidth)
            except ValueError:
                continue

    def join_weighted(self, zone: int, units: int, coord=(0.0, 0.0), bandwidth: float = 100.0) -> list[int]:
        """Appendix L: heterogeneous resources via LOGICAL nodes — a
        physical node with ``units`` resource units joins as that many
        P2P nodes (more units => proportionally more master assignments);
        the ids are recorded as one physical group for accounting."""
        ids = [self.join_random(zone, coord, bandwidth) for _ in range(max(1, units))]
        group = ids[0]
        for nid in ids:
            self.physical_group[nid] = group
        return ids

    def leave(self, node_id: int) -> None:
        zone, suffix = self.space.zone_of(node_id), self.space.suffix_of(node_id)
        members = self.zone_members.get(zone, [])
        i = bisect.bisect_left(members, suffix)
        if i < len(members) and members[i] == suffix:
            members.pop(i)
        self.alive.discard(node_id)

    def fail(self, node_id: int) -> None:
        """Crash-fail (no graceful handoff) — same membership effect."""
        self.leave(node_id)

    @property
    def num_nodes(self) -> int:
        return len(self.alive)

    def zones(self) -> list[int]:
        return [z for z, m in self.zone_members.items() if m]

    def nodes(self) -> list[int]:
        return sorted(self.alive)

    # -- successor / closest lookups (the "by-rule" table evaluation) --------

    def _zone_successor(self, zone: int, suffix: int) -> int | None:
        members = self.zone_members.get(zone)
        if not members:
            return None
        i = bisect.bisect_left(members, suffix) % len(members)
        return self.space.make(zone, members[i])

    def _zone_closest(self, zone: int, suffix: int) -> int | None:
        members = self.zone_members.get(zone)
        if not members:
            return None
        i = bisect.bisect_left(members, suffix)
        cands = {members[i % len(members)], members[(i - 1) % len(members)]}
        best = min(
            cands, key=lambda s: abs_ring_distance(suffix, s, self.space.suffix_space)
        )
        return self.space.make(zone, best)

    def nearest_zone(self, zone: int) -> int | None:
        """Next non-empty zone clockwise from `zone` (incl. itself)."""
        for d in range(self.space.num_zones):
            z = (zone + d) % self.space.num_zones
            if self.zone_members.get(z):
                return z
        return None

    # -- leaf / neighborhood sets --------------------------------------------

    def leaf_set(self, node_id: int) -> list[int]:
        zone, suffix = self.space.zone_of(node_id), self.space.suffix_of(node_id)
        members = self.zone_members.get(zone, [])
        if len(members) <= 1:
            return []
        i = bisect.bisect_left(members, suffix)
        half = self.leaf_size // 2
        out = []
        for d in range(1, half + 1):
            out.append(self.space.make(zone, members[(i + d) % len(members)]))
            out.append(self.space.make(zone, members[(i - d) % len(members)]))
        return [x for x in dict.fromkeys(out) if x != node_id]

    def neighborhood_set(self, node_id: int) -> list[int]:
        """Physically closest live nodes (for master state replication)."""
        cx, cy = self.coords[node_id]
        others = [n for n in self.alive if n != node_id]
        others.sort(key=lambda n: (self.coords[n][0] - cx) ** 2 + (self.coords[n][1] - cy) ** 2)
        return others[: self.neighborhood_size]

    # -- routing -------------------------------------------------------------

    def _digit_prefix_len(self, a: int, b_: int, b: int | None = None) -> int:
        """Common prefix length in base-2^b digits, MSB first."""
        b = b or self.b
        n = self.space.suffix_bits
        rows = (n + b - 1) // b
        for p in range(rows):
            shift = max(0, n - b * (p + 1))
            if (a >> shift) != (b_ >> shift):
                return p
        return rows

    def _next_hop_in_zone(
        self, cur_suffix: int, key_suffix: int, zone: int, b: int | None = None
    ) -> int | None:
        """Pastry-style digit-fixing hop: jump to the canonical node of the
        range sharing one more base-2^b digit with the key.  Canonical =
        clockwise successor of the range start, so paths from different
        sources CONVERGE (the paper's path-convergence property) and tree
        fanout is bounded by 2^b (+ leaf-set final hops)."""
        b = b or self.b
        n = self.space.suffix_bits
        rows = (n + b - 1) // b
        p = self._digit_prefix_len(cur_suffix, key_suffix, b)
        while p < rows:
            shift = max(0, n - b * (p + 1))
            # Plaxton rule: fix the key's next digit, KEEP the source's
            # remaining digits — paths from different sources spread across
            # the range and converge progressively (bounded tree fanout),
            # instead of all landing on one canonical node per level.
            target = ((key_suffix >> shift) << shift) | (cur_suffix & ((1 << shift) - 1))
            nxt = self._zone_successor(zone, target)
            if nxt is None:
                return None
            ns = self.space.suffix_of(nxt)
            if (ns >> shift) == (key_suffix >> shift) and ns != cur_suffix:
                return nxt
            p += 1  # empty range: try to fix the next digit
        # all populated ranges exhausted: leaf-set final hop
        nxt = self._zone_closest(zone, key_suffix)
        return nxt if nxt is not None and self.space.suffix_of(nxt) != cur_suffix else None

    def route(
        self,
        src: int,
        key: int,
        *,
        restrict_zone: int | None = None,
        base_bits: int | None = None,
        max_hops: int | None = None,
    ) -> RouteResult:
        """Greedy two-level prefix/finger routing to the node numerically
        closest to `key`.  ``restrict_zone`` enforces administrative
        isolation (level-1 entries disabled; cross-zone packets blocked);
        ``base_bits`` overrides the digit base 2^b for this route only
        (per-tree fanout — one app's choice must not leak into others)."""
        space = self.space
        cur = src
        path = [cur]
        key_zone, key_suffix = space.zone_of(key), space.suffix_of(key)
        max_hops = max_hops or (4 * space.total_bits)

        for _ in range(max_hops):
            cur_zone = space.zone_of(cur)
            if restrict_zone is not None and cur_zone != restrict_zone:
                return RouteResult(path, len(path) - 1, blocked=True)

            if cur_zone != key_zone and restrict_zone is None:
                # level 1: finger across zones toward the key's zone
                target_zone = self.nearest_zone(key_zone)
                if target_zone is None:
                    break
                if target_zone == cur_zone:
                    key_zone = cur_zone  # key's zone empty -> deliver here
                    continue
                dz = ring_distance(cur_zone, target_zone, space.num_zones)
                step = 1 << (dz.bit_length() - 1)
                hop_zone = (cur_zone + step) % space.num_zones
                hop_zone = self.nearest_zone(hop_zone)
                # land near the *source's* suffix (spread; suffix digits are
                # fixed by level-2 routing once inside the key's zone)
                nxt = self._zone_closest(hop_zone, space.suffix_of(cur))
                if nxt is None or nxt == cur:
                    break
                cur = nxt
                path.append(cur)
                continue

            if restrict_zone is not None and key_zone != restrict_zone:
                key_zone = restrict_zone  # deliver within the restricted ring

            # destination reached: the numerically closest node in the zone
            if cur == self._zone_closest(cur_zone, key_suffix):
                break

            # level 2: canonical digit-fixing within the zone
            nxt = self._next_hop_in_zone(space.suffix_of(cur), key_suffix, cur_zone, base_bits)
            if nxt is None or nxt == cur or nxt in path[-2:]:
                # no better hop / would cycle: deliver via leaf set
                final = self._zone_closest(cur_zone, key_suffix)
                if final is not None and final != cur and final not in path:
                    path.append(final)
                break
            cur = nxt
            path.append(cur)

        return RouteResult(path, len(path) - 1)

    # -- table materialization (inspection / tests) --------------------------

    def routing_table_of(self, node_id: int) -> dict:
        """Materialize the node's two-level routing table per the paper's
        entry rule: L1[i] = (P_x + 2^{i-1}) mod 2^m * 2^n,
        L2 rows of base-2^b digit fingers."""
        space = self.space
        zone, suffix = space.zone_of(node_id), space.suffix_of(node_id)
        l1 = []
        for i in range(1, space.zone_bits + 1):
            tz = (zone + (1 << (i - 1))) % space.num_zones
            tz_live = self.nearest_zone(tz)
            l1.append(
                self._zone_closest(tz_live, suffix) if tz_live is not None else None
            )
        l2 = []
        rows = (space.suffix_bits + self.b - 1) // self.b
        for i in range(rows):
            row = []
            for j in range(1, 1 << self.b):
                t = (suffix + j * (1 << (self.b * i))) % space.suffix_space
                row.append(self._zone_closest(zone, t))
            l2.append(row)
        return {"level1": l1, "level2": l2}

    # -- latency model --------------------------------------------------------

    def rtt(self, a: int, b: int) -> float:
        """Synthetic RTT (ms) from coordinates: 0.1 ms/unit + 1 ms base."""
        (ax, ay), (bx, by) = self.coords[a], self.coords[b]
        return 1.0 + 0.1 * ((ax - bx) ** 2 + (ay - by) ** 2) ** 0.5

    def path_latency(self, path: list[int]) -> float:
        return sum(self.rtt(a, b) for a, b in zip(path, path[1:]))


# ---------------------------------------------------------------------------
# Ratnasamy–Shenker distributed binning (paper §IV-B, [55])


def distributed_binning(
    coords: np.ndarray, num_landmarks: int, *, levels: int = 3, seed: int = 0
) -> np.ndarray:
    """Bin nodes by landmark-RTT ordering (+ RTT-level quantization).

    Returns an integer bin id per node; bins with identical landmark
    orderings and level vectors land in the same zone — nearby nodes get
    the same bin without any coordination beyond landmark pings.
    """
    rng = np.random.default_rng(seed)
    landmarks = coords[rng.choice(len(coords), size=num_landmarks, replace=False)]
    d = np.sqrt(((coords[:, None, :] - landmarks[None, :, :]) ** 2).sum(-1))  # (N, L)
    order = np.argsort(d, axis=1)  # landmark ordering
    dmax = d.max() + 1e-9
    level = np.minimum((d / dmax * levels).astype(int), levels - 1)
    bins: dict[tuple, int] = {}
    out = np.zeros(len(coords), dtype=np.int64)
    for i in range(len(coords)):
        key = (tuple(order[i]), tuple(level[i][order[i]]))
        out[i] = bins.setdefault(key, len(bins))
    return out


def build_overlay_from_coords(
    coords: np.ndarray,
    space: IdSpace,
    *,
    base_bits: int = 4,
    bandwidth_range=(20.0, 100.0),
    seed: int = 0,
) -> tuple[MultiRingOverlay, list[int]]:
    """EUA-style construction: bin nodes into zones, assign random suffixes."""
    overlay = MultiRingOverlay(space, base_bits=base_bits, seed=seed)
    nbins = distributed_binning(coords, min(space.num_zones, max(2, space.num_zones)), seed=seed)
    zones = nbins % space.num_zones
    rng = np.random.default_rng(seed + 1)
    ids = []
    for i, z in enumerate(zones):
        bw = float(rng.uniform(*bandwidth_range))
        ids.append(overlay.join_random(int(z), coord=coords[i], bandwidth=bw))
    return overlay, ids
