"""Layer 1: locality-aware P2P multi-ring structure (paper §IV-B).

Edge nodes self-organize into m zone rings via Ratnasamy–Shenker
distributed binning over landmark RTTs.  Each node keeps:
  - a two-level routing table — level 1 fingers across zones at
    (P_x + 2^{i-1}) mod 2^m (scaled by 2^n), level 2 fingers within the
    zone at (S_y + j*2^{b*i}) mod 2^n for digits j in [1, 2^b) — per the
    paper's table definition, generalized to base 2^b so the dataflow-tree
    fanout is configurable (the paper evaluates b = 3, 4, 5);
  - a leaf set (closest ids both sides, for repair + final delivery);
  - a neighborhood set (physically closest nodes, for state replication).

Scaling note: tables are evaluated *by rule* against the live membership
(sorted-array successor lookup) rather than materialized per node, so the
simulator routes on 10^6-node rings while following exactly the hop
sequence a materialized table would produce; ``routing_table_of``
materializes a node's table for inspection/tests.  Routing never uses
global knowledge beyond each hop's own entries.

Array-of-structs layout (the "scale layer", docs/performance.md): node
state lives in flat numpy arrays — append-only id/coord/bandwidth rows
plus an alive mask — and each zone ring is a sorted int64 suffix array
with a parallel row array, grown in place with capacity doubling.  The
public mapping/set attributes (``coords``, ``bandwidth``, ``alive``,
``zone_members``) are thin views over those arrays, so ``forest.py``,
``pathplan.py`` and ``recovery.py`` run unchanged against either layout.
``route_many`` resolves a whole batch of routes in vectorized ring/prefix
arithmetic, hop-for-hop identical to the scalar ``route`` oracle, and
``neighborhood_set`` is backed by an incremental spatial-grid index
instead of a full per-call sort.
"""
from __future__ import annotations

import math
from collections.abc import Mapping, Set
from dataclasses import dataclass

import numpy as np

from .nodeid import IdSpace, abs_ring_distance, ring_distance


@dataclass
class RouteResult:
    path: list[int]  # node ids visited (src first, destination last)
    hops: int
    blocked: bool = False  # administrative isolation block

    @property
    def dest(self) -> int:
        return self.path[-1]


@dataclass
class RouteBatch:
    """Result of ``route_many``: per-route arrays + lazy path recovery.

    ``hops[k]`` / ``dest[k]`` / ``blocked[k]`` / ``latency_ms[k]`` mirror
    the scalar ``RouteResult`` fields of route ``k``; ``path(k)``
    reconstructs the visited node list from the per-iteration snapshots
    (stored as one int64 array per executed hop iteration, not one list
    per route, so a million-route batch stays a handful of arrays).
    """

    hops: np.ndarray  # (K,) int64
    dest: np.ndarray  # (K,) int64 node ids
    blocked: np.ndarray  # (K,) bool
    latency_ms: np.ndarray  # (K,) float64
    _hist: list[np.ndarray]  # per-iteration cur snapshots

    def __len__(self) -> int:
        return len(self.hops)

    def path(self, k: int) -> list[int]:
        """Visited node ids of route ``k`` (src first, destination last)."""
        out = [int(self._hist[0][k])]
        for snap in self._hist[1:]:
            nid = int(snap[k])
            if nid != out[-1]:
                out.append(nid)
        return out

    def result(self, k: int) -> RouteResult:
        return RouteResult(self.path(k), int(self.hops[k]), bool(self.blocked[k]))

    def paths_flat(self) -> tuple[np.ndarray, np.ndarray]:
        """All paths at once as a CSR pair ``(flat, offsets)``:
        ``flat[offsets[k]:offsets[k+1]]`` equals ``path(k)`` (consecutive
        duplicates dropped, src first).  One boolean mask over the
        stacked snapshots instead of K Python reconstructions."""
        H = np.stack(self._hist, axis=1)  # (K, T)
        keep = np.ones(H.shape, bool)
        keep[:, 1:] = H[:, 1:] != H[:, :-1]
        lens = keep.sum(axis=1)
        offsets = np.zeros(len(lens) + 1, np.int64)
        np.cumsum(lens, out=offsets[1:])
        return H[keep], offsets


# ---------------------------------------------------------------------------
# storage primitives


class _ZoneRing:
    """One zone's membership: sorted suffix array + parallel row array.

    Capacity-managed in place (memmove inside the buffer, doubling on
    overflow) so a single join/leave is O(n_zone) element moves with no
    realloc churn, and the live views are zero-copy slices.
    """

    __slots__ = ("suf", "row", "n")

    def __init__(self, capacity: int = 8):
        self.suf = np.empty(max(8, capacity), np.int64)
        self.row = np.empty(max(8, capacity), np.int64)
        self.n = 0

    def view(self) -> np.ndarray:
        return self.suf[: self.n]

    def rows(self) -> np.ndarray:
        return self.row[: self.n]

    def _grow(self, need: int) -> None:
        cap = len(self.suf)
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        for name in ("suf", "row"):
            old = getattr(self, name)
            new = np.empty(cap, np.int64)
            new[: self.n] = old[: self.n]
            setattr(self, name, new)

    def insert(self, i: int, suffix: int, row: int) -> None:
        self._grow(self.n + 1)
        self.suf[i + 1 : self.n + 1] = self.suf[i : self.n]
        self.row[i + 1 : self.n + 1] = self.row[i : self.n]
        self.suf[i] = suffix
        self.row[i] = row
        self.n += 1

    def pop(self, i: int) -> int:
        row = int(self.row[i])
        self.suf[i : self.n - 1] = self.suf[i + 1 : self.n]
        self.row[i : self.n - 1] = self.row[i + 1 : self.n]
        self.n -= 1
        return row

    def bulk_add(self, sufs: np.ndarray, rows: np.ndarray) -> None:
        """Merge a sorted, collision-free batch into the ring in one pass."""
        k = len(sufs)
        if k == 0:
            return
        self._grow(self.n + k)
        merged_suf = np.empty(self.n + k, np.int64)
        merged_row = np.empty(self.n + k, np.int64)
        pos = np.searchsorted(self.suf[: self.n], sufs) + np.arange(k)
        mask = np.zeros(self.n + k, bool)
        mask[pos] = True
        merged_suf[pos], merged_row[pos] = sufs, rows
        merged_suf[~mask], merged_row[~mask] = self.suf[: self.n], self.row[: self.n]
        self.n += k
        self.suf[: self.n] = merged_suf
        self.row[: self.n] = merged_row


class _SpatialGrid:
    """Incremental uniform-grid index over alive node coordinates.

    Cells are a dict keyed by integer cell coords, holding row lists.
    ``knn`` expands Chebyshev cell rings outward and stops once the
    k-th best candidate provably beats every unscanned cell, so a
    neighborhood query touches O(k) nodes instead of sorting all N.
    Maintained incrementally on join/leave/fail; the overlay rebuilds it
    (lazily) when the population drifts far from the build-time size.
    """

    __slots__ = ("h", "x0", "y0", "cells", "built_n", "cmin", "cmax")

    def __init__(self, xy: np.ndarray, rows: np.ndarray):
        n = max(1, len(rows))
        if len(rows):
            x0, y0 = float(xy[rows, 0].min()), float(xy[rows, 1].min())
            span = max(
                float(xy[rows, 0].max()) - x0, float(xy[rows, 1].max()) - y0
            )
        else:
            x0 = y0 = span = 0.0
        self.x0, self.y0 = x0, y0
        self.h = span / max(4.0, math.sqrt(n))
        if self.h <= 0.0:
            self.h = 1.0
        self.cells: dict[tuple[int, int], list[int]] = {}
        self.built_n = len(rows)
        self.cmin = [0, 0]
        self.cmax = [0, 0]
        for r in rows:
            self.add(int(r), float(xy[r, 0]), float(xy[r, 1]))

    def _cell(self, x: float, y: float) -> tuple[int, int]:
        return (int((x - self.x0) // self.h), int((y - self.y0) // self.h))

    def add(self, row: int, x: float, y: float) -> None:
        c = self._cell(x, y)
        self.cells.setdefault(c, []).append(row)
        self.cmin = [min(self.cmin[0], c[0]), min(self.cmin[1], c[1])]
        self.cmax = [max(self.cmax[0], c[0]), max(self.cmax[1], c[1])]

    def remove(self, row: int, x: float, y: float) -> None:
        c = self._cell(x, y)
        bucket = self.cells.get(c)
        if bucket is not None:
            try:
                bucket.remove(row)
            except ValueError:
                pass
            if not bucket:
                del self.cells[c]

    def knn(self, x: float, y: float, k: int, exclude_row: int,
            xy: np.ndarray) -> np.ndarray:
        """Rows of the k nearest alive nodes, sorted by (dist^2, row order
        resolved by the caller).  Returns candidate rows (>= k when
        available) whose k nearest are guaranteed correct."""
        cx, cy = self._cell(x, y)
        max_r = max(
            cx - self.cmin[0], self.cmax[0] - cx,
            cy - self.cmin[1], self.cmax[1] - cy, 0,
        )
        cand: list[int] = []
        d2 = np.empty(0)
        for r in range(max_r + 1):
            if r == 0:
                coords_iter = [(cx, cy)]
            else:
                coords_iter = (
                    [(i, cy - r) for i in range(cx - r, cx + r + 1)]
                    + [(i, cy + r) for i in range(cx - r, cx + r + 1)]
                    + [(cx - r, j) for j in range(cy - r + 1, cy + r)]
                    + [(cx + r, j) for j in range(cy - r + 1, cy + r)]
                )
            ring_rows: list[int] = []
            for c in coords_iter:
                bucket = self.cells.get(c)
                if bucket:
                    ring_rows.extend(bucket)
            if ring_rows:
                rr = np.asarray(
                    [q for q in ring_rows if q != exclude_row], np.int64
                )
                if len(rr):
                    dd = (xy[rr, 0] - x) ** 2 + (xy[rr, 1] - y) ** 2
                    cand.extend(rr.tolist())
                    d2 = np.concatenate([d2, dd])
            # stop once the k-th best beats anything beyond ring r:
            # every unscanned point is at Chebyshev cell distance > r,
            # hence Euclidean distance >= r*h from the query point.
            if len(cand) >= k:
                kth = np.partition(d2, k - 1)[k - 1]
                if kth <= (r * self.h) ** 2:
                    break
        return np.asarray(cand, np.int64)


# ---------------------------------------------------------------------------
# thin views: the legacy dict/set API over the array layout


class _CoordView(Mapping):
    __slots__ = ("_ov",)

    def __init__(self, ov: "MultiRingOverlay"):
        self._ov = ov

    def __getitem__(self, nid: int) -> tuple[float, float]:
        row = self._ov._row_of(nid)
        if row < 0:
            raise KeyError(nid)
        x, y = self._ov._xy[row]
        return (float(x), float(y))

    def __contains__(self, nid) -> bool:
        return self._ov._row_of(nid) >= 0

    def __iter__(self):
        return iter(self._ov._known_ids())

    def __len__(self) -> int:
        return len(self._ov._known_ids())


class _BandwidthView(Mapping):
    __slots__ = ("_ov",)

    def __init__(self, ov: "MultiRingOverlay"):
        self._ov = ov

    def __getitem__(self, nid: int) -> float:
        row = self._ov._row_of(nid)
        if row < 0:
            raise KeyError(nid)
        return float(self._ov._bw[row])

    def __contains__(self, nid) -> bool:
        return self._ov._row_of(nid) >= 0

    def __iter__(self):
        return iter(self._ov._known_ids())

    def __len__(self) -> int:
        return len(self._ov._known_ids())


class _AliveView(Set):
    __slots__ = ("_ov",)

    def __init__(self, ov: "MultiRingOverlay"):
        self._ov = ov

    @classmethod
    def _from_iterable(cls, it):
        return set(it)  # set algebra on the view yields plain sets

    def __contains__(self, nid) -> bool:
        ring = self._ov._rings.get(self._ov.space.zone_of(nid))
        if ring is None or ring.n == 0:
            return False
        suf = self._ov.space.suffix_of(nid)
        i = int(np.searchsorted(ring.view(), suf))
        return i < ring.n and ring.suf[i] == suf

    def __iter__(self):
        space = self._ov.space
        for z, ring in self._ov._rings.items():
            base = z * space.suffix_space
            for s in ring.view().tolist():
                yield base + s

    def __len__(self) -> int:
        return self._ov._num_alive


class _ZoneMembersView(Mapping):
    """zone -> sorted suffix array (live view; supports len/index/iter)."""

    __slots__ = ("_ov",)
    _EMPTY = np.empty(0, np.int64)

    def __init__(self, ov: "MultiRingOverlay"):
        self._ov = ov

    def __getitem__(self, zone: int) -> np.ndarray:
        ring = self._ov._rings.get(zone)
        if ring is None:
            raise KeyError(zone)
        return ring.view()

    def get(self, zone: int, default=None):
        ring = self._ov._rings.get(zone)
        if ring is None:
            return default
        return ring.view()

    def __contains__(self, zone) -> bool:
        return zone in self._ov._rings

    def __iter__(self):
        return iter(self._ov._rings)

    def __len__(self) -> int:
        return len(self._ov._rings)


# ---------------------------------------------------------------------------


class MultiRingOverlay:
    def __init__(
        self,
        space: IdSpace,
        *,
        base_bits: int = 4,
        leaf_size: int = 24,
        neighborhood_size: int = 8,
        seed: int = 0,
    ):
        self.space = space
        self.b = base_bits
        self.leaf_size = leaf_size
        self.neighborhood_size = neighborhood_size
        self.rng = np.random.default_rng(seed)
        # flat node rows (append-only; alive mask distinguishes the dead)
        cap = 64
        self._ids = np.empty(cap, np.int64)
        self._xy = np.empty((cap, 2), np.float64)
        self._bw = np.empty(cap, np.float64)
        self._alive_mask = np.zeros(cap, bool)
        self._nrows = 0
        self._num_alive = 0
        self._dead_rows: dict[int, int] = {}  # node_id -> row (post-leave attrs)
        # per-zone sorted rings
        self._rings: dict[int, _ZoneRing] = {}
        self._occupancy_epoch = 0  # bumps when a zone flips empty<->nonempty
        self._nearest_cache: tuple[int, np.ndarray] | None = None
        self._grid: _SpatialGrid | None = None
        # legacy mapping/set API as thin views over the arrays
        self.zone_members = _ZoneMembersView(self)
        self.coords = _CoordView(self)
        self.alive = _AliveView(self)
        self.bandwidth = _BandwidthView(self)
        self.physical_group: dict[int, int] = {}  # logical id -> physical id (App. L)

    # -- flat-row plumbing ---------------------------------------------------

    def _grow_rows(self, need: int) -> None:
        cap = len(self._ids)
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        n = self._nrows
        for name, shape in (("_ids", (cap,)), ("_xy", (cap, 2)),
                            ("_bw", (cap,)), ("_alive_mask", (cap,))):
            old = getattr(self, name)
            new = np.zeros(shape, old.dtype)
            new[:n] = old[:n]
            setattr(self, name, new)

    def _append_rows(self, ids, xy, bw) -> np.ndarray:
        k = len(ids)
        self._grow_rows(self._nrows + k)
        rows = np.arange(self._nrows, self._nrows + k, dtype=np.int64)
        self._ids[rows] = ids
        self._xy[rows] = xy
        self._bw[rows] = bw
        self._alive_mask[rows] = True
        self._nrows += k
        return rows

    def _row_of(self, nid: int) -> int:
        """Row of ``nid`` — alive (ring lookup) or dead (retained attrs)."""
        ring = self._rings.get(self.space.zone_of(nid))
        if ring is not None and ring.n:
            suf = self.space.suffix_of(nid)
            i = int(np.searchsorted(ring.view(), suf))
            if i < ring.n and ring.suf[i] == suf:
                return int(ring.row[i])
        return self._dead_rows.get(nid, -1)

    def _known_ids(self) -> list[int]:
        out = self.nodes()
        out.extend(self._dead_rows)
        return out

    # -- membership ---------------------------------------------------------

    def join(self, zone: int, suffix: int, coord=(0.0, 0.0), bandwidth: float = 100.0) -> int:
        nid = self.space.make(zone, suffix)
        ring = self._rings.get(zone)
        if ring is None:
            ring = self._rings[zone] = _ZoneRing()
        i = int(np.searchsorted(ring.view(), suffix))
        if i < ring.n and ring.suf[i] == suffix:
            raise ValueError(f"suffix collision {suffix} in zone {zone}")
        if ring.n == 0:
            self._occupancy_epoch += 1
        x, y = float(coord[0]), float(coord[1])
        row = int(self._append_rows([nid], [(x, y)], [float(bandwidth)])[0])
        ring.insert(i, suffix, row)
        self._dead_rows.pop(nid, None)
        self._num_alive += 1
        if self._grid is not None:
            self._grid.add(row, x, y)
        return int(nid)

    def join_random(self, zone: int, coord=(0.0, 0.0), bandwidth: float = 100.0) -> int:
        while True:
            suffix = int(self.rng.integers(0, self.space.suffix_space))
            try:
                return self.join(zone, suffix, coord, bandwidth)
            except ValueError:
                continue

    def join_weighted(self, zone: int, units: int, coord=(0.0, 0.0), bandwidth: float = 100.0) -> list[int]:
        """Appendix L: heterogeneous resources via LOGICAL nodes — a
        physical node with ``units`` resource units joins as that many
        P2P nodes (more units => proportionally more master assignments);
        the ids are recorded as one physical group for accounting."""
        ids = [self.join_random(zone, coord, bandwidth) for _ in range(max(1, units))]
        group = ids[0]
        for nid in ids:
            self.physical_group[nid] = group
        return ids

    def join_many(
        self,
        zones: np.ndarray,
        coords: np.ndarray | None = None,
        bandwidth: np.ndarray | float = 100.0,
        suffixes: np.ndarray | None = None,
    ) -> np.ndarray:
        """Bulk join: K nodes in one vectorized pass (the million-node
        build path — per-node ``join`` is O(n_zone) moves each, this is
        one sort per zone).  ``suffixes=None`` draws unique random
        suffixes per zone from the overlay rng.  Returns node ids (K,)."""
        zones = np.asarray(zones, np.int64)
        k = len(zones)
        if k == 0:
            return np.empty(0, np.int64)
        coords = (np.zeros((k, 2)) if coords is None
                  else np.asarray(coords, np.float64).reshape(k, 2))
        bw = np.broadcast_to(np.asarray(bandwidth, np.float64), (k,))
        out = np.empty(k, np.int64)
        order = np.argsort(zones, kind="stable")
        zs = zones[order]
        bounds = np.flatnonzero(np.diff(zs)) + 1
        for idx in np.split(order, bounds):
            z = int(zones[idx[0]])
            ring = self._rings.get(z)
            if ring is None:
                ring = self._rings[z] = _ZoneRing()
            if ring.n == 0:
                self._occupancy_epoch += 1
            if suffixes is None:
                sufs = self._draw_unique_suffixes(z, len(idx))
            else:
                sufs = np.asarray(suffixes, np.int64)[idx]
                srt = np.argsort(sufs, kind="stable")
                sufs, idx = sufs[srt], idx[srt]
                if len(np.unique(sufs)) != len(sufs) or (
                    ring.n and np.any(np.isin(sufs, ring.view()))
                ):
                    raise ValueError(f"suffix collision in zone {z}")
            ids = z * self.space.suffix_space + sufs
            rows = self._append_rows(ids, coords[idx], bw[idx])
            ring.bulk_add(sufs, rows)
            out[idx] = ids
            for nid in ids.tolist():
                self._dead_rows.pop(nid, None)
        self._num_alive += k
        self._grid = None  # rebuild lazily at the new population
        return out

    def _draw_unique_suffixes(self, zone: int, k: int) -> np.ndarray:
        """k fresh suffixes for ``zone``: unique and collision-free."""
        ring = self._rings.get(zone)
        existing = ring.view() if ring is not None else np.empty(0, np.int64)
        space = self.space.suffix_space
        if k + len(existing) > space:
            raise ValueError(f"zone {zone} suffix space exhausted")
        picked = np.empty(0, np.int64)
        while len(picked) < k:
            draw = self.rng.integers(0, space, size=int((k - len(picked)) * 1.1) + 16)
            draw = np.unique(draw.astype(np.int64))
            if len(existing):
                draw = draw[~np.isin(draw, existing)]
            if len(picked):
                draw = draw[~np.isin(draw, picked)]
            picked = np.concatenate([picked, draw])
        # keep sorted order (np.unique already sorts; concat of leftovers may not)
        return np.sort(picked[:k])

    def leave(self, node_id: int) -> None:
        zone, suffix = self.space.zone_of(node_id), self.space.suffix_of(node_id)
        ring = self._rings.get(zone)
        if ring is None or ring.n == 0:
            return
        i = int(np.searchsorted(ring.view(), suffix))
        if i >= ring.n or ring.suf[i] != suffix:
            return
        row = ring.pop(i)
        if ring.n == 0:
            self._occupancy_epoch += 1
        self._alive_mask[row] = False
        self._dead_rows[node_id] = row
        self._num_alive -= 1
        if self._grid is not None:
            self._grid.remove(row, float(self._xy[row, 0]), float(self._xy[row, 1]))

    def fail(self, node_id: int) -> None:
        """Crash-fail (no graceful handoff) — same membership effect."""
        self.leave(node_id)

    @property
    def num_nodes(self) -> int:
        return self._num_alive

    def zones(self) -> list[int]:
        return [z for z, ring in self._rings.items() if ring.n]

    def nodes(self) -> list[int]:
        out: list[int] = []
        space = self.space.suffix_space
        for z in sorted(self._rings):
            ring = self._rings[z]
            if ring.n:
                out.extend((z * space + ring.view()).tolist())
        return out

    def node_array(self) -> np.ndarray:
        """All alive node ids, sorted, as one int64 array (no copy loop)."""
        space = self.space.suffix_space
        parts = [
            z * space + self._rings[z].view()
            for z in sorted(self._rings)
            if self._rings[z].n
        ]
        return np.concatenate(parts) if parts else np.empty(0, np.int64)

    # -- successor / closest lookups (the "by-rule" table evaluation) --------

    def _zone_successor(self, zone: int, suffix: int) -> int | None:
        ring = self._rings.get(zone)
        if ring is None or ring.n == 0:
            return None
        i = int(np.searchsorted(ring.view(), suffix)) % ring.n
        return self.space.make(zone, int(ring.suf[i]))

    def _zone_closest(self, zone: int, suffix: int) -> int | None:
        ring = self._rings.get(zone)
        if ring is None or ring.n == 0:
            return None
        i = int(np.searchsorted(ring.view(), suffix))
        succ = int(ring.suf[i % ring.n])
        pred = int(ring.suf[(i - 1) % ring.n])
        space = self.space.suffix_space
        # deterministic tie-break: ties go clockwise (the successor), the
        # same convention as nodeid.numerically_closest — and the same
        # rule the vectorized route_many applies.
        if abs_ring_distance(suffix, succ, space) <= abs_ring_distance(suffix, pred, space):
            return self.space.make(zone, succ)
        return self.space.make(zone, pred)

    def nearest_zone(self, zone: int) -> int | None:
        """Next non-empty zone clockwise from `zone` (incl. itself)."""
        for d in range(self.space.num_zones):
            z = (zone + d) % self.space.num_zones
            ring = self._rings.get(z)
            if ring is not None and ring.n:
                return z
        return None

    def _nearest_zone_arr(self) -> np.ndarray:
        """nearest_zone for every zone as one int64 array (-1 = none),
        cached per occupancy epoch."""
        if self._nearest_cache is not None and self._nearest_cache[0] == self._occupancy_epoch:
            return self._nearest_cache[1]
        occ = np.asarray(sorted(self.zones()), np.int64)
        nz = np.arange(self.space.num_zones, dtype=np.int64)
        if len(occ) == 0:
            arr = np.full(self.space.num_zones, -1, np.int64)
        else:
            arr = occ[np.searchsorted(occ, nz) % len(occ)]
        self._nearest_cache = (self._occupancy_epoch, arr)
        return arr

    # -- leaf / neighborhood sets --------------------------------------------

    def leaf_set(self, node_id: int) -> list[int]:
        zone, suffix = self.space.zone_of(node_id), self.space.suffix_of(node_id)
        ring = self._rings.get(zone)
        if ring is None or ring.n <= 1:
            return []
        members = ring.view()
        i = int(np.searchsorted(members, suffix))
        half = self.leaf_size // 2
        out = []
        for d in range(1, half + 1):
            out.append(self.space.make(zone, int(members[(i + d) % ring.n])))
            out.append(self.space.make(zone, int(members[(i - d) % ring.n])))
        return [x for x in dict.fromkeys(out) if x != node_id]

    def _ensure_grid(self) -> _SpatialGrid:
        g = self._grid
        n = self._num_alive
        if g is None or n > 4 * g.built_n + 8 or n < g.built_n // 4:
            rows = np.flatnonzero(self._alive_mask[: self._nrows])
            g = self._grid = _SpatialGrid(self._xy, rows)
        return g

    def neighborhood_set(self, node_id: int) -> list[int]:
        """Physically closest live nodes (for master state replication).

        Served from the incremental spatial-grid index: O(k) cells
        visited per query instead of a full O(N log N) sort of every
        live node (ties broken by node id, deterministically)."""
        row = self._row_of(node_id)
        if row < 0:
            raise KeyError(node_id)
        x, y = float(self._xy[row, 0]), float(self._xy[row, 1])
        grid = self._ensure_grid()
        cand = grid.knn(x, y, self.neighborhood_size, row, self._xy)
        if len(cand) == 0:
            return []
        ids = self._ids[cand]
        d2 = (self._xy[cand, 0] - x) ** 2 + (self._xy[cand, 1] - y) ** 2
        order = np.lexsort((ids, d2))
        return ids[order[: self.neighborhood_size]].tolist()

    def neighborhood_set_bruteforce(self, node_id: int) -> list[int]:
        """Reference implementation (full sort) — the grid-index oracle."""
        row = self._row_of(node_id)
        if row < 0:
            raise KeyError(node_id)
        x, y = float(self._xy[row, 0]), float(self._xy[row, 1])
        rows = np.flatnonzero(self._alive_mask[: self._nrows])
        rows = rows[rows != row]
        ids = self._ids[rows]
        d2 = (self._xy[rows, 0] - x) ** 2 + (self._xy[rows, 1] - y) ** 2
        order = np.lexsort((ids, d2))
        return ids[order[: self.neighborhood_size]].tolist()

    # -- routing -------------------------------------------------------------

    def _digit_prefix_len(self, a: int, b_: int, b: int | None = None) -> int:
        """Common prefix length in base-2^b digits, MSB first."""
        b = b or self.b
        n = self.space.suffix_bits
        rows = (n + b - 1) // b
        for p in range(rows):
            shift = max(0, n - b * (p + 1))
            if (a >> shift) != (b_ >> shift):
                return p
        return rows

    def _next_hop_in_zone(
        self, cur_suffix: int, key_suffix: int, zone: int, b: int | None = None
    ) -> int | None:
        """Pastry-style digit-fixing hop: jump to the canonical node of the
        range sharing one more base-2^b digit with the key.  Canonical =
        clockwise successor of the range start, so paths from different
        sources CONVERGE (the paper's path-convergence property) and tree
        fanout is bounded by 2^b (+ leaf-set final hops)."""
        b = b or self.b
        n = self.space.suffix_bits
        rows = (n + b - 1) // b
        p = self._digit_prefix_len(cur_suffix, key_suffix, b)
        while p < rows:
            shift = max(0, n - b * (p + 1))
            # Plaxton rule: fix the key's next digit, KEEP the source's
            # remaining digits — paths from different sources spread across
            # the range and converge progressively (bounded tree fanout),
            # instead of all landing on one canonical node per level.
            target = ((key_suffix >> shift) << shift) | (cur_suffix & ((1 << shift) - 1))
            nxt = self._zone_successor(zone, target)
            if nxt is None:
                return None
            ns = self.space.suffix_of(nxt)
            if (ns >> shift) == (key_suffix >> shift) and ns != cur_suffix:
                return nxt
            p += 1  # empty range: try to fix the next digit
        # all populated ranges exhausted: leaf-set final hop
        nxt = self._zone_closest(zone, key_suffix)
        return nxt if nxt is not None and self.space.suffix_of(nxt) != cur_suffix else None

    def route(
        self,
        src: int,
        key: int,
        *,
        restrict_zone: int | None = None,
        base_bits: int | None = None,
        max_hops: int | None = None,
    ) -> RouteResult:
        """Greedy two-level prefix/finger routing to the node numerically
        closest to `key`.  ``restrict_zone`` enforces administrative
        isolation (level-1 entries disabled; cross-zone packets blocked);
        ``base_bits`` overrides the digit base 2^b for this route only
        (per-tree fanout — one app's choice must not leak into others)."""
        space = self.space
        cur = src
        path = [cur]
        key_zone, key_suffix = space.zone_of(key), space.suffix_of(key)
        max_hops = max_hops or (4 * space.total_bits)

        for _ in range(max_hops):
            cur_zone = space.zone_of(cur)
            if restrict_zone is not None and cur_zone != restrict_zone:
                return RouteResult(path, len(path) - 1, blocked=True)

            if cur_zone != key_zone and restrict_zone is None:
                # level 1: finger across zones toward the key's zone
                target_zone = self.nearest_zone(key_zone)
                if target_zone is None:
                    break
                if target_zone == cur_zone:
                    key_zone = cur_zone  # key's zone empty -> deliver here
                    continue
                dz = ring_distance(cur_zone, target_zone, space.num_zones)
                step = 1 << (dz.bit_length() - 1)
                hop_zone = (cur_zone + step) % space.num_zones
                hop_zone = self.nearest_zone(hop_zone)
                # land near the *source's* suffix (spread; suffix digits are
                # fixed by level-2 routing once inside the key's zone)
                nxt = self._zone_closest(hop_zone, space.suffix_of(cur))
                if nxt is None or nxt == cur:
                    break
                cur = nxt
                path.append(cur)
                continue

            if restrict_zone is not None and key_zone != restrict_zone:
                key_zone = restrict_zone  # deliver within the restricted ring

            # destination reached: the numerically closest node in the zone
            if cur == self._zone_closest(cur_zone, key_suffix):
                break

            # level 2: canonical digit-fixing within the zone
            nxt = self._next_hop_in_zone(space.suffix_of(cur), key_suffix, cur_zone, base_bits)
            if nxt is None or nxt == cur or nxt in path[-2:]:
                # no better hop / would cycle: deliver via leaf set
                final = self._zone_closest(cur_zone, key_suffix)
                if final is not None and final != cur and final not in path:
                    path.append(final)
                break
            cur = nxt
            path.append(cur)

        return RouteResult(path, len(path) - 1)

    # -- vectorized routing (the scale layer) ---------------------------------

    def _by_zone(self, zones: np.ndarray):
        """Yield (zone, index-array) groups for a zone array."""
        order = np.argsort(zones, kind="stable")
        zs = zones[order]
        bounds = np.flatnonzero(np.diff(zs)) + 1
        for idx in np.split(order, bounds):
            yield int(zones[idx[0]]), idx

    def _zone_lookup_many(self, zones: np.ndarray, suffixes: np.ndarray,
                          closest: bool):
        """Vectorized `_zone_successor` (closest=False) / `_zone_closest`
        (closest=True): returns (suffix, row) arrays; suffix = -1 where
        the zone is empty."""
        out_suf = np.full(len(zones), -1, np.int64)
        out_row = np.full(len(zones), -1, np.int64)
        space = self.space.suffix_space
        for z, idx in self._by_zone(zones):
            ring = self._rings.get(z)
            if ring is None or ring.n == 0:
                continue
            members, rows = ring.view(), ring.rows()
            i = np.searchsorted(members, suffixes[idx])
            if closest:
                si, pi = i % ring.n, (i - 1) % ring.n
                succ, pred = members[si], members[pi]
                ds = np.abs(succ - suffixes[idx])
                ds = np.minimum(ds % space, (-ds) % space)
                dp = np.abs(pred - suffixes[idx])
                dp = np.minimum(dp % space, (-dp) % space)
                take_succ = ds <= dp  # ties -> clockwise, same as scalar
                pick = np.where(take_succ, si, pi)
            else:
                pick = i % ring.n
            out_suf[idx] = members[pick]
            out_row[idx] = rows[pick]
        return out_suf, out_row

    @staticmethod
    def _bit_length(x: np.ndarray) -> np.ndarray:
        """Vectorized int.bit_length for non-negative int64 < 2**52."""
        return np.frexp(x.astype(np.float64))[1].astype(np.int64)

    def _prefix_len_many(self, a: np.ndarray, b_: np.ndarray, b: int) -> np.ndarray:
        """Vectorized `_digit_prefix_len` over suffix arrays."""
        n = self.space.suffix_bits
        rows = (n + b - 1) // b
        x = a ^ b_
        h = self._bit_length(x) - 1  # highest differing bit (x > 0)
        pl = (n - 1 - h) // b
        return np.where(x == 0, rows, pl)

    def _next_hop_in_zone_many(
        self, cur_suf: np.ndarray, key_suf: np.ndarray, zones: np.ndarray,
        b: int,
    ):
        """Vectorized `_next_hop_in_zone`: (suffix, row) per element,
        suffix = -1 where the scalar oracle returns None."""
        n = self.space.suffix_bits
        rows_total = (n + b - 1) // b
        k = len(cur_suf)
        out_suf = np.full(k, -1, np.int64)
        out_row = np.full(k, -1, np.int64)
        p = self._prefix_len_many(cur_suf, key_suf, b)
        pending = np.flatnonzero(p < rows_total)
        fallback = np.flatnonzero(p >= rows_total)
        while len(pending):
            shift = np.maximum(0, n - b * (p[pending] + 1))
            low_mask = (np.int64(1) << shift) - 1
            target = ((key_suf[pending] >> shift) << shift) | (cur_suf[pending] & low_mask)
            ns, nrow = self._zone_lookup_many(zones[pending], target, closest=False)
            ok = ((ns >> shift) == (key_suf[pending] >> shift)) & (ns != cur_suf[pending])
            hit = pending[ok]
            out_suf[hit] = ns[ok]
            out_row[hit] = nrow[ok]
            miss = pending[~ok]
            p[miss] += 1
            done_mask = p[miss] >= rows_total
            fallback = np.concatenate([fallback, miss[done_mask]])
            pending = miss[~done_mask]
        if len(fallback):
            cs, crow = self._zone_lookup_many(zones[fallback], key_suf[fallback], closest=True)
            ok = (cs >= 0) & (cs != cur_suf[fallback])
            hit = fallback[ok]
            out_suf[hit] = cs[ok]
            out_row[hit] = crow[ok]
        return out_suf, out_row

    def _rows_of_many(self, ids: np.ndarray) -> np.ndarray:
        """Rows of node ids (vectorized; dead nodes resolve via the
        retained-attribute table, -1 where entirely unknown)."""
        zones = ids >> self.space.suffix_bits
        sufs = ids & (self.space.suffix_space - 1)
        # the successor lookup returns the node itself when present
        suf_found, rows = self._zone_lookup_many(zones, sufs, closest=False)
        rows = np.where(suf_found == sufs, rows, -1)
        for i in np.flatnonzero(rows < 0):
            rows[i] = self._dead_rows.get(int(ids[i]), -1)
        return rows

    def route_many(
        self,
        sources: np.ndarray,
        keys: np.ndarray,
        *,
        restrict_zone: int | None = None,
        base_bits: int | None = None,
        max_hops: int | None = None,
    ) -> RouteBatch:
        """Batched ``route``: resolves every (source, key) pair in
        vectorized ring/prefix arithmetic — hop-for-hop identical to the
        scalar oracle (tests/test_scale.py pins path, hops and latency).

        One iteration advances every still-active route by at most one
        hop; per-iteration node snapshots are retained so full paths can
        be reconstructed (``RouteBatch.path``) and the scalar code's
        "final not already in path" delivery check is exact.
        """
        space = self.space
        sources = np.asarray(sources, np.int64)
        keys = np.asarray(keys, np.int64)
        k = len(sources)
        max_hops = max_hops or (4 * space.total_bits)
        cur = sources.copy()
        prev = np.full(k, -1, np.int64)  # path[-2] (cycle guard)
        hops = np.zeros(k, np.int64)
        blocked = np.zeros(k, bool)
        latency = np.zeros(k, np.float64)
        key_zone = keys >> space.suffix_bits
        key_suf = keys & (space.suffix_space - 1)
        active = np.ones(k, bool)
        hist = [cur.copy()]
        b = base_bits or self.b
        Z = space.num_zones
        cur_row = self._rows_of_many(cur) if k else np.empty(0, np.int64)

        def advance(idx: np.ndarray, nxt_id: np.ndarray, nxt_row: np.ndarray,
                    count_hop: bool = True) -> None:
            """Move routes ``idx`` to ``nxt_id`` and accumulate latency."""
            a, bxy = self._xy[cur_row[idx]], self._xy[nxt_row]
            d = np.sqrt(((a - bxy) ** 2).sum(axis=1))
            latency[idx] += 1.0 + 0.1 * d
            prev[idx] = cur[idx]
            cur[idx] = nxt_id
            cur_row[idx] = nxt_row
            if count_hop:
                hops[idx] += 1

        for _ in range(max_hops):
            act = np.flatnonzero(active)
            if len(act) == 0:
                break
            cur_zone = cur[act] >> space.suffix_bits
            cur_suf = cur[act] & (space.suffix_space - 1)

            if restrict_zone is not None:
                bad = cur_zone != restrict_zone
                blocked[act[bad]] = True
                active[act[bad]] = False
                act = act[~bad]
                cur_zone, cur_suf = cur_zone[~bad], cur_suf[~bad]
                # deliver within the restricted ring
                key_zone[act] = restrict_zone
                cross = np.zeros(len(act), bool)
            else:
                cross = cur_zone != key_zone[act]

            moved = False
            # -- level 1: cross-zone finger hop ------------------------------
            xi = act[cross]
            if len(xi):
                nz = self._nearest_zone_arr()
                target_zone = nz[key_zone[xi]]
                dead = target_zone < 0
                active[xi[dead]] = False
                xi, target_zone = xi[~dead], target_zone[~dead]
                cz = cur[xi] >> space.suffix_bits
                same = target_zone == cz
                key_zone[xi[same]] = cz[same]  # empty key zone -> deliver here
                xi, cz, target_zone = xi[~same], cz[~same], target_zone[~same]
                if len(xi):
                    dz = (target_zone - cz) % Z
                    step = np.int64(1) << (self._bit_length(dz) - 1)
                    hop_zone = nz[(cz + step) % Z]
                    nsuf, nrow = self._zone_lookup_many(
                        hop_zone, cur[xi] & (space.suffix_space - 1), closest=True
                    )
                    nxt = hop_zone * space.suffix_space + nsuf
                    stuck = (nsuf < 0) | (nxt == cur[xi])
                    active[xi[stuck]] = False
                    go = xi[~stuck]
                    if len(go):
                        advance(go, nxt[~stuck], nrow[~stuck])
                        moved = True

            # -- level 2: in-zone digit fixing -------------------------------
            ii = act[~cross]
            if len(ii):
                cz = cur[ii] >> space.suffix_bits
                csuf = cur[ii] & (space.suffix_space - 1)
                closest_suf, closest_row = self._zone_lookup_many(
                    cz, key_suf[ii], closest=True
                )
                delivered = closest_suf == csuf
                active[ii[delivered]] = False
                ii, cz, csuf = ii[~delivered], cz[~delivered], csuf[~delivered]
                closest_suf, closest_row = closest_suf[~delivered], closest_row[~delivered]
                if len(ii):
                    nsuf, nrow = self._next_hop_in_zone_many(csuf, key_suf[ii], cz, b)
                    nxt = cz * space.suffix_space + nsuf
                    guard = (nsuf < 0) | (nxt == cur[ii]) | (nxt == prev[ii])
                    # guard-tripped: deliver via leaf set unless the
                    # closest node is cur or already on the path
                    gi = ii[guard]
                    if len(gi):
                        fsuf = closest_suf[guard]
                        frow = closest_row[guard]
                        final = (cur[gi] >> space.suffix_bits) * space.suffix_space + fsuf
                        skip = final == cur[gi]
                        seen = np.zeros(len(gi), bool)
                        for snap in hist:
                            seen |= snap[gi] == final
                        ok = ~(skip | seen)
                        if ok.any():
                            advance(gi[ok], final[ok], frow[ok])
                            moved = True
                        active[gi] = False
                    go = ii[~guard]
                    if len(go):
                        advance(go, nxt[~guard], nrow[~guard])
                        moved = True

            if moved:
                hist.append(cur.copy())

        return RouteBatch(
            hops=hops, dest=cur, blocked=blocked, latency_ms=latency, _hist=hist
        )

    # -- table materialization (inspection / tests) --------------------------

    def routing_table_of(self, node_id: int) -> dict:
        """Materialize the node's two-level routing table per the paper's
        entry rule: L1[i] = (P_x + 2^{i-1}) mod 2^m * 2^n,
        L2 rows of base-2^b digit fingers."""
        space = self.space
        zone, suffix = space.zone_of(node_id), space.suffix_of(node_id)
        l1 = []
        for i in range(1, space.zone_bits + 1):
            tz = (zone + (1 << (i - 1))) % space.num_zones
            tz_live = self.nearest_zone(tz)
            l1.append(
                self._zone_closest(tz_live, suffix) if tz_live is not None else None
            )
        l2 = []
        rows = (space.suffix_bits + self.b - 1) // self.b
        for i in range(rows):
            row = []
            for j in range(1, 1 << self.b):
                t = (suffix + j * (1 << (self.b * i))) % space.suffix_space
                row.append(self._zone_closest(zone, t))
            l2.append(row)
        return {"level1": l1, "level2": l2}

    # -- latency model --------------------------------------------------------

    def rtt(self, a: int, b: int) -> float:
        """Synthetic RTT (ms) from coordinates: 0.1 ms/unit + 1 ms base."""
        (ax, ay), (bx, by) = self.coords[a], self.coords[b]
        return 1.0 + 0.1 * ((ax - bx) ** 2 + (ay - by) ** 2) ** 0.5

    def path_latency(self, path: list[int]) -> float:
        return sum(self.rtt(a, b) for a, b in zip(path, path[1:]))


# ---------------------------------------------------------------------------
# Ratnasamy–Shenker distributed binning (paper §IV-B, [55])


def distributed_binning(
    coords: np.ndarray, num_landmarks: int, *, levels: int = 3, seed: int = 0
) -> np.ndarray:
    """Bin nodes by landmark-RTT ordering (+ RTT-level quantization).

    Returns an integer bin id per node; bins with identical landmark
    orderings and level vectors land in the same zone — nearby nodes get
    the same bin without any coordination beyond landmark pings.
    """
    rng = np.random.default_rng(seed)
    landmarks = coords[rng.choice(len(coords), size=num_landmarks, replace=False)]
    d = np.sqrt(((coords[:, None, :] - landmarks[None, :, :]) ** 2).sum(-1))  # (N, L)
    order = np.argsort(d, axis=1)  # landmark ordering
    dmax = d.max() + 1e-9
    level = np.minimum((d / dmax * levels).astype(int), levels - 1)
    bins: dict[tuple, int] = {}
    out = np.zeros(len(coords), dtype=np.int64)
    for i in range(len(coords)):
        key = (tuple(order[i]), tuple(level[i][order[i]]))
        out[i] = bins.setdefault(key, len(bins))
    return out


def build_overlay_from_coords(
    coords: np.ndarray,
    space: IdSpace,
    *,
    base_bits: int = 4,
    bandwidth_range=(20.0, 100.0),
    seed: int = 0,
) -> tuple[MultiRingOverlay, list[int]]:
    """EUA-style construction: bin nodes into zones, assign random suffixes."""
    overlay = MultiRingOverlay(space, base_bits=base_bits, seed=seed)
    nbins = distributed_binning(coords, min(space.num_zones, max(2, space.num_zones)), seed=seed)
    zones = nbins % space.num_zones
    rng = np.random.default_rng(seed + 1)
    bws = rng.uniform(bandwidth_range[0], bandwidth_range[1], len(zones))
    ids = overlay.join_many(zones, coords=coords, bandwidth=bws)
    return overlay, ids.tolist()
