"""Game-theoretic path planning — Algorithm 1 (paper §V-B), in JAX.

Per episode, every node: (line 3) samples tau next hops from its policy
and observes bandit rewards; (line 5) picks the exploratory policy
rho = argmin_det M(lambda) over its candidate policy set Delta(P_n);
(line 6) estimates the potential gradient by importance-weighted linear
regression grad(p) = (1/tau) sum_t psi(p)^T M(pi)^{-1} psi(p_t) r_t —
with one-hot psi this is sum_t 1[p_t=p] r_t / pi(p); (line 7) takes the
candidate maximizing <lambda, grad>; (line 8) Frank–Wolfe mixes with
exploration: pi' = alpha[pi + beta(pi~ - pi)] + (1-alpha) rho.

Everything is vmapped over nodes and jitted — the per-node update is pure
matrix algebra (the O(log N * Matmul) claim, Fig. 15/16); the Pallas
``policy_update`` kernel is the TPU port of the same update.

Baselines (paper §VII-E): the EuroSys'24 Totoro bandit planner (UCB on
per-hop delay, congestion-blind) and OPT (knows capacities; greedy
balanced assignment).  ``nash_regret`` evaluates both per Definition 2.

Live placement (docs/architecture.md "placement layer"): the synthetic
``CongestionEnv`` demo above never sees the simulator, so the planner
used to be a figure reproduction while chronic stragglers sat as
aggregators on hot paths.  ``PlacementEngine`` is the same congestion
game played against *measured* state: per-uplink occupancy and byte
ledgers from the ``EventCore``, per-worker defer/deadline attribution
from ``fl/selection.py``, and per-app fairness snapshots.  Each replan
is one ε-best-response step — the OPT planner's greedy marginal-reward
rule, computed exactly from the live hop costs instead of bandit
samples, with a multiplicative-improvement hysteresis (``improve``)
playing ε.  The cost model is ``tree_path_costs``: per-node commit and
download path costs accumulated root-down over the array-backed
``DataflowTree``'s cached BFS levels — ONE array pass per level per
replan, the same treatment the schedules got in PR 7.  The per-node
Python walk survives as ``tree_path_costs_scalar``, the exactness
oracle (tests/test_placement.py asserts float-for-float equality).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .congestion import CongestionEnv

NEG = -1e9


def candidate_policy_set(K: int, num_random: int = 8, *, seed: int = 0) -> jnp.ndarray:
    """Delta(P_n): a finite candidate set over K hops — the uniform policy,
    per-hop skewed corners (0.9 mass), and a few Dirichlet samples.
    All entries strictly positive (Theorem 1's no-zero-element condition)."""
    rng = np.random.default_rng(seed)
    cands = [np.full(K, 1.0 / K)]
    for k in range(K):
        v = np.full(K, 0.1 / max(K - 1, 1))
        v[k] = 0.9
        cands.append(v)
    for _ in range(num_random):
        cands.append(rng.dirichlet(np.ones(K)) * 0.9 + 0.1 / K)
    M = np.stack(cands)
    return jnp.asarray(M / M.sum(-1, keepdims=True), jnp.float32)


@partial(jax.jit, static_argnames=("tau",))
def algorithm1_episode(pi, mask, cand, actions, rewards, *, tau: int, alpha: float, beta: float):
    """One Algorithm-1 policy update, batched over nodes.

    pi: (N, K) current policies;  mask: (N, K) valid-hop mask;
    cand: (M, K) candidate policy set Delta(P_n) (shared, re-masked per node);
    actions: (N, tau) sampled hop indices;  rewards: (N, tau).
    Returns pi^{k+1}: (N, K).
    """
    maskf = mask.astype(jnp.float32)

    # re-normalize the candidate set onto each node's valid hops
    candn = cand[None] * maskf[:, None, :]  # (N, M, K)
    candn = candn / jnp.maximum(candn.sum(-1, keepdims=True), 1e-12)

    # line 5: rho = argmin det M(lambda); one-hot psi => det = prod lambda_k
    logdet = jnp.where(maskf[:, None, :] > 0, jnp.log(jnp.maximum(candn, 1e-12)), 0.0).sum(-1)
    rho = candn[jnp.arange(pi.shape[0]), jnp.argmin(logdet, axis=1)]  # (N, K)

    # line 6: importance-weighted gradient estimate (M(pi)^{-1} = diag(1/pi))
    onehot = jax.nn.one_hot(actions, pi.shape[1], dtype=jnp.float32)  # (N, tau, K)
    grad = (onehot * rewards[..., None]).sum(1) / (tau * jnp.maximum(pi, 1e-12))
    grad = grad * maskf

    # line 7: best candidate by inner product
    scores = jnp.einsum("nmk,nk->nm", candn, grad)
    pi_tilde = candn[jnp.arange(pi.shape[0]), jnp.argmax(scores, axis=1)]

    # line 8: Frank–Wolfe + exploration mixture
    pi_new = alpha * (pi + beta * (pi_tilde - pi)) + (1.0 - alpha) * rho
    pi_new = pi_new * maskf
    return pi_new / jnp.maximum(pi_new.sum(-1, keepdims=True), 1e-12)


@dataclass
class GameTheoreticPlanner:
    """Totoro+ planner (Algorithm 1)."""

    num_nodes: int
    num_paths: int
    tau: int = 8
    alpha: float = 0.9
    beta: float = 0.5
    mask: jnp.ndarray | None = None  # (N, K) valid hops
    seed: int = 0

    def __post_init__(self):
        K = self.num_paths
        self.mask = (
            jnp.ones((self.num_nodes, K), bool) if self.mask is None else self.mask
        )
        pi = jnp.ones((self.num_nodes, K), jnp.float32) * self.mask
        self.pi = pi / pi.sum(-1, keepdims=True)
        self.cand = candidate_policy_set(K, seed=self.seed)

    def sample_actions(self, key) -> jnp.ndarray:
        """(tau,) packets per node, i.i.d. from the current policies."""
        return jax.random.categorical(
            key, jnp.log(jnp.maximum(self.pi, 1e-12))[:, None, :].repeat(self.tau, 1)
        )

    def update(self, actions, rewards) -> None:
        self.pi = algorithm1_episode(
            self.pi, self.mask, self.cand, actions, rewards,
            tau=self.tau, alpha=self.alpha, beta=self.beta,
        )


@dataclass
class BanditPlanner:
    """EuroSys'24 Totoro baseline: per-hop UCB on observed reward,
    congestion-blind (Appendix B's bandit model)."""

    num_nodes: int
    num_paths: int
    tau: int = 8
    explore_c: float = 0.5
    epsilon: float = 0.05

    def __post_init__(self):
        N, K = self.num_nodes, self.num_paths
        self.counts = jnp.ones((N, K), jnp.float32)
        self.means = jnp.zeros((N, K), jnp.float32)
        self.t = 1

    @property
    def pi(self) -> jnp.ndarray:
        """Greedy-UCB induced (nearly deterministic) policy."""
        ucb = self.means + self.explore_c * jnp.sqrt(jnp.log(self.t + 1.0) / self.counts)
        best = jnp.argmax(ucb, axis=1)
        eye = jax.nn.one_hot(best, self.num_paths)
        return (1 - self.epsilon) * eye + self.epsilon / self.num_paths

    def sample_actions(self, key) -> jnp.ndarray:
        return jax.random.categorical(
            key, jnp.log(jnp.maximum(self.pi, 1e-12))[:, None, :].repeat(self.tau, 1)
        )

    def update(self, actions, rewards) -> None:
        onehot = jax.nn.one_hot(actions, self.num_paths, dtype=jnp.float32)
        cnt = onehot.sum(1)
        s = (onehot * rewards[..., None]).sum(1)
        new_counts = self.counts + cnt
        self.means = (self.means * self.counts + s) / new_counts
        self.counts = new_counts
        self.t += self.tau


@dataclass
class OptPlanner:
    """OPT oracle: knows capacities/thetas; greedy balanced assignment
    maximizing marginal mean reward given current congestion."""

    env: CongestionEnv
    num_nodes: int
    tau: int = 8

    def __post_init__(self):
        P = self.env.num_paths
        counts = np.zeros(P, np.int64)
        assign = np.zeros(self.num_nodes, np.int64)
        for n in range(self.num_nodes):
            best, best_r = 0, -1.0
            for p in range(P):
                r = self.env.mean_reward(p, int(counts[p]) + 1)
                if r > best_r:
                    best, best_r = p, r
            assign[n] = best
            counts[best] += 1
        self.assign = jnp.asarray(assign)

    @property
    def pi(self) -> jnp.ndarray:
        return jax.nn.one_hot(self.assign, self.env.num_paths)

    def sample_actions(self, key) -> jnp.ndarray:
        return jnp.broadcast_to(self.assign[:, None], (self.num_nodes, self.tau))

    def update(self, actions, rewards) -> None:
        pass


# ---------------------------------------------------------------------------
# evaluation: Nash regret + cumulative latency


@partial(jax.jit, static_argnames=("samples",))
def policy_values(env: CongestionEnv, pi: jnp.ndarray, key, samples: int = 64):
    """Monte-Carlo V_n(pi) and best-response values V_n(a, pi_{-n}).

    Returns (values (N,), best_response (N,)) using `samples` joint draws.
    """
    N, K = pi.shape
    keys = jax.random.split(key, samples)

    def draw(k):
        a = jax.random.categorical(k, jnp.log(jnp.maximum(pi, 1e-12)))
        counts = jnp.zeros(K, jnp.float32).at[a].add(1.0)
        # on-policy reward per node (mean over link success)
        rate = env.capacity[a] / jnp.maximum(counts[a], 1.0)
        lat = env.base_ms + 1e3 * env.packet_mbit / jnp.maximum(rate, 1e-6)
        r = jnp.clip(1.0 - lat / env.l_max_ms, 0.0, 1.0) * env.theta[a]
        # deviation values: node n switches to pure action p (others fixed)
        counts_wo = counts[None, :] - jax.nn.one_hot(a, K)  # (N, K)
        cnt_dev = counts_wo + 1.0
        rate_dev = env.capacity[None, :] / jnp.maximum(cnt_dev, 1.0)
        lat_dev = env.base_ms + 1e3 * env.packet_mbit / jnp.maximum(rate_dev, 1e-6)
        r_dev = jnp.clip(1.0 - lat_dev / env.l_max_ms, 0.0, 1.0) * env.theta[None, :]
        return r, r_dev

    rs, rdevs = jax.lax.map(draw, keys)
    v = rs.mean(0)  # (N,)
    v_dev = rdevs.mean(0)  # (N, K)
    return v, jnp.max(v_dev, axis=1)


def nash_regret_step(env, pi, key, samples: int = 64) -> float:
    v, br = policy_values(env, pi, key, samples)
    return float(jnp.max(br - v))


def run_planner(planner, env: CongestionEnv, episodes: int, *, seed: int = 1, eval_samples: int = 64):
    """Drive a planner; returns dict of per-episode series."""
    key = jax.random.key(seed)
    lat_total = 0.0
    series = {"nash_regret": [], "cum_latency_ms": [], "mean_reward": []}
    for ep in range(episodes):
        key, k1, k2, k3 = jax.random.split(key, 4)
        actions = planner.sample_actions(k1)  # (N, tau)
        rws = []
        lats = []
        for t in range(actions.shape[1]):
            kk = jax.random.fold_in(k2, t)
            a_t = actions[:, t]
            rws.append(env.rewards(a_t, kk))
            lats.append(env.latency_ms(a_t))
        rewards = jnp.stack(rws, 1)
        lat_total += float(jnp.sum(jnp.stack(lats)) / actions.shape[0])
        planner.update(actions, rewards)
        series["nash_regret"].append(nash_regret_step(env, planner.pi, k3, eval_samples))
        series["cum_latency_ms"].append(lat_total)
        series["mean_reward"].append(float(jnp.mean(rewards)))
    series["selection_freq"] = np.asarray(
        jax.nn.one_hot(planner.sample_actions(jax.random.key(99)), env.num_paths).mean((0, 1))
    )
    return series


# ---------------------------------------------------------------------------
# live placement: measured-telemetry best response over the actual trees


def tree_path_costs(tree, rows, cap, occ, *, base_ms, down_mbit, up_mbit):
    """Vectorized commit/download path costs over an array-backed tree.

    ``rows[s]`` maps tree slot ``s`` to its core uplink row; ``cap``/``occ``
    are the per-uplink capacity (Mbps) and measured occupancy arrays from
    the event core's congestion ledger.  A node's prospective fair share on
    its own uplink is ``cap / (1 + occ)`` (its flow joins whatever is
    already there), so the per-slot hop costs are

        hc_up[s]   = base_ms + 1e3 * up_mbit   / max(share[s], eps)
        hc_down[s] = base_ms + 1e3 * down_mbit / max(share[s], eps)

    and the path costs accumulate root-down over the cached BFS levels —
    one array pass per level, no per-node Python (the replan hot path):

        up[s]   = hc_up[s] + up[parent]        (commit: s -> root)
        down[s] = down[parent] + hc_down[parent]  (broadcast: root -> s)

    Returns ``(up, down, hc_up, hc_down)`` as float64 arrays of length
    ``tree._n``; detached slots keep ``+inf`` path costs.  The retained
    per-node oracle is :func:`tree_path_costs_scalar`; the two-operand
    accumulation order above is chosen so parity is EXACT float equality.
    """
    cache = tree._ensure_cache()
    n = tree._n
    r = np.asarray(rows)
    share = np.asarray(cap, np.float64)[r] / np.maximum(1.0 + np.asarray(occ, np.float64)[r], 1.0)
    hc_up = base_ms + 1e3 * up_mbit / np.maximum(share, 1e-9)
    hc_down = base_ms + 1e3 * down_mbit / np.maximum(share, 1e-9)
    up = np.full(n, np.inf)
    down = np.full(n, np.inf)
    rs = cache["root_s"]
    up[rs] = 0.0
    down[rs] = 0.0
    for lev in cache["levels"][1:]:
        ps = tree._par[lev]
        up[lev] = hc_up[lev] + up[ps]
        down[lev] = down[ps] + hc_down[ps]
    return up, down, hc_up, hc_down


def tree_path_costs_scalar(tree, rows, cap, occ, *, base_ms, down_mbit, up_mbit, nodes):
    """Per-node Python cost sweep — the pre-refactor model, retained as the
    exactness oracle for :func:`tree_path_costs` (tests/test_placement.py
    asserts float-for-float equality).  Walks each node's ``path_to_root``
    and accumulates hop costs top-down in the same two-operand order as the
    vectorized level pass."""
    cap = np.asarray(cap, np.float64)
    occ = np.asarray(occ, np.float64)
    out_up, out_down = [], []
    for node in nodes:
        path = tree.path_to_root(int(node))  # node .. root
        u = 0.0
        dn = 0.0
        for child, par in zip(reversed(path[:-1]), reversed(path[1:])):
            cs = tree._slot[child]
            ps = tree._slot[par]
            sc = cap[rows[cs]] / max(1.0 + occ[rows[cs]], 1.0)
            sp = cap[rows[ps]] / max(1.0 + occ[rows[ps]], 1.0)
            u = (base_ms + 1e3 * up_mbit / max(sc, 1e-9)) + u
            dn = dn + (base_ms + 1e3 * down_mbit / max(sp, 1e-9))
        out_up.append(u)
        out_down.append(dn)
    return np.asarray(out_up, np.float64), np.asarray(out_down, np.float64)


@dataclass(frozen=True)
class Move:
    """One planned re-graft: ``node`` (with its subtree) leaves
    ``old_parent`` for ``new_parent``; costs are the measured commit+
    download path cost before the move and the engine's estimate after."""

    node: int
    old_parent: int
    new_parent: int
    cost_before: float
    cost_est: float


class PlacementEngine:
    """Live utility-aware placement: Algorithm 1's congestion game played
    against measured state instead of bandit samples.

    Each ``plan_tree`` call is one ε-best-response step of the OPT
    planner's greedy marginal-reward rule: the costliest (or
    selector-flagged) members are offered the lowest-cost attachment
    points, and a move is emitted only when the estimated cost drops
    below ``improve`` × the measured cost (the hysteresis playing ε, so
    the greedy dynamics settle instead of oscillating).  The estimate
    for re-grafting ``w`` under ``p`` decomposes as

        cost(w under p) = hc_up[w] + (up[p] + down[p] + hc_down[p])

    whose second term is mover-independent — so candidate scoring is one
    vectorized pass and each mover takes the first admissible candidate.

    The engine is pure policy: the scheduler feeds it telemetry
    (occupancy, uplink bytes, defer/deadline flags via :meth:`flag`) and
    applies its moves through ``Forest.regraft_many``, pricing the JOIN
    control traffic on the simulation clock.  ``spike_jain`` /
    ``spike_occupancy`` / ``min_interval_ms`` configure the scheduler's
    replan triggers (docs/architecture.md "placement layer").
    """

    def __init__(
        self,
        *,
        max_moves: int = 4,
        improve: float = 0.9,
        candidate_k: int = 8,
        straggler_factor: float = 1.25,
        per_parent: int = 2,
        max_fanout: int = 6,
        min_interval_ms: float = 250.0,
        cooldown_ms: float = 1000.0,
        join_bytes: float = 4096.0,
        spike_occupancy: float = 6.0,
        spike_jain: float = 0.7,
    ):
        if max_moves < 0 or candidate_k < 1 or per_parent < 1 or max_fanout < 1:
            raise ValueError(
                "max_moves >= 0, candidate_k >= 1, per_parent >= 1, max_fanout >= 1 required"
            )
        if not 0.0 < improve <= 1.0:
            raise ValueError("improve must be in (0, 1]")
        self.max_moves = int(max_moves)
        self.improve = float(improve)
        self.candidate_k = int(candidate_k)
        self.straggler_factor = float(straggler_factor)
        self.per_parent = int(per_parent)
        self.max_fanout = int(max_fanout)
        self.min_interval_ms = float(min_interval_ms)
        self.cooldown_ms = float(cooldown_ms)
        self.join_bytes = float(join_bytes)
        self.join_mbit = float(join_bytes) * 8e-6
        self.spike_occupancy = float(spike_occupancy)
        self.spike_jain = float(spike_jain)
        self.flagged: dict[tuple[int, int], float] = {}
        self._last_move: dict[tuple[int, int], float] = {}
        self.replans = 0
        self.moves_applied = 0

    def reset(self) -> None:
        self.flagged.clear()
        self._last_move.clear()
        self.replans = 0
        self.moves_applied = 0

    def flag(self, app_idx: int, worker: int, weight: float = 1.0) -> None:
        """Telemetry feed: mark ``worker`` as transport-hurt (deferred past
        deadline, blocklist-bound, …).  Flagged workers move first."""
        key = (int(app_idx), int(worker))
        self.flagged[key] = self.flagged.get(key, 0.0) + float(weight)

    def consume_flags(self, app_idx: int) -> dict[int, float]:
        out = {w: v for (a, w), v in self.flagged.items() if a == app_idx}
        for w in out:
            del self.flagged[(app_idx, w)]
        return out

    def plan_tree(
        self,
        tree,
        *,
        rows,
        cap,
        occ,
        base_ms: float,
        down_mbit: float,
        up_mbit: float,
        flagged=None,
        blocked=frozenset(),
        app_idx: int = 0,
        now_ms: float = 0.0,
    ) -> list[Move]:
        """One best-response step over ``tree``; returns validated moves
        (cycle-free against the current tree, deterministic order).
        ``now_ms`` drives the per-node move cooldown: a node re-grafted
        within the last ``cooldown_ms`` is not moved again, so a churn
        repair reverting a placement cannot thrash the same worker back
        and forth every replan."""
        if self.max_moves == 0 or tree._n <= 1:
            return []
        up, down, hc_up, hc_down = tree_path_costs(
            tree, rows, cap, occ, base_ms=base_ms, down_mbit=down_mbit, up_mbit=up_mbit
        )
        cache = tree._ensure_cache()
        srt, slots_srt = cache["ids_sorted"], cache["slots_sorted"]
        if len(srt) == 0:
            return []
        blocked_arr = (
            np.asarray(sorted(blocked), np.int64) if blocked else np.empty(0, np.int64)
        )

        # member slots (vectorized id -> slot over the sorted cache)
        marr = np.asarray(sorted(tree.members), np.int64)
        j = np.searchsorted(srt, marr)
        jj = np.minimum(j, len(srt) - 1)
        known = (j < len(srt)) & (srt[jj] == marr)
        mslots = slots_srt[jj[known]]
        mids = marr[known]
        good = np.isfinite(up[mslots]) & (mids != tree.root)
        if len(blocked_arr):
            good &= ~np.isin(mids, blocked_arr)
        mslots, mids = mslots[good], mids[good]
        if len(mids) == 0:
            return []

        total = up[mslots] + down[mslots]
        med = float(np.median(total))
        fl = flagged or {}
        fw = np.asarray([fl.get(int(w), 0.0) for w in mids], np.float64)
        cooled = np.asarray(
            [
                now_ms - self._last_move.get((app_idx, int(w)), float("-inf"))
                >= self.cooldown_ms
                for w in mids
            ],
            bool,
        )
        eligible = cooled & ((fw > 0.0) | (total >= self.straggler_factor * med))
        # flagged first, then costliest, id ascending for determinism
        order = np.lexsort((mids, -total, -(fw > 0.0).astype(np.int64)))
        movers = [int(i) for i in order if eligible[i]][: self.max_moves]
        if not movers:
            return []
        mover_ids = mids[movers]

        # candidate attachment points: reachable, not blocked, not a mover,
        # scored by the mover-independent term — one vectorized pass
        all_slots = np.concatenate(cache["levels"]) if cache["levels"] else np.empty(0, np.int64)
        score = up[all_slots] + down[all_slots] + hc_down[all_slots]
        cids = tree._ids[all_slots]
        ok = ~np.isin(cids, mover_ids)
        if len(blocked_arr):
            ok &= ~np.isin(cids, blocked_arr)
        all_slots, score, cids = all_slots[ok], score[ok], cids[ok]
        if len(cids) == 0:
            return []
        pick = np.lexsort((cids, score))[: self.candidate_k]
        cand_slots = all_slots[pick]
        cand_ids = cids[pick]
        cand_score = score[pick]
        # current child counts: a hub cap — piling movers onto one parent
        # both re-creates the contention being planned away and makes
        # that parent a single point of failure under churn
        cand_kids = np.where(
            tree._ch_present[cand_slots], tree._ch_len[cand_slots], 0
        ).astype(np.int64)

        moves: list[Move] = []
        assigned: dict[int, int] = {}
        parent = tree.parent
        root = tree.root
        for mi in movers:
            w = int(mids[mi])
            ws = int(mslots[mi])
            base_cost = float(total[mi])
            chosen = None
            for ci, (cid, sc) in enumerate(zip(cand_ids.tolist(), cand_score.tolist())):
                cid = int(cid)
                if assigned.get(cid, 0) >= self.per_parent:
                    continue
                if int(cand_kids[ci]) + assigned.get(cid, 0) >= self.max_fanout:
                    continue
                est = float(hc_up[ws]) + float(sc)
                if est > self.improve * base_cost:
                    continue
                # cycle guard: the candidate must not sit in w's subtree
                cur, inside = cid, False
                while cur != root:
                    if cur == w:
                        inside = True
                        break
                    cur = parent[cur]
                if inside:
                    continue
                chosen = (cid, est)
                break  # candidates are score-sorted: first admissible wins
            if chosen is None:
                continue
            cid, est = chosen
            old_parent = int(parent[w])
            if cid == old_parent:
                continue
            moves.append(Move(w, old_parent, cid, base_cost, est))
            assigned[cid] = assigned.get(cid, 0) + 1
            self._last_move[(app_idx, w)] = float(now_ms)
        return moves
