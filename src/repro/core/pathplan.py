"""Game-theoretic path planning — Algorithm 1 (paper §V-B), in JAX.

Per episode, every node: (line 3) samples tau next hops from its policy
and observes bandit rewards; (line 5) picks the exploratory policy
rho = argmin_det M(lambda) over its candidate policy set Delta(P_n);
(line 6) estimates the potential gradient by importance-weighted linear
regression grad(p) = (1/tau) sum_t psi(p)^T M(pi)^{-1} psi(p_t) r_t —
with one-hot psi this is sum_t 1[p_t=p] r_t / pi(p); (line 7) takes the
candidate maximizing <lambda, grad>; (line 8) Frank–Wolfe mixes with
exploration: pi' = alpha[pi + beta(pi~ - pi)] + (1-alpha) rho.

Everything is vmapped over nodes and jitted — the per-node update is pure
matrix algebra (the O(log N * Matmul) claim, Fig. 15/16); the Pallas
``policy_update`` kernel is the TPU port of the same update.

Baselines (paper §VII-E): the EuroSys'24 Totoro bandit planner (UCB on
per-hop delay, congestion-blind) and OPT (knows capacities; greedy
balanced assignment).  ``nash_regret`` evaluates both per Definition 2.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .congestion import CongestionEnv

NEG = -1e9


def candidate_policy_set(K: int, num_random: int = 8, *, seed: int = 0) -> jnp.ndarray:
    """Delta(P_n): a finite candidate set over K hops — the uniform policy,
    per-hop skewed corners (0.9 mass), and a few Dirichlet samples.
    All entries strictly positive (Theorem 1's no-zero-element condition)."""
    rng = np.random.default_rng(seed)
    cands = [np.full(K, 1.0 / K)]
    for k in range(K):
        v = np.full(K, 0.1 / max(K - 1, 1))
        v[k] = 0.9
        cands.append(v)
    for _ in range(num_random):
        cands.append(rng.dirichlet(np.ones(K)) * 0.9 + 0.1 / K)
    M = np.stack(cands)
    return jnp.asarray(M / M.sum(-1, keepdims=True), jnp.float32)


@partial(jax.jit, static_argnames=("tau",))
def algorithm1_episode(pi, mask, cand, actions, rewards, *, tau: int, alpha: float, beta: float):
    """One Algorithm-1 policy update, batched over nodes.

    pi: (N, K) current policies;  mask: (N, K) valid-hop mask;
    cand: (M, K) candidate policy set Delta(P_n) (shared, re-masked per node);
    actions: (N, tau) sampled hop indices;  rewards: (N, tau).
    Returns pi^{k+1}: (N, K).
    """
    maskf = mask.astype(jnp.float32)

    # re-normalize the candidate set onto each node's valid hops
    candn = cand[None] * maskf[:, None, :]  # (N, M, K)
    candn = candn / jnp.maximum(candn.sum(-1, keepdims=True), 1e-12)

    # line 5: rho = argmin det M(lambda); one-hot psi => det = prod lambda_k
    logdet = jnp.where(maskf[:, None, :] > 0, jnp.log(jnp.maximum(candn, 1e-12)), 0.0).sum(-1)
    rho = candn[jnp.arange(pi.shape[0]), jnp.argmin(logdet, axis=1)]  # (N, K)

    # line 6: importance-weighted gradient estimate (M(pi)^{-1} = diag(1/pi))
    onehot = jax.nn.one_hot(actions, pi.shape[1], dtype=jnp.float32)  # (N, tau, K)
    grad = (onehot * rewards[..., None]).sum(1) / (tau * jnp.maximum(pi, 1e-12))
    grad = grad * maskf

    # line 7: best candidate by inner product
    scores = jnp.einsum("nmk,nk->nm", candn, grad)
    pi_tilde = candn[jnp.arange(pi.shape[0]), jnp.argmax(scores, axis=1)]

    # line 8: Frank–Wolfe + exploration mixture
    pi_new = alpha * (pi + beta * (pi_tilde - pi)) + (1.0 - alpha) * rho
    pi_new = pi_new * maskf
    return pi_new / jnp.maximum(pi_new.sum(-1, keepdims=True), 1e-12)


@dataclass
class GameTheoreticPlanner:
    """Totoro+ planner (Algorithm 1)."""

    num_nodes: int
    num_paths: int
    tau: int = 8
    alpha: float = 0.9
    beta: float = 0.5
    mask: jnp.ndarray | None = None  # (N, K) valid hops
    seed: int = 0

    def __post_init__(self):
        K = self.num_paths
        self.mask = (
            jnp.ones((self.num_nodes, K), bool) if self.mask is None else self.mask
        )
        pi = jnp.ones((self.num_nodes, K), jnp.float32) * self.mask
        self.pi = pi / pi.sum(-1, keepdims=True)
        self.cand = candidate_policy_set(K, seed=self.seed)

    def sample_actions(self, key) -> jnp.ndarray:
        """(tau,) packets per node, i.i.d. from the current policies."""
        return jax.random.categorical(
            key, jnp.log(jnp.maximum(self.pi, 1e-12))[:, None, :].repeat(self.tau, 1)
        )

    def update(self, actions, rewards) -> None:
        self.pi = algorithm1_episode(
            self.pi, self.mask, self.cand, actions, rewards,
            tau=self.tau, alpha=self.alpha, beta=self.beta,
        )


@dataclass
class BanditPlanner:
    """EuroSys'24 Totoro baseline: per-hop UCB on observed reward,
    congestion-blind (Appendix B's bandit model)."""

    num_nodes: int
    num_paths: int
    tau: int = 8
    explore_c: float = 0.5
    epsilon: float = 0.05

    def __post_init__(self):
        N, K = self.num_nodes, self.num_paths
        self.counts = jnp.ones((N, K), jnp.float32)
        self.means = jnp.zeros((N, K), jnp.float32)
        self.t = 1

    @property
    def pi(self) -> jnp.ndarray:
        """Greedy-UCB induced (nearly deterministic) policy."""
        ucb = self.means + self.explore_c * jnp.sqrt(jnp.log(self.t + 1.0) / self.counts)
        best = jnp.argmax(ucb, axis=1)
        eye = jax.nn.one_hot(best, self.num_paths)
        return (1 - self.epsilon) * eye + self.epsilon / self.num_paths

    def sample_actions(self, key) -> jnp.ndarray:
        return jax.random.categorical(
            key, jnp.log(jnp.maximum(self.pi, 1e-12))[:, None, :].repeat(self.tau, 1)
        )

    def update(self, actions, rewards) -> None:
        onehot = jax.nn.one_hot(actions, self.num_paths, dtype=jnp.float32)
        cnt = onehot.sum(1)
        s = (onehot * rewards[..., None]).sum(1)
        new_counts = self.counts + cnt
        self.means = (self.means * self.counts + s) / new_counts
        self.counts = new_counts
        self.t += self.tau


@dataclass
class OptPlanner:
    """OPT oracle: knows capacities/thetas; greedy balanced assignment
    maximizing marginal mean reward given current congestion."""

    env: CongestionEnv
    num_nodes: int
    tau: int = 8

    def __post_init__(self):
        P = self.env.num_paths
        counts = np.zeros(P, np.int64)
        assign = np.zeros(self.num_nodes, np.int64)
        for n in range(self.num_nodes):
            best, best_r = 0, -1.0
            for p in range(P):
                r = self.env.mean_reward(p, int(counts[p]) + 1)
                if r > best_r:
                    best, best_r = p, r
            assign[n] = best
            counts[best] += 1
        self.assign = jnp.asarray(assign)

    @property
    def pi(self) -> jnp.ndarray:
        return jax.nn.one_hot(self.assign, self.env.num_paths)

    def sample_actions(self, key) -> jnp.ndarray:
        return jnp.broadcast_to(self.assign[:, None], (self.num_nodes, self.tau))

    def update(self, actions, rewards) -> None:
        pass


# ---------------------------------------------------------------------------
# evaluation: Nash regret + cumulative latency


@partial(jax.jit, static_argnames=("samples",))
def policy_values(env: CongestionEnv, pi: jnp.ndarray, key, samples: int = 64):
    """Monte-Carlo V_n(pi) and best-response values V_n(a, pi_{-n}).

    Returns (values (N,), best_response (N,)) using `samples` joint draws.
    """
    N, K = pi.shape
    keys = jax.random.split(key, samples)

    def draw(k):
        a = jax.random.categorical(k, jnp.log(jnp.maximum(pi, 1e-12)))
        counts = jnp.zeros(K, jnp.float32).at[a].add(1.0)
        # on-policy reward per node (mean over link success)
        rate = env.capacity[a] / jnp.maximum(counts[a], 1.0)
        lat = env.base_ms + 1e3 * env.packet_mbit / jnp.maximum(rate, 1e-6)
        r = jnp.clip(1.0 - lat / env.l_max_ms, 0.0, 1.0) * env.theta[a]
        # deviation values: node n switches to pure action p (others fixed)
        counts_wo = counts[None, :] - jax.nn.one_hot(a, K)  # (N, K)
        cnt_dev = counts_wo + 1.0
        rate_dev = env.capacity[None, :] / jnp.maximum(cnt_dev, 1.0)
        lat_dev = env.base_ms + 1e3 * env.packet_mbit / jnp.maximum(rate_dev, 1e-6)
        r_dev = jnp.clip(1.0 - lat_dev / env.l_max_ms, 0.0, 1.0) * env.theta[None, :]
        return r, r_dev

    rs, rdevs = jax.lax.map(draw, keys)
    v = rs.mean(0)  # (N,)
    v_dev = rdevs.mean(0)  # (N, K)
    return v, jnp.max(v_dev, axis=1)


def nash_regret_step(env, pi, key, samples: int = 64) -> float:
    v, br = policy_values(env, pi, key, samples)
    return float(jnp.max(br - v))


def run_planner(planner, env: CongestionEnv, episodes: int, *, seed: int = 1, eval_samples: int = 64):
    """Drive a planner; returns dict of per-episode series."""
    key = jax.random.key(seed)
    lat_total = 0.0
    series = {"nash_regret": [], "cum_latency_ms": [], "mean_reward": []}
    for ep in range(episodes):
        key, k1, k2, k3 = jax.random.split(key, 4)
        actions = planner.sample_actions(k1)  # (N, tau)
        rws = []
        lats = []
        for t in range(actions.shape[1]):
            kk = jax.random.fold_in(k2, t)
            a_t = actions[:, t]
            rws.append(env.rewards(a_t, kk))
            lats.append(env.latency_ms(a_t))
        rewards = jnp.stack(rws, 1)
        lat_total += float(jnp.sum(jnp.stack(lats)) / actions.shape[0])
        planner.update(actions, rewards)
        series["nash_regret"].append(nash_regret_step(env, planner.pi, k3, eval_samples))
        series["cum_latency_ms"].append(lat_total)
        series["mean_reward"].append(float(jnp.mean(rewards)))
    series["selection_freq"] = np.asarray(
        jax.nn.one_hot(planner.sample_actions(jax.random.key(99)), env.num_paths).mean((0, 1))
    )
    return series
