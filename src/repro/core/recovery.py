"""Failure recovery (paper §IV-D): parallel repair of dataflow trees.

Worker failure: children stop receiving keep-alives, each orphan routes a
JOIN using AppId to find a new parent (repairs happen in parallel — the
modeled recovery time is detection timeout + the *max* re-join latency).
Master failure: state is replicated across k neighborhood-set nodes every
round; the numerically-next node takes over, restores from any replica,
and the tree re-grafts under it.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .forest import DataflowTree, Forest
from .nodeid import abs_ring_distance
from .overlay import MultiRingOverlay

KEEPALIVE_TIMEOUT_MS = 500.0


@dataclass
class RecoveryReport:
    failed: list[int]
    orphans_rejoined: int
    master_failed: bool
    new_master: int | None
    recovery_time_ms: float
    hops: int  # max hops any repair took
    restored_from_replica: int | None = None


class ReplicaStore:
    """Master state replication across the k-node neighborhood set."""

    def __init__(self, k: int = 2):
        self.k = k
        self.replicas: dict[int, dict[int, object]] = {}  # app_id -> {holder: state}

    def replicate(self, overlay: MultiRingOverlay, app_id: int, master: int, state) -> list[int]:
        holders = overlay.neighborhood_set(master)[: self.k]
        self.replicas[app_id] = {h: state for h in holders}
        return holders

    def restore(self, overlay: MultiRingOverlay, app_id: int, *, master: int | None = None):
        """Restore from the live holder ring-closest to the failed master.

        Any intact copy suffices for correctness; picking by ring distance
        (ties broken by id) makes the takeover deterministic — the old
        dict-insertion-order scan depended on replication-call history.
        """
        live = [h for h in self.replicas.get(app_id, {}) if h in overlay.alive]
        if not live:
            return None, None
        if master is None:
            holder = min(live)
        else:
            space = overlay.space
            ms = space.suffix_of(master)
            holder = min(
                live,
                key=lambda h: (
                    abs_ring_distance(space.suffix_of(h), ms, space.suffix_space),
                    h,
                ),
            )
        return holder, self.replicas[app_id][holder]


def fail_and_recover(
    overlay: MultiRingOverlay,
    forest: Forest,
    tree: DataflowTree,
    failed: list[int],
    *,
    replicas: ReplicaStore | None = None,
) -> RecoveryReport:
    """Fail `failed` nodes simultaneously; repair the tree in parallel."""
    failed_set = set(failed)
    for n in failed:
        overlay.fail(n)

    master_failed = tree.root in failed_set
    new_master = None
    restored_from = None
    max_hops = 0
    max_latency = 0.0

    if master_failed:
        # the immediate child detects it and routes a JOIN by AppId: the new
        # rendezvous is the live node numerically closest to AppId
        space = overlay.space
        zone = tree.meta.get("restrict_zone")
        if zone is None:
            zone = overlay.nearest_zone(space.zone_of(tree.app_id))
        new_master = overlay._zone_closest(zone, space.suffix_of(tree.app_id))
        detector = next(iter(tree.children.get(tree.root, [])), new_master)
        if detector in failed_set or detector is None:
            detector = new_master
        res = overlay.route(detector, tree.app_id)
        max_hops = max(max_hops, res.hops)
        max_latency = max(max_latency, overlay.path_latency(res.path))
        if replicas is not None:
            restored_from, _state = replicas.restore(overlay, tree.app_id, master=tree.root)
        old_root = tree.root
        tree.root = new_master
        tree.parent.pop(new_master, None)
        for c in tree.children.pop(old_root, []):
            if c not in failed_set and c != new_master:
                tree.parent[c] = new_master
                tree.children.setdefault(new_master, []).append(c)

    # drop failed nodes' edges; collect orphans
    orphans = []
    for n in failed_set:
        for c in tree.children.pop(n, []):
            if c not in failed_set:
                orphans.append(c)
        p = tree.parent.pop(n, None)
        if p is not None and p in tree.children and n in tree.children[p]:
            tree.children[p].remove(n)
        tree.members.discard(n)

    # each orphan re-JOINs by AppId (parallel): new parent = first live tree
    # node on its route (or the root)
    rejoined = 0
    for o in orphans:
        if o in failed_set or o == tree.root:
            continue
        res = overlay.route(o, tree.app_id)
        max_hops = max(max_hops, res.hops)
        max_latency = max(max_latency, overlay.path_latency(res.path))
        # graft o under the first node of the path that is in the tree
        parent = tree.root
        for hop in res.path[1:]:
            if hop == tree.root or hop in tree.parent:
                parent = hop
                break
        if parent == o:
            parent = tree.root
        tree.parent[o] = parent
        tree.children.setdefault(parent, []).append(o)
        rejoined += 1

    return RecoveryReport(
        failed=sorted(failed_set),
        orphans_rejoined=rejoined,
        master_failed=master_failed,
        new_master=new_master,
        recovery_time_ms=KEEPALIVE_TIMEOUT_MS + max_latency,
        hops=max_hops,
        restored_from_replica=restored_from,
    )


def verify_tree(tree: DataflowTree, overlay: MultiRingOverlay) -> bool:
    """Every member reaches the root through live nodes, acyclically."""
    for n in tree.members:
        if n not in overlay.alive:
            return False
        seen = set()
        cur = n
        while cur != tree.root:
            if cur in seen or cur not in tree.parent:
                return False
            seen.add(cur)
            cur = tree.parent[cur]
            if cur not in overlay.alive:
                return False
    return True
