"""Discrete-event execution layer: pluggable schedulers on one clock.

The round engine used to be a monolith: ``MultiAppSimulator`` priced each
app's round as a fixed chain of phases with a hard barrier per round.
This module splits that into an event core plus two schedulers:

- ``EventCore`` owns the shared clock (heap of completion events), the
  congestion-priced transfer model from ``core/congestion.py`` (a node
  uploading to k concurrent flows serves each at capacity/k), and event
  cancellation — everything that is *not* policy.
- ``SyncRoundScheduler`` reproduces the original barrier-per-round
  behavior (paper §VII-D, Table III): broadcast levels down, one compute
  phase, aggregation levels up.  ``MultiAppSimulator`` remains as an
  alias.  New: ``pipelined=True`` prices dissemination with per-edge
  store-and-forward overlap (``pipelined_time``), so a deep tree's
  broadcast cost approaches its max level instead of the level sum.
- ``AsyncBufferScheduler`` is the FedBuff-style async path (ROADMAP):
  every worker runs its own download -> compute -> upload cycle as
  individual clock events, commits land in the master's buffer, and the
  aggregator applies a staleness-weighted buffered update after K
  arrivals.  A ``ChurnModel`` injects fail/rejoin events on the *same*
  clock, driving ``core/recovery.fail_and_recover`` mid-round so repair
  latency lands on the timeline.
- **Weighted-fair transfer pricing** (this PR, the multi-app starvation
  fix): the PR-1/PR-2 transfer model priced a flow once, at start time,
  against whatever else happened to be in flight — so a flow that began
  alone kept its solo ``capacity`` rate even after k contenders arrived,
  and a flow that began against k contenders kept ``capacity/k`` after
  they all drained.  Both directions are wrong, and at M >= 16 apps the
  error compounds into uplink starvation (ROADMAP).  ``EventCore`` now
  carries a fluid-flow engine: each hop of a transfer is an open *flow*
  on its sender's uplink, the uplink is divided by weighted max-min fair
  sharing (``core/congestion.fair_share_rates``), and whenever a flow
  joins or completes every in-flight flow on that uplink is **re-priced
  progress-preservingly** — bytes already delivered at the old rate stay
  delivered, only the remaining bytes reschedule at the new rate (a
  virtual-finish-time update; total delivered bytes are conserved
  exactly across any number of re-prices).  ``AsyncBufferScheduler``
  uses the fair engine by default (``fair=False`` keeps the exact PR-3
  start-time pricing); an uncontended (single-flow) fair trace is
  identical to the legacy trace because one flow's fair share is the
  whole uplink.  Per-app ``transfer_weight`` / ``rate_cap_mbps`` knobs
  bias or bound the share, and a ``RelayAdmission`` policy adds
  staleness-aware admission at shared relays: a contended relay defers
  forwarding commits whose staleness discount ``1/(1+s)^a`` has decayed
  below a threshold, freeing uplink for fresh traffic (deferred commits
  resume FIFO as the uplink frees, or unconditionally at
  ``max_defer_ms``, so no commit is ever dropped).
- ``AdaptiveKController`` (PR 3) closes the loop on K: instead of a
  fixed buffer size, each buffered apply re-sizes K from the observed
  commit inter-arrival rate (EMA of arrivals per simulated millisecond)
  and the staleness distribution (a target percentile), clamped to
  ``[k_min, live membership]`` so churn can neither stall the buffer
  nor let K reference dead workers.  ``adaptive=False`` (the default)
  takes the exact PR-2 fixed-K code path — trace-identical, asserted by
  tests/test_selection.py.  Client admission is equally pluggable: a
  ``fl/selection.ClientSelector`` gates each worker's next cycle
  (utility-based straggler avoidance), with ``selector=None`` /
  ``UniformSelector`` preserving the admit-everyone behavior.

- **Hot-path overhaul** (this PR): transfer pricing and event plumbing
  were the simulator's own bottleneck at M >= 16.  Three exact-semantics
  optimizations, all defaulting on: (1) *incremental repricing* — each
  uplink keeps a ``core/congestion.UplinkState`` (incremental group
  counts + a cap ladder sorted by the group-invariant ``cap/weight``
  ratio) and schedules ONE completion event (the earliest finisher)
  instead of one per flow, so a flow join/complete costs O(F) float
  adds + O(log H) heap work instead of O(F log H) pushes that each left
  a dead heap entry behind; (2) *lazy-deletion heap compaction* —
  cancelled events are counted and the heap is rebuilt once dead
  entries outnumber live ones, bounding heap size under churn; (3)
  *numpy-resident route tables* — ``transfer_ms`` prices phases with
  f32 numpy arithmetic (bit-identical to the jitted lookup it
  replaces) and ``_path_senders`` memoizes per-(app, worker, direction)
  sender arrays between churn events.  ``incremental=False`` restores
  the full-water-filling engine; traces are byte-identical either way
  (gated by benchmarks/bench_hotpath.py).

Units and invariants: the clock is simulated milliseconds (``now``,
every ``*_ms``); transfer sizes are bytes (``model_bytes``), converted
once to megabits for ``CongestionEnv``; staleness is counted in model
*versions* (applies elapsed since the worker's download), not time.
Everything is deterministic: ties on the clock break by event sequence
number, churn and selection draws come from seeded generators owned by
their models, and the congestion pricing has no stochastic terms.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable

import jax.numpy as jnp
import numpy as np

from .congestion import CongestionEnv, UplinkState, fair_share_rates


@dataclass(frozen=True)
class RoundEvent:
    """One completed (app, round): recorded when the root finishes
    aggregating, i.e. the paper's per-app round completion time."""

    app_id: int
    round: int
    start_ms: float
    end_ms: float

    @property
    def duration_ms(self) -> float:
        return self.end_ms - self.start_ms


@dataclass(frozen=True)
class ApplyEvent:
    """One buffered apply at an app's master: the async analogue of a
    round completion (K deltas arrived, staleness-weighted update done).
    ``k`` is the effective buffer threshold that triggered this apply —
    the constructor K (clamped to live membership) in fixed mode, the
    controller's current K in adaptive mode."""

    app_id: int
    apply_index: int
    time_ms: float
    arrivals: int
    mean_staleness: float
    max_staleness: float
    k: int = 0


@dataclass(frozen=True)
class ChurnRecord:
    """A churn event as it landed on the clock (fail or rejoin)."""

    time_ms: float
    kind: str  # "fail" | "rejoin"
    nodes: tuple
    recovery_ms: float = 0.0


@dataclass(frozen=True)
class RelayAdmission:
    """Staleness-aware admission control at shared relay uplinks.

    When a relay already serves ``min_contenders`` or more flows, a
    commit whose staleness discount ``1/(1+s)^alpha`` (s in model
    versions, measured *now* — staleness keeps growing while the commit
    is in flight) has decayed below ``threshold`` is deferred at that
    relay: fresh traffic keeps the uplink, and the stale commit resumes
    FIFO when a flow on the uplink completes, or unconditionally after
    ``max_defer_ms`` — deferral delays, it never drops.  Each deferral
    is reported to the client selector (``on_defer``) so chronic
    deferral feeds the deadline term of utility-based selection.
    """

    threshold: float = 0.5
    alpha: float = 0.5
    min_contenders: int = 1
    max_defer_ms: float = 200.0


@dataclass(frozen=True)
class DeferRecord:
    """One relay-admission deferral as it resolved (telemetry)."""

    start_ms: float
    end_ms: float
    app_idx: int
    worker: int
    relay: int
    forced: bool  # True = resumed by the max_defer_ms deadline

    @property
    def waited_ms(self) -> float:
        return self.end_ms - self.start_ms


@dataclass(frozen=True)
class ReplanRecord:
    """One placement replan as it ran on the clock (telemetry).

    ``trigger`` names what marked the planner dirty (``bootstrap`` /
    ``churn`` / ``defer`` / ``selector`` / ``contention``); ``moves`` is
    the applied re-graft set as ``(app_idx, node, old_parent,
    new_parent)`` tuples; ``cost_ms`` is the total on-clock price of the
    JOIN control traffic (re-grafts are not free)."""

    time_ms: float
    trigger: str
    moves: tuple
    cost_ms: float
    control_bytes: float


class _Flow:
    """One in-flight hop transfer on a sender's uplink (fluid model)."""

    __slots__ = (
        "fid", "sender", "total_mbit", "delivered_mbit", "weight",
        "rate_cap", "on_done", "ev", "rate", "t_last", "group",
    )

    def __init__(self, fid, sender, mbit, weight, rate_cap, on_done, group):
        self.fid = fid
        self.sender = sender
        self.total_mbit = float(mbit)
        self.delivered_mbit = 0.0
        self.weight = float(weight)
        self.rate_cap = rate_cap
        self.on_done = on_done
        self.ev: int | None = None
        self.rate = 0.0
        self.t_last = 0.0
        self.group = group  # flows sharing a group split ONE weight share


def pipelined_time(level_ms, chunks: int = 8) -> float:
    """Store-and-forward pipelining of a phase sequence: the payload is
    cut into ``chunks`` pieces so level i+1 starts forwarding as soon as
    the first piece lands.  total = sum(t)/C + max(t)*(C-1)/C — equal to
    the synchronous sum at C=1, approaching max(t) as C grows, and never
    exceeding the sum (max <= sum)."""
    ts = [float(t) for t in level_ms]
    if not ts:
        return 0.0
    c = max(1, int(chunks))
    return sum(ts) / c + max(ts) * (c - 1) / c


class EventCore:
    """Shared clock + congestion-priced transfers for the schedulers.

    ``handles``: the apps' ``AppHandle``s.  ``model_bytes`` sizes every
    transfer.  Transfers are priced when scheduled, against every flow
    still in flight (``CongestionEnv.latency_ms``), and stay registered
    as active flows until their completion event pops.
    """

    def __init__(
        self, system, handles, *, model_bytes: float, base_ms: float = 5.0,
        incremental: bool = True,
    ):
        self.system = system
        self.handles = list(handles)
        nodes = system.overlay.nodes()
        self._node_idx = {n: i for i, n in enumerate(nodes)}
        # vectorized mirror of _node_idx for sender_indices_many
        self._idx_ids = np.asarray(nodes, np.int64)  # globally ascending
        self._idx_vals = np.arange(len(nodes), dtype=np.int32)
        cap = np.asarray([system.overlay.bandwidth[n] for n in nodes], np.float32)
        self._cap_mbps = cap.astype(np.float64)
        self._cap_f32 = cap  # numpy-resident mirror for transfer_ms
        self.model_bytes = float(model_bytes)
        self.base_ms = float(base_ms)
        self.incremental = bool(incremental)
        self.env = CongestionEnv(
            capacity=jnp.asarray(cap),
            theta=jnp.ones(len(nodes), jnp.float32),
            packet_mbit=float(model_bytes) * 8e-6,
            base_ms=base_ms,
        )
        self.now = 0.0
        self.events_dispatched = 0
        self.heap_max = 0
        self._heap: list[tuple[float, int]] = []
        self._seq = 0
        self._dead = 0  # cancelled-but-unpopped heap entries (lazy deletion)
        self._active: dict[int, np.ndarray] = {}  # event seq -> sender idx array
        self._callbacks: dict[int, Callable | None] = {}
        # fluid fair-share flows (weighted processor sharing per uplink)
        self._flows: dict[int, _Flow] = {}
        self._flows_by_sender: dict[int, list[int]] = {}
        self._flow_seq = 0
        # incremental-repricing state: one allocator + at most one pending
        # completion event per uplink (instead of one event per flow)
        self._uplink_state: dict[int, UplinkState] = {}
        self._uplink_ev: dict[int, int] = {}
        # cohort batching: events sharing a cohort id keep their own
        # (t, seq) completion-time heap; only each cohort's earliest
        # member occupies the global heap (see schedule_cohort)
        self._cohorts: dict = {}  # cohort id -> [(t, seq), ...] heap
        self._cohort_of: dict[int, object] = {}  # member seq -> cohort id
        self._armed: dict[int, object] = {}  # seq in global heap -> cohort id
        # optional per-dispatch hook (event-count-triggered congestion
        # resampling); None keeps the dispatch loop branch nearly free
        self._tick_hook: Callable[[], None] | None = None
        # per-uplink delivered-bytes ledger: credited by schedulers on
        # commit/control completions (only when a placement engine is
        # attached), read by the engine's reward model
        self.uplink_bytes = np.zeros(len(nodes), np.float64)

    def _reset_clock(self) -> None:
        self.now = 0.0
        self.events_dispatched = 0
        self.heap_max = 0
        self._heap.clear()
        self._seq = 0
        self._dead = 0
        self._active.clear()
        self._callbacks.clear()
        self._flows.clear()
        self._flows_by_sender.clear()
        self._flow_seq = 0
        self._uplink_state.clear()
        self._uplink_ev.clear()
        self._cohorts.clear()
        self._cohort_of.clear()
        self._armed.clear()
        self.uplink_bytes[:] = 0.0

    def sender_indices(self, nodes) -> np.ndarray:
        return np.asarray([self._node_idx[n] for n in nodes], np.int32)

    def sender_indices_many(self, ids: np.ndarray) -> np.ndarray:
        """Vectorized ``sender_indices`` over an int64 id array; raises
        KeyError (like the dict lookup) on any id the core never indexed."""
        j = np.searchsorted(self._idx_ids, ids)
        jj = np.minimum(j, len(self._idx_ids) - 1)
        bad = (j >= len(self._idx_ids)) | (self._idx_ids[jj] != ids)
        if bad.any():
            raise KeyError(int(ids[np.flatnonzero(bad)[0]]))
        return self._idx_vals[jj].copy()

    def transfer_ms(
        self, senders: np.ndarray, *, reduce: str = "max", mbit: float | None = None
    ) -> float:
        """Price one phase's flows with every in-flight flow still active:
        per-flow latency = base + bits / (capacity_sender / k) where k is
        the number of concurrent flows sharing that sender's uplink.
        ``reduce="max"`` models parallel flows (phase ends when the
        slowest does); ``"sum"`` models store-and-forward along a path.
        ``mbit`` overrides the payload size (default: the full-model
        ``packet_mbit`` — commit legs under a compression policy pass
        their compressed size; an equal value is bit-identical).

        Runs on numpy-resident route/capacity tables: the old path built
        device arrays and dispatched a jitted lookup per *phase*, which
        recompiled for every distinct in-flight flow count.  The numpy
        arithmetic is f32 elementwise, bit-identical to the jitted
        ``CongestionEnv.latency_ms`` (sync traces are unchanged)."""
        if len(senders) == 0:
            return 0.0
        own = np.asarray(senders)
        if self._active:
            actions = np.concatenate([own] + list(self._active.values()))
        else:
            actions = own
        counts = np.bincount(actions, minlength=len(self._cap_f32)).astype(np.float32)
        rate = self._cap_f32[own] / np.maximum(counts[own], np.float32(1.0))
        lat = np.float32(self.base_ms) + np.float32(
            1e3 * (self.env.packet_mbit if mbit is None else mbit)
        ) / np.maximum(rate, np.float32(1e-6))
        return float(lat.sum() if reduce == "sum" else lat.max())

    def schedule(self, delay_ms: float, callback: Callable, senders: np.ndarray | None = None) -> int:
        """Push a completion event ``delay_ms`` from now; ``senders`` (if
        given) are registered as active flows until the event pops.
        Returns the event seq (usable with ``cancel``)."""
        seq = self._seq
        self._seq += 1
        if senders is not None and len(senders):
            self._active[seq] = senders
        self._callbacks[seq] = callback
        heapq.heappush(self._heap, (self.now + delay_ms, seq))
        if len(self._heap) > self.heap_max:
            self.heap_max = len(self._heap)  # telemetry: peak incl. dead entries
        return seq

    def schedule_cohort(self, cohort, delay_ms: float, callback: Callable,
                        senders: np.ndarray | None = None) -> int:
        """Like ``schedule``, but events sharing ``cohort`` (any hashable
        id — the async scheduler passes the app index) share ONE global
        heap entry: the cohort keeps its own (t, seq) completion-time
        heap, and only its earliest member is "armed" into the global
        heap.  When that member pops, the next one is armed.  Because a
        member always enters the global heap carrying its original
        (t, seq) — and every unarmed member of its cohort sorts after
        it — the dispatch order is exactly the per-event baseline's
        (the M=16 trace-identity gate in tests/test_scale.py), while the
        heap holds O(cohorts) entries instead of O(workers)."""
        seq = self._seq
        self._seq += 1
        if senders is not None and len(senders):
            self._active[seq] = senders
        self._callbacks[seq] = callback
        h = self._cohorts.setdefault(cohort, [])
        heapq.heappush(h, (self.now + delay_ms, seq))
        self._cohort_of[seq] = cohort
        self._arm_cohort(cohort)
        return seq

    def _arm_cohort(self, cohort) -> None:
        """Push the cohort's earliest live, not-yet-armed member into the
        global heap (no-op if the head is already armed)."""
        h = self._cohorts.get(cohort)
        while h:
            t, seq = h[0]
            if seq in self._armed:
                return  # head already in the global heap
            if self._callbacks.get(seq) is None:
                heapq.heappop(h)  # cancelled before ever arming: drop
                self._callbacks.pop(seq, None)
                self._cohort_of.pop(seq, None)
                continue
            self._armed[seq] = cohort
            heapq.heappush(self._heap, (t, seq))
            if len(self._heap) > self.heap_max:
                self.heap_max = len(self._heap)
            return
        self._cohorts.pop(cohort, None)

    def cancel(self, seq: int) -> None:
        """Void a pending event (its flows stop contending immediately).
        Safe on an already-fired seq (the fair path re-cancels the last
        leg event of a cycle wholesale on churn).

        Cancellation is lazy — the heap entry stays until popped — but
        counted: once dead entries outnumber live ones the heap is
        compacted, so churn- and reprice-cancelled events can no longer
        bloat ``run_events`` for the rest of a run (regression:
        tests/test_hotpath.py).  An unarmed cohort member occupies no
        global heap entry; it is only marked dead and dropped lazily
        when it reaches its cohort's head."""
        if seq in self._cohort_of and seq not in self._armed:
            if self._callbacks.get(seq) is not None:
                self._callbacks[seq] = None
            self._active.pop(seq, None)
            return
        if self._callbacks.get(seq) is not None:
            self._callbacks[seq] = None
            self._dead += 1
            if self._dead > 64 and self._dead * 2 > len(self._heap):
                self._compact_heap()
        self._active.pop(seq, None)

    def _compact_heap(self) -> None:
        """Drop every dead (cancelled) entry and re-heapify in O(live)."""
        cbs = self._callbacks
        self._heap = [e for e in self._heap if cbs.get(e[1]) is not None]
        heapq.heapify(self._heap)
        for seq in [s for s, cb in cbs.items() if cb is None]:
            del cbs[seq]
            self._active.pop(seq, None)
            self._cohort_of.pop(seq, None)
        self._dead = 0
        # compaction may have evicted dead ARMED cohort members from the
        # global heap — their cohorts must be re-armed or they stall
        in_heap = {s for _, s in self._heap}
        stale = [s for s in self._armed if s not in in_heap]
        for seq in stale:
            cohort = self._armed.pop(seq)
            h = self._cohorts.get(cohort)
            if h and h[0][1] == seq:
                heapq.heappop(h)
            self._arm_cohort(cohort)

    # -- fluid fair-share flows (weighted-fair transfer pricing) ---------------

    def open_flow(
        self,
        sender: int,
        mbit: float,
        *,
        weight: float = 1.0,
        rate_cap: float | None = None,
        on_done: Callable[[float], None],
        group=None,
    ) -> int:
        """Start one hop transfer of ``mbit`` megabits on ``sender``'s
        uplink.  The uplink is shared by weighted max-min fair sharing;
        opening (and closing) a flow re-prices every in-flight flow on
        that uplink progress-preservingly.  Flows with the same non-None
        ``group`` (the async scheduler passes the app index) split one
        ``weight`` share — and one ``rate_cap`` — between them, so an
        app's aggregate share of a relay is set by its weight, not by
        how many of its workers happen to route through it.
        ``on_done(t)`` fires when the last byte lands."""
        fid = self._flow_seq
        self._flow_seq += 1
        key = ("solo", fid) if group is None else ("grp", group)
        f = _Flow(fid, int(sender), mbit, weight, rate_cap, on_done, key)
        f.t_last = self.now
        self._flows[fid] = f
        self._flows_by_sender.setdefault(f.sender, []).append(fid)
        if self.incremental:
            st = self._uplink_state.get(f.sender)
            if st is None:
                st = self._uplink_state[f.sender] = UplinkState(
                    float(self._cap_mbps[f.sender])
                )
            st.add(fid, f.weight, f.rate_cap, key)
        self._reprice_uplink(f.sender)
        return fid

    def cancel_flow(self, fid: int) -> None:
        """Abort an in-flight flow (sender failed / cycle cancelled); the
        survivors on that uplink immediately speed up."""
        f = self._flows.pop(fid, None)
        if f is None:
            return
        if f.ev is not None:
            self.cancel(f.ev)
        self._drop_from_sender(f)
        self._reprice_uplink(f.sender)
        self._on_uplink_freed(f.sender, self.now)

    def flow_contenders(self, sender: int) -> int:
        """Number of flows currently sharing ``sender``'s uplink."""
        return len(self._flows_by_sender.get(int(sender), ()))

    def _drop_from_sender(self, f: _Flow) -> None:
        fids = self._flows_by_sender.get(f.sender)
        if fids is not None:
            fids.remove(f.fid)
            if not fids:
                del self._flows_by_sender[f.sender]
        if self.incremental:
            self._uplink_state[f.sender].remove(f.fid)

    def _reprice_uplink(self, sender: int) -> None:
        """Progress-preserving re-price of every flow on one uplink:
        credit bytes delivered at the old rates since the last update,
        recompute the weighted-fair rates, reschedule the completion(s)
        at ``remaining / new_rate`` (a virtual-finish-time update).

        Incremental mode (the default) gets the rates from the uplink's
        ``UplinkState`` (group counts and the sorted cap ladder are
        maintained on join/complete, not rebuilt here) and schedules ONE
        completion event — the earliest finisher — instead of one per
        flow: a reprice costs O(F) float work + O(log H) heap work where
        the legacy path paid O(F log H) pushes and left F dead heap
        entries behind.  Completion times are computed with the same
        arithmetic in the same flow order, so event traces are
        byte-identical across both modes (bench_hotpath's gate).
        """
        if self.incremental:
            prev = self._uplink_ev.pop(sender, None)
            if prev is not None:
                self.cancel(prev)
            fids = self._flows_by_sender.get(sender)
            if not fids:
                return
            flows = [self._flows[fid] for fid in fids]
            now = self.now
            for f in flows:
                f.delivered_mbit = min(
                    f.total_mbit, f.delivered_mbit + f.rate * (now - f.t_last) * 1e-3
                )
                f.t_last = now
            rates = self._uplink_state[sender].rates()
            best_fid, best_delay = None, None
            for f, r in zip(flows, rates):
                f.rate = r
                d = 1e3 * (f.total_mbit - f.delivered_mbit) / max(r, 1e-9)
                # strict < keeps the earliest-opened flow on ties, matching
                # the legacy per-flow events' seq-order tie-break
                if best_delay is None or d < best_delay:
                    best_fid, best_delay = f.fid, d
            self._uplink_ev[sender] = self.schedule(
                best_delay, lambda t, fid=best_fid: self._finish_flow(fid, t)
            )
            return
        fids = self._flows_by_sender.get(sender)
        if not fids:
            return
        flows = [self._flows[fid] for fid in fids]
        for f in flows:
            f.delivered_mbit = min(
                f.total_mbit, f.delivered_mbit + f.rate * (self.now - f.t_last) * 1e-3
            )
            f.t_last = self.now
        # per-group (= per-app) fairness: flows in one group split a
        # single weight share and rate cap equally, so an app's slice of
        # a relay is its weight, not its concurrent-flow count
        group_n: dict = {}
        for f in flows:
            group_n[f.group] = group_n.get(f.group, 0) + 1
        rates = fair_share_rates(
            float(self._cap_mbps[sender]),
            [f.weight / group_n[f.group] for f in flows],
            [None if f.rate_cap is None else f.rate_cap / group_n[f.group] for f in flows],
        )
        for f, r in zip(flows, rates):
            f.rate = r
            if f.ev is not None:
                self.cancel(f.ev)
            remaining = f.total_mbit - f.delivered_mbit
            f.ev = self.schedule(
                1e3 * remaining / max(r, 1e-9),
                lambda t, fid=f.fid: self._finish_flow(fid, t),
            )

    def _finish_flow(self, fid: int, t: float) -> None:
        f = self._flows.pop(fid)
        f.delivered_mbit = f.total_mbit  # exact byte conservation
        self._drop_from_sender(f)
        self._reprice_uplink(f.sender)
        f.on_done(t)
        self._on_uplink_freed(f.sender, t)

    def _on_uplink_freed(self, sender: int, t: float) -> None:
        """Hook: a flow left ``sender``'s uplink.  The async scheduler
        overrides this to resume relay-deferred commits."""

    def _progress_summary(self) -> str:
        """Hook: one-line per-app progress for the budget-exhausted
        diagnostic.  Schedulers override this with real progress."""
        return ""

    def run_events(self, *, max_events: int = 1_000_000, stop: Callable[[], bool] | None = None) -> None:
        """Drain the heap in clock order, dispatching callbacks."""
        n = 0
        while self._heap:
            if stop is not None and stop():
                return
            t, seq = heapq.heappop(self._heap)
            cohort = self._armed.pop(seq, None)
            if cohort is not None:
                # this member was its cohort's head: retire it and arm
                # the next earliest (which sorts at or after (t, seq))
                self._cohort_of.pop(seq, None)
                h = self._cohorts.get(cohort)
                if h and h[0][1] == seq:
                    heapq.heappop(h)
                self._arm_cohort(cohort)
            self._active.pop(seq, None)
            cb = self._callbacks.pop(seq, None)
            if cb is None:
                if self._dead:
                    self._dead -= 1
                continue  # cancelled
            self.now = t
            cb(t)
            n += 1
            self.events_dispatched += 1
            if self._tick_hook is not None:
                self._tick_hook()
            if n >= max_events:
                live = len(self._heap) - self._dead
                msg = (
                    f"event budget exhausted ({max_events} events dispatched): "
                    f"clock={self.now:.1f}ms, heap={max(live, 0)} live"
                    f"/{self._dead} dead entries"
                )
                prog = self._progress_summary()
                if prog:
                    msg += f"; {prog}"
                msg += (
                    " — raise max_events (threaded through run()/run_async"
                    "/bench entry points) for longer runs"
                )
                raise RuntimeError(msg)


class SyncRoundScheduler(EventCore):
    """Barrier-per-round scheduling (the original behavior, preserved).

    Each app's round is a chain of phases — broadcast the model
    level-by-level down its dataflow tree, workers compute E local steps,
    partial aggregates flow level-by-level back up — and every phase is
    one event.  ``compute_ms`` is a scalar or ``f(handle, round) -> ms``.
    ``pipelined=True`` collapses the broadcast levels into one phase
    priced by ``pipelined_time`` (per-edge store-and-forward overlap,
    never slower than the synchronous level sum); aggregation keeps the
    level chain either way (partial sums must land before forwarding).
    """

    def __init__(
        self,
        system,
        handles,
        *,
        model_bytes: float,
        compute_ms: float | Callable = 50.0,
        base_ms: float = 5.0,
        pipelined: bool = False,
        pipeline_chunks: int = 8,
    ):
        super().__init__(system, handles, model_bytes=model_bytes, base_ms=base_ms)
        self.compute_ms = compute_ms
        self.pipelined = pipelined
        self.pipeline_chunks = pipeline_chunks
        self._phases = [self._phases_of(h.tree) for h in self.handles]

    def _phases_of(self, tree) -> list[tuple[str, object]]:
        """Round = broadcast levels (sender = parent, one flow per child),
        one compute phase, aggregation levels (sender = each child)."""
        phases: list[tuple[str, object]] = []
        agg = tree.aggregation_schedule()
        bcast_levels = []
        for level in reversed(agg):  # root -> leaves
            senders = [self._node_idx[p] for p, kids in level for _ in kids]
            bcast_levels.append(np.asarray(senders, np.int32))
        if self.pipelined and bcast_levels:
            phases.append(("pbcast", bcast_levels))
        else:
            phases.extend(("bcast", s) for s in bcast_levels)
        phases.append(("compute", None))
        for level in agg:  # leaves -> root
            senders = [self._node_idx[c] for _, kids in level for c in kids]
            phases.append(("agg", np.asarray(senders, np.int32)))
        return phases

    def _compute_ms(self, app_idx: int, round_num: int) -> float:
        if callable(self.compute_ms):
            return float(self.compute_ms(self.handles[app_idx], round_num))
        return float(self.compute_ms)

    def run(self, rounds: int = 1) -> list[RoundEvent]:
        """Interleave every app's ``rounds`` rounds; returns the per-app
        completion records in completion order (deterministic)."""
        self._reset_clock()
        state = [{"phase": 0, "round": 0, "start": 0.0} for _ in self.handles]
        history: list[RoundEvent] = []

        def start_phase(i: int) -> None:
            kind, senders = self._phases[i][state[i]["phase"]]
            if kind == "compute":
                dur, senders = self._compute_ms(i, state[i]["round"]), None
            elif kind == "pbcast":
                # price each level against the current in-flight set, then
                # overlap them: all levels' flows stay active together
                level_ms = [self.transfer_ms(s) for s in senders]
                dur = pipelined_time(level_ms, self.pipeline_chunks)
                senders = np.concatenate(senders)
            elif senders is None or len(senders) == 0:
                dur, senders = 0.0, None
            else:
                dur = self.transfer_ms(senders)
            self.schedule(dur, lambda t, i=i: end_phase(i, t), senders)

        def end_phase(i: int, t: float) -> None:
            st = state[i]
            st["phase"] += 1
            if st["phase"] >= len(self._phases[i]):
                history.append(
                    RoundEvent(self.handles[i].app_id, st["round"], st["start"], t)
                )
                st["round"] += 1
                st["phase"] = 0
                st["start"] = t
                if st["round"] >= rounds:
                    return
            start_phase(i)

        for i in range(len(self._phases)):
            # every app has >= 1 phase: _phases_of always emits compute
            start_phase(i)
        self.run_events()
        return history


# the original name stays importable: the sync scheduler IS the old
# MultiAppSimulator, bit-for-bit on its event trace
MultiAppSimulator = SyncRoundScheduler


class ChurnModel:
    """Deterministic fail/rejoin schedule for the async scheduler.

    Every ``period_ms`` it fails ``group_size`` live workers (drawn from a
    seeded generator over the sorted live-worker pool — never a tree root
    unless ``allow_master_failure``); each failed node rejoins the overlay
    and re-Subscribes ``downtime_ms`` later.  Fail events call
    ``core/recovery.fail_and_recover`` per affected tree, so orphan
    re-grafts and master failover land on the simulation clock and their
    repair latency delays the orphans' next cycle.
    """

    def __init__(
        self,
        *,
        period_ms: float = 500.0,
        downtime_ms: float = 1500.0,
        group_size: int = 1,
        seed: int = 0,
        allow_master_failure: bool = False,
        max_fail_events: int | None = None,
    ):
        self.period_ms = float(period_ms)
        self.downtime_ms = float(downtime_ms)
        self.group_size = int(group_size)
        self.allow_master_failure = allow_master_failure
        self.max_fail_events = max_fail_events
        self.rng = np.random.default_rng(seed)
        self.fired = 0

    def pick_victims(self, pool: list[int]) -> list[int]:
        if not pool:
            return []
        k = min(self.group_size, len(pool))
        idx = self.rng.choice(len(pool), size=k, replace=False)
        return [pool[int(i)] for i in np.sort(idx)]

    def exhausted(self) -> bool:
        return self.max_fail_events is not None and self.fired >= self.max_fail_events


class AdaptiveKController:
    """Per-app feedback controller for the async buffer size K.

    The fixed-K scheduler has a built-in tension: small K applies
    eagerly (fast wall-clock progress, but every apply bumps the model
    version, so in-flight workers land with higher *staleness*), large K
    degenerates toward the barrier (low staleness, straggler-bound).
    This controller re-sizes K after every buffered apply from two
    observations:

    - **staleness feedback**: let ``p`` be the ``percentile``-th
      percentile of the staleness values (in model versions) in the
      buffer just applied.  K moves multiplicatively toward the
      ``target_staleness``: ``K *= 1 + gain * (p - target) / target``,
      with the per-apply multiplier clamped to [0.5, 2.0] — staleness
      above target grows K (fewer version bumps per cycle), below
      target shrinks it (apply more eagerly).
    - **arrival rate**: an EMA of commit arrivals per simulated
      millisecond (``arrivals_per_ms``, smoothed by ``arrival_beta``).
      With ``max_apply_interval_ms`` set, K is capped at
      ``rate * max_apply_interval_ms`` so the expected buffer fill time
      ``K / rate`` never exceeds the interval — under churn the rate
      drops and the cap pulls K down before the buffer can stall.
      Outage handling: the *first* commit gap longer than
      ``rate_gap_ms`` (default ``max_apply_interval_ms``) is treated as
      an outage — every worker failed, then rejoined — and resets the
      inter-arrival tracking instead of folding a near-zero
      instantaneous rate into the EMA (with a large ``arrival_beta``
      that poisoned rate cap would clamp K at ``k_min`` essentially
      forever), so the EMA keeps its pre-outage value and K recovers as
      soon as post-rejoin commits flow.  A *second* consecutive long
      gap is not an outage but a persistently slow arrival regime: it
      folds normally, so the interval cap still pulls K down when the
      system genuinely slows (the PR-3 behavior the cap exists for).

    The result is clamped to ``[k_min, min(k_max, live_workers)]``;
    live membership comes from the scheduler each apply, so failed
    workers can never be counted toward K.  ``history`` records
    ``(t_ms, k, staleness_percentile, arrivals_per_ms)`` per apply for
    telemetry.  Fully deterministic — no random draws.
    """

    def __init__(
        self,
        *,
        k_init: int = 8,
        k_min: int = 1,
        k_max: int | None = None,
        target_staleness: float = 1.5,
        percentile: float = 90.0,
        gain: float = 0.5,
        arrival_beta: float = 0.2,
        max_apply_interval_ms: float | None = None,
        rate_gap_ms: float | None = None,
    ):
        self.k_min = max(1, int(k_min))
        self.k_max = None if k_max is None else int(k_max)
        self.k = float(max(self.k_min, int(k_init)))
        self.target_staleness = float(target_staleness)
        self.percentile = float(percentile)
        self.gain = float(gain)
        self.arrival_beta = float(arrival_beta)
        self.max_apply_interval_ms = max_apply_interval_ms
        self.rate_gap_ms = rate_gap_ms if rate_gap_ms is not None else max_apply_interval_ms
        self.arrivals_per_ms = 0.0
        self._last_commit_ms: float | None = None
        self._tied_arrivals = 0
        self._gap_skipped = False
        self.history: list[tuple[float, int, float, float]] = []

    @property
    def current_k(self) -> int:
        return max(self.k_min, int(round(self.k)))

    def on_commit(self, t_ms: float) -> None:
        """One commit landed: fold its inter-arrival into the rate EMA.
        Commits tied on the clock (same event timestamp) are folded into
        one batch so a tie can never masquerade as an infinite rate."""
        if self._last_commit_ms is None:
            self._last_commit_ms = t_ms
            self._tied_arrivals = 1
            return
        dt = t_ms - self._last_commit_ms
        if dt <= 1e-9:
            self._tied_arrivals += 1
            return
        if self.rate_gap_ms is not None and dt > self.rate_gap_ms and not self._gap_skipped:
            # full-window outage (all workers down, now rejoined): restart
            # the inter-arrival tracking rather than folding a near-zero
            # instantaneous rate into the EMA — the pre-outage rate stands
            # until real post-rejoin arrivals update it, so K recovers.
            # Only one consecutive gap is forgiven: a second long gap is a
            # persistently slow regime and folds below, keeping the cap live
            self._gap_skipped = True
            self._last_commit_ms = t_ms
            self._tied_arrivals = 1
            return
        self._gap_skipped = False
        inst = self._tied_arrivals / dt
        if self.arrivals_per_ms == 0.0:
            self.arrivals_per_ms = inst
        else:
            self.arrivals_per_ms = (
                self.arrival_beta * inst + (1.0 - self.arrival_beta) * self.arrivals_per_ms
            )
        self._last_commit_ms = t_ms
        self._tied_arrivals = 1

    def on_apply(self, t_ms: float, staleness: list[int], live_workers: int) -> int:
        """One buffered apply finished: update K and return the new value."""
        p = float(np.percentile(staleness, self.percentile)) if staleness else 0.0
        err = (p - self.target_staleness) / max(self.target_staleness, 1e-6)
        mult = float(np.clip(1.0 + self.gain * err, 0.5, 2.0))
        k = self.k * mult
        if self.max_apply_interval_ms is not None and self.arrivals_per_ms > 0.0:
            k = min(k, self.arrivals_per_ms * float(self.max_apply_interval_ms))
        hi = float(live_workers) if live_workers > 0 else k
        if self.k_max is not None:
            hi = min(hi, float(self.k_max))
        self.k = float(np.clip(k, float(self.k_min), max(float(self.k_min), hi)))
        self.history.append((t_ms, self.current_k, p, self.arrivals_per_ms))
        return self.current_k


class AsyncBufferScheduler(EventCore):
    """FedBuff-style buffered-asynchronous execution on the event clock.

    Every (app, worker) runs an independent cycle: *download* the current
    model along its tree path (store-and-forward, congestion-priced),
    *compute* its E local steps (``compute_ms`` scalar or
    ``f(handle, worker, cycle) -> ms`` for heterogeneous edges), *upload*
    its delta along the path back to the master.  Each completed upload
    is a commit; after K commits the master applies a staleness-weighted
    buffered update and bumps the global model version.  No barrier:
    workers immediately begin their next cycle, so fast edges lap slow
    ones and arrive with staleness > 0.  ``barrier=True`` makes workers
    wait for the next apply before re-downloading — with K = W that is
    exactly the synchronous FedAvg round on per-worker events (every
    buffer holds one commit per worker at uniform staleness), which is
    the equivalence anchor tests/test_async.py checks against the
    synchronous engine.

    The data plane is delegated to an optional ``trainer``
    (``fl/async_engine.AsyncTrainer``): ``begin_download`` snapshots the
    version a worker trains from, ``commit``/``apply`` run the real
    batched training and the ``CommitDelta``/``ApplyBuffered`` verbs.
    Without a trainer the scheduler is a pure timing model.

    ``churn`` (a ``ChurnModel``) injects mid-round fail/rejoin events:
    failed workers' in-flight events are cancelled, affected trees are
    repaired through ``core/recovery.fail_and_recover`` on the same
    clock, and re-grafted orphans stall for the repair latency.

    Transfer pricing (this PR): ``fair=True`` (the default) runs every
    hop of every download/upload as a fluid flow on its sender's uplink
    through the ``EventCore`` fair-share engine — weighted max-min
    sharing, re-priced progress-preservingly whenever a flow joins or
    completes, so no app keeps a stale solo (or stale congested) rate.
    Per-app ``app_weights`` / ``app_rate_caps`` (falling back to the
    handles' ``transfer_weight`` / ``rate_cap_mbps``) bias or bound each
    app's share, and ``relay_admission`` (a ``RelayAdmission``) defers
    stale commits at contended relays.  ``fair=False`` restores the
    PR-3 start-time-only pricing bit for bit; a single-flow (never
    contended) trace is identical in both modes.  Per-app uplink bytes
    are accounted per delivered commit leg; ``transport_stats()`` and the
    per-apply ``fairness_log`` expose throughput and Jain's index.

    Compressed transport (docs/performance.md "compressed transport"):
    ``app_compression`` (an ``fl/compression.CompressionPolicy``, kind
    string, or per-app list; falling back to the handles'
    ``compression`` fields) prices every COMMIT leg at
    ``policy.wire_bytes(model_bytes)`` — through the fair-share flows,
    the legacy start-time pricing, and the sampled cold-cycle legs
    alike — and credits the uplink ledger at the same compressed size.
    Downloads stay full-model-sized.  ``None`` / ``kind="none"``
    reproduces the uncompressed trace byte-identically
    (tests/test_compression.py).

    Two control knobs are pluggable (both default OFF, preserving the
    PR-2 trace exactly):

    - ``adaptive=True`` replaces the fixed ``buffer_k`` with one
      ``AdaptiveKController`` per app (``buffer_k`` becomes K's initial
      value; ``adaptive_kwargs`` forwards controller config).  The live
      controllers are exposed as ``self.controllers`` after ``run()``.
    - ``selector`` (an ``fl/selection.ClientSelector``) gates every
      would-be worker cycle: declined workers are *parked* and
      re-offered at their app's next apply.  A liveness guard force-
      admits when fewer than K workers are in flight, so selection can
      never deadlock the buffer.

    Scale layer (docs/performance.md "scale layer"):

    - ``cohort=True`` (default) batches per-worker cycle events into one
      global heap entry per app cohort (``EventCore.schedule_cohort``):
      the heap holds O(apps + uplinks) entries instead of O(workers),
      and the dispatch order — hence the ApplyEvent/ChurnRecord trace —
      is byte-identical to the per-event baseline (``cohort=False``).
    - ``congestion_mode="exact"`` (default) prices every transfer leg
      through the fluid fair-share engine.  ``"sampled"`` prices COLD
      cycles statistically: the whole download+compute+upload cycle is
      priced once at start against the current uplink loads and runs as
      a single cohort event, while any cycle whose path crosses a hot
      uplink (>= ``hot_threshold`` concurrent flows + cold cycles) still
      runs exact leg-by-leg.  ``hot_threshold=0`` therefore degenerates
      sampled mode to exact mode (a tested invariant).  Cold cycles skip
      relay admission (their hops never individually materialize).
    """

    def __init__(
        self,
        system,
        handles,
        *,
        model_bytes: float,
        compute_ms: float | Callable = 50.0,
        base_ms: float = 5.0,
        buffer_k: int | list[int] = 8,
        churn: ChurnModel | None = None,
        trainer=None,
        barrier: bool = False,
        adaptive: bool = False,
        adaptive_kwargs: dict | None = None,
        selector=None,
        fair: bool = True,
        app_weights: float | list[float] | None = None,
        app_rate_caps: float | list[float] | None = None,
        relay_admission: RelayAdmission | None = None,
        incremental: bool = True,
        cohort: bool = True,
        congestion_mode: str = "exact",
        hot_threshold: int = 4,
        resample_every: float | None = None,
        resample_events: int | None = None,
        resample_target_error: float | None = None,
        app_compression=None,
        placement=None,
    ):
        super().__init__(
            system, handles, model_bytes=model_bytes, base_ms=base_ms,
            incremental=incremental,
        )
        if congestion_mode not in ("exact", "sampled"):
            raise ValueError(
                f"congestion_mode must be 'exact' or 'sampled', got {congestion_mode!r}"
            )
        if (resample_every is not None or resample_events is not None) and (
            congestion_mode != "sampled"
        ):
            raise ValueError(
                "resample_every/resample_events refresh frozen cold-cycle "
                "loads and only apply to congestion_mode='sampled'"
            )
        if resample_every is not None and not resample_every > 0:
            raise ValueError(f"resample_every must be > 0 ms, got {resample_every!r}")
        if resample_events is not None and not resample_events > 0:
            raise ValueError(f"resample_events must be > 0, got {resample_events!r}")
        if resample_target_error is not None:
            if resample_every is None and resample_events is None:
                raise ValueError(
                    "resample_target_error adapts the resample cadence and "
                    "needs resample_every and/or resample_events as the base"
                )
            if not resample_target_error > 0:
                raise ValueError(
                    f"resample_target_error must be > 0, got {resample_target_error!r}"
                )
        self.cohort = bool(cohort)
        self.congestion_mode = congestion_mode
        self.hot_threshold = int(hot_threshold)
        self.resample_every = None if resample_every is None else float(resample_every)
        self.resample_events = None if resample_events is None else int(resample_events)
        self.resample_target_error = (
            None if resample_target_error is None else float(resample_target_error)
        )
        # constructor-time cadence, restored at each run() so adaptation
        # never leaks across runs
        self._resample_every0 = self.resample_every
        self._resample_events0 = self.resample_events
        # live placement engine (docs/architecture.md "placement layer");
        # None keeps every hook dormant and the event trace byte-identical
        from .pathplan import PlacementEngine

        if placement is True:
            placement = PlacementEngine()
        if placement is not None and not isinstance(placement, PlacementEngine):
            raise TypeError(
                f"placement must be None or a PlacementEngine, got {placement!r}"
            )
        self.placement = placement
        self.compute_ms = compute_ms
        self.trainer = trainer
        self.barrier = barrier
        if isinstance(buffer_k, int):
            self.buffer_k = [buffer_k] * len(self.handles)
        else:
            self.buffer_k = list(buffer_k)
        assert len(self.buffer_k) == len(self.handles)
        self.churn = churn
        self.adaptive = bool(adaptive)
        self.adaptive_kwargs = dict(adaptive_kwargs or {})
        self.selector = selector
        self.fair = bool(fair)
        self.relay_admission = relay_admission
        self._weight = self._per_app(app_weights, "transfer_weight", 1.0)
        self._cap = self._per_app(app_rate_caps, "rate_cap_mbps", None)
        if any(w <= 0 for w in self._weight) or any(
            c is not None and c <= 0 for c in self._cap
        ):
            raise ValueError(
                "app transfer weights must be > 0 and rate caps > 0 Mbps "
                f"(got weights={self._weight}, caps={self._cap}): a zero "
                "share would price the app's transfers at rate 0 and its "
                "cycles would never complete"
            )
        # compression (docs/performance.md "compressed transport" /
        # "compressed downlink"): a per-app CompressionPolicy shrinks the
        # COMMIT payload, and — when its downlink axis is on — the
        # BROADCAST payload too; the compressed byte counts are what
        # every pricing path sees: fair-share flows (open_flow mbit),
        # the legacy start-time pricing, and sampled cold-cycle legs.
        # Download legs are priced per worker (_download_mbit): a
        # delta-qsgd worker pays its version-gap chain, a rejoiner or
        # over-cap straggler the full f32 fallback.  policy None /
        # kind="none" / downlink="none" reproduces model_bytes through
        # the same float expressions, so disabled traces stay
        # byte-identical.
        from repro.fl.compression import CompressionPolicy, as_policy

        if isinstance(app_compression, (str, CompressionPolicy)):
            app_compression = [app_compression] * len(handles)
        self._compression = [
            as_policy(p) for p in self._per_app(app_compression, "compression", None)
        ]
        self._commit_bytes = [
            float(model_bytes) if p is None else p.wire_bytes(model_bytes)
            for p in self._compression
        ]
        self._commit_mbit = [b * 8e-6 for b in self._commit_bytes]
        # steady-state broadcast size for the placement planner: one
        # version delta for delta-qsgd, the quantized model for
        # downlink qsgd-int8, env.packet_mbit (the same float object)
        # when the downlink is uncompressed
        self._downlink_mbit_plan = [
            self.env.packet_mbit
            if (p is None or not p.downlink_enabled)
            else p.downlink_wire_bytes(model_bytes, chain=1) * 8e-6
            for p in self._compression
        ]
        self.controllers: list[AdaptiveKController | None] = []
        self.history: list[ApplyEvent] = []
        self.churn_log: list[ChurnRecord] = []
        self.defer_log: list[DeferRecord] = []
        self.fairness_log: list[dict] = []
        # per-app run state (filled by run())
        self._version: list[int] = []
        self._buffer: list[list[tuple[int, int]]] = []  # (worker, version)
        self._done: list[bool] = []
        self._cycle: dict[tuple[int, int], int] = {}
        self._version_at_start: dict[tuple[int, int], int] = {}
        self._pending_ev: dict[tuple[int, int], int] = {}
        self._pending_flow: dict[tuple[int, int], int] = {}
        self._delay_until: dict[tuple[int, int], float] = {}
        self._cycle_start: dict[tuple[int, int], float] = {}
        self._parked: list[set[int]] = []
        self._failed: set[int] = set()
        self._orig_workers: list[set[int]] = []
        self._applies_target = 1
        # weighted-fair transport state
        self._uplink_bytes: list[float] = []
        # downlink ledger + per-worker delta-chain state (compressed
        # downlink): which version each worker last downloaded, and the
        # byte credit stashed at cycle start until the cycle completes
        self._downlink_bytes: list[float] = []
        self._worker_base: dict[tuple[int, int], int] = {}
        self._pending_down_bytes: dict[tuple[int, int], float] = {}
        self.downlink_log: list[tuple] = []  # (t, ai, w, chain|None, bytes)
        self._done_ms: list[float] = []
        self._defer_count: list[int] = []
        self._deferred: dict[int, list[dict]] = {}  # relay -> FIFO of records
        self._deferred_by_key: dict[tuple[int, int], dict] = {}
        self._path_cache: dict[tuple[int, int, bool], np.ndarray] = {}
        # sampled-congestion state: cold cycles occupy their uplinks
        # statistically (a load counter) instead of as fluid flows
        self._cold_load = np.zeros(len(self._cap_f32), np.int64)
        self._cold_hops: dict[tuple[int, int], np.ndarray] = {}
        # resampling state: in-flight cold-cycle spans for re-pricing
        # key -> (t_priced, t_end, down_idx, up_idx, compute_ms)
        self._cold_span: dict[tuple[int, int], tuple] = {}
        self._resample_count = 0
        # adaptive-cadence controller state (resample_target_error)
        self.resample_log: list[tuple] = []  # (t, err_ema, every, events)
        self._resample_err: float | None = None
        # placement replan state (PR 5's lazy-invalidation pattern: triggers
        # only mark dirty; the replan itself runs at the next apply/churn
        # boundary once min_interval_ms has passed)
        self.replan_log: list[ReplanRecord] = []
        self._replan_dirty: str | None = None
        self._last_replan_ms = float("-inf")
        self.control_bytes = 0.0

    def _per_app(self, value, handle_attr: str, default):
        """Resolve a per-app knob: explicit arg (scalar broadcast or
        list) beats the handle attribute beats the default."""
        n = len(self.handles)
        if value is None:
            return [getattr(h, handle_attr, default) for h in self.handles]
        if isinstance(value, (int, float)):
            return [value] * n
        vals = list(value)
        assert len(vals) == n
        return vals

    # -- worker membership ----------------------------------------------------

    def _workers(self, ai: int) -> list[int]:
        if self.trainer is not None:
            return self.trainer.workers(ai)
        return sorted(self.handles[ai].tree.members)

    def _live_workers(self, ai: int) -> list[int]:
        return [w for w in self._workers(ai) if w not in self._failed]

    def _effective_k(self, ai: int) -> int:
        """Clamp K to the live membership so churn can't stall the buffer.
        In adaptive mode the base K comes from the app's controller."""
        ctrl = self.controllers[ai] if self.controllers else None
        k = ctrl.current_k if ctrl is not None else self.buffer_k[ai]
        live = len(self._live_workers(ai))
        return max(1, min(k, live)) if live else k

    # -- per-worker cycle ------------------------------------------------------

    def _path_senders(self, ai: int, w: int, *, up: bool) -> np.ndarray:
        """Sender index array for one leg, memoized on a numpy-resident
        route table: trees only change on churn (fail/repair/rejoin), so
        the per-cycle ``path_to_root`` walks + dict lookups are paid once
        per (app, worker, direction) between churn events — churn
        handlers clear the cache wholesale after repairs."""
        key = (ai, w, up)
        cached = self._path_cache.get(key)
        if cached is None:
            if ("warm", ai) not in self._path_cache:
                # first miss after a cache clear: bulk-fill both legs for
                # every tree member in two vectorized passes (paths_matrix
                # + sender_indices_many) instead of per-worker walks; the
                # marker key rides in the cache so any wholesale clear
                # (churn repair) automatically re-arms the warm.
                self._path_cache[("warm", ai)] = np.asarray([], np.int32)
                self._warm_path_cache(ai)
                cached = self._path_cache.get(key)
        if cached is None:
            tree = self.handles[ai].tree
            if w == tree.root:
                cached = np.asarray([], np.int32)
            else:
                path = tree.path_to_root(w)  # w -> root
                hops = path if up else list(reversed(path))
                cached = self.sender_indices(hops[:-1])
            self._path_cache[key] = cached
        return cached

    def _warm_path_cache(self, ai: int) -> None:
        """Vectorized route-table fill for one app's tree members.  Only
        members the tree can resolve are warmed — anything else falls
        through to the scalar path, which raises exactly where the
        legacy per-worker walk would."""
        tree = self.handles[ai].tree
        root = tree.root
        members = [w for w in tree.members if w == root or w in tree.parent]
        if not members:
            return
        arr = np.asarray(members, np.int64)
        try:
            mat = tree.paths_matrix(arr)
            d = tree.depths_of(arr)
            valid = mat >= 0
            idx = np.full(mat.shape, -1, np.int32)
            idx[valid] = self.sender_indices_many(mat[valid])
        except (KeyError, RuntimeError):
            return  # mid-repair transient: scalar path reports the error
        for i in range(len(arr)):
            w, di = int(arr[i]), int(d[i])
            row = idx[i]
            self._path_cache[(ai, w, True)] = row[:di].copy()
            self._path_cache[(ai, w, False)] = row[1 : di + 1][::-1].copy()

    def _sched_worker(self, ai: int, delay_ms: float, callback: Callable,
                      senders: np.ndarray | None = None) -> int:
        """Schedule one per-worker cycle event — cohort-batched per app
        when ``cohort`` is on, a plain heap entry otherwise."""
        if self.cohort:
            return self.schedule_cohort(ai, delay_ms, callback, senders)
        return self.schedule(delay_ms, callback, senders)

    # -- sampled/statistical congestion (cold-path cycles) ---------------------

    def _uplink_load(self, sender: int) -> int:
        """Concurrent occupancy of one uplink: fluid flows + cold cycles."""
        return len(self._flows_by_sender.get(int(sender), ())) + int(
            self._cold_load[int(sender)]
        )

    def _is_hot(self, hops: np.ndarray) -> bool:
        if self.hot_threshold <= 0:
            return True
        return any(self._uplink_load(int(s)) >= self.hot_threshold for s in hops)

    def _sampled_leg_ms(self, senders: np.ndarray, mbit: float | None = None) -> float:
        """Statistical store-and-forward price of one leg: each hop at its
        *current* load (fluid flows + cold cycles + this one), frozen for
        the cycle's whole duration.  Same f32 arithmetic as the legacy
        ``transfer_ms`` pricing, with the cold-cycle load folded in.
        ``mbit`` overrides the payload size (compressed commit legs)."""
        if len(senders) == 0:
            return 0.0
        own = np.asarray(senders)
        counts = np.asarray(
            [1 + self._uplink_load(int(s)) for s in own], np.float32
        )
        rate = self._cap_f32[own] / np.maximum(counts, np.float32(1.0))
        lat = np.float32(self.base_ms) + np.float32(
            1e3 * (self.env.packet_mbit if mbit is None else mbit)
        ) / np.maximum(rate, np.float32(1e-6))
        return float(lat.sum())

    def _start_cycle_cold(
        self, ai: int, w: int, delay: float, down_mbit: float | None = None
    ) -> None:
        """Sampled-mode cold path: price the whole cycle now, occupy its
        uplinks statistically, and complete in ONE cohort event.
        ``down_mbit`` carries the compressed broadcast size (None keeps
        the legacy full-model price, bit for bit)."""
        key = (ai, w)
        down = self._path_senders(ai, w, up=False)
        up = self._path_senders(ai, w, up=True)
        cyc = self._cycle.get(key, 0)
        if callable(self.compute_ms):
            comp = float(self.compute_ms(self.handles[ai], w, cyc))
        else:
            comp = float(self.compute_ms)
        dur = (
            delay + self._sampled_leg_ms(down, down_mbit) + comp
            + self._sampled_leg_ms(up, self._commit_mbit[ai])
        )
        hops = np.concatenate([down, up]).astype(np.int64)
        if len(hops):
            np.add.at(self._cold_load, hops, 1)
            self._cold_hops[key] = hops
            self._cold_span[key] = (
                self.now, self.now + dur, down, up, comp + delay, dur, down_mbit
            )
        self._pending_ev[key] = self._sched_worker(
            ai, dur, lambda t, ai=ai, w=w: self._finish_cold_cycle(ai, w, t)
        )

    def _release_cold(self, key: tuple[int, int]) -> None:
        hops = self._cold_hops.pop(key, None)
        self._cold_span.pop(key, None)
        if hops is not None:
            np.subtract.at(self._cold_load, hops, 1)

    def _finish_cold_cycle(self, ai: int, w: int, t: float) -> None:
        self._release_cold((ai, w))
        self._on_uploaded(ai, w, t)

    def _resample_cold(self, t: float) -> None:
        """Re-price every in-flight cold cycle against *current* loads.

        A cold cycle freezes its transfer price at start; under bursty
        contention that estimate drifts.  This refresh treats the cycle
        as a fluid job: the fraction of work left is (t_end - t) /
        (t_end - t_priced), and finishing that fraction at today's
        prices takes frac * new_total — the same progress-preserving
        rule the exact engine uses when a fair-share rate changes.  Each
        cycle's own uplink occupancy is subtracted while re-pricing (the
        start-time price also excluded it, counting itself via the +1 in
        ``_sampled_leg_ms``), and unchanged prices are detected by exact
        f32 equality (identical loads reproduce the identical sum), so a
        cycle whose congestion did not move keeps its scheduled event —
        with no cold cycles in flight (e.g. ``hot_threshold=0``) a
        resample is a pure no-op and the apply/churn trace stays
        identical to exact mode."""
        self._resample_count += 1
        drift_sum, drift_n = 0.0, 0
        for key in list(self._cold_span):
            span = self._cold_span.get(key)
            hops = self._cold_hops.get(key)
            if span is None or hops is None:
                continue
            t0, t1, down, up, fixed, total, down_mbit = span
            if t1 <= t or t1 <= t0:
                continue  # completing at this very instant
            np.subtract.at(self._cold_load, hops, 1)
            new_total = (
                self._sampled_leg_ms(down, down_mbit) + fixed
                + self._sampled_leg_ms(up, self._commit_mbit[key[0]])
            )
            np.add.at(self._cold_load, hops, 1)
            drift_n += 1
            if new_total == total:
                continue  # unchanged price: keep the event (no seq churn)
            drift_sum += abs(new_total - total) / total
            new_end = t + (t1 - t) / (t1 - t0) * new_total
            old_ev = self._pending_ev.get(key)
            if old_ev is not None:
                self.cancel(old_ev)
            ai, w = key
            self._pending_ev[key] = self._sched_worker(
                ai, new_end - t, lambda tt, ai=ai, w=w: self._finish_cold_cycle(ai, w, tt)
            )
            self._cold_span[key] = (t, new_end, down, up, fixed, new_total, down_mbit)
        if self.resample_target_error is not None and drift_n:
            self._adapt_resample_cadence(t, drift_sum / drift_n)

    def _adapt_resample_cadence(self, t: float, err: float) -> None:
        """Adaptive cadence: the measured relative price drift per
        resample IS the apply-time error the fixed cadence only measured
        — so control it.  Drift above ``resample_target_error`` halves
        the interval (more refreshes), drift below half the target
        relaxes it by 1.25x; both knobs stay within [base/8, 4*base] of
        their constructor values.  A 50/50 EMA smooths bursts.  Off
        (target None) never touches the cadence, keeping traces
        identical."""
        ema = err if self._resample_err is None else 0.5 * err + 0.5 * self._resample_err
        self._resample_err = ema
        tgt = self.resample_target_error
        scale = 0.5 if ema > tgt else (1.25 if ema < 0.5 * tgt else 1.0)
        if scale != 1.0:
            if self.resample_every is not None:
                base = self._resample_every0
                self.resample_every = float(
                    min(4.0 * base, max(base / 8.0, self.resample_every * scale))
                )
            if self.resample_events is not None:
                base = self._resample_events0
                self.resample_events = int(
                    round(min(4 * base, max(max(1, base // 8), self.resample_events * scale)))
                )
        self.resample_log.append((t, ema, self.resample_every, self.resample_events))

    def _on_resample_timer(self, t: float) -> None:
        self._resample_cold(t)
        self.schedule(self.resample_every, self._on_resample_timer)

    def _offer_cycle(self, ai: int, w: int) -> None:
        """Gate a worker's next cycle through the selector (if any).

        Declined workers are parked until the app's next apply.  The
        liveness guard admits whenever fewer than K workers are in
        flight — otherwise selection could park everyone and the buffer
        would never fill.  The guard runs *before* the selector is
        consulted, so a forced admission is not an offer: it neither
        burns blocklist decay nor counts as a parked decline.
        """
        if self._done[ai] or w in self._failed:
            return
        if self.selector is None:
            self._start_cycle(ai, w)
            return
        active = sum(1 for (a, _) in self._pending_ev if a == ai)
        if active < self._effective_k(ai):
            # liveness guard: fewer than K cycles in flight — this worker
            # is needed regardless of utility.  Drain its blocklist too
            # (satellite fix): when adaptive K exceeds the live
            # non-blocklisted pool, forced admissions must spend the
            # block, or the blocklist pins workers the buffer depends on.
            drain = getattr(self.selector, "on_force_admit", None)
            if drain is not None:
                drain(ai, w)
            self._parked[ai].discard(w)
            self._start_cycle(ai, w)
        elif self.selector.admit(ai, w, self.now):
            self._parked[ai].discard(w)
            self._start_cycle(ai, w)
        else:
            self._parked[ai].add(w)

    def _download_mbit(self, ai: int, w: int, senders) -> float | None:
        """Price one broadcast (download) leg for this worker's cycle.

        ``None`` means the downlink is uncompressed — callers fall
        through to the exact legacy expressions (``env.packet_mbit``),
        keeping disabled traces byte-identical.  Otherwise the size is
        ``downlink_wire_bytes``: for delta-qsgd, the worker's version
        gap as a delta chain when its cached base is within
        ``chain_cap`` (a gap of 0 is a free version check), the full
        f32 state when it has no base (first download, churn rejoin —
        ``_worker_base`` is dropped on fail) or the gap exceeds the
        cap.  The byte credit (size x path legs) is stashed and lands
        on the per-app downlink ledger when the cycle commits — the
        same cycle-completion granularity the uplink ledger uses in
        every pricing mode."""
        p = self._compression[ai]
        if p is None or not p.downlink_enabled:
            return None
        key = (ai, w)
        cur = self._version[ai]
        chain = None
        if p.downlink == "delta-qsgd":
            base = self._worker_base.get(key)
            if base is not None and 0 <= cur - base <= p.chain_cap:
                chain = cur - base
        self._worker_base[key] = cur
        down_bytes = p.downlink_wire_bytes(self.model_bytes, chain=chain)
        self._pending_down_bytes[key] = down_bytes * len(senders)
        self.downlink_log.append((self.now, ai, w, chain, down_bytes))
        return down_bytes * 8e-6

    def _start_cycle(self, ai: int, w: int) -> None:
        if self._done[ai] or w in self._failed:
            return
        key = (ai, w)
        delay = max(0.0, self._delay_until.pop(key, self.now) - self.now)
        self._version_at_start[key] = self._version[ai]
        self._cycle_start[key] = self.now
        if self.trainer is not None:
            self.trainer.begin_download(ai, w)
        senders = self._path_senders(ai, w, up=False)
        down_mbit = self._download_mbit(ai, w, senders)
        if self.congestion_mode == "sampled" and not (
            self._is_hot(senders) or self._is_hot(self._path_senders(ai, w, up=True))
        ):
            self._start_cycle_cold(ai, w, delay, down_mbit)
            return
        if self.fair:
            self._begin_leg(
                ai, w, senders, delay, commit=False, mbit=down_mbit,
                done=lambda t, ai=ai, w=w: self._on_downloaded(ai, w, t),
            )
            return
        dur = delay + self.transfer_ms(senders, reduce="sum", mbit=down_mbit)
        self._pending_ev[key] = self._sched_worker(
            ai, dur, lambda t, ai=ai, w=w: self._on_downloaded(ai, w, t), senders
        )

    def _on_downloaded(self, ai: int, w: int, t: float) -> None:
        if self._done[ai] or w in self._failed:
            return
        cyc = self._cycle.get((ai, w), 0)
        if callable(self.compute_ms):
            dur = float(self.compute_ms(self.handles[ai], w, cyc))
        else:
            dur = float(self.compute_ms)
        self._pending_ev[(ai, w)] = self._sched_worker(
            ai, dur, lambda t, ai=ai, w=w: self._on_computed(ai, w, t)
        )

    def _on_computed(self, ai: int, w: int, t: float) -> None:
        if self._done[ai] or w in self._failed:
            return
        senders = self._path_senders(ai, w, up=True)
        if self.fair:
            self._begin_leg(
                ai, w, senders, 0.0, commit=True,
                done=lambda t, ai=ai, w=w: self._on_uploaded(ai, w, t),
            )
            return
        dur = self.transfer_ms(senders, reduce="sum", mbit=self._commit_mbit[ai])
        self._pending_ev[(ai, w)] = self._sched_worker(
            ai, dur, lambda t, ai=ai, w=w: self._on_uploaded(ai, w, t), senders
        )

    # -- fair-share leg execution (hop-by-hop fluid flows) ---------------------

    def _begin_leg(
        self, ai: int, w: int, senders, delay: float, *, commit: bool, done,
        mbit: float | None = None,
    ) -> None:
        """Run one transfer leg (download or upload) as sequential per-hop
        flows on the fair-share engine.  The leg's store-and-forward total
        for an uncontended path equals the legacy ``reduce="sum"`` price
        exactly: sum over hops of ``base_ms + mbit / capacity``.  Commit
        legs pass relay admission at every intermediate hop.  ``(ai, w)``
        stays in
        ``_pending_ev`` for the whole leg (cycle liveness/barrier checks
        key off membership, not the stored seq)."""
        key = (ai, w)
        hops = [int(s) for s in senders]
        if not hops:
            self._pending_ev[key] = self._sched_worker(ai, delay, lambda t: done(t))
            return

        def start_hop(j: int, extra: float) -> None:
            if self._done[ai] or w in self._failed:
                return
            relay = hops[j]
            if commit and j > 0 and self._admission_defers(ai, w, relay):
                # resume bypasses the admission re-check: a deadline-forced
                # resume must forward unconditionally (no re-deferral, so
                # max_defer_ms is a hard bound, not a livelock)
                self._defer_hop(ai, w, relay, lambda j=j, extra=extra: launch_hop(j, extra))
                return
            launch_hop(j, extra)

        def launch_hop(j: int, extra: float) -> None:
            if self._done[ai] or w in self._failed:
                return
            self._pending_ev[key] = self._sched_worker(
                ai, self.base_ms + extra,
                lambda t, j=j, relay=hops[j]: open_hop(j, relay),
            )

        if mbit is not None:
            leg_mbit = mbit  # compressed broadcast size from _download_mbit
        else:
            leg_mbit = self._commit_mbit[ai] if commit else self.env.packet_mbit

        def open_hop(j: int, relay: int) -> None:
            if self._done[ai] or w in self._failed:
                return
            self._pending_flow[key] = self.open_flow(
                relay, leg_mbit,
                weight=self._weight[ai], rate_cap=self._cap[ai],
                on_done=lambda t, j=j: hop_done(j, t), group=ai,
            )

        def hop_done(j: int, t: float) -> None:
            self._pending_flow.pop(key, None)
            if j + 1 < len(hops):
                start_hop(j + 1, 0.0)
            else:
                done(t)

        start_hop(0, delay)

    def _admission_defers(self, ai: int, w: int, relay: int) -> bool:
        adm = self.relay_admission
        if adm is None or self.flow_contenders(relay) < adm.min_contenders:
            return False
        staleness = self._version[ai] - self._version_at_start[(ai, w)]
        return (1.0 + staleness) ** (-adm.alpha) < adm.threshold

    def _defer_hop(self, ai: int, w: int, relay: int, resume: Callable[[], None]) -> None:
        """Park a stale commit's hop at a contended relay.  It resumes
        FIFO when a flow on the relay's uplink completes (and admission
        passes again), or unconditionally at ``max_defer_ms``."""
        key = (ai, w)
        t0 = self.now

        def fire(t: float, forced: bool) -> None:
            rec = self._deferred_by_key.pop(key, None)
            if rec is None:
                return  # already resumed or cancelled by churn
            queue = self._deferred.get(relay)
            if queue is not None:
                queue.remove(rec)
                if not queue:
                    del self._deferred[relay]
            if not forced:
                self.cancel(rec["deadline_ev"])
            self.defer_log.append(DeferRecord(t0, t, ai, w, relay, forced))
            self._defer_count[ai] += 1
            if self.placement is not None:
                # transport deferral observed: flag the worker for
                # re-placement and mark the planner dirty (lazy — the
                # replan runs at the next apply/churn boundary)
                self.placement.flag(ai, w, t - t0)
                if self._replan_dirty is None:
                    self._replan_dirty = "defer"
            if self.selector is not None:
                on_defer = getattr(self.selector, "on_defer", None)
                if on_defer is not None:
                    on_defer(ai, w, t, t - t0)
            resume()

        rec = {"key": key, "relay": relay, "fire": fire}
        rec["deadline_ev"] = self.schedule(
            self.relay_admission.max_defer_ms, lambda t: fire(t, True)
        )
        # the deadline event keeps (ai, w) cancellable through churn
        self._pending_ev[key] = rec["deadline_ev"]
        self._deferred.setdefault(relay, []).append(rec)
        self._deferred_by_key[key] = rec

    def _on_uplink_freed(self, sender: int, t: float) -> None:
        """A flow left ``sender``'s uplink: re-offer the oldest deferred
        commit parked there (one per freed flow — FIFO, no stampede)."""
        queue = self._deferred.get(sender)
        if not queue:
            return
        for rec in list(queue):
            ai, w = rec["key"]
            if not self._admission_defers(ai, w, sender):
                rec["fire"](t, False)
                return

    def _drop_deferred(self, key: tuple[int, int]) -> None:
        rec = self._deferred_by_key.pop(key, None)
        if rec is None:
            return
        self.cancel(rec["deadline_ev"])
        queue = self._deferred.get(rec["relay"])
        if queue is not None:
            queue.remove(rec)
            if not queue:
                del self._deferred[rec["relay"]]

    def _on_uploaded(self, ai: int, w: int, t: float) -> None:
        if self._done[ai] or w in self._failed:
            return
        key = (ai, w)
        # uplink bytes are credited at commit (leg) granularity in BOTH
        # pricing modes, so fairness comparisons across modes never
        # measure accounting granularity at a horizon cut; flow-level
        # byte conservation across re-prices is asserted separately
        # (tests/test_fairness.py on _Flow.delivered_mbit)
        up_path = self._path_senders(ai, w, up=True)
        self._uplink_bytes[ai] += self._commit_bytes[ai] * len(up_path)
        # the matching downlink credit: stashed by _download_mbit when a
        # compression policy prices the broadcast, else the legacy
        # full-model size over the download path — same cycle-commit
        # granularity as the uplink ledger in every pricing mode
        down_credit = self._pending_down_bytes.pop(key, None)
        if down_credit is None:
            down_credit = self.model_bytes * len(self._path_senders(ai, w, up=False))
        self._downlink_bytes[ai] += down_credit
        if self.placement is not None and len(up_path):
            # per-uplink ledger for the placement engine's reward model
            np.add.at(self.uplink_bytes, up_path, self._commit_bytes[ai])
        self._pending_ev.pop(key, None)
        self._cycle[key] = self._cycle.get(key, 0) + 1
        self._buffer[ai].append((w, self._version_at_start.pop(key)))
        cyc_start = self._cycle_start.pop(key, None)
        if self.selector is not None and cyc_start is not None:
            self.selector.on_commit(ai, w, t, t - cyc_start)
        if self.controllers and self.controllers[ai] is not None:
            self.controllers[ai].on_commit(t)
        if self.trainer is not None:
            self.trainer.commit(ai, w, t)
        full = len(self._buffer[ai]) >= self._effective_k(ai)
        if full:
            self._apply(ai, t)
        if not self.barrier:
            self._offer_cycle(ai, w)  # next cycle begins immediately
        elif full:
            # release only workers idling at the barrier — anyone still
            # mid-flight (K < W) finishes its current cycle first; parked
            # workers were already re-offered by _apply
            for lw in self._live_workers(ai):
                if (ai, lw) not in self._pending_ev and lw not in self._parked[ai]:
                    self._offer_cycle(ai, lw)

    def _apply(self, ai: int, t: float) -> None:
        arrivals = self._buffer[ai]
        self._buffer[ai] = []
        k_used = self._effective_k(ai)
        cur = self._version[ai]
        stal = [cur - v for _, v in arrivals]
        transport = self._transport_record(ai, t)
        self.fairness_log.append(transport)
        if self.trainer is not None:
            scores = self.selector.scores(ai) if self.selector is not None else None
            self.trainer.apply(ai, t, k=k_used, selector_scores=scores, transport=transport)
        self._version[ai] = cur + 1
        if self.controllers and self.controllers[ai] is not None:
            self.controllers[ai].on_apply(t, stal, len(self._live_workers(ai)))
        self.history.append(
            ApplyEvent(
                app_id=self.handles[ai].tree.app_id,
                apply_index=cur,
                time_ms=t,
                arrivals=len(arrivals),
                mean_staleness=float(np.mean(stal)) if stal else 0.0,
                max_staleness=float(max(stal)) if stal else 0.0,
                k=k_used,
            )
        )
        if self._version[ai] >= self._applies_target:
            self._done[ai] = True
            self._done_ms[ai] = t
        elif self.selector is not None and self._parked[ai]:
            # re-offer parked workers against the post-apply utilities
            parked, self._parked[ai] = sorted(self._parked[ai]), set()
            for w in parked:
                self._offer_cycle(ai, w)
        if self.placement is not None:
            self._check_contention(transport)
            self._maybe_replan(t)

    # -- fairness telemetry ----------------------------------------------------

    def _uplink_throughputs(self) -> list[float]:
        """Per-app uplink throughput (Mbps) over each app's active
        window [0, done-or-now]."""
        out = []
        for ai in range(len(self.handles)):
            t_end = self._done_ms[ai] if self._done[ai] else self.now
            out.append(self._uplink_bytes[ai] * 8e-6 / max(t_end * 1e-3, 1e-9))
        return out

    def _transport_record(self, ai: int, t: float) -> dict:
        from repro.kernels.ops import jain_fairness

        tp = self._uplink_throughputs()
        return {
            "t_ms": t,
            "app_id": self.handles[ai].tree.app_id,
            "uplink_bytes": self._uplink_bytes[ai],
            "downlink_bytes": self._downlink_bytes[ai],
            "uplink_mbps": tp[ai],
            "jain_uplink": jain_fairness(tp),
            "deferred_commits": self._defer_count[ai],
        }

    def transport_stats(self) -> dict:
        """End-of-run fairness summary: per-app uplink bytes/throughput,
        per-app completion time, Jain's index over the throughputs."""
        from repro.kernels.ops import jain_fairness

        tp = self._uplink_throughputs()
        return {
            "uplink_bytes": list(self._uplink_bytes),
            "downlink_bytes": list(self._downlink_bytes),
            "uplink_mbps": tp,
            "done_ms": [
                self._done_ms[ai] if self._done[ai] else self.now
                for ai in range(len(self.handles))
            ],
            "jain_uplink": jain_fairness(tp),
            "deferred_commits": len(self.defer_log),
        }

    # -- live placement (docs/architecture.md "placement layer") ---------------

    def uplink_occupancy(self) -> np.ndarray:
        """Per-uplink concurrent occupancy (fluid flows + cold cycles) —
        the congestion ledger the placement engine plans against."""
        occ = self._cold_load.astype(np.float64)
        for s, fids in self._flows_by_sender.items():
            occ[s] += len(fids)
        return occ

    def _placement_feedback(self, ai: int, w: int, kind: str, magnitude: float) -> None:
        """Selector -> planner feedback: a transport-hurt worker is
        flagged for re-placement (``UtilitySelector.placement_hook``)."""
        self.placement.flag(ai, w, max(float(magnitude), 1.0))
        if self._replan_dirty is None:
            self._replan_dirty = "selector"

    def _check_contention(self, transport: dict) -> None:
        """Apply-time contention-spike trigger: fairness collapse or a
        pile-up on any single uplink marks the planner dirty."""
        eng = self.placement
        if self._replan_dirty is not None:
            return
        if transport["jain_uplink"] < eng.spike_jain:
            self._replan_dirty = "contention"
            return
        if self._flows_by_sender or self._cold_load.any():
            if self.uplink_occupancy().max() >= eng.spike_occupancy:
                self._replan_dirty = "contention"

    def _maybe_replan(self, t: float) -> None:
        """PR 5's lazy-invalidation pattern: triggers only mark dirty;
        the replan itself runs here, rate-limited by the engine's
        ``min_interval_ms`` so a churn storm costs one replan."""
        eng = self.placement
        if eng is None or self._replan_dirty is None:
            return
        if t - self._last_replan_ms < eng.min_interval_ms:
            return
        trigger, self._replan_dirty = self._replan_dirty, None
        self._last_replan_ms = t
        self._replan(t, trigger)

    def _replan(self, t: float, trigger: str) -> None:
        """One placement episode: plan every live app's tree against the
        measured occupancy, apply the moves through the forest's batched
        re-graft, and price the JOIN control traffic on the clock —
        moved members stall (``_delay_until``) until their JOIN lands,
        and the control bytes hit the same per-uplink ledger commits do."""
        eng = self.placement
        occ = self.uplink_occupancy()
        all_moves: list[tuple[int, int, int, int]] = []
        cost_total = 0.0
        bytes_total = 0.0
        for ai, h in enumerate(self.handles):
            if self._done[ai]:
                continue
            tree = h.tree
            try:
                rows = self.sender_indices_many(tree._ids[: tree._n])
            except KeyError:
                continue  # mid-repair transient: a tree node left the overlay
            moves = eng.plan_tree(
                tree,
                rows=rows,
                cap=self._cap_mbps,
                occ=occ,
                base_ms=self.base_ms,
                down_mbit=self._downlink_mbit_plan[ai],
                up_mbit=self._commit_mbit[ai],
                flagged=eng.consume_flags(ai),
                blocked=self._failed,
                app_idx=ai,
                now_ms=t,
            )
            if not moves:
                continue
            applied = self.system.forest.regraft_many(
                tree.app_id, [(m.node, m.new_parent) for m in moves], strict=False
            )
            if not applied:
                continue
            self._path_cache.clear()  # moved subtrees invalidate memoized routes
            applied_set = set(applied)
            for m in moves:
                if (m.node, m.new_parent) not in applied_set:
                    continue
                try:
                    senders = self._path_senders(ai, m.node, up=True)
                except KeyError:
                    senders = np.empty(0, np.int32)
                join_ms = self.transfer_ms(senders, reduce="sum", mbit=eng.join_mbit)
                cost_total += join_ms
                if len(senders):
                    np.add.at(self.uplink_bytes, senders, eng.join_bytes)
                    bytes_total += eng.join_bytes * len(senders)
                if m.node in tree.members and m.node not in self._failed:
                    key = (ai, m.node)
                    self._delay_until[key] = max(
                        self._delay_until.get(key, 0.0), t + join_ms
                    )
                all_moves.append((ai, m.node, m.old_parent, m.new_parent))
        self.control_bytes += bytes_total
        eng.replans += 1
        eng.moves_applied += len(all_moves)
        self.replan_log.append(
            ReplanRecord(t, trigger, tuple(all_moves), cost_total, bytes_total)
        )

    # -- churn -----------------------------------------------------------------

    def _schedule_churn(self) -> None:
        if self.churn is None or self.churn.exhausted():
            return
        self.schedule(self.churn.period_ms, self._on_churn_fail)

    def _victim_pool(self) -> list[int]:
        roots = {h.tree.root for h in self.handles}
        pool = set()
        for ai in range(len(self.handles)):
            if not self._done[ai]:
                pool.update(self._live_workers(ai))
        if not self.churn.allow_master_failure:
            pool -= roots
        return sorted(pool)

    def _on_churn_fail(self, t: float) -> None:
        victims = self.churn.pick_victims(self._victim_pool())
        self.churn.fired += 1
        if victims:
            self._path_cache.clear()  # repairs re-graft arbitrary subtrees
            overlay = self.system.overlay
            rejoin_info = {
                n: (overlay.space.zone_of(n), overlay.space.suffix_of(n),
                    overlay.coords[n], overlay.bandwidth[n])
                for n in victims
            }
            recovery_ms = 0.0
            for ai, h in enumerate(self.handles):
                tree = h.tree
                in_tree = [n for n in victims if n in tree.nodes() or n in tree.members]
                if not in_tree:
                    continue
                orphans = [
                    c for n in in_tree for c in tree.children.get(n, [])
                    if c not in victims
                ]
                report = self.system.fail_nodes(tree.app_id, in_tree)
                recovery_ms = max(recovery_ms, report.recovery_time_ms)
                for o in orphans:  # re-grafted subtrees stall for the repair
                    self._delay_until[(ai, o)] = t + report.recovery_time_ms
            for n in victims:
                self._failed.add(n)
                for ai in range(len(self.handles)):
                    key = (ai, n)
                    ev = self._pending_ev.pop(key, None)
                    if ev is not None:
                        self.cancel(ev)
                    fid = self._pending_flow.pop(key, None)
                    if fid is not None:
                        self.cancel_flow(fid)
                    self._release_cold(key)
                    self._drop_deferred(key)
                    self._version_at_start.pop(key, None)
                    self._cycle_start.pop(key, None)
                    # a failed worker loses its cached broadcast base:
                    # on rejoin its first download is priced full-state
                    self._worker_base.pop(key, None)
                    self._pending_down_bytes.pop(key, None)
                    self._parked[ai].discard(n)
                    if self.trainer is not None:
                        self.trainer.drop(ai, n)
            self.churn_log.append(
                ChurnRecord(t, "fail", tuple(victims), recovery_ms=recovery_ms)
            )
            # a fail can strand an app in three ways, all fixed by _kick:
            # the live pool shrank so the buffer already meets the clamped
            # K but no commit event will re-check it; live workers sit
            # parked while fewer than K cycles are in flight; or barrier
            # idlers lost the commit that would have released them
            for ai in range(len(self.handles)):
                self._kick(ai, t)
            if self.placement is not None:
                if self._replan_dirty is None:
                    self._replan_dirty = "churn"
                self._maybe_replan(t)
            self.schedule(
                self.churn.downtime_ms,
                lambda tt, victims=victims, info=rejoin_info: self._on_churn_rejoin(
                    tt, victims, info
                ),
            )
        self._schedule_churn()

    def _kick(self, ai: int, t: float) -> None:
        """Liveness after a membership change: apply if the buffer already
        meets the (possibly shrunk) effective K — commits only re-check
        fullness as they land, so a fail that clamps K below the current
        fill would otherwise stall the app forever (regression:
        tests/test_fairness.py) — then re-offer parked workers (the
        force-admit guard drains blocklists).  Barrier idlers are
        restarted ONLY when the apply fired here: the normal release in
        ``_on_uploaded`` never runs for a churn-triggered apply, but an
        unconditional re-offer would hand committed idlers a second
        cycle inside the same barrier round (duplicate commits) whenever
        any unrelated node failed."""
        if self._done[ai]:
            return
        applied = False
        if self._buffer[ai] and len(self._buffer[ai]) >= self._effective_k(ai):
            self._apply(ai, t)
            applied = True
            if self._done[ai]:
                return
        if self.selector is not None and self._parked[ai]:
            parked, self._parked[ai] = sorted(self._parked[ai]), set()
            for w in parked:
                self._offer_cycle(ai, w)
        if self.barrier and applied:
            for lw in self._live_workers(ai):
                if (ai, lw) not in self._pending_ev and lw not in self._parked[ai]:
                    self._offer_cycle(ai, lw)

    def _on_churn_rejoin(self, t: float, victims: list[int], info: dict) -> None:
        self._path_cache.clear()  # re-Subscribes re-graft the rejoiners
        overlay = self.system.overlay
        rejoined = []
        for n in victims:
            if n in overlay.alive:
                continue
            zone, suffix, coord, bw = info[n]
            try:
                overlay.join(zone, suffix, coord, bw)
            except ValueError:
                continue  # its id got reused while it was away
            rejoined.append(n)
            self._failed.discard(n)
            for ai, h in enumerate(self.handles):
                if n in self._orig_workers[ai]:
                    self.system.Subscribe(h.tree.app_id, n)
                    self._offer_cycle(ai, n)
        if rejoined:
            self.churn_log.append(ChurnRecord(t, "rejoin", tuple(rejoined)))
            if self.placement is not None:
                if self._replan_dirty is None:
                    self._replan_dirty = "churn"
                self._maybe_replan(t)

    # -- driver ----------------------------------------------------------------

    def _progress_summary(self) -> str:
        """Per-app progress for the budget-exhaustion diagnostic."""
        target = getattr(self, "_applies_target", None)
        if target is None or not self._version:
            return ""
        done = sum(1 for d in self._done if d)
        lagging = ", ".join(
            f"app{ai}={v}/{target}"
            for ai, v in enumerate(self._version)
            if not self._done[ai]
        )
        head = f"apps done {done}/{len(self._done)}"
        return head + (f" (pending: {lagging})" if lagging else "")

    def run(
        self,
        applies: int = 1,
        *,
        max_events: int = 1_000_000,
        horizon_ms: float | None = None,
    ) -> list[ApplyEvent]:
        """Run every app until it has performed ``applies`` buffered
        updates; returns the ``ApplyEvent`` history in clock order.
        ``horizon_ms`` additionally stops the clock at a fixed simulated
        time — the fairness bench uses it to compare per-app uplink
        delivery over one common contended window."""
        self._reset_clock()
        self._applies_target = applies
        n = len(self.handles)
        self._version = [0] * n
        self._buffer = [[] for _ in range(n)]
        self._done = [False] * n
        self._cycle.clear()
        self._version_at_start.clear()
        self._pending_ev.clear()
        self._pending_flow.clear()
        self._cold_load[:] = 0
        self._cold_hops.clear()
        self._cold_span.clear()
        self._resample_count = 0
        self._delay_until.clear()
        self._cycle_start.clear()
        self._parked = [set() for _ in range(n)]
        self._failed.clear()
        self._uplink_bytes = [0.0] * n
        self._downlink_bytes = [0.0] * n
        self._worker_base = {}
        self._pending_down_bytes = {}
        self.downlink_log = []
        self._done_ms = [0.0] * n
        self._defer_count = [0] * n
        self._deferred = {}
        self._deferred_by_key = {}
        self._path_cache = {}
        self.history = []
        self.churn_log = []
        self.defer_log = []
        self.fairness_log = []
        self.replan_log = []
        self.resample_log = []
        self._replan_dirty = None
        self._last_replan_ms = float("-inf")
        self.control_bytes = 0.0
        self._resample_err = None
        self.resample_every = self._resample_every0
        self.resample_events = self._resample_events0
        if self.placement is not None:
            self.placement.reset()
            self._replan_dirty = "bootstrap"
            if self.selector is not None and hasattr(self.selector, "placement_hook"):
                # close the selection loop: transport-deferred workers
                # are handed to the planner instead of blocklisted
                self.selector.placement_hook = self._placement_feedback
        self.controllers = [
            AdaptiveKController(**{"k_init": self.buffer_k[ai], **self.adaptive_kwargs})
            if self.adaptive
            else None
            for ai in range(n)
        ]
        self._orig_workers = [set(self._workers(ai)) for ai in range(n)]
        for ai in range(n):
            if not self._workers(ai):
                self._done[ai] = True
            for w in self._workers(ai):
                self._offer_cycle(ai, w)
        self._schedule_churn()
        self._tick_hook = None
        if self.resample_events is not None:
            # reads the attribute each tick: the adaptive-cadence
            # controller mutates it mid-run (a fixed cadence reads the
            # same value every time, so this stays behavior-identical)
            def _tick() -> None:
                if self.events_dispatched % self.resample_events == 0:
                    self._resample_cold(self.now)

            self._tick_hook = _tick
        if self.resample_every is not None:
            self.schedule(self.resample_every, self._on_resample_timer)
        if horizon_ms is None:
            stop = lambda: all(self._done)
        else:
            stop = lambda: all(self._done) or self.now >= horizon_ms
        self.run_events(max_events=max_events, stop=stop)
        return list(self.history)


def per_app_round_ms(history: list[RoundEvent]) -> dict[int, list[float]]:
    """app_id -> round durations (ms), in round order."""
    out: dict[int, list[float]] = {}
    for ev in sorted(history, key=lambda e: (e.app_id, e.round)):
        out.setdefault(ev.app_id, []).append(ev.duration_ms)
    return out


def per_app_apply_ms(history: list[ApplyEvent]) -> dict[int, list[float]]:
    """app_id -> apply completion times (ms), in apply order."""
    out: dict[int, list[float]] = {}
    for ev in sorted(history, key=lambda e: (e.app_id, e.apply_index)):
        out.setdefault(ev.app_id, []).append(ev.time_ms)
    return out
