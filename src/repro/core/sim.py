"""Discrete-event multi-app round simulator (paper §VII-D, Table III).

M concurrent FL applications interleave on one overlay: each app's round
is a chain of phases — broadcast the model level-by-level down its
dataflow tree, workers compute E local steps, partial aggregates flow
level-by-level back up — and every phase is an event on a shared clock
(a heap of completion events).  Transfer phases are priced by the
bandwidth-sharing model in ``core/congestion.py``: a node uploading to k
concurrent flows (its own fanout plus any other app whose tree routes
through it) serves each at capacity/k, so overlapping trees contend for
links exactly where they share nodes.  This is what makes the paper's
"M concurrent apps vs centralized queue" speedup curve measurable: the
centralized baseline (``fl/rounds.CentralizedBaseline``) serializes all
M apps through one coordinator, Totoro+'s trees only slow each other
down where they physically overlap.

Everything is deterministic: ties on the clock break by event sequence
number, and the congestion pricing has no stochastic terms (link-failure
draws stay in the planner's environment, not here).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable

import jax.numpy as jnp
import numpy as np

from .congestion import CongestionEnv


@dataclass(frozen=True)
class RoundEvent:
    """One completed (app, round): recorded when the root finishes
    aggregating, i.e. the paper's per-app round completion time."""

    app_id: int
    round: int
    start_ms: float
    end_ms: float

    @property
    def duration_ms(self) -> float:
        return self.end_ms - self.start_ms


class MultiAppSimulator:
    """Event-driven clock over M apps' rounds on one shared overlay.

    ``handles``: the apps' ``AppHandle``s (their trees define the phase
    structure).  ``model_bytes`` sizes every transfer; ``compute_ms`` is
    a scalar or ``f(handle, round) -> ms`` for the local-training phase.
    """

    def __init__(
        self,
        system,
        handles,
        *,
        model_bytes: float,
        compute_ms: float | Callable = 50.0,
        base_ms: float = 5.0,
    ):
        self.system = system
        self.handles = list(handles)
        self.compute_ms = compute_ms
        nodes = system.overlay.nodes()
        self._node_idx = {n: i for i, n in enumerate(nodes)}
        cap = np.asarray([system.overlay.bandwidth[n] for n in nodes], np.float32)
        self.env = CongestionEnv(
            capacity=jnp.asarray(cap),
            theta=jnp.ones(len(nodes), jnp.float32),
            packet_mbit=float(model_bytes) * 8e-6,
            base_ms=base_ms,
        )
        self._phases = [self._phases_of(h.tree) for h in self.handles]
        self._active: dict[int, np.ndarray] = {}  # event seq -> sender idx array

    def _phases_of(self, tree) -> list[tuple[str, np.ndarray | None]]:
        """Round = broadcast levels (sender = parent, one flow per child),
        one compute phase, aggregation levels (sender = each child)."""
        phases: list[tuple[str, np.ndarray | None]] = []
        agg = tree.aggregation_schedule()
        for level in reversed(agg):  # root -> leaves
            senders = [self._node_idx[p] for p, kids in level for _ in kids]
            phases.append(("bcast", np.asarray(senders, np.int32)))
        phases.append(("compute", None))
        for level in agg:  # leaves -> root
            senders = [self._node_idx[c] for _, kids in level for c in kids]
            phases.append(("agg", np.asarray(senders, np.int32)))
        return phases

    def _transfer_ms(self, senders: np.ndarray) -> float:
        """Price this phase's flows with every in-flight flow still active:
        per-flow latency = base + bits / (capacity_sender / k) where k is
        the number of concurrent flows sharing that sender's uplink
        (``CongestionEnv.latency_ms``); the phase ends when its slowest
        flow does."""
        flows = [senders] + list(self._active.values())
        actions = jnp.asarray(np.concatenate(flows))
        lat = np.asarray(self.env.latency_ms(actions))
        return float(lat[: len(senders)].max())

    def _compute_ms(self, app_idx: int, round_num: int) -> float:
        if callable(self.compute_ms):
            return float(self.compute_ms(self.handles[app_idx], round_num))
        return float(self.compute_ms)

    def run(self, rounds: int = 1) -> list[RoundEvent]:
        """Interleave every app's ``rounds`` rounds; returns the per-app
        completion records in completion order (deterministic)."""
        heap: list[tuple[float, int, int]] = []
        seq = 0
        self._active.clear()
        state = [
            {"phase": 0, "round": 0, "start": 0.0} for _ in self.handles
        ]
        history: list[RoundEvent] = []

        def start_phase(i: int, t: float) -> None:
            nonlocal seq
            kind, senders = self._phases[i][state[i]["phase"]]
            if kind == "compute":
                dur = self._compute_ms(i, state[i]["round"])
            elif senders is None or len(senders) == 0:
                dur = 0.0
            else:
                dur = self._transfer_ms(senders)
                self._active[seq] = senders
            heapq.heappush(heap, (t + dur, seq, i))
            seq += 1

        for i in range(len(self._phases)):
            # every app has >= 1 phase: _phases_of always emits compute
            start_phase(i, 0.0)

        while heap:
            t, ev_seq, i = heapq.heappop(heap)
            self._active.pop(ev_seq, None)
            st = state[i]
            st["phase"] += 1
            if st["phase"] >= len(self._phases[i]):
                history.append(
                    RoundEvent(self.handles[i].app_id, st["round"], st["start"], t)
                )
                st["round"] += 1
                st["phase"] = 0
                st["start"] = t
                if st["round"] >= rounds:
                    continue
            start_phase(i, t)
        return history


def per_app_round_ms(history: list[RoundEvent]) -> dict[int, list[float]]:
    """app_id -> round durations (ms), in round order."""
    out: dict[int, list[float]] = {}
    for ev in sorted(history, key=lambda e: (e.app_id, e.round)):
        out.setdefault(ev.app_id, []).append(ev.duration_ms)
    return out
