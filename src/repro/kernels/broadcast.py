"""Pallas TPU kernel: fused dequantize-and-apply for broadcast deltas.

The compressed-downlink hot loop (docs/performance.md "compressed
downlink"): a worker holding params ``w`` receives a chain of D
quantized version deltas (int8 lattice points + per-chunk f32 scales)
and folds them into its held state in ONE pass — no materialized f32
delta, no per-version round trip.  The chain axis is accumulated
strictly in order (a static unroll over D, which is <= the policy's
``chain_cap``), element-wise identical to applying the deltas one
version at a time, so chained reconstruction lands exactly on the
master's incrementally-maintained reference state.

Tiling matches ``quantize.py``: (ROWS_PER_BLOCK, 256) f32 blocks in
VMEM with the full (small) chain axis resident per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW = 256
ROWS_PER_BLOCK = 256


def _apply_kernel(w_ref, q_ref, s_ref, o_ref):
    acc = w_ref[...].astype(jnp.float32)  # (RB, 256)
    for d in range(q_ref.shape[0]):  # static unroll: D <= chain_cap
        acc = acc + q_ref[d].astype(jnp.float32) * s_ref[d]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def apply_quantized_broadcast(
    w: jax.Array, q: jax.Array, s: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """w: (R, 256) f32; q: (D, R, 256) int8; s: (D, R, 1) f32 ->
    (R, 256) f32 with the D deltas accumulated in chain order.
    R % ROWS_PER_BLOCK == 0."""
    R, W = w.shape
    D = q.shape[0]
    assert W == ROW and R % ROWS_PER_BLOCK == 0, (R, W)
    assert q.shape == (D, R, ROW) and s.shape == (D, R, 1), (q.shape, s.shape)
    grid = (R // ROWS_PER_BLOCK,)
    return pl.pallas_call(
        _apply_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_PER_BLOCK, ROW), lambda i: (i, 0)),
            pl.BlockSpec((D, ROWS_PER_BLOCK, ROW), lambda i: (0, i, 0)),
            pl.BlockSpec((D, ROWS_PER_BLOCK, 1), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS_PER_BLOCK, ROW), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, ROW), jnp.float32),
        interpret=interpret,
    )(w, q, s)
