"""Pallas TPU kernels for Totoro+'s compute hot-spots.

tree_aggregate — weighted child-gradient reduction (aggregator inner loop)
quantize      — QSGD int8 stochastic quantize/dequantize (cross-zone wire)
policy_update — Algorithm 1 lines 5-8, batched over nodes
fused_update  — fused SGD + FedProx proximal + weight decay

Each: pl.pallas_call + explicit BlockSpec VMEM tiling; ops.py = jit'd
public wrappers (interpret=True off-TPU); ref.py = pure-jnp oracles.
"""
