"""Pallas TPU kernels for Totoro+'s compute hot-spots.

tree_aggregate — weighted child-gradient reduction (aggregator inner loop)
quantize      — QSGD int8 stochastic quantize/dequantize (cross-zone wire)
broadcast     — fused dequantize-and-apply of broadcast delta chains
policy_update — Algorithm 1 lines 5-8, batched over nodes
fused_update  — fused SGD + FedProx proximal + weight decay

Each: pl.pallas_call + explicit BlockSpec VMEM tiling; ops.py = jit'd
public wrappers; ref.py = pure-jnp oracles.

Off-TPU the wrappers route to the *compiled* jnp oracles instead of
Pallas interpret mode (which executes the kernel body per grid point at
Python speed): ``ops.kernel_mode()`` is ``auto`` | ``pallas`` | ``jnp``,
settable via ``ops.set_kernel_mode`` or ``REPRO_KERNEL_MODE``.  The
Pallas source is unchanged and remains the TPU path; parity between the
paths is property-tested (tests/test_kernels.py, tests/test_hotpath.py).
"""
