"""Pallas TPU kernel: weighted child-gradient aggregation (tree node inner loop).

An aggregator node in a Totoro+ dataflow tree combines C children's model
updates: out = sum_c w_c * g_c over a flattened parameter vector.  The
kernel tiles the parameter dim into MXU/VPU-aligned (C, TILE) VMEM blocks
and accumulates in f32 regardless of the payload dtype (bf16 children
updates are the common case after compression).

Grid: one program per tile of L; the full child dim C sits in VMEM
(C <= 32 children per the fanout configs, TILE*C*4B << 16 MB VMEM).

Units and invariants:

- Inputs are *flattened* parameter vectors (f32/bf16 elements; sizes in
  ``ops.py`` are tracked in bytes).  L must be a multiple of ``TILE`` —
  callers pad, and padding slots MUST carry zero weight so they cannot
  contribute to the sum (``tree_aggregate_groups``' ragged groups and
  the phantom groups added for grid alignment both rely on this).
- The kernels produce partial weighted *sums*, never means: weight
  normalization happens exactly once, at the tree root (see
  ``core/api._aggregate_hierarchical`` and ``ApplyBuffered``) — this is
  what makes level-by-level aggregation associative and bit-compatible
  (up to f32 reduction order) with the flat weighted mean.
- ``staleness_weights`` is the *entire* async modification to the math:
  the Table-II verbs ``CommitDelta``/``ApplyBuffered`` discount each
  buffered commit's weight by ``1/(1+staleness)^alpha`` (staleness in
  model versions) and feed the result through the same kernels'
  weight vectors as the synchronous ``Aggregate`` verb.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024


def staleness_weights(weights, staleness, alpha: float):
    """FedBuff-style staleness discount: w_i / (1 + s_i)^alpha.

    This is the *only* change the async buffered path makes to the
    aggregation math — the discounted weights ride the existing kernels'
    weight vector, so alpha = 0 (or all-zero staleness) reproduces the
    synchronous weighted mean bit-for-bit.
    """
    w = jnp.asarray(weights, jnp.float32)
    s = jnp.asarray(staleness, jnp.float32)
    return w * (1.0 + s) ** (-float(alpha))


def _kernel(g_ref, w_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)  # (C, TILE)
    w = w_ref[...].astype(jnp.float32)  # (C, 1)
    o_ref[...] = jnp.sum(g * w, axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tree_aggregate(grads: jax.Array, weights: jax.Array, *, interpret: bool = False) -> jax.Array:
    """grads: (C, L) any float dtype; weights: (C,) -> (L,) f32.

    L must be a multiple of TILE (callers pad; ops.py handles it).
    """
    C, L = grads.shape
    assert L % TILE == 0, L
    w2 = weights.reshape(C, 1).astype(jnp.float32)
    return pl.pallas_call(
        _kernel,
        grid=(L // TILE,),
        in_specs=[
            pl.BlockSpec((C, TILE), lambda i: (0, i)),
            pl.BlockSpec((C, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((L,), jnp.float32),
        interpret=interpret,
    )(grads, w2)


@functools.partial(jax.jit)
def tree_aggregate_jnp(grads: jax.Array, weights: jax.Array) -> jax.Array:
    """Compiled pure-jnp fallback for ``tree_aggregate`` (no Pallas).

    Selected by ``ops.py`` whenever the Pallas path would run in
    ``interpret=True`` (i.e. off-TPU): interpret mode executes the kernel
    body per grid point at Python speed, which made every CPU aggregation
    a hot spot.  Same contraction as ``ref.tree_aggregate_ref`` — the
    oracle IS the fallback — jitted once per shape bucket.  No tile
    padding needed: XLA handles arbitrary L.
    """
    return jnp.einsum(
        "c,cl->l", weights.astype(jnp.float32), grads.astype(jnp.float32)
    )


@functools.partial(jax.jit)
def tree_aggregate_groups_jnp(grads: jax.Array, weights: jax.Array) -> jax.Array:
    """Compiled pure-jnp fallback for ``tree_aggregate_groups``:
    (G, C, L) x (G, C) -> (G, L) batched weighted sums.  Zero-weight
    padding slots (ragged groups, phantom groups, bucket padding) carry
    zero grads as well, so they add exact float zeros to the contraction."""
    return jnp.einsum(
        "gc,gcl->gl", weights.astype(jnp.float32), grads.astype(jnp.float32)
    )


GROUP_BLOCK = 8  # groups per program: GB*C*TILE*4B <= 1 MB VMEM at C=32


def _group_kernel(g_ref, w_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)  # (GB, C, TILE)
    w = w_ref[...].astype(jnp.float32)  # (GB, C, 1)
    o_ref[...] = jnp.sum(g * w, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tree_aggregate_groups(
    grads: jax.Array, weights: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """Batched per-level aggregation: one tree level is G independent
    (parent, children) groups, padded to a common child count C — the
    whole level runs as ONE kernel launch (grid over group blocks x
    tiles) instead of G separate aggregator calls.

    grads: (G, C, L); weights: (G, C) — ragged groups carry zero weights
    in the padding slots -> (G, L) f32 weighted sums, one per parent.
    """
    G, C, L = grads.shape
    assert L % TILE == 0, L
    w3 = weights.reshape(G, C, 1).astype(jnp.float32)
    gb = min(GROUP_BLOCK, G)
    pad = (-G) % gb
    if pad:  # zero-weight phantom groups complete the last block
        grads = jnp.pad(grads, ((0, pad), (0, 0), (0, 0)))
        w3 = jnp.pad(w3, ((0, pad), (0, 0), (0, 0)))
    out = pl.pallas_call(
        _group_kernel,
        grid=((G + pad) // gb, L // TILE),
        in_specs=[
            pl.BlockSpec((gb, C, TILE), lambda g, i: (g, 0, i)),
            pl.BlockSpec((gb, C, 1), lambda g, i: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((gb, TILE), lambda g, i: (g, i)),
        out_shape=jax.ShapeDtypeStruct((G + pad, L), jnp.float32),
        interpret=interpret,
    )(grads, w3)
    return out[:G]
