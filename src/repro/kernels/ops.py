"""Public jit'd wrappers for the Pallas kernels, with a compiled fallback.

Two execution paths per kernel (selected by ``kernel_mode()``):

- **Pallas** — the TPU target.  On TPU the kernels compile through
  Mosaic; off-TPU the same source runs in ``interpret=True`` mode, which
  executes the kernel body per grid point at Python speed.  Interpret
  mode is the correctness anchor, not a production path — it made every
  CPU aggregation call a simulator hot spot.
- **Compiled jnp fallback** — the ``ref.py`` oracles (the kernels'
  correctness contract) jitted directly, selected automatically whenever
  the Pallas path would have interpreted (``mode="auto"``, the default).
  The update kernel donates its parameter buffer so the fallback is an
  in-place read-modify-write like the fused Pallas kernel.

Modes: ``auto`` (jnp off-TPU, Pallas on TPU), ``pallas`` (always Pallas
— interpret off-TPU; the pre-optimization behavior, kept for parity
tests and benchmark baselines), ``jnp`` (always the compiled fallback).
Set via ``set_kernel_mode`` or the ``REPRO_KERNEL_MODE`` env var.

To keep recompiles at O(#buckets) instead of O(#distinct shapes), the
batched-group wrapper pads the group and child dims up to power-of-two
buckets with zero-weight, zero-valued slots; appending exact float zeros
to a weighted sum never changes the partial sums, so bucketing is
bit-exact (asserted in tests/test_hotpath.py).  Wrappers also handle
tile padding and pytree-level application as before.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import broadcast as _bc
from . import fused_update as _fu
from . import policy_update as _pu
from . import quantize as _q
from . import ref as _ref
from . import tree_aggregate as _ta

_VALID_MODES = ("auto", "pallas", "jnp")
_MODE = os.environ.get("REPRO_KERNEL_MODE", "auto")
if _MODE not in _VALID_MODES:
    raise ValueError(f"REPRO_KERNEL_MODE must be one of {_VALID_MODES}, got {_MODE!r}")


def kernel_mode() -> str:
    return _MODE


def set_kernel_mode(mode: str) -> str:
    """Select the kernel execution path; returns the previous mode."""
    global _MODE
    if mode not in _VALID_MODES:
        raise ValueError(f"kernel mode must be one of {_VALID_MODES}, got {mode!r}")
    prev, _MODE = _MODE, mode
    return prev


def _use_jnp() -> bool:
    if _MODE == "jnp":
        return True
    if _MODE == "pallas":
        return False
    return jax.default_backend() != "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def bucket_size(n: int) -> int:
    """Next power of two >= n (>= 1): THE shape-bucket policy, shared by
    the kernel wrappers here and the training engine (``fl/engine.py``
    re-exports it) so the two sides can never desynchronize.  Padding
    cost is bounded below 2x elements per axis, in exchange for O(log)
    distinct compiled programs per dimension."""
    return 1 << (max(1, int(n)) - 1).bit_length()


_bucket = bucket_size  # internal alias used by the wrappers below


def _pad_to(x: jax.Array, mult: int, axis: int = 0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def _pad_axis_to(x, size: int, axis: int):
    """Zero-pad one axis up to an absolute size (no-op when already there)."""
    if x.shape[axis] == size:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, size - x.shape[axis])
    return jnp.pad(x, widths)


def tree_aggregate(grads: jax.Array, weights: jax.Array) -> jax.Array:
    """(C, L) x (C,) -> (L,) f32 weighted sum (pads L to the tile size)."""
    if _use_jnp():
        c = _bucket(grads.shape[0])
        g = _pad_axis_to(grads, c, 0)
        w = _pad_axis_to(weights, c, 0)
        return _ta.tree_aggregate_jnp(g, w)
    g, pad = _pad_to(grads, _ta.TILE, axis=1)
    out = _ta.tree_aggregate(g, weights, interpret=_interpret())
    return out[: grads.shape[1]]


def tree_aggregate_groups(grads: jax.Array, weights: jax.Array) -> jax.Array:
    """(G, C, L) x (G, C) -> (G, L): one tree level as G padded groups.

    The compiled fallback buckets G and C to powers of two with
    zero-weight phantom slots, so every level of every tree hits one of
    O(log G * log C) compiled programs per L instead of one per exact
    shape (the recompile gate in bench_hotpath).
    """
    if _use_jnp():
        gb, cb = _bucket(grads.shape[0]), _bucket(grads.shape[1])
        g = _pad_axis_to(_pad_axis_to(grads, gb, 0), cb, 1)
        w = _pad_axis_to(_pad_axis_to(weights, gb, 0), cb, 1)
        return _ta.tree_aggregate_groups_jnp(g, w)[: grads.shape[0]]
    g, pad = _pad_to(grads, _ta.TILE, axis=2)
    out = _ta.tree_aggregate_groups(g, weights, interpret=_interpret())
    return out[:, : grads.shape[2]]


def _stack_pytrees(updates: list) -> jax.Array:
    """(C, L) f32 stack of flattened update pytrees."""
    return jnp.stack([
        jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in jax.tree.leaves(u)])
        for u in updates
    ])


def _unflatten_like(vec: jax.Array, like) -> object:
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for l in leaves:
        out.append(vec[off : off + l.size].reshape(l.shape))
        off += l.size
    return jax.tree.unflatten(treedef, out)


def tree_aggregate_pytree(updates: list, weights) -> object:
    """Aggregate a list of model-update pytrees with the kernel."""
    w = jnp.asarray(weights, jnp.float32)
    agg = tree_aggregate(_stack_pytrees(updates), w)
    return _unflatten_like(agg, updates[0])


def buffered_aggregate(updates: list, weights, staleness, *, alpha: float = 0.5):
    """Staleness-weighted buffered aggregate (async FedBuff apply).

    The K buffered deltas form ONE (1, K, L) group through the batched
    ``tree_aggregate_groups`` kernel with the staleness discount
    ``w_i / (1+s_i)^alpha`` folded into its weight vector; the weighted
    sum is normalized by the combined weight so a full uniform-staleness
    buffer at alpha's no-op point matches synchronous FedAvg exactly.
    K rides the group wrapper's child-dim bucketing, so varying buffer
    fills (adaptive K, churn-clamped applies) reuse one compiled program
    per bucket.

    Returns (aggregate pytree, combined weights (K,) f32).
    """
    w = _ta.staleness_weights(weights, staleness, alpha)
    stacked = _stack_pytrees(updates)[None]  # (1, K, L)
    agg = tree_aggregate_groups(stacked, w[None])[0] / jnp.maximum(w.sum(), 1e-12)
    return _unflatten_like(agg, updates[0]), w


def buffered_aggregate_quantized(qs, scales, weights, staleness, *, alpha: float = 0.5):
    """Staleness-weighted aggregate of K *quantized* deltas, dequantized
    inside the aggregation (the compressed-transport apply path).

    ``qs``: K int8 arrays (R, C) — each worker's flattened delta on the
    QSGD lattice; ``scales``: K f32 arrays (R, 1) — the per-chunk
    max-abs scales.  Instead of dequantizing each delta and re-running
    ``buffered_aggregate``, the per-row scale composes with the
    staleness discount into ONE weight per (row, worker):

        agg[r, :] = sum_k (w_k * s_{k,r}) * q_k[r, :] / sum_k w_k

    where ``w_k = weight_k / (1+staleness_k)^alpha`` — exactly the
    unfused ``buffered_aggregate(dequantize(q_k * s_k), ...)`` result
    (linearity; checked to fp tolerance in tests/test_compression.py).
    The R rows form the group axis of ``tree_aggregate_groups``, so the
    fused path rides the same Pallas kernel / compiled fallback and the
    same shape buckets as the uncompressed apply.

    Returns (flat (R*C,) f32 aggregate, combined weights (K,) f32);
    callers unflatten via ``QuantizedDelta.unflatten``.
    """
    w = _ta.staleness_weights(weights, staleness, alpha)  # (K,)
    q = jnp.stack([jnp.asarray(x) for x in qs]).astype(jnp.float32)  # (K, R, C)
    s = jnp.stack([jnp.asarray(x).reshape(-1) for x in scales])  # (K, R)
    g = jnp.transpose(q, (1, 0, 2))  # (R, K, C)
    gw = jnp.transpose(w[:, None] * s)  # (R, K): staleness x per-row scale
    agg = tree_aggregate_groups(g, gw) / jnp.maximum(w.sum(), 1e-12)
    return jnp.ravel(agg), w


def jain_fairness(x) -> float:
    """Jain's fairness index ``(sum x)^2 / (n * sum x^2)`` in (0, 1].

    Host-side telemetry used by the async scheduler's fairness log and
    ``benchmarks/bench_fairness.py``: x is a vector of per-app uplink
    throughputs (or progress rates); 1.0 means a perfectly even split,
    ``1/n`` means one app holds everything.  An empty or all-zero vector
    scores 1.0 (nothing to be unfair about)."""
    v = np.asarray(x, np.float64)
    if v.size == 0:
        return 1.0
    q = float(np.sum(v * v))
    if q <= 0.0:
        return 1.0
    s = float(np.sum(v))
    return (s * s) / (v.size * q)


@functools.partial(jax.jit, static_argnames=("levels",))
def _qsgd_quantize_jnp(x, rand, levels=127):
    return _ref.quantize_ref(x, rand, levels=levels)


@functools.partial(jax.jit)
def _qsgd_dequantize_jnp(q, scale):
    return _ref.dequantize_ref(q, scale)


def qsgd_quantize(x: jax.Array, rand: jax.Array, *, levels: int = 127):
    """(R, 256) -> (int8, scales); pads rows to the block size.
    ``levels`` (static) is the per-sign lattice size (<= 127)."""
    if _use_jnp():
        return _qsgd_quantize_jnp(x, rand, levels=levels)
    xp, pad = _pad_to(x, _q.ROWS_PER_BLOCK, axis=0)
    rp, _ = _pad_to(rand, _q.ROWS_PER_BLOCK, axis=0)
    q, s = _q.qsgd_quantize(xp, rp, interpret=_interpret(), levels=levels)
    R = x.shape[0]
    return q[:R], s[:R]


def qsgd_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    if _use_jnp():
        return _qsgd_dequantize_jnp(q, scale)
    qp, pad = _pad_to(q, _q.ROWS_PER_BLOCK, axis=0)
    sp, _ = _pad_to(scale, _q.ROWS_PER_BLOCK, axis=0)
    out = _q.qsgd_dequantize(qp, sp, interpret=_interpret())
    return out[: q.shape[0]]


@jax.jit
def _apply_quantized_jnp(w, q, s):
    return _ref.apply_quantized_ref(w, q, s)


def apply_quantized_broadcast(w: jax.Array, q: jax.Array, scale: jax.Array) -> jax.Array:
    """Fused dequantize-and-apply of a broadcast delta chain: (R, 256)
    f32 held params + (D, R, 256) int8 lattice points * (D, R, 1) f32
    per-chunk scales -> (R, 256) f32, the chain accumulated strictly in
    order in one pass (docs/performance.md "compressed downlink").  Pads
    rows to the block size; the chain axis D (<= ``chain_cap``) is a
    static unroll, so distinct chain lengths compile O(chain_cap)
    programs total."""
    w, q, scale = jnp.asarray(w), jnp.asarray(q), jnp.asarray(scale)
    if _use_jnp():
        return _apply_quantized_jnp(w, q, scale)
    wp, _ = _pad_to(w, _bc.ROWS_PER_BLOCK, axis=0)
    qp, _ = _pad_to(q, _bc.ROWS_PER_BLOCK, axis=1)
    sp, _ = _pad_to(scale, _bc.ROWS_PER_BLOCK, axis=1)
    out = _bc.apply_quantized_broadcast(wp, qp, sp, interpret=_interpret())
    return out[: w.shape[0]]


@functools.partial(jax.jit, static_argnames=("tau", "alpha", "beta"))
def _policy_update_jnp(pi, mask, cand, reward_sums, *, tau, alpha, beta):
    return _ref.policy_update_ref(
        pi, mask, cand, reward_sums, tau=tau, alpha=alpha, beta=beta
    )


def policy_update(pi, mask, cand, reward_sums, *, tau: int, alpha: float, beta: float):
    """(N,K) policies -> updated policies (pads N to the node block)."""
    if _use_jnp():
        return _policy_update_jnp(
            pi, mask, cand, reward_sums, tau=tau, alpha=alpha, beta=beta
        )
    N = pi.shape[0]
    pi_p, _ = _pad_to(pi, _pu.NODE_BLOCK, axis=0)
    # padded nodes get a valid uniform row to avoid 0/0
    if pi_p.shape[0] != N:
        pad_rows = pi_p.shape[0] - N
        K = pi.shape[1]
        pi_p = pi_p.at[N:].set(1.0 / K)
    mask_p, _ = _pad_to(mask.astype(jnp.float32), _pu.NODE_BLOCK, axis=0)
    mask_p = mask_p.at[N:].set(1.0) if mask_p.shape[0] != N else mask_p
    rs_p, _ = _pad_to(reward_sums, _pu.NODE_BLOCK, axis=0)
    out = _pu.policy_update(
        pi_p, mask_p > 0, cand, rs_p, tau=tau, alpha=alpha, beta=beta,
        interpret=_interpret(),
    )
    return out[:N]


@functools.partial(jax.jit, static_argnames=("lr", "mu", "wd"))
def _fused_update_jnp(w, g, w0, *, lr, mu, wd):
    return _ref.fused_update_ref(w, g, w0, lr, mu, wd)


@functools.partial(
    jax.jit, donate_argnums=(0,), static_argnames=("lr", "mu", "wd")
)
def _fused_update_jnp_donated(w, g, w0, *, lr, mu, wd):
    # the parameter buffer is donated: like the Pallas kernel's VMEM
    # read-modify-write, the fallback updates w in place instead of
    # allocating a second full parameter vector
    return _ref.fused_update_ref(w, g, w0, lr, mu, wd)


def fused_update(
    w, g, w0, *, lr: float, mu: float = 0.0, wd: float = 0.0, donate: bool = False
):
    """Flattened fused FedProx/SGD update (pads to the tile size).

    ``donate=True`` (compiled-fallback path) donates ``w``'s buffer to
    the update — the in-place read-modify-write a server update wants —
    so the caller MUST NOT touch ``w`` afterwards (and ``w0`` must not
    alias it; pass ``donate=False``, the default, for the reference
    semantics where ``w`` stays valid).
    """
    shape, dtype = w.shape, w.dtype
    if _use_jnp():
        fn = _fused_update_jnp_donated if donate else _fused_update_jnp
        out = fn(jnp.ravel(w), jnp.ravel(g), jnp.ravel(w0), lr=lr, mu=mu, wd=wd)
        return out.reshape(shape).astype(dtype)
    wf, _ = _pad_to(w.ravel(), _fu.TILE)
    gf, _ = _pad_to(g.ravel(), _fu.TILE)
    w0f, _ = _pad_to(w0.ravel(), _fu.TILE)
    out = _fu.fused_update(wf, gf, w0f, lr=lr, mu=mu, wd=wd, interpret=_interpret())
    return out[: w.size].reshape(shape).astype(dtype)


def fused_update_pytree(params, grads, round_start, *, lr, mu=0.0, wd=0.0, donate=False):
    return jax.tree.map(
        lambda w, g, w0: fused_update(w, g, w0, lr=lr, mu=mu, wd=wd, donate=donate),
        params, grads, round_start,
    )
