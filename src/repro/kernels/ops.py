"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes as pure JAX for correctness validation; on TPU (the
target) they compile through Mosaic.  Wrappers handle padding to the
kernels' tile multiples and pytree-level application.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import fused_update as _fu
from . import policy_update as _pu
from . import quantize as _q
from . import tree_aggregate as _ta


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int = 0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def tree_aggregate(grads: jax.Array, weights: jax.Array) -> jax.Array:
    """(C, L) x (C,) -> (L,) f32 weighted sum (pads L to the tile size)."""
    g, pad = _pad_to(grads, _ta.TILE, axis=1)
    out = _ta.tree_aggregate(g, weights, interpret=_interpret())
    return out[: grads.shape[1]]


def tree_aggregate_groups(grads: jax.Array, weights: jax.Array) -> jax.Array:
    """(G, C, L) x (G, C) -> (G, L): one tree level as G padded groups."""
    g, pad = _pad_to(grads, _ta.TILE, axis=2)
    out = _ta.tree_aggregate_groups(g, weights, interpret=_interpret())
    return out[:, : grads.shape[2]]


def _stack_pytrees(updates: list) -> jax.Array:
    """(C, L) f32 stack of flattened update pytrees."""
    return jnp.stack([
        jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in jax.tree.leaves(u)])
        for u in updates
    ])


def _unflatten_like(vec: jax.Array, like) -> object:
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for l in leaves:
        out.append(vec[off : off + l.size].reshape(l.shape))
        off += l.size
    return jax.tree.unflatten(treedef, out)


def tree_aggregate_pytree(updates: list, weights) -> object:
    """Aggregate a list of model-update pytrees with the kernel."""
    w = jnp.asarray(weights, jnp.float32)
    agg = tree_aggregate(_stack_pytrees(updates), w)
    return _unflatten_like(agg, updates[0])


def buffered_aggregate(updates: list, weights, staleness, *, alpha: float = 0.5):
    """Staleness-weighted buffered aggregate (async FedBuff apply).

    The K buffered deltas form ONE (1, K, L) group through the batched
    ``tree_aggregate_groups`` kernel with the staleness discount
    ``w_i / (1+s_i)^alpha`` folded into its weight vector; the weighted
    sum is normalized by the combined weight so a full uniform-staleness
    buffer at alpha's no-op point matches synchronous FedAvg exactly.

    Returns (aggregate pytree, combined weights (K,) f32).
    """
    w = _ta.staleness_weights(weights, staleness, alpha)
    stacked = _stack_pytrees(updates)[None]  # (1, K, L)
    agg = tree_aggregate_groups(stacked, w[None])[0] / jnp.maximum(w.sum(), 1e-12)
    return _unflatten_like(agg, updates[0]), w


def jain_fairness(x) -> float:
    """Jain's fairness index ``(sum x)^2 / (n * sum x^2)`` in (0, 1].

    Host-side telemetry used by the async scheduler's fairness log and
    ``benchmarks/bench_fairness.py``: x is a vector of per-app uplink
    throughputs (or progress rates); 1.0 means a perfectly even split,
    ``1/n`` means one app holds everything.  An empty or all-zero vector
    scores 1.0 (nothing to be unfair about)."""
    v = np.asarray(x, np.float64)
    if v.size == 0:
        return 1.0
    q = float(np.sum(v * v))
    if q <= 0.0:
        return 1.0
    s = float(np.sum(v))
    return (s * s) / (v.size * q)


def qsgd_quantize(x: jax.Array, rand: jax.Array):
    """(R, 256) -> (int8, scales); pads rows to the block size."""
    xp, pad = _pad_to(x, _q.ROWS_PER_BLOCK, axis=0)
    rp, _ = _pad_to(rand, _q.ROWS_PER_BLOCK, axis=0)
    q, s = _q.qsgd_quantize(xp, rp, interpret=_interpret())
    R = x.shape[0]
    return q[:R], s[:R]


def qsgd_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    qp, pad = _pad_to(q, _q.ROWS_PER_BLOCK, axis=0)
    sp, _ = _pad_to(scale, _q.ROWS_PER_BLOCK, axis=0)
    out = _q.qsgd_dequantize(qp, sp, interpret=_interpret())
    return out[: q.shape[0]]


def policy_update(pi, mask, cand, reward_sums, *, tau: int, alpha: float, beta: float):
    """(N,K) policies -> updated policies (pads N to the node block)."""
    N = pi.shape[0]
    pi_p, _ = _pad_to(pi, _pu.NODE_BLOCK, axis=0)
    # padded nodes get a valid uniform row to avoid 0/0
    if pi_p.shape[0] != N:
        pad_rows = pi_p.shape[0] - N
        K = pi.shape[1]
        pi_p = pi_p.at[N:].set(1.0 / K)
    mask_p, _ = _pad_to(mask.astype(jnp.float32), _pu.NODE_BLOCK, axis=0)
    mask_p = mask_p.at[N:].set(1.0) if mask_p.shape[0] != N else mask_p
    rs_p, _ = _pad_to(reward_sums, _pu.NODE_BLOCK, axis=0)
    out = _pu.policy_update(
        pi_p, mask_p > 0, cand, rs_p, tau=tau, alpha=alpha, beta=beta,
        interpret=_interpret(),
    )
    return out[:N]


def fused_update(w, g, w0, *, lr: float, mu: float = 0.0, wd: float = 0.0):
    """Flattened fused FedProx/SGD update (pads to the tile size)."""
    shape, dtype = w.shape, w.dtype
    wf, _ = _pad_to(w.ravel(), _fu.TILE)
    gf, _ = _pad_to(g.ravel(), _fu.TILE)
    w0f, _ = _pad_to(w0.ravel(), _fu.TILE)
    out = _fu.fused_update(wf, gf, w0f, lr=lr, mu=mu, wd=wd, interpret=_interpret())
    return out[: w.size].reshape(shape).astype(dtype)


def fused_update_pytree(params, grads, round_start, *, lr, mu=0.0, wd=0.0):
    return jax.tree.map(
        lambda w, g, w0: fused_update(w, g, w0, lr=lr, mu=mu, wd=wd),
        params, grads, round_start,
    )
