"""Pallas TPU kernel: Algorithm-1 episode policy update, batched over nodes.

The paper's headline complexity claim (Table I: O(log N * Matmul); Figs
15/16) is that the Totoro+ planner is "parallel matrix multiplications".
This kernel runs lines 5-8 for a block of nodes entirely in VMEM:
min-log-det exploratory policy over the candidate set, importance-weighted
potential gradient (one-hot features => M(pi)^{-1} = diag(1/pi)), the
candidate-argmax via an (NB,K)x(K,M) matmul on the MXU, and the
Frank-Wolfe + exploration mixture.

Block shapes: nodes tiled by NODE_BLOCK; K (hops) and M (candidates) are
small (<= 32/64) and sit fully in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NODE_BLOCK = 256


def _kernel(alpha_ref, beta_ref, tau_ref, pi_ref, mask_ref, cand_ref, rsum_ref, out_ref):
    alpha = alpha_ref[0]
    beta = beta_ref[0]
    tau = tau_ref[0]
    pi = pi_ref[...]  # (NB, K)
    maskf = mask_ref[...].astype(jnp.float32)
    cand = cand_ref[...]  # (M, K)
    rsum = rsum_ref[...]  # (NB, K)

    # per-node re-masked candidate set: (NB, M, K)
    candn = cand[None, :, :] * maskf[:, None, :]
    candn = candn / jnp.maximum(jnp.sum(candn, axis=-1, keepdims=True), 1e-12)

    # line 5: rho = argmin_det M(lambda); det = prod_k lambda_k (one-hot psi)
    logdet = jnp.sum(
        jnp.where(maskf[:, None, :] > 0, jnp.log(jnp.maximum(candn, 1e-12)), 0.0), axis=-1
    )  # (NB, M)
    rho_idx = jnp.argmin(logdet, axis=-1)  # (NB,)
    rho = jnp.take_along_axis(candn, rho_idx[:, None, None], axis=1)[:, 0, :]

    # line 6: grad = rsum / (tau * pi)
    grad = rsum / (tau * jnp.maximum(pi, 1e-12)) * maskf  # (NB, K)

    # line 7: scores = candn . grad  -> argmax candidate
    scores = jnp.sum(candn * grad[:, None, :], axis=-1)  # (NB, M)
    best_idx = jnp.argmax(scores, axis=-1)
    pi_tilde = jnp.take_along_axis(candn, best_idx[:, None, None], axis=1)[:, 0, :]

    # line 8: Frank-Wolfe + exploration mixture, renormalized on the mask
    pi_new = alpha * (pi + beta * (pi_tilde - pi)) + (1.0 - alpha) * rho
    pi_new = pi_new * maskf
    out_ref[...] = pi_new / jnp.maximum(jnp.sum(pi_new, axis=-1, keepdims=True), 1e-12)


@functools.partial(jax.jit, static_argnames=("tau", "interpret"))
def policy_update(
    pi: jax.Array,  # (N, K) f32
    mask: jax.Array,  # (N, K) bool
    cand: jax.Array,  # (M, K) f32
    reward_sums: jax.Array,  # (N, K) f32
    *,
    tau: int,
    alpha: float,
    beta: float,
    interpret: bool = False,
) -> jax.Array:
    N, K = pi.shape
    assert N % NODE_BLOCK == 0, N
    M = cand.shape[0]
    scal = lambda v, dt: jnp.asarray([v], dt)
    return pl.pallas_call(
        _kernel,
        grid=(N // NODE_BLOCK,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # alpha
            pl.BlockSpec(memory_space=pl.ANY),  # beta
            pl.BlockSpec(memory_space=pl.ANY),  # tau
            pl.BlockSpec((NODE_BLOCK, K), lambda i: (i, 0)),
            pl.BlockSpec((NODE_BLOCK, K), lambda i: (i, 0)),
            pl.BlockSpec((M, K), lambda i: (0, 0)),
            pl.BlockSpec((NODE_BLOCK, K), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((NODE_BLOCK, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, K), jnp.float32),
        interpret=interpret,
    )(scal(alpha, jnp.float32), scal(beta, jnp.float32), scal(tau, jnp.float32), pi, mask.astype(jnp.float32) > 0, cand, reward_sums)
