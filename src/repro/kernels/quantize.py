"""Pallas TPU kernels: QSGD int8 stochastic quantize / dequantize.

The compression hook on the cross-zone aggregation hop (paper Table II's
custom compression functions; refs [37] QSGD).  Rows of 256 values share
one f32 max-abs scale; stochastic rounding consumes pre-supplied uniform
bits so the kernel is bit-identical to ``ref.quantize_ref`` (and to the
pure-JAX path used inside the train step).

Tiling: (ROWS_PER_BLOCK, 256) blocks in VMEM — the trailing 256 is lane-
aligned; row blocks keep the footprint < 1 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW = 256
ROWS_PER_BLOCK = 256
LEVELS = 127


def _quant_kernel(x_ref, r_ref, q_ref, s_ref, *, levels: int = LEVELS):
    x = x_ref[...].astype(jnp.float32)  # (RB, 256)
    r = r_ref[...].astype(jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / levels
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.floor(x / scale + r)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret", "levels"))
def qsgd_quantize(
    x: jax.Array, rand: jax.Array, *, interpret: bool = False, levels: int = LEVELS
):
    """x, rand: (R, 256) with R % ROWS_PER_BLOCK == 0 -> (int8 (R,256), f32 (R,1)).

    ``levels`` (static, <= 127) is the per-sign lattice size — the
    ``CompressionPolicy.levels`` knob; the grid respecializes per value."""
    R, W = x.shape
    assert W == ROW and R % ROWS_PER_BLOCK == 0, (R, W)
    grid = (R // ROWS_PER_BLOCK,)
    return pl.pallas_call(
        functools.partial(_quant_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_PER_BLOCK, ROW), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_BLOCK, ROW), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS_PER_BLOCK, ROW), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_BLOCK, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, ROW), jnp.int8),
            jax.ShapeDtypeStruct((R, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, rand)


@functools.partial(jax.jit, static_argnames=("interpret",))
def qsgd_dequantize(q: jax.Array, scale: jax.Array, *, interpret: bool = False) -> jax.Array:
    R, W = q.shape
    assert W == ROW and R % ROWS_PER_BLOCK == 0, (R, W)
    return pl.pallas_call(
        _dequant_kernel,
        grid=(R // ROWS_PER_BLOCK,),
        in_specs=[
            pl.BlockSpec((ROWS_PER_BLOCK, ROW), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_BLOCK, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS_PER_BLOCK, ROW), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, ROW), jnp.float32),
        interpret=interpret,
    )(q, scale)
