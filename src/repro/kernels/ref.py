"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_aggregate_ref(grads: jax.Array, weights: jax.Array) -> jax.Array:
    """Weighted sum of C children's flattened gradient tiles.

    grads: (C, L); weights: (C,) -> (L,) f32 (an aggregator node's inner
    loop: acc = sum_c w_c * g_c, paper §IV-C gradient aggregation).
    """
    return jnp.einsum(
        "c,cl->l", weights.astype(jnp.float32), grads.astype(jnp.float32)
    )


def quantize_ref(x: jax.Array, rand: jax.Array, levels: int = 127):
    """QSGD stochastic int8 quantization with per-row max-abs scale.

    x: (R, 256); rand: (R, 256) uniforms in [0,1) -> (q int8, scale (R,1)).
    """
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / levels
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.floor(xf / scale + rand.astype(jnp.float32))
    return q.astype(jnp.int8), scale


def dequantize_ref(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def apply_quantized_ref(w: jax.Array, q: jax.Array, s: jax.Array) -> jax.Array:
    """Fused dequantize-and-apply of a broadcast delta chain.

    w: (R, C) f32; q: (D, R, C) int8; s: (D, R, 1) f32 -> (R, C) f32.
    The chain axis D accumulates strictly in order (a static Python
    unroll, same element-wise addition sequence as D successive
    single-delta applies), so chained reconstruction matches the
    incremental reference state.
    """
    acc = w.astype(jnp.float32)
    for d in range(q.shape[0]):
        acc = acc + q[d].astype(jnp.float32) * s[d]
    return acc


def policy_update_ref(
    pi: jax.Array,  # (N, K)
    mask: jax.Array,  # (N, K) bool
    cand: jax.Array,  # (M, K)
    reward_sums: jax.Array,  # (N, K): sum_t 1[a_t = k] r_t
    tau: int,
    alpha: float,
    beta: float,
) -> jax.Array:
    """Algorithm-1 episode update (lines 5-8) with one-hot features.

    Matches ``repro.core.pathplan.algorithm1_episode`` given
    reward_sums[n, k] = sum over the episode's tau packets of r when hop k
    was chosen.
    """
    maskf = mask.astype(jnp.float32)
    candn = cand[None] * maskf[:, None, :]
    candn = candn / jnp.maximum(candn.sum(-1, keepdims=True), 1e-12)
    logdet = jnp.where(maskf[:, None, :] > 0, jnp.log(jnp.maximum(candn, 1e-12)), 0.0).sum(-1)
    rho = jnp.take_along_axis(candn, jnp.argmin(logdet, 1)[:, None, None], 1)[:, 0]
    grad = reward_sums / (tau * jnp.maximum(pi, 1e-12)) * maskf
    scores = jnp.einsum("nmk,nk->nm", candn, grad)
    pi_t = jnp.take_along_axis(candn, jnp.argmax(scores, 1)[:, None, None], 1)[:, 0]
    pi_new = alpha * (pi + beta * (pi_t - pi)) + (1 - alpha) * rho
    pi_new = pi_new * maskf
    return pi_new / jnp.maximum(pi_new.sum(-1, keepdims=True), 1e-12)


def fused_update_ref(
    w: jax.Array, g: jax.Array, w0: jax.Array, lr: float, mu: float, wd: float
) -> jax.Array:
    """Fused SGD + FedProx proximal term + weight decay:
    w' = w - lr * (g + mu*(w - w0) + wd*w)."""
    wf = w.astype(jnp.float32)
    out = wf - lr * (g.astype(jnp.float32) + mu * (wf - w0.astype(jnp.float32)) + wd * wf)
    return out.astype(w.dtype)
