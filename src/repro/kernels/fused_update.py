"""Pallas TPU kernel: fused SGD + FedProx proximal + weight-decay update.

w' = w - lr * (g + mu*(w - w_global) + wd*w) — the FedProx [56] client
update the paper exposes through the Aggregate hook.  Fusing keeps each
parameter tile resident in VMEM for one read-modify-write instead of
three elementwise passes over HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 2048


def _kernel(h_ref, w_ref, g_ref, w0_ref, o_ref):
    lr, mu, wd = h_ref[0], h_ref[1], h_ref[2]
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    w0 = w0_ref[...].astype(jnp.float32)
    out = w - lr * (g + mu * (w - w0) + wd * w)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_update(
    w: jax.Array, g: jax.Array, w0: jax.Array, *, lr: float, mu: float, wd: float,
    interpret: bool = False,
) -> jax.Array:
    """w, g, w0: (L,) with L % TILE == 0 (ops.py pads); returns w.dtype."""
    (L,) = w.shape
    assert L % TILE == 0, L
    hyper = jnp.asarray([lr, mu, wd], jnp.float32)
    return pl.pallas_call(
        _kernel,
        grid=(L // TILE,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((L,), w.dtype),
        interpret=interpret,
    )(hyper, w, g, w0)
