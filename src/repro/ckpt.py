"""Checkpointing with k-replica writes (the paper's master-state replication).

Totoro+ §IV-D: "the master node in each communication round replicates the
training state across k nodes in its neighborhood set (k=2 by default)";
on master failure the takeover node restores from any replica.  Here a
"neighborhood node" is a distinct storage target (directory standing in
for a peer's disk); ``save`` fsyncs k replicas with checksums, ``restore``
reads the first intact one — so the training loop survives loss of any
k-1 replicas.

Arrays are stored as flat .npz per replica with a JSON manifest (pytree
structure + shapes + per-file SHA1).  Checkpoints hold *full logical*
arrays, so resume works onto any mesh shape (elastic re-shard): the
launcher re-device_puts with the new NamedShardings.  At 1000+ node scale
you would swap the .npz body for per-host shard files (OCDBT-style) while
keeping this manifest/replica protocol; see DESIGN.md §4.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flatten_with_path = getattr(
        jax.tree, "flatten_with_path", jax.tree_util.tree_flatten_with_path
    )
    flat, treedef = flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp) for kp, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


def _sha1(path: str) -> str:
    h = hashlib.sha1()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save(tree: Any, directory: str, *, step: int, replicas: int = 2) -> list[str]:
    """Write ``replicas`` identical copies under directory/replica_i/step_N."""
    paths, leaves, treedef = _flatten_with_paths(tree)
    arrays = [np.asarray(x) for x in leaves]
    written = []
    for r in range(replicas):
        dst = os.path.join(directory, f"replica_{r}", f"step_{step:08d}")
        tmp = dst + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        npz = os.path.join(tmp, "arrays.npz")
        np.savez(npz, **{f"a{i}": a for i, a in enumerate(arrays)})
        manifest = {
            "step": step,
            "paths": paths,
            "dtypes": [str(a.dtype) for a in arrays],
            "shapes": [list(a.shape) for a in arrays],
            "sha1": _sha1(npz),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(dst):
            shutil.rmtree(dst)
        os.replace(tmp, dst)
        written.append(dst)
    return written


def latest_step(directory: str) -> int | None:
    steps = set()
    if not os.path.isdir(directory):
        return None
    for rep in os.listdir(directory):
        rd = os.path.join(directory, rep)
        if not os.path.isdir(rd):
            continue
        for s in os.listdir(rd):
            if s.startswith("step_") and not s.endswith(".tmp"):
                steps.add(int(s[5:]))
    return max(steps) if steps else None


def restore(tree_like: Any, directory: str, *, step: int | None = None) -> tuple[Any, int]:
    """Restore from the first intact replica (checksum-verified).

    ``tree_like`` provides the pytree structure (values ignored).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    errors = []
    for rep in sorted(os.listdir(directory)):
        d = os.path.join(directory, rep, f"step_{step:08d}")
        if not os.path.isdir(d):
            continue
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            npz_path = os.path.join(d, "arrays.npz")
            if _sha1(npz_path) != manifest["sha1"]:
                raise IOError(f"checksum mismatch in {d}")
            with np.load(npz_path) as z:
                arrays = [z[f"a{i}"] for i in range(len(manifest["paths"]))]
            _, leaves, treedef = _flatten_with_paths(tree_like)
            if len(leaves) != len(arrays):
                raise IOError(
                    f"leaf count mismatch: ckpt {len(arrays)} vs tree {len(leaves)}"
                )
            return jax.tree.unflatten(treedef, arrays), manifest["step"]
        except Exception as e:  # corrupted replica: try the next one
            errors.append(f"{d}: {e}")
    raise IOError("all replicas unreadable:\n" + "\n".join(errors))


def corrupt_replica(directory: str, replica: int, step: int) -> None:
    """Test helper: simulate a failed neighborhood node (truncate its copy)."""
    d = os.path.join(directory, f"replica_{replica}", f"step_{step:08d}", "arrays.npz")
    with open(d, "r+b") as f:
        f.truncate(max(0, os.path.getsize(d) // 2))
