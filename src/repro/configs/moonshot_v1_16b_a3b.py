"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64e top-6 — kimi/moonlight [hf:moonshotai/Moonlight-16B-A3B; hf].

Assigned spec followed literally: uniform MoE layers (the shipped
Moonlight additionally has a dense first layer; see DESIGN.md §6)."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    moe_num_experts=64,
    moe_top_k=6,
    moe_d_ff=1408,
    rope_theta=5e4,
)
