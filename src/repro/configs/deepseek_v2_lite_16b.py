"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff=1408
vocab=102400, MoE 64e top-6 — MLA kv_lora=512 (+64 rope dims),
2 shared + 64 routed top-6, dense first layer (d_ff 10944)
[arXiv:2405.04434; hf]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    attn_impl="mla",
    kv_lora_rank=512,
    qk_rope_dim=64,
    moe_num_experts=64,
    moe_top_k=6,
    moe_d_ff=1408,
    moe_num_shared=2,
    first_dense=1,
    first_dense_d_ff=10944,
    rope_theta=1e4,
)
