"""rwkv6-7b [ssm]: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536
— Finch, data-dependent decay [arXiv:2404.05892; hf]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,  # d_model / 64 wkv heads
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    attn_impl="none",
    ssm_kind="rwkv6",
)
