"""seamless-m4t-medium [audio]: 12L d_model=1024 16H (GQA kv=16)
d_ff=4096 vocab=256206 — enc-dec backbone; modality frontend is a stub
(input_specs supplies precomputed frame embeddings) [arXiv:2308.11596; hf]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,  # decoder layers
    enc_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    is_encoder_decoder=True,
    embed_inputs=True,
    # 256206 % 16 != 0: pad the embedding/head to 256256 so the vocab dim
    # shards (otherwise a replicated 67 GB logits+one-hot chain appears);
    # padded logit columns are masked to -inf
    vocab_pad_multiple=256,
)
