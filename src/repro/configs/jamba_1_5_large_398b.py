"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE
every 2nd layer [arXiv:2403.19887; hf]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    ssm_kind="mamba",
    attn_every=8,  # 1 attention : 7 mamba
    moe_num_experts=16,
    moe_top_k=2,
    moe_d_ff=24576,
    moe_every=2,
    d_state=16,
    d_conv=4,
    expand=2,
    optimizer="adafactor",  # 398B
    param_dtype="float32",
)
