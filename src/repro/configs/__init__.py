"""Architecture registry: one module per assigned architecture.

``get_config(name)`` -> full ModelConfig (exact published dims);
``get_reduced(name)`` -> structure-preserving small config for CPU smoke
tests; ``get_plan(name, shape)`` -> RunPlan (grad accumulation etc.).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.config import ModelConfig, RunPlan, ShapeSpec, SHAPES

ARCH_NAMES = [
    "mistral_large_123b",
    "deepseek_67b",
    "qwen3_8b",
    "tinyllama_1_1b",
    "rwkv6_7b",
    "jamba_1_5_large_398b",
    "seamless_m4t_medium",
    "llava_next_34b",
    "moonshot_v1_16b_a3b",
    "deepseek_v2_lite_16b",
]

# public ids (--arch flag) -> module name
ARCH_IDS = {
    "mistral-large-123b": "mistral_large_123b",
    "deepseek-67b": "deepseek_67b",
    "qwen3-8b": "qwen3_8b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "rwkv6-7b": "rwkv6_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llava-next-34b": "llava_next_34b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
}


def get_config(name: str) -> ModelConfig:
    mod = ARCH_IDS.get(name, name.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{mod}").CONFIG


def supports_long_context(cfg: ModelConfig) -> bool:
    """Sub-quadratic (SSM/hybrid) archs run long_500k; pure attention skips."""
    return cfg.ssm_kind in ("mamba", "rwkv6")


def supports_decode(cfg: ModelConfig) -> bool:
    return True  # all assigned archs have decoders (enc-dec included)


def runnable_cells(name: str) -> list[str]:
    cfg = get_config(name)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if supports_long_context(cfg):
        cells.append("long_500k")
    return cells


def get_reduced(name: str) -> ModelConfig:
    """Structure-preserving reduced config for CPU smoke tests."""
    cfg = get_config(name)
    pat = max(cfg.attn_every, cfg.moe_every, 1)
    layers = cfg.first_dense + 2 * pat  # two scan blocks
    kw = dict(
        num_layers=layers,
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        attn_chunk=64,
        ssm_chunk=16,
        dtype="float32",
        param_dtype="float32",
        remat=False,
    )
    if cfg.ssm_kind == "rwkv6":
        kw.update(d_model=128, d_ff=256)  # heads = 128/64 = 2
    if cfg.attn_impl == "mla":
        kw.update(kv_lora_rank=32, qk_rope_dim=16, head_dim=32)
    if cfg.moe_num_experts:
        kw.update(moe_num_experts=8, moe_top_k=2, moe_d_ff=64, moe_group_size=64)
        if cfg.first_dense:
            kw.update(first_dense=1, first_dense_d_ff=256)
    if cfg.is_encoder_decoder:
        kw.update(enc_layers=2, num_layers=2)
    return cfg.replace(**kw)


# per-(arch, shape) execution plans: grad-accum bounds activation memory
_ACCUM = {
    "mistral-large-123b": 4,
    "jamba-1.5-large-398b": 4,
    "deepseek-67b": 2,
    "llava-next-34b": 2,
}


def get_plan(name: str, shape: str) -> RunPlan:
    if shape == "train_4k":
        return RunPlan(grad_accum=_ACCUM.get(name, 1))
    return RunPlan(grad_accum=1)
