"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768  [hf:mistralai/Mistral-Large-Instruct-2407; unverified]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1e6,
    optimizer="adafactor",  # 123B: factored states; see DESIGN.md §6
    param_dtype="float32",
)
