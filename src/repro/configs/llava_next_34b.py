"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling happens in the stubbed frontend; input_specs
supplies patch embeddings [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    embed_inputs=True,  # train/prefill consume embeddings; decode uses tokens
    rope_theta=1e6,
    # 56 heads don't divide the 16-way model axis: queries are padded per kv
    # group (7 -> 8, masked out of wo) so attention shards instead of
    # replicating (a measured 6x whole-model FLOP inflation otherwise)
    tp_pad_multiple=16,
)
