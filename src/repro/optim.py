"""Optimizers: sgd/momentum, adamw (+fp32 master), adafactor (factored).

Pure pytree-function style: ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (new_params, new_state)``;
``opt.state_specs(param_specs, param_shapes) -> state spec tree`` (for the
launcher to build NamedShardings without tracing).

adamw keeps fp32 master weights + fp32 (m, v) — params may be stored bf16
for compute; updates happen on the master and the bf16 copy is re-derived.
adafactor keeps factored second moments (row/col, ~1 byte/param) and is
used for the >100B architectures (mistral-123b, jamba-398b) where adamw
states would not leave activation headroom (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]
    state_specs: Callable[[Any, Any], Any]


def _map_like_params(fn, params, *rest):
    return jax.tree.map(fn, params, *rest)


# ---------------------------------------------------------------------------
# SGD (+momentum)


def sgd(lr: float, momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        st: dict = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            st["mu"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return st

    def update(grads, state, params):
        step = state["step"] + 1
        if momentum:
            mu = jax.tree.map(
                lambda m, g, p: momentum * m
                + g.astype(jnp.float32)
                + weight_decay * p.astype(jnp.float32),
                state["mu"], grads, params,
            )
            newp = jax.tree.map(lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype), params, mu)
            return newp, {"step": step, "mu": mu}
        newp = jax.tree.map(
            lambda p, g: (
                p.astype(jnp.float32)
                - lr * (g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32))
            ).astype(p.dtype),
            params, grads,
        )
        return newp, {"step": step}

    def state_specs(pspecs, pshapes):
        st = {"step": P()}
        if momentum:
            st["mu"] = pspecs
        return st

    return Optimizer(init, update, state_specs)


# ---------------------------------------------------------------------------
# AdamW with fp32 master weights


def adamw(
    lr: float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    keep_master: bool = True,
) -> Optimizer:
    def init(params):
        st = {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }
        if keep_master:
            # explicit copy: when params are already f32 an astype would
            # alias the same buffer and break donation (donate-twice error)
            st["master"] = jax.tree.map(
                lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params
            )
        return st

    def update(grads, state, params):
        step = state["step"] + 1
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads
        )
        base = state["master"] if keep_master else jax.tree.map(lambda p: p.astype(jnp.float32), params)
        new_master = jax.tree.map(
            lambda b, mm, vv: b - lr * (mm / c1 / (jnp.sqrt(vv / c2) + eps) + weight_decay * b),
            base, m, v,
        )
        newp = jax.tree.map(lambda p, b: b.astype(p.dtype), params, new_master)
        newst = {"step": step, "m": m, "v": v}
        if keep_master:
            newst["master"] = new_master
        return newp, newst

    def state_specs(pspecs, pshapes):
        st = {"step": P(), "m": pspecs, "v": pspecs}
        if keep_master:
            st["master"] = pspecs
        return st

    return Optimizer(init, update, state_specs)


# ---------------------------------------------------------------------------
# Adafactor


def _factored_dims(shape, min_size):
    if len(shape) < 2:
        return None
    dims = sorted(range(len(shape)), key=lambda i: shape[i])[-2:]
    r, c = sorted(dims)
    if shape[r] < min_size or shape[c] < min_size:
        return None
    return r, c


def adafactor(
    lr: float = 1e-2,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    min_dim_size_to_factor: int = 128,
) -> Optimizer:
    def init(params):
        def per_param(p):
            f = _factored_dims(p.shape, min_dim_size_to_factor)
            if f is None:
                return {"v": jnp.zeros(p.shape, jnp.float32)}
            r, c = f
            vr = jnp.zeros(tuple(s for i, s in enumerate(p.shape) if i != c), jnp.float32)
            vc = jnp.zeros(tuple(s for i, s in enumerate(p.shape) if i != r), jnp.float32)
            return {"vr": vr, "vc": vc}

        return {"step": jnp.zeros((), jnp.int32), "v": jax.tree.map(per_param, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        beta = 1.0 - step.astype(jnp.float32) ** (-decay)

        def upd(p, g, v):
            g = g.astype(jnp.float32)
            f = _factored_dims(p.shape, min_dim_size_to_factor)
            g2 = jnp.square(g) + eps
            if f is None:
                vn = beta * v["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(vn, eps))
                v_new = {"v": vn}
            else:
                r, c = f  # r < c
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=c)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=r)
                v_new = {"vr": vr, "vc": vc}
                red = jnp.mean(vr, axis=r, keepdims=True)  # vr still has axis r at index r
                vr_n = vr / jnp.maximum(red, eps)
                vhat = jnp.expand_dims(vr_n, c) * jnp.expand_dims(vc, r)
                u = g * jax.lax.rsqrt(jnp.maximum(vhat, eps))
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            p32 = p.astype(jnp.float32)
            if weight_decay:
                u = u + weight_decay * p32
            return (p32 - lr * u).astype(p.dtype), v_new

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(*t) for t in zip(flat_p, flat_g, flat_v)]
        newp = jax.tree.unflatten(treedef, [o[0] for o in out])
        newv = jax.tree.unflatten(treedef, [o[1] for o in out])
        return newp, {"step": step, "v": newv}

    def state_specs(pspecs, pshapes):
        def per_param(spec, shp):
            f = _factored_dims(shp.shape, min_dim_size_to_factor)
            parts = list(spec) + [None] * (len(shp.shape) - len(spec))
            if f is None:
                return {"v": P(*parts)}
            r, c = f
            return {
                "vr": P(*(x for i, x in enumerate(parts) if i != c)),
                "vc": P(*(x for i, x in enumerate(parts) if i != r)),
            }

        v = jax.tree.map(per_param, pspecs, pshapes, is_leaf=lambda x: isinstance(x, P))
        return {"step": P(), "v": v}

    return Optimizer(init, update, state_specs)


def make_optimizer(cfg) -> Optimizer:
    if cfg.optimizer == "adamw":
        return adamw(cfg.learning_rate, weight_decay=cfg.weight_decay)
    if cfg.optimizer == "adafactor":
        return adafactor(lr=cfg.learning_rate, weight_decay=cfg.weight_decay)
    if cfg.optimizer == "sgd":
        return sgd(cfg.learning_rate, momentum=0.9, weight_decay=cfg.weight_decay)
    raise ValueError(cfg.optimizer)
