"""Encoder-decoder assembly (seamless-m4t backbone).

The modality frontend (speech feature extractor / unit tokenizer) is a
STUB per the assignment: ``input_specs()`` supplies precomputed frame
embeddings (B, S_src, d_model).  Encoder = bidirectional self-attn + FFN;
decoder = causal self-attn + cross-attn + FFN; both are scan-over-layers.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as attn
from . import nn
from .lm import lm_loss
from .nn import FSDP, TP, DP, dense_init, embed_init, rms_norm


def _init_ffn(key, cfg):
    ks = nn.split_keys(key, 3)
    dt = cfg.pdtype
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "wi": dense_init(ks[0], d, (ff,), dt),
        "wg": dense_init(ks[1], d, (ff,), dt),
        "wo": dense_init(ks[2], ff, (d,), dt),
    }


_FFN_SPECS = {"wi": P(FSDP, TP), "wg": P(FSDP, TP), "wo": P(TP, FSDP)}


def _init_enc_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "self": attn.init_gqa(k1, cfg),
        "norm2": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "ffn": _init_ffn(k2, cfg),
    }


def _init_dec_layer(key, cfg):
    k1, k2, k3 = nn.split_keys(key, 3)
    return {
        "norm1": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "self": attn.init_gqa(k1, cfg),
        "norm_x": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "cross": attn.init_cross_attn(k2, cfg),
        "norm2": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "ffn": _init_ffn(k3, cfg),
    }


def init_params(key, cfg) -> nn.Params:
    k_emb, k_head, k_enc, k_dec = nn.split_keys(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    return {
        "embed": embed_init(k_emb, cfg.padded_vocab, cfg.d_model, cfg.pdtype),
        "head": dense_init(k_head, cfg.d_model, (cfg.padded_vocab,), cfg.pdtype),
        "enc_norm": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "enc": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "dec": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
    }


def param_specs(cfg) -> nn.Specs:
    gs = attn.gqa_specs(cfg)

    def stack(tree):
        return jax.tree.map(lambda s: P(None, *s), tree, is_leaf=lambda x: isinstance(x, P))

    enc = stack({"norm1": P(None), "self": gs, "norm2": P(None), "ffn": _FFN_SPECS})
    dec = stack(
        {
            "norm1": P(None),
            "self": gs,
            "norm_x": P(None),
            "cross": attn.cross_attn_specs(cfg),
            "norm2": P(None),
            "ffn": _FFN_SPECS,
        }
    )
    return {
        "embed": P(TP, FSDP),
        "head": P(FSDP, TP),
        "enc_norm": P(None),
        "final_norm": P(None),
        "enc": enc,
        "dec": dec,
    }


def _mask_pad_vocab(cfg, logits):
    if cfg.padded_vocab != cfg.vocab_size:
        vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(vmask[None, None, :], logits, -1e9)
    return logits


def encode(params, cfg, src_embeds):
    x = src_embeds.astype(cfg.jdtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, lp):
        h = rms_norm(x, lp["norm1"])
        out, _ = attn.gqa_forward(lp["self"], cfg, h, positions=positions, mode="train", causal=False)
        x = x + out
        h = rms_norm(x, lp["norm2"])
        x = x + nn.swiglu(h, lp["ffn"]["wi"], lp["ffn"]["wg"], lp["ffn"]["wo"])
        return nn.constrain(x, ("dp", "sp", None)), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return rms_norm(x, params["enc_norm"])


def _dec_body(cfg, mode, enc_out):
    def body(carry, xs):
        x, positions, cache_index = carry
        lp = xs["params"]
        c = xs.get("cache")
        h = rms_norm(x, lp["norm1"])
        out, self_c = attn.gqa_forward(
            lp["self"], cfg, h, positions=positions, mode=mode,
            cache=c["self"] if c else None, cache_index=cache_index,
        )
        x = x + out
        h = rms_norm(x, lp["norm_x"])
        out, cross_c = attn.cross_attn_forward(
            lp["cross"], cfg, h,
            enc_kv=c["cross"] if (c and mode == "decode") else None,
            enc_out=enc_out,
        )
        x = x + out
        h = rms_norm(x, lp["norm2"])
        x = x + nn.swiglu(h, lp["ffn"]["wi"], lp["ffn"]["wg"], lp["ffn"]["wo"])
        x = nn.constrain(x, ("dp", "sp", None))
        new_c = None
        if mode in ("prefill", "decode"):
            new_c = {"self": self_c, "cross": cross_c}
        return (x, positions, cache_index), new_c

    return body


def decode_stack(params, cfg, tgt_x, *, mode, enc_out=None, cache=None, cache_index=None):
    B, S = tgt_x.shape[0], tgt_x.shape[1]
    if mode == "decode":
        positions = jnp.full((B, 1), cache_index, jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    body = _dec_body(cfg, mode, enc_out)
    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    xs = {"params": params["dec"]}
    if cache is not None:
        xs["cache"] = cache
    ci = cache_index if cache_index is not None else jnp.zeros((), jnp.int32)
    (x, _, _), caches = jax.lax.scan(body, (tgt_x, positions, ci), xs)
    return x, caches


def forward_train(params, cfg, batch):
    """batch: {'embeds': (B,S_src,d), 'tokens': (B,S_tgt), 'labels': (B,S_tgt)}."""
    enc_out = encode(params, cfg, batch["embeds"])
    tgt = params["embed"].astype(cfg.jdtype)[batch["tokens"]]
    tgt = nn.constrain(tgt, ("dp", None, None))
    x, _ = decode_stack(params, cfg, tgt, mode="train", enc_out=enc_out)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(cfg.jdtype))
    logits = _mask_pad_vocab(cfg, logits)
    logits = nn.constrain(logits, ("dp", None, "tp"))
    loss = lm_loss(logits, batch["labels"])
    return loss, (loss, jnp.zeros((), jnp.float32))


def prefill(params, cfg, batch):
    """Returns (cache, last_logits)."""
    enc_out = encode(params, cfg, batch["embeds"])
    tgt = params["embed"].astype(cfg.jdtype)[batch["tokens"]]
    x, caches = decode_stack(params, cfg, tgt, mode="prefill", enc_out=enc_out)
    x = rms_norm(x[:, -1:], params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(cfg.jdtype)).astype(jnp.float32)
    return caches, _mask_pad_vocab(cfg, logits)


def decode_step(params, cfg, cache, token, cache_index):
    tgt = params["embed"].astype(cfg.jdtype)[token]  # (B,1,d)
    x, new_cache = decode_stack(params, cfg, tgt, mode="decode", cache=cache, cache_index=cache_index)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(cfg.jdtype)).astype(jnp.float32)
    return new_cache, _mask_pad_vocab(cfg, logits)


def cache_shapes(cfg, batch: int, self_len: int, src_len: int):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    nl = cfg.num_layers

    def sd(shape):
        return jax.ShapeDtypeStruct(shape, cfg.jdtype)

    shp = {
        "self": {"k": sd((nl, batch, self_len, kv, hd)), "v": sd((nl, batch, self_len, kv, hd))},
        "cross": {"k": sd((nl, batch, src_len, kv, hd)), "v": sd((nl, batch, src_len, kv, hd))},
    }
    spec_kv = P(None, DP, TP, None, None)
    spec = {"self": {"k": spec_kv, "v": spec_kv}, "cross": {"k": spec_kv, "v": spec_kv}}
    return shp, spec


def init_cache(cfg, batch: int, self_len: int, src_len: int):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    nl = cfg.num_layers
    z = lambda s: jnp.zeros((nl, batch) + s, cfg.jdtype)
    return {
        "self": {"k": z((self_len, kv, hd)), "v": z((self_len, kv, hd))},
        "cross": {"k": z((src_len, kv, hd)), "v": z((src_len, kv, hd))},
    }
