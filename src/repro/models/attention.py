"""Attention: chunked-causal (flash-style) GQA and MLA, with KV caches.

TPU adaptation notes (DESIGN.md §2):
  - training/prefill use a chunked online-softmax loop (lax.scan over KV
    chunks inside a scan over Q chunks) so the S x S score matrix is never
    materialized — required at 32k prefill, and the memory-safe default at
    4k given the per-chip batch sizes;
  - decode uses plain attention math over the cache with the *sequence*
    dim of the cache sharded over the `model` mesh axis (context-parallel
    decode). GSPMD turns the softmax max/sum and the PV contraction into
    small all-reduces — the flash-decoding pattern without shard_map;
  - MLA decode uses the absorbed formulation: scores and outputs live in
    the kv_lora latent space, the cache stays (S, lora+rope).
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import nn
from .nn import FSDP, TP, DP, apply_rope, dense_init, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunked flash-style attention core


def _chunk(x, size, axis):
    s = x.shape[axis]
    n = s // size
    new = x.shape[:axis] + (n, size) + x.shape[axis + 1 :]
    return x.reshape(new)


def _mask_chunk(q_pos, k_pos, causal, window):
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    return mask


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, q_offset, chunk_q, chunk_kv, window, scale):
    """Flash attention core with a flash backward (custom VJP) so autodiff
    never stores per-chunk score matrices — forward residuals are just
    (q, k, v, out, lse)."""
    out, _ = _flash_fwd_impl(q, k, v, causal, q_offset, chunk_q, chunk_kv, window, scale)
    return out


def _flash_fwd_impl(q, k, v, causal, q_offset, chunk_q, chunk_kv, window, scale):
    nq, B, Cq, KV, G, Dk = q.shape[0], *q.shape[1:3], q.shape[3], q.shape[4], q.shape[5]
    nk, Ck, Dv = k.shape[0], k.shape[2], v.shape[-1]
    q_pos_base = jnp.asarray(q_offset, jnp.int32)

    def q_chunk_body(iq, q_i):
        q_pos = q_pos_base + iq * Cq + jnp.arange(Cq, dtype=jnp.int32)

        def kv_body(carry, inputs):
            acc, m, l = carry
            ik, k_j, v_j = inputs
            k_pos = ik * Ck + jnp.arange(Ck, dtype=jnp.int32)
            s = jnp.einsum("bqkgd,bckd->bqkgc", q_i, k_j, preferred_element_type=jnp.float32)
            s = s * scale
            mask = _mask_chunk(q_pos, k_pos, causal, window)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(v_j.dtype), v_j, preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, Cq, KV, G, Dv), jnp.float32)
        m0 = jnp.full((B, Cq, KV, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Cq, KV, G), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_body, (acc0, m0, l0), (jnp.arange(nk, dtype=jnp.int32), k, v)
        )
        l_safe = jnp.maximum(l, 1e-30)
        out = (acc / l_safe[..., None]).astype(q.dtype)
        lse = m + jnp.log(l_safe)
        return out, lse

    out, lse = jax.lax.map(
        lambda args: q_chunk_body(*args), (jnp.arange(nq, dtype=jnp.int32), q)
    )  # out: (nq,B,Cq,KV,G,Dv); lse: (nq,B,Cq,KV,G)
    return out, lse


def _flash_fwd(q, k, v, causal, q_offset, chunk_q, chunk_kv, window, scale):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_offset, chunk_q, chunk_kv, window, scale)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_offset, chunk_q, chunk_kv, window, scale, res, dout):
    q, k, v, out, lse = res
    nq, B, Cq, KV, G, Dk = q.shape[0], *q.shape[1:3], q.shape[3], q.shape[4], q.shape[5]
    nk, Ck, Dv = k.shape[0], k.shape[2], v.shape[-1]
    q_pos_base = jnp.asarray(q_offset, jnp.int32)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)  # (nq,B,Cq,KV,G)

    def q_chunk_body(carry, inputs):
        dk_acc, dv_acc = carry
        iq, q_i, do_i, lse_i, delta_i = inputs
        q_pos = q_pos_base + iq * Cq + jnp.arange(Cq, dtype=jnp.int32)
        do_f = do_i.astype(jnp.float32)

        def kv_body(dq_i, inputs):
            ik, k_j, v_j = inputs
            k_pos = ik * Ck + jnp.arange(Ck, dtype=jnp.int32)
            s = jnp.einsum("bqkgd,bckd->bqkgc", q_i, k_j, preferred_element_type=jnp.float32) * scale
            mask = _mask_chunk(q_pos, k_pos, causal, window)
            p = jnp.where(mask[None, :, None, None, :], jnp.exp(s - lse_i[..., None]), 0.0)
            dv_j = jnp.einsum("bqkgc,bqkgd->bckd", p, do_f)
            dp = jnp.einsum("bqkgd,bckd->bqkgc", do_f, v_j.astype(jnp.float32))
            ds = p * (dp - delta_i[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bqkgc,bckd->bqkgd", ds, k_j.astype(jnp.float32))
            dk_j = jnp.einsum("bqkgc,bqkgd->bckd", ds, q_i.astype(jnp.float32))
            return dq_i, (dk_j, dv_j)

        dq0 = jnp.zeros((B, Cq, KV, G, Dk), jnp.float32)
        dq_i, (dk_js, dv_js) = jax.lax.scan(
            kv_body, dq0, (jnp.arange(nk, dtype=jnp.int32), k, v)
        )
        return (dk_acc + dk_js, dv_acc + dv_js), dq_i

    dk0 = jnp.zeros((nk, B, Ck, KV, Dk), jnp.float32)
    dv0 = jnp.zeros((nk, B, Ck, KV, Dv), jnp.float32)
    (dk, dv), dq = jax.lax.scan(
        q_chunk_body,
        (dk0, dv0),
        (jnp.arange(nq, dtype=jnp.int32), q, dout, lse, delta),
    )
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def chunked_attention(
    q: jax.Array,  # (B, Sq, H, Dk)
    k: jax.Array,  # (B, Skv, KV, Dk)
    v: jax.Array,  # (B, Skv, KV, Dv)
    *,
    causal: bool = True,
    q_offset: int = 0,
    chunk_q: int = 512,
    chunk_kv: int = 512,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Memory-efficient attention; returns (B, Sq, H, Dv).

    ``q_offset`` is the absolute position of q[0] (static int, for prefill
    continuation); GQA group structure is inferred from H // KV.
    """
    B, Sq, H, Dk = q.shape
    Skv, KV, Dv = k.shape[1], k.shape[2], v.shape[-1]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(Dk)

    chunk_q = min(chunk_q, Sq)
    chunk_kv = min(chunk_kv, Skv)
    nq, nk = Sq // chunk_q, Skv // chunk_kv
    assert nq * chunk_q == Sq and nk * chunk_kv == Skv, (Sq, Skv, chunk_q, chunk_kv)

    qc = _chunk(q, chunk_q, 1).transpose(1, 0, 2, 3, 4).reshape(nq, B, chunk_q, KV, G, Dk)
    kc = _chunk(k, chunk_kv, 1).transpose(1, 0, 2, 3, 4)  # (nk, B, Ck, KV, Dk)
    vc = _chunk(v, chunk_kv, 1).transpose(1, 0, 2, 3, 4)  # (nk, B, Ck, KV, Dv)

    out = _flash(qc, kc, vc, causal, int(q_offset), chunk_q, chunk_kv, window, scale)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, Dv)
    return out


def decode_attention(
    q: jax.Array,  # (B, 1, H, Dk)
    k_cache: jax.Array,  # (B, S, KV, Dk)
    v_cache: jax.Array,  # (B, S, KV, Dv)
    length_mask: jax.Array,  # (B, S) bool — True for valid positions
    *,
    scale: float | None = None,
) -> jax.Array:
    """Single-token attention over a (possibly seq-sharded) cache."""
    B, _, H, Dk = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(Dk)
    qg = q.reshape(B, KV, G, Dk)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32) * scale
    s = jnp.where(length_mask[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(l, 1e-30)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache, preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, -1).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block


def padded_heads(cfg) -> tuple[int, int]:
    """(H_padded, G_padded): query heads padded per KV group to a multiple
    of ``tp_pad_multiple`` so the head dim shards evenly on the model axis
    (llava's H=56 on a 16-way axis; padded heads are masked out of the
    output projection, so the math matches the unpadded model exactly)."""
    H, KV = cfg.num_heads, cfg.num_kv_heads
    mult = getattr(cfg, "tp_pad_multiple", 1)
    G = H // KV
    if mult <= 1 or (H % mult == 0 and G >= 1):
        return H, G
    G_pad = G
    while (KV * G_pad) % mult:
        G_pad += 1
    return KV * G_pad, G_pad


def head_mask(cfg) -> jax.Array | None:
    H_pad, G_pad = padded_heads(cfg)
    if H_pad == cfg.num_heads:
        return None
    G = cfg.num_heads // cfg.num_kv_heads
    m = (jnp.arange(G_pad) < G).astype(jnp.float32)  # (G_pad,)
    return jnp.tile(m, cfg.num_kv_heads)  # (H_pad,) kv-major head order


def init_gqa(key, cfg) -> nn.Params:
    d, KV, hd = cfg.d_model, cfg.num_kv_heads, cfg.head_dim
    H_pad, _ = padded_heads(cfg)
    ks = nn.split_keys(key, 4)
    dt = cfg.pdtype
    p = {
        "wq": dense_init(ks[0], d, (H_pad * hd,), dt).reshape(d, H_pad, hd),
        "wk": dense_init(ks[1], d, (KV * hd,), dt).reshape(d, KV, hd),
        "wv": dense_init(ks[2], d, (KV * hd,), dt).reshape(d, KV, hd),
        "wo": dense_init(ks[3], H_pad * hd, (d,), dt).reshape(H_pad, hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


def gqa_specs(cfg) -> nn.Specs:
    kv_shardable = (cfg.num_kv_heads * cfg.head_dim) % 16 == 0  # conservative: shard flat kv dim
    s = {
        "wq": P(FSDP, TP, None),
        "wk": P(FSDP, TP if cfg.num_kv_heads % 8 == 0 else None, None),
        "wv": P(FSDP, TP if cfg.num_kv_heads % 8 == 0 else None, None),
        "wo": P(TP, None, FSDP),
    }
    del kv_shardable
    if cfg.qk_norm:
        s["q_norm"] = P(None)
        s["k_norm"] = P(None)
    return s


def gqa_forward(p, cfg, x, *, positions, mode, cache=None, cache_index=None, causal=True):
    """mode: 'train'/'prefill' (full seq) or 'decode' (one token).

    Returns (out, new_cache) — new_cache is None in train mode.
    """
    B, S, d = x.shape
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    H, G = padded_heads(cfg)
    hmask = head_mask(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # Megatron-SP: gather the (sp-sharded) seq dim here; shard heads over tp
    # (without this GSPMD keeps seq sharded and replicates all heads — a
    # measured 16x attention-FLOP inflation)
    q = nn.constrain(q, ("dp", None, "tp", None))

    if mode in ("train", "prefill"):
        # repeat kv heads to full H: keeps the head dim shardable by the
        # 16-way model axis (a (KV, G) reshape of the sharded H dim forces
        # GSPMD reshards inside the flash loops — measured 5.9 GB/dev of
        # spurious per-layer all-reduce on tinyllama)
        k_full = jnp.repeat(k, G, axis=2) if G > 1 else k
        v_full = jnp.repeat(v, G, axis=2) if G > 1 else v
        k_full = nn.constrain(k_full, ("dp", None, "tp", None))
        v_full = nn.constrain(v_full, ("dp", None, "tp", None))
        out = chunked_attention(
            q, k_full, v_full, causal=causal, chunk_q=cfg.attn_chunk,
            chunk_kv=cfg.attn_chunk, window=cfg.window,
        )
        out = nn.constrain(out, ("dp", None, "tp", None))
        new_cache = {"k": k, "v": v} if mode == "prefill" else None
    elif mode == "decode":
        # write new kv at cache_index, attend over valid positions
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
        Smax = k_cache.shape[1]
        pos_ids = jnp.arange(Smax, dtype=jnp.int32)
        mask = (pos_ids[None, :] <= cache_index)
        if cfg.window is not None:
            mask &= pos_ids[None, :] > cache_index - cfg.window
        mask = jnp.broadcast_to(mask, (B, Smax))
        out = decode_attention(q, k_cache, v_cache, mask)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        raise ValueError(mode)

    if hmask is not None:  # zero the padded query heads (exact-math padding)
        out = out * hmask[None, None, :, None].astype(out.dtype)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, new_cache


def gqa_cache_shape(cfg, batch: int, max_len: int):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shp = jax.ShapeDtypeStruct((batch, max_len, kv, hd), cfg.jdtype)
    spec = P(DP, TP, None, None)  # sequence-sharded over model (context parallel)
    return {"k": shp, "v": shp}, {"k": spec, "v": spec}


def gqa_init_cache(cfg, batch: int, max_len: int):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    z = jnp.zeros((batch, max_len, kv, hd), cfg.jdtype)
    return {"k": z, "v": z}


# ---------------------------------------------------------------------------
# MLA block (deepseek-v2 style)


def init_mla(key, cfg) -> nn.Params:
    d = cfg.d_model
    H = cfg.num_heads
    lora, rope_d = cfg.kv_lora_rank, cfg.qk_rope_dim
    nope_d, v_d = cfg.head_dim, cfg.head_dim  # qk_nope dim == v dim == head_dim (128)
    qd = nope_d + rope_d
    ks = nn.split_keys(key, 5)
    dt = cfg.pdtype
    return {
        "wq": dense_init(ks[0], d, (H * qd,), dt).reshape(d, H, qd),
        "w_dkv": dense_init(ks[1], d, (lora + rope_d,), dt),
        "kv_norm": jnp.zeros((lora,), dt),
        "w_uk": dense_init(ks[2], lora, (H * nope_d,), dt).reshape(lora, H, nope_d),
        "w_uv": dense_init(ks[3], lora, (H * v_d,), dt).reshape(lora, H, v_d),
        "wo": dense_init(ks[4], H * v_d, (d,), dt).reshape(H, v_d, d),
    }


def mla_specs(cfg) -> nn.Specs:
    return {
        "wq": P(FSDP, TP, None),
        "w_dkv": P(FSDP, None),
        "kv_norm": P(None),
        "w_uk": P(None, TP, None),
        "w_uv": P(None, TP, None),
        "wo": P(TP, None, FSDP),
    }


def _mla_qc(p, cfg, x, positions):
    H = cfg.num_heads
    lora, rope_d, nope_d = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :nope_d], q[..., nope_d:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c = jnp.einsum("bsd,dk->bsk", x, p["w_dkv"].astype(x.dtype))
    c_kv = rms_norm(c[..., :lora], p["kv_norm"])
    k_rope = apply_rope(c[..., None, lora:], positions, cfg.rope_theta)[:, :, 0]  # (B,S,rope)
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(p, cfg, x, *, positions, mode, cache=None, cache_index=None):
    B, S, d = x.shape
    H = cfg.num_heads
    lora, rope_d, nope_d = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.head_dim
    scale = 1.0 / math.sqrt(nope_d + rope_d)
    q_nope, q_rope, c_kv, k_rope = _mla_qc(p, cfg, x, positions)

    if mode in ("train", "prefill"):
        # naive (up-projected) attention — compute-bound path, MXU friendly
        k_nope = jnp.einsum("bsk,khd->bshd", c_kv, p["w_uk"].astype(x.dtype))
        v = jnp.einsum("bsk,khd->bshd", c_kv, p["w_uv"].astype(x.dtype))
        k_nope = nn.constrain(k_nope, ("dp", None, "tp", None))
        v = nn.constrain(v, ("dp", None, "tp", None))
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rope_d))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        q = nn.constrain(q, ("dp", None, "tp", None))
        out = chunked_attention(
            q, k, v, causal=True, chunk_q=cfg.attn_chunk, chunk_kv=cfg.attn_chunk,
            scale=scale,
        )
        new_cache = {"c_kv": c_kv, "k_rope": k_rope} if mode == "prefill" else None
    elif mode == "decode":
        c_cache = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, cache_index, 0))
        r_cache = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, cache_index, 0))
        Smax = c_cache.shape[1]
        # absorbed: q_abs (B,H,lora) = q_nope @ w_uk
        q_abs = jnp.einsum("bthd,lhd->bthl", q_nope, p["w_uk"].astype(x.dtype))[:, 0]
        s = jnp.einsum("bhl,bsl->bhs", q_abs, c_cache, preferred_element_type=jnp.float32)
        s += jnp.einsum("bthr,bsr->bhs", q_rope, r_cache, preferred_element_type=jnp.float32)
        s *= scale
        mask = jnp.arange(Smax, dtype=jnp.int32)[None, :] <= cache_index
        s = jnp.where(mask[:, None, :], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhs,bsl->bhl", a.astype(c_cache.dtype), c_cache, preferred_element_type=jnp.float32)
        out = jnp.einsum("bhl,lhd->bhd", o_lat.astype(x.dtype), p["w_uv"].astype(x.dtype))[:, None]
        new_cache = {"c_kv": c_cache, "k_rope": r_cache}
    else:
        raise ValueError(mode)

    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, new_cache


def mla_cache_shape(cfg, batch: int, max_len: int):
    lora, rope_d = cfg.kv_lora_rank, cfg.qk_rope_dim
    return (
        {
            "c_kv": jax.ShapeDtypeStruct((batch, max_len, lora), cfg.jdtype),
            "k_rope": jax.ShapeDtypeStruct((batch, max_len, rope_d), cfg.jdtype),
        },
        {"c_kv": P(DP, TP, None), "k_rope": P(DP, TP, None)},
    )


def mla_init_cache(cfg, batch: int, max_len: int):
    lora, rope_d = cfg.kv_lora_rank, cfg.qk_rope_dim
    return {
        "c_kv": jnp.zeros((batch, max_len, lora), cfg.jdtype),
        "k_rope": jnp.zeros((batch, max_len, rope_d), cfg.jdtype),
    }


# ---------------------------------------------------------------------------
# cross attention (enc-dec)


def init_cross_attn(key, cfg) -> nn.Params:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = nn.split_keys(key, 4)
    dt = cfg.pdtype
    return {
        "wq": dense_init(ks[0], d, (H * hd,), dt).reshape(d, H, hd),
        "wk": dense_init(ks[1], d, (KV * hd,), dt).reshape(d, KV, hd),
        "wv": dense_init(ks[2], d, (KV * hd,), dt).reshape(d, KV, hd),
        "wo": dense_init(ks[3], H * hd, (d,), dt).reshape(H, hd, d),
    }


cross_attn_specs = gqa_specs  # same shapes/sharding (qk_norm absent)


def cross_attn_forward(p, cfg, x, *, enc_kv=None, enc_out=None, src_mask=None):
    """enc_kv: precomputed {'k','v'} (B, S_src, KV, hd); else computed from enc_out."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if enc_kv is None:
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(x.dtype))
    else:
        k, v = enc_kv["k"], enc_kv["v"]
    if x.shape[1] == 1:
        mask = jnp.ones((x.shape[0], k.shape[1]), bool) if src_mask is None else src_mask
        out = decode_attention(q, k, v, mask)
    else:
        out = chunked_attention(q, k, v, causal=False, chunk_q=cfg.attn_chunk, chunk_kv=cfg.attn_chunk)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, {"k": k, "v": v}
