"""RWKV6 ("Finch") time-mix + channel-mix, TPU-adapted.

The reference CUDA wkv6 kernel is token-sequential; here the recurrence is
reformulated as chunked matmuls: within a chunk of C tokens the pairwise
decay factors exp(cum_{i-1} - cum_j) (always <= 1, so overflow-safe) are
materialized as a (C, C, head_dim) tensor and contracted on the MXU;
across chunks only the (B, H, K, V) state is carried.  Data-dependent decay
(the Finch hallmark) is kept: w_t = exp(-exp(w0 + tanh(x W_a) W_b)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import nn
from .nn import FSDP, TP, dense_init

HEAD_DIM = 64
DECAY_LORA = 64


def num_heads(cfg) -> int:
    return cfg.d_model // HEAD_DIM


def init_time_mix(key, cfg) -> nn.Params:
    d = cfg.d_model
    ks = nn.split_keys(key, 8)
    dt = cfg.pdtype
    return {
        "mix_r": jnp.full((d,), 0.5, dt),
        "mix_k": jnp.full((d,), 0.5, dt),
        "mix_v": jnp.full((d,), 0.5, dt),
        "mix_w": jnp.full((d,), 0.5, dt),
        "mix_g": jnp.full((d,), 0.5, dt),
        "w_r": dense_init(ks[0], d, (d,), dt),
        "w_k": dense_init(ks[1], d, (d,), dt),
        "w_v": dense_init(ks[2], d, (d,), dt),
        "w_g": dense_init(ks[3], d, (d,), dt),
        "w_o": dense_init(ks[4], d, (d,), dt),
        # data-dependent decay LoRA
        "decay_a": dense_init(ks[5], d, (DECAY_LORA,), dt),
        "decay_b": dense_init(ks[6], DECAY_LORA, (d,), dt),
        "decay_0": jnp.full((d,), -6.0, jnp.float32),
        "bonus_u": jnp.zeros((d,), jnp.float32),
        "ln_scale": jnp.ones((d,), dt),
        "ln_bias": jnp.zeros((d,), dt),
    }


def time_mix_specs(cfg) -> nn.Specs:
    mat = P(FSDP, TP)
    vec = P(None)
    return {
        "mix_r": vec, "mix_k": vec, "mix_v": vec, "mix_w": vec, "mix_g": vec,
        "w_r": mat, "w_k": mat, "w_v": mat, "w_g": mat,
        "w_o": P(TP, FSDP),
        "decay_a": P(FSDP, None), "decay_b": P(None, TP),
        "decay_0": vec, "bonus_u": vec, "ln_scale": vec, "ln_bias": vec,
    }


def init_channel_mix(key, cfg) -> nn.Params:
    d, dff = cfg.d_model, cfg.d_ff
    ks = nn.split_keys(key, 3)
    dt = cfg.pdtype
    return {
        "mix_k": jnp.full((d,), 0.5, dt),
        "mix_r": jnp.full((d,), 0.5, dt),
        "w_k": dense_init(ks[0], d, (dff,), dt),
        "w_v": dense_init(ks[1], dff, (d,), dt),
        "w_r": dense_init(ks[2], d, (d,), dt),
    }


def channel_mix_specs(cfg) -> nn.Specs:
    return {
        "mix_k": P(None), "mix_r": P(None),
        "w_k": P(FSDP, TP), "w_v": P(TP, FSDP), "w_r": P(FSDP, TP),
    }


def _shift(x, prev):
    """Token shift: concat prev token state then drop last. x: (B,S,d), prev: (B,d)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix(x, xs, m):
    return x + (xs - x) * m.astype(x.dtype)


def _wkv_chunk(r, k, v, lw, u, state):
    """One chunk of the wkv recurrence.

    r,k,v: (B,C,H,hd); lw: (B,C,H,hd) log-decay (<=0, f32); u: (H,hd) bonus;
    state: (B,H,hd,hd) f32 — state[b,h,c_k,c_v] = sum_j k_j[c_k] D_j v_j[c_v].
    Returns (out (B,C,H,hd), new_state).
    """
    B, C, H, hd = r.shape
    rf, kf, vf = r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    cum = jnp.cumsum(lw, axis=1)  # inclusive
    cum_prev = cum - lw  # exclusive (cum_{i-1})

    # inter-chunk: o_i += (r_i * exp(cum_prev_i)) @ state
    r_dec = rf * jnp.exp(cum_prev)
    o_inter = jnp.einsum("bchk,bhkv->bchv", r_dec, state)

    # intra-chunk: A_ij = sum_c r_i[c] k_j[c] exp(cum_prev_i[c]-cum_j[c]) (j<i)
    #              A_ii = sum_c r_i[c] k_j[c] u[c]
    E = jnp.exp(
        jnp.clip(cum_prev[:, :, None] - cum[:, None, :], -60.0, 0.0)
    )  # (B,C,C,H,hd), <=1
    tri = (jnp.arange(C)[:, None] > jnp.arange(C)[None, :]).astype(jnp.float32)
    A = jnp.einsum("bihc,bjhc,bijhc->bhij", rf, kf, E) * tri[None, None]
    diag = jnp.einsum("bihc,bihc,hc->bhi", rf, kf, u)
    A = A + jnp.eye(C, dtype=jnp.float32)[None, None] * diag[..., None]
    o_intra = jnp.einsum("bhij,bjhv->bihv", A, vf)

    # state update: S' = exp(cum_C) * S + sum_j (k_j * exp(cum_C - cum_j)) v_j^T
    cum_all = cum[:, -1]  # (B,H,hd)
    k_dec = kf * jnp.exp(jnp.clip(cum_all[:, None] - cum, -60.0, 0.0))
    state_new = jnp.exp(cum_all)[..., None] * state + jnp.einsum("bchk,bchv->bhkv", k_dec, vf)

    out = (o_inter + o_intra).astype(r.dtype)
    return out, state_new


def time_mix_forward(p, cfg, x, *, mode, cache=None):
    """x: (B,S,d). cache: {'shift': (B,d), 'state': (B,H,hd,hd)}."""
    B, S, d = x.shape
    H = num_heads(cfg)
    prev = cache["shift"] if cache is not None else jnp.zeros((B, d), x.dtype)
    xs = _shift(x, prev) if S > 1 else prev[:, None, :]

    xr = _mix(x, xs, p["mix_r"]) ; xk = _mix(x, xs, p["mix_k"])
    xv = _mix(x, xs, p["mix_v"]) ; xw = _mix(x, xs, p["mix_w"])
    xg = _mix(x, xs, p["mix_g"])

    r = nn.constrain(jnp.einsum("bsd,de->bse", xr, p["w_r"].astype(x.dtype)), ("dp", None, "tp"))
    k = nn.constrain(jnp.einsum("bsd,de->bse", xk, p["w_k"].astype(x.dtype)), ("dp", None, "tp"))
    v = nn.constrain(jnp.einsum("bsd,de->bse", xv, p["w_v"].astype(x.dtype)), ("dp", None, "tp"))
    g = jax.nn.silu(nn.constrain(jnp.einsum("bsd,de->bse", xg, p["w_g"].astype(x.dtype)), ("dp", None, "tp")))

    # data-dependent decay (Finch): lw = -exp(w0 + tanh(xw A) B)  (log w, <= 0)
    dec = jnp.einsum(
        "bsl,ld->bsd",
        jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, p["decay_a"].astype(x.dtype))),
        p["decay_b"].astype(x.dtype),
    ).astype(jnp.float32)
    lw = -jnp.exp(p["decay_0"][None, None] + dec)  # (B,S,d) f32, <= 0

    def heads(t):
        return t.reshape(B, S, H, HEAD_DIM)

    r, k, v, lw = heads(r), heads(k), heads(v), heads(lw)
    u = p["bonus_u"].reshape(H, HEAD_DIM).astype(jnp.float32)

    state0 = (
        cache["state"].astype(jnp.float32)
        if cache is not None
        else jnp.zeros((B, H, HEAD_DIM, HEAD_DIM), jnp.float32)
    )

    if mode == "decode":
        # exact single-step recurrence
        rf, kf, vf = (t[:, 0].astype(jnp.float32) for t in (r, k, v))
        w1 = jnp.exp(lw[:, 0])  # (B,H,hd)
        kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
        o = jnp.einsum("bhk,bhkv->bhv", rf, state0 + u[None, :, :, None] * kv)
        state_new = w1[..., None] * state0 + kv
        out = o.reshape(B, 1, d).astype(x.dtype)
    else:
        import math as _math
        chunk = min(cfg.ssm_chunk, S)
        if S % chunk:
            chunk = _math.gcd(S, chunk)
        nck = S // chunk

        def rs(t):
            return t.reshape(B, nck, chunk, *t.shape[2:]).swapaxes(0, 1)

        def body(st, inp):
            r_i, k_i, v_i, lw_i = inp
            o, st2 = _wkv_chunk(r_i, k_i, v_i, lw_i, u, st)
            return st2, o

        # remat: never store the (B,C,C,H,hd) intra-chunk decay tensor
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        state_new, outs = jax.lax.scan(body, state0, (rs(r), rs(k), rs(v), rs(lw)))
        out = outs.swapaxes(0, 1).reshape(B, S, d)

    out = nn.group_norm(out, p["ln_scale"], p["ln_bias"], groups=H)
    out = out * g
    out = jnp.einsum("bsd,de->bse", out, p["w_o"].astype(x.dtype))
    new_cache = {"shift": x[:, -1, :], "state": state_new}
    return out, new_cache


def channel_mix_forward(p, cfg, x, *, mode, cache=None):
    B, S, d = x.shape
    prev = cache["shift"] if cache is not None else jnp.zeros((B, d), x.dtype)
    xs = _shift(x, prev) if S > 1 else prev[:, None, :]
    xk = _mix(x, xs, p["mix_k"])
    xr = _mix(x, xs, p["mix_r"])
    k = jnp.einsum("bsd,df->bsf", xk, p["w_k"].astype(x.dtype))
    k = nn.constrain(k, ("dp", None, "tp"))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["w_v"].astype(x.dtype))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_r"].astype(x.dtype)))
    out = rr * kv
    return out, {"shift": x[:, -1, :]}


def rwkv_cache_shape(cfg, batch: int, max_len: int):
    d, H = cfg.d_model, num_heads(cfg)
    del max_len
    shapes = {
        "tm": {
            "shift": jax.ShapeDtypeStruct((batch, d), cfg.jdtype),
            "state": jax.ShapeDtypeStruct((batch, H, HEAD_DIM, HEAD_DIM), jnp.float32),
        },
        "cm": {"shift": jax.ShapeDtypeStruct((batch, d), cfg.jdtype)},
    }
    specs = {
        "tm": {"shift": P(nn.DP, None), "state": P(nn.DP, TP, None, None)},
        "cm": {"shift": P(nn.DP, None)},
    }
    return shapes, specs


def rwkv_init_cache(cfg, batch: int, max_len: int):
    d, H = cfg.d_model, num_heads(cfg)
    del max_len
    return {
        "tm": {
            "shift": jnp.zeros((batch, d), cfg.jdtype),
            "state": jnp.zeros((batch, H, HEAD_DIM, HEAD_DIM), jnp.float32),
        },
        "cm": {"shift": jnp.zeros((batch, d), cfg.jdtype)},
    }
