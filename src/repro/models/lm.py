"""Decoder-LM assembly: heterogeneous layer patterns, scan-over-blocks, loss.

A model is a repeated *pattern block* of layers (e.g. jamba: 1 attention +
7 mamba layers, MoE on every 2nd FFN).  Per-pattern-position params are
stacked over the number of blocks and the stack is consumed by
``lax.scan`` (compile-time O(1) in depth; FSDP all-gathers happen per
block inside the scan).  ``first_dense`` leading layers (deepseek-v2's
dense layer 0) live outside the scan.
"""
from __future__ import annotations

import functools
from typing import Any

from jax.ad_checkpoint import checkpoint_name as _ckpt_name

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as attn
from . import moe as moe_mod
from . import nn
from . import rwkv as rwkv_mod
from . import ssm as ssm_mod
from .nn import FSDP, TP, DP, dense_init, embed_init, rms_norm


# ---------------------------------------------------------------------------
# layer pattern


def layer_pattern(cfg) -> list[tuple[str, str]]:
    """Pattern of (mixer, ffn) for one scan block (excludes first_dense)."""
    if cfg.ssm_kind == "rwkv6":
        return [("rwkv", "rwkv_cm")]
    n = cfg.attn_every if cfg.attn_every > 1 else 1
    if cfg.moe_num_experts and cfg.moe_every > 1:
        n = max(n, cfg.moe_every)
    pat = []
    for i in range(n):
        if cfg.ssm_kind == "mamba" and cfg.attn_every > 1:
            mixer = "attn" if i % cfg.attn_every == 0 else "mamba"
        elif cfg.attn_impl == "mla":
            mixer = "mla"
        else:
            mixer = "attn"
        if cfg.moe_num_experts:
            ffn = "moe" if (i % cfg.moe_every == cfg.moe_every - 1 or cfg.moe_every == 1) else "dense"
        else:
            ffn = "dense"
        pat.append((mixer, ffn))
    return pat


def num_blocks(cfg) -> int:
    pat = layer_pattern(cfg)
    n = (cfg.num_layers - cfg.first_dense) // len(pat)
    assert n * len(pat) + cfg.first_dense == cfg.num_layers, (
        cfg.num_layers,
        cfg.first_dense,
        len(pat),
    )
    return n


# ---------------------------------------------------------------------------
# single layer (one (mixer, ffn) pair)


_MIXERS = {
    "attn": (attn.init_gqa, attn.gqa_specs),
    "mla": (attn.init_mla, attn.mla_specs),
    "mamba": (ssm_mod.init_mamba, ssm_mod.mamba_specs),
    "rwkv": (rwkv_mod.init_time_mix, rwkv_mod.time_mix_specs),
}


def _init_ffn(key, cfg, kind: str, *, d_ff: int | None = None):
    d = cfg.d_model
    if kind == "moe":
        return moe_mod.init_moe(key, cfg)
    if kind == "rwkv_cm":
        return rwkv_mod.init_channel_mix(key, cfg)
    ff = d_ff or cfg.d_ff
    ks = nn.split_keys(key, 3)
    dt = cfg.pdtype
    return {
        "wi": dense_init(ks[0], d, (ff,), dt),
        "wg": dense_init(ks[1], d, (ff,), dt),
        "wo": dense_init(ks[2], ff, (d,), dt),
    }


def _ffn_specs(cfg, kind: str):
    if kind == "moe":
        return moe_mod.moe_specs(cfg)
    if kind == "rwkv_cm":
        return rwkv_mod.channel_mix_specs(cfg)
    return {"wi": P(FSDP, TP), "wg": P(FSDP, TP), "wo": P(TP, FSDP)}


def init_layer(key, cfg, mixer: str, ffn: str, *, d_ff: int | None = None):
    k1, k2 = jax.random.split(key)
    init_m, _ = _MIXERS[mixer]
    return {
        "norm1": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "mixer": init_m(k1, cfg),
        "norm2": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "ffn": _init_ffn(k2, cfg, ffn, d_ff=d_ff),
    }


def layer_specs(cfg, mixer: str, ffn: str):
    _, specs_m = _MIXERS[mixer]
    return {
        "norm1": P(None),
        "mixer": specs_m(cfg),
        "norm2": P(None),
        "ffn": _ffn_specs(cfg, ffn),
    }


def apply_layer(p, cfg, x, mixer: str, ffn: str, *, positions, mode, cache=None, cache_index=None):
    """Returns (x, new_cache, aux)."""
    h = rms_norm(x, p["norm1"])
    if mixer in ("attn", "mla"):
        fwd = attn.gqa_forward if mixer == "attn" else attn.mla_forward
        mix_cache = cache.get("mix") if cache else None
        out, nc = fwd(p["mixer"], cfg, h, positions=positions, mode=mode, cache=mix_cache, cache_index=cache_index)
    elif mixer == "mamba":
        out, nc = ssm_mod.mamba_forward(p["mixer"], cfg, h, mode=mode, cache=cache.get("mix") if cache else None)
    elif mixer == "rwkv":
        out, nc = rwkv_mod.time_mix_forward(p["mixer"], cfg, h, mode=mode, cache=cache.get("mix") if cache else None)
    else:
        raise ValueError(mixer)
    out = _ckpt_name(out, "mixer_out")
    x = x + out
    x = nn.constrain(x, ("dp", "sp", None))  # sequence-parallel boundary

    h = rms_norm(x, p["norm2"])
    aux = jnp.zeros((), jnp.float32)
    ffn_cache = None
    if ffn == "moe":
        out, aux = moe_mod.moe_forward(p["ffn"], cfg, h)
    elif ffn == "rwkv_cm":
        out, ffn_cache = rwkv_mod.channel_mix_forward(
            p["ffn"], cfg, h, mode=mode, cache=cache.get("ffn") if cache else None
        )
    else:
        out = nn.swiglu(h, p["ffn"]["wi"], p["ffn"]["wg"], p["ffn"]["wo"])
    out = _ckpt_name(out, "ffn_out")
    x = x + out
    x = nn.constrain(x, ("dp", "sp", None))  # sequence-parallel boundary

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {}
        if nc is not None:
            new_cache["mix"] = nc
        if ffn_cache is not None:
            new_cache["ffn"] = ffn_cache
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# full model params


def init_params(key, cfg) -> nn.Params:
    pat = layer_pattern(cfg)
    nb = num_blocks(cfg)
    keys = nn.split_keys(key, 4)
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model, cfg.pdtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[1], cfg.d_model, (cfg.padded_vocab,), cfg.pdtype)

    if cfg.first_dense:
        fk = nn.split_keys(keys[2], cfg.first_dense)
        mixer = "mla" if cfg.attn_impl == "mla" else "attn"
        params["first"] = [
            init_layer(fk[i], cfg, mixer, "dense", d_ff=cfg.first_dense_d_ff or cfg.d_ff)
            for i in range(cfg.first_dense)
        ]

    bkeys = jax.random.split(keys[3], nb)
    blocks = {}
    for pos, (mixer, ffn) in enumerate(pat):
        pkeys = jax.vmap(lambda k, i=pos: jax.random.fold_in(k, i))(bkeys)
        blocks[f"pos{pos}"] = jax.vmap(lambda k, m=mixer, f=ffn: init_layer(k, cfg, m, f))(pkeys)
    params["blocks"] = blocks
    return params


def param_specs(cfg) -> nn.Specs:
    pat = layer_pattern(cfg)
    specs: dict[str, Any] = {
        "embed": P(TP, FSDP),
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["head"] = P(FSDP, TP)
    if cfg.first_dense:
        mixer = "mla" if cfg.attn_impl == "mla" else "attn"
        specs["first"] = [layer_specs(cfg, mixer, "dense") for _ in range(cfg.first_dense)]

    def stack_spec(s):
        return P(None, *s)

    blocks = {}
    for pos, (mixer, ffn) in enumerate(pat):
        ls = layer_specs(cfg, mixer, ffn)
        blocks[f"pos{pos}"] = jax.tree.map(stack_spec, ls, is_leaf=lambda x: isinstance(x, P))
    specs["blocks"] = blocks
    return specs


# ---------------------------------------------------------------------------
# forward


def embed_tokens(params, cfg, tokens):
    emb = params["embed"]
    x = emb.astype(cfg.jdtype)[tokens]
    return nn.constrain(x, ("dp", None, None))


def _block_body(cfg, pat, mode):
    def body(carry, xs):
        x, aux, positions, cache_index = carry
        bparams = xs["params"]
        bcache = xs.get("cache")
        new_cache = {}
        for pos, (mixer, ffn) in enumerate(pat):
            c = bcache[f"pos{pos}"] if bcache is not None else None
            x, nc, a = apply_layer(
                bparams[f"pos{pos}"], cfg, x, mixer, ffn,
                positions=positions, mode=mode, cache=c, cache_index=cache_index,
            )
            aux = aux + a
            if nc is not None:
                new_cache[f"pos{pos}"] = nc
        return (x, aux, positions, cache_index), (new_cache if new_cache else None)

    return body


def forward(params, cfg, *, tokens=None, embeds=None, mode="train", cache=None, cache_index=None, positions=None):
    """Returns (logits_or_hidden, new_cache, aux_loss).

    tokens: (B, S) int32 or embeds: (B, S, d).  cache: stacked cache pytree
    {'blocks': ..., 'first': [...]} for prefill/decode.
    """
    pat = layer_pattern(cfg)
    if embeds is None:
        x = embed_tokens(params, cfg, tokens)
    else:
        x = embeds.astype(cfg.jdtype)
    B, S = x.shape[0], x.shape[1]
    if positions is None:
        if mode == "decode":
            assert cache_index is not None
            positions = jnp.full((B, 1), cache_index, jnp.int32)
        else:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    aux = jnp.zeros((), jnp.float32)
    new_first_caches = []
    if cfg.first_dense:
        mixer = "mla" if cfg.attn_impl == "mla" else "attn"
        for i, lp in enumerate(params["first"]):
            c = cache["first"][i] if cache is not None else None
            x, nc, a = apply_layer(
                lp, cfg, x, mixer, "dense", positions=positions, mode=mode,
                cache=c, cache_index=cache_index,
            )
            aux += a
            new_first_caches.append(nc)

    body = _block_body(cfg, pat, mode)
    if cfg.remat and mode == "train":
        policy = (
            jax.checkpoint_policies.save_only_these_names("mixer_out", "ffn_out")
            if cfg.remat_policy == "save_mixer_ffn"
            else jax.checkpoint_policies.nothing_saveable
        )
        body = jax.checkpoint(body, policy=policy)

    xs = {"params": params["blocks"]}
    if cache is not None:
        xs["cache"] = cache["blocks"]
    ci = cache_index if cache_index is not None else jnp.zeros((), jnp.int32)
    (x, aux, _, _), block_caches = jax.lax.scan(body, (x, aux, positions, ci), xs)

    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    # logits stay in activation dtype: the f32 upcast happens inside the loss
    # so the backward chain (incl. TP all-reduces) runs in bf16, not f32
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.jdtype))
    if cfg.padded_vocab != cfg.vocab_size:  # mask padded vocab columns
        vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(vmask[None, None, :], logits, -1e9)
    logits = nn.constrain(logits, ("dp", None, "tp"))

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"blocks": block_caches}
        if cfg.first_dense:
            new_cache["first"] = new_first_caches
    return logits, new_cache, aux


# ---------------------------------------------------------------------------
# loss


def lm_loss(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None):
    """Stable CE with vocab-sharded logits. labels: (B, S) int32 (-1 = pad).

    f32 math internally; the incoming logits may be bf16 (their cotangent
    then stays bf16, keeping backward collectives at half width).
    """
    V = logits.shape[-1]
    if mask is None:
        mask = labels >= 0
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), V, dtype=lf.dtype)
    onehot = nn.constrain(onehot, ("dp", None, "tp"))  # keep vocab-sharded
    ll = jnp.sum(lf * onehot, axis=-1)
    ce = (lse - ll) * mask.astype(jnp.float32)
    return jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1)


def train_loss(params, cfg, batch):
    logits, _, aux = forward(
        params, cfg,
        tokens=batch.get("tokens"), embeds=batch.get("embeds"), mode="train",
    )
    labels = batch["labels"]
    loss = lm_loss(logits, labels)
    return loss + cfg.moe_aux_weight * aux, (loss, aux)


# ---------------------------------------------------------------------------
# caches


def _mixer_cache_fns(mixer: str):
    return {
        "attn": (attn.gqa_cache_shape, attn.gqa_init_cache),
        "mla": (attn.mla_cache_shape, attn.mla_init_cache),
        "mamba": (ssm_mod.mamba_cache_shape, ssm_mod.mamba_init_cache),
        "rwkv": (rwkv_mod.rwkv_cache_shape, rwkv_mod.rwkv_init_cache),
    }[mixer]


def _layer_cache(cfg, mixer, ffn, batch, max_len, *, shapes: bool):
    shape_fn, init_fn = _mixer_cache_fns(mixer)
    if mixer == "rwkv":
        # rwkv cache covers both tm (mixer) and cm (ffn shift)
        if shapes:
            shp, spec = shape_fn(cfg, batch, max_len)
            return {"mix": shp["tm"], "ffn": shp["cm"]}, {"mix": spec["tm"], "ffn": spec["cm"]}
        full = init_fn(cfg, batch, max_len)
        return {"mix": full["tm"], "ffn": full["cm"]}
    if shapes:
        shp, spec = shape_fn(cfg, batch, max_len)
        return {"mix": shp}, {"mix": spec}
    return {"mix": init_fn(cfg, batch, max_len)}


def cache_shapes(cfg, batch: int, max_len: int):
    """Returns (ShapeDtypeStruct tree, PartitionSpec tree) matching forward()."""
    pat = layer_pattern(cfg)
    nb = num_blocks(cfg)

    def stack(x):
        return jax.ShapeDtypeStruct((nb,) + x.shape, x.dtype)

    def stack_spec(s):
        return P(None, *s)

    blocks_shp, blocks_spec = {}, {}
    for pos, (mixer, ffn) in enumerate(pat):
        shp, spec = _layer_cache(cfg, mixer, ffn, batch, max_len, shapes=True)
        blocks_shp[f"pos{pos}"] = jax.tree.map(stack, shp)
        blocks_spec[f"pos{pos}"] = jax.tree.map(stack_spec, spec, is_leaf=lambda x: isinstance(x, P))
    out_shp: dict[str, Any] = {"blocks": blocks_shp}
    out_spec: dict[str, Any] = {"blocks": blocks_spec}
    if cfg.first_dense:
        mixer = "mla" if cfg.attn_impl == "mla" else "attn"
        fs, fsp = [], []
        for _ in range(cfg.first_dense):
            shp, spec = _layer_cache(cfg, mixer, "dense", batch, max_len, shapes=True)
            fs.append(shp)
            fsp.append(spec)
        out_shp["first"] = fs
        out_spec["first"] = fsp
    return out_shp, out_spec


def init_cache(cfg, batch: int, max_len: int):
    pat = layer_pattern(cfg)
    nb = num_blocks(cfg)

    def stack(x):
        return jnp.broadcast_to(x[None], (nb,) + x.shape)

    blocks = {}
    for pos, (mixer, ffn) in enumerate(pat):
        c = _layer_cache(cfg, mixer, ffn, batch, max_len, shapes=False)
        blocks[f"pos{pos}"] = jax.tree.map(stack, c)
    out: dict[str, Any] = {"blocks": blocks}
    if cfg.first_dense:
        mixer = "mla" if cfg.attn_impl == "mla" else "attn"
        out["first"] = [
            _layer_cache(cfg, mixer, "dense", batch, max_len, shapes=False)
            for _ in range(cfg.first_dense)
        ]
    return out


# ---------------------------------------------------------------------------
# analytic parameter counts (via eval_shape — no allocation)


def count_params_analytic(cfg) -> tuple[int, int]:
    """(total_params, active_params) — active subtracts unrouted experts."""
    import math

    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))
    total = sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))
    # subtract masked head padding (llava): padded q/o rows are dead weights
    H_pad, _ = attn.padded_heads(cfg)
    if H_pad != cfg.num_heads and cfg.attn_impl == "gqa":
        pat = layer_pattern(cfg)
        n_attn = sum(1 for m, _ in pat if m == "attn") * num_blocks(cfg) + cfg.first_dense
        total -= n_attn * (H_pad - cfg.num_heads) * cfg.head_dim * cfg.d_model * 2
    active = total
    if cfg.moe_num_experts:
        pat = layer_pattern(cfg)
        n_moe = sum(1 for _, f in pat if f == "moe") * num_blocks(cfg)
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff
        active = total - n_moe * (cfg.moe_num_experts - cfg.moe_top_k) * per_expert
    return total, active
