"""Mamba selective-SSM block (jamba's mixer), TPU-adapted.

The CUDA selective-scan kernel is replaced by a chunked formulation:
``lax.scan`` over sequence chunks with a ``lax.associative_scan`` (log-depth)
inside each chunk — the carry is the (B, d_inner, d_state) SSM state.  This
keeps the working set to one chunk (VMEM-friendly when the same blocking is
used by a Pallas port) and exposes large elementwise/matmul ops to the VPU/
MXU instead of a token-sequential loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import nn
from .nn import FSDP, TP, dense_init


def d_inner(cfg) -> int:
    return cfg.expand * cfg.d_model


def dt_rank(cfg) -> int:
    return max(16, cfg.d_model // 16)


def init_mamba(key, cfg) -> nn.Params:
    d, di, ds, dc, dr = cfg.d_model, d_inner(cfg), cfg.d_state, cfg.d_conv, dt_rank(cfg)
    ks = nn.split_keys(key, 6)
    dt = cfg.pdtype
    A = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "in_proj": dense_init(ks[0], d, (2 * di,), dt),
        "conv_w": dense_init(ks[1], dc, (di,), dt),  # depthwise causal conv
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(ks[2], di, (dr + 2 * ds,), dt),
        "dt_w": dense_init(ks[3], dr, (di,), dt),
        "dt_b": jnp.full((di,), -4.6, dt),  # softplus^-1(0.01) ~= -4.6
        "A_log": jnp.log(A).astype(jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, (d,), dt),
    }


def mamba_specs(cfg) -> nn.Specs:
    return {
        "in_proj": P(FSDP, TP),
        "conv_w": P(None, TP),
        "conv_b": P(TP),
        "x_proj": P(TP, None),
        "dt_w": P(None, TP),
        "dt_b": P(TP),
        "A_log": P(TP, None),
        "D": P(TP),
        "out_proj": P(TP, FSDP),
    }


def _causal_conv(x, w, b):
    """x: (B,S,di); w: (dc,di) depthwise; left-padded causal conv."""
    dc = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(dc))
    return out + b[None, None, :]


def _ssm_inputs(p, cfg, xz):
    """From in_proj output produce (x_raw, x_conv, z, dt, A, Bm, Cm)."""
    di, ds, dr = d_inner(cfg), cfg.d_state, dt_rank(cfg)
    x_raw, z = jnp.split(xz, 2, axis=-1)
    x = jax.nn.silu(_causal_conv(x_raw, p["conv_w"].astype(x_raw.dtype), p["conv_b"].astype(x_raw.dtype)))
    proj = jnp.einsum("bsi,ik->bsk", x, p["x_proj"].astype(x.dtype))
    dt_in, Bm, Cm = jnp.split(proj, [dr, dr + ds], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_in, p["dt_w"].astype(x.dtype)).astype(jnp.float32)
        + p["dt_b"].astype(jnp.float32)
    )  # (B,S,di) f32
    A = -jnp.exp(p["A_log"])  # (di, ds), negative
    return x_raw, x, z, dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def mamba_forward(p, cfg, x_in, *, mode, cache=None):
    """x_in: (B,S,d). Returns (out, new_cache)."""
    B, S, d = x_in.shape
    di, ds, dc = d_inner(cfg), cfg.d_state, cfg.d_conv
    xz = jnp.einsum("bsd,de->bse", x_in, p["in_proj"].astype(x_in.dtype))
    xz = nn.constrain(xz, ("dp", None, "tp"))

    if mode == "decode":
        # single token: use cached conv inputs + state
        x, z = jnp.split(xz, 2, axis=-1)
        conv_hist = jnp.concatenate([cache["conv"], x], axis=1)  # (B, dc, di)
        w = p["conv_w"].astype(x.dtype)
        xc = jnp.einsum("bci,ci->bi", conv_hist, w) + p["conv_b"].astype(x.dtype)
        xc = jax.nn.silu(xc)[:, None, :]
        proj = jnp.einsum("bsi,ik->bsk", xc, p["x_proj"].astype(x.dtype))
        dr = dt_rank(cfg)
        dt_in, Bm, Cm = jnp.split(proj, [dr, dr + ds], axis=-1)
        dt = jax.nn.softplus(
            jnp.einsum("bsr,ri->bsi", dt_in, p["dt_w"].astype(x.dtype)).astype(jnp.float32)
            + p["dt_b"].astype(jnp.float32)
        )
        A = -jnp.exp(p["A_log"])
        a = jnp.exp(dt[:, 0, :, None] * A[None])  # (B,di,ds)
        bu = dt[:, 0, :, None] * Bm[:, 0, None, :].astype(jnp.float32) * xc[:, 0, :, None].astype(jnp.float32)
        h = a * cache["h"] + bu
        y = jnp.einsum("bis,bs->bi", h, Cm[:, 0].astype(jnp.float32)) + p["D"] * xc[:, 0].astype(jnp.float32)
        y = y[:, None, :].astype(x_in.dtype) * jax.nn.silu(z)
        out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x_in.dtype))
        new_cache = {"conv": conv_hist[:, 1:], "h": h}
        return out, new_cache

    x_raw, x, z, dt, A, Bm, Cm = _ssm_inputs(p, cfg, xz)
    import math as _math
    chunk = min(cfg.ssm_chunk, S)
    if S % chunk:
        chunk = _math.gcd(S, chunk)
    nck = S // chunk

    xf = x.astype(jnp.float32)
    # per-chunk tensors: (nc, B, C, ...)
    def rs(t):
        return t.reshape(B, nck, chunk, *t.shape[2:]).swapaxes(0, 1)

    dt_c, B_c, C_c, x_c = rs(dt), rs(Bm), rs(Cm), rs(xf)

    def chunk_body(h, inp):
        dt_i, B_i, C_i, x_i = inp  # (B,C,di),(B,C,ds),(B,C,ds),(B,C,di)
        a = jnp.exp(dt_i[..., None] * A[None, None])  # (B,C,di,ds)
        bu = dt_i[..., None] * B_i[:, :, None, :] * x_i[..., None]  # (B,C,di,ds)

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        a_sc, b_sc = jax.lax.associative_scan(comb, (a, bu), axis=1)
        hs = a_sc * h[:, None] + b_sc  # (B,C,di,ds)
        y = jnp.einsum("bcis,bcs->bci", hs, C_i) + p["D"][None, None] * x_i
        return hs[:, -1], y

    h0 = cache["h"] if (cache is not None and mode == "prefill") else jnp.zeros((B, di, ds), jnp.float32)
    # remat the chunk body: backward replays a chunk instead of saving the
    # (B, C, d_inner, d_state) decay/scan tensors for every chunk
    body = jax.checkpoint(chunk_body, policy=jax.checkpoint_policies.nothing_saveable)
    h_last, ys = jax.lax.scan(body, h0, (dt_c, B_c, C_c, x_c))
    y = ys.swapaxes(0, 1).reshape(B, S, di).astype(x_in.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x_in.dtype))
    new_cache = None
    if mode == "prefill":
        new_cache = {
            "conv": x_raw[:, S - (dc - 1) :, :].astype(cfg.jdtype)
            if dc > 1
            else jnp.zeros((B, 0, di), cfg.jdtype),
            "h": h_last,
        }
    return out, new_cache


def mamba_cache_shape(cfg, batch: int, max_len: int):
    di, ds, dc = d_inner(cfg), cfg.d_state, cfg.d_conv
    del max_len
    return (
        {
            "conv": jax.ShapeDtypeStruct((batch, dc - 1, di), cfg.jdtype),
            "h": jax.ShapeDtypeStruct((batch, di, ds), jnp.float32),
        },
        {"conv": P(nn.DP, None, TP), "h": P(nn.DP, TP, None)},
    )


def mamba_init_cache(cfg, batch: int, max_len: int):
    di, ds, dc = d_inner(cfg), cfg.d_state, cfg.d_conv
    del max_len
    return {
        "conv": jnp.zeros((batch, dc - 1, di), cfg.jdtype),
        "h": jnp.zeros((batch, di, ds), jnp.float32),
    }
