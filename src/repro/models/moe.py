"""Mixture-of-Experts: grouped einsum dispatch (GShard-style) with EP.

TPU adaptation: tokens are reshaped into groups of ``moe_group_size`` so
the (G, T_g, E, C) dispatch/combine tensors stay small (T_g defaults to
512 -> dispatch matmul ~15% of expert-FFN FLOPs and ~100 MB transients per
device), experts are sharded over the `model` mesh axis (GSPMD inserts the
all-to-all at the group->expert resharding boundary), and expert weights
are FSDP-sharded on d_model over `data`.  Capacity-based token dropping
with a load-balance auxiliary loss, plus optional shared experts
(deepseek-v2 style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import nn
from .nn import FSDP, TP, dense_init


def init_moe(key, cfg) -> nn.Params:
    d, E, ff = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
    ks = nn.split_keys(key, 5)
    dt = cfg.pdtype
    p = {
        "router": dense_init(ks[0], d, (E,), jnp.float32),
        "wi": _expert_init(ks[1], E, d, ff, dt),
        "wg": _expert_init(ks[2], E, d, ff, dt),
        "wo": _expert_init(ks[3], E, ff, d, dt),
    }
    if cfg.moe_num_shared:
        sff = cfg.moe_num_shared * ff
        kk = nn.split_keys(ks[4], 3)
        p["shared"] = {
            "wi": dense_init(kk[0], d, (sff,), dt),
            "wg": dense_init(kk[1], d, (sff,), dt),
            "wo": dense_init(kk[2], sff, (d,), dt),
        }
    return p


def _expert_init(key, E, din, dout, dt):
    import math

    std = 1.0 / math.sqrt(din)
    return nn.truncated_normal_init(key, (E, din, dout), dt, std)


def moe_specs(cfg) -> nn.Specs:
    s = {
        "router": P(None, None),
        "wi": P(TP, FSDP, None),
        "wg": P(TP, FSDP, None),
        "wo": P(TP, None, FSDP),
    }
    if cfg.moe_num_shared:
        s["shared"] = {"wi": P(FSDP, TP), "wg": P(FSDP, TP), "wo": P(TP, FSDP)}
    return s


def _capacity(cfg, tokens_per_group: int) -> int:
    c = int(cfg.moe_top_k * tokens_per_group * cfg.capacity_factor / cfg.moe_num_experts)
    return max(8, (c + 7) // 8 * 8)


def route(gates: jax.Array, k: int, capacity: int):
    """gates: (G, T, E) probabilities.  Returns (dispatch, combine, aux_loss).

    dispatch/combine: (G, T, E, C).  GShard-style cumulative-position
    routing with per-group capacity and token dropping.
    """
    G, T, E = gates.shape
    w, idx = jax.lax.top_k(gates, k)  # (G,T,k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * mean_e(frac_tokens_e * mean_prob_e)
    me = jnp.mean(gates, axis=1)  # (G,E)
    assign1 = jax.nn.one_hot(idx[..., 0], E, dtype=gates.dtype)
    ce = jnp.mean(assign1, axis=1)  # (G,E)
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))

    dispatch = jnp.zeros((G, T, E, capacity), dtype=gates.dtype)
    combine = jnp.zeros((G, T, E, capacity), dtype=gates.dtype)
    counts = jnp.zeros((G, E), dtype=jnp.int32)
    for j in range(k):
        onehot = jax.nn.one_hot(idx[..., j], E, dtype=jnp.int32)  # (G,T,E)
        pos = jnp.cumsum(onehot, axis=1) - 1 + counts[:, None, :]
        keep = (pos < capacity) & (onehot > 0)
        counts = counts + jnp.sum(onehot, axis=1)
        pos_c = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity, dtype=gates.dtype)
        d_j = keep.astype(gates.dtype)[..., None] * pos_c  # (G,T,E,C)
        dispatch = dispatch + d_j
        combine = combine + d_j * w[..., j][..., None, None]
    return dispatch, combine, aux


def moe_forward(p, cfg, x, *, num_groups_hint: int | None = None):
    """x: (B, S, d) -> (out, aux_loss)."""
    B, S, d = x.shape
    T_all = B * S
    gsz = min(cfg.moe_group_size, T_all)
    G = T_all // gsz
    assert G * gsz == T_all, (B, S, gsz)
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    C = _capacity(cfg, gsz)

    xg = x.reshape(G, gsz, d)
    xg = nn.constrain(xg, ("dp", None, None))
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    dispatch, combine, aux = route(gates, k, C)
    dispatch = dispatch.astype(x.dtype)

    expert_in = jnp.einsum("gtec,gtd->ecgd", dispatch, xg)
    expert_in = expert_in.reshape(E, C * G, d)
    # EP x DP: experts sharded over `model`, expert TOKENS sharded over
    # `data` (GSPMD inserts the all-to-all here).  Without the 'dp' part
    # each device processed ALL of its experts' tokens — a measured 16x
    # expert-FFN FLOP replication.
    expert_in = nn.constrain(expert_in, ("tp", "dp", None))
    h = jnp.einsum("ekd,edf->ekf", expert_in, p["wi"].astype(x.dtype))
    g = jnp.einsum("ekd,edf->ekf", expert_in, p["wg"].astype(x.dtype))
    h = nn.constrain(h, ("tp", "dp", None))
    g = nn.constrain(g, ("tp", "dp", None))
    h = jax.nn.silu(g) * h
    expert_out = jnp.einsum("ekf,efd->ekd", h, p["wo"].astype(x.dtype))
    expert_out = nn.constrain(expert_out, ("tp", "dp", None))
    expert_out = expert_out.reshape(E, C, G, d)
    out = jnp.einsum("gtec,ecgd->gtd", combine.astype(x.dtype), expert_out)
    out = out.reshape(B, S, d)

    if cfg.moe_num_shared:
        out = out + nn.swiglu(x, p["shared"]["wi"], p["shared"]["wg"], p["shared"]["wo"])
    return out, aux
