"""Minimal functional param/module system with sharding-spec trees.

No flax in this environment; params are plain pytrees (nested dicts of
jnp arrays). Every ``init_*`` function has a ``*_specs`` twin returning an
identically-structured tree of ``jax.sharding.PartitionSpec`` so the
launcher can build NamedShardings without tracing.

Axis-name conventions (resolved by :func:`repro.launch.mesh.logical_axes`):
  - ``fsdp``  -> ('data',) or ('pod', 'data') depending on mesh
  - ``tp``    -> 'model'
  - ``dp``    -> batch axes ('data',) / (('pod','data'),)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Any  # nested dict pytree of arrays
Specs = Any  # same-structure pytree of PartitionSpec

# Logical axis names used inside spec trees; they are substituted with
# concrete mesh axis names by ``resolve_specs``.
FSDP = "__fsdp__"
TP = "__tp__"
DP = "__dp__"


def resolve_specs(tree: Specs, *, multi_pod: bool) -> Specs:
    """Replace logical axis placeholders with concrete mesh axis names."""
    fsdp = ("pod", "data") if multi_pod else ("data",)
    dp = ("pod", "data") if multi_pod else ("data",)

    def fix(spec):
        if not isinstance(spec, P):
            return spec
        out = []
        for part in spec:
            if part == FSDP:
                out.append(fsdp)
            elif part == DP:
                out.append(dp)
            elif part == TP:
                out.append("model")
            elif isinstance(part, tuple):
                sub: list = []
                for q in part:
                    if q == FSDP:
                        sub.extend(fsdp)
                    elif q == TP:
                        sub.append("model")
                    else:
                        sub.append(q)
                out.append(tuple(sub))
            else:
                out.append(part)
        return P(*out)

    return jax.tree.map(fix, tree, is_leaf=lambda x: isinstance(x, P))


def shardings_for(tree_specs: Specs, mesh) -> Any:
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# initializers


def truncated_normal_init(key, shape, dtype, stddev):
    return (stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def dense_init(key, in_dim: int, out_shape, dtype) -> jax.Array:
    """Fan-in scaled init for a matmul with contraction dim ``in_dim``."""
    shape = (in_dim,) + tuple(out_shape) if isinstance(out_shape, (tuple, list)) else (in_dim, out_shape)
    stddev = 1.0 / math.sqrt(in_dim)
    return truncated_normal_init(key, shape, dtype, stddev)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return truncated_normal_init(key, (vocab, dim), dtype, 1.0)


# ---------------------------------------------------------------------------
# primitive layers (pure functions over param dicts)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def group_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, groups: int, eps: float = 1e-5):
    """GroupNorm over the last dim split into ``groups`` (used by RWKV)."""
    dt = x.dtype
    *lead, d = x.shape
    xg = x.astype(jnp.float32).reshape(*lead, groups, d // groups)
    mu = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xg - mu), axis=-1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    y = xg.reshape(*lead, d)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def swiglu(x: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, wi.astype(x.dtype))
    g = jnp.einsum("...d,df->...f", x, wg.astype(x.dtype))
    # ff dim sharded over tp, seq gathered (Megatron-SP boundary)
    dims = ("dp",) + (None,) * (h.ndim - 2) + ("tp",)
    h = constrain(h, dims)
    g = constrain(g, dims)
    h = jax.nn.silu(g) * h
    return jnp.einsum("...f,fd->...d", h, wo.astype(x.dtype))


# ---------------------------------------------------------------------------
# rotary embeddings


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., s, 1, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# misc


# ---------------------------------------------------------------------------
# activation sharding hook — configured by the launcher; no-op by default so
# model code runs on a single device (smoke tests) without a mesh.

_ACT_AXES: dict[str, Any] = {"dp": None, "tp": None, "sp": None, "sizes": {}}


def set_activation_axes(dp=None, tp=None, sp=None, sizes: dict | None = None) -> None:
    """dp: batch axes; tp: tensor axis; sp: sequence-parallel axis (saved
    residuals between blocks are sharded over it — Megatron-SP style)."""
    _ACT_AXES["dp"] = dp
    _ACT_AXES["tp"] = tp
    _ACT_AXES["sp"] = sp
    _ACT_AXES["sizes"] = sizes or {}


def _axis_size(axis) -> int:
    sizes = _ACT_AXES["sizes"]
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(axis, 1)


def constrain(x: jax.Array, dims) -> jax.Array:
    """dims: tuple like ('dp', None, 'tp'); resolved via set_activation_axes.
    Axes that do not evenly divide their dim are dropped (e.g. batch=1
    decode, or a seq dim smaller than the model axis)."""
    axes = []
    for i, d in enumerate(dims):
        a = _ACT_AXES.get(d) if isinstance(d, str) else None
        if a is not None and _ACT_AXES["sizes"]:
            if i >= x.ndim or x.shape[i] % _axis_size(a) != 0:
                a = None
        axes.append(a)
    if all(a is None for a in axes):
        return x
    return jax.lax.with_sharding_constraint(x, P(*axes))


def count_params(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def cast_tree(params: Params, dtype) -> Params:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, params
    )


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
