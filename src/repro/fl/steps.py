"""Step builders: one Totoro+ FL round (train) and serving steps.

``build_train_step`` composes: microbatch gradient accumulation (the
client's local pass), zone-local reduction over `data` (inside backprop),
a cross-zone (`pod`) aggregation stage, and the optimizer update.

Aggregation modes (sharding contract resolved in launch/specs.py):
  - xla_auto       : params FSDP over ('pod','data') + TP over 'model';
                     the whole reduction is left to GSPMD (the
                     centralized-baseline schedule: params gathered
                     cross-pod every layer).
  - totoro_tree    : params replicated across pods (each pod = one edge
                     zone holding a full zone replica, FSDP over 'data'
                     inside).  GSPMD then emits exactly the paper's tree:
                     reduce-scatter over `data` (zone-local) feeding an
                     all-reduce over `pod` (cross-zone) — verifiable in
                     the compiled replica_groups.
  - totoro_tree_q8 : *podded* params — every state leaf gets a leading
                     (num_pods,) dim sharded over 'pod' and the local pass
                     runs under vmap, so autodiff cannot reduce across
                     pods; the cross-zone hop is then explicit: QSGD int8
                     quantize -> replicate-constraint (an int8 all-gather
                     on the wire, ~4x less traffic) -> dequantize-mean.
                     (A partial-manual shard_map formulation hits XLA SPMD
                     partitioner CHECK-crashes on this build; the podded
                     formulation is pure GSPMD and robust.)
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import encdec, lm
from repro import optim as optim_mod


def _loss_fn(cfg):
    if cfg.is_encoder_decoder:
        return lambda params, batch: encdec.forward_train(params, cfg, batch)
    return lambda params, batch: lm.train_loss(params, cfg, batch)


def _split_microbatches(batch, accum: int):
    """(B, ...) -> (accum, B//accum, ...) with microbatches *strided* so each
    microbatch spans every (pod, data) shard — reshaping to contiguous
    blocks would concentrate a microbatch on a subset of devices."""
    from repro.models import nn

    def split(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        y = x.reshape(b // accum, accum, *x.shape[1:]).swapaxes(0, 1)
        return nn.constrain(y, (None, "dp") + (None,) * (y.ndim - 2))

    return jax.tree.map(split, batch)


def grads_and_metrics(cfg, plan, params, batch):
    """Gradient accumulation over ``plan.grad_accum`` microbatches (fp32)."""
    loss_fn = _loss_fn(cfg)
    accum = plan.grad_accum
    if accum == 1:
        (_, (ce, aux)), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        return g, {"loss": ce, "aux": aux}

    micro = _split_microbatches(batch, accum)

    def body(carry, mb):
        gsum, lsum, asum = carry
        (_, (ce, aux)), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
        return (gsum, lsum + ce, asum + aux), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (g, lsum, asum), _ = jax.lax.scan(body, (g0, jnp.zeros(()), jnp.zeros(())), micro)
    g = jax.tree.map(lambda x: x / accum, g)
    return g, {"loss": lsum / accum, "aux": asum / accum}


def q8_mean_over_pods(grads_pod):
    """Cross-zone compressed aggregation in pure GSPMD.

    grads_pod leaves: (P, ...) f32, dim 0 sharded over 'pod'.  Quantize to
    int8 per 256-wide row (local), force dim-0 replication (the resulting
    all-gather moves int8 + one f32 scale per row — the compressed wire
    format), then dequantize and average locally.
    """
    from jax.sharding import PartitionSpec as P

    from .compression import qsgd_quantize

    def agg(g):
        pods = g.shape[0]
        flat = g.reshape(pods, -1)
        pad = (-flat.shape[1]) % 256
        flat = jnp.pad(flat, ((0, 0), (0, pad))).reshape(pods, -1, 256)
        rows = flat.shape[1]
        # rows stay sharded over (data, model); only the pod dim is gathered,
        # so the wire payload is the int8 shard (+ f32 scales, 1/256 of it)
        row_part = ("data", "model") if rows % 256 == 0 else None
        q, scale = qsgd_quantize(flat)
        q = jax.lax.with_sharding_constraint(q, P(None, row_part, None))
        scale = jax.lax.with_sharding_constraint(scale, P(None, row_part, None))
        deq = jnp.mean(q.astype(jnp.float32) * scale, axis=0)
        return deq.reshape(-1)[: g[0].size].reshape(g.shape[1:])

    return jax.tree.map(agg, grads_pod)


def build_train_step(cfg, plan, *, mesh=None, num_pods: int = 1):
    """Returns train_step(state, batch) -> (state, metrics)."""
    opt = optim_mod.make_optimizer(cfg)

    def local_round(params, batch):
        return grads_and_metrics(cfg, plan, params, batch)

    def apply_update(state, grads):
        new_params, new_opt = opt.update(grads, state["opt"], state["params"])
        return {"params": new_params, "opt": new_opt}

    podded = plan.aggregation == "totoro_tree_q8" and num_pods > 1

    if not podded:
        # 'xla_auto' and 'totoro_tree' differ only in the param shardings
        # chosen by launch/specs.py (see module docstring).
        def train_step(state, batch):
            grads, metrics = local_round(state["params"], batch)
            return apply_update(state, grads), metrics

        return train_step

    from jax.sharding import PartitionSpec as P

    def train_step(state, batch):
        # batch (B, ...) -> (P, B/P, ...): pods are the outermost shard axis,
        # so the contiguous split matches the (pod, data) batch sharding.
        def podify(x):
            y = x.reshape(num_pods, x.shape[0] // num_pods, *x.shape[1:])
            return jax.lax.with_sharding_constraint(
                y, P("pod", "data", *([None] * (y.ndim - 2)))
            )

        batch_pod = jax.tree.map(podify, batch)
        grads_pod, metrics_pod = jax.vmap(local_round)(state["params"], batch_pod)
        agg = q8_mean_over_pods(grads_pod)
        new_state = jax.vmap(apply_update, in_axes=(0, None))(state, agg)
        metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics_pod)
        return new_state, metrics

    return train_step


def init_train_state(cfg, params, *, num_pods: int = 1, podded: bool = False):
    opt = optim_mod.make_optimizer(cfg)
    state = {"params": params, "opt": opt.init(params)}
    if podded:
        state = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (num_pods,) + x.shape), state
        )
    return state


def train_state_specs(cfg, pspecs, pshapes, *, podded: bool = False):
    from jax.sharding import PartitionSpec as P

    opt = optim_mod.make_optimizer(cfg)
    specs = {"params": pspecs, "opt": opt.state_specs(pspecs, pshapes)}
    if podded:
        specs = jax.tree.map(
            lambda s: P("pod", *s), specs, is_leaf=lambda x: isinstance(x, P)
        )
    return specs


# ---------------------------------------------------------------------------
# serving steps


def build_prefill_step(cfg):
    def prefill_step(params, batch):
        if cfg.is_encoder_decoder:
            cache, logits = encdec.prefill(params, cfg, batch)
            return cache, jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        logits, cache, _ = lm.forward(
            params, cfg,
            tokens=batch.get("tokens"), embeds=batch.get("embeds"), mode="prefill",
        )
        return cache, jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    return prefill_step


def build_decode_step(cfg):
    def decode_step(params, cache, token, cache_index):
        if cfg.is_encoder_decoder:
            new_cache, logits = encdec.decode_step(params, cfg, cache, token, cache_index)
        else:
            logits, new_cache, _ = lm.forward(
                params, cfg, tokens=token, mode="decode",
                cache=cache, cache_index=cache_index,
            )
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return new_cache, nxt

    return decode_step
