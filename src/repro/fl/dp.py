"""Differential-privacy hook (per-application customization, Table II).

Clip-then-Gaussian-noise on gradient pytrees — the mechanism application
owners can specify in ``Aggregate(app_id, object)`` per the paper §IV-E.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), n


def gaussianize(tree, key, sigma: float):
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noised = [
        x + (sigma * jax.random.normal(k, x.shape)).astype(x.dtype)
        for x, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, noised)


def dp_sanitize(grads, key, *, clip: float, sigma: float):
    """Clip to ``clip`` then add N(0, (sigma*clip)^2) noise (per-round DP-SGD)."""
    clipped, _ = clip_by_global_norm(grads, clip)
    return gaussianize(clipped, key, sigma * clip)
