"""FedAvg / FedProx aggregation and client weighting.

Two call sites:
  - the mesh data plane (LM-scale): weights enter at the loss level
    (per-example weights), stragglers as zero-weight masks;
  - the overlay simulation (paper-scale small models in ``fl/rounds.py``):
    explicit weighted model-delta averaging along the dataflow tree,
    including FedProx's proximal term during local training.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def fedavg(deltas: Sequence, weights: Sequence[float]):
    """Weighted average of client model deltas (pytrees)."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-12)

    def avg(*leaves):
        return sum(wi * l.astype(jnp.float32) for wi, l in zip(w, leaves))

    return jax.tree.map(avg, *deltas)


def pairwise_accumulate(acc, delta, weight: float):
    """Streaming form used by internal tree nodes: acc += w * delta.

    This is exactly what the ``tree_aggregate`` Pallas kernel computes on
    flattened tiles at an aggregator node.
    """
    if acc is None:
        return jax.tree.map(lambda d: weight * d.astype(jnp.float32), delta)
    return jax.tree.map(lambda a, d: a + weight * d.astype(jnp.float32), acc, delta)


def fedprox_grad(grads, params, round_start, mu: float):
    """Add the FedProx proximal gradient mu * (w - w_global)."""
    if mu == 0.0:
        return grads
    return jax.tree.map(
        lambda g, p, w0: g + mu * (p.astype(jnp.float32) - w0.astype(jnp.float32)),
        grads, params, round_start,
    )


def straggler_mask(weights: Sequence[float], completed: Sequence[bool]):
    """Deadline-style straggler mitigation: drop late clients, renormalize."""
    w = jnp.asarray(weights, jnp.float32) * jnp.asarray(completed, jnp.float32)
    return w / jnp.maximum(jnp.sum(w), 1e-12)


def server_update(global_params, agg_delta, server_lr: float = 1.0):
    """FedOpt-style server step (plain SGD on the aggregated delta)."""
    return jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) + server_lr * d).astype(p.dtype),
        global_params, agg_delta,
    )
