"""Paper-scale models for the FL-effectiveness benchmarks.

The paper trains ResNet-34 / ShuffleNet-V2 on Google Speech / FEMNIST;
those datasets are unavailable offline, so the time-to-accuracy benches
use synthetic classification with an MLP and a small CNN (same role:
a real local-training workload whose per-round cost we can measure).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.nn import dense_init, split_keys


def init_mlp(key, dim: int, hidden: int, num_classes: int):
    ks = split_keys(key, 3)
    return {
        "w1": dense_init(ks[0], dim, (hidden,), jnp.float32),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": dense_init(ks[1], hidden, (hidden,), jnp.float32),
        "b2": jnp.zeros((hidden,), jnp.float32),
        "w3": dense_init(ks[2], hidden, (num_classes,), jnp.float32),
        "b3": jnp.zeros((num_classes,), jnp.float32),
    }


def mlp_logits(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    return h @ params["w3"] + params["b3"]


def init_cnn(key, num_classes: int, channels: int = 16):
    """Tiny conv net over 16x16x1 synthetic images (ShuffleNet stand-in)."""
    ks = split_keys(key, 3)
    return {
        "conv1": 0.1 * jax.random.normal(ks[0], (3, 3, 1, channels)),
        "conv2": 0.1 * jax.random.normal(ks[1], (3, 3, channels, channels * 2)),
        "head": dense_init(ks[2], 4 * 4 * channels * 2, (num_classes,), jnp.float32),
    }


def cnn_logits(params, x):
    """x: (B, 16, 16, 1)."""
    h = jax.lax.conv_general_dilated(
        x, params["conv1"], (2, 2), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    h = jax.nn.relu(h)
    h = jax.lax.conv_general_dilated(
        h, params["conv2"], (2, 2), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    h = jax.nn.relu(h)
    return h.reshape(h.shape[0], -1) @ params["head"]


def ce_loss(logits, y):
    return -jnp.mean(
        jnp.take_along_axis(jax.nn.log_softmax(logits), y[:, None], axis=1)
    )


def accuracy(logits, y):
    return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))


@partial(jax.jit, static_argnames=("logits_fn", "steps", "lr", "mu"))
def local_train(params, global_params, x, y, *, logits_fn, steps: int, lr: float, mu: float = 0.0):
    """E local SGD steps with optional FedProx proximal term; returns
    (new_params, mean_loss).  This is the worker-side computation."""

    def loss_fn(p):
        base = ce_loss(logits_fn(p, x), y)
        if mu > 0:
            prox = sum(
                jnp.sum(jnp.square(a - b))
                for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(global_params))
            )
            base = base + 0.5 * mu * prox
        return base

    def step(p, _):
        l, g = jax.value_and_grad(loss_fn)(p)
        p = jax.tree.map(lambda w, gg: w - lr * gg, p, g)
        return p, l

    params, losses = jax.lax.scan(step, params, None, length=steps)
    return params, jnp.mean(losses)


LOGITS = {"mlp": mlp_logits, "cnn": cnn_logits}
