"""Gradient compression for the cross-zone (cross-pod) aggregation hop.

The paper's ``Broadcast``/``Aggregate`` APIs accept application-specified
compression functions (Table II; refs [37] QSGD, [38] signSGD).  These are
the pure-JAX implementations; ``repro.kernels.quantize`` is the Pallas TPU
version of the QSGD hot loop (bit-identical given the same random bits).

Compressed transport (docs/performance.md "compressed transport"): a
``CompressionPolicy`` rides on ``AppHandle.compression`` (or the async
scheduler's ``app_compression`` knob) and governs the *commit* direction
— workers' delta uploads.  ``quantize_delta`` serializes an update
pytree into a ``QuantizedDelta`` (int8 payload + per-chunk f32 scales),
``CommitDelta`` buffers it as-is, and ``ApplyBuffered`` dequantizes
*inside* the buffered aggregation (``kernels.ops.
buffered_aggregate_quantized``: per-row scales compose with the
staleness weights in one kernel call).  The scheduler prices commit
flows at ``CompressionPolicy.wire_bytes(model_bytes)``, so the
compressed byte count is what enters ``EventCore.open_flow`` — fair
shares, caps, relay admission and sampled cold loads all see the
smaller flows.  ``kind="none"`` is proven byte-identical to the
uncompressed path (tests/test_compression.py).

Rounding bits: every commit draws its own PRNG key via ``commit_key``
(policy seed -> app -> commit sequence number), so repeated commits do
not share rounding bias — the old deterministic default (``rand=0.5``
everywhere) rounded every commit half-down identically.  A fixed
(policy, app, seq) triple reproduces the wire bytes exactly.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp


def qsgd_quantize(x: jax.Array, *, levels: int = 127, key=None, rand=None):
    """Stochastic int8 quantization with per-row scale.

    x: (..., d).  Returns (q int8, scale f32 (..., 1)).
    ``rand``: optional precomputed uniforms in [0,1) (for bit-exact refs).
    With neither ``key`` nor ``rand``, rounding is deterministic
    round-half-down (``rand=0.5``) — fine for one-shot use, but commits
    must thread a per-commit key (``commit_key``) or they all share the
    same rounding bias.
    """
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / levels
    scale = jnp.maximum(scale, 1e-12)
    y = xf / scale
    if rand is None:
        rand = (
            jax.random.uniform(key, x.shape) if key is not None else jnp.full(x.shape, 0.5)
        )
    q = jnp.floor(y + rand).astype(jnp.int8)
    return q, scale


def qsgd_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def signsgd_compress(x: jax.Array):
    """1-bit sign compression with mean-|x| scale."""
    xf = x.astype(jnp.float32)
    scale = jnp.mean(jnp.abs(xf), axis=-1, keepdims=True)
    return jnp.sign(xf).astype(jnp.int8), scale


def signsgd_decompress(s: jax.Array, scale: jax.Array) -> jax.Array:
    return s.astype(jnp.float32) * scale


def topk_sparsify(x: jax.Array, frac: float):
    """Keep the top-``frac`` fraction by |value| (per leading row)."""
    xf = x.astype(jnp.float32)
    flat = xf.reshape(xf.shape[0], -1) if xf.ndim > 1 else xf[None]
    k = max(1, int(flat.shape[-1] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    out = jnp.zeros_like(flat)
    out = jax.vmap(lambda o, i, f: o.at[i].set(f[i]))(out, idx, flat)
    return out.reshape(xf.shape)


def error_feedback_update(x: jax.Array, err: jax.Array, compress_fn):
    """EF-SGD: compress (x + err), carry the residual forward."""
    target = x.astype(jnp.float32) + err
    c, scale = compress_fn(target)
    approx = c.astype(jnp.float32) * scale
    return (c, scale), target - approx


# -- per-app commit compression policy (bytes on the wire) ---------------------

_KINDS = ("none", "qsgd-int8")


@dataclass(frozen=True)
class CompressionPolicy:
    """Per-app commit-direction compression (paper Table II's per-app
    compression hooks, made first-class for the transport model).

    ``kind``: ``"none"`` (full f32 payloads, the byte-identical default)
    or ``"qsgd-int8"`` (QSGD stochastic int8, one f32 max-abs scale per
    ``chunk`` elements).  ``levels`` is the quantization grid per sign
    (<= 127 so the lattice fits int8).  ``seed`` roots the per-commit
    rounding-key chain (``commit_key``)."""

    kind: str = "none"
    levels: int = 127
    chunk: int = 256
    seed: int = 0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"compression kind must be one of {_KINDS}, got {self.kind!r}")
        if not 1 <= int(self.levels) <= 127:
            raise ValueError(f"levels must be in [1, 127] (int8 lattice), got {self.levels!r}")
        if int(self.chunk) < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk!r}")

    @property
    def enabled(self) -> bool:
        return self.kind != "none"

    def wire_bytes(self, payload_bytes: float) -> float:
        """Modeled bytes on the wire for a ``payload_bytes`` f32 payload.

        qsgd-int8 serializes n = payload_bytes/4 elements as one int8
        each, padded to whole chunks, plus one f32 scale per chunk —
        exactly ``QuantizedDelta.nbytes`` for a real n-element delta
        (tested).  ``kind="none"`` returns the input unchanged (same
        float object arithmetic as the uncompressed path, so pricing is
        bit-identical)."""
        if not self.enabled:
            return float(payload_bytes)
        n = float(payload_bytes) / 4.0
        rows = math.ceil(n / self.chunk)
        return float(rows * self.chunk + rows * 4)


def as_policy(value) -> CompressionPolicy | None:
    """Normalize a policy knob: None, a ``CompressionPolicy``, or a kind
    string (``"qsgd-int8"``)."""
    if value is None or isinstance(value, CompressionPolicy):
        return value
    if isinstance(value, str):
        return CompressionPolicy(kind=value)
    raise TypeError(f"expected CompressionPolicy, kind string or None, got {value!r}")


def commit_key(policy: CompressionPolicy, app_idx: int, commit_seq: int):
    """The per-commit rounding key: policy seed -> app -> commit number.

    The sequence number is assigned when the scheduler delivers the
    commit (``AsyncTrainer.commit``), so the chain is deterministic for
    a given event trace: a fixed (seed, app, seq) reproduces the wire
    bytes exactly, while consecutive commits draw decorrelated uniforms
    (tests/test_compression.py)."""
    base = jax.random.PRNGKey(int(policy.seed))
    return jax.random.fold_in(jax.random.fold_in(base, int(app_idx)), int(commit_seq))


@dataclass(frozen=True)
class QuantizedDelta:
    """One worker delta serialized for the wire: int8 lattice points +
    per-chunk f32 scales + the pytree structure needed to rebuild it.

    ``q`` is (R, chunk) int8 (the flattened, zero-padded delta), ``scale``
    (R, 1) f32.  Dequantization is ``q * scale`` row-wise; padding
    elements quantize to exactly 0 (|0/scale + u| < 1 for u in [0, 1))
    and are dropped by ``unflatten``."""

    q: np.ndarray
    scale: np.ndarray
    length: int                 # unpadded element count
    shapes: tuple               # leaf shapes, flatten order
    treedef: Any
    levels: int
    chunk: int

    @property
    def nbytes(self) -> float:
        """Serialized wire size (what ``CommitDelta`` accounts)."""
        return float(self.q.nbytes + self.scale.nbytes)

    def unflatten(self, flat) -> Any:
        """Rebuild the delta pytree from a flat (>= length,) f32 vector."""
        vec = np.asarray(flat)[: self.length]
        leaves, off = [], 0
        for s in self.shapes:
            size = int(np.prod(s)) if s else 1
            leaves.append(vec[off : off + size].reshape(s))
            off += size
        return jax.tree.unflatten(self.treedef, leaves)

    def dequantize(self) -> Any:
        """Unfused reference: dequantize this delta alone (the fused
        apply-side path composes scales with staleness weights instead —
        ``kernels.ops.buffered_aggregate_quantized``)."""
        flat = self.q.astype(np.float32) * self.scale.astype(np.float32)
        return self.unflatten(flat.reshape(-1))


def quantize_delta(delta, policy: CompressionPolicy, key=None) -> QuantizedDelta:
    """Serialize an update pytree under ``policy`` (must be enabled).

    Routes through the kernel wrapper (``kernels.ops.qsgd_quantize``:
    Pallas on TPU, compiled ref off-TPU) when the chunking matches the
    kernel's 256-lane row; any other ``chunk`` takes the pure-JAX path —
    both are bit-identical given the same uniforms.  ``key=None`` falls
    back to deterministic round-half-down (tests only; the commit path
    always threads ``commit_key``)."""
    if not policy.enabled:
        raise ValueError("quantize_delta requires an enabled policy (kind != 'none')")
    leaves, treedef = jax.tree.flatten(delta)
    shapes = tuple(np.shape(l) for l in leaves)
    flat = jnp.concatenate(
        [jnp.ravel(l).astype(jnp.float32) for l in leaves]
    ) if leaves else jnp.zeros((0,), jnp.float32)
    n = int(flat.size)
    chunk = int(policy.chunk)
    rows = max(1, math.ceil(n / chunk))
    padded = jnp.zeros((rows * chunk,), jnp.float32).at[:n].set(flat)
    x2d = padded.reshape(rows, chunk)
    if key is None:
        rand = jnp.full((rows, chunk), 0.5, jnp.float32)
    else:
        rand = jax.random.uniform(key, (rows, chunk), jnp.float32)
    if chunk == 256:
        from repro.kernels import ops as kops

        q, s = kops.qsgd_quantize(x2d, rand, levels=int(policy.levels))
    else:
        q, s = qsgd_quantize(x2d, levels=int(policy.levels), rand=rand)
    return QuantizedDelta(
        q=np.asarray(q), scale=np.asarray(s), length=n, shapes=shapes,
        treedef=treedef, levels=int(policy.levels), chunk=chunk,
    )


def dequantize_delta(qd: QuantizedDelta) -> Any:
    return qd.dequantize()
