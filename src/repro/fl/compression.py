"""Gradient compression for the cross-zone (cross-pod) aggregation hop.

The paper's ``Broadcast``/``Aggregate`` APIs accept application-specified
compression functions (Table II; refs [37] QSGD, [38] signSGD).  These are
the pure-JAX implementations; ``repro.kernels.quantize`` is the Pallas TPU
version of the QSGD hot loop (bit-identical given the same random bits).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def qsgd_quantize(x: jax.Array, *, levels: int = 127, key=None, rand=None):
    """Stochastic int8 quantization with per-row scale.

    x: (..., d).  Returns (q int8, scale f32 (..., 1)).
    ``rand``: optional precomputed uniforms in [0,1) (for bit-exact refs).
    """
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / levels
    scale = jnp.maximum(scale, 1e-12)
    y = xf / scale
    if rand is None:
        rand = (
            jax.random.uniform(key, x.shape) if key is not None else jnp.full(x.shape, 0.5)
        )
    q = jnp.floor(y + rand).astype(jnp.int8)
    return q, scale


def qsgd_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def signsgd_compress(x: jax.Array):
    """1-bit sign compression with mean-|x| scale."""
    xf = x.astype(jnp.float32)
    scale = jnp.mean(jnp.abs(xf), axis=-1, keepdims=True)
    return jnp.sign(xf).astype(jnp.int8), scale


def signsgd_decompress(s: jax.Array, scale: jax.Array) -> jax.Array:
    return s.astype(jnp.float32) * scale


def topk_sparsify(x: jax.Array, frac: float):
    """Keep the top-``frac`` fraction by |value| (per leading row)."""
    xf = x.astype(jnp.float32)
    flat = xf.reshape(xf.shape[0], -1) if xf.ndim > 1 else xf[None]
    k = max(1, int(flat.shape[-1] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    out = jnp.zeros_like(flat)
    out = jax.vmap(lambda o, i, f: o.at[i].set(f[i]))(out, idx, flat)
    return out.reshape(xf.shape)


def error_feedback_update(x: jax.Array, err: jax.Array, compress_fn):
    """EF-SGD: compress (x + err), carry the residual forward."""
    target = x.astype(jnp.float32) + err
    c, scale = compress_fn(target)
    approx = c.astype(jnp.float32) * scale
    return (c, scale), target - approx
