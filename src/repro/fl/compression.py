"""Gradient compression for the cross-zone (cross-pod) aggregation hop.

The paper's ``Broadcast``/``Aggregate`` APIs accept application-specified
compression functions (Table II; refs [37] QSGD, [38] signSGD).  These are
the pure-JAX implementations; ``repro.kernels.quantize`` is the Pallas TPU
version of the QSGD hot loop (bit-identical given the same random bits).

Compressed transport (docs/performance.md "compressed transport"): a
``CompressionPolicy`` rides on ``AppHandle.compression`` (or the async
scheduler's ``app_compression`` knob) and governs the *commit* direction
— workers' delta uploads.  ``quantize_delta`` serializes an update
pytree into a ``QuantizedDelta`` (int8 payload + per-chunk f32 scales),
``CommitDelta`` buffers it as-is, and ``ApplyBuffered`` dequantizes
*inside* the buffered aggregation (``kernels.ops.
buffered_aggregate_quantized``: per-row scales compose with the
staleness weights in one kernel call).  The scheduler prices commit
flows at ``CompressionPolicy.wire_bytes(model_bytes)``, so the
compressed byte count is what enters ``EventCore.open_flow`` — fair
shares, caps, relay admission and sampled cold loads all see the
smaller flows.  ``kind="none"`` is proven byte-identical to the
uncompressed path (tests/test_compression.py).

Rounding bits: every commit draws its own PRNG key via ``commit_key``
(policy seed -> app -> commit sequence number), so repeated commits do
not share rounding bias — the old deterministic default (``rand=0.5``
everywhere) rounded every commit half-down identically.  A fixed
(policy, app, seq) triple reproduces the wire bytes exactly.

Compressed downlink (docs/performance.md "compressed downlink"): the
``downlink`` axis governs the *broadcast* direction — the master's
model downloads.  ``"qsgd-int8"`` quantizes each new version before it
ships; ``"delta-qsgd"`` broadcasts ``quantize(params_v+1 - ref_v)``
against a bounded per-app version-delta cache, where ``ref_v`` is the
reference reconstruction every delta-following worker holds (error
feedback on the downlink: the reference absorbs each step's quantizer
error, so drift from the true params stays one quantization bound, it
never compounds).  A worker K versions behind downloads the chained
deltas for its gap; past ``chain_cap`` (or with no cached base at all —
first download, churn rejoin) it falls back to the full f32 state.
Delta payloads pack the small ``downlink_levels`` lattice at
``downlink_bits`` bits per element (``delta_wire_bytes``); the
scheduler prices every broadcast leg at ``downlink_wire_bytes`` and the
fused ``kernels.ops.apply_quantized_broadcast`` kernel folds a whole
chain into the held params in one pass (``apply_delta_chain``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp


def qsgd_quantize(x: jax.Array, *, levels: int = 127, key=None, rand=None):
    """Stochastic int8 quantization with per-row scale.

    x: (..., d).  Returns (q int8, scale f32 (..., 1)).
    ``rand``: optional precomputed uniforms in [0,1) (for bit-exact refs).
    With neither ``key`` nor ``rand``, rounding is deterministic
    round-half-down (``rand=0.5``) — fine for one-shot use, but commits
    must thread a per-commit key (``commit_key``) or they all share the
    same rounding bias.
    """
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / levels
    scale = jnp.maximum(scale, 1e-12)
    y = xf / scale
    if rand is None:
        rand = (
            jax.random.uniform(key, x.shape) if key is not None else jnp.full(x.shape, 0.5)
        )
    q = jnp.floor(y + rand).astype(jnp.int8)
    return q, scale


def qsgd_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def signsgd_compress(x: jax.Array):
    """1-bit sign compression with mean-|x| scale."""
    xf = x.astype(jnp.float32)
    scale = jnp.mean(jnp.abs(xf), axis=-1, keepdims=True)
    return jnp.sign(xf).astype(jnp.int8), scale


def signsgd_decompress(s: jax.Array, scale: jax.Array) -> jax.Array:
    return s.astype(jnp.float32) * scale


def topk_sparsify(x: jax.Array, frac: float):
    """Keep the top-``frac`` fraction by |value| (per leading row)."""
    xf = x.astype(jnp.float32)
    flat = xf.reshape(xf.shape[0], -1) if xf.ndim > 1 else xf[None]
    k = max(1, int(flat.shape[-1] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    out = jnp.zeros_like(flat)
    out = jax.vmap(lambda o, i, f: o.at[i].set(f[i]))(out, idx, flat)
    return out.reshape(xf.shape)


def error_feedback_update(x: jax.Array, err: jax.Array, compress_fn):
    """EF-SGD: compress (x + err), carry the residual forward."""
    target = x.astype(jnp.float32) + err
    c, scale = compress_fn(target)
    approx = c.astype(jnp.float32) * scale
    return (c, scale), target - approx


# -- per-app commit compression policy (bytes on the wire) ---------------------

_KINDS = ("none", "qsgd-int8", "signsgd", "topk")
_DOWNLINK_KINDS = ("none", "qsgd-int8", "delta-qsgd")


@dataclass(frozen=True)
class CompressionPolicy:
    """Per-app compression for both wire directions (paper Table II's
    per-app compression hooks, made first-class for the transport model).

    Commit (uplink) axis — ``kind``: ``"none"`` (full f32 payloads, the
    byte-identical default), ``"qsgd-int8"`` (QSGD stochastic int8, one
    f32 max-abs scale per ``chunk`` elements), ``"signsgd"`` (1-bit sign
    + per-chunk mean-|x| scale, ref [38]), or ``"topk"`` (keep the
    ``topk_frac`` fraction by |value|, QSGD-quantized; wire ships int8
    value + i32 index per survivor).  ``levels`` is the quantization
    grid per sign (<= 127 so the lattice fits int8).  ``seed`` roots the
    per-commit rounding-key chain (``commit_key``).  ``error_feedback``
    turns on EF-SGD: the trainer carries each worker's residual
    ``x - deq(q(x))`` into its next commit, so aggressive ``levels``
    settings stay unbiased over rounds.

    Broadcast (downlink) axis — ``downlink``: ``"none"`` (full f32
    broadcasts, byte-identical to the uncompressed path),
    ``"qsgd-int8"`` (each new version ships quantized at ``levels``), or
    ``"delta-qsgd"`` (version deltas quantized at ``downlink_levels``
    and packed at ``downlink_bits`` bits/element; workers <= ``chain_cap``
    versions behind download the chained deltas, everyone else the full
    f32 state — see the module docstring for the reference-
    reconstruction scheme)."""

    kind: str = "none"
    levels: int = 127
    chunk: int = 256
    seed: int = 0
    topk_frac: float = 0.01
    error_feedback: bool = False
    downlink: str = "none"
    downlink_levels: int = 7
    chain_cap: int = 3

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"compression kind must be one of {_KINDS}, got {self.kind!r}")
        if not 1 <= int(self.levels) <= 127:
            raise ValueError(f"levels must be in [1, 127] (int8 lattice), got {self.levels!r}")
        if int(self.chunk) < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk!r}")
        if not 0.0 < float(self.topk_frac) <= 1.0:
            raise ValueError(f"topk_frac must be in (0, 1], got {self.topk_frac!r}")
        if self.downlink not in _DOWNLINK_KINDS:
            raise ValueError(
                f"downlink kind must be one of {_DOWNLINK_KINDS}, got {self.downlink!r}"
            )
        if not 1 <= int(self.downlink_levels) <= 127:
            raise ValueError(
                f"downlink_levels must be in [1, 127] (int8 lattice), "
                f"got {self.downlink_levels!r}"
            )
        if int(self.chain_cap) < 1:
            raise ValueError(f"chain_cap must be >= 1, got {self.chain_cap!r}")

    @property
    def enabled(self) -> bool:
        return self.kind != "none"

    @property
    def downlink_enabled(self) -> bool:
        return self.downlink != "none"

    def _rows(self, payload_bytes: float) -> int:
        return max(1, math.ceil(float(payload_bytes) / 4.0 / self.chunk))

    def wire_bytes(self, payload_bytes: float) -> float:
        """Modeled commit bytes on the wire for a ``payload_bytes`` f32
        payload.

        qsgd-int8 serializes n = payload_bytes/4 elements as one int8
        each, padded to whole chunks, plus one f32 scale per chunk —
        exactly ``QuantizedDelta.nbytes`` for a real n-element delta
        (tested).  signsgd bit-packs one sign per element (chunk/8 bytes
        per row) plus the per-chunk f32 scale.  topk ships k = ceil(n *
        topk_frac) survivors as int8 value + i32 index pairs plus the
        per-chunk scales.  ``kind="none"`` returns the input unchanged
        (same float object arithmetic as the uncompressed path, so
        pricing is bit-identical)."""
        if not self.enabled:
            return float(payload_bytes)
        n = float(payload_bytes) / 4.0
        rows = self._rows(payload_bytes)
        if self.kind == "signsgd":
            return float(rows * math.ceil(self.chunk / 8) + rows * 4)
        if self.kind == "topk":
            k = max(1, math.ceil(n * float(self.topk_frac)))
            return float(5 * k + rows * 4)
        return float(rows * self.chunk + rows * 4)

    @property
    def downlink_bits(self) -> int:
        """Bits per element of a packed broadcast delta: the minimal
        fixed width for the 2*downlink_levels+1 lattice points."""
        return max(1, math.ceil(math.log2(2 * int(self.downlink_levels) + 1)))

    def delta_wire_bytes(self, payload_bytes: float) -> float:
        """Modeled bytes of ONE quantized version delta: elements packed
        at ``downlink_bits`` bits plus one f32 scale per chunk.  (The
        in-memory ``QuantizedDelta`` keeps int8 — the packed size is the
        wire model, mirrored in ``QuantizedDelta.wire_nbytes``.)"""
        rows = self._rows(payload_bytes)
        return float(rows * math.ceil(self.chunk * self.downlink_bits / 8) + rows * 4)

    def downlink_wire_bytes(self, payload_bytes: float, chain: int | None = None) -> float:
        """Modeled bytes of one broadcast (download) to one worker.

        ``chain`` is the worker's version gap when it qualifies for the
        delta path (``downlink="delta-qsgd"``, base cached, gap <=
        ``chain_cap``) — ``chain=0`` is a version check with no payload,
        ``chain=k`` ships k cached deltas.  ``chain=None`` means the
        full path: the f32 state for ``delta-qsgd`` fallback (and for
        ``downlink="none"``), the quantized full model for
        ``downlink="qsgd-int8"`` (which never chains)."""
        if self.downlink == "delta-qsgd" and chain is not None:
            if int(chain) < 0:
                raise ValueError(f"delta chain must be >= 0, got {chain!r}")
            return float(chain) * self.delta_wire_bytes(payload_bytes)
        if self.downlink == "qsgd-int8":
            rows = self._rows(payload_bytes)
            return float(rows * self.chunk + rows * 4)
        return float(payload_bytes)


def as_policy(value) -> CompressionPolicy | None:
    """Normalize a policy knob: None, a ``CompressionPolicy``, or a kind
    string (``"qsgd-int8"``)."""
    if value is None or isinstance(value, CompressionPolicy):
        return value
    if isinstance(value, str):
        return CompressionPolicy(kind=value)
    raise TypeError(f"expected CompressionPolicy, kind string or None, got {value!r}")


def commit_key(policy: CompressionPolicy, app_idx: int, commit_seq: int):
    """The per-commit rounding key: policy seed -> app -> commit number.

    The sequence number is assigned when the scheduler delivers the
    commit (``AsyncTrainer.commit``), so the chain is deterministic for
    a given event trace: a fixed (seed, app, seq) reproduces the wire
    bytes exactly, while consecutive commits draw decorrelated uniforms
    (tests/test_compression.py)."""
    base = jax.random.PRNGKey(int(policy.seed))
    return jax.random.fold_in(jax.random.fold_in(base, int(app_idx)), int(commit_seq))


def broadcast_key(policy: CompressionPolicy, app_idx: int, version: int):
    """The per-broadcast rounding key: seed -> downlink lane -> app ->
    model version.  Folding a fixed lane constant first decorrelates the
    broadcast stream from the commit stream even when (app, version)
    collides with some (app, seq)."""
    base = jax.random.fold_in(jax.random.PRNGKey(int(policy.seed)), 0x0D0C)
    return jax.random.fold_in(jax.random.fold_in(base, int(app_idx)), int(version))


@dataclass(frozen=True)
class QuantizedDelta:
    """One worker delta serialized for the wire: int8 lattice points +
    per-chunk f32 scales + the pytree structure needed to rebuild it.

    ``q`` is (R, chunk) int8 (the flattened, zero-padded delta), ``scale``
    (R, 1) f32.  Dequantization is ``q * scale`` row-wise; padding
    elements quantize to exactly 0 (|0/scale + u| < 1 for u in [0, 1))
    and are dropped by ``unflatten``.

    ``wire_nbytes`` overrides the modeled wire size when the serialized
    format is narrower than the in-memory int8 grid (bit-packed signsgd,
    sparse topk, packed downlink deltas); ``None`` means the arrays ARE
    the wire format (dense qsgd-int8)."""

    q: np.ndarray
    scale: np.ndarray
    length: int                 # unpadded element count
    shapes: tuple               # leaf shapes, flatten order
    treedef: Any
    levels: int
    chunk: int
    wire_nbytes: float | None = None

    @property
    def nbytes(self) -> float:
        """Serialized wire size (what ``CommitDelta`` accounts)."""
        if self.wire_nbytes is not None:
            return float(self.wire_nbytes)
        return float(self.q.nbytes + self.scale.nbytes)

    def unflatten(self, flat) -> Any:
        """Rebuild the delta pytree from a flat (>= length,) f32 vector."""
        vec = np.asarray(flat)[: self.length]
        leaves, off = [], 0
        for s in self.shapes:
            size = int(np.prod(s)) if s else 1
            leaves.append(vec[off : off + size].reshape(s))
            off += size
        return jax.tree.unflatten(self.treedef, leaves)

    def dequantize(self) -> Any:
        """Unfused reference: dequantize this delta alone (the fused
        apply-side path composes scales with staleness weights instead —
        ``kernels.ops.buffered_aggregate_quantized``)."""
        flat = self.q.astype(np.float32) * self.scale.astype(np.float32)
        return self.unflatten(flat.reshape(-1))


def _flatten_grid(delta, chunk: int):
    """Flatten a pytree onto the (rows, chunk) quantization grid."""
    leaves, treedef = jax.tree.flatten(delta)
    shapes = tuple(np.shape(l) for l in leaves)
    flat = jnp.concatenate(
        [jnp.ravel(l).astype(jnp.float32) for l in leaves]
    ) if leaves else jnp.zeros((0,), jnp.float32)
    n = int(flat.size)
    rows = max(1, math.ceil(n / chunk))
    padded = jnp.zeros((rows * chunk,), jnp.float32).at[:n].set(flat)
    return padded.reshape(rows, chunk), flat, n, shapes, treedef


def _qsgd_grid(x2d, key, levels: int):
    """QSGD-quantize one (rows, chunk) grid, kernel-routed when the
    chunking matches the Pallas 256-lane row."""
    rows, chunk = x2d.shape
    if key is None:
        rand = jnp.full((rows, chunk), 0.5, jnp.float32)
    else:
        rand = jax.random.uniform(key, (rows, chunk), jnp.float32)
    if chunk == 256:
        from repro.kernels import ops as kops

        return kops.qsgd_quantize(x2d, rand, levels=levels)
    return qsgd_quantize(x2d, levels=levels, rand=rand)


def quantize_delta(delta, policy: CompressionPolicy, key=None) -> QuantizedDelta:
    """Serialize an update pytree under ``policy`` (must be enabled).

    qsgd-int8 routes through the kernel wrapper (``kernels.ops.
    qsgd_quantize``: Pallas on TPU, compiled ref off-TPU) when the
    chunking matches the kernel's 256-lane row; any other ``chunk``
    takes the pure-JAX path — both are bit-identical given the same
    uniforms.  signsgd stores signs on the same int8 grid with a masked
    per-chunk mean-|x| scale (padding rows never dilute the mean); topk
    zeroes everything below the global top-``topk_frac`` cut, then
    QSGD-quantizes the survivors.  All three ride ``QuantizedDelta`` —
    the same buffer, the same fused dequantize-in-aggregate apply path —
    with ``wire_nbytes`` carrying the packed/sparse wire model where the
    int8 grid overstates it.  ``key=None`` falls back to deterministic
    round-half-down (tests only; the commit path always threads
    ``commit_key``)."""
    if not policy.enabled:
        raise ValueError("quantize_delta requires an enabled policy (kind != 'none')")
    chunk = int(policy.chunk)
    x2d, flat, n, shapes, treedef = _flatten_grid(delta, chunk)
    wire = None
    if policy.kind == "signsgd":
        rows = x2d.shape[0]
        counts = np.clip(n - chunk * np.arange(rows), 1, chunk).astype(np.float32)
        s = jnp.sum(jnp.abs(x2d), axis=-1, keepdims=True) / counts[:, None]
        q = jnp.sign(x2d).astype(jnp.int8)
        wire = policy.wire_bytes(4.0 * n)
    elif policy.kind == "topk":
        k = max(1, math.ceil(n * float(policy.topk_frac)))
        if n > k:
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            sparse = jnp.zeros_like(flat).at[idx].set(flat[idx])
            rows = x2d.shape[0]
            x2d = jnp.zeros((rows * chunk,), jnp.float32).at[:n].set(sparse)
            x2d = x2d.reshape(rows, chunk)
        q, s = _qsgd_grid(x2d, key, int(policy.levels))
        wire = policy.wire_bytes(4.0 * n)
    else:
        q, s = _qsgd_grid(x2d, key, int(policy.levels))
    return QuantizedDelta(
        q=np.asarray(q), scale=np.asarray(s), length=n, shapes=shapes,
        treedef=treedef, levels=int(policy.levels), chunk=chunk,
        wire_nbytes=wire,
    )


def dequantize_delta(qd: QuantizedDelta) -> Any:
    return qd.dequantize()


# -- downlink: version deltas + fused chain application ------------------------


def quantize_broadcast_delta(delta, policy: CompressionPolicy, key=None) -> QuantizedDelta:
    """Serialize one version delta for the broadcast direction: QSGD on
    the coarse ``downlink_levels`` lattice, ``wire_nbytes`` set to the
    bit-packed size (``delta_wire_bytes``) the scheduler prices chained
    downloads at."""
    if not policy.downlink_enabled:
        raise ValueError(
            "quantize_broadcast_delta requires an enabled downlink (downlink != 'none')"
        )
    chunk = int(policy.chunk)
    x2d, _, n, shapes, treedef = _flatten_grid(delta, chunk)
    levels = int(policy.downlink_levels) if policy.downlink == "delta-qsgd" else int(policy.levels)
    q, s = _qsgd_grid(x2d, key, levels)
    wire = policy.downlink_wire_bytes(4.0 * n, chain=1)
    return QuantizedDelta(
        q=np.asarray(q), scale=np.asarray(s), length=n, shapes=shapes,
        treedef=treedef, levels=levels, chunk=chunk, wire_nbytes=wire,
    )


def apply_delta_chain(params, deltas: list) -> Any:
    """Fold a chain of quantized version deltas into ``params`` in ONE
    fused dequantize-and-apply pass (``kernels.ops.
    apply_quantized_broadcast``; pure-JAX for non-kernel chunkings).

    The deltas are accumulated strictly in chain order, element-wise —
    the same additions, in the same order, as applying them one version
    at a time — so a stale worker folding its whole gap in one call
    lands on the same reconstruction the master maintained
    incrementally.  All deltas must share one (rows, chunk) grid (same
    model, same policy)."""
    if not deltas:
        return params
    qd0 = deltas[0]
    rows, chunk = qd0.q.shape
    leaves = jax.tree.leaves(params)
    flat = np.concatenate(
        [np.ravel(np.asarray(l)).astype(np.float32) for l in leaves]
    ) if leaves else np.zeros((0,), np.float32)
    if flat.size != qd0.length:
        raise ValueError(
            f"params have {flat.size} elements but the chain was built for {qd0.length}"
        )
    w2d = np.zeros((rows * chunk,), np.float32)
    w2d[: flat.size] = flat
    w2d = w2d.reshape(rows, chunk)
    q = np.stack([d.q for d in deltas])          # (D, rows, chunk) int8
    s = np.stack([d.scale for d in deltas])      # (D, rows, 1) f32
    if chunk == 256:
        from repro.kernels import ops as kops

        out = np.asarray(kops.apply_quantized_broadcast(w2d, q, s))
    else:
        out = w2d
        for d in range(q.shape[0]):
            out = out + q[d].astype(np.float32) * s[d]
    rebuilt = qd0.unflatten(out.reshape(-1))
    return jax.tree.map(
        lambda p, v: np.asarray(v, dtype=np.asarray(p).dtype), params, rebuilt
    )
