"""Utility-based client selection for the async buffered scheduler.

The PR-2 ``AsyncBufferScheduler`` admits every live worker into every
cycle, so chronic stragglers keep feeding stale, slow commits into the
buffer and bound time-to-accuracy (FedBuff's warning; see PAPERS.md,
"Practical Federated Learning without a Server" / "EdgeFL").  This
module makes selection pluggable: the scheduler *offers* each would-be
cycle to a ``ClientSelector`` and only starts it if the selector admits
the worker; parked workers are re-offered at the app's next buffered
apply.

Two selectors ship:

- ``UniformSelector`` — admits everyone.  It is the default oracle: a
  run with a ``UniformSelector`` is trace-identical to a run with no
  selector at all (asserted by tests/test_selection.py).
- ``UtilitySelector`` — Oort-style per-client utility
  ``U(w) = stat(w) * sys(w)``:

  * statistical term ``stat``: EMA of the client's recent training
    signal (local loss when the data plane reports it, delta-norm as a
    fallback, 1.0 cold-start) — clients whose data still moves the
    model score high;
  * system term ``sys``: 1 while the client's observed cycle time
    (download + compute + upload, in simulator milliseconds) stays
    within ``deadline_ms``, and ``(deadline / cycle)^penalty`` beyond it
    — chronic stragglers decay toward 0;
  * admission: a worker is admitted when its utility reaches the
    ``admit_quantile`` of the app's current utilities, with an
    ``epsilon`` exploration floor (a seeded draw that admits *any*
    worker, blocked or not, with probability epsilon — the liveness
    lower bound: no client starves forever);
  * blocklist decay: ``blocklist_after`` consecutive deadline misses
    park the worker for ``blocklist_rounds * misses`` offers; each
    declined offer burns one, so the block decays and repeat offenders
    are parked longer, while a within-deadline commit walks the miss
    count back down.

All randomness comes from one seeded generator and every hook fires in
deterministic event order, so selection is reproducible run-to-run.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class ClientSelector(Protocol):
    """What ``AsyncBufferScheduler`` needs from a selection policy.

    ``admit`` gates a worker's next cycle (called once per offer, in
    deterministic event order).  ``on_commit`` reports the system term's
    raw signal (observed cycle wall-clock, ms).  ``on_train`` reports
    the statistical term's signal when a data plane exists (the trainer
    calls it at apply time with the client's fresh local loss and delta
    norm).  ``on_defer`` reports that one of the worker's commits was
    held back at a contended relay (``core/sim.RelayAdmission``); the
    hold time is already inside the cycle wall-clock ``on_commit``
    reports (the deadline term sees it automatically), so ``on_defer``
    is attribution: it lets a policy distinguish transport-deferred
    workers from genuinely slow ones.
    ``on_force_admit`` fires when the scheduler's liveness guard admits
    a worker without consulting ``admit`` (fewer than K cycles in
    flight): a blocklist must drain then, or selection could pin the
    very workers the buffer needs.  ``scores`` exposes the current
    utilities for telemetry.
    """

    def admit(self, app_idx: int, worker: int, now_ms: float) -> bool: ...

    def on_commit(self, app_idx: int, worker: int, now_ms: float, cycle_ms: float) -> None: ...

    def on_train(self, app_idx: int, worker: int, loss: float, delta_norm: float) -> None: ...

    def on_defer(self, app_idx: int, worker: int, now_ms: float, waited_ms: float) -> None: ...

    def on_force_admit(self, app_idx: int, worker: int) -> None: ...

    def scores(self, app_idx: int) -> dict[int, float]: ...


class UniformSelector:
    """Admit every worker, always — the PR-2 behavior as a selector.

    Kept as the default oracle: ``selector=None`` and
    ``selector=UniformSelector()`` must produce identical event traces.
    """

    def admit(self, app_idx: int, worker: int, now_ms: float) -> bool:
        return True

    def on_commit(self, app_idx: int, worker: int, now_ms: float, cycle_ms: float) -> None:
        pass

    def on_train(self, app_idx: int, worker: int, loss: float, delta_norm: float) -> None:
        pass

    def on_defer(self, app_idx: int, worker: int, now_ms: float, waited_ms: float) -> None:
        pass

    def on_force_admit(self, app_idx: int, worker: int) -> None:
        pass

    def scores(self, app_idx: int) -> dict[int, float]:
        return {}


class _ClientStats:
    __slots__ = (
        "stat", "cycle_ms", "defer_ms", "misses", "block_offers",
        "commits", "offers", "admitted", "defers", "force_admits",
    )

    def __init__(self):
        self.stat = None  # EMA of loss (preferred) or delta norm
        self.cycle_ms = None  # EMA of observed cycle time
        self.defer_ms = 0.0  # EMA of relay-admission hold time per cycle
        self.misses = 0  # consecutive deadline misses
        self.block_offers = 0  # offers left to decline (blocklist decay)
        self.commits = 0
        self.offers = 0
        self.admitted = 0
        self.defers = 0
        self.force_admits = 0


class UtilitySelector:
    """Oort-style utility gate: ``U = stat * sys`` with ε-exploration.

    Parameters
    ----------
    deadline_ms: round deadline for the system term; cycles beyond it
        are penalized by ``(deadline / cycle)^penalty``.
    epsilon: exploration floor — every offer is admitted with this
        probability regardless of utility or blocklist, so no client is
        starved forever (tests/test_selection.py asserts the bound).
    admit_quantile: utility quantile a worker must reach among its
        app's currently-known utilities (0.5 = top half admitted).
    blocklist_after / blocklist_rounds: ``blocklist_after`` consecutive
        deadline misses block the worker for ``blocklist_rounds * misses``
        offers; the block decays one offer at a time.
    ema: smoothing for both the statistical and system EMAs.
    """

    def __init__(
        self,
        *,
        deadline_ms: float = 250.0,
        epsilon: float = 0.1,
        penalty: float = 2.0,
        admit_quantile: float = 0.5,
        blocklist_after: int = 3,
        blocklist_rounds: int = 8,
        ema: float = 0.3,
        seed: int = 0,
    ):
        self.deadline_ms = float(deadline_ms)
        self.epsilon = float(epsilon)
        self.penalty = float(penalty)
        self.admit_quantile = float(admit_quantile)
        self.blocklist_after = int(blocklist_after)
        self.blocklist_rounds = int(blocklist_rounds)
        self.ema = float(ema)
        self.rng = np.random.default_rng(seed)
        self._stats: dict[tuple[int, int], _ClientStats] = {}
        self.parked_total = 0  # declined offers (telemetry)
        # placement loop (set by AsyncBufferScheduler when a
        # PlacementEngine is attached): called as hook(app_idx, worker,
        # kind, magnitude_ms).  With a hook present, a blocklist-bound
        # worker whose slowness is transport-attributed (defer EMA
        # dominates its cycle) is handed to the planner for re-placement
        # INSTEAD of being blocklisted — moving it beats benching it.
        # hook=None keeps the legacy policy bit-for-bit.
        self.placement_hook = None
        self.replaced_total = 0  # blocklists converted to re-placements
        # defer share of the cycle EMA above which a miss is considered
        # transport-caused rather than compute-caused
        self.defer_fraction = 0.5

    # -- internals -------------------------------------------------------------

    def _s(self, ai: int, w: int) -> _ClientStats:
        return self._stats.setdefault((ai, w), _ClientStats())

    def _utility(self, st: _ClientStats) -> float:
        stat = 1.0 if st.stat is None else max(float(st.stat), 1e-6)
        # relay-admission hold time already lands in the deadline term:
        # the scheduler reports end-to-end cycle wall-clock, deferral
        # included — defer_ms is kept separately only as attribution
        # (transport-deferred vs genuinely slow), never added on top
        if st.cycle_ms is None or st.cycle_ms <= self.deadline_ms:
            sys_term = 1.0
        else:
            sys_term = (self.deadline_ms / float(st.cycle_ms)) ** self.penalty
        return stat * sys_term

    # -- ClientSelector hooks --------------------------------------------------

    def admit(self, app_idx: int, worker: int, now_ms: float) -> bool:
        st = self._s(app_idx, worker)
        st.offers += 1
        explore = float(self.rng.random()) < self.epsilon
        if explore:  # liveness floor: blocklist and utility both bypassed
            st.admitted += 1
            return True
        if st.block_offers > 0:
            st.block_offers -= 1
            self.parked_total += 1
            return False
        if st.cycle_ms is None and st.stat is None:
            st.admitted += 1  # cold start: nothing observed yet
            return True
        utils = [self._utility(s) for (ai, _), s in self._stats.items() if ai == app_idx]
        bar = float(np.quantile(utils, self.admit_quantile)) if utils else 0.0
        if self._utility(st) >= bar:
            st.admitted += 1
            return True
        self.parked_total += 1
        return False

    def on_commit(self, app_idx: int, worker: int, now_ms: float, cycle_ms: float) -> None:
        st = self._s(app_idx, worker)
        st.commits += 1
        # defer attribution decays with each landed commit, mirroring the
        # cycle EMA (a commit that was not deferred walks it toward zero)
        st.defer_ms *= 1.0 - self.ema
        st.cycle_ms = (
            float(cycle_ms)
            if st.cycle_ms is None
            else self.ema * float(cycle_ms) + (1.0 - self.ema) * st.cycle_ms
        )
        if cycle_ms > self.deadline_ms:
            st.misses += 1
            if st.misses >= self.blocklist_after:
                if (
                    self.placement_hook is not None
                    and st.defer_ms >= self.defer_fraction * float(st.cycle_ms)
                ):
                    # transport-deferred, not slow: re-place instead of
                    # blocklisting; misses reset so the worker re-earns
                    # a block only if it stays late AFTER the move
                    self.placement_hook(app_idx, worker, "transport", float(st.defer_ms))
                    self.replaced_total += 1
                    st.misses = 0
                else:
                    st.block_offers = self.blocklist_rounds * st.misses
                    if self.placement_hook is not None:
                        # deadline-attributed block: still tell the
                        # planner, a better path may yet shorten cycles
                        self.placement_hook(app_idx, worker, "deadline", float(cycle_ms))
        else:
            st.misses = max(0, st.misses - 1)

    def on_train(self, app_idx: int, worker: int, loss: float, delta_norm: float) -> None:
        signal = float(loss) if np.isfinite(loss) else float(delta_norm)
        st = self._s(app_idx, worker)
        st.stat = signal if st.stat is None else self.ema * signal + (1.0 - self.ema) * st.stat

    def on_defer(self, app_idx: int, worker: int, now_ms: float, waited_ms: float) -> None:
        st = self._s(app_idx, worker)
        st.defers += 1
        st.defer_ms = self.ema * float(waited_ms) + (1.0 - self.ema) * st.defer_ms

    def on_force_admit(self, app_idx: int, worker: int) -> None:
        """Liveness-guard admission: drain the blocklist (satellite fix).
        The scheduler needs this worker to keep the buffer filling, so a
        standing block would only re-park it the moment pressure drops —
        misses are kept, so a still-slow worker can re-earn its block."""
        st = self._s(app_idx, worker)
        st.force_admits += 1
        st.block_offers = 0

    def scores(self, app_idx: int) -> dict[int, float]:
        return {
            w: self._utility(st) for (ai, w), st in sorted(self._stats.items()) if ai == app_idx
        }

    # -- telemetry -------------------------------------------------------------

    def commit_counts(self, app_idx: int) -> dict[int, int]:
        return {w: st.commits for (ai, w), st in sorted(self._stats.items()) if ai == app_idx}
