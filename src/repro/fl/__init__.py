"""Federated-learning substrate (Totoro+ data plane on the mesh)."""
