"""Async buffered FL data plane: real training under the event clock.

``AsyncTrainer`` is the data-plane counterpart of
``core/sim.AsyncBufferScheduler``: the scheduler decides *when* a
worker's download / compute / upload events fire; the trainer decides
*what* those events mean for the model.  It threads per-worker model
versions through the system — a worker trains from the (possibly stale)
global version it downloaded, and the master keeps every version that
still has in-flight workers so their deltas can be reproduced exactly.

The actual gradient work is the same jitted path the synchronous engine
uses: when an apply fires, the buffered commits are grouped by model
version and each group runs through ``engine.batched_local_train`` as
one vmap (one XLA dispatch per version, not per worker).  Deltas then
flow through the Table-II async verbs — ``CommitDelta`` per worker
(per-edge traffic up the tree) and one ``ApplyBuffered`` (staleness
discount folded into the ``tree_aggregate_groups`` kernel's weight
vector) — so with a full buffer of staleness-0 commits and alpha = 0 the
applied update equals the synchronous round's aggregate to fp tolerance
(tests/test_async.py).

Units and invariants: times are simulated milliseconds from the
scheduler's clock (``t_ms``); payload sizes are bytes (``model_bytes``
and the verbs' ``bytes`` metrics); staleness is counted in model
versions.  Version bookkeeping is refcounted — a snapshot is kept
exactly as long as some in-flight worker may still commit against it
(``_gc_snapshots``), and weight normalization happens once, inside
``ApplyBuffered``'s kernel call, never per level.

The trainer is also the feedback path for utility-based selection
(``fl/selection.UtilitySelector``): at apply time it reports each
client's fresh local loss and delta norm through ``selector.on_train``,
giving the selector its statistical utility term; the scheduler
separately reports observed cycle times (the system term).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.fl import engine
from repro.fl.compression import (
    CompressionPolicy,
    apply_delta_chain,
    as_policy,
    broadcast_key,
    commit_key,
    quantize_broadcast_delta,
    quantize_delta,
)


class AsyncTrainer:
    """Per-app version store + buffered-apply data plane.

    ``apps``: ``fl/rounds.FLApp`` instances (params, shards, hyperparams).
    ``staleness_alpha``: exponent of the 1/(1+s)^a weight discount.
    ``selector``: optional ``fl/selection.ClientSelector`` — fed each
    client's local loss + delta norm at apply time (statistical utility).
    ``compression``: per-app ``CompressionPolicy`` (scalar broadcast or
    list; ``None`` falls back to each ``AppHandle.compression``).  An
    enabled policy quantizes every commit delta (``quantize_delta``)
    under a per-commit rounding key before it enters ``CommitDelta`` —
    the buffered entries then carry ``QuantizedDelta`` wire payloads and
    ``ApplyBuffered`` dequantizes inside the aggregation kernel.  With
    ``error_feedback`` set, each worker's quantization residual
    ``x - deq(q(x))`` is carried into its next commit (EF-SGD), so
    coarse ``levels`` settings stay unbiased over rounds; a failed
    worker loses its residual with the rest of its local state.

    A policy with ``downlink != "none"`` also compresses the broadcast
    direction: the per-version snapshot the workers train from becomes
    the *broadcast state* — ``deq(quantize(params_v))`` for
    ``downlink="qsgd-int8"``, or for ``"delta-qsgd"`` the reference
    reconstruction updated by one fused ``apply_delta_chain`` step per
    apply, with the quantized version delta cached (bounded to
    ``chain_cap`` entries) so stale workers can chain their gap.  Every
    worker at version v holds the same canonical state, so version-group
    megabatching is untouched; the master always aggregates into the
    exact f32 params.
    """

    def __init__(
        self, system, apps, *, staleness_alpha: float = 0.5, replicate: bool = True,
        selector=None, megabatch: bool = True, compression=None,
    ):
        self.system = system
        self.apps = list(apps)
        self.staleness_alpha = float(staleness_alpha)
        self.replicate = replicate
        self.selector = selector
        self.megabatch = bool(megabatch)
        n = len(self.apps)
        if isinstance(compression, (str, CompressionPolicy)):
            compression = [compression] * n
        if compression is None:
            compression = [getattr(a.handle, "compression", None) for a in self.apps]
        assert len(compression) == n
        self._compression = [as_policy(p) for p in compression]
        # monotone per-app commit counter: seeds each commit's rounding
        # key (compression.commit_key) so rounding bits never repeat
        self._commit_seq = [0] * n
        self.version = [0] * n
        self._snapshots = [{0: a.params} for a in self.apps]  # version -> params
        self._refs = [{0: 0} for _ in range(n)]  # version -> in-flight users
        self._worker_version = [dict() for _ in range(n)]  # worker -> version
        self._pending = [[] for _ in range(n)]  # committed (worker, version, seq)
        # EF-SGD residual store: worker -> residual pytree (error_feedback)
        self._ef = [dict() for _ in range(n)]
        # downlink delta-qsgd state: the reference reconstruction the
        # workers hold (== _snapshots[ai][version]) and the bounded
        # version-delta cache, keyed by the version each delta produces
        self._recon = [a.params for a in self.apps]
        self._delta_cache = [dict() for _ in range(n)]  # version -> QuantizedDelta
        self.history: list[dict] = []

    # -- scheduler hooks -------------------------------------------------------

    def workers(self, ai: int) -> list[int]:
        app = self.apps[ai]
        return [w for w in sorted(app.handle.tree.members) if w in app.data]

    def begin_download(self, ai: int, w: int) -> None:
        """The master transmits the current version to ``w``: pin it."""
        v = self.version[ai]
        self._worker_version[ai][w] = v
        self._refs[ai][v] = self._refs[ai].get(v, 0) + 1

    def commit(self, ai: int, w: int, t: float) -> None:
        """``w``'s upload landed: move it to the apply queue (its delta is
        materialized lazily at apply time, batched with its version peers).
        The commit sequence number is pinned here — delivery order — so a
        worker lapping the buffer twice gets two distinct rounding keys."""
        v = self._worker_version[ai].pop(w)
        seq = self._commit_seq[ai]
        self._commit_seq[ai] += 1
        self._pending[ai].append((w, v, seq))

    def drop(self, ai: int, w: int) -> None:
        """``w`` failed mid-cycle: release its version pin.  Commits it
        already delivered stay buffered — the master has them.  Its
        EF-SGD residual is local state and dies with it."""
        v = self._worker_version[ai].pop(w, None)
        if v is not None:
            self._refs[ai][v] -= 1
        self._ef[ai].pop(w, None)

    def delta_chain(self, ai: int, base: int, target: int) -> list:
        """The cached broadcast deltas reconstructing ``base -> target``
        (one per version step).  Raises ``KeyError`` past the cache
        window — exactly the gap the scheduler prices as a full f32
        fallback download."""
        return [self._delta_cache[ai][v] for v in range(base + 1, target + 1)]

    def _broadcast_state(self, ai: int, params, version: int, policy) -> object:
        """What a worker downloading ``version`` actually receives.

        ``downlink="qsgd-int8"``: the dequantized full-model broadcast.
        ``"delta-qsgd"``: the reference reconstruction — the previous
        reference plus this version's quantized delta, folded in by one
        fused ``apply_delta_chain`` step.  Quantizing against the
        *reference* (not the previous exact params) is error feedback on
        the downlink: the reference stays within one quantizer bound of
        the true params at every version, and a worker chaining cached
        deltas from any base lands bit-for-bit on this state."""
        if policy.downlink == "qsgd-int8":
            qd = quantize_broadcast_delta(params, policy, broadcast_key(policy, ai, version))
            deq = qd.dequantize()
            return jax.tree.map(
                lambda p, v: np.asarray(v, dtype=np.asarray(p).dtype), params, deq
            )
        delta = jax.tree.map(
            lambda p, r: np.asarray(p, np.float32) - np.asarray(r, np.float32),
            params, self._recon[ai],
        )
        qd = quantize_broadcast_delta(delta, policy, broadcast_key(policy, ai, version))
        cache = self._delta_cache[ai]
        cache[version] = qd
        for v in [v for v in cache if v <= version - int(policy.chain_cap)]:
            del cache[v]
        self._recon[ai] = apply_delta_chain(self._recon[ai], [qd])
        return self._recon[ai]

    def apply(
        self, ai: int, t: float, *, k: int | None = None, selector_scores=None,
        transport: dict | None = None,
    ) -> dict | None:
        """Buffer is full: train each version group, commit the deltas,
        apply the staleness-weighted update, bump the global version.

        ``k`` (the effective buffer threshold that triggered this apply),
        ``selector_scores`` (the selector's per-client utilities at
        apply time) and ``transport`` (the scheduler's fairness snapshot:
        per-app uplink bytes/throughput and Jain's index) are telemetry
        from the scheduler; they ride into the app handle's
        ``round_records`` via ``ApplyBuffered``.
        """
        app = self.apps[ai]
        pending, self._pending[ai] = self._pending[ai], []
        if not pending:  # commit batch drained (e.g. by churn)
            return None
        cur = self.version[ai]
        groups: dict[int, list[tuple[int, int]]] = {}
        for w, v, seq in pending:
            groups.setdefault(v, []).append((w, seq))
        versions = sorted(groups)
        if self.megabatch:
            # every version group of this apply stacks into ONE compiled
            # dispatch: megabatched_local_train carries per-worker start
            # params, so staleness-ragged buffers stop costing one XLA
            # program (and often one compile) per version
            trained = engine.fused_local_training(
                [(app, [w for w, _ in groups[v]], self._snapshots[ai][v]) for v in versions]
            )
        else:  # pre-optimization path: one dispatch per version group
            trained = [
                engine.local_training(
                    app, [w for w, _ in groups[v]], params=self._snapshots[ai][v],
                    bucketed=False,
                )
                for v in versions
            ]
        policy = self._compression[ai]
        losses, loss_weights = [], []
        for v, (deltas, weights, group_losses) in zip(versions, trained):
            ws = groups[v]
            for (w, seq), d, wt, l in zip(ws, deltas, weights, group_losses):
                payload = d
                if policy is not None and policy.enabled:
                    target = d
                    if policy.error_feedback:
                        # EF-SGD: fold the worker's carried residual into
                        # this commit before quantizing, then carry the
                        # fresh quantization error forward
                        r = self._ef[ai].get(w)
                        if r is not None:
                            target = jax.tree.map(
                                lambda a, b: jnp.asarray(a, jnp.float32) + b, d, r
                            )
                    payload = quantize_delta(target, policy, commit_key(policy, ai, seq))
                    if policy.error_feedback:
                        deq = payload.dequantize()
                        self._ef[ai][w] = jax.tree.map(
                            lambda a, b: jnp.asarray(a, jnp.float32)
                            - jnp.asarray(np.asarray(b), jnp.float32),
                            target, deq,
                        )
                self.system.CommitDelta(
                    app.handle.app_id, w, payload, weight=wt, staleness=cur - v
                )
                losses.append(l)
                loss_weights.append(wt)
                if self.selector is not None:
                    loss_val = float(l)
                    if np.isfinite(loss_val):
                        dnorm = 0.0  # loss is the stat signal; skip W host transfers
                    else:
                        dnorm = float(
                            np.sqrt(
                                sum(
                                    float(np.sum(np.square(np.asarray(x))))
                                    for x in jax.tree.leaves(d)
                                )
                            )
                        )
                    self.selector.on_train(ai, w, loss_val, dnorm)
            self._refs[ai][v] -= len(ws)
        stats = self.system.ApplyBuffered(
            app.handle.app_id, staleness_alpha=self.staleness_alpha,
            k=k, selector_scores=selector_scores, transport=transport,
        )
        agg = stats["result"]
        app.params = jax.tree.map(lambda p, d: (p + d).astype(p.dtype), app.params, agg)
        app.round_num += 1
        self.version[ai] = cur + 1
        # the snapshot is what workers RECEIVE for this version: the
        # exact params, or the compressed broadcast state when the
        # downlink axis is on (every worker at a version holds the same
        # canonical state, so version-group training is unchanged)
        held = app.params
        if policy is not None and policy.downlink_enabled:
            held = self._broadcast_state(ai, app.params, cur + 1, policy)
        self._snapshots[ai][cur + 1] = held
        self._refs[ai][cur + 1] = self._refs[ai].get(cur + 1, 0)
        self._gc_snapshots(ai)
        if self.replicate:
            self.system.replicate_master_state(
                app.handle.app_id, {"round": app.round_num, "version": cur + 1}
            )
        record = {
            "app_id": app.handle.app_id,
            "t_ms": t,
            "version": cur + 1,
            "arrivals": len(pending),
            "k": k,
            "loss": float(np.average(losses, weights=loss_weights)),
            "mean_staleness": float(np.mean([cur - v for _, v, _ in pending])),
        }
        self.history.append(record)
        app.history.append(record)
        return record

    def _gc_snapshots(self, ai: int) -> None:
        """Drop param versions no in-flight worker can still reference."""
        cur = self.version[ai]
        for v in [v for v, r in self._refs[ai].items() if r <= 0 and v != cur]:
            self._refs[ai].pop(v)
            self._snapshots[ai].pop(v, None)


def run_async(
    system,
    apps,
    *,
    applies: int,
    buffer_k: int | list[int],
    staleness_alpha: float = 0.5,
    model_bytes: float,
    compute_ms=50.0,
    base_ms: float = 5.0,
    churn=None,
    barrier: bool = False,
    adaptive: bool = False,
    adaptive_kwargs: dict | None = None,
    selector=None,
    fair: bool = True,
    app_weights=None,
    app_rate_caps=None,
    relay_admission=None,
    compression=None,
    megabatch: bool = True,
    incremental: bool = True,
    cohort: bool = True,
    congestion_mode: str = "exact",
    hot_threshold: int = 4,
    resample_every: float | None = None,
    resample_events: int | None = None,
    resample_target_error: float | None = None,
    placement=None,
    max_events: int = 1_000_000,
) -> dict:
    """Wire an ``AsyncTrainer`` under an ``AsyncBufferScheduler`` and run
    every app to ``applies`` buffered updates.  Returns the scheduler
    apply events, churn log, and the trainer's loss-vs-simtime history.

    ``megabatch=False`` restores the per-version-group dispatch loop and
    ``incremental=False`` the full-water-filling repricing engine — the
    pre-optimization hot paths kept as bench_hotpath baselines (both
    default on; results match to fp tolerance, event traces exactly).

    ``adaptive=True`` turns on per-app ``AdaptiveKController``s
    (``buffer_k`` seeds K); ``selector`` plugs a
    ``fl/selection.ClientSelector`` into both the scheduler (admission,
    cycle-time feedback) and the trainer (loss/delta-norm feedback).
    ``fair`` selects the weighted-fair transfer pricing (default; set
    False for the legacy start-time-only pricing), ``app_weights`` /
    ``app_rate_caps`` bias or bound per-app uplink shares, and
    ``relay_admission`` (a ``core.sim.RelayAdmission``) defers stale
    commits at contended relays.

    ``compression`` (a ``fl/compression.CompressionPolicy``, kind string,
    per-app list, or ``None`` for the handles' ``compression`` fields)
    turns on commit-direction quantization: the trainer serializes each
    delta to a ``QuantizedDelta`` and the scheduler prices commit legs
    at the compressed wire size (docs/performance.md "compressed
    transport").  A policy's ``downlink`` axis additionally compresses
    broadcasts — the scheduler prices each download at the worker's
    delta-chain (or fallback) size and the trainer serves the matching
    broadcast state (docs/performance.md "compressed downlink");
    ``error_feedback`` carries per-worker EF-SGD residuals across
    commits.

    Scale knobs (docs/performance.md "scale layer"): ``cohort`` batches
    per-worker events into one heap entry per app (trace-identical,
    default on); ``congestion_mode="sampled"`` prices cold cycles
    statistically with ``hot_threshold`` selecting which uplinks stay
    exact, and ``resample_every`` (simulated ms) / ``resample_events``
    (dispatch count) periodically re-price in-flight cold cycles against
    current loads; ``max_events`` raises the event budget for large
    scale runs.  ``resample_target_error`` makes the sampled-congestion
    cadence adaptive (tighten/relax around a target apply-time drift).

    ``placement`` (a ``core.pathplan.PlacementEngine`` or ``True`` for
    defaults) turns on live utility-aware placement: replans on churn /
    defer / contention triggers, re-grafts through the forest's batched
    moves, and feeds selector defer-attribution back into the planner
    (docs/architecture.md "placement layer").  ``None`` (default) keeps
    static placement with byte-identical traces."""
    from repro.core.sim import AsyncBufferScheduler

    trainer = AsyncTrainer(
        system, apps, staleness_alpha=staleness_alpha, selector=selector,
        megabatch=megabatch, compression=compression,
    )
    sched = AsyncBufferScheduler(
        system,
        [a.handle for a in apps],
        model_bytes=model_bytes,
        compute_ms=compute_ms,
        base_ms=base_ms,
        buffer_k=buffer_k,
        churn=churn,
        trainer=trainer,
        barrier=barrier,
        adaptive=adaptive,
        adaptive_kwargs=adaptive_kwargs,
        selector=selector,
        fair=fair,
        app_weights=app_weights,
        app_rate_caps=app_rate_caps,
        relay_admission=relay_admission,
        app_compression=compression,
        incremental=incremental,
        cohort=cohort,
        congestion_mode=congestion_mode,
        hot_threshold=hot_threshold,
        resample_every=resample_every,
        resample_events=resample_events,
        resample_target_error=resample_target_error,
        placement=placement,
    )
    events = sched.run(applies, max_events=max_events)
    return {
        "events": events,
        "churn": list(sched.churn_log),
        "history": list(trainer.history),
        "trainer": trainer,
        "scheduler": sched,
    }


def worker_compute_fn(base_ms: float = 40.0, spread: float = 6.0, seed: int = 0):
    """Deterministic heterogeneous edge-compute model: each (app, worker)
    draws a fixed slowdown in [1, spread] from a seeded hash — the same
    worker is always the same straggler, for sync and async alike.  The
    draw is memoized per (app, worker): it is called once per cycle
    event, and re-seeding a Generator each call was a measurable event-
    loop cost at M >= 16 (same values either way)."""

    cache: dict[tuple[int, int], float] = {}

    def per_worker(handle, worker, cycle: int = 0):
        key = (handle.app_id, worker)
        ms = cache.get(key)
        if ms is None:
            rng = np.random.default_rng([seed, handle.app_id, worker])
            ms = cache[key] = base_ms * (1.0 + (spread - 1.0) * float(rng.random()))
        return ms

    return per_worker


def sync_barrier_compute_fn(per_worker):
    """Sync counterpart of a per-worker compute model: the barrier round
    waits for the slowest subscribed worker."""

    def f(handle, round_num):
        members = sorted(handle.tree.members)
        return max((per_worker(handle, w) for w in members), default=0.0)

    return f
