"""Vectorized FL round engine: all workers of an app train in one kernel.

The seed's ``run_round`` dispatched one jitted ``local_train`` per worker
from a Python loop — W dispatches, W × E sequential SGD steps.  The
engine stacks every worker's shard into padded ``(W, B, ...)`` arrays
(mask marks the padding) and runs the E local steps as a single jitted
``vmap`` over the worker axis, so one XLA program trains the whole app.
A masked mean makes each worker's loss/gradient identical to what its
unpadded shard produces, so the vectorized path matches the per-worker
reference loop to fp tolerance (see tests/test_engine.py).

Shape-bucketed megabatching (the hot-path PR): ragged shard stacks used
to force one XLA *compile* per distinct (W, B) — at M=16 apps the
backend compiler dominated end-to-end wall-clock (23 s of a 37 s run in
the pre-optimization profile).  Two fixes:

- **bucketing** — ``pack_shards`` pads W and B up to power-of-two
  buckets (zero mask rows on phantom workers train to exactly-zero
  deltas, discarded on unstack), so every ragged stack hits one of
  O(log W * log B) compiled programs; the per-run jit cache-miss count
  is tracked by ``DISPATCH`` and gated in tests/test_hotpath.py.
- **fusion** — ``megabatched_local_train`` vmaps over *per-worker start
  params* as well, so commit batches training from different model
  versions — and different apps entirely, when their static config
  (model, steps, lr, mu) matches — stack into ONE dispatch
  (``fused_local_training``; per-job unstacking of deltas).

``local_training(..., vectorized=False)`` keeps the reference loop both
as the equivalence oracle and as the baseline the engine benchmark
compares against; ``set_bucketing(False)`` restores the exact-shape
pre-optimization packing (the bench_hotpath baseline).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl import small_models as sm

_BUCKETED = True  # module default for pack_shards/local_training bucketing


def set_bucketing(on: bool) -> bool:
    """Toggle shape-bucketed packing globally; returns the previous value."""
    global _BUCKETED
    prev, _BUCKETED = _BUCKETED, bool(on)
    return prev


# THE shape-bucket policy (next power of two), shared with the kernel
# wrappers so training-side and kernel-side bucketing stay in lockstep
from repro.kernels.ops import bucket_size  # noqa: E402  (re-export)


class DispatchStats:
    """Counts jitted training dispatches and (bucketed) jit cache misses.

    ``dispatches`` = calls into a jitted training entry point;
    ``compiles`` = dispatches whose (entry, static config, padded shape)
    key was never seen since the last ``reset()`` — with bucketing on,
    this is O(#buckets) per run instead of O(#distinct ragged shapes)
    (cross-checked against jax's own jit cache size in tests).
    """

    def __init__(self):
        self.reset()

    def reset(self) -> None:
        self.dispatches = 0
        self.compiles = 0
        self._keys: set = set()

    def record(self, key) -> None:
        self.dispatches += 1
        if key not in self._keys:
            self._keys.add(key)
            self.compiles += 1


DISPATCH = DispatchStats()


def pack_shards(
    data_by_worker: dict,
    workers: list[int],
    *,
    b_bucket: int | None = None,
    w_bucket: int | None = None,
):
    """Stack ragged worker shards into padded (W, B, ...) arrays + mask.

    Returns (x, y, mask): x (W, B, *feat) f32, y (W, B) i32, mask (W, B)
    f32 with 1.0 on real examples, 0.0 on padding.  ``b_bucket`` /
    ``w_bucket`` pad the batch / worker axes up to an absolute size
    (phantom workers are all-padding rows: zero mask, zero data — they
    train to exactly-zero deltas).
    """
    if not workers:  # a drained commit batch: empty padded stacks, not max([])
        z = np.zeros((0, 0), np.float32)
        return jnp.asarray(z), jnp.asarray(z, jnp.int32), jnp.asarray(z)
    bs = [len(data_by_worker[w][1]) for w in workers]
    B = max(bs) if bs else 1
    if b_bucket is not None:
        assert b_bucket >= B, (b_bucket, B)
        B = b_bucket
    W = len(workers)
    if w_bucket is not None:
        assert w_bucket >= W, (w_bucket, W)
        W = w_bucket
    x0 = np.asarray(data_by_worker[workers[0]][0])
    xs = np.zeros((W, B) + x0.shape[1:], np.float32)
    ys = np.zeros((W, B), np.int32)
    mask = np.zeros((W, B), np.float32)
    for i, w in enumerate(workers):
        x, y = data_by_worker[w]
        b = len(y)
        xs[i, :b] = np.asarray(x, np.float32)
        ys[i, :b] = np.asarray(y, np.int32)
        mask[i, :b] = 1.0
    return jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(mask)


def _masked_ce(logits, y, mask):
    ll = jnp.take_along_axis(jax.nn.log_softmax(logits), y[:, None], axis=1)[:, 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


@partial(jax.jit, static_argnames=("logits_fn", "steps", "lr", "mu"))
def batched_local_train(global_params, x, y, mask, *, logits_fn, steps: int, lr: float, mu: float = 0.0):
    """E local SGD steps for every worker at once: vmap over the W axis.

    Equivalent to running ``small_models.local_train`` per worker — the
    masked CE mean reproduces each shard's unpadded loss exactly.
    Returns (stacked new params (W, ...), per-worker mean loss (W,)).
    """

    def one_worker(xw, yw, mw):
        def loss_fn(p):
            base = _masked_ce(logits_fn(p, xw), yw, mw)
            if mu > 0:
                prox = sum(
                    jnp.sum(jnp.square(a - b))
                    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(global_params))
                )
                base = base + 0.5 * mu * prox
            return base

        def step(p, _):
            l, g = jax.value_and_grad(loss_fn)(p)
            p = jax.tree.map(lambda w, gg: w - lr * gg, p, g)
            return p, l

        params, losses = jax.lax.scan(step, global_params, None, length=steps)
        return params, jnp.mean(losses)

    return jax.vmap(one_worker)(x, y, mask)


@partial(jax.jit, static_argnames=("logits_fn", "steps", "lr", "mu"))
def megabatched_local_train(
    params_stack, x, y, mask, *, logits_fn, steps: int, lr: float, mu: float = 0.0
):
    """E local SGD steps with *per-worker start params*: vmap over
    (params, shard) together.

    The generalization that makes cross-version and cross-app fusion
    possible: ``batched_local_train`` closes over ONE global params
    pytree, so commit batches training from different model versions
    (or different apps) each needed their own dispatch.  Here every
    worker row carries its own start params (its FedProx anchor too),
    so any set of same-config jobs stacks into one compiled program.
    Returns (stacked new params (W, ...), per-worker mean loss (W,)).
    """

    def one_worker(p0, xw, yw, mw):
        def loss_fn(p):
            base = _masked_ce(logits_fn(p, xw), yw, mw)
            if mu > 0:
                prox = sum(
                    jnp.sum(jnp.square(a - b))
                    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p0))
                )
                base = base + 0.5 * mu * prox
            return base

        def step(p, _):
            l, g = jax.value_and_grad(loss_fn)(p)
            p = jax.tree.map(lambda w, gg: w - lr * gg, p, g)
            return p, l

        params, losses = jax.lax.scan(step, p0, None, length=steps)
        return params, jnp.mean(losses)

    return jax.vmap(one_worker)(params_stack, x, y, mask)


def local_training(
    app, workers: list[int], *, vectorized: bool = True, params=None,
    bucketed: bool | None = None,
):
    """Run the app's E local steps on every worker's shard.

    Returns (deltas, weights, losses) with one entry per worker, in
    ``workers`` order — deltas are model-update pytrees, weights the
    shard sizes (FedAvg weighting), losses the mean local losses.
    ``params`` overrides the starting model (the async path trains each
    commit batch from the — possibly stale — version its workers
    downloaded, not from ``app.params``).  ``bucketed`` pads (W, B) to
    power-of-two buckets so ragged shards reuse compiled programs
    (default: the module flag set by ``set_bucketing``).
    """
    if not workers:
        return [], [], []
    start = app.params if params is None else params
    logits_fn = sm.LOGITS[app.model]
    weights = [float(len(app.data[w][1])) for w in workers]
    if not vectorized:
        deltas, losses = [], []
        for w in workers:
            x, y = app.data[w]
            new_p, loss = sm.local_train(
                start, start, x, y,
                logits_fn=logits_fn, steps=app.local_steps, lr=app.lr, mu=app.mu,
            )
            deltas.append(jax.tree.map(lambda a, b: a - b, new_p, start))
            losses.append(float(loss))
        return deltas, weights, losses

    if bucketed is None:
        bucketed = _BUCKETED
    W = len(workers)
    if bucketed:
        B = max(len(app.data[w][1]) for w in workers)
        x, y, mask = pack_shards(
            app.data, workers, b_bucket=bucket_size(B), w_bucket=bucket_size(W)
        )
    else:
        x, y, mask = pack_shards(app.data, workers)
    DISPATCH.record(
        ("batched", app.model, app.local_steps, app.lr, app.mu, x.shape)
    )
    new_params, losses = batched_local_train(
        start, x, y, mask,
        logits_fn=logits_fn, steps=app.local_steps, lr=app.lr, mu=app.mu,
    )
    stacked = jax.tree.map(lambda n, p: n - p[None], new_params, start)
    # one device->host transfer per leaf, then cheap numpy row views —
    # per-worker device slicing would cost W x leaves dispatches
    stacked_np = jax.tree.map(np.asarray, stacked)
    deltas = [jax.tree.map(lambda l, i=i: l[i], stacked_np) for i in range(W)]
    return deltas, weights, [float(l) for l in np.asarray(losses)[:W]]


def fused_local_training(jobs: list, *, bucketed: bool | None = None) -> list:
    """Train many (app, workers, start_params) jobs in as few dispatches
    as possible — the cross-app / cross-version megabatch.

    ``jobs``: list of ``(app, workers, start_params)`` (``start_params``
    ``None`` = ``app.params``).  Jobs whose static training config
    (model, local_steps, lr, mu, feature shape) matches are stacked
    along the worker axis — each worker row carrying its own start
    params — padded to one (W, B) shape bucket, and run through a
    single ``megabatched_local_train`` dispatch; deltas/losses are then
    unstacked per job.  Returns ``[(deltas, weights, losses), ...]``
    aligned with ``jobs``.
    """
    if bucketed is None:
        bucketed = _BUCKETED
    results: list = [None] * len(jobs)
    groups: dict[tuple, list[int]] = {}
    for j, (app, workers, start) in enumerate(jobs):
        if not workers:
            results[j] = ([], [], [])
            continue
        feat = np.asarray(app.data[workers[0]][0]).shape[1:]
        if start is None:
            start = app.params
        # the param treedef + leaf shapes are part of the fusion key:
        # two apps may share a model NAME (and feat/steps/lr/mu) while
        # differing in num_classes or hidden sizes, and stacking those
        # into one params buffer would be a shape error
        params_sig = (
            jax.tree.structure(start),
            tuple(np.shape(l) for l in jax.tree.leaves(start)),
        )
        key = (app.model, app.local_steps, app.lr, app.mu, feat, params_sig)
        groups.setdefault(key, []).append(j)

    for key, idxs in groups.items():
        model, steps, lr, mu, feat, _params_sig = key
        logits_fn = sm.LOGITS[model]
        w_tot = sum(len(jobs[j][1]) for j in idxs)
        b_max = max(
            len(jobs[j][0].data[w][1]) for j in idxs for w in jobs[j][1]
        )
        W = bucket_size(w_tot) if bucketed else w_tot
        B = bucket_size(b_max) if bucketed else b_max
        xs = np.zeros((W, B) + feat, np.float32)
        ys = np.zeros((W, B), np.int32)
        mask = np.zeros((W, B), np.float32)
        row = 0
        spans = []  # (job index, row offset, worker count)
        for j in idxs:
            app, workers, _ = jobs[j]
            spans.append((j, row, len(workers)))
            for w in workers:
                x, yv = app.data[w]
                b = len(yv)
                xs[row, :b] = np.asarray(x, np.float32)
                ys[row, :b] = np.asarray(yv, np.int32)
                mask[row, :b] = 1.0
                row += 1
        # per-row start params; phantom rows reuse the first job's params
        # (zero mask -> zero grads -> exactly-zero deltas, discarded)
        first = jobs[idxs[0]][2]
        if first is None:
            first = jobs[idxs[0]][0].params
        leaves0, treedef = jax.tree.flatten(first)
        rows_per_leaf = [
            np.empty((W,) + np.shape(l), np.asarray(l).dtype) for l in leaves0
        ]
        for j, off, count in spans:
            start = jobs[j][2] if jobs[j][2] is not None else jobs[j][0].params
            for arr, leaf in zip(rows_per_leaf, jax.tree.leaves(start)):
                arr[off : off + count] = np.asarray(leaf)
        for arr, leaf in zip(rows_per_leaf, leaves0):
            arr[row:] = np.asarray(leaf)
        params_stack = jax.tree.unflatten(
            treedef, [jnp.asarray(a) for a in rows_per_leaf]
        )
        DISPATCH.record(("mega", model, steps, lr, mu, xs.shape, _params_sig))
        new_params, losses = megabatched_local_train(
            params_stack, jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(mask),
            logits_fn=logits_fn, steps=steps, lr=lr, mu=mu,
        )
        stacked = jax.tree.map(lambda n, p: n - p, new_params, params_stack)
        stacked_np = jax.tree.map(np.asarray, stacked)
        losses_np = np.asarray(losses)
        for j, off, count in spans:
            app, workers, _ = jobs[j]
            deltas = [
                jax.tree.map(lambda l, i=off + i: l[i], stacked_np)
                for i in range(count)
            ]
            weights = [float(len(app.data[w][1])) for w in workers]
            results[j] = (deltas, weights, [float(l) for l in losses_np[off : off + count]])
    return results


def run_round(system, app, *, use_kernel: bool = True, vectorized: bool = True) -> dict:
    """One Totoro+ round through the Table-II verbs; returns metrics.

    Broadcast down the tree, vectorized local training, hierarchical
    kernel aggregation up the tree (``TotoroSystem.Aggregate`` executes
    the level schedule), master server-update + state replication.
    """
    bstats = system.Broadcast(app.handle.app_id, app.params)

    tree = app.handle.tree
    workers = [w for w in sorted(tree.members) if w in app.data]
    deltas, weights, losses = local_training(app, workers, vectorized=vectorized)

    astats = system.Aggregate(
        app.handle.app_id,
        {w: d for w, d in zip(workers, deltas)},
        weights={w: wt for w, wt in zip(workers, weights)},
        use_kernel=use_kernel,
    )
    agg = astats["result"]

    app.params = jax.tree.map(lambda p, d: (p + d).astype(p.dtype), app.params, agg)
    app.round_num += 1
    system.replicate_master_state(app.handle.app_id, {"round": app.round_num})

    metrics = {
        "round": app.round_num,
        "loss": float(np.mean(losses)),
        "time_ms": bstats["time_ms"] + astats["time_ms"],
        "traffic_bytes": bstats["bytes"] + astats["bytes"],
        "agg_levels": astats.get("levels", []),
    }
    app.history.append(metrics)
    return metrics


def run_round_fused(system, apps: list, *, use_kernel: bool = True) -> list[dict]:
    """One round for MANY apps with a single fused training dispatch.

    The multi-app analogue of ``run_round``: every app Broadcasts, then
    all apps' workers train together through ``fused_local_training``
    (same-config apps stack into one megabatched vmap; deltas unstack
    per app), then each app Aggregates and applies its server update.
    Semantics per app match ``run_round`` to fp tolerance; dispatches
    per round drop from M to the number of distinct static configs.
    Returns one metrics dict per app, in ``apps`` order.
    """
    bstats_all, jobs = [], []
    for app in apps:
        bstats_all.append(system.Broadcast(app.handle.app_id, app.params))
        tree = app.handle.tree
        workers = [w for w in sorted(tree.members) if w in app.data]
        jobs.append((app, workers, app.params))
    trained = fused_local_training(jobs)

    out = []
    for app, bstats, (_, workers, _), (deltas, weights, losses) in zip(
        apps, bstats_all, jobs, trained
    ):
        astats = system.Aggregate(
            app.handle.app_id,
            {w: d for w, d in zip(workers, deltas)},
            weights={w: wt for w, wt in zip(workers, weights)},
            use_kernel=use_kernel,
        )
        agg = astats["result"]
        app.params = jax.tree.map(
            lambda p, d: (p + d).astype(p.dtype), app.params, agg
        )
        app.round_num += 1
        system.replicate_master_state(app.handle.app_id, {"round": app.round_num})
        metrics = {
            "round": app.round_num,
            "loss": float(np.mean(losses)) if losses else 0.0,
            "time_ms": bstats["time_ms"] + astats["time_ms"],
            "traffic_bytes": bstats["bytes"] + astats["bytes"],
            "agg_levels": astats.get("levels", []),
        }
        app.history.append(metrics)
        out.append(metrics)
    return out
