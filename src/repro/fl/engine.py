"""Vectorized FL round engine: all workers of an app train in one kernel.

The seed's ``run_round`` dispatched one jitted ``local_train`` per worker
from a Python loop — W dispatches, W × E sequential SGD steps.  The
engine stacks every worker's shard into padded ``(W, B, ...)`` arrays
(mask marks the padding) and runs the E local steps as a single jitted
``vmap`` over the worker axis, so one XLA program trains the whole app.
A masked mean makes each worker's loss/gradient identical to what its
unpadded shard produces, so the vectorized path matches the per-worker
reference loop to fp tolerance (see tests/test_engine.py).

``local_training(..., vectorized=False)`` keeps the reference loop both
as the equivalence oracle and as the baseline the engine benchmark
compares against.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl import small_models as sm


def pack_shards(data_by_worker: dict, workers: list[int]):
    """Stack ragged worker shards into padded (W, B, ...) arrays + mask.

    Returns (x, y, mask): x (W, B, *feat) f32, y (W, B) i32, mask (W, B)
    f32 with 1.0 on real examples, 0.0 on padding.
    """
    if not workers:  # a drained commit batch: empty padded stacks, not max([])
        z = np.zeros((0, 0), np.float32)
        return jnp.asarray(z), jnp.asarray(z, jnp.int32), jnp.asarray(z)
    bs = [len(data_by_worker[w][1]) for w in workers]
    B = max(bs)
    x0 = np.asarray(data_by_worker[workers[0]][0])
    xs = np.zeros((len(workers), B) + x0.shape[1:], np.float32)
    ys = np.zeros((len(workers), B), np.int32)
    mask = np.zeros((len(workers), B), np.float32)
    for i, w in enumerate(workers):
        x, y = data_by_worker[w]
        b = len(y)
        xs[i, :b] = np.asarray(x, np.float32)
        ys[i, :b] = np.asarray(y, np.int32)
        mask[i, :b] = 1.0
    return jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(mask)


def _masked_ce(logits, y, mask):
    ll = jnp.take_along_axis(jax.nn.log_softmax(logits), y[:, None], axis=1)[:, 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


@partial(jax.jit, static_argnames=("logits_fn", "steps", "lr", "mu"))
def batched_local_train(global_params, x, y, mask, *, logits_fn, steps: int, lr: float, mu: float = 0.0):
    """E local SGD steps for every worker at once: vmap over the W axis.

    Equivalent to running ``small_models.local_train`` per worker — the
    masked CE mean reproduces each shard's unpadded loss exactly.
    Returns (stacked new params (W, ...), per-worker mean loss (W,)).
    """

    def one_worker(xw, yw, mw):
        def loss_fn(p):
            base = _masked_ce(logits_fn(p, xw), yw, mw)
            if mu > 0:
                prox = sum(
                    jnp.sum(jnp.square(a - b))
                    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(global_params))
                )
                base = base + 0.5 * mu * prox
            return base

        def step(p, _):
            l, g = jax.value_and_grad(loss_fn)(p)
            p = jax.tree.map(lambda w, gg: w - lr * gg, p, g)
            return p, l

        params, losses = jax.lax.scan(step, global_params, None, length=steps)
        return params, jnp.mean(losses)

    return jax.vmap(one_worker)(x, y, mask)


def local_training(app, workers: list[int], *, vectorized: bool = True, params=None):
    """Run the app's E local steps on every worker's shard.

    Returns (deltas, weights, losses) with one entry per worker, in
    ``workers`` order — deltas are model-update pytrees, weights the
    shard sizes (FedAvg weighting), losses the mean local losses.
    ``params`` overrides the starting model (the async path trains each
    commit batch from the — possibly stale — version its workers
    downloaded, not from ``app.params``).
    """
    if not workers:
        return [], [], []
    start = app.params if params is None else params
    logits_fn = sm.LOGITS[app.model]
    weights = [float(len(app.data[w][1])) for w in workers]
    if not vectorized:
        deltas, losses = [], []
        for w in workers:
            x, y = app.data[w]
            new_p, loss = sm.local_train(
                start, start, x, y,
                logits_fn=logits_fn, steps=app.local_steps, lr=app.lr, mu=app.mu,
            )
            deltas.append(jax.tree.map(lambda a, b: a - b, new_p, start))
            losses.append(float(loss))
        return deltas, weights, losses

    x, y, mask = pack_shards(app.data, workers)
    new_params, losses = batched_local_train(
        start, x, y, mask,
        logits_fn=logits_fn, steps=app.local_steps, lr=app.lr, mu=app.mu,
    )
    stacked = jax.tree.map(lambda n, p: n - p[None], new_params, start)
    # one device->host transfer per leaf, then cheap numpy row views —
    # per-worker device slicing would cost W x leaves dispatches
    stacked_np = jax.tree.map(np.asarray, stacked)
    deltas = [jax.tree.map(lambda l, i=i: l[i], stacked_np) for i in range(len(workers))]
    return deltas, weights, [float(l) for l in np.asarray(losses)]


def run_round(system, app, *, use_kernel: bool = True, vectorized: bool = True) -> dict:
    """One Totoro+ round through the Table-II verbs; returns metrics.

    Broadcast down the tree, vectorized local training, hierarchical
    kernel aggregation up the tree (``TotoroSystem.Aggregate`` executes
    the level schedule), master server-update + state replication.
    """
    bstats = system.Broadcast(app.handle.app_id, app.params)

    tree = app.handle.tree
    workers = [w for w in sorted(tree.members) if w in app.data]
    deltas, weights, losses = local_training(app, workers, vectorized=vectorized)

    astats = system.Aggregate(
        app.handle.app_id,
        {w: d for w, d in zip(workers, deltas)},
        weights={w: wt for w, wt in zip(workers, weights)},
        use_kernel=use_kernel,
    )
    agg = astats["result"]

    app.params = jax.tree.map(lambda p, d: (p + d).astype(p.dtype), app.params, agg)
    app.round_num += 1
    system.replicate_master_state(app.handle.app_id, {"round": app.round_num})

    metrics = {
        "round": app.round_num,
        "loss": float(np.mean(losses)),
        "time_ms": bstats["time_ms"] + astats["time_ms"],
        "traffic_bytes": bstats["bytes"] + astats["bytes"],
        "agg_levels": astats.get("levels", []),
    }
    app.history.append(metrics)
    return metrics
