"""Tree aggregation on the mesh — Totoro+'s dataflow tree as collectives.

Mapping (DESIGN.md §2): one pod = one edge zone = one ring of the
multi-ring.  Gradient aggregation leaves->root becomes a two-stage tree:
stage 1 reduces over the ``data`` axis inside a pod (zone-local, fast
ICI — performed by XLA inside backprop), stage 2 reduces across ``pod``
(cross-zone, the slow hop Totoro+'s planner optimizes) — expressed
explicitly inside a partial-manual shard_map so the cross-zone hop can be
compressed (QSGD int8) exactly where the paper compresses.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import compression


def cross_pod_psum(grads, num_pods: int):
    """FedAvg across zones: plain mean over the 'pod' axis (inside shard_map)."""
    return jax.tree.map(lambda g: jax.lax.psum(g, "pod") / num_pods, grads)


def cross_pod_q8(grads, num_pods: int):
    """Compressed cross-zone aggregation: int8 QSGD + all_gather + dequant-mean.

    Traffic on the cross-zone hop drops ~4x vs fp32 psum (int8 payload +
    one f32 scale per row); deterministic rounding keeps pods in lockstep.
    """

    def agg(g):
        flat = g.reshape(-1)
        pad = (-flat.shape[0]) % 256
        flat = jnp.pad(flat, (0, pad)).reshape(-1, 256)
        q, scale = compression.qsgd_quantize(flat)
        qs = jax.lax.all_gather(q, "pod")  # (pods, rows, 256) int8
        ss = jax.lax.all_gather(scale, "pod")
        deq = jnp.mean(qs.astype(jnp.float32) * ss, axis=0)
        out = deq.reshape(-1)[: g.size].reshape(g.shape)
        return out.astype(jnp.float32)

    return jax.tree.map(agg, grads)


AGGREGATORS = {
    "totoro_tree": cross_pod_psum,
    "totoro_tree_q8": cross_pod_q8,
}
