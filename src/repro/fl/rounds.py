"""FL round orchestration over the simulated Totoro+ overlay.

Drives full FedAvg/FedProx rounds for paper-scale models through the
Table-II API: Broadcast the global model down the dataflow tree, workers
run E local steps on their (non-IID) shards, model deltas aggregate up
the tree level-by-level (internal nodes run the ``tree_aggregate``
kernel's math), the master applies the server update and replicates its
state to the k-node neighborhood set.

Also provides ``CentralizedBaseline``: the OpenFL/FedScale-style single
coordinator that serves M concurrent applications through one queue —
the queuing behavior behind the paper's Table III speedups.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.api import TotoroSystem
from repro.fl import small_models as sm


@dataclass
class FLApp:
    name: str
    handle: object
    params: object
    model: str = "mlp"
    local_steps: int = 4
    lr: float = 0.1
    mu: float = 0.0  # FedProx
    data: dict = field(default_factory=dict)  # node -> (x, y)
    round_num: int = 0
    history: list = field(default_factory=list)


def make_app(
    system: TotoroSystem,
    name: str,
    *,
    workers: list[int],
    data_by_worker: dict,
    model: str = "mlp",
    dim: int = 32,
    hidden: int = 64,
    num_classes: int = 8,
    local_steps: int = 4,
    lr: float = 0.1,
    mu: float = 0.0,
    seed: int = 0,
) -> FLApp:
    handle = system.CreateTree(name)
    for w in workers:
        system.Subscribe(handle.app_id, w)
    if model == "mlp":
        params = sm.init_mlp(jax.random.key(seed), dim, hidden, num_classes)
    else:
        params = sm.init_cnn(jax.random.key(seed), num_classes)
    return FLApp(
        name=name, handle=handle, params=params, model=model,
        local_steps=local_steps, lr=lr, mu=mu, data=data_by_worker,
    )


def run_round(
    system: TotoroSystem, app: FLApp, *, use_kernel: bool = True, vectorized: bool = True
) -> dict:
    """One Totoro+ round; returns metrics incl. modeled wall time.

    Delegates to the vectorized round engine (``fl/engine.py``): all
    workers' local steps run as one jitted vmap, aggregation executes the
    tree's level schedule through the batched Pallas kernel.  Pass
    ``vectorized=False`` for the per-worker reference loop.
    """
    from repro.fl import engine

    return engine.run_round(system, app, use_kernel=use_kernel, vectorized=vectorized)


def run_round_fused(system: TotoroSystem, apps: list[FLApp], *, use_kernel: bool = True) -> list[dict]:
    """One round for many apps with a single fused training dispatch.

    Delegates to ``fl/engine.run_round_fused``: same-config apps stack
    into one megabatched vmap (per-worker start params, shape-bucketed
    padding) and deltas unstack per app — per-app results match
    ``run_round`` to fp tolerance while dispatches per round drop from
    M to the number of distinct static configs."""
    from repro.fl import engine

    return engine.run_round_fused(system, apps, use_kernel=use_kernel)


def run_async(
    system: TotoroSystem,
    apps: list[FLApp],
    *,
    applies: int,
    buffer_k: int | list[int],
    staleness_alpha: float = 0.5,
    model_bytes: float,
    compute_ms=50.0,
    churn=None,
    barrier: bool = False,
    adaptive: bool = False,
    adaptive_kwargs: dict | None = None,
    selector=None,
    fair: bool = True,
    app_weights=None,
    app_rate_caps=None,
    relay_admission=None,
) -> dict:
    """FedBuff-style buffered-async rounds on the event clock.

    Delegates to ``fl/async_engine.run_async``: every worker's
    download / compute / upload is its own simulator event, the master
    applies a staleness-weighted update after ``buffer_k`` arrivals
    (``CommitDelta``/``ApplyBuffered`` verbs), and optional ``churn``
    (``core.sim.ChurnModel``) fails/rejoins workers mid-round.
    ``adaptive=True`` re-sizes K per apply (``core.sim
    .AdaptiveKController``); ``selector`` plugs in utility-based client
    admission (``fl/selection``).  Transfers are priced by the
    weighted-fair flow engine (``fair=False`` restores the legacy
    start-time pricing); ``app_weights`` / ``app_rate_caps`` /
    ``relay_admission`` expose the per-app fairness knobs.
    """
    from repro.fl import async_engine

    return async_engine.run_async(
        system, apps, applies=applies, buffer_k=buffer_k,
        staleness_alpha=staleness_alpha, model_bytes=model_bytes,
        compute_ms=compute_ms, churn=churn, barrier=barrier,
        adaptive=adaptive, adaptive_kwargs=adaptive_kwargs, selector=selector,
        fair=fair, app_weights=app_weights, app_rate_caps=app_rate_caps,
        relay_admission=relay_admission,
    )


def evaluate(app: FLApp, x, y) -> float:
    return float(sm.accuracy(sm.LOGITS[app.model](app.params, x), y))


# ---------------------------------------------------------------------------
# centralized baseline (OpenFL / FedScale architecture)


@dataclass
class CentralizedBaseline:
    """Single coordinator, first-come-first-served across M applications
    (paper §VII-D: 'the central coordinator needs to handle them one by
    one ... which causes large queuing delays')."""

    server_bandwidth_mbps: float = 1000.0
    coordinator_overhead_ms: float = 20.0

    def round_time_ms(self, apps: list[FLApp], per_round_compute_ms: float, model_bytes: float) -> list[float]:
        """Per-app wall time for one round of every app: uploads/downloads
        serialize through the central server's link + coordinator queue."""
        times = []
        clock = 0.0
        for app in apps:
            n_workers = max(len(app.data), 1)
            xfer_ms = 2 * n_workers * model_bytes * 8 / (self.server_bandwidth_mbps * 1e3)
            clock += self.coordinator_overhead_ms + xfer_ms + per_round_compute_ms
            times.append(clock)
        return times
