"""FL round orchestration over the simulated Totoro+ overlay.

Drives full FedAvg/FedProx rounds for paper-scale models through the
Table-II API: Broadcast the global model down the dataflow tree, workers
run E local steps on their (non-IID) shards, model deltas aggregate up
the tree level-by-level (internal nodes run the ``tree_aggregate``
kernel's math), the master applies the server update and replicates its
state to the k-node neighborhood set.

Also provides ``CentralizedBaseline``: the OpenFL/FedScale-style single
coordinator that serves M concurrent applications through one queue —
the queuing behavior behind the paper's Table III speedups.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import TotoroSystem
from repro.fl import small_models as sm
from repro.kernels import ops as kops


@dataclass
class FLApp:
    name: str
    handle: object
    params: object
    model: str = "mlp"
    local_steps: int = 4
    lr: float = 0.1
    mu: float = 0.0  # FedProx
    data: dict = field(default_factory=dict)  # node -> (x, y)
    round_num: int = 0
    history: list = field(default_factory=list)


def make_app(
    system: TotoroSystem,
    name: str,
    *,
    workers: list[int],
    data_by_worker: dict,
    model: str = "mlp",
    dim: int = 32,
    hidden: int = 64,
    num_classes: int = 8,
    local_steps: int = 4,
    lr: float = 0.1,
    mu: float = 0.0,
    seed: int = 0,
) -> FLApp:
    handle = system.CreateTree(name)
    for w in workers:
        system.Subscribe(handle.app_id, w)
    if model == "mlp":
        params = sm.init_mlp(jax.random.key(seed), dim, hidden, num_classes)
    else:
        params = sm.init_cnn(jax.random.key(seed), num_classes)
    return FLApp(
        name=name, handle=handle, params=params, model=model,
        local_steps=local_steps, lr=lr, mu=mu, data=data_by_worker,
    )


def run_round(system: TotoroSystem, app: FLApp, *, use_kernel: bool = True) -> dict:
    """One Totoro+ round; returns metrics incl. modeled wall time."""
    logits_fn = sm.LOGITS[app.model]
    tree = app.handle.tree

    # 1. model broadcast down the tree
    bstats = system.Broadcast(app.handle.app_id, app.params)

    # 2. local training on each worker's shard
    deltas, weights, losses = [], [], []
    for w in sorted(tree.members):
        if w not in app.data:
            continue
        x, y = app.data[w]
        new_p, loss = sm.local_train(
            app.params, app.params, x, y,
            logits_fn=logits_fn, steps=app.local_steps, lr=app.lr, mu=app.mu,
        )
        deltas.append(jax.tree.map(lambda a, b: a - b, new_p, app.params))
        weights.append(float(len(y)))
        losses.append(float(loss))

    # 3. aggregation up the tree (weighted mean; kernel = aggregator math)
    w = np.asarray(weights) / np.sum(weights)
    if use_kernel:
        agg = kops.tree_aggregate_pytree(deltas, w)
    else:
        agg = jax.tree.map(lambda *ls: sum(wi * l for wi, l in zip(w, ls)), *deltas)
    astats = system.Aggregate(
        app.handle.app_id,
        {n: d for n, d in zip(sorted(tree.members), deltas)},
        weights={n: wt for n, wt in zip(sorted(tree.members), weights)},
    )

    # 4. server update + state replication (paper §IV-D)
    app.params = jax.tree.map(lambda p, d: p + d, app.params, agg)
    app.round_num += 1
    system.replicate_master_state(app.handle.app_id, {"round": app.round_num})

    metrics = {
        "round": app.round_num,
        "loss": float(np.mean(losses)),
        "time_ms": bstats["time_ms"] + astats["time_ms"],
        "traffic_bytes": bstats["bytes"] + astats["bytes"],
    }
    app.history.append(metrics)
    return metrics


def evaluate(app: FLApp, x, y) -> float:
    return float(sm.accuracy(sm.LOGITS[app.model](app.params, x), y))


# ---------------------------------------------------------------------------
# centralized baseline (OpenFL / FedScale architecture)


@dataclass
class CentralizedBaseline:
    """Single coordinator, first-come-first-served across M applications
    (paper §VII-D: 'the central coordinator needs to handle them one by
    one ... which causes large queuing delays')."""

    server_bandwidth_mbps: float = 1000.0
    coordinator_overhead_ms: float = 20.0

    def round_time_ms(self, apps: list[FLApp], per_round_compute_ms: float, model_bytes: float) -> list[float]:
        """Per-app wall time for one round of every app: uploads/downloads
        serialize through the central server's link + coordinator queue."""
        times = []
        clock = 0.0
        for app in apps:
            n_workers = max(len(app.data), 1)
            xfer_ms = 2 * n_workers * model_bytes * 8 / (self.server_bandwidth_mbps * 1e3)
            clock += self.coordinator_overhead_ms + xfer_ms + per_round_compute_ms
            times.append(clock)
        return times
