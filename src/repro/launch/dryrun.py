import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Reduce XLA:CPU codegen effort: SPMD partitioning (what we analyze) is
# unaffected; LLVM-side optimization of the host code is irrelevant to the
# TPU-target roofline and costs minutes per 100B-scale cell on this 1-core
# box (verified identical roofline terms with/without).
if os.environ.get("REPRO_FULL_OPT") != "1":
    os.environ["XLA_FLAGS"] += (
        " --xla_backend_optimization_level=0 --xla_llvm_disable_expensive_passes=true"
    )

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell: ``jit(step).lower(**input_specs).compile()`` on the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh, record
``memory_analysis()`` / ``cost_analysis()`` / collective schedule, and
derive the three roofline terms (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out results.json]
"""
import argparse
import json
import time
import traceback


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, aggregation=None, quiet=False,
             cfg_overrides=None, grad_accum=None):
    import jax
    from repro import configs
    from repro.config import SHAPES
    from repro.launch import hlo as hlo_mod
    from repro.launch import mesh as mesh_mod
    from repro.launch import specs as specs_mod
    from repro.models import encdec, lm

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    nchips = mesh.devices.size
    cell = specs_mod.build_cell(arch, shape_name, mesh, aggregation=aggregation,
                                cfg_overrides=cfg_overrides, grad_accum=grad_accum)
    t0 = time.time()
    lowered = specs_mod.lower_cell(cell, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    mod = hlo_mod.analyze_module(txt)  # trip-count-aware (see hlo.py docstring)

    flops = float(mod.flops)
    bytes_acc = float(mod.hbm_bytes)
    terms = hlo_mod.roofline_terms(flops, bytes_acc, mod.collective_bytes)

    model = encdec if cell.cfg.is_encoder_decoder else lm
    if cell.cfg.is_encoder_decoder:
        import jax.numpy as jnp

        shapes = jax.eval_shape(lambda k: model.init_params(k, cell.cfg), jax.random.key(0))
        n_total = sum(int(x.size) for x in jax.tree.leaves(shapes))
        n_active = n_total
    else:
        n_total, n_active = lm.count_params_analytic(cell.cfg)
    mflops = hlo_mod.model_flops(cell.cfg, cell.shape, n_total, n_active)
    ratio = mflops / (flops * nchips) if flops else 0.0

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": nchips,
        "aggregation": cell.plan.aggregation if cell.shape.kind == "train" else None,
        "grad_accum": cell.plan.grad_accum,
        "params_total": n_total,
        "params_active": n_active,
        "lower_s": round(t1 - t0, 1),
        "compile_s": round(t2 - t1, 1),
        "memory": {
            "argument_bytes_per_dev": mem.argument_size_in_bytes,
            "output_bytes_per_dev": mem.output_size_in_bytes,
            "temp_bytes_per_dev": mem.temp_size_in_bytes,
            "alias_bytes_per_dev": mem.alias_size_in_bytes,
            "peak_bytes_per_dev": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "cost": {
            "flops_per_dev": flops,
            "bytes_per_dev": bytes_acc,
            "xla_flops_per_dev_loop_undercounted": float(cost.get("flops", 0.0)),
            "xla_bytes_per_dev_loop_undercounted": float(cost.get("bytes accessed", 0.0)),
        },
        "collectives": {
            "total_bytes_per_dev": mod.collective_bytes,
            "bytes_by_kind": {k: round(v) for k, v in mod.coll_bytes_by_kind.items()},
            "count_by_kind": {k: round(v) for k, v in mod.coll_count_by_kind.items()},
            "loops": [(b, t, m) for b, t, m in mod.loops if t > 1][:40],
        },
        "roofline": {
            **terms,
            "model_flops": mflops,
            "useful_flops_ratio": ratio,
        },
    }
    if not quiet:
        print(f"== {arch} x {shape_name} x {result['mesh']} ==")
        print("memory_analysis:", mem)
        print("cost_analysis flops/bytes per dev:", flops, bytes_acc)
        print(
            "collectives:",
            {k: f"{v/1e6:.1f}MB" for k, v in mod.coll_bytes_by_kind.items()},
            {k: round(v) for k, v in mod.coll_count_by_kind.items()},
        )
        print(
            f"roofline: compute={terms['compute_s']*1e3:.2f}ms "
            f"memory={terms['memory_s']*1e3:.2f}ms "
            f"collective={terms['collective_s']*1e3:.2f}ms bound={terms['bound']} "
            f"useful_flops_ratio={ratio:.3f}"
        )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--aggregation", default=None,
                    help="override: xla_auto | totoro_tree | totoro_tree_q8")
    ap.add_argument("--out", default=None, help="append JSON results here")
    args = ap.parse_args()

    from repro import configs

    if args.all:
        cells = [
            (a, s)
            for a in configs.ARCH_IDS
            for s in configs.runnable_cells(a)
        ]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    results, failures = [], []
    for arch, shape in cells:
        for mp in meshes:
            try:
                results.append(
                    run_cell(arch, shape, multi_pod=mp, aggregation=args.aggregation)
                )
            except Exception as e:
                traceback.print_exc()
                failures.append({"arch": arch, "shape": shape, "multi_pod": mp, "error": str(e)})
    if args.out:
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        with open(args.out, "w") as f:
            json.dump(existing + results, f, indent=1)
    if failures:
        print("FAILURES:", json.dumps(failures, indent=1))
        raise SystemExit(1)
    print(f"dry-run OK: {len(results)} cells")


if __name__ == "__main__":
    main()
