"""Crash-safe full dry-run sweep: every (arch x runnable shape x mesh) cell,
one subprocess per cell (isolates XLA crashes), JSONL output."""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--arch", default=None, help="only this arch")
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", ".."))
    from repro import configs

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.skip_done and os.path.exists(args.out):
        for line in open(args.out):
            r = json.loads(line)
            if "error" not in r:
                done.add((r["arch"], r["shape"], r["mesh"]))

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    for arch in configs.ARCH_IDS:
        if args.arch and arch != args.arch:
            continue
        for shape in configs.runnable_cells(arch):
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                if (arch, shape, mesh_name) not in done:
                    cells.append((arch, shape, mp))

    print(f"sweep: {len(cells)} cells to run", flush=True)
    for i, (arch, shape, mp) in enumerate(cells):
        t0 = time.time()
        code = (
            "import json,sys\n"
            "from repro.launch.dryrun import run_cell\n"
            f"r = run_cell({arch!r}, {shape!r}, multi_pod={mp}, quiet=True)\n"
            "print('RESULT_JSON:' + json.dumps(r))\n"
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "..")
        )
        p = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True, env=env,
            timeout=3600,
        )
        rec = None
        for line in p.stdout.splitlines():
            if line.startswith("RESULT_JSON:"):
                rec = json.loads(line[len("RESULT_JSON:"):])
        if rec is None:
            rec = {
                "arch": arch, "shape": shape,
                "mesh": "2x16x16" if mp else "16x16",
                "error": (p.stderr or p.stdout)[-2000:],
            }
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        status = "FAIL" if "error" in rec else rec["roofline"]["bound"]
        print(
            f"[{i+1}/{len(cells)}] {arch} x {shape} x {'multi' if mp else 'single'}: "
            f"{status} ({time.time()-t0:.0f}s)",
            flush=True,
        )


if __name__ == "__main__":
    main()
