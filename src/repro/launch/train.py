"""Training launcher: federated LM rounds with checkpoint/restart.

Runs on whatever devices exist (1 CPU here; the production mesh on TPU).
Fault tolerance: k-replica checkpoints every ``--ckpt-every`` rounds and
restart-from-latest on relaunch (the paper's master-state replication);
elastic scaling: checkpoints hold full logical arrays, so a relaunch on a
different mesh re-shards automatically.  Straggler mitigation: optional
per-round client dropout mask re-weighting the FedAvg average (zero-weight
examples at the loss level).

Compute/communication overlap: microbatch gradient accumulation naturally
pipelines reduce-scatters against the next microbatch's compute; on real
TPU deployments enable async collectives via
  LIBTPU_INIT_ARGS=--xla_tpu_enable_async_collective_fusion=true
  XLA_FLAGS=--xla_tpu_overlap_compute_collective_tc=true (see README).

Usage:
  python -m repro.launch.train --arch tinyllama-1.1b --steps 50 \
      --reduced --ckpt-dir /tmp/ckpt [--resume] [--aggregation totoro_tree_q8]
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", help="reduced config (CPU-scale)")
    ap.add_argument("--width", type=int, default=0, help="override d_model (reduced)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--straggler-rate", type=float, default=0.0,
                    help="simulated per-round client dropout probability")
    ap.add_argument("--non-iid", type=float, default=0.0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import ckpt, configs, data
    from repro.config import RunPlan
    from repro.fl import steps as steps_mod
    from repro.models import encdec, lm

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get_config(args.arch)
    if args.width:
        cfg = cfg.replace(d_model=args.width, num_heads=max(4, args.width // 32), head_dim=32)
    if args.layers:
        cfg = cfg.replace(num_layers=args.layers)
    cfg = cfg.replace(learning_rate=args.lr)
    plan = RunPlan(grad_accum=args.grad_accum)
    model = encdec if cfg.is_encoder_decoder else lm

    n_dev = jax.device_count()
    print(f"devices={n_dev} arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model}")

    params = model.init_params(jax.random.key(0), cfg)
    state = steps_mod.init_train_state(cfg, params)
    start_step = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        state, start_step = ckpt.restore(state, args.ckpt_dir)
        state = jax.device_put(state)  # elastic: re-shard onto current mesh
        print(f"resumed from step {start_step}")

    train_step = jax.jit(steps_mod.build_train_step(cfg, plan), donate_argnums=(0,))
    sc = data.StreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        batch_per_shard=args.global_batch, non_iid_alpha=args.non_iid,
    )
    rng = np.random.default_rng(0)

    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = data.learnable_lm_batch(sc, shard=0, step=step)
        if args.straggler_rate > 0:
            # deadline-style straggler mitigation: dropped clients' examples
            # get zero weight by masking their labels (paper §III ch.2)
            drop = rng.random(args.global_batch) < args.straggler_rate
            batch["labels"] = np.where(drop[:, None], -1, batch["labels"])
        if cfg.embed_inputs or cfg.is_encoder_decoder:
            emb = data.embeds_batch(sc, cfg.d_model, 0, step)
            b = {"embeds": jnp.asarray(emb), "labels": jnp.asarray(batch["labels"])}
            if cfg.is_encoder_decoder:
                b["tokens"] = jnp.asarray(batch["tokens"])
        else:
            b = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = train_step(state, b)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step}: loss={float(metrics['loss']):.4f} "
                  f"({(time.time()-t0)/max(step-start_step+1,1)*1e3:.0f} ms/step)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(state, args.ckpt_dir, step=step + 1, replicas=args.replicas)
    if args.ckpt_dir:
        ckpt.save(state, args.ckpt_dir, step=args.steps, replicas=args.replicas)
        print(f"final checkpoint at step {args.steps} ({args.replicas} replicas)")
    print("done")


if __name__ == "__main__":
    main()
