"""Production meshes.  Functions (not module constants) so importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def _mk(shape, axes):
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    # older jax (< 0.6): all mesh axes are implicitly Auto
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod: 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small mesh for subprocess integration tests (8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def num_pods(mesh) -> int:
    return mesh_axis_sizes(mesh).get("pod", 1)
