"""Compiled-HLO analysis: trip-count-aware FLOPs / HBM bytes / collective bytes.

XLA's ``cost_analysis()`` counts a ``while`` body ONCE (verified by probe —
a scan of 10 matmuls reports the FLOPs of one), which silently undercounts
everything inside layer scans / grad-accumulation loops / attention chunk
loops.  This module re-derives the three roofline inputs from the compiled
HLO text with loop trip-count multiplication:

  - FLOPs: every ``dot`` contributes 2 * numel(result) * contraction_size
    (convolutions approximated the same way through their window);
  - HBM bytes: fusion-boundary traffic — for every top-level op except
    pure metadata ops, result bytes + operand bytes;
  - collective bytes: operand bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute ops;

each multiplied by the product of enclosing while-loop trip counts (parsed
from the loop condition's s32 constants).  All numbers are PER DEVICE
(post-GSPMD partitioned module).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(\([^()]*\)|[\w\[\],{}]+)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "add-dependency",
    "opt-barrier", "iota", "partition-id", "replica-id", "custom-call",
}


def _shape_dims(type_str: str):
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",")] if dims else []
        out.append((dt, d))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(type_str: str) -> int:
    total = 0
    for _, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclass
class CompStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes_by_kind: dict = field(default_factory=dict)
    coll_count_by_kind: dict = field(default_factory=dict)
    whiles: list = field(default_factory=list)  # (cond_name, body_name)
    calls: list = field(default_factory=list)  # called computation names (call/cond)
    s32_constants: list = field(default_factory=list)


@dataclass
class ModuleStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes_by_kind: dict = field(default_factory=dict)
    coll_count_by_kind: dict = field(default_factory=dict)
    loops: list = field(default_factory=list)  # (body_name, trip, multiplier)

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_bytes_by_kind.values())


def _parse_computations(hlo_text: str):
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_WHILE_RE = re.compile(r"condition=(%[\w\.\-]+),\s*body=(%[\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:to_apply|calls)=(%[\w\.\-]+)")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")


def _first_operand(line: str, op: str) -> str | None:
    m = re.search(re.escape(op) + r"\((%[\w\.\-]+)", line)
    return m.group(1) if m else None


def _analyze_comp(lines: list[str]) -> CompStats:
    st = CompStats()
    symbols: dict[str, str] = {}
    producers: dict[str, tuple[str, str | None]] = {}  # name -> (op, first operand)
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            symbols[m.group(1)] = m.group(2)
            producers[m.group(1)] = (m.group(3), _first_operand(line, m.group(3)))
        cm = _CONST_RE.search(line)
        if cm:
            st.s32_constants.append(int(cm.group(1)))

    def effective_bytes(name: str) -> int:
        """Collective payload width, seeing through XLA:CPU's bf16->f32
        upcast wrappers (TPU collectives run at the logical bf16 width)."""
        b = _type_bytes(symbols.get(name, ""))
        if "convert" in name:
            prod = producers.get(name)
            if prod and prod[1]:
                src = symbols.get(prod[1], "")
                if src and _numel(src) == _numel(symbols.get(name, "")) and _type_bytes(src) < b:
                    return _type_bytes(src)
        return b

    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()

        # operands: balanced-paren args after "op("
        rhs = line.split("=", 1)[1]
        start = rhs.index(op + "(") + len(op) + 1
        depth, args, cur = 1, [], []
        for ch in rhs[start:]:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if ch == "," and depth == 1:
                args.append("".join(cur).strip())
                cur = []
            else:
                cur.append(ch)
        if cur:
            args.append("".join(cur).strip())
        operand_bytes = sum(_type_bytes(symbols.get(a, "")) for a in args if a.startswith("%"))

        if op == "while":
            wm = _WHILE_RE.search(line)
            if wm:
                st.whiles.append((wm.group(1), wm.group(2)))
            continue
        if op in ("call", "conditional"):
            for cm in _CALLS_RE.finditer(line):
                st.calls.append(cm.group(1))
            continue

        kind = next((c for c in COLLECTIVES if op == c or op.startswith(c + "-")), None)
        if kind is not None and not op.endswith("-done"):
            nbytes = sum(effective_bytes(a) for a in args if a.startswith("%"))
            nbytes = nbytes or _type_bytes(type_str)
            st.coll_bytes_by_kind[kind] = st.coll_bytes_by_kind.get(kind, 0) + nbytes
            st.coll_count_by_kind[kind] = st.coll_count_by_kind.get(kind, 0) + 1
            st.hbm_bytes += nbytes + _type_bytes(type_str)
            continue

        if op in ("dot", "convolution"):
            contraction = 1
            cm = _DOT_CONTRACT_RE.search(line)
            lhs = args[0] if args else None
            if cm and lhs and lhs in symbols:
                dims = _shape_dims(symbols[lhs])
                if dims:
                    _, ldims = dims[0]
                    for idx in (int(x) for x in cm.group(1).split(",") if x):
                        if idx < len(ldims):
                            contraction *= ldims[idx]
            elif op == "convolution" and lhs and lhs in symbols:
                # approximate: contraction = operand numel / result spatial rows
                contraction = max(1, _numel(symbols.get(args[1], "")) // max(1, _numel(type_str)))
            st.flops += 2.0 * _numel(type_str) * contraction
            st.hbm_bytes += operand_bytes + _type_bytes(type_str)
            continue

        if op in _SKIP_BYTES_OPS:
            continue
        st.hbm_bytes += operand_bytes + _type_bytes(type_str)
    return st


def _trip_count(cond: CompStats) -> int:
    """Loop bound = max s32 constant in the condition computation."""
    return max(cond.s32_constants, default=1) or 1


def analyze_module(hlo_text: str) -> ModuleStats:
    comps, entry = _parse_computations(hlo_text)
    stats = {name: _analyze_comp(lines) for name, lines in comps.items()}
    out = ModuleStats()

    def visit(name: str, mult: float, depth: int = 0):
        if name not in stats or depth > 32:
            return
        st = stats[name]
        out.flops += mult * st.flops
        out.hbm_bytes += mult * st.hbm_bytes
        for k, v in st.coll_bytes_by_kind.items():
            out.coll_bytes_by_kind[k] = out.coll_bytes_by_kind.get(k, 0) + mult * v
        for k, v in st.coll_count_by_kind.items():
            out.coll_count_by_kind[k] = out.coll_count_by_kind.get(k, 0) + mult * v
        for cond_name, body_name in st.whiles:
            trip = _trip_count(stats.get(cond_name, CompStats()))
            out.loops.append((body_name, trip, mult))
            visit(body_name, mult * trip, depth + 1)
            visit(cond_name, mult * trip, depth + 1)
        for callee in st.calls:
            visit(callee, mult, depth + 1)

    if entry:
        visit(entry, 1.0)
    return out


# ---------------------------------------------------------------------------
# hardware constants (TPU v5e target) + roofline terms

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link


def roofline_terms(flops_per_dev: float, bytes_per_dev: float, coll_bytes_per_dev: float):
    t_c = flops_per_dev / PEAK_FLOPS
    t_m = bytes_per_dev / HBM_BW
    t_x = coll_bytes_per_dev / ICI_BW
    bound = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x, "bound": bound}


def model_flops(cfg, spec, n_total: int, n_active: int) -> float:
    """6·N·D (train) / 2·N_active·D (inference), whole step over all chips."""
    tokens = spec.global_batch * (spec.seq_len if spec.kind != "decode" else 1)
    n = n_active if cfg.moe_num_experts else n_total
    if spec.kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens
