"""Serving driver: batched prefill + decode with KV caches.

Usage:
  python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.fl import steps as steps_mod
    from repro.models import encdec, lm

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get_config(args.arch)
    model = encdec if cfg.is_encoder_decoder else lm
    params = model.init_params(jax.random.key(0), cfg)

    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G
    key = jax.random.key(1)

    if cfg.is_encoder_decoder:
        batch = {
            "embeds": jax.random.normal(key, (B, P, cfg.d_model)) * 0.3,
            "tokens": jax.random.randint(jax.random.fold_in(key, 1), (B, P), 0, cfg.vocab_size),
        }
        full_cache = encdec.init_cache(cfg, B, max_len, P)
    elif cfg.embed_inputs:
        batch = {"embeds": jax.random.normal(key, (B, P, cfg.d_model)) * 0.3}
        full_cache = lm.init_cache(cfg, B, max_len)
    else:
        batch = {"tokens": jax.random.randint(key, (B, P), 0, cfg.vocab_size)}
        full_cache = lm.init_cache(cfg, B, max_len)

    prefill = jax.jit(steps_mod.build_prefill_step(cfg))
    decode = jax.jit(steps_mod.build_decode_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    pcache, tok = prefill(params, batch)

    # merge prefill cache (prefix-length) into the max_len cache
    def merge(full, pre):
        def f(a, b):
            if a.shape == b.shape:
                return b.astype(a.dtype)
            return jax.lax.dynamic_update_slice(a, b.astype(a.dtype), (0,) * a.ndim)
        return jax.tree.map(f, full, pre)

    cache = merge(full_cache, pcache)
    t1 = time.time()

    out_tokens = [tok]
    for i in range(G - 1):
        cache, tok = decode(params, cache, tok[:, None], jnp.asarray(P + i, jnp.int32))
        out_tokens.append(tok)
    toks = jnp.stack(out_tokens, axis=1)
    t2 = time.time()
    print(f"prefill {P} tokens x{B}: {t1-t0:.2f}s; decode {G} tokens: {(t2-t1)/max(G-1,1)*1e3:.1f} ms/token")
    print("generated token ids (first row):", toks[0].tolist())


if __name__ == "__main__":
    main()
