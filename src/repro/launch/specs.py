"""input_specs + sharding resolution for every (arch x shape x mesh) cell.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every step input (no allocation), plus the PartitionSpec
trees the launcher turns into NamedShardings.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.config import SHAPES, ModelConfig, RunPlan, ShapeSpec
from repro.models import encdec, lm, nn
from repro.fl import steps as steps_mod
from . import mesh as mesh_mod


def fit_spec(spec: P, shape: tuple[int, ...], sizes: dict[str, int]) -> P:
    """Drop mesh axes from dims they don't divide (e.g. batch=1 decode)."""
    out = []
    for i, part in enumerate(spec):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        total = 1
        for a in axes:
            total *= sizes.get(a, 1)
        if i < len(shape) and shape[i] % total == 0:
            out.append(part)
        else:
            out.append(None)
    return P(*out)


def fit_specs_tree(specs, shapes, sizes):
    return jax.tree.map(
        lambda s, x: fit_spec(s, x.shape, sizes),
        specs,
        shapes,
        is_leaf=lambda x: isinstance(x, P),
    )


class Cell(NamedTuple):
    """Everything needed to lower one (arch x shape x mesh) cell."""

    cfg: ModelConfig
    shape: ShapeSpec
    plan: RunPlan
    step_fn: Any
    args: tuple  # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _batch_specs(cfg: ModelConfig, spec: ShapeSpec, *, with_labels: bool) -> tuple[dict, dict]:
    B, S = spec.global_batch, spec.seq_len
    batch, bspecs = {}, {}
    dp = nn.DP
    if cfg.is_encoder_decoder:
        batch["embeds"] = _sds((B, S, cfg.d_model), cfg.jdtype)  # frontend stub
        bspecs["embeds"] = P(dp, None, None)
        batch["tokens"] = _sds((B, S), jnp.int32)
        bspecs["tokens"] = P(dp, None)
    elif cfg.embed_inputs and spec.kind in ("train", "prefill"):
        batch["embeds"] = _sds((B, S, cfg.d_model), cfg.jdtype)  # patch embeds stub
        bspecs["embeds"] = P(dp, None, None)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
        bspecs["tokens"] = P(dp, None)
    if with_labels:
        batch["labels"] = _sds((B, S), jnp.int32)
        bspecs["labels"] = P(dp, None)
    return batch, bspecs


def resolve(tree, *, multi_pod: bool, pod_replicated: bool):
    """Resolve logical placeholders; pod_replicated forces fsdp=('data',)."""
    return nn.resolve_specs(tree, multi_pod=multi_pod and not pod_replicated)


def build_cell(arch: str, shape_name: str, mesh, *, aggregation: str | None = None,
               cfg_overrides: dict | None = None, grad_accum: int | None = None) -> Cell:
    cfg = configs.get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    spec = SHAPES[shape_name]
    plan = configs.get_plan(arch, shape_name)
    import dataclasses

    if aggregation is not None:
        plan = dataclasses.replace(plan, aggregation=aggregation)
    if grad_accum is not None:
        plan = dataclasses.replace(plan, grad_accum=grad_accum)
    sizes = mesh_mod.mesh_axis_sizes(mesh)
    multi_pod = "pod" in sizes
    npods = sizes.get("pod", 1)
    # Totoro tree mode: params replicated across pods (zone replicas)
    pod_replicated = plan.aggregation.startswith("totoro_tree") and multi_pod and spec.kind == "train"

    def rs(tree):
        """Param/state resolution (pod-replicated in totoro_tree mode)."""
        return resolve(tree, multi_pod=multi_pod, pod_replicated=pod_replicated)

    def rs_batch(tree):
        """Batch/cache resolution — always sharded across pods when present."""
        return resolve(tree, multi_pod=multi_pod, pod_replicated=False)

    # activation sharding axes for with_sharding_constraint inside the graph.
    # Batch dims are sharded over ('pod','data') even when params are
    # pod-replicated (zones process disjoint clients); in the podded-vmap
    # (q8) mode the pod dim is outside the vmapped view, so 'data' only.
    podded_mode = plan.aggregation == "totoro_tree_q8" and multi_pod and spec.kind == "train"
    if multi_pod and not podded_mode:
        nn.set_activation_axes(dp=("pod", "data"), tp="model", sp="model", sizes=sizes)
    else:
        nn.set_activation_axes(dp="data", tp="model", sp="model", sizes=sizes)

    model = encdec if cfg.is_encoder_decoder else lm
    key = jax.random.key(0)
    params_shapes = jax.eval_shape(lambda k: model.init_params(k, cfg), key)
    pspecs = rs(model.param_specs(cfg))
    pspecs = fit_specs_tree(pspecs, params_shapes, sizes)

    if spec.kind == "train":
        podded = plan.aggregation == "totoro_tree_q8" and multi_pod
        state_shapes = jax.eval_shape(
            lambda k: steps_mod.init_train_state(
                cfg, model.init_params(k, cfg), num_pods=npods, podded=podded
            ),
            key,
        )
        sspecs = steps_mod.train_state_specs(cfg, pspecs, params_shapes, podded=podded)
        sspecs = fit_specs_tree(sspecs, state_shapes, sizes)
        batch, bspecs = _batch_specs(cfg, spec, with_labels=True)
        bspecs = rs_batch(bspecs)
        bspecs = fit_specs_tree(bspecs, batch, sizes)
        step = steps_mod.build_train_step(cfg, plan, num_pods=npods)
        in_sh = (
            jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs, is_leaf=lambda x: isinstance(x, P)),
            jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs, is_leaf=lambda x: isinstance(x, P)),
        )
        out_sh = (in_sh[0], NamedSharding(mesh, P()))
        return Cell(cfg, spec, plan, step, (state_shapes, batch), in_sh, out_sh, (0,))

    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P))
    B, S = spec.global_batch, spec.seq_len

    if spec.kind == "prefill":
        batch, bspecs = _batch_specs(cfg, spec, with_labels=False)
        bspecs = fit_specs_tree(rs_batch(bspecs), batch, sizes)
        if cfg.is_encoder_decoder:
            cache_shp, cache_specs = encdec.cache_shapes(cfg, B, S, S)
        else:
            cache_shp, cache_specs = lm.cache_shapes(cfg, B, S)
        cache_specs = fit_specs_tree(rs_batch(cache_specs), cache_shp, sizes)
        step = steps_mod.build_prefill_step(cfg)
        in_sh = (
            pshard,
            jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs, is_leaf=lambda x: isinstance(x, P)),
        )
        out_sh = (
            jax.tree.map(lambda s: NamedSharding(mesh, s), cache_specs, is_leaf=lambda x: isinstance(x, P)),
            NamedSharding(mesh, P()),
        )
        return Cell(cfg, spec, plan, step, (params_shapes, batch), in_sh, out_sh, ())

    assert spec.kind == "decode"
    if cfg.is_encoder_decoder:
        cache_shp, cache_specs = encdec.cache_shapes(cfg, B, S, S)
    else:
        cache_shp, cache_specs = lm.cache_shapes(cfg, B, S)
    cache_specs = fit_specs_tree(rs_batch(cache_specs), cache_shp, sizes)
    cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cache_specs, is_leaf=lambda x: isinstance(x, P))
    token = _sds((B, 1), jnp.int32)
    dp_part = ("pod", "data") if multi_pod else ("data",)
    tok_spec = fit_spec(P(dp_part, None), token.shape, sizes)
    tok_sh = NamedSharding(mesh, tok_spec)
    idx = _sds((), jnp.int32)
    idx_sh = NamedSharding(mesh, P())
    step = steps_mod.build_decode_step(cfg)
    in_sh = (pshard, cache_sh, tok_sh, idx_sh)
    out_sh = (cache_sh, NamedSharding(mesh, fit_spec(P(("pod", "data") if multi_pod else ("data",)), (B,), sizes)))
    return Cell(cfg, spec, plan, step, (params_shapes, cache_shp, token, idx), in_sh, out_sh, (1,))


def lower_cell(cell: Cell, mesh):
    jitted = jax.jit(
        cell.step_fn,
        in_shardings=cell.in_shardings,
        out_shardings=cell.out_shardings,
        donate_argnums=cell.donate_argnums,
    )
    # jax < 0.6 has no jax.set_mesh; Mesh is itself the ambient-mesh context
    ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
    with ctx:
        lowered = jitted.lower(*cell.args)
        return lowered
