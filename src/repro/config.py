"""Framework configuration: model configs, shape specs, run plans."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax.numpy as jnp

_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | ssm | hybrid | moe | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    attn_impl: str = "gqa"  # gqa | mla | none
    tp_pad_multiple: int = 1  # pad query heads per kv group to shard evenly
    qk_norm: bool = False
    rope_theta: float = 1e4
    window: int | None = None  # sliding-window attention (beyond-paper long-ctx option)
    attn_chunk: int = 512  # flash-chunk size

    # MLA
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64

    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_num_shared: int = 0
    moe_every: int = 1  # every k-th layer within a pattern block is MoE
    capacity_factor: float = 1.25
    moe_group_size: int = 512
    first_dense: int = 0  # leading dense (non-MoE) layers
    first_dense_d_ff: int = 0

    # hybrid / ssm
    attn_every: int = 1  # 1 attention layer per `attn_every` layers (jamba: 8)
    ssm_kind: str = ""  # '' | 'mamba' | 'rwkv6'
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    ssm_chunk: int = 64

    # enc-dec
    is_encoder_decoder: bool = False
    enc_layers: int = 0

    # io
    embed_inputs: bool = False  # frontend stub supplies embeddings
    tie_embeddings: bool = False

    # numerics / memory
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | save_mixer_ffn (keep sublayer
    # outputs: backward skips re-running attention/FFN forward, removing one
    # of three TP-collective passes at ~2 sharded tensors/layer of memory)
    optimizer: str = "adamw"  # adamw | adafactor | sgd
    learning_rate: float = 3e-4
    weight_decay: float = 0.0
    moe_aux_weight: float = 0.01

    # FL (Totoro+) integration
    fl_local_steps: int = 1  # FedAvg local steps per round
    fedprox_mu: float = 0.0  # FedProx proximal coefficient (0 = FedAvg)

    vocab_pad_multiple: int = 1  # pad vocab so embed/head shard evenly

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def jdtype(self):
        return _DTYPES[self.dtype]

    @property
    def pdtype(self):
        return _DTYPES[self.param_dtype]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class RunPlan:
    """Per-(arch, shape) execution plan (memory/comm knobs)."""

    grad_accum: int = 1  # microbatches per FL local step
    aggregation: str = "totoro_tree"  # xla_auto | totoro_tree | totoro_tree_q8
