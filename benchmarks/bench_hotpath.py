"""Hot-path overhaul bench: the simulator racing its pre-optimization self.

The reproduction's wall-clock was bound by its own hot paths, not the
modeled system (ISSUE 5 / docs/performance.md): ragged commit batches
recompiled ``batched_local_train`` per (W, B) shape, every Pallas kernel
ran in interpret mode on CPU, and each flow join/complete re-ran full
water-filling and rescheduled every flow's completion event.  This bench
runs the SAME simulation twice — once on the pre-optimization paths
(``megabatch=False, incremental=False``, kernel mode ``pallas``), once
on the optimized defaults (megabatched bucketed dispatch, compiled jnp
kernel fallback, incremental repricing) — and measures:

- end-to-end wall-clock for a bench_async-style trained run at
  M in {4, 16, 64} (smoke: {4, 16}) with heterogeneous compute + churn;
- training dispatches and jit cache misses per run (``engine.DISPATCH``);
- pure event-engine throughput (events/sec on a timing-model run, no
  trainer) for the incremental vs legacy repricing engines, plus the
  peak heap size (lazy-deletion compaction keeps it bounded).

Gates (CI fails on regression):

- end-to-end speedup at M=16 >= 3x (>= 2x in ``--smoke``: the smaller
  run amortizes fewer recompiles);
- event traces **byte-identical** between the two paths (repricing is
  exact, just incremental; ApplyEvent/ChurnRecord dataclass equality
  on exact float timestamps) and final losses equal to fp tolerance
  (1e-6 — megabatch padding only reorders float reductions);
- optimized jit cache misses bounded by the shape-bucket count
  (O(#buckets), not O(#distinct ragged shapes)).

``python -m benchmarks.bench_hotpath --smoke`` writes BENCH_hotpath.json
(the CI artifact).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import build_system, row


def _bucket_bound(m_apps: int, workers: int) -> int:
    """Upper bound on distinct compiled training programs under the
    power-of-two bucket policy: one static config here, W buckets up to
    bucket(workers * m-ish) and B buckets up to bucket(max shard).  Loose
    on purpose — the gate is O(log^2), not an exact count."""
    logw = int(math.log2(max(2, workers * m_apps))) + 2
    logb = 12  # B <= 2**12 covers every shard size the benches use
    return logw * logb


def _run_trained(m_apps, *, optimized, workers, applies, seed, base_ms, spread,
                 model_bytes, n_nodes, zones):
    """One trained async run on fresh, seed-identical state; returns
    (result dict, wall seconds, dispatch stats snapshot)."""
    from benchmarks.bench_async import _make_apps
    from repro.core.sim import ChurnModel
    from repro.fl import async_engine, engine
    from repro.kernels import ops as kops

    per_worker = async_engine.worker_compute_fn(base_ms, spread, seed=seed)
    sys_a, nodes_a, rng_a = build_system(n_nodes=n_nodes, zones=zones, seed=seed)
    apps_a = _make_apps(sys_a, nodes_a, rng_a, m_apps, workers, tag="h")
    churn = ChurnModel(
        period_ms=6.0 * base_ms, downtime_ms=12.0 * base_ms,
        group_size=max(1, round(0.1 * workers)), seed=seed,
    )
    prev_mode = kops.set_kernel_mode("auto" if optimized else "pallas")
    prev_bucketing = engine.set_bucketing(optimized)
    engine.DISPATCH.reset()
    t0 = time.perf_counter()
    try:
        res = async_engine.run_async(
            sys_a, apps_a, applies=applies, buffer_k=max(2, workers // 2),
            staleness_alpha=0.5, model_bytes=model_bytes, compute_ms=per_worker,
            churn=churn, megabatch=optimized, incremental=optimized,
        )
    finally:
        kops.set_kernel_mode(prev_mode)
        engine.set_bucketing(prev_bucketing)
    wall = time.perf_counter() - t0
    stats = {
        "dispatches": engine.DISPATCH.dispatches,
        "jit_cache_misses": engine.DISPATCH.compiles,
    }
    return res, wall, stats


def _run_timing_model(m_apps, *, incremental, workers, applies, seed, base_ms,
                      spread, model_bytes, n_nodes, zones):
    """Pure event-engine run (no trainer): events/sec + peak heap size."""
    from benchmarks.bench_async import _make_apps
    from repro.core.sim import AsyncBufferScheduler, ChurnModel
    from repro.fl import async_engine

    per_worker = async_engine.worker_compute_fn(base_ms, spread, seed=seed)
    sys_a, nodes_a, rng_a = build_system(n_nodes=n_nodes, zones=zones, seed=seed)
    apps_a = _make_apps(sys_a, nodes_a, rng_a, m_apps, workers, tag="t")
    churn = ChurnModel(
        period_ms=6.0 * base_ms, downtime_ms=12.0 * base_ms,
        group_size=max(1, round(0.1 * workers)), seed=seed,
    )
    sched = AsyncBufferScheduler(
        sys_a, [a.handle for a in apps_a], model_bytes=model_bytes,
        compute_ms=per_worker, buffer_k=max(2, workers // 2), churn=churn,
        incremental=incremental,
    )
    t0 = time.perf_counter()
    events = sched.run(applies)
    wall = time.perf_counter() - t0
    return {
        "events": events,
        "wall_s": wall,
        "events_dispatched": sched.events_dispatched,
        "events_per_sec": sched.events_dispatched / max(wall, 1e-9),
        "heap_max": sched.heap_max,
    }


def hotpath_compare(m_apps: int, *, workers=8, applies=3, timing_applies=12,
                    seed=0, base_ms=40.0, spread=6.0, model_bytes=2e5,
                    n_nodes=600, zones=4) -> dict:
    """Baseline vs optimized on identical seeds/topology/churn.  The
    baseline runs FIRST so any jit-cache sharing between the two runs
    favors it.  Returns the metric dict (no gating here; see gate())."""
    cfg = dict(workers=workers, applies=applies, seed=seed, base_ms=base_ms,
               spread=spread, model_bytes=model_bytes, n_nodes=n_nodes, zones=zones)
    res_b, wall_b, disp_b = _run_trained(m_apps, optimized=False, **cfg)
    res_o, wall_o, disp_o = _run_trained(m_apps, optimized=True, **cfg)

    losses_b = [r["loss"] for r in res_b["history"]]
    losses_o = [r["loss"] for r in res_o["history"]]
    loss_max_diff = (
        max((abs(a - b) for a, b in zip(losses_b, losses_o)), default=0.0)
        if len(losses_b) == len(losses_o)
        else float("inf")
    )
    tm_cfg = dict(workers=workers, applies=timing_applies, seed=seed,
                  base_ms=base_ms, spread=spread, model_bytes=model_bytes,
                  n_nodes=n_nodes, zones=zones)
    tm_legacy = _run_timing_model(m_apps, incremental=False, **tm_cfg)
    tm_inc = _run_timing_model(m_apps, incremental=True, **tm_cfg)

    applies_total = max(len(res_o["events"]), 1)
    return {
        "m": m_apps,
        "workers": workers,
        "applies": applies,
        "wall_s_baseline": wall_b,
        "wall_s_optimized": wall_o,
        "speedup": wall_b / max(wall_o, 1e-9),
        "traces_identical": res_b["events"] == res_o["events"]
        and res_b["churn"] == res_o["churn"]
        and tm_legacy["events"] == tm_inc["events"],
        "loss_max_diff": loss_max_diff,
        "dispatches_baseline": disp_b["dispatches"],
        "dispatches_optimized": disp_o["dispatches"],
        "dispatches_per_apply_baseline": disp_b["dispatches"] / applies_total,
        "dispatches_per_apply_optimized": disp_o["dispatches"] / applies_total,
        "jit_cache_misses_baseline": disp_b["jit_cache_misses"],
        "jit_cache_misses_optimized": disp_o["jit_cache_misses"],
        "bucket_bound": _bucket_bound(m_apps, workers),
        "events_per_sec_legacy": tm_legacy["events_per_sec"],
        "events_per_sec_incremental": tm_inc["events_per_sec"],
        "events_speedup": tm_inc["events_per_sec"]
        / max(tm_legacy["events_per_sec"], 1e-9),
        "heap_max_legacy": tm_legacy["heap_max"],
        "heap_max_incremental": tm_inc["heap_max"],
    }


def gate(results: list[dict], *, min_speedup_m16: float) -> list[str]:
    """The acceptance gates; returns failure messages (empty = pass)."""
    fails = []
    for r in results:
        if not r["traces_identical"]:
            fails.append(f"M={r['m']}: event traces diverge between paths")
        if not (r["loss_max_diff"] <= 1e-6):
            fails.append(
                f"M={r['m']}: final losses diverge (max diff {r['loss_max_diff']:.2e})"
            )
        if r["jit_cache_misses_optimized"] > r["bucket_bound"]:
            fails.append(
                f"M={r['m']}: {r['jit_cache_misses_optimized']} jit cache misses "
                f"exceed the bucket bound {r['bucket_bound']}"
            )
        if r["m"] == 16 and r["speedup"] < min_speedup_m16:
            fails.append(
                f"M=16 speedup {r['speedup']:.2f}x below the "
                f"{min_speedup_m16:.1f}x gate"
            )
    return fails


def run() -> list[str]:
    out = []
    for m in (4, 16):
        r = hotpath_compare(m)
        out.append(
            row(
                f"hotpath_m{m}",
                r["wall_s_optimized"] * 1e6,
                f"speedup={r['speedup']:.2f}x;"
                f"events_per_sec={r['events_per_sec_incremental']:.0f}"
                f"(x{r['events_speedup']:.2f});"
                f"dispatches_per_apply={r['dispatches_per_apply_optimized']:.2f};"
                f"jit_misses={r['jit_cache_misses_optimized']};"
                f"traces_identical={r['traces_identical']}",
            )
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--smoke", action="store_true",
                    help="small config (M in {4,16}, 2x gate); write artifact")
    ap.add_argument("--out", default="BENCH_hotpath.json")
    args = ap.parse_args()
    if args.smoke:
        ms, applies, min_speedup = (4, 16), 2, 2.0
    else:
        ms, applies, min_speedup = (4, 16, 64), 3, 3.0
    results = [hotpath_compare(m, applies=applies) for m in ms]
    payload = {
        "bench": "hotpath_megabatch_jnp_fallback_incremental_repricing",
        "smoke": bool(args.smoke),
        "min_speedup_m16": min_speedup,
        "results": results,
    }
    out_path = os.path.abspath(args.out)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, allow_nan=False)
    for r in results:
        print(
            f"M={r['m']}: wall {r['wall_s_baseline']:.1f}s -> "
            f"{r['wall_s_optimized']:.1f}s ({r['speedup']:.2f}x); "
            f"events/s {r['events_per_sec_legacy']:.0f} -> "
            f"{r['events_per_sec_incremental']:.0f}; dispatches/apply "
            f"{r['dispatches_per_apply_baseline']:.2f} -> "
            f"{r['dispatches_per_apply_optimized']:.2f}; jit misses "
            f"{r['jit_cache_misses_baseline']} -> {r['jit_cache_misses_optimized']}; "
            f"heap max {r['heap_max_legacy']} -> {r['heap_max_incremental']}; "
            f"traces identical {r['traces_identical']} "
            f"(loss diff {r['loss_max_diff']:.1e})"
        )
    fails = gate(results, min_speedup_m16=min_speedup)
    print(f"wrote {out_path}")
    for msg in fails:
        print(f"GATE FAIL: {msg}")
    if fails:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
