"""Compressed transport: qsgd commits + delta-qsgd broadcasts on a tight wire.

Two axes, both driven from ``bench_fairness`` on the same commit-bound
fixture (M apps, near-zero compute, 2 MB model):

**Uplink** (``compression_compare``): per-app
``CompressionPolicy(kind="qsgd-int8")`` shrinks every commit flow to
~0.26x (int8 lattice + per-256-chunk f32 scales) and the scheduler
prices exactly those bytes through the fair-share fluid model, so the
saving must show up as simulated wall-clock.

**Downlink** (``downlink_compare``): with the uplink compressed, the
full-f32 broadcast leg is ~80% of the remaining wire.  Adding
``downlink="delta-qsgd"`` broadcasts 3-bit packed version deltas
against the master's reference reconstruction; workers within
``chain_cap`` versions download only their gap's cached deltas, and
rejoiners fall back to the full f32 state.

Gates (``bench_fairness.gate_compression`` / ``gate_downlink``):

- uplink: mean time-to-target-loss < 0.95x, loss gap <= 1e-2, uplink
  bytes < 0.3x, > 25% per-app starvation guard;
- downlink (vs the uplink-only baseline): TOTAL wire bytes (up + down)
  < 0.35x, mean time-to-target <= 0.90x, Jain over per-app progress no
  worse, same starvation guard.

``python -m benchmarks.bench_compression --smoke`` runs M=16 on both
axes and writes ``BENCH_compression.json`` (a CI artifact); the full
run adds M=64 on the uplink axis.  Everything is seeded and
deterministic.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_fairness import (
    compression_compare,
    downlink_compare,
    gate_compression,
    gate_downlink,
)
from benchmarks.common import row

SMOKE_MS = (16,)   # --smoke stays bounded at M <= 16
FULL_MS = (16, 64)
DOWNLINK_MS = (16,)  # the downlink gate is specified at M=16


def run() -> list[str]:
    out = []
    for m in SMOKE_MS:
        r = compression_compare(m)
        out.append(
            row(
                f"compression_m{m}",
                0.0,
                f"mean_tt_ratio={r['mean_tt_ratio']:.2f};"
                f"loss_gap={r['loss_gap']:.4f};bytes_ratio={r['bytes_ratio']:.3f}",
            )
        )
    for m in DOWNLINK_MS:
        r = downlink_compare(m)
        out.append(
            row(
                f"downlink_m{m}",
                0.0,
                f"mean_tt_ratio={r['mean_tt_ratio']:.2f};"
                f"total_bytes_ratio={r['bytes_total_ratio']:.3f};"
                f"jain={r['jain_up_only']:.3f}->{r['jain_up_down']:.3f}",
            )
        )
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--smoke", action="store_true",
                    help="M=16 on both axes; write BENCH_compression.json")
    ap.add_argument("--out", default="BENCH_compression.json")
    args = ap.parse_args(argv)

    results = [compression_compare(m) for m in (SMOKE_MS if args.smoke else FULL_MS)]
    for r in results:
        print(
            f"M={r['m']}: time-to-loss qsgd/none mean {r['mean_tt_ratio']:.2f}x "
            f"(worst {r['max_tt_ratio']:.2f}x)  loss gap {r['loss_gap']:.4f}  "
            f"uplink bytes {r['bytes_ratio']:.3f}x"
        )

    down_results = [downlink_compare(m) for m in DOWNLINK_MS]
    for r in down_results:
        print(
            f"M={r['m']} downlink: time-to-loss up+down/up-only mean "
            f"{r['mean_tt_ratio']:.2f}x (worst {r['max_tt_ratio']:.2f}x)  "
            f"total bytes {r['bytes_total_ratio']:.3f}x  "
            f"broadcast bytes {r['downlink_bytes_ratio']:.3f}x  "
            f"jain {r['jain_up_only']:.3f} -> {r['jain_up_down']:.3f}"
        )

    from benchmarks.bench_async import _json_safe

    payload = _json_safe({
        "bench": "compressed_transport",
        "smoke": bool(args.smoke),
        "results": results,
        "downlink_results": down_results,
    })
    out_path = os.path.abspath(args.out)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, allow_nan=False)
    print(f"wrote {out_path}")

    fails = gate_compression(results) + gate_downlink(down_results)
    for msg in fails:
        print(f"GATE FAIL: {msg}")
    if fails:
        raise SystemExit(1)
    print("compression gates passed: uplink (mean time-to-target improves, "
          "no app starved, loss gap <= 1e-2, uplink bytes < 0.3x) and "
          "downlink (total bytes < 0.35x, mean time-to-target <= 0.90x, "
          "jain no worse)")


if __name__ == "__main__":
    main()
