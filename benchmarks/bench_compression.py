"""Compressed transport: qsgd-int8 commits vs full-f32 on a tight uplink.

Drives ``bench_fairness.compression_compare`` — M apps with near-zero
compute and a 2 MB model, so the commit uplink dominates each cycle.
Per-app ``CompressionPolicy(kind="qsgd-int8")`` shrinks every commit
flow to ~0.26x (int8 lattice + per-256-chunk f32 scales) and the
scheduler prices exactly those bytes through the fair-share fluid model,
so the saving must show up as simulated wall-clock.

Gates (``bench_fairness.gate_compression``):

- the mean simulated time-to-target-loss clearly improves under
  compression (< 0.95x), with a > 25% per-app starvation guard (the
  crossing time is quantized by apply events, so single-apply shifts
  are tolerated);
- the mean final loss drifts <= 1e-2 from the uncompressed run
  (stochastic int8 rounding is statistically free at levels=127);
- total uplink bytes shrink below 0.3x.

``python -m benchmarks.bench_compression --smoke`` runs M=16 and writes
``BENCH_compression.json`` (a CI artifact); the full run adds M=64.
Everything is seeded and deterministic.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_fairness import compression_compare, gate_compression
from benchmarks.common import row

SMOKE_MS = (16,)   # --smoke stays bounded at M <= 16
FULL_MS = (16, 64)


def run() -> list[str]:
    out = []
    for m in SMOKE_MS:
        r = compression_compare(m)
        out.append(
            row(
                f"compression_m{m}",
                0.0,
                f"mean_tt_ratio={r['mean_tt_ratio']:.2f};"
                f"loss_gap={r['loss_gap']:.4f};bytes_ratio={r['bytes_ratio']:.3f}",
            )
        )
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--smoke", action="store_true",
                    help="M=16 only; write BENCH_compression.json")
    ap.add_argument("--out", default="BENCH_compression.json")
    args = ap.parse_args(argv)

    results = [compression_compare(m) for m in (SMOKE_MS if args.smoke else FULL_MS)]
    for r in results:
        print(
            f"M={r['m']}: time-to-loss qsgd/none mean {r['mean_tt_ratio']:.2f}x "
            f"(worst {r['max_tt_ratio']:.2f}x)  loss gap {r['loss_gap']:.4f}  "
            f"uplink bytes {r['bytes_ratio']:.3f}x"
        )

    from benchmarks.bench_async import _json_safe

    payload = _json_safe({
        "bench": "compressed_transport",
        "smoke": bool(args.smoke),
        "results": results,
    })
    out_path = os.path.abspath(args.out)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, allow_nan=False)
    print(f"wrote {out_path}")

    fails = gate_compression(results)
    for msg in fails:
        print(f"GATE FAIL: {msg}")
    if fails:
        raise SystemExit(1)
    print("compression gates passed: mean time-to-target clearly improves "
          "(no app starved), loss gap <= 1e-2, uplink bytes < 0.3x")


if __name__ == "__main__":
    main()
