"""Figs 15-16: Algorithm-1 runtime vs node count + per-line breakdown.

The paper's claim: Totoro+'s update is parallel matrix algebra (~50 ms,
flat in N) vs Totoro's per-node convex solves (grows to ~1.5 s).  We
measure the batched JAX update and the Pallas kernel (interpret mode),
plus a per-line cost breakdown mirroring Fig 16.
"""
from __future__ import annotations

import time

import numpy as np

from .common import row, timeit


def run() -> list[str]:
    import jax
    import jax.numpy as jnp

    from repro.core.pathplan import algorithm1_episode, candidate_policy_set
    from repro.kernels import ops as kops

    out = []
    K, tau = 16, 8
    cand = candidate_policy_set(K)
    for N in (100, 1000, 10000):
        key = jax.random.key(N)
        pi = jax.random.dirichlet(key, jnp.ones(K), (N,)).astype(jnp.float32)
        mask = jnp.ones((N, K), bool)
        actions = jax.random.randint(jax.random.fold_in(key, 1), (N, tau), 0, K)
        rewards = jax.random.uniform(jax.random.fold_in(key, 2), (N, tau))

        t, _ = timeit(
            lambda: jax.block_until_ready(
                algorithm1_episode(pi, mask, cand, actions, rewards, tau=tau, alpha=0.9, beta=0.5)
            )
        )
        out.append(row(f"fig15_alg1_jax_n{N}", t * 1e6, f"ms_total={t*1e3:.2f}"))

        rsums = (jax.nn.one_hot(actions, K) * rewards[..., None]).sum(1)
        t2, _ = timeit(
            lambda: jax.block_until_ready(
                kops.policy_update(pi, mask, cand, rsums, tau=tau, alpha=0.9, beta=0.5)
            )
        )
        out.append(row(f"fig15_alg1_pallas_n{N}", t2 * 1e6, f"ms_total={t2*1e3:.2f}"))

    # Fig 16: line breakdown (jitted pieces timed separately)
    N = 10000
    key = jax.random.key(0)
    pi = jax.random.dirichlet(key, jnp.ones(K), (N,)).astype(jnp.float32)
    maskf = jnp.ones((N, K), jnp.float32)
    actions = jax.random.randint(jax.random.fold_in(key, 1), (N, tau), 0, K)
    rewards = jax.random.uniform(jax.random.fold_in(key, 2), (N, tau))

    candn = jax.jit(lambda m: cand[None] * m[:, None, :] / jnp.maximum((cand[None] * m[:, None, :]).sum(-1, keepdims=True), 1e-12))
    line5 = jax.jit(lambda c: jnp.argmin(jnp.log(jnp.maximum(c, 1e-12)).sum(-1), axis=1))
    line6 = jax.jit(lambda a, r, p: (jax.nn.one_hot(a, K) * r[..., None]).sum(1) / (tau * jnp.maximum(p, 1e-12)))
    line7 = jax.jit(lambda c, g: jnp.argmax(jnp.einsum("nmk,nk->nm", c, g), axis=1))
    line8 = jax.jit(lambda p, pt, rh: 0.9 * (p + 0.5 * (pt - p)) + 0.1 * rh)

    c = candn(maskf)
    g = line6(actions, rewards, pi)
    i5 = line5(c)
    i7 = line7(c, g)
    rho = c[jnp.arange(N), i5]
    pit = c[jnp.arange(N), i7]
    for name, fn in (
        ("line5_min_det", lambda: jax.block_until_ready(line5(c))),
        ("line6_grad_est", lambda: jax.block_until_ready(line6(actions, rewards, pi))),
        ("line7_argmax", lambda: jax.block_until_ready(line7(c, g))),
        ("line8_frank_wolfe", lambda: jax.block_until_ready(line8(pi, pit, rho))),
    ):
        t, _ = timeit(fn)
        out.append(row(f"fig16_{name}", t * 1e6, f"n={N}"))

    # batched level aggregation: one kernel launch for a whole tree level
    # of G (parent, children) groups vs G separate aggregator calls.
    # (CPU numbers are interpret-mode — the launch-count reduction is the
    # TPU story; the row records both times plus the dispatch ratio.)
    G, C, L = 32, 8, 8192
    key = jax.random.key(1)
    g = jax.random.normal(key, (G, C, L), jnp.float32)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (G, C), jnp.float32)
    t_b, _ = timeit(lambda: jax.block_until_ready(kops.tree_aggregate_groups(g, w)))
    t_f, _ = timeit(
        lambda: [jax.block_until_ready(kops.tree_aggregate(g[i], w[i])) for i in range(G)]
    )
    out.append(
        row(
            "level_agg_batched_g32",
            t_b * 1e6,
            f"launches=1_vs_{G};batched_ms={t_b*1e3:.2f};"
            f"per_group_ms={t_f*1e3:.2f};mode=interpret",
        )
    )
    return out
