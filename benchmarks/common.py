"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

import math
import time

import numpy as np


def build_system(n_nodes=2000, zones=8, seed=0, base_bits=4, suffix_bits=24,
                 bulk=False):
    """Build a populated TotoroSystem.  ``bulk=False`` (default) joins
    node-by-node — that exact draw order anchors the trace-identity
    baselines, so it must not change; ``bulk=True`` is the vectorized
    `join_many` path for benches that only need *a* population fast
    (different rng consumption, so different node ids)."""
    from repro.core.api import TotoroSystem

    sys_ = TotoroSystem(
        zone_bits=int(math.log2(zones)), suffix_bits=suffix_bits,
        base_bits=base_bits, seed=seed,
    )
    rng = np.random.default_rng(seed)
    if bulk:
        sites = rng.integers(0, zones, n_nodes)
        coords = rng.uniform(0, 100, (n_nodes, 2))
        bws = rng.uniform(20, 100, n_nodes)
        nodes = sys_.overlay.join_many(sites, coords=coords, bandwidth=bws).tolist()
    else:
        nodes = [
            sys_.Join("n", i, site=int(rng.integers(0, zones)), coord=rng.uniform(0, 100, 2),
                      bandwidth=float(rng.uniform(20, 100)))
            for i in range(n_nodes)
        ]
    return sys_, nodes, rng


def eua_like_coords(n: int, seed: int = 0) -> np.ndarray:
    """EUA-style clustered geography: population-weighted city clusters
    (stand-in for the 95,271-station Australian dataset)."""
    rng = np.random.default_rng(seed)
    # 12 'states' with skewed populations like the EUA split
    weights = np.array([24574, 21576, 18163, 15933, 7682, 3213, 3137, 931, 36, 15, 8, 3], float)
    weights /= weights.sum()
    centers = rng.uniform(0, 1000, (12, 2))
    which = rng.choice(12, size=n, p=weights)
    return centers[which] + rng.normal(0, 15, (n, 2))


def timeit(fn, *args, repeat=3, **kw):
    fn(*args, **kw)  # warmup / compile
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return min(ts), out


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
