"""Figs 17-18: failure-recovery time — exponentially more simultaneous
failures in one tree; many trees failing 5% of nodes at once."""
from __future__ import annotations

import numpy as np

from .common import build_system, row, timeit


def run() -> list[str]:
    from repro.core.recovery import ReplicaStore, fail_and_recover, verify_tree

    out = []
    # Fig 17: one 1000-node tree, 1..128 simultaneous failures
    for k in (1, 8, 32, 128):
        sys_, nodes, rng = build_system(n_nodes=3000, zones=4, seed=10 + k)
        h = sys_.CreateTree("rec")
        for w in rng.choice(nodes, size=1000, replace=False):
            sys_.Subscribe(h.app_id, int(w))
        rs = ReplicaStore(k=2)
        rs.replicate(sys_.overlay, h.app_id, h.tree.root, {"round": 0})
        internal = [n for n in h.tree.children if n != h.tree.root]
        leaves = [n for n in h.tree.nodes() if n not in h.tree.children and n != h.tree.root]
        victims = (internal + leaves)[:k]
        import time as _t

        t0 = _t.perf_counter()  # stateful: single invocation (no warmup)
        rep = fail_and_recover(sys_.overlay, sys_.forest, h.tree, list(victims), replicas=rs)
        t = _t.perf_counter() - t0
        ok = verify_tree(h.tree, sys_.overlay)
        out.append(
            row(
                f"fig17_fail{k}",
                t * 1e6,
                f"recovery_ms={rep.recovery_time_ms:.1f};hops={rep.hops};"
                f"rejoined={rep.orphans_rejoined};valid={ok}",
            )
        )

    # Fig 18: 1..16 trees each losing 5% of nodes simultaneously
    for n_trees in (1, 4, 16):
        sys_, nodes, rng = build_system(n_nodes=4000, zones=4, seed=33)
        trees = []
        for i in range(n_trees):
            h = sys_.CreateTree(f"rec-{i}")
            for w in rng.choice(nodes, size=500, replace=False):
                sys_.Subscribe(h.app_id, int(w))
            trees.append(h)
        times = []
        for h in trees:
            victims = [n for n in list(h.tree.nodes()) if n != h.tree.root][: max(1, len(h.tree.nodes()) // 20)]
            rep = sys_.fail_nodes(h.app_id, list(victims))
            times.append(rep.recovery_time_ms)
        # trees recover in parallel -> wall time = max
        out.append(
            row(
                f"fig18_trees{n_trees}",
                0.0,
                f"recovery_ms={max(times):.1f};mean_ms={np.mean(times):.1f}",
            )
        )
    return out
