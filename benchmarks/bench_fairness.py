"""Multi-app uplink fairness: weighted-fair pricing vs start-time pricing.

The seed's transfer model priced each flow once, at start time, against
whatever happened to be in flight — a flow that began alone kept its
solo rate after contenders arrived, and vice versa.  At M >= 16 apps
sharing one edge network that error compounds into uplink starvation
(ROADMAP; the Table-III scaling claim bends).  This bench measures the
fix on an M ∈ {4, 16, 64} matrix with **one hot app** (near-zero
compute, so its workers hammer the shared relays continuously) against
M-1 compute-bound apps:

- **fairness matrix** (timing-only): every app moves the same transfer
  workload (a fixed number of buffered applies); the per-app *uplink
  progress rate* is its solo completion time on the same topology
  divided by its contended completion time (1.0 = as fast as running
  alone — solo-normalized throughput, the standard way to compare apps
  with different demands, and free of horizon-cut truncation bias).
  Jain's index over those rates is gated **>= 0.8** for the
  weighted-fair engine and must improve on the legacy pricing.
- **time-to-loss guard** (trained, M = 16): the same hot/cold mix with
  real training; per-app simulated time until the mean local loss
  reaches the target, fair vs legacy.  Gated: **no app regresses more
  than 5%**, and the max/min spread across apps must not widen —
  restoring fairness must not buy it by slowing anyone down.

``python -m benchmarks.bench_fairness --smoke`` runs M ∈ {4, 16} plus
the trained guard and writes ``BENCH_fairness.json`` (a CI artifact);
the full run adds the M = 64 column.  Everything is seeded and
deterministic.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.common import build_system, row

# one column per M: the topology scales with the app count so the matrix
# stays in the contended-but-feasible regime (oversubscribed enough to
# starve under the seed pricing, not so overloaded that nothing moves)
CONFIGS = {
    4: dict(n_nodes=120, workers=8, model_bytes=1.5e6, applies=4, buffer_k=4),
    16: dict(n_nodes=120, workers=8, model_bytes=1.5e6, applies=4, buffer_k=4),
    64: dict(n_nodes=320, workers=4, model_bytes=8e5, applies=4, buffer_k=2),
}
HOT_MS, COLD_MS = 2.0, 40.0


def _build_handles(m, workers, n_nodes, seed=0):
    """Timing-only fixture: M dataflow trees over one shared overlay."""
    from repro.core.api import TotoroSystem

    sys_ = TotoroSystem(zone_bits=2, suffix_bits=22, seed=seed)
    rng = np.random.default_rng(seed)
    nodes = [
        sys_.Join("n", i, site=i % 4, coord=rng.uniform(0, 50, 2),
                  bandwidth=float(rng.uniform(20, 100)))
        for i in range(n_nodes)
    ]
    handles = []
    for a in range(m):
        h = sys_.CreateTree(f"fairness-{m}-{a}")
        for w in rng.choice(nodes, size=workers, replace=False):
            sys_.Subscribe(h.app_id, int(w))
        handles.append(h)
    return sys_, handles


def _admission():
    from repro.core.sim import RelayAdmission

    return RelayAdmission(threshold=0.6, alpha=0.5, max_defer_ms=150.0)


def fairness_compare(m: int, *, seed: int = 0) -> dict:
    """One matrix column: legacy vs weighted-fair(+relay admission) on
    identical topology/schedules.  Every app completes the same applies
    target in every run (no horizon truncation); the per-app progress
    rate is solo completion time / contended completion time, so 1.0
    means the app ran as fast as it would alone."""
    from repro.core.sim import AsyncBufferScheduler
    from repro.kernels.ops import jain_fairness

    cfg = CONFIGS[m]
    sys_, handles = _build_handles(m, cfg["workers"], cfg["n_nodes"], seed=seed)
    hot_id = handles[0].app_id

    def compute(handle, worker, cycle):
        return HOT_MS if handle.app_id == hot_id else COLD_MS

    def run(fair, relay=None, subset=None):
        hs = handles if subset is None else [handles[i] for i in subset]
        sched = AsyncBufferScheduler(
            sys_, hs, model_bytes=cfg["model_bytes"], compute_ms=compute,
            buffer_k=cfg["buffer_k"], fair=fair, relay_admission=relay,
        )
        sched.run(cfg["applies"], max_events=8_000_000)
        return sched.transport_stats()

    # solo baseline: each app alone on the same topology under the
    # correct (fluid) pricing — its own workers still share intra-app
    # relays; both modes normalize by this one true demand
    solo = [run(True, subset=[a])["done_ms"][0] for a in range(m)]

    def rates(st):
        return [s / max(d, 1e-9) for s, d in zip(solo, st["done_ms"])]

    legacy = run(False)
    fair = run(True, _admission())
    r_legacy, r_fair = rates(legacy), rates(fair)
    return {
        "m": m,
        "jain_legacy": jain_fairness(r_legacy),
        "jain_fair": jain_fairness(r_fair),
        "hot_ratio_legacy": r_legacy[0],
        "hot_ratio_fair": r_fair[0],
        "min_ratio_legacy": min(r_legacy),
        "min_ratio_fair": min(r_fair),
        "deferred_commits": fair["deferred_commits"],
        "jain_bytes_legacy": jain_fairness(legacy["uplink_bytes"]),
        "jain_bytes_fair": jain_fairness(fair["uplink_bytes"]),
        "ratios_legacy": r_legacy,
        "ratios_fair": r_fair,
    }


def time_to_loss_guard(*, m: int = 16, seed: int = 0, target: float = 0.35) -> dict:
    """Trained fair-vs-legacy comparison at M apps with one hot app:
    per-app simulated time-to-target-loss must not regress under the
    fairness fix, and the cross-app spread must not widen."""
    from repro import data as data_mod
    from repro.fl import async_engine, rounds

    workers, applies = 8, 14

    def make_apps(sys_, nodes, rng):
        apps = []
        for a in range(m):
            x, y = data_mod.synthetic_classification(workers * 24, 16, 4, seed=100 + a)
            parts = data_mod.dirichlet_partition(y, workers, alpha=1.0, seed=200 + a)
            ws = [int(n) for n in rng.choice(nodes, size=workers, replace=False)]
            apps.append(
                rounds.make_app(
                    sys_, f"ttl-{m}-{a}", workers=ws,
                    data_by_worker={n: (x[parts[i]], y[parts[i]]) for i, n in enumerate(ws)},
                    dim=16, num_classes=4, local_steps=3, lr=0.2, seed=a,
                )
            )
        return apps

    def tt(history, app_id):
        for r in history:
            if r["app_id"] == app_id and r["loss"] <= target:
                return r["t_ms"]
        return float("inf")

    def run(fair, relay=None):
        sys_, nodes, rng = build_system(n_nodes=300, zones=4, seed=seed)
        apps = make_apps(sys_, nodes, rng)
        hot_id = apps[0].handle.app_id

        def compute(handle, worker, cycle):
            if handle.app_id == hot_id:
                return 5.0
            slow = np.random.default_rng([7, handle.app_id, worker])
            return COLD_MS * (1.0 + 3.0 * float(slow.random()))

        res = async_engine.run_async(
            sys_, apps, applies=applies, buffer_k=4, staleness_alpha=0.5,
            model_bytes=4e5, compute_ms=compute, fair=fair, relay_admission=relay,
        )
        return [tt(res["history"], a.handle.app_id) for a in apps]

    tt_legacy = run(False)
    tt_fair = run(True, _admission())
    ratio = [f / max(l, 1e-9) for f, l in zip(tt_fair, tt_legacy)]

    def spread(ts):
        finite = [t for t in ts if np.isfinite(t)]
        return max(finite) / max(min(finite), 1e-9) if finite else float("inf")

    return {
        "m": m,
        "target_loss": target,
        "tt_legacy_ms": tt_legacy,
        "tt_fair_ms": tt_fair,
        "tt_ratio": ratio,
        "max_regression": max(ratio),
        "mean_ratio": float(np.mean(ratio)),
        "spread_legacy": spread(tt_legacy),
        "spread_fair": spread(tt_fair),
        "all_finite": bool(all(np.isfinite(t) for t in tt_fair + tt_legacy)),
    }


def compression_compare(
    m: int = 64, *, seed: int = 0, target: float = 0.35
) -> dict:
    """The tight-uplink compression axis (docs/performance.md "compressed
    transport"): M apps with near-zero compute and a big model, so the
    commit uplink is the bottleneck; qsgd-int8 vs uncompressed on the
    identical topology/schedule.  Gated (``gate_compression``): the mean
    simulated time-to-target-loss must clearly improve under compression
    (the ~4x smaller commit flows must actually buy wall-clock; no
    single app may regress > 25% — a starvation guard, sized to tolerate
    one-apply quantization shifts in the crossing time), and the mean
    final loss may not drift more than 1e-2 from the uncompressed run
    (int8 rounding must stay statistically free)."""
    from repro import data as data_mod
    from repro.fl import async_engine, rounds

    workers, applies, model_bytes = 4, 12, 2e6
    n_nodes = max(80, 5 * m)

    def make_apps(sys_, nodes, rng):
        apps = []
        for a in range(m):
            x, y = data_mod.synthetic_classification(workers * 24, 16, 4, seed=100 + a)
            parts = data_mod.dirichlet_partition(y, workers, alpha=1.0, seed=200 + a)
            ws = [int(n) for n in rng.choice(nodes, size=workers, replace=False)]
            apps.append(
                rounds.make_app(
                    sys_, f"comp-{m}-{a}", workers=ws,
                    data_by_worker={n: (x[parts[i]], y[parts[i]]) for i, n in enumerate(ws)},
                    dim=16, num_classes=4, local_steps=3, lr=0.2, seed=a,
                )
            )
        return apps

    def tt(history, app_id):
        for r in history:
            if r["app_id"] == app_id and r["loss"] <= target:
                return r["t_ms"]
        return float("inf")

    def run(compression):
        sys_, nodes, rng = build_system(n_nodes=n_nodes, zones=4, seed=seed)
        apps = make_apps(sys_, nodes, rng)
        res = async_engine.run_async(
            sys_, apps, applies=applies, buffer_k=4, staleness_alpha=0.5,
            model_bytes=model_bytes, compute_ms=5.0, fair=True,
            compression=compression, max_events=8_000_000,
        )
        final = {}
        for r in res["history"]:  # last apply per app wins
            final[r["app_id"]] = r["loss"]
        ids = [a.handle.app_id for a in apps]
        up = res["scheduler"].transport_stats()["uplink_bytes"]
        return [tt(res["history"], i) for i in ids], [final[i] for i in ids], up

    tt_none, loss_none, up_none = run(None)
    tt_qsgd, loss_qsgd, up_qsgd = run("qsgd-int8")
    ratio = [q / max(n, 1e-9) for q, n in zip(tt_qsgd, tt_none)]
    return {
        "m": m,
        "target_loss": target,
        "model_bytes": model_bytes,
        "tt_none_ms": tt_none,
        "tt_qsgd_ms": tt_qsgd,
        "tt_ratio": ratio,
        "mean_tt_ratio": float(np.mean(ratio)),
        "max_tt_ratio": max(ratio),
        "loss_none": loss_none,
        "loss_qsgd": loss_qsgd,
        "loss_gap": abs(float(np.mean(loss_qsgd)) - float(np.mean(loss_none))),
        "bytes_ratio": float(sum(up_qsgd) / max(sum(up_none), 1e-9)),
        "all_finite": bool(all(np.isfinite(t) for t in tt_none + tt_qsgd)),
    }


def downlink_compare(
    m: int = 16, *, seed: int = 0, target: float = 0.35
) -> dict:
    """The tight-downlink axis (docs/performance.md "compressed
    downlink"): the same commit-bound fixture as ``compression_compare``
    — near-zero compute, 2 MB model, K = W so workers re-download every
    version — where the full-f32 broadcast leg is ~80% of the wire.
    Three runs on the identical topology/schedule: uncompressed,
    uplink-only qsgd-int8 (the PR-8 baseline), and uplink qsgd +
    delta-qsgd downlink (3-bit packed version deltas, ``chain_cap=3``).
    Gated (``gate_downlink``) against the uplink-only baseline: total
    wire bytes (up + down) < 0.35x, mean time-to-target <= 0.90x, Jain
    over per-app progress no worse, and a 25% per-app starvation
    guard."""
    from repro import data as data_mod
    from repro.fl import async_engine, rounds
    from repro.fl.compression import CompressionPolicy
    from repro.kernels.ops import jain_fairness

    workers, applies, model_bytes = 4, 12, 2e6
    n_nodes = max(80, 5 * m)

    def make_apps(sys_, nodes, rng):
        apps = []
        for a in range(m):
            x, y = data_mod.synthetic_classification(workers * 24, 16, 4, seed=100 + a)
            parts = data_mod.dirichlet_partition(y, workers, alpha=1.0, seed=200 + a)
            ws = [int(n) for n in rng.choice(nodes, size=workers, replace=False)]
            apps.append(
                rounds.make_app(
                    sys_, f"down-{m}-{a}", workers=ws,
                    data_by_worker={n: (x[parts[i]], y[parts[i]]) for i, n in enumerate(ws)},
                    dim=16, num_classes=4, local_steps=3, lr=0.2, seed=a,
                )
            )
        return apps

    def tt(history, app_id):
        for r in history:
            if r["app_id"] == app_id and r["loss"] <= target:
                return r["t_ms"]
        return float("inf")

    def run(compression):
        sys_, nodes, rng = build_system(n_nodes=n_nodes, zones=4, seed=seed)
        apps = make_apps(sys_, nodes, rng)
        res = async_engine.run_async(
            sys_, apps, applies=applies, buffer_k=4, staleness_alpha=0.5,
            model_bytes=model_bytes, compute_ms=5.0, fair=True,
            compression=compression, max_events=8_000_000,
        )
        ids = [a.handle.app_id for a in apps]
        st = res["scheduler"].transport_stats()
        return {
            "tt": [tt(res["history"], i) for i in ids],
            "up": sum(st["uplink_bytes"]),
            "down": sum(st["downlink_bytes"]),
        }

    up_only = run(CompressionPolicy(kind="qsgd-int8"))
    up_down = run(CompressionPolicy(
        kind="qsgd-int8", downlink="delta-qsgd", downlink_levels=3, chain_cap=3
    ))
    none = run(None)

    def jain_progress(r):
        return jain_fairness([1.0 / max(t, 1e-9) for t in r["tt"]])

    ratio = [d / max(u, 1e-9) for d, u in zip(up_down["tt"], up_only["tt"])]
    total_up_only = up_only["up"] + up_only["down"]
    total_up_down = up_down["up"] + up_down["down"]
    return {
        "m": m,
        "target_loss": target,
        "model_bytes": model_bytes,
        "tt_none_ms": none["tt"],
        "tt_up_only_ms": up_only["tt"],
        "tt_up_down_ms": up_down["tt"],
        "tt_ratio": ratio,
        "mean_tt_ratio": float(np.mean(ratio)),
        "max_tt_ratio": max(ratio),
        "bytes_up_only": total_up_only,
        "bytes_up_down": total_up_down,
        "bytes_none": none["up"] + none["down"],
        "bytes_total_ratio": float(total_up_down / max(total_up_only, 1e-9)),
        "downlink_bytes_ratio": float(up_down["down"] / max(up_only["down"], 1e-9)),
        "jain_up_only": jain_progress(up_only),
        "jain_up_down": jain_progress(up_down),
        "all_finite": bool(
            all(np.isfinite(t) for t in up_only["tt"] + up_down["tt"])
        ),
    }


def gate_downlink(rows: list[dict]) -> list[str]:
    """Compressed-downlink acceptance gates; human-readable failures."""
    fails = []
    for r in rows:
        if not r["all_finite"]:
            fails.append(f"downlink M={r['m']}: an app never hit the target loss")
        if r["bytes_total_ratio"] >= 0.35:
            fails.append(
                f"downlink M={r['m']}: total wire bytes "
                f"{r['bytes_total_ratio']:.3f}x >= 0.35x uplink-only baseline"
            )
        if r["mean_tt_ratio"] > 0.90:
            fails.append(
                f"downlink M={r['m']}: mean time-to-target "
                f"{r['mean_tt_ratio']:.2f} > 0.90x (compressed broadcasts "
                f"must buy wall-clock)"
            )
        # starvation guard (same rationale as gate_compression: the apply
        # quantization of time-to-target tolerates one-apply shifts)
        if r["max_tt_ratio"] > 1.25:
            fails.append(
                f"downlink M={r['m']}: an app regressed "
                f"{(r['max_tt_ratio'] - 1) * 100:.1f}% (> 25%)"
            )
        # fp slack only — the downlink must not redistribute progress
        if r["jain_up_down"] < r["jain_up_only"] - 0.02:
            fails.append(
                f"downlink M={r['m']}: jain worsened "
                f"({r['jain_up_only']:.3f} -> {r['jain_up_down']:.3f})"
            )
    return fails


def gate_compression(rows: list[dict]) -> list[str]:
    """Compressed-transport acceptance gates; human-readable failures."""
    fails = []
    for r in rows:
        if not r["all_finite"]:
            fails.append(f"compression M={r['m']}: an app never hit the target loss")
        if r["mean_tt_ratio"] >= 0.95:
            fails.append(
                f"compression M={r['m']}: mean time-to-target did not clearly "
                f"improve (qsgd/none {r['mean_tt_ratio']:.2f} >= 0.95)"
            )
        # starvation guard, not a per-app improvement gate: time-to-target
        # is quantized by apply events, so a rescheduled app can cross one
        # apply later (~10% here) without anything being wrong
        if r["max_tt_ratio"] > 1.25:
            fails.append(
                f"compression M={r['m']}: an app regressed "
                f"{(r['max_tt_ratio'] - 1) * 100:.1f}% (> 25%) under compression"
            )
        if r["loss_gap"] > 1e-2:
            fails.append(
                f"compression M={r['m']}: loss gap {r['loss_gap']:.4f} > 1e-2"
            )
        if r["bytes_ratio"] > 0.3:
            fails.append(
                f"compression M={r['m']}: uplink bytes ratio "
                f"{r['bytes_ratio']:.3f} > 0.3 (int8+scales should be ~0.26x)"
            )
    return fails


def gate(results: list[dict], guard: dict | None) -> list[str]:
    """The fairness acceptance gates; returns human-readable failures."""
    fails = []
    for r in results:
        if r["jain_fair"] < 0.8:
            fails.append(f"M={r['m']}: jain_fair {r['jain_fair']:.3f} < 0.8")
        if r["jain_fair"] < r["jain_legacy"]:
            fails.append(
                f"M={r['m']}: jain did not improve "
                f"({r['jain_legacy']:.3f} -> {r['jain_fair']:.3f})"
            )
    if guard is not None:
        if not guard["all_finite"]:
            fails.append("time-to-loss guard: some app never reached the target")
        if guard["max_regression"] > 1.05:
            fails.append(
                f"time-to-loss guard: worst app regressed "
                f"{(guard['max_regression'] - 1) * 100:.1f}% (> 5%)"
            )
        if guard["spread_fair"] > guard["spread_legacy"] * 1.02:
            fails.append(
                f"time-to-loss guard: spread widened "
                f"({guard['spread_legacy']:.2f} -> {guard['spread_fair']:.2f})"
            )
    return fails


def run() -> list[str]:
    out = []
    for m in sorted(CONFIGS):
        r = fairness_compare(m)
        out.append(
            row(
                f"fairness_m{m}",
                0.0,
                f"jain_legacy={r['jain_legacy']:.3f};jain_fair={r['jain_fair']:.3f};"
                f"hot_ratio={r['hot_ratio_legacy']:.2f}->{r['hot_ratio_fair']:.2f};"
                f"deferred={r['deferred_commits']}",
            )
        )
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--smoke", action="store_true",
                    help="M in {4,16} + trained guard; write BENCH_fairness.json")
    ap.add_argument("--out", default="BENCH_fairness.json")
    args = ap.parse_args(argv)

    ms = (4, 16) if args.smoke else tuple(sorted(CONFIGS))
    results = [fairness_compare(m) for m in ms]
    for r in results:
        print(
            f"M={r['m']}: jain legacy={r['jain_legacy']:.3f} -> fair={r['jain_fair']:.3f}  "
            f"hot app ratio {r['hot_ratio_legacy']:.2f} -> {r['hot_ratio_fair']:.2f}  "
            f"min ratio {r['min_ratio_legacy']:.2f} -> {r['min_ratio_fair']:.2f}  "
            f"deferred={r['deferred_commits']}"
        )
    guard = time_to_loss_guard()
    print(
        f"time-to-loss (M={guard['m']}, target {guard['target_loss']}): "
        f"mean fair/legacy {guard['mean_ratio']:.2f}x, worst {guard['max_regression']:.2f}x, "
        f"spread {guard['spread_legacy']:.2f} -> {guard['spread_fair']:.2f}"
    )

    from benchmarks.bench_async import _json_safe

    payload = _json_safe({
        "bench": "multi_app_uplink_fairness",
        "smoke": bool(args.smoke),
        "results": results,
        "time_to_loss_guard": guard,
    })
    out_path = os.path.abspath(args.out)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, allow_nan=False)
    print(f"wrote {out_path}")

    fails = gate(results, guard)
    for msg in fails:
        print(f"GATE FAIL: {msg}")
    if fails:
        raise SystemExit(1)
    print("fairness gates passed: jain >= 0.8, improves on legacy, "
          "no app's time-to-loss regressed > 5%")


if __name__ == "__main__":
    main()
