"""Table III / Figs 8-9: multi-application time-to-accuracy — Totoro+
parallel trees vs the centralized single-coordinator baseline.

Real local training (MLP on synthetic non-IID classification) drives the
per-round compute cost; wall time composes measured compute with each
architecture's communication model: Totoro+ trees run concurrently
(dedicated masters), the baseline's M apps serialize through one
coordinator queue (paper §VII-D).
"""
from __future__ import annotations

import numpy as np

from .common import build_system, row, timeit


def run() -> list[str]:
    import jax

    from repro import data as data_mod
    from repro.core import sim as sim_mod
    from repro.fl import rounds, small_models as sm

    out = []
    sys_, nodes, rng = build_system(n_nodes=800, zones=4, seed=4)
    dim, classes, clients = 32, 8, 24
    xall, yall = data_mod.synthetic_classification(7000, dim, classes, seed=0)
    x, y, xt, yt = xall[:6000], yall[:6000], xall[6000:], yall[6000:]
    parts = data_mod.dirichlet_partition(y, clients, alpha=0.5, seed=1)
    # equal shard sizes -> one jit trace for local_train across workers
    m = min(len(p) for p in parts)
    m = max(m, 32)
    parts = [np.resize(p, m) for p in parts]

    for n_apps in (1, 5, 20):
        apps = []
        for a in range(n_apps):
            workers = [int(w) for w in rng.choice(nodes, size=clients, replace=False)]
            dbw = {
                w: (x[parts[i]], y[parts[i]])
                for i, w in enumerate(workers)
            }
            apps.append(
                rounds.make_app(
                    sys_, f"tta-{n_apps}-{a}", workers=workers, data_by_worker=dbw,
                    dim=dim, num_classes=classes, local_steps=4, lr=0.2, seed=a,
                )
            )
        target = 0.75
        base = rounds.CentralizedBaseline()
        model_bytes = sum(np.asarray(l).nbytes for l in jax.tree.leaves(apps[0].params))
        reached = 0.0
        compute_samples, n_rounds = [], 0
        for rnd in range(12):
            import time as _t

            t0 = _t.perf_counter()
            for app in apps:
                rounds.run_round(sys_, app)  # vectorized engine path
            compute_samples.append((_t.perf_counter() - t0) * 1e3 / n_apps)
            n_rounds += 1
            reached = rounds.evaluate(apps[0], xt, yt)
            if reached >= target:
                break
        compute_ms = float(np.mean(compute_samples))
        # Totoro+: the event-driven simulator interleaves the M apps'
        # rounds with shared-link contention where their trees overlap
        sim = sim_mod.MultiAppSimulator(
            sys_, [a.handle for a in apps], model_bytes=model_bytes, compute_ms=compute_ms
        )
        totoro_time = max(ev.end_ms for ev in sim.run(rounds=n_rounds))
        # baseline: all M apps serialize through the coordinator queue
        base_time = n_rounds * base.round_time_ms(apps, compute_ms, model_bytes)[-1]
        speedup = base_time / max(totoro_time, 1e-9)
        out.append(
            row(
                f"tab3_tta_apps{n_apps}",
                0.0,
                f"acc={reached:.3f};totoro_s={totoro_time/1e3:.2f};central_s={base_time/1e3:.2f};speedup={speedup:.1f}x",
            )
        )
        for app in apps:
            sys_.apps.pop(app.handle.app_id, None)
    return out
