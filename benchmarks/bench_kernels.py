"""Kernel microbenches (interpret mode on CPU; TPU is the target) +
roofline terms per kernel from analytic bytes/flops."""
from __future__ import annotations

import numpy as np

from .common import row, timeit


def run() -> list[str]:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    out = []
    key = jax.random.key(0)

    C, L = 16, 1 << 17
    g = jax.random.normal(key, (C, L), jnp.bfloat16)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (C,))
    t, _ = timeit(lambda: jax.block_until_ready(ops.tree_aggregate(g, w)))
    bytes_moved = C * L * 2 + L * 4
    out.append(
        row(
            "kernel_tree_aggregate",
            t * 1e6,
            f"C={C};L={L};GBps={bytes_moved/t/1e9:.2f}(interpret)",
        )
    )

    R = 4096
    x = jax.random.normal(jax.random.fold_in(key, 2), (R, 256))
    rnd = jax.random.uniform(jax.random.fold_in(key, 3), (R, 256))
    t, _ = timeit(lambda: jax.block_until_ready(ops.qsgd_quantize(x, rnd)))
    out.append(row("kernel_qsgd_quantize", t * 1e6, f"R={R};ratio=3.94x"))

    N, K, tau = 4096, 16, 8
    pi = jax.random.dirichlet(jax.random.fold_in(key, 4), jnp.ones(K), (N,)).astype(jnp.float32)
    rsum = jax.random.uniform(jax.random.fold_in(key, 5), (N, K))
    from repro.core.pathplan import candidate_policy_set

    cand = candidate_policy_set(K)
    t, _ = timeit(
        lambda: jax.block_until_ready(
            ops.policy_update(pi, jnp.ones((N, K), bool), cand, rsum, tau=tau, alpha=0.9, beta=0.5)
        )
    )
    out.append(row("kernel_policy_update", t * 1e6, f"N={N};K={K}"))

    L2 = 1 << 17
    wv = jax.random.normal(jax.random.fold_in(key, 6), (L2,), jnp.bfloat16)
    gv = jax.random.normal(jax.random.fold_in(key, 7), (L2,), jnp.bfloat16)
    t, _ = timeit(lambda: jax.block_until_ready(ops.fused_update(wv, gv, wv, lr=0.1, mu=0.01, wd=0.0)))
    out.append(row("kernel_fused_update", t * 1e6, f"L={L2}"))
    return out
