"""Figs 11-14: adaptivity — cumulative packet latency, Nash regret,
selection frequencies for Totoro+ vs Totoro(bandit) vs OPT on a
constrained-bandwidth (20-100 Mbps) hop set.

Gates (``gate_adaptivity``):

- the game-theoretic planner beats the bandit baseline on cumulative
  latency and on final Nash regret (the paper's Fig 11/13 ordering);
- it stays within 1.3x of the clairvoyant OPT planner's latency;
- its selection-frequency spread (Fig 14) is no wider than the
  bandit's — ε-Nash play spreads load instead of herding.

``python -m benchmarks.bench_adaptivity --smoke`` writes
``BENCH_adaptivity.json`` (a CI artifact).  Seeded and deterministic.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import row, timeit


def _env():
    from repro.core.congestion import make_env

    env = make_env(8, seed=7, bw_range=(20.0, 100.0))
    return env.__class__(capacity=env.capacity, theta=env.theta, packet_mbit=2.0)


def adaptivity_compare(episodes: int = 40, n: int = 128) -> dict:
    """Run the three planners on one seeded env; return gate inputs."""
    from repro.core.pathplan import (
        BanditPlanner, GameTheoreticPlanner, OptPlanner, run_planner,
    )

    env = _env()
    out = {}
    for name, planner in (
        ("totoro_plus", GameTheoreticPlanner(n, 8, tau=16, alpha=0.98, beta=0.5, seed=0)),
        ("totoro_bandit", BanditPlanner(n, 8, tau=16)),
        ("opt", OptPlanner(env, n, tau=16)),
    ):
        t, series = timeit(lambda p=planner: run_planner(p, env, episodes), repeat=1)
        f = np.asarray(series["selection_freq"])
        out[name] = {
            "us_per_episode": t / episodes * 1e6,
            "cum_latency_ms": float(series["cum_latency_ms"][-1]),
            "final_nash_regret": float(np.mean(series["nash_regret"][-8:])),
            "mean_reward": float(np.mean(series["mean_reward"][-8:])),
            "selection_spread": float(f.max() - f.min()),
        }
    return out


def alpha_sweep(episodes: int = 25, n: int = 128) -> dict:
    from repro.core.pathplan import GameTheoreticPlanner, run_planner

    env = _env()
    out = {}
    for alpha in (0.6, 0.8, 0.95):
        p = GameTheoreticPlanner(n, 8, tau=16, alpha=alpha, beta=0.5, seed=2)
        s = run_planner(p, env, episodes)
        out[f"alpha{alpha}"] = float(s["cum_latency_ms"][-1])
    return out


def gate_adaptivity(results: dict) -> list[str]:
    fails = []
    tp, tb, opt = results["totoro_plus"], results["totoro_bandit"], results["opt"]
    if tp["cum_latency_ms"] > tb["cum_latency_ms"]:
        fails.append(
            f"totoro_plus cum latency {tp['cum_latency_ms']:.0f} > "
            f"bandit {tb['cum_latency_ms']:.0f}"
        )
    if tp["final_nash_regret"] > tb["final_nash_regret"]:
        fails.append(
            f"totoro_plus final regret {tp['final_nash_regret']:.4f} > "
            f"bandit {tb['final_nash_regret']:.4f}"
        )
    if tp["cum_latency_ms"] > 1.3 * opt["cum_latency_ms"]:
        fails.append(
            f"totoro_plus cum latency {tp['cum_latency_ms']:.0f} > "
            f"1.3x OPT {opt['cum_latency_ms']:.0f}"
        )
    if tp["selection_spread"] > tb["selection_spread"]:
        fails.append(
            f"totoro_plus selection spread {tp['selection_spread']:.3f} > "
            f"bandit {tb['selection_spread']:.3f}"
        )
    return fails


def run() -> list[str]:
    results = adaptivity_compare()
    out = []
    for name, r in results.items():
        out.append(
            row(
                f"fig11_13_{name}",
                r["us_per_episode"],
                f"cum_latency_ms={r['cum_latency_ms']:.0f};"
                f"final_nash_regret={r['final_nash_regret']:.4f};"
                f"mean_reward={r['mean_reward']:.3f}",
            )
        )
    # Fig 14: selection-frequency spread (max - min across hops)
    for name, r in results.items():
        out.append(
            row(f"fig14_selection_{name}", 0.0, f"spread={r['selection_spread']:.3f}")
        )
    # Fig 12-like: alpha sweep (CDF quality proxy: final latency)
    for key, cum in alpha_sweep().items():
        out.append(row(f"fig12_{key}", 0.0, f"cum_latency_ms={cum:.0f}"))
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--smoke", action="store_true",
                    help="planner compare only (skip the alpha sweep)")
    ap.add_argument("--out", default="BENCH_adaptivity.json")
    args = ap.parse_args(argv)

    results = adaptivity_compare()
    for name, r in results.items():
        print(
            f"{name}: cum_latency={r['cum_latency_ms']:.0f}ms "
            f"regret={r['final_nash_regret']:.4f} "
            f"spread={r['selection_spread']:.3f}"
        )
    payload = {"bench": "adaptivity", "smoke": bool(args.smoke), "results": results}
    if not args.smoke:
        payload["alpha_sweep"] = alpha_sweep()
    out_path = os.path.abspath(args.out)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, allow_nan=False)
    print(f"wrote {out_path}")

    fails = gate_adaptivity(results)
    for msg in fails:
        print(f"GATE FAIL: {msg}")
    if fails:
        raise SystemExit(1)
    print("adaptivity gates passed: game-theoretic planner beats bandit on "
          "latency+regret, within 1.3x OPT, tighter selection spread")


if __name__ == "__main__":
    main()
