"""Figs 11-14: adaptivity — cumulative packet latency, Nash regret,
selection frequencies for Totoro+ vs Totoro(bandit) vs OPT on a
constrained-bandwidth (20-100 Mbps) hop set."""
from __future__ import annotations

import numpy as np

from .common import row, timeit


def run() -> list[str]:
    from repro.core.congestion import make_env
    from repro.core.pathplan import (
        BanditPlanner, GameTheoreticPlanner, OptPlanner, run_planner,
    )

    env = make_env(8, seed=7, bw_range=(20.0, 100.0))
    env = env.__class__(capacity=env.capacity, theta=env.theta, packet_mbit=2.0)
    N, episodes = 128, 40
    out = []

    results = {}
    for name, planner in (
        ("totoro_plus", GameTheoreticPlanner(N, 8, tau=16, alpha=0.98, beta=0.5, seed=0)),
        ("totoro_bandit", BanditPlanner(N, 8, tau=16)),
        ("opt", OptPlanner(env, N, tau=16)),
    ):
        t, series = timeit(lambda p=planner: run_planner(p, env, episodes), repeat=1)
        results[name] = series
        out.append(
            row(
                f"fig11_13_{name}",
                t / episodes * 1e6,
                f"cum_latency_ms={series['cum_latency_ms'][-1]:.0f};"
                f"final_nash_regret={np.mean(series['nash_regret'][-8:]):.4f};"
                f"mean_reward={np.mean(series['mean_reward'][-8:]):.3f}",
            )
        )

    # Fig 14: selection-frequency spread (min/max across hops)
    for name, series in results.items():
        f = np.asarray(series["selection_freq"])
        out.append(
            row(f"fig14_selection_{name}", 0.0, f"min={f.min():.3f};max={f.max():.3f}")
        )

    # Fig 12-like: alpha sweep (CDF quality proxy: final latency)
    for alpha in (0.6, 0.8, 0.95):
        p = GameTheoreticPlanner(N, 8, tau=16, alpha=alpha, beta=0.5, seed=2)
        s = run_planner(p, env, 25)
        out.append(
            row(
                f"fig12_alpha{alpha}",
                0.0,
                f"cum_latency_ms={s['cum_latency_ms'][-1]:.0f}",
            )
        )
    return out
