"""Fig 7: per-node traffic cost vs number of dataflow trees (expect
sublinear growth: ~1.2-1.3x traffic for 10x trees)."""
from __future__ import annotations

import numpy as np

from .common import build_system, row


def run() -> list[str]:
    out = []
    sys_, nodes, rng = build_system(n_nodes=1500, zones=4, seed=3)
    payload = np.ones(1024, np.float32)  # fixed control-plane payload
    prev = None
    for n_trees in (5, 50):
        # overlay maintenance traffic: keep-alives ~ O(N); per-tree JOINs
        join_edges = 0
        for i in range(n_trees):
            h = sys_.CreateTree(f"t{n_trees}-{i}")
            subs = rng.choice(nodes, size=100, replace=False)
            for w in subs:
                sys_.Subscribe(h.app_id, int(w))
            join_edges += len(h.tree.parent)
            sys_.Broadcast(h.app_id, payload)
        total_traffic = sum(h.traffic_bytes for h in sys_.apps.values())
        per_node = total_traffic / len(nodes)
        out.append(
            row(
                f"fig7_traffic_trees{n_trees}",
                0.0,
                f"per_node_bytes={per_node:.0f};join_edges={join_edges}",
            )
        )
        if prev is not None:
            out.append(
                row(
                    "fig7_traffic_ratio_10x_trees",
                    0.0,
                    f"ratio={per_node/prev:.2f}x_for_10x_trees",
                )
            )
        prev = per_node
        for h in list(sys_.apps.values()):
            h.traffic_bytes = 0.0

    # aggregation traffic now follows the tree level-by-level: per-level
    # bytes/latency come from the hierarchical kernel schedule
    h = sys_.apps[sys_.forest.app_names["t50-0"]]
    members = sorted(h.tree.members)[:20]
    stats = sys_.Aggregate(h.app_id, {w: payload for w in members})
    out.append(
        row(
            "fig7_agg_per_level",
            0.0,
            f"levels={len(stats['levels'])};agg_bytes={stats['bytes']:.0f};"
            f"agg_ms={stats['time_ms']:.1f}",
        )
    )
    return out
