"""Vectorized round engine + event-driven multi-app simulator benchmarks.

(a) Engine: one app's E local steps for all W workers as a single jitted
vmap (``fl/engine.py``) vs the seed's per-worker dispatch loop — the
vectorized path must be >=5x faster at W >= 64 (the win is amortized
dispatch: one XLA program instead of W).

(b) Table III: per-app round completion time for M in {1, 4, 16}
concurrent apps on one overlay, priced by the discrete-event simulator
(``core/sim.py``, shared-link contention where trees overlap) vs the
centralized single-coordinator queue (``fl/rounds.CentralizedBaseline``).
"""
from __future__ import annotations

import types

import numpy as np

from .common import build_system, row, timeit


def run() -> list[str]:
    from repro import data as data_mod
    from repro.core.sim import MultiAppSimulator, per_app_round_ms
    from repro.fl import engine, rounds

    out = []
    sys_, nodes, rng = build_system(n_nodes=1200, zones=4, seed=11)
    dim, classes, shard = 32, 8, 16

    # (a) vectorized engine vs per-worker reference loop
    for W in (64, 128, 256):
        x, y = data_mod.synthetic_classification(W * shard, dim, classes, seed=W)
        workers = [int(w) for w in rng.choice(nodes, size=W, replace=False)]
        app = rounds.make_app(
            sys_, f"eng-{W}", workers=workers,
            data_by_worker={
                w: (x[i * shard : (i + 1) * shard], y[i * shard : (i + 1) * shard])
                for i, w in enumerate(workers)
            },
            dim=dim, hidden=32, num_classes=classes, local_steps=2, lr=0.1,
        )
        ws = [w for w in sorted(app.handle.tree.members) if w in app.data]
        tv, _ = timeit(lambda: engine.local_training(app, ws, vectorized=True))
        tr, _ = timeit(lambda: engine.local_training(app, ws, vectorized=False))
        out.append(
            row(
                f"engine_local_train_w{W}",
                tv * 1e6,
                f"loop_ms={tr*1e3:.1f};vec_ms={tv*1e3:.1f};speedup={tr/tv:.1f}x",
            )
        )
        sys_.apps.pop(app.handle.app_id, None)

    # (b) Table-III curve: M concurrent apps, shared links vs central queue
    model_bytes = 4.0 * (dim * 32 + 32 + 32 * 32 + 32 + 32 * classes + classes)
    compute_ms = 40.0
    base = rounds.CentralizedBaseline()
    for M in (1, 4, 16):
        handles = []
        for a in range(M):
            h = sys_.CreateTree(f"tab3-{M}-{a}")
            for w in rng.choice(nodes, size=32, replace=False):
                sys_.Subscribe(h.app_id, int(w))
            handles.append(h)
        sim = MultiAppSimulator(sys_, handles, model_bytes=model_bytes, compute_ms=compute_ms)
        hist = sim.run(rounds=3)
        per_app = per_app_round_ms(hist)
        totoro_ms = float(np.mean([np.mean(v) for v in per_app.values()]))
        shims = [
            types.SimpleNamespace(data={w: None for w in h.tree.members})
            for h in handles
        ]
        central = base.round_time_ms(shims, compute_ms, model_bytes)
        central_ms = float(np.mean(central))  # mean per-app completion in the queue
        out.append(
            row(
                f"tab3_sim_m{M}",
                0.0,
                f"totoro_round_ms={totoro_ms:.1f};central_round_ms={central_ms:.1f};"
                f"speedup={central_ms/max(totoro_ms,1e-9):.1f}x",
            )
        )
        for h in handles:
            sys_.apps.pop(h.app_id, None)
    return out
