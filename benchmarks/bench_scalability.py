"""Fig 5: master distribution + scaling masters with workload.

(a/b) masters per node under 125..2000 concurrent apps on EUA-like
topology; (c) masters scale with per-zone workload; (d) tree-branch
balance across zones.
"""
from __future__ import annotations

import numpy as np

from .common import build_system, eua_like_coords, row, timeit


def run() -> list[str]:
    import math

    from repro.core.nodeid import IdSpace
    from repro.core.overlay import build_overlay_from_coords
    from repro.core.forest import Forest

    coords = eua_like_coords(4000)
    space = IdSpace(zone_bits=4, suffix_bits=24)
    overlay, ids = build_overlay_from_coords(coords, space, base_bits=3)
    forest = Forest(overlay)

    out = []
    for n_apps in (125, 500, 2000):
        t, _ = timeit(
            lambda: [forest.create_tree(f"app-{n_apps}-{i}", salt=str(i)) for i in range(50)],
            repeat=1,
        )
        for i in range(50, n_apps):
            forest.create_tree(f"app-{n_apps}-{i}", salt=str(i))
        per_node = forest.masters_per_node()
        counts = np.zeros(overlay.num_nodes)
        counts[: len(per_node)] = sorted(per_node.values(), reverse=True)
        frac_le3 = float(np.mean(counts <= 3))
        out.append(
            row(
                f"fig5b_masters_dist_apps{n_apps}",
                t / 50 * 1e6,
                f"max={int(counts.max())};frac_le3={frac_le3:.4f}",
            )
        )
        forest.trees.clear()

    # (c) masters scale with workload: heavy zones get more masters
    rng = np.random.default_rng(0)
    forest2 = Forest(overlay)
    zones = overlay.zones()
    weights = np.array([len(overlay.zone_members[z]) for z in zones], float)
    weights /= weights.sum()
    for i in range(400):
        z = int(rng.choice(zones, p=weights))
        forest2.create_tree(f"zonal-{i}", salt=str(i), restrict_zone=z)
    per_zone = {}
    for t_ in forest2.trees.values():
        z = overlay.space.zone_of(t_.root)
        per_zone[z] = per_zone.get(z, 0) + 1
    corr = np.corrcoef(
        [per_zone.get(z, 0) for z in zones],
        [len(overlay.zone_members[z]) for z in zones],
    )[0, 1]
    out.append(row("fig5c_masters_scale_workload", 0.0, f"zone_corr={corr:.3f}"))

    # (e) aggregation-schedule depth: the engine executes one batched
    # kernel call per level, so O(log N) levels = O(log N) sequential
    # dissemination/aggregation steps regardless of subscriber count
    forest3 = Forest(overlay)
    rng2 = np.random.default_rng(1)
    all_nodes = overlay.nodes()
    for n_sub in (100, 400, 1600):
        t_ = forest3.create_tree(f"sched-{n_sub}")
        forest3.subscribe_many(
            t_.app_id, rng2.choice(all_nodes, size=n_sub, replace=False)
        )
        sched = t_.aggregation_schedule()
        groups = sum(len(l) for l in sched)
        out.append(
            row(
                f"fig5e_agg_schedule_n{n_sub}",
                0.0,
                f"levels={len(sched)};groups={groups};log2n={math.log2(n_sub):.1f}",
            )
        )
    return out
