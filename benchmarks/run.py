# One function per paper table/figure. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    from benchmarks import (
        bench_adaptivity,
        bench_async,
        bench_engine,
        bench_hops,
        bench_kernels,
        bench_overhead,
        bench_recovery,
        bench_scalability,
        bench_time_to_accuracy,
        bench_traffic,
        bench_runtime,
    )

    modules = [
        ("engine+sim(TabIII)", bench_engine),
        ("async_vs_sync(FedBuff)", bench_async),
        ("scalability(Fig5)", bench_scalability),
        ("hops(Fig6)", bench_hops),
        ("traffic(Fig7)", bench_traffic),
        ("time_to_accuracy(TabIII/Fig8-9)", bench_time_to_accuracy),
        ("adaptivity(Fig11-14)", bench_adaptivity),
        ("runtime(Fig15-16)", bench_runtime),
        ("recovery(Fig17-18)", bench_recovery),
        ("overhead(Fig19)", bench_overhead),
        ("kernels", bench_kernels),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for label, mod in modules:
        try:
            for line in mod.run():
                print(line, flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{label},NaN,FAILED", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
