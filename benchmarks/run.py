"""Benchmark driver: one module per paper table/figure.

``python -m benchmarks.run`` runs every registered bench and prints
``name,us_per_call,derived`` CSV rows.  ``--help`` lists the registry
with a one-line description per bench; ``--only NAME`` (repeatable)
restricts the run to named entries.
"""
from __future__ import annotations

import argparse
import importlib
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# (name, module, description) — every bench registers a real one-line
# description here, surfaced by --help without importing the module (a
# broken bench must not take down the driver's help or other benches).
REGISTRY: list[tuple[str, str, str]] = [
    ("engine+sim(TabIII)", "benchmarks.bench_engine",
     "vectorized round engine vs per-worker loop; M-app event simulator vs centralized baseline"),
    ("async_vs_sync(FedBuff)", "benchmarks.bench_async",
     "sync vs fixed-K vs adaptive-K vs adaptive-K+utility time-to-target-loss under churn"),
    ("fairness(TabIII)", "benchmarks.bench_fairness",
     "multi-app uplink fairness: weighted-fair re-pricing vs legacy start-time pricing, Jain's index at M in {4,16,64}"),
    ("compression", "benchmarks.bench_compression",
     "compressed wire, both directions: qsgd-int8 commits (time-to-target + <=1e-2 loss-gap gates on a tight uplink) and delta-qsgd downlink broadcasts (total bytes < 0.35x, time-to-target <= 0.90x vs uplink-only)"),
    ("hotpath(perf)", "benchmarks.bench_hotpath",
     "simulator hot paths: megabatched dispatch + compiled kernel fallback + incremental repricing vs the pre-optimization engine (>=3x gate, byte-identical traces)"),
    ("scale(perf)", "benchmarks.bench_scale",
     "million-node scale layer: route_many hops vs N log-fit (R^2 gate), cohort-batched events/s + peak RSS vs M, M=16 trace-identity anchor"),
    ("scalability(Fig5)", "benchmarks.bench_scalability",
     "overlay join/route cost vs network size"),
    ("hops(Fig6)", "benchmarks.bench_hops",
     "dataflow-tree path lengths vs DHT routing bounds"),
    ("traffic(Fig7)", "benchmarks.bench_traffic",
     "per-round bytes on the tree vs flat aggregation"),
    ("time_to_accuracy(TabIII/Fig8-9)", "benchmarks.bench_time_to_accuracy",
     "FedAvg/FedProx rounds to target accuracy on non-IID shards"),
    ("adaptivity(Fig11-14)", "benchmarks.bench_adaptivity",
     "game-theoretic vs bandit vs OPT planner: cumulative latency, Nash regret, selection spread (gated ordering)"),
    ("placement(live)", "benchmarks.bench_placement",
     "live placement loop vs static trees: time-to-target-loss <= 0.95x and Jain no worse under >=10% churn, placement=None trace identity"),
    ("runtime(Fig15-16)", "benchmarks.bench_runtime",
     "end-to-end simulated round time across model sizes"),
    ("recovery(Fig17-18)", "benchmarks.bench_recovery",
     "master/worker failure repair latency and state-restore hit rate"),
    ("overhead(Fig19)", "benchmarks.bench_overhead",
     "control-plane overhead of the Table-II verbs"),
    ("kernels", "benchmarks.bench_kernels",
     "Pallas tree_aggregate / tree_aggregate_groups vs XLA reference"),
]


def _registry_help() -> str:
    width = max(len(n) for n, _, _ in REGISTRY)
    lines = ["registered benches:"]
    for name, _, desc in REGISTRY:
        lines.append(f"  {name:<{width}}  {desc}")
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.run",
        description=__doc__,
        epilog=_registry_help(),
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument(
        "--only", action="append", default=None, metavar="NAME",
        help="run only the named bench (repeatable; names as listed below)",
    )
    args = ap.parse_args(argv)
    selected = REGISTRY
    if args.only:
        known = {n for n, _, _ in REGISTRY}
        unknown = [n for n in args.only if n not in known]
        if unknown:
            ap.error(f"unknown bench name(s): {unknown}; known: {sorted(known)}")
        selected = [r for r in REGISTRY if r[0] in args.only]

    print("name,us_per_call,derived")
    failures = 0
    for label, mod_name, _ in selected:
        try:
            mod = importlib.import_module(mod_name)
            for line in mod.run():
                print(line, flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{label},NaN,FAILED", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
