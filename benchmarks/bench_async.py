"""Sync vs async buffered execution: time-to-target-loss under churn.

The async claim (ROADMAP / paper §VII): a barrier round is priced by its
slowest worker, so under a heterogeneous edge compute distribution the
synchronous engine crawls at straggler speed, while FedBuff-style
buffered aggregation applies after the K fastest arrivals and keeps the
pipeline full — even with ≥10% of workers failing and rejoining
mid-round (churn on the event clock, repaired by ``core/recovery``).

For M in {1, 4, 16} concurrent apps on one overlay this measures, per
app, the simulated time until the mean local loss first reaches a target
for (a) the synchronous scheduler (clean — no churn handicap), and
(b) the async scheduler with heterogeneous compute AND churn.  Async
wins despite the handicap.

``python -m benchmarks.bench_async --smoke`` runs a small configuration
and writes a ``BENCH_async.json`` artifact (the CI perf trajectory).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.common import build_system, row


def _make_apps(sys_, nodes, rng, m, w, *, dim=16, classes=4, shard=24, tag=""):
    from repro import data as data_mod
    from repro.fl import rounds

    apps = []
    for a in range(m):
        x, y = data_mod.synthetic_classification(w * shard, dim, classes, seed=100 + a)
        parts = data_mod.dirichlet_partition(y, w, alpha=1.0, seed=200 + a)
        parts = [p if len(p) else np.arange(3) for p in parts]
        ws = [int(n) for n in rng.choice(nodes, size=w, replace=False)]
        apps.append(
            rounds.make_app(
                sys_, f"async{tag}-{m}-{a}", workers=ws,
                data_by_worker={n: (x[parts[i]], y[parts[i]]) for i, n in enumerate(ws)},
                dim=dim, num_classes=classes, local_steps=3, lr=0.2, seed=a,
            )
        )
    return apps


def _time_to_target(ts, losses, target):
    for t, l in zip(ts, losses):
        if l <= target:
            return float(t)
    return float("inf")


def compare(m_apps: int, *, workers=8, rounds_n=5, seed=0, target=0.5,
            base_ms=40.0, spread=6.0, model_bytes=2e5) -> dict:
    """One sync-vs-async comparison at M concurrent apps; returns metrics."""
    from repro.core.sim import ChurnModel, SyncRoundScheduler, per_app_round_ms
    from repro.fl import async_engine, rounds

    per_worker = async_engine.worker_compute_fn(base_ms, spread, seed=seed)

    # (a) synchronous: barrier waits for the slowest worker; no churn
    sys_s, nodes_s, rng_s = build_system(n_nodes=600, zones=4, seed=seed)
    apps_s = _make_apps(sys_s, nodes_s, rng_s, m_apps, workers, tag="s")
    sched = SyncRoundScheduler(
        sys_s, [a.handle for a in apps_s], model_bytes=model_bytes,
        compute_ms=async_engine.sync_barrier_compute_fn(per_worker),
    )
    hist = sched.run(rounds=rounds_n)
    sync_t = {aid: np.cumsum(v) for aid, v in per_app_round_ms(hist).items()}
    sync_tt = []
    for app in apps_s:
        losses = [rounds.run_round(sys_s, app)["loss"] for _ in range(rounds_n)]
        sync_tt.append(_time_to_target(sync_t[app.handle.app_id], losses, target))

    # (b) async buffered: K = W/2, staleness-weighted, WITH churn
    sys_a, nodes_a, rng_a = build_system(n_nodes=600, zones=4, seed=seed)
    apps_a = _make_apps(sys_a, nodes_a, rng_a, m_apps, workers, tag="a")
    churn = ChurnModel(
        period_ms=6.0 * base_ms, downtime_ms=12.0 * base_ms,
        group_size=max(1, round(0.1 * workers)), seed=seed,
    )
    res = async_engine.run_async(
        sys_a, apps_a, applies=2 * rounds_n, buffer_k=max(2, workers // 2),
        staleness_alpha=0.5, model_bytes=model_bytes, compute_ms=per_worker,
        churn=churn,
    )
    async_tt = []
    for app in apps_a:
        h = [r for r in res["history"] if r["app_id"] == app.handle.app_id]
        async_tt.append(_time_to_target([r["t_ms"] for r in h], [r["loss"] for r in h], target))
    failed_once = {n for c in res["churn"] if c.kind == "fail" for n in c.nodes}
    stal = [e.mean_staleness for e in res["events"]]
    return {
        "m": m_apps,
        "workers": workers,
        "target_loss": target,
        "sync_tt_ms": float(np.mean(sync_tt)),
        "async_tt_ms": float(np.mean(async_tt)),
        "speedup": float(np.mean(sync_tt) / max(np.mean(async_tt), 1e-9)),
        "churn_fraction": len(failed_once) / float(m_apps * workers),
        "churn_events": len(res["churn"]),
        "mean_staleness": float(np.mean(stal)) if stal else 0.0,
    }


def run() -> list[str]:
    out = []
    for m in (1, 4, 16):
        r = compare(m)
        out.append(
            row(
                f"async_vs_sync_m{m}",
                0.0,
                f"sync_tt_ms={r['sync_tt_ms']:.0f};async_tt_ms={r['async_tt_ms']:.0f};"
                f"speedup={r['speedup']:.2f}x;churn_frac={r['churn_fraction']:.2f};"
                f"mean_staleness={r['mean_staleness']:.2f}",
            )
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="small config; write BENCH_async.json")
    ap.add_argument("--out", default="BENCH_async.json")
    args = ap.parse_args()
    ms = (1, 4) if args.smoke else (1, 4, 16)
    rounds_n = 3 if args.smoke else 5
    results = [compare(m, rounds_n=rounds_n) for m in ms]
    payload = {
        "bench": "async_vs_sync_time_to_target",
        "smoke": bool(args.smoke),
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    for r in results:
        print(
            f"M={r['m']}: sync={r['sync_tt_ms']:.0f}ms async={r['async_tt_ms']:.0f}ms "
            f"speedup={r['speedup']:.2f}x churn={r['churn_fraction']:.0%} "
            f"staleness={r['mean_staleness']:.2f}"
        )
    ok = all(r["speedup"] > 1.0 and r["churn_fraction"] >= 0.10 for r in results)
    print(f"wrote {args.out}; async beats sync under churn: {ok}")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
