"""Sync vs async buffered execution: time-to-target-loss under churn.

The async claim (ROADMAP / paper §VII): a barrier round is priced by its
slowest worker, so under a heterogeneous edge compute distribution the
synchronous engine crawls at straggler speed, while FedBuff-style
buffered aggregation applies after the K fastest arrivals and keeps the
pipeline full — even with ≥10% of workers failing and rejoining
mid-round (churn on the event clock, repaired by ``core/recovery``).

This bench runs four schedulers per M ∈ {1, 4, 16} concurrent apps:

- ``sync``    — barrier rounds, clean (no churn handicap);
- ``fixed``   — async, fixed K = W/2, heterogeneous compute + churn;
- ``adaptive``— same, but an ``AdaptiveKController`` re-sizes K each
  apply from the arrival rate + staleness percentile;
- ``adaptive+utility`` — adaptive K plus Oort-style utility client
  selection (``fl/selection.UtilitySelector``): chronic stragglers are
  parked, fast informative clients keep the buffer full.

All async variants share seeds, topology, shards and churn schedule, so
the comparison isolates the control policy.  Reported metric: simulated
time until the mean local loss first reaches the target, per app.

``python -m benchmarks.bench_async --smoke`` runs a small configuration
and writes a ``BENCH_async.json`` artifact (the CI perf trajectory).
The smoke run also gates the multi-app fairness acceptance criteria
(``benchmarks/bench_fairness.py``): at M = 16 with one hot app, Jain's
index over demand-normalized per-app uplink throughput must reach 0.8
under the weighted-fair engine and improve on the legacy start-time
pricing, with no app's time-to-target-loss regressing more than 5%.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.common import build_system, row


def _make_apps(sys_, nodes, rng, m, w, *, dim=16, classes=4, shard=24, tag=""):
    from repro import data as data_mod
    from repro.fl import rounds

    apps = []
    for a in range(m):
        x, y = data_mod.synthetic_classification(w * shard, dim, classes, seed=100 + a)
        parts = data_mod.dirichlet_partition(y, w, alpha=1.0, seed=200 + a)
        parts = [p if len(p) else np.arange(3) for p in parts]
        ws = [int(n) for n in rng.choice(nodes, size=w, replace=False)]
        apps.append(
            rounds.make_app(
                sys_, f"async{tag}-{m}-{a}", workers=ws,
                data_by_worker={n: (x[parts[i]], y[parts[i]]) for i, n in enumerate(ws)},
                dim=dim, num_classes=classes, local_steps=3, lr=0.2, seed=a,
            )
        )
    return apps


def _time_to_target(ts, losses, target):
    for t, l in zip(ts, losses):
        if l <= target:
            return float(t)
    return float("inf")


def _run_async_variant(variant, m_apps, *, workers, rounds_n, seed, target,
                       base_ms, spread, model_bytes, n_nodes, zones):
    """One async run (fresh system, shared seeds -> identical topology,
    shards, compute draws and churn schedule across variants)."""
    from repro.core.sim import ChurnModel
    from repro.fl import async_engine
    from repro.fl.selection import UtilitySelector

    per_worker = async_engine.worker_compute_fn(base_ms, spread, seed=seed)
    sys_a, nodes_a, rng_a = build_system(n_nodes=n_nodes, zones=zones, seed=seed)
    apps_a = _make_apps(sys_a, nodes_a, rng_a, m_apps, workers, tag="a")
    churn = ChurnModel(
        period_ms=6.0 * base_ms, downtime_ms=12.0 * base_ms,
        group_size=max(1, round(0.1 * workers)), seed=seed,
    )
    kwargs = {}
    if variant in ("adaptive", "adaptive+utility"):
        kwargs["adaptive"] = True
        kwargs["adaptive_kwargs"] = {"target_staleness": 1.0, "percentile": 75.0}
    if variant == "adaptive+utility":
        kwargs["selector"] = UtilitySelector(
            deadline_ms=6.0 * base_ms, epsilon=0.1, admit_quantile=0.35, seed=seed,
        )
    res = async_engine.run_async(
        sys_a, apps_a, applies=2 * rounds_n, buffer_k=max(2, workers // 2),
        staleness_alpha=0.5, model_bytes=model_bytes, compute_ms=per_worker,
        churn=churn, **kwargs,
    )
    tts = []
    for app in apps_a:
        h = [r for r in res["history"] if r["app_id"] == app.handle.app_id]
        tts.append(_time_to_target([r["t_ms"] for r in h], [r["loss"] for r in h], target))
    failed_once = {n for c in res["churn"] if c.kind == "fail" for n in c.nodes}
    stal = [e.mean_staleness for e in res["events"]]
    ks = [e.k for e in res["events"]]
    return {
        "tt_ms": float(np.mean(tts)),
        "churn_fraction": len(failed_once) / float(m_apps * workers),
        "churn_events": len(res["churn"]),
        "mean_staleness": float(np.mean(stal)) if stal else 0.0,
        "mean_k": float(np.mean(ks)) if ks else 0.0,
    }


def compare(m_apps: int, *, workers=8, rounds_n=5, seed=0, target=0.5,
            base_ms=40.0, spread=6.0, model_bytes=2e5, n_nodes=600, zones=4) -> dict:
    """One full comparison at M concurrent apps; returns per-variant metrics.
    The topology constants (``n_nodes``, ``zones``) are shared between the
    sync baseline and every async variant — that's what makes the
    comparison isolate the control policy."""
    from repro.core.sim import SyncRoundScheduler, per_app_round_ms
    from repro.fl import async_engine, rounds

    per_worker = async_engine.worker_compute_fn(base_ms, spread, seed=seed)

    # (a) synchronous baseline: barrier waits for the slowest worker; no churn
    sys_s, nodes_s, rng_s = build_system(n_nodes=n_nodes, zones=zones, seed=seed)
    apps_s = _make_apps(sys_s, nodes_s, rng_s, m_apps, workers, tag="s")
    sched = SyncRoundScheduler(
        sys_s, [a.handle for a in apps_s], model_bytes=model_bytes,
        compute_ms=async_engine.sync_barrier_compute_fn(per_worker),
    )
    hist = sched.run(rounds=rounds_n)
    sync_t = {aid: np.cumsum(v) for aid, v in per_app_round_ms(hist).items()}
    sync_tt = []
    for app in apps_s:
        losses = [rounds.run_round(sys_s, app)["loss"] for _ in range(rounds_n)]
        sync_tt.append(_time_to_target(sync_t[app.handle.app_id], losses, target))

    # (b) async variants: same seeds/topology/churn, different control policy
    cfg = dict(workers=workers, rounds_n=rounds_n, seed=seed, target=target,
               base_ms=base_ms, spread=spread, model_bytes=model_bytes,
               n_nodes=n_nodes, zones=zones)
    variants = {v: _run_async_variant(v, m_apps, **cfg)
                for v in ("fixed", "adaptive", "adaptive+utility")}
    fixed, adap, util = variants["fixed"], variants["adaptive"], variants["adaptive+utility"]
    return {
        "m": m_apps,
        "workers": workers,
        "target_loss": target,
        "sync_tt_ms": float(np.mean(sync_tt)),
        "fixed_tt_ms": fixed["tt_ms"],
        "adaptive_tt_ms": adap["tt_ms"],
        "adaptive_utility_tt_ms": util["tt_ms"],
        "speedup_vs_sync": float(np.mean(sync_tt)) / max(util["tt_ms"], 1e-9),
        "utility_vs_fixed": fixed["tt_ms"] / max(util["tt_ms"], 1e-9),
        "churn_fraction": fixed["churn_fraction"],
        "variants": variants,
    }


def run() -> list[str]:
    out = []
    for m in (1, 4, 16):
        r = compare(m)
        out.append(
            row(
                f"async_vs_sync_m{m}",
                0.0,
                f"sync_tt_ms={r['sync_tt_ms']:.0f};fixed_tt_ms={r['fixed_tt_ms']:.0f};"
                f"adaptive_tt_ms={r['adaptive_tt_ms']:.0f};"
                f"adaptive_utility_tt_ms={r['adaptive_utility_tt_ms']:.0f};"
                f"utility_vs_fixed={r['utility_vs_fixed']:.2f}x;"
                f"churn_frac={r['churn_fraction']:.2f}",
            )
        )
    return out


def _json_safe(obj):
    """inf (a variant that never hit the target) -> null: json.dump would
    otherwise emit bare ``Infinity``, which is not valid JSON."""
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, float) and not np.isfinite(obj):
        return None
    return obj


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--smoke", action="store_true", help="small config; write BENCH_async.json")
    ap.add_argument("--out", default="BENCH_async.json")
    args = ap.parse_args()
    ms = (1, 4) if args.smoke else (1, 4, 16)
    rounds_n = 3 if args.smoke else 5
    results = [compare(m, rounds_n=rounds_n) for m in ms]
    fairness = None
    if args.smoke:
        from benchmarks import bench_fairness

        fairness = {
            "matrix": [bench_fairness.fairness_compare(16)],
            "time_to_loss_guard": bench_fairness.time_to_loss_guard(),
        }
    payload = {
        "bench": "async_time_to_target_fixed_vs_adaptive_vs_utility",
        "smoke": bool(args.smoke),
        "results": _json_safe(results),
        "fairness": _json_safe(fairness),
    }
    out_path = os.path.abspath(args.out)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, allow_nan=False)
    for r in results:
        print(
            f"M={r['m']}: sync={r['sync_tt_ms']:.0f}ms fixed={r['fixed_tt_ms']:.0f}ms "
            f"adaptive={r['adaptive_tt_ms']:.0f}ms "
            f"adaptive+utility={r['adaptive_utility_tt_ms']:.0f}ms "
            f"(utility vs fixed {r['utility_vs_fixed']:.2f}x, churn {r['churn_fraction']:.0%})"
        )
    ok_sync = all(r["sync_tt_ms"] >= r["adaptive_utility_tt_ms"] for r in results)
    ok_fixed = all(
        np.isfinite(r["adaptive_utility_tt_ms"])
        and r["adaptive_utility_tt_ms"] <= r["fixed_tt_ms"]
        for r in results
        if r["m"] >= 4
    )
    # every variant of every M must have seen >= 10% churn, not just fixed
    ok_churn = all(
        v["churn_fraction"] >= 0.10 for r in results for v in r["variants"].values()
    )
    print(f"wrote {out_path}")
    print(
        f"adaptive+utility <= fixed at M>=4: {ok_fixed}; beats sync: {ok_sync}; "
        f"churn >= 10% in every variant: {ok_churn}"
    )
    fairness_fails = []
    if fairness is not None:
        from benchmarks import bench_fairness

        r = fairness["matrix"][0]
        g = fairness["time_to_loss_guard"]
        print(
            f"fairness M=16: jain {r['jain_legacy']:.3f} -> {r['jain_fair']:.3f}; "
            f"time-to-loss worst {g['max_regression']:.2f}x, mean {g['mean_ratio']:.2f}x"
        )
        fairness_fails = bench_fairness.gate(fairness["matrix"], g)
        for msg in fairness_fails:
            print(f"GATE FAIL: {msg}")
    if not (ok_fixed and ok_sync and ok_churn) or fairness_fails:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
