"""Fig 6: model dissemination / gradient aggregation time vs #nodes
(exponential sweep) and vs tree fanout (b = 3, 4, 5)."""
from __future__ import annotations

import numpy as np

from .common import build_system, row, timeit


def run() -> list[str]:
    out = []
    # (a, b): time vs exponentially growing node count — expect ~linear
    # (depth = O(log N)); we report modeled tree latency + measured hops
    for n in (20, 80, 320, 1280, 5120):
        sys_, nodes, rng = build_system(n_nodes=max(n, 64), zones=4, seed=1, bulk=True)
        h = sys_.CreateTree(f"bench-{n}")
        sys_.SubscribeMany(
            h.app_id, rng.choice(nodes, size=min(n, len(nodes)), replace=False)
        )
        tree = h.tree
        bt = tree.broadcast_time(sys_.overlay)
        at = tree.aggregation_time(sys_.overlay)
        out.append(
            row(
                f"fig6ab_tree_n{n}",
                0.0,
                f"depth={tree.depth()};broadcast_ms={bt:.2f};aggregate_ms={at:.2f}",
            )
        )

    # (c, d): fanout sweep (ResNet-34-sized payload, 85 MB)
    for b in (3, 4, 5):
        sys_, nodes, rng = build_system(n_nodes=2000, zones=1, seed=2, base_bits=b, bulk=True)
        h = sys_.CreateTree(f"fan-{b}")
        sys_.SubscribeMany(h.app_id, rng.choice(nodes, size=1500, replace=False))
        tree = h.tree
        # payload time per edge: 85MB over per-node bandwidth ~60 Mbps
        payload_ms = 85e6 * 8 / (60e6) * 1e3 / 1000
        bt = tree.broadcast_time(sys_.overlay, payload_ms=payload_ms)
        out.append(
            row(
                f"fig6cd_fanout_b{b}",
                0.0,
                f"fanout={tree.fanout()};depth={tree.depth()};broadcast_ms={bt:.1f}",
            )
        )
    return out
