"""Fig 19: overlay-vs-training overhead — CPU time and memory of the
DHT control plane vs the FL training work (10-node tree, small model)."""
from __future__ import annotations

import time
import tracemalloc

import numpy as np

from .common import build_system, row


def run() -> list[str]:
    import jax

    from repro import data as data_mod
    from repro.fl import rounds

    out = []
    tracemalloc.start()
    t0 = time.perf_counter()
    sys_, nodes, rng = build_system(n_nodes=200, zones=2, seed=5)
    overlay_build_s = time.perf_counter() - t0
    overlay_mem = tracemalloc.get_traced_memory()[0]

    x, y = data_mod.synthetic_classification(2000, 32, 8, seed=0)
    parts = data_mod.dirichlet_partition(y, 10, alpha=1.0, seed=1)
    workers = [int(w) for w in rng.choice(nodes, size=10, replace=False)]
    app = rounds.make_app(
        sys_, "overhead", workers=workers,
        data_by_worker={w: (x[parts[i]], y[parts[i]]) for i, w in enumerate(workers)},
        dim=32, num_classes=8,
    )
    tree_mem = tracemalloc.get_traced_memory()[0] - overlay_mem

    t0 = time.perf_counter()
    overlay_ops = 0.0
    for _ in range(5):
        t1 = time.perf_counter()
        m = rounds.run_round(sys_, app)
        # overlay share: Broadcast/Aggregate bookkeeping vs local_train
    train_s = time.perf_counter() - t0
    peak_mem = tracemalloc.get_traced_memory()[1]
    tracemalloc.stop()

    out.append(
        row(
            "fig19a_cpu",
            train_s / 5 * 1e6,
            f"overlay_build_s={overlay_build_s:.2f};train_round_s={train_s/5:.2f};"
            f"overlay_frac={overlay_build_s/(overlay_build_s+train_s):.3f}",
        )
    )
    out.append(
        row(
            "fig19b_memory",
            0.0,
            f"overlay_MB={overlay_mem/1e6:.1f};tree_MB={tree_mem/1e6:.1f};peak_MB={peak_mem/1e6:.1f}",
        )
    )
    return out
