"""Million-node scale bench: O(log N) routing + cohort-batched events.

The paper's headline scalability claim is O(log N) hops "with millions
of nodes" (§VI, Fig. 5-6 at smaller N).  This bench measures it
directly against the vectorized scale layer (docs/performance.md
"scale layer"):

- **hops vs N** for N in {1e3, 1e4, 1e5, 1e6} (smoke: up to 1e5): each
  overlay is bulk-built with ``join_many`` and a fixed sample of routes
  is resolved through the batched ``route_many``; mean delivered hops
  are least-squares fit to ``hops = a + c*log2(N)`` and the fit must
  explain the curve (R^2 >= 0.95).  A random sub-sample of every batch
  is replayed through the scalar object-API ``route`` (the oracle) and
  must match hop-for-hop.
- **events/s + peak RSS vs M** for M in {4, 16, 64, 256} (smoke: up to
  64): pure timing-model runs (no trainer) of the cohort-batched
  scheduler in sampled-congestion mode — the configuration that holds
  the heap at O(apps + uplinks).  Peak RSS is ``resource.getrusage``'s
  high-water mark, so the sweep runs small M -> large M and each row
  reports the peak *up to and including* that M.
- **forest bootstrap vs N** for the same N ladder: subscribe N workers
  split across M apps through ``join_many`` + ``subscribe_many`` (the
  vectorized union-of-paths graft) and report subscribes/s, tree
  depth, and peak RSS.  At N <= 1e4 the bulk trees must be
  node-for-node identical to a sequential ``subscribe`` loop (the
  oracle — parent maps, children order, members, schedules), at
  N = 1e5 bulk bootstrap must be >= 10x faster than the loop, and mean
  member depth must fit ``a + c*log2(N)`` with R^2 >= 0.95.
- **M=16 exactness anchor**: the cohort-batched core in exact mode must
  produce a byte-identical event trace (ApplyEvent/ChurnRecord
  dataclass equality, exact float timestamps) to the per-event
  baseline, and ``congestion_mode="sampled"`` with ``hot_threshold=0``
  must degenerate to the exact trace.
- **sampled-congestion error**: apply-time relative error of sampled
  mode vs the exact trace, with and without periodic cold-cycle
  re-pricing (``resample_every``) — reported, not gated (the knob
  trades exactness for events, the error bound is the datum).

Gates (CI fails on regression): hops and depth log-fit R^2 >= 0.95,
zero oracle mismatches, bulk-vs-sequential tree identity, >= 10x
bootstrap speedup at N=1e5, both trace-identity checks.
``--max-events`` threads the event budget through for longer runs (the
budget error names it).

``python -m benchmarks.bench_scale --smoke`` writes BENCH_scale.json
(the CI artifact).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import resource
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.common import build_system, row

FULL_NS = (1_000, 10_000, 100_000, 1_000_000)
SMOKE_NS = (1_000, 10_000, 100_000)
FULL_MS = (4, 16, 64, 256)
SMOKE_MS = (4, 16, 64)


def _peak_rss_mb() -> float:
    """ru_maxrss is KiB on Linux, bytes on macOS."""
    peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if sys.platform == "darwin":
        peak /= 1024
    return peak / 1024.0


# -- hops vs N (route_many against the scalar oracle) -------------------------


def route_scaling(ns, *, zones=8, routes=2000, parity_sample=50, seed=0) -> list[dict]:
    from repro.core.nodeid import IdSpace
    from repro.core.overlay import MultiRingOverlay

    out = []
    for n in ns:
        space = IdSpace(zone_bits=int(math.log2(zones)), suffix_bits=28)
        ov = MultiRingOverlay(space, base_bits=4, seed=seed)
        rng = np.random.default_rng(seed + n)
        t0 = time.perf_counter()
        ids = ov.join_many(
            rng.integers(0, zones, n), coords=rng.uniform(0, 1000, (n, 2))
        )
        build_s = time.perf_counter() - t0
        srcs = ids[rng.integers(0, n, routes)]
        keys = rng.integers(0, 1 << space.total_bits, routes)
        t0 = time.perf_counter()
        batch = ov.route_many(srcs, keys)
        route_s = time.perf_counter() - t0
        mismatches = 0
        for k in rng.integers(0, routes, parity_sample):
            k = int(k)
            res = ov.route(int(srcs[k]), int(keys[k]))
            if (
                res.path != batch.path(k)
                or res.hops != int(batch.hops[k])
                or res.blocked != bool(batch.blocked[k])
            ):
                mismatches += 1
        delivered = ~batch.blocked
        out.append(
            {
                "n": int(n),
                "mean_hops": float(batch.hops[delivered].mean()),
                "max_hops": int(batch.hops[delivered].max()),
                "routes": int(routes),
                "build_s": build_s,
                "routes_per_sec": routes / max(route_s, 1e-9),
                "oracle_mismatches": mismatches,
                "peak_rss_mb": _peak_rss_mb(),
            }
        )
    return out


def log_fit(curve: list[dict], key: str = "mean_hops") -> dict:
    """Least-squares y = a + c*log2(N) over ``curve[i][key]``; returns
    slope, intercept, R^2."""
    x = np.log2([r["n"] for r in curve])
    y = np.array([r[key] for r in curve])
    c, a = np.polyfit(x, y, 1)
    pred = a + c * x
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum())
    r2 = 1.0 - ss_res / max(ss_tot, 1e-12)
    return {"slope_per_log2n": float(c), "intercept": float(a), "r2": float(r2)}


# -- forest bootstrap vs N (subscribe_many against the sequential oracle) -----


def _tree_fingerprint(tree) -> tuple:
    """Everything the bulk graft must reproduce node-for-node: topology,
    child order, membership, and the schedules derived from them."""
    return (
        tree.root,
        sorted(tree.parent.items()),
        [(p, list(tree.children[p])) for p in tree.children],
        sorted(tree.members),
        tree.aggregation_schedule(),
    )


def forest_bootstrap(ns, *, m_apps=4, zones=8, seed=0, oracle_max=10_000,
                     speedup_at=100_000) -> list[dict]:
    """Subscribe N workers across M apps, bulk vs sequential.

    The sequential ``subscribe`` loop runs (on a second Forest over the
    SAME overlay, so routes are identical) wherever it is affordable:
    at every N <= ``oracle_max`` it is the identity oracle, and at
    N == ``speedup_at`` it is the timing baseline for the >= 10x gate.
    """
    from repro.core.forest import Forest
    from repro.core.nodeid import IdSpace
    from repro.core.overlay import MultiRingOverlay

    out = []
    for n in ns:
        space = IdSpace(zone_bits=int(math.log2(zones)), suffix_bits=28)
        ov = MultiRingOverlay(space, base_bits=4, seed=seed)
        rng = np.random.default_rng(seed + n)
        ids = ov.join_many(
            rng.integers(0, zones, n), coords=rng.uniform(0, 1000, (n, 2))
        )
        shards = np.array_split(rng.permutation(ids), m_apps)

        def bulk_build():
            bulk = Forest(ov)
            trees = [bulk.create_tree(f"boot-{n}-{a}") for a in range(m_apps)]
            t0 = time.perf_counter()
            for t, shard in zip(trees, shards):
                bulk.subscribe_many(t.app_id, shard)
            return time.perf_counter() - t0, trees

        # best-of-2: the graft is deterministic, so the rebuild only
        # de-noises the wall clock (allocator churn from earlier axes)
        s1, trees = bulk_build()
        s2, trees = bulk_build()
        bulk_s = min(s1, s2)
        depths = np.concatenate(
            [t.depths_of(np.asarray(sorted(t.members), np.int64)) for t in trees]
        )
        rec = {
            "n": int(n),
            "m_apps": int(m_apps),
            "mean_depth": float(depths.mean()),
            "max_depth": int(depths.max()),
            "bulk_s": bulk_s,
            "subscribes_per_sec": n / max(bulk_s, 1e-9),
            "peak_rss_mb": _peak_rss_mb(),
        }
        if n <= oracle_max or n == speedup_at:
            seq = Forest(ov)
            seq_trees = [seq.create_tree(f"boot-{n}-{a}") for a in range(m_apps)]
            t0 = time.perf_counter()
            for t, shard in zip(seq_trees, shards):
                for w in shard.tolist():
                    seq.subscribe(t.app_id, int(w))
            rec["seq_s"] = time.perf_counter() - t0
            rec["speedup"] = rec["seq_s"] / max(bulk_s, 1e-9)
            if n <= oracle_max:
                rec["identical"] = all(
                    _tree_fingerprint(tb) == _tree_fingerprint(ts)
                    for tb, ts in zip(trees, seq_trees)
                )
        out.append(rec)
    return out


# -- events/s + RSS vs M (cohort-batched timing model) ------------------------


def _make_handles(sys_, nodes, rng, m, w, tag=""):
    """Timing-model app handles: trees + subscriptions, no jax models."""
    handles = []
    for a in range(m):
        h = sys_.CreateTree(f"scale{tag}-{m}-{a}")
        for node in rng.choice(nodes, size=w, replace=False):
            sys_.Subscribe(h.app_id, int(node))
        handles.append(h)
    return handles


def _timing_run(m_apps, *, cohort, congestion_mode, hot_threshold=4, workers=8,
                applies=2, seed=0, base_ms=40.0, spread=6.0, model_bytes=2e5,
                n_nodes=600, zones=4, max_events=1_000_000,
                resample_every=None, resample_events=None) -> dict:
    from repro.core.sim import AsyncBufferScheduler, ChurnModel
    from repro.fl import async_engine

    per_worker = async_engine.worker_compute_fn(base_ms, spread, seed=seed)
    sys_a, nodes_a, rng_a = build_system(n_nodes=n_nodes, zones=zones, seed=seed)
    handles = _make_handles(sys_a, nodes_a, rng_a, m_apps, workers, tag="s")
    churn = ChurnModel(
        period_ms=6.0 * base_ms, downtime_ms=12.0 * base_ms,
        group_size=max(1, round(0.1 * workers)), seed=seed,
    )
    sched = AsyncBufferScheduler(
        sys_a, handles, model_bytes=model_bytes, compute_ms=per_worker,
        buffer_k=max(2, workers // 2), churn=churn, cohort=cohort,
        congestion_mode=congestion_mode, hot_threshold=hot_threshold,
        resample_every=resample_every, resample_events=resample_events,
    )
    t0 = time.perf_counter()
    events = sched.run(applies, max_events=max_events)
    wall = time.perf_counter() - t0
    return {
        "events": events,
        "churn": list(sched.churn_log),
        "wall_s": wall,
        "events_dispatched": sched.events_dispatched,
        "events_per_sec": sched.events_dispatched / max(wall, 1e-9),
        "heap_max": sched.heap_max,
        "resamples": sched._resample_count,
    }


def event_scaling(ms, *, applies=2, seed=0, max_events=1_000_000) -> list[dict]:
    """Sweep M small -> large (getrusage is a high-water mark)."""
    out = []
    for m in ms:
        r = _timing_run(
            m, cohort=True, congestion_mode="sampled", applies=applies,
            seed=seed, max_events=max_events,
        )
        out.append(
            {
                "m": int(m),
                "applies_completed": len(r["events"]),
                "events_dispatched": r["events_dispatched"],
                "events_per_sec": r["events_per_sec"],
                "heap_max": r["heap_max"],
                "wall_s": r["wall_s"],
                "peak_rss_mb": _peak_rss_mb(),
            }
        )
    return out


def trace_identity(*, m_apps=16, applies=3, seed=0, max_events=1_000_000) -> dict:
    """The exactness anchor: cohort/exact and sampled(ht=0) vs baseline."""
    kw = dict(applies=applies, seed=seed, max_events=max_events)
    base = _timing_run(m_apps, cohort=False, congestion_mode="exact", **kw)
    coh = _timing_run(m_apps, cohort=True, congestion_mode="exact", **kw)
    deg = _timing_run(
        m_apps, cohort=True, congestion_mode="sampled", hot_threshold=0, **kw
    )
    return {
        "m": int(m_apps),
        "cohort_identical": base["events"] == coh["events"]
        and base["churn"] == coh["churn"],
        "sampled_ht0_identical": base["events"] == deg["events"]
        and base["churn"] == deg["churn"],
        "events_dispatched_baseline": base["events_dispatched"],
        "events_dispatched_cohort": coh["events_dispatched"],
        "heap_max_baseline": base["heap_max"],
        "heap_max_cohort": coh["heap_max"],
    }


def sampled_error(*, m_apps=8, applies=2, seed=1, base_ms=40.0,
                  max_events=1_000_000) -> dict:
    """Apply-time error of sampled congestion vs the exact trace, with
    and without periodic cold-cycle re-pricing.  Per (app, apply_index)
    relative |t_sampled - t_exact| / t_exact; the refresh bounds drift
    under bursty contention (ROADMAP follow-on (c)) — reported as data,
    not gated."""
    kw = dict(applies=applies, seed=seed, max_events=max_events)
    exact = _timing_run(m_apps, cohort=True, congestion_mode="exact", **kw)
    runs = {
        "sampled": _timing_run(
            m_apps, cohort=True, congestion_mode="sampled", **kw
        ),
        "sampled_resampled": _timing_run(
            m_apps, cohort=True, congestion_mode="sampled",
            resample_every=2.0 * base_ms, **kw
        ),
    }
    ref = {(e.app_id, e.apply_index): e.time_ms for e in exact["events"]}
    out = {"m": int(m_apps), "applies_per_app": int(applies)}
    for tag, r in runs.items():
        errs = [
            abs(e.time_ms - ref[(e.app_id, e.apply_index)])
            / max(ref[(e.app_id, e.apply_index)], 1e-9)
            for e in r["events"]
            if (e.app_id, e.apply_index) in ref
        ]
        out[tag] = {
            "mean_rel_err": float(np.mean(errs)) if errs else 0.0,
            "max_rel_err": float(np.max(errs)) if errs else 0.0,
            "events_dispatched": r["events_dispatched"],
            "resamples": r["resamples"],
        }
    out["exact_events_dispatched"] = exact["events_dispatched"]
    return out


# -- gates / drivers ----------------------------------------------------------


def gate(payload: dict, *, min_r2: float = 0.95, min_speedup: float = 10.0) -> list[str]:
    """The acceptance gates; returns failure messages (empty = pass)."""
    fails = []
    fit = payload["hops_fit"]
    if fit["r2"] < min_r2:
        fails.append(
            f"hops-vs-N log fit R^2 {fit['r2']:.4f} below the {min_r2} gate"
        )
    for r in payload["hops_vs_n"]:
        if r["oracle_mismatches"]:
            fails.append(
                f"N={r['n']}: {r['oracle_mismatches']} route_many results "
                "diverge from the scalar oracle"
            )
    dfit = payload["depth_fit"]
    if dfit["r2"] < min_r2:
        fails.append(
            f"depth-vs-N log fit R^2 {dfit['r2']:.4f} below the {min_r2} gate"
        )
    for r in payload["forest_vs_n"]:
        if "identical" in r and not r["identical"]:
            fails.append(
                f"N={r['n']}: subscribe_many tree diverges from the "
                "sequential-subscribe oracle"
            )
        if "speedup" in r and r["n"] >= 100_000 and r["speedup"] < min_speedup:
            fails.append(
                f"N={r['n']}: bulk bootstrap speedup {r['speedup']:.1f}x "
                f"below the {min_speedup}x gate"
            )
    tid = payload["trace_identity"]
    if not tid["cohort_identical"]:
        fails.append("M=16 cohort trace diverges from the per-event baseline")
    if not tid["sampled_ht0_identical"]:
        fails.append("M=16 sampled(hot_threshold=0) trace diverges from exact")
    for r in payload["events_vs_m"]:
        want = r["m"] * payload["applies_per_app"]
        if r["applies_completed"] < want:
            fails.append(
                f"M={r['m']}: only {r['applies_completed']}/{want} applies completed"
            )
    return fails


def bench(*, smoke: bool, max_events: int, seed: int = 0) -> dict:
    ns = SMOKE_NS if smoke else FULL_NS
    ms = SMOKE_MS if smoke else FULL_MS
    applies = 2
    curve = route_scaling(ns, seed=seed)
    fit = log_fit(curve)
    forest = forest_bootstrap(ns, seed=seed)
    dfit = log_fit(forest, key="mean_depth")
    tid = trace_identity(seed=seed, max_events=max_events)
    sweep = event_scaling(ms, applies=applies, seed=seed, max_events=max_events)
    serr = sampled_error(seed=seed + 1, max_events=max_events)
    return {
        "bench": "scale_vectorized_overlay_cohort_events",
        "smoke": bool(smoke),
        "applies_per_app": applies,
        "hops_vs_n": curve,
        "hops_fit": fit,
        "forest_vs_n": forest,
        "depth_fit": dfit,
        "trace_identity": tid,
        "events_vs_m": sweep,
        "sampled_error": serr,
    }


def run() -> list[str]:
    """Registry entry (python -m benchmarks.run): smoke-sized."""
    payload = bench(smoke=True, max_events=1_000_000)
    out = []
    for r in payload["hops_vs_n"]:
        out.append(
            row(
                f"scale_route_n{r['n']}",
                1e6 / max(r["routes_per_sec"], 1e-9),
                f"mean_hops={r['mean_hops']:.2f};"
                f"oracle_mismatches={r['oracle_mismatches']}",
            )
        )
    for r in payload["forest_vs_n"]:
        out.append(
            row(
                f"scale_forest_n{r['n']}",
                1e6 / max(r["subscribes_per_sec"], 1e-9),
                f"mean_depth={r['mean_depth']:.2f};"
                f"identical={r.get('identical', 'n/a')};"
                f"speedup={r.get('speedup', float('nan')):.1f}",
            )
        )
    fit = payload["hops_fit"]
    dfit = payload["depth_fit"]
    tid = payload["trace_identity"]
    serr = payload["sampled_error"]
    for r in payload["events_vs_m"]:
        out.append(
            row(
                f"scale_events_m{r['m']}",
                r["wall_s"] * 1e6,
                f"events_per_sec={r['events_per_sec']:.0f};"
                f"heap_max={r['heap_max']};peak_rss_mb={r['peak_rss_mb']:.0f}",
            )
        )
    out.append(
        row(
            "scale_gates",
            0.0,
            f"fit_r2={fit['r2']:.4f};slope={fit['slope_per_log2n']:.3f};"
            f"depth_fit_r2={dfit['r2']:.4f};"
            f"cohort_identical={tid['cohort_identical']};"
            f"sampled_ht0_identical={tid['sampled_ht0_identical']};"
            f"resample_mean_err={serr['sampled_resampled']['mean_rel_err']:.4f}",
        )
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--smoke", action="store_true",
                    help="N <= 1e5, M <= 64 (CI tier); same gates")
    ap.add_argument("--out", default="BENCH_scale.json")
    ap.add_argument("--max-events", type=int, default=1_000_000,
                    help="event budget per scheduler run (threaded through)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    payload = bench(smoke=args.smoke, max_events=args.max_events, seed=args.seed)
    out_path = os.path.abspath(args.out)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, allow_nan=False)

    for r in payload["hops_vs_n"]:
        print(
            f"N={r['n']:>9,}: mean hops {r['mean_hops']:.2f} (max {r['max_hops']}), "
            f"build {r['build_s']:.2f}s, {r['routes_per_sec']:.0f} routes/s, "
            f"oracle mismatches {r['oracle_mismatches']}, "
            f"peak RSS {r['peak_rss_mb']:.0f} MB"
        )
    fit = payload["hops_fit"]
    print(
        f"log fit: hops = {fit['intercept']:.2f} + "
        f"{fit['slope_per_log2n']:.3f}*log2(N), R^2 = {fit['r2']:.4f}"
    )
    for r in payload["forest_vs_n"]:
        extra = ""
        if "speedup" in r:
            extra += f", {r['speedup']:.1f}x vs sequential"
        if "identical" in r:
            extra += f", identical={r['identical']}"
        print(
            f"forest N={r['n']:>9,}: {r['subscribes_per_sec']:.0f} subscribes/s, "
            f"mean depth {r['mean_depth']:.2f} (max {r['max_depth']}), "
            f"bulk {r['bulk_s']:.2f}s{extra}, peak RSS {r['peak_rss_mb']:.0f} MB"
        )
    dfit = payload["depth_fit"]
    print(
        f"depth fit: depth = {dfit['intercept']:.2f} + "
        f"{dfit['slope_per_log2n']:.3f}*log2(N), R^2 = {dfit['r2']:.4f}"
    )
    serr = payload["sampled_error"]
    print(
        f"sampled apply-time error vs exact (M={serr['m']}): "
        f"frozen mean {serr['sampled']['mean_rel_err']:.4f} "
        f"(max {serr['sampled']['max_rel_err']:.4f}); with resample "
        f"mean {serr['sampled_resampled']['mean_rel_err']:.4f} "
        f"(max {serr['sampled_resampled']['max_rel_err']:.4f}, "
        f"{serr['sampled_resampled']['resamples']} resamples)"
    )
    tid = payload["trace_identity"]
    print(
        f"M={tid['m']} trace identity: cohort == baseline: "
        f"{tid['cohort_identical']}; sampled(ht=0) == exact: "
        f"{tid['sampled_ht0_identical']}; heap max "
        f"{tid['heap_max_baseline']} -> {tid['heap_max_cohort']}"
    )
    for r in payload["events_vs_m"]:
        print(
            f"M={r['m']:>4}: {r['events_per_sec']:.0f} events/s, "
            f"{r['applies_completed']} applies, heap max {r['heap_max']}, "
            f"wall {r['wall_s']:.2f}s, peak RSS {r['peak_rss_mb']:.0f} MB"
        )
    fails = gate(payload)
    print(f"wrote {out_path}")
    for msg in fails:
        print(f"GATE FAIL: {msg}")
    if fails:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
