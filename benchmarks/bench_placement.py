"""Live placement vs static trees: time-to-target-loss under churn.

M apps with small (300 KB) models, heterogeneous compute, and churn
whose period is scale-matched to the cycle length (seconds, not the
milliseconds-scale churn of ``bench_async`` — a placement layer cannot
help if no cycle ever survives between failures).  Each configuration
runs twice on identical seeds: once with static trees
(``placement=None``) and once with the default ``PlacementEngine``
closing the loop planner → forest re-graft → event core → selector.

Gates (``gate_placement``):

- placed mean simulated time-to-target-loss <= 0.95x static at every M;
- Jain's index over per-app completion rates is no worse than static;
- >= 10% of workers fail at least once in both runs (the churn floor
  the comparison is claimed under);
- trace identity: an explicit ``placement=None`` run is byte-identical
  (apply/churn-trace digest) to a run that never mentions placement —
  the closed loop is pay-for-what-you-use.

``python -m benchmarks.bench_placement --smoke`` runs M=16 and writes
``BENCH_placement.json`` (a CI artifact); the full run adds M=64.
Everything is seeded and deterministic.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import build_system, row

SMOKE_MS = (16,)   # --smoke stays bounded at M <= 16
FULL_MS = (16, 64)

# Fixture: commit uplink matters (300 KB over 20-100 Mbps shared hops
# ~ tens to hundreds of ms) but cycles complete between churn events
# (period 1.5 s >> cycle ~ 0.2-0.5 s).  group_size keeps ~5% of all
# workers down at any instant, which over a run fails well over 10% of
# workers at least once.  The churn window is bounded
# (max_fail_events): churn volume must be a property of the scenario,
# not of the run length — an unbounded model feeds back (a slower run
# absorbs proportionally more failures, which makes it slower still),
# which contaminates any static-vs-placed comparison and can stall a
# straggler app indefinitely at M=64.
WORKERS = 5
APPLIES = 8
MODEL_BYTES = 3e5
BASE_MS = 4.0
TARGET_LOSS = 0.35
CHURN_PERIOD_MS = 1500.0
CHURN_DOWNTIME_MS = 3000.0
CHURN_MAX_FAILS = 32


def _make_apps(sys_, nodes, rng, m):
    from repro import data as data_mod
    from repro.fl import rounds

    apps = []
    for a in range(m):
        x, y = data_mod.synthetic_classification(WORKERS * 24, 16, 4, seed=100 + a)
        parts = data_mod.dirichlet_partition(y, WORKERS, alpha=1.0, seed=200 + a)
        ws = [int(n) for n in rng.choice(nodes, size=WORKERS, replace=False)]
        apps.append(
            rounds.make_app(
                sys_,
                f"plc-{m}-{a}",
                workers=ws,
                data_by_worker={n: (x[parts[i]], y[parts[i]]) for i, n in enumerate(ws)},
                dim=16,
                num_classes=4,
                local_steps=3,
                lr=0.2,
                seed=a,
            )
        )
    return apps


def _time_to_loss(history, app_id, target=TARGET_LOSS):
    for r in history:
        if r["app_id"] == app_id and r["loss"] <= target:
            return float(r["t_ms"])
    return float("inf")


def _run_once(m, seed, placement, *, pass_kwarg=True):
    from repro.core.sim import ChurnModel
    from repro.fl import async_engine

    sys_, nodes, rng = build_system(n_nodes=max(96, 5 * m), zones=8, seed=seed)
    apps = _make_apps(sys_, nodes, rng, m)
    churn = ChurnModel(
        period_ms=CHURN_PERIOD_MS,
        downtime_ms=CHURN_DOWNTIME_MS,
        group_size=max(1, round(0.05 * m * WORKERS)),
        seed=seed + 3,
        max_fail_events=CHURN_MAX_FAILS,
    )
    kw = {"placement": placement} if pass_kwarg else {}
    res = async_engine.run_async(
        sys_,
        apps,
        applies=APPLIES,
        buffer_k=4,
        staleness_alpha=0.5,
        model_bytes=MODEL_BYTES,
        compute_ms=async_engine.worker_compute_fn(20.0, 3.0, seed),
        base_ms=BASE_MS,
        fair=True,
        churn=churn,
        max_events=8_000_000,
        **kw,
    )
    return res, [a.handle.app_id for a in apps]


def _churn_fraction(sched, m):
    failed_once = set()
    for c in sched.churn_log:
        if c.kind == "fail":
            failed_once.update(c.nodes)
    allw = set().union(*[set(sched._orig_workers[ai]) for ai in range(m)])
    return len(failed_once & allw) / max(len(allw), 1)


def _trace_digest(sched) -> str:
    h = hashlib.sha256()
    for ev in sched.history:  # ApplyEvent dataclasses: repr is total
        h.update(repr(ev).encode())
    for c in sched.churn_log:
        h.update(repr(c).encode())
    for f in sched.fairness_log:
        h.update(repr(f).encode())
    return h.hexdigest()


def placement_compare(m: int, *, seed: int = 0) -> dict:
    """Static vs placed run on identical seeds; returns gate inputs."""
    from repro.core.pathplan import PlacementEngine
    from repro.kernels.ops import jain_fairness

    res_s, ids = _run_once(m, seed, None)
    res_p, _ = _run_once(m, seed, PlacementEngine(cooldown_ms=5000.0))
    ss, sp = res_s["scheduler"], res_p["scheduler"]

    tts_s = [_time_to_loss(res_s["history"], i) for i in ids]
    tts_p = [_time_to_loss(res_p["history"], i) for i in ids]
    rate_s = [1.0 / max(t, 1e-9) for t in tts_s]
    rate_p = [1.0 / max(t, 1e-9) for t in tts_p]
    ratios = [p / s for p, s in zip(tts_p, tts_s)]
    return {
        "m": m,
        "tt_static_ms": tts_s,
        "tt_placed_ms": tts_p,
        "mean_tt_ratio": float(np.mean(tts_p) / np.mean(tts_s)),
        "max_tt_ratio": float(max(ratios)),
        "jain_static": float(jain_fairness(rate_s)),
        "jain_placed": float(jain_fairness(rate_p)),
        "churn_frac_static": _churn_fraction(ss, m),
        "churn_frac_placed": _churn_fraction(sp, m),
        "replans": len(sp.replan_log),
        "moves": int(sum(len(r.moves) for r in sp.replan_log)),
        "replan_cost_ms": float(sum(r.cost_ms for r in sp.replan_log)),
        "control_bytes": float(sp.control_bytes),
    }


def trace_identity(m: int = 16, *, seed: int = 0) -> dict:
    """`placement=None` must not perturb a single event vs the legacy path."""
    res_a, _ = _run_once(m, seed, None, pass_kwarg=True)
    res_b, _ = _run_once(m, seed, None, pass_kwarg=False)
    da = _trace_digest(res_a["scheduler"])
    db = _trace_digest(res_b["scheduler"])
    return {"m": m, "digest_none": da, "digest_legacy": db, "identical": da == db}


def gate_placement(results: list[dict], ident: dict) -> list[str]:
    fails = []
    if not ident["identical"]:
        fails.append(
            f"placement=None trace digest {ident['digest_none'][:12]} != "
            f"legacy {ident['digest_legacy'][:12]} at M={ident['m']}"
        )
    for r in results:
        m = r["m"]
        if r["mean_tt_ratio"] > 0.95:
            fails.append(
                f"M={m}: placed mean time-to-loss {r['mean_tt_ratio']:.3f}x > 0.95x static"
            )
        if r["jain_placed"] < r["jain_static"] - 1e-3:
            fails.append(
                f"M={m}: Jain worsened {r['jain_static']:.3f} -> {r['jain_placed']:.3f}"
            )
        for key in ("churn_frac_static", "churn_frac_placed"):
            if r[key] < 0.10:
                fails.append(f"M={m}: {key}={r[key]:.2f} < 0.10 churn floor")
    return fails


def run() -> list[str]:
    out = []
    for m in SMOKE_MS:
        r = placement_compare(m)
        out.append(
            row(
                f"placement_m{m}",
                0.0,
                f"mean_tt_ratio={r['mean_tt_ratio']:.3f};"
                f"jain={r['jain_static']:.3f}->{r['jain_placed']:.3f};"
                f"moves={r['moves']};replan_cost_ms={r['replan_cost_ms']:.0f}",
            )
        )
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--smoke", action="store_true",
                    help="M=16 only; write BENCH_placement.json")
    ap.add_argument("--out", default="BENCH_placement.json")
    args = ap.parse_args(argv)

    ident = trace_identity(16)
    print(f"trace identity (placement=None vs legacy, M=16): {ident['identical']}")
    results = [placement_compare(m) for m in (SMOKE_MS if args.smoke else FULL_MS)]
    for r in results:
        print(
            f"M={r['m']}: time-to-loss placed/static mean {r['mean_tt_ratio']:.3f}x "
            f"(worst {r['max_tt_ratio']:.2f}x)  "
            f"jain {r['jain_static']:.3f}->{r['jain_placed']:.3f}  "
            f"churn {r['churn_frac_static']:.2f}/{r['churn_frac_placed']:.2f}  "
            f"replans={r['replans']} moves={r['moves']} "
            f"cost={r['replan_cost_ms']:.0f}ms"
        )

    from benchmarks.bench_async import _json_safe

    payload = _json_safe({
        "bench": "live_placement",
        "smoke": bool(args.smoke),
        "trace_identity": ident,
        "results": results,
    })
    out_path = os.path.abspath(args.out)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, allow_nan=False)
    print(f"wrote {out_path}")

    fails = gate_placement(results, ident)
    for msg in fails:
        print(f"GATE FAIL: {msg}")
    if fails:
        raise SystemExit(1)
    print("placement gates passed: placed mean time-to-target <= 0.95x static, "
          "Jain no worse, >=10% churn, placement=None trace identical")


if __name__ == "__main__":
    main()
